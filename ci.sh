#!/usr/bin/env bash
# CI gates for the Astra repo.
#
# Lanes:
#   tier-1 (default)  — release build + `cargo test -q`. This is the hard
#                       gate every PR must keep green; an advisory
#                       `cargo fmt --check` warns but never fails.
#   tier-2 (TIER2=1)  — strict style lane on top of tier-1:
#                       `cargo fmt --check` and `cargo clippy -- -D warnings`
#                       both FAIL the run. Opt-in so the tier-1 contract is
#                       unchanged; run it before large refactors land.
#
#   bench (BENCH=1)   — perf smoke lane on top of tier-1: runs the
#                       rust/benches/perf_search.rs hetero-cost workload in
#                       fast mode, writes BENCH_search.json at the repo
#                       root, and FAILS if the memo-warm hit-rate on the
#                       reference workload drops below the pinned floor
#                       (override with ASTRA_BENCH_MIN_HIT_RATE).
#
#   ./ci.sh            # tier-1 gate
#   FAST=1 ./ci.sh     # tier-1 minus the release build (debug tests only)
#   TIER2=1 ./ci.sh    # tier-1 + strict fmt/clippy lane
#   BENCH=1 ./ci.sh    # tier-1 + perf smoke bench with hit-rate floor
set -euo pipefail
cd "$(dirname "$0")"
ROOT="$(pwd)"

# The crate manifest may live at the repo root or under rust/ depending on
# how the workspace was materialized; prefer whichever exists.
if [ -f Cargo.toml ]; then
  MANIFEST_DIR=.
elif [ -f rust/Cargo.toml ]; then
  MANIFEST_DIR=rust
else
  echo "ci.sh: no Cargo.toml found (repo root or rust/)" >&2
  exit 1
fi

run() { echo "+ $*" >&2; "$@"; }

cd "$MANIFEST_DIR"

if [ "${FAST:-0}" != "1" ]; then
  run cargo build --release
fi
run cargo test -q

if [ "${TIER2:-0}" = "1" ]; then
  # --- tier-2 lane: strict formatting + lint ---
  if cargo fmt --version >/dev/null 2>&1; then
    run cargo fmt --check
  else
    echo "ci.sh: TIER2 requested but rustfmt unavailable" >&2
    exit 1
  fi
  if cargo clippy --version >/dev/null 2>&1; then
    run cargo clippy -- -D warnings
  else
    echo "ci.sh: TIER2 requested but clippy unavailable" >&2
    exit 1
  fi
fi

if [ "${BENCH:-0}" = "1" ]; then
  # --- bench lane: perf smoke + memo hit-rate floor ---
  # The floor is deliberately conservative: the warm pass on the reference
  # workload re-scores an already-resident profile set, so its hit-rate
  # sits near 1.0 when the memo is healthy; 0.50 is the issue's pinned
  # minimum and catches scope/key regressions with wide margin.
  run env ASTRA_BENCH_FAST=1 \
      ASTRA_BENCH_OUT="$ROOT/BENCH_search.json" \
      ASTRA_BENCH_MIN_HIT_RATE="${ASTRA_BENCH_MIN_HIT_RATE:-0.50}" \
      cargo bench --bench perf_search
fi

if [ "${TIER2:-0}" != "1" ]; then
  # Formatting is advisory in tier-1: parts of the seed predate rustfmt
  # adoption, so a diff here warns but does not fail the gate.
  if cargo fmt --version >/dev/null 2>&1; then
    if ! cargo fmt --check >/dev/null 2>&1; then
      echo "ci.sh: WARNING — cargo fmt --check reports drift (advisory only; TIER2=1 enforces)" >&2
    fi
  else
    echo "ci.sh: rustfmt unavailable; skipping cargo fmt --check" >&2
  fi
fi

echo "ci.sh: all gates passed"
