#!/usr/bin/env bash
# CI gates for the Astra repo.
#
# Lanes:
#   tier-1 (default)  — release build + `cargo test -q`. This is the hard
#                       gate every PR must keep green; an advisory
#                       `cargo fmt --check` warns but never fails.
#   tier-2 (TIER2=1)  — strict style lane on top of tier-1:
#                       `cargo fmt --check` and `cargo clippy -- -D warnings`
#                       both FAIL the run. Opt-in so the tier-1 contract is
#                       unchanged; run it before large refactors land.
#
#   bench (BENCH=1)   — perf smoke lane on top of tier-1: runs the
#                       rust/benches/perf_search.rs hetero-cost workload in
#                       fast mode, writes BENCH_search.json at the repo
#                       root (commit it to track perf PR-over-PR), and
#                       FAILS if the memo-warm hit-rate on the reference
#                       workload drops below the pinned floor (override
#                       with ASTRA_BENCH_MIN_HIT_RATE), if the warm_restore
#                       leg's restored hit-rate drops below its floor
#                       (ASTRA_BENCH_MIN_RESTORE_HIT_RATE), or if the HLO
#                       engine's streamed path disagrees with the native
#                       pick on the fig5 workload
#                       (ASTRA_BENCH_MIN_HLO_PARITY; self-skips without
#                       PJRT artifacts), or if repricing a held frontier
#                       report under a rate-only price-book change beats a
#                       cold re-search by less than the pinned factor
#                       (ASTRA_BENCH_MIN_REPRICE_SPEEDUP, default 100×),
#                       or if the flat-forest η batch kernel beats the
#                       scalar per-row walk by less than the pinned factor
#                       (ASTRA_BENCH_MIN_ETA_SPEEDUP, default 3×).
#
# Tier-1 also runs a persistence roundtrip through the release binary
# (astra warm save → search --warm-load → diff of the canonical --json
# reports against a cold search), a trace smoke (search --trace must
# emit a valid, ts-monotonic Chrome-trace JSONL while leaving the --json
# report byte-identical to an untraced run), a chaos smoke (a fault
# injected via ASTRA_FAILPOINTS into the release binary must surface as
# a typed error line while the process keeps serving), and an
# explain/health smoke (`astra explain` on the fig7 hetero-cost workload
# must certify every prune and stay byte-deterministic; an audited +
# health request pair through `astra batch` must answer with the audit
# object and a ready health line); all are skipped under FAST=1 since
# they need the release build.
#
#   ./ci.sh            # tier-1 gate
#   FAST=1 ./ci.sh     # tier-1 minus the release build (debug tests only)
#   TIER2=1 ./ci.sh    # tier-1 + strict fmt/clippy lane
#   BENCH=1 ./ci.sh    # tier-1 + perf smoke bench with hit-rate floor
set -euo pipefail
cd "$(dirname "$0")"
ROOT="$(pwd)"

# The crate manifest may live at the repo root or under rust/ depending on
# how the workspace was materialized; prefer whichever exists.
if [ -f Cargo.toml ]; then
  MANIFEST_DIR=.
elif [ -f rust/Cargo.toml ]; then
  MANIFEST_DIR=rust
else
  echo "ci.sh: no Cargo.toml found (repo root or rust/)" >&2
  exit 1
fi

run() { echo "+ $*" >&2; "$@"; }

cd "$MANIFEST_DIR"

if [ "${FAST:-0}" != "1" ]; then
  run cargo build --release
fi
run cargo test -q

if [ "${FAST:-0}" != "1" ]; then
  # --- tier-1 persistence roundtrip: save → load → diff reports ---
  # A search restored from a spilled warm snapshot must print the exact
  # canonical report a cold search prints (the --json view excludes wall
  # times, so the diff is byte-meaningful).
  BIN=target/release/astra
  WARMTMP="$(mktemp -d)"
  run "$BIN" warm save "$WARMTMP/warm.jsonl" --model llama2-7b --gpu a800 --gpus 8
  run "$BIN" warm inspect "$WARMTMP/warm.jsonl"
  "$BIN" search --model llama2-7b --gpu a800 --gpus 8 --json > "$WARMTMP/cold.json"
  "$BIN" search --model llama2-7b --gpu a800 --gpus 8 \
      --warm-load "$WARMTMP/warm.jsonl" --json \
      > "$WARMTMP/restored.json" 2> "$WARMTMP/restored.err"
  cat "$WARMTMP/restored.err" >&2
  # The diff alone cannot catch a silent no-restore (a cold start prints
  # the same canonical report by design) — also require that the scope
  # actually imported, with nothing rejected.
  run grep -q "restored 1 scope" "$WARMTMP/restored.err"
  run grep -q "rejected 0" "$WARMTMP/restored.err"
  run diff "$WARMTMP/cold.json" "$WARMTMP/restored.json"
  rm -rf "$WARMTMP"
  echo "ci.sh: persistence roundtrip ok (cold == restored, 1 scope imported)" >&2

  # --- tier-1 trace smoke: flight recorder must not change the picks ---
  # Run the same search untraced and traced; the canonical --json reports
  # must be byte-identical, and the trace file must pass trace-check
  # (every line valid JSON, `ts` nondecreasing).
  TRACETMP="$(mktemp -d)"
  "$BIN" search --model llama2-7b --gpu a800 --gpus 8 --json > "$TRACETMP/plain.json"
  "$BIN" search --model llama2-7b --gpu a800 --gpus 8 --json \
      --trace "$TRACETMP/t.jsonl" > "$TRACETMP/traced.json"
  run diff "$TRACETMP/plain.json" "$TRACETMP/traced.json"
  run test -s "$TRACETMP/t.jsonl"
  run "$BIN" trace-check "$TRACETMP/t.jsonl"
  rm -rf "$TRACETMP"
  echo "ci.sh: trace smoke ok (traced report identical, trace valid and monotonic)" >&2

  # --- tier-1 chaos smoke: injected faults surface as typed lines ---
  # Arm the scoring seam for exactly one panic through the env grammar
  # (the production binary needs no wiring to become chaos-testable).
  # The first request must come back as an isolated `kind:"panic"` error
  # line, the identical second request must then succeed with a real
  # search, and the process must exit 0 — an injected fault degrades one
  # line, never the service. Deeper scripted schedules live in
  # rust/tests/chaos.rs (run by `cargo test` above in its own process).
  CHAOSTMP="$(mktemp -d)"
  printf '%s\n' \
    '{"id":"boom","model":"llama2-7b","gpu":"a800","gpus":8}' \
    '{"id":"ok","model":"llama2-7b","gpu":"a800","gpus":8}' \
    > "$CHAOSTMP/reqs.jsonl"
  run env ASTRA_FAILPOINTS="engine.score=panic:1:1" ASTRA_FAILPOINT_SEED=42 \
      "$BIN" batch "$CHAOSTMP/reqs.jsonl" --max-batch 1 --retries 0 \
      > "$CHAOSTMP/out.jsonl"
  run test "$(wc -l < "$CHAOSTMP/out.jsonl")" -eq 2
  run grep -q '"id":"boom","kind":"panic"' "$CHAOSTMP/out.jsonl"
  run grep -q '"retryable":false' "$CHAOSTMP/out.jsonl"
  run grep -q '"id":"ok"' "$CHAOSTMP/out.jsonl"
  run grep -q '"source":"search"' "$CHAOSTMP/out.jsonl"
  rm -rf "$CHAOSTMP"
  echo "ci.sh: chaos smoke ok (injected panic isolated to one typed line, service recovered)" >&2

  # --- tier-1 explain/health smoke: the decision audit through the binary ---
  # A $1 ceiling sits below every pool's lower-bound bill on the fig7-style
  # three-type workload, so the audit must show zero admitted pools and
  # every prune as `pruned_budget` — and every pruned pool must carry its
  # certifying evidence object. The canonical audit JSON is assembled by
  # the executor's serial replay, so a second run is byte-identical.
  AUDTMP="$(mktemp -d)"
  "$BIN" explain --mode hetero-cost --model llama2-7b \
      --hetero 'a800:8,h100:8,v100:8' --max-money 1 --json > "$AUDTMP/tight.json"
  run grep -q '"astra_audit": 1' "$AUDTMP/tight.json"
  run test "$(grep -c '"decision": "pruned_budget"' "$AUDTMP/tight.json")" -gt 0
  run test "$(grep -c '"decision": "admitted"' "$AUDTMP/tight.json")" -eq 0
  run test "$(grep -c '"decision": "pruned' "$AUDTMP/tight.json")" \
      -eq "$(grep -c '"evidence"' "$AUDTMP/tight.json")"
  "$BIN" explain --mode hetero-cost --model llama2-7b \
      --hetero 'a800:8,h100:8,v100:8' --max-money 1 --json > "$AUDTMP/tight2.json"
  run diff "$AUDTMP/tight.json" "$AUDTMP/tight2.json"
  # --audit is a pure view switch: the canonical report of an audited
  # search must be byte-identical to the unaudited one (the audited run
  # appends the audit JSON after the report, so compare the report prefix).
  "$BIN" search --model llama2-7b --gpu a800 --gpus 8 --json > "$AUDTMP/plain.json"
  "$BIN" search --model llama2-7b --gpu a800 --gpus 8 --json --audit > "$AUDTMP/audited.json"
  run test "$(wc -l < "$AUDTMP/audited.json")" -gt "$(wc -l < "$AUDTMP/plain.json")"
  head -n "$(wc -l < "$AUDTMP/plain.json")" "$AUDTMP/audited.json" > "$AUDTMP/audited_report.json"
  run diff "$AUDTMP/plain.json" "$AUDTMP/audited_report.json"
  # Health through the wire grammar: after a real search the health line
  # must report ready with a live latency window (compact wire format).
  printf '%s\n' \
    '{"id":"warm","model":"llama2-7b","gpu":"a800","gpus":8}' \
    '{"cmd":"health","id":"h"}' \
    > "$AUDTMP/reqs.jsonl"
  run "$BIN" batch "$AUDTMP/reqs.jsonl" --max-batch 1 --retries 0 > "$AUDTMP/out.jsonl"
  run test "$(wc -l < "$AUDTMP/out.jsonl")" -eq 2
  run grep -q '"id":"h"' "$AUDTMP/out.jsonl"
  run grep -q '"ready":true' "$AUDTMP/out.jsonl"
  run grep -q '"p50_ms"' "$AUDTMP/out.jsonl"
  rm -rf "$AUDTMP"
  echo "ci.sh: explain/health smoke ok (all prunes certified, audit byte-deterministic, health ready)" >&2
fi

if [ "${TIER2:-0}" = "1" ]; then
  # --- tier-2 lane: strict formatting + lint ---
  if cargo fmt --version >/dev/null 2>&1; then
    run cargo fmt --check
  else
    echo "ci.sh: TIER2 requested but rustfmt unavailable" >&2
    exit 1
  fi
  if cargo clippy --version >/dev/null 2>&1; then
    run cargo clippy -- -D warnings
  else
    echo "ci.sh: TIER2 requested but clippy unavailable" >&2
    exit 1
  fi
fi

if [ "${BENCH:-0}" = "1" ]; then
  # --- bench lane: perf smoke + memo hit-rate floor ---
  # The floor is deliberately conservative: the warm pass on the reference
  # workload re-scores an already-resident profile set, so its hit-rate
  # sits near 1.0 when the memo is healthy; 0.50 is the issue's pinned
  # minimum and catches scope/key regressions with wide margin.
  # The restore floor mirrors the warm floor: a healthy snapshot replays
  # the exact profile set, so its hit-rate sits near 1.0; 0.50 catches
  # format/digest regressions with wide margin.
  # The HLO-parity smoke additionally asserts the HLO engine's streamed
  # per-pool path picks the same strategy as the native engine on the fig5
  # workload; it self-skips when the PJRT artifacts are absent.
  # The frontier_reprice leg re-bills a held frontier report under a
  # rate-only price-book change and must beat a cold re-search under the
  # same book by ≥100× (the reprice is arithmetic over the cached skeleton;
  # the cold search re-runs the whole sweep) while staying byte-identical.
  # The eta_kernel floor pins the flat-forest batch kernel at ≥3× over the
  # scalar per-row walk (the cold_forest end-to-end leg when trained
  # artifacts exist, else the synthetic micro-leg), with bit-identical
  # predictions asserted before timing.
  run env ASTRA_BENCH_FAST=1 \
      ASTRA_BENCH_OUT="$ROOT/BENCH_search.json" \
      ASTRA_BENCH_MIN_HIT_RATE="${ASTRA_BENCH_MIN_HIT_RATE:-0.50}" \
      ASTRA_BENCH_MIN_RESTORE_HIT_RATE="${ASTRA_BENCH_MIN_RESTORE_HIT_RATE:-0.50}" \
      ASTRA_BENCH_MIN_HLO_PARITY="${ASTRA_BENCH_MIN_HLO_PARITY:-1.0}" \
      ASTRA_BENCH_MIN_REPRICE_SPEEDUP="${ASTRA_BENCH_MIN_REPRICE_SPEEDUP:-100}" \
      ASTRA_BENCH_MIN_ETA_SPEEDUP="${ASTRA_BENCH_MIN_ETA_SPEEDUP:-3}" \
      cargo bench --bench perf_search
  echo "ci.sh: BENCH_search.json written at the repo root — commit it to extend the perf trajectory" >&2
fi

if [ "${TIER2:-0}" != "1" ]; then
  # Formatting is advisory in tier-1: parts of the seed predate rustfmt
  # adoption, so a diff here warns but does not fail the gate.
  if cargo fmt --version >/dev/null 2>&1; then
    if ! cargo fmt --check >/dev/null 2>&1; then
      echo "ci.sh: WARNING — cargo fmt --check reports drift (advisory only; TIER2=1 enforces)" >&2
    fi
  else
    echo "ci.sh: rustfmt unavailable; skipping cargo fmt --check" >&2
  fi
fi

echo "ci.sh: all gates passed"
