#!/usr/bin/env bash
# Tier-1 gate for the Astra repo: release build + tests, plus a formatting
# check when rustfmt is installed. Run from anywhere; it cds to the repo.
#
#   ./ci.sh          # full gate
#   FAST=1 ./ci.sh   # skip the release build (tests only, debug profile)
set -euo pipefail
cd "$(dirname "$0")"

# The crate manifest may live at the repo root or under rust/ depending on
# how the workspace was materialized; prefer whichever exists.
if [ -f Cargo.toml ]; then
  MANIFEST_DIR=.
elif [ -f rust/Cargo.toml ]; then
  MANIFEST_DIR=rust
else
  echo "ci.sh: no Cargo.toml found (repo root or rust/)" >&2
  exit 1
fi

run() { echo "+ $*" >&2; "$@"; }

cd "$MANIFEST_DIR"

if [ "${FAST:-0}" != "1" ]; then
  run cargo build --release
fi
run cargo test -q

# Formatting is advisory: parts of the seed predate rustfmt adoption, so a
# diff here warns but does not fail the gate (the build+test gate above is
# the tier-1 contract).
if cargo fmt --version >/dev/null 2>&1; then
  if ! cargo fmt --check >/dev/null 2>&1; then
    echo "ci.sh: WARNING — cargo fmt --check reports drift (advisory only)" >&2
  fi
else
  echo "ci.sh: rustfmt unavailable; skipping cargo fmt --check" >&2
fi

echo "ci.sh: all gates passed"
