#!/usr/bin/env bash
# CI gates for the Astra repo.
#
# Lanes:
#   tier-1 (default)  — release build + `cargo test -q`. This is the hard
#                       gate every PR must keep green; an advisory
#                       `cargo fmt --check` warns but never fails.
#   tier-2 (TIER2=1)  — strict style lane on top of tier-1:
#                       `cargo fmt --check` and `cargo clippy -- -D warnings`
#                       both FAIL the run. Opt-in so the tier-1 contract is
#                       unchanged; run it before large refactors land.
#
#   ./ci.sh            # tier-1 gate
#   FAST=1 ./ci.sh     # tier-1 minus the release build (debug tests only)
#   TIER2=1 ./ci.sh    # tier-1 + strict fmt/clippy lane
set -euo pipefail
cd "$(dirname "$0")"

# The crate manifest may live at the repo root or under rust/ depending on
# how the workspace was materialized; prefer whichever exists.
if [ -f Cargo.toml ]; then
  MANIFEST_DIR=.
elif [ -f rust/Cargo.toml ]; then
  MANIFEST_DIR=rust
else
  echo "ci.sh: no Cargo.toml found (repo root or rust/)" >&2
  exit 1
fi

run() { echo "+ $*" >&2; "$@"; }

cd "$MANIFEST_DIR"

if [ "${FAST:-0}" != "1" ]; then
  run cargo build --release
fi
run cargo test -q

if [ "${TIER2:-0}" = "1" ]; then
  # --- tier-2 lane: strict formatting + lint ---
  if cargo fmt --version >/dev/null 2>&1; then
    run cargo fmt --check
  else
    echo "ci.sh: TIER2 requested but rustfmt unavailable" >&2
    exit 1
  fi
  if cargo clippy --version >/dev/null 2>&1; then
    run cargo clippy -- -D warnings
  else
    echo "ci.sh: TIER2 requested but clippy unavailable" >&2
    exit 1
  fi
else
  # Formatting is advisory in tier-1: parts of the seed predate rustfmt
  # adoption, so a diff here warns but does not fail the gate.
  if cargo fmt --version >/dev/null 2>&1; then
    if ! cargo fmt --check >/dev/null 2>&1; then
      echo "ci.sh: WARNING — cargo fmt --check reports drift (advisory only; TIER2=1 enforces)" >&2
    fi
  else
    echo "ci.sh: rustfmt unavailable; skipping cargo fmt --check" >&2
  fi
fi

echo "ci.sh: all gates passed"
