//! rust ↔ python hardware-truth lockstep.
//!
//! `python/compile/effdata.py` re-implements `rust/src/hw/` for the GBDT
//! training data; any drift between the two silently corrupts the η
//! predictors. `aot.py` exports deterministic noise-free samples
//! (`artifacts/eff_samples.json`); this test replays them through the rust
//! implementation and requires bit-for-bit-grade agreement.

use astra::gpu::GpuCatalog;
use astra::hw;
use astra::runtime::artifacts_dir;

#[test]
fn eff_samples_match_rust_hw() {
    let path = artifacts_dir().join("eff_samples.json");
    if !path.exists() {
        eprintln!("SKIP: {path:?} missing — run `make artifacts` first");
        return;
    }
    let v = astra::json::from_file(&path).unwrap();
    let catalog = GpuCatalog::builtin();

    let comp = v.req_arr("comp").unwrap();
    assert!(comp.len() >= 100, "too few comp samples");
    for s in comp {
        let gpu = catalog.find(s.req_str("gpu").unwrap()).unwrap();
        let spec = catalog.spec(gpu);
        let flops = s.req_f64("flops").unwrap();
        let dim = s.req_f64("min_dim").unwrap();
        let inten = s.req_f64("intensity").unwrap();
        let want = s.req_f64("eta").unwrap();
        let got = hw::eta_comp(spec, flops, dim, inten);
        assert!(
            (got - want).abs() / want < 1e-9,
            "eta_comp drift on {}: rust {got} vs python {want}",
            spec.name
        );
        // Feature vectors must agree too (forest input contract).
        let feats = hw::comp_features(spec, flops, dim, inten);
        let pyfeats = s.req_f64_arr("features").unwrap();
        assert_eq!(feats.len(), pyfeats.len());
        for (a, b) in feats.iter().zip(&pyfeats) {
            assert!((a - b).abs() < 1e-9, "comp feature drift {a} vs {b}");
        }
    }

    let comm = v.req_arr("comm").unwrap();
    assert!(comm.len() >= 100, "too few comm samples");
    for s in comm {
        let gpu = catalog.find(s.req_str("gpu").unwrap()).unwrap();
        let spec = catalog.spec(gpu);
        let bytes = s.req_f64("bytes").unwrap();
        let bw = s.req_f64("bw_gbs").unwrap();
        let parts = s.req_f64("participants").unwrap();
        let want = s.req_f64("eta").unwrap();
        let got = hw::eta_comm(spec, bytes, bw, parts);
        assert!(
            (got - want).abs() / want < 1e-9,
            "eta_comm drift on {}: rust {got} vs python {want}",
            spec.name
        );
        let feats = hw::comm_features(spec, bytes, bw, parts);
        let pyfeats = s.req_f64_arr("features").unwrap();
        for (a, b) in feats.iter().zip(&pyfeats) {
            assert!((a - b).abs() < 1e-9, "comm feature drift {a} vs {b}");
        }
    }
}
