//! Property-based tests over coordinator invariants (randomized with the
//! in-tree PRNG — no proptest crate offline; shrinking is replaced by
//! printing the failing seed/case).

use astra::gpu::GpuCatalog;
use astra::hetero::HeteroSolver;
use astra::memory::MemoryModel;
use astra::model::ModelRegistry;
use astra::pareto::{OptimalPool, PoolEntry};
use astra::prng::Rng;
use astra::strategy::{SearchSpace, SpaceConfig};

/// Any strategy the generator emits must be structurally valid, consume
/// exactly the requested GPU count, and round-trip its microbatch math.
#[test]
fn prop_generator_soundness() {
    let reg = ModelRegistry::builtin();
    let cat = GpuCatalog::builtin();
    let mut rng = Rng::new(2024);
    let space = SearchSpace::new(SpaceConfig::default());
    for case in 0..30 {
        let model = *rng.choose(&reg.paper_seven());
        let count = *rng.choose(&[32usize, 64, 96, 128, 256, 512, 1024]);
        let gpu = rng.below(cat.len() as u64) as usize;
        let strategies = space.homogeneous(model, &cat, gpu, count);
        for s in &strategies {
            s.validate(model)
                .unwrap_or_else(|e| panic!("case {case} ({}, {count}): {e}", model.name));
            assert_eq!(s.num_gpus(), count, "case {case}");
            assert_eq!(
                s.num_microbatches() * s.dp * s.micro_batch,
                s.global_batch,
                "case {case}: K·dp·mbs ≠ gbs"
            );
        }
    }
}

/// The generator is exhaustive over its declared sub-space: every valid
/// (tp, pp) division of the GPU count appears at least once.
#[test]
fn prop_generator_completeness() {
    let reg = ModelRegistry::builtin();
    let cat = GpuCatalog::builtin();
    let space = SearchSpace::new(SpaceConfig::default());
    let mut rng = Rng::new(7);
    for _ in 0..10 {
        let model = *rng.choose(&reg.paper_seven());
        let count = *rng.choose(&[64usize, 128, 256]);
        let strategies = space.homogeneous(model, &cat, 0, count);
        let mut seen: std::collections::BTreeSet<(usize, usize)> = Default::default();
        for s in &strategies {
            seen.insert((s.tp, s.pp()));
        }
        for tp in space.valid_tps(model, &cat) {
            if count % tp != 0 {
                continue;
            }
            for pp in space.valid_pps(model, count, tp) {
                // (tp, pp) is representable iff some mbs divides gbs/dp —
                // mbs=1 always works, so it must be present.
                assert!(
                    seen.contains(&(tp, pp)),
                    "{} @{count}: missing (tp={tp}, pp={pp})",
                    model.name
                );
            }
        }
    }
}

/// Memory model monotonicity: more tensor parallelism never increases the
/// per-GPU peak; a bigger micro-batch never decreases activations.
#[test]
fn prop_memory_monotonicity() {
    let reg = ModelRegistry::builtin();
    let cat = GpuCatalog::builtin();
    let mem = MemoryModel::default();
    let space = SearchSpace::new(SpaceConfig::default());
    let mut rng = Rng::new(99);
    let mut checked = 0;
    for _ in 0..200 {
        let model = *rng.choose(&reg.paper_seven());
        let strategies = space.homogeneous(model, &cat, 0, 128);
        let s = &strategies[rng.below(strategies.len() as u64) as usize];
        // tp doubling comparison, same everything else.
        let mut s2 = s.clone();
        s2.tp *= 2;
        s2.dp = (s2.dp + 1) / 2; // keep gpu count roughly stable; only
                                 // memory is evaluated, validity is not needed
        if model.heads % s2.tp != 0 || s2.tp > 8 {
            continue;
        }
        let m1 = mem.peak_bytes(model, s);
        let m2 = mem.peak_bytes(model, &s2);
        assert!(
            m2 <= m1 * 1.01,
            "{}: tp {}→{} grew memory {m1:.3e}→{m2:.3e} ({})",
            model.name,
            s.tp,
            s2.tp,
            s.summary()
        );
        checked += 1;
    }
    assert!(checked > 50, "too few comparable cases: {checked}");
}

/// Pareto frontier: random candidate clouds — no frontier point dominated,
/// every non-frontier point dominated by some frontier point.
#[test]
fn prop_pareto_frontier_complete() {
    let mut rng = Rng::new(555);
    for case in 0..100 {
        let n = 1 + rng.below(300) as usize;
        let cands: Vec<PoolEntry> = (0..n)
            .map(|i| PoolEntry {
                idx: i,
                throughput: rng.range_f64(1.0, 100.0).round(),
                cost: rng.range_f64(1.0, 100.0).round(),
            })
            .collect();
        let pool = OptimalPool::build(cands.clone());
        assert!(pool.is_valid_frontier(), "case {case}");
        for c in &cands {
            let covered = pool
                .entries()
                .iter()
                .any(|f| f.throughput >= c.throughput && f.cost <= c.cost);
            assert!(covered, "case {case}: candidate {c:?} not covered by frontier");
        }
    }
}

/// Eq. 23 bookkeeping: every hetero assignment covers all layers/stages and
/// respects per-type stage budgets, for random shapes.
#[test]
fn prop_hetero_partitions_sound() {
    let cat = GpuCatalog::builtin();
    let solver = HeteroSolver::default();
    let mut rng = Rng::new(31);
    for case in 0..40 {
        let layers = 8 + 2 * rng.below(40) as usize;
        let pp = 2 + rng.below(8) as usize;
        if pp > layers {
            continue;
        }
        let cap_a = (pp / 2 + rng.below(8) as usize).max(1);
        let cap_h = (pp / 2 + rng.below(8) as usize).max(1);
        let budgets = HeteroSolver::budgets(
            &cat,
            &[(cat.find("a800").unwrap(), cap_a * 4), (cat.find("h100").unwrap(), cap_h * 4)],
            2,
            2,
        );
        for ca in solver.enumerate_exhaustive(layers, pp, &budgets) {
            assert_eq!(ca.pp(), pp, "case {case}");
            assert_eq!(ca.layers(), layers, "case {case}");
            for seg in &ca.segments {
                let budget = budgets.iter().find(|b| b.gpu == seg.gpu).unwrap();
                assert!(seg.stages <= budget.max_stages, "case {case}: budget violated");
                assert!(seg.layers_per_stage >= 1);
            }
        }
    }
}

/// The pruned solver never emits an assignment the exhaustive one wouldn't.
#[test]
fn prop_pruned_is_subset() {
    let cat = GpuCatalog::builtin();
    let solver = HeteroSolver::default();
    let budgets = HeteroSolver::budgets(
        &cat,
        &[(cat.find("a800").unwrap(), 64), (cat.find("h100").unwrap(), 64)],
        2,
        4,
    );
    let mut rng = Rng::new(17);
    for _ in 0..15 {
        let layers = 16 + 2 * rng.below(24) as usize;
        let pp = 2 + rng.below(4) as usize;
        let ex: std::collections::BTreeSet<String> = solver
            .enumerate_exhaustive(layers, pp, &budgets)
            .iter()
            .map(|c| format!("{:?}", c.segments))
            .collect();
        for c in solver.enumerate_pruned(layers, pp, &budgets) {
            assert!(ex.contains(&format!("{:?}", c.segments)), "pruned ∉ exhaustive: {c:?}");
        }
    }
}

/// JSON substrate: random value trees round-trip compact and pretty.
#[test]
fn prop_json_roundtrip() {
    use astra::json::{parse, to_string, to_string_pretty, Value};
    fn gen(rng: &mut Rng, depth: usize) -> Value {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Value::Null,
            1 => Value::Bool(rng.bool()),
            2 => {
                // Mix integers and dyadic fractions (exact in f64).
                if rng.bool() {
                    Value::Num(rng.range_u64(0, 1 << 50) as f64 - (1u64 << 49) as f64)
                } else {
                    Value::Num(rng.range_f64(-1e9, 1e9))
                }
            }
            3 => {
                let n = rng.below(12) as usize;
                let s: String = (0..n)
                    .map(|_| char::from_u32(rng.range_u64(1, 0xD7FF) as u32).unwrap_or('x'))
                    .collect();
                Value::Str(s)
            }
            4 => Value::Arr((0..rng.below(5)).map(|_| gen(rng, depth - 1)).collect()),
            _ => {
                let mut o = Value::obj();
                for i in 0..rng.below(5) {
                    o = o.set(&format!("k{i}"), gen(rng, depth - 1));
                }
                o
            }
        }
    }
    let mut rng = Rng::new(808);
    for case in 0..300 {
        let v = gen(&mut rng, 4);
        let compact = parse(&to_string(&v)).unwrap_or_else(|e| panic!("case {case}: {e}"));
        assert_eq!(compact, v, "case {case} compact");
        let pretty = parse(&to_string_pretty(&v)).unwrap();
        assert_eq!(pretty, v, "case {case} pretty");
    }
}

/// Cost memoization: random subsets of random searches agree with the
/// direct path bit-for-bit across models and cluster shapes.
#[test]
fn prop_memoized_scoring_equivalence() {
    use astra::cost::{CostModel, EtaProvider};
    let reg = ModelRegistry::builtin();
    let cat = GpuCatalog::builtin();
    let cost = CostModel::new(cat.clone(), EtaProvider::Analytic);
    let space = SearchSpace::new(SpaceConfig::default());
    let mut rng = Rng::new(4242);
    for _ in 0..8 {
        let model = *rng.choose(&reg.paper_seven());
        let count = *rng.choose(&[64usize, 128, 512]);
        let strategies = space.homogeneous(model, &cat, rng.below(3) as usize, count);
        if strategies.is_empty() {
            continue;
        }
        let sample: Vec<&astra::strategy::ParallelStrategy> = strategies
            .iter()
            .step_by(1 + rng.below(50) as usize)
            .take(100)
            .collect();
        let batch = cost.evaluate_batch(model, &sample);
        for (s, b) in sample.iter().zip(&batch) {
            let d = cost.evaluate(model, s);
            assert!((d.step_time - b.step_time).abs() <= 1e-12 * d.step_time);
        }
    }
}

/// Simulator failure injection: extreme noise and tiny/huge microbatch
/// counts never produce non-finite or non-positive times, and more noise
/// never changes results by more than the noise scale allows.
#[test]
fn prop_simulator_robustness() {
    use astra::simulator::{PipelineSimulator, SimConfig};
    let reg = ModelRegistry::builtin();
    let cat = GpuCatalog::builtin();
    let model = reg.get("llama2-7b").unwrap();
    let space = SearchSpace::new(SpaceConfig::default());
    let mem = MemoryModel::default();
    let strategies: Vec<_> = space
        .homogeneous(model, &cat, 1, 64)
        .into_iter()
        .filter(|s| mem.fits(model, s, &cat))
        .step_by(211)
        .take(12)
        .collect();
    let mut rng = Rng::new(5);
    for s in &strategies {
        for sigma in [0.0, 0.05, 0.2] {
            let sim = PipelineSimulator::new(
                cat.clone(),
                SimConfig { seed: rng.next_u64(), noise_sigma: sigma },
            );
            let r = sim.measure(model, s);
            assert!(r.step_time.is_finite() && r.step_time > 0.0, "sigma {sigma}");
            assert!(r.tokens_per_s.is_finite() && r.tokens_per_s > 0.0);
            assert!(r.pipeline_time <= r.step_time + 1e-12);
        }
    }
}
