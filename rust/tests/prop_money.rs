//! Property-test harness for the money-search substrate (seeded with the
//! in-tree PRNG — no proptest offline; failures print the seed/case):
//! `OptimalPool::build` invariants on random candidate clouds,
//! `best_within_budget` monotonicity, price-book algebra, and soundness of
//! the branch-and-bound pool bounds against the real cost model.

use astra::gpu::GpuCatalog;
use astra::model::ModelRegistry;
use astra::pareto::{DominancePruner, MoneyModel, OptimalPool, PoolEntry};
use astra::pricing::{PriceBook, PriceEntry};
use astra::prng::Rng;
use astra::strategy::{SearchSpace, SpaceConfig};

fn random_cloud(rng: &mut Rng, n: usize, rounded: bool) -> Vec<PoolEntry> {
    (0..n)
        .map(|i| {
            let (p, c) = (rng.range_f64(1.0, 500.0), rng.range_f64(1.0, 500.0));
            PoolEntry {
                idx: i,
                throughput: if rounded { p.round() } else { p },
                cost: if rounded { c.round() } else { c },
            }
        })
        .collect()
}

/// Frontier validity + dominance over every dropped candidate, including
/// heavy-tie clouds (rounded coordinates force duplicates).
#[test]
fn prop_frontier_valid_and_dominates_dropped() {
    let mut rng = Rng::new(0xF00D);
    for case in 0..120 {
        let n = 1 + rng.below(250) as usize;
        let cands = random_cloud(&mut rng, n, case % 2 == 0);
        let pool = OptimalPool::build(cands.clone());
        assert!(pool.is_valid_frontier(), "case {case}");
        assert!(!pool.is_empty(), "case {case}: frontier empty for nonempty cloud");
        let kept: std::collections::BTreeSet<usize> =
            pool.entries().iter().map(|e| e.idx).collect();
        for c in &cands {
            if kept.contains(&c.idx) {
                continue;
            }
            // Every dropped candidate is dominated-or-equal by a frontier
            // entry (Eq. 29/30: the pool loses nothing anyone would pick).
            assert!(
                pool.entries()
                    .iter()
                    .any(|f| f.throughput >= c.throughput && f.cost <= c.cost),
                "case {case}: dropped {c:?} not dominated by the frontier"
            );
        }
    }
}

/// Non-finite candidates never reach the frontier.
#[test]
fn prop_frontier_filters_non_finite() {
    let mut rng = Rng::new(77);
    for case in 0..30 {
        let mut cands = random_cloud(&mut rng, 40, false);
        for bad in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            let i = rng.below(cands.len() as u64) as usize;
            cands[i].cost = bad;
            let j = rng.below(cands.len() as u64) as usize;
            cands[j].throughput = bad;
        }
        let pool = OptimalPool::build(cands);
        assert!(pool.is_valid_frontier(), "case {case}");
        for e in pool.entries() {
            assert!(e.throughput.is_finite() && e.cost.is_finite(), "case {case}: {e:?}");
        }
    }
}

/// `best_within_budget` is monotone in the budget: paying more never buys
/// a slower plan, and the pick always respects the ceiling.
#[test]
fn prop_best_within_budget_monotone() {
    let mut rng = Rng::new(0xB1D6E7);
    for case in 0..80 {
        let pool = OptimalPool::build(random_cloud(&mut rng, 1 + rng.below(200) as usize, false));
        let mut budgets: Vec<f64> = (0..20).map(|_| rng.range_f64(0.0, 600.0)).collect();
        budgets.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mut last: Option<f64> = None;
        for &b in &budgets {
            match pool.best_within_budget(b) {
                Some(e) => {
                    assert!(e.cost <= b, "case {case}: pick over budget");
                    if let Some(prev) = last {
                        assert!(
                            e.throughput >= prev,
                            "case {case}: budget {b} bought {} < {} tokens/s",
                            e.throughput,
                            prev
                        );
                    }
                    last = Some(e.throughput);
                }
                None => {
                    assert!(last.is_none(), "case {case}: raising the budget lost the pick");
                }
            }
        }
        // An unlimited budget returns the fastest frontier entry.
        if let Some(first) = pool.entries().first() {
            let pick = pool.best_within_budget(f64::INFINITY).unwrap();
            assert_eq!(pick.throughput, first.throughput, "case {case}");
        }
    }
}

/// Random rate cards: the effective rate is always spot/on-demand × the
/// active multiplier, and lookups never cross GPU names.
#[test]
fn prop_price_book_rate_algebra() {
    let mut rng = Rng::new(0xCA4D);
    for case in 0..60 {
        let mut book = PriceBook::empty();
        let names = ["a", "bb", "ccc", "dddd", "e5"];
        let n = 1 + rng.below(names.len() as u64) as usize;
        let mut expected: Vec<(String, f64, f64)> = Vec::new();
        for name in names.iter().take(n) {
            let od = rng.range_f64(0.5, 10.0);
            let spot = od * rng.range_f64(0.1, 1.0);
            book.upsert(PriceEntry {
                gpu: name.to_string(),
                on_demand_per_hour: od,
                spot_per_hour: spot,
            });
            expected.push((name.to_string(), od, spot));
        }
        for m in book.tod_multipliers.iter_mut() {
            *m = rng.range_f64(0.25, 2.0);
        }
        book.use_spot = rng.bool();
        let hour = rng.below(24) as usize;
        book.hour = if rng.bool() { Some(hour) } else { None };
        book.validate().unwrap_or_else(|e| panic!("case {case}: {e}"));
        for (name, od, spot) in &expected {
            let base = if book.use_spot { *spot } else { *od };
            let mult = match book.hour {
                Some(h) => book.tod_multipliers[h],
                None => 1.0,
            };
            let got = book.rate_per_hour(name).unwrap();
            assert!(
                (got - base * mult).abs() < 1e-12 * base.max(1.0),
                "case {case}: {name} rate {got} != {base}·{mult}"
            );
        }
        assert!(book.rate_per_hour("zz-not-listed").is_none());
    }
}

/// Soundness of the branch-and-bound bounds: for random pools of real
/// strategies, every scored plan's money is ≥ the pool's lower bound and
/// its throughput ≤ the pool's upper bound — the pruner can never discard
/// a plan the exhaustive search would have selected.
#[test]
fn prop_pool_bounds_sound_against_cost_model() {
    use astra::cost::{CostModel, EtaProvider};
    let reg = ModelRegistry::builtin();
    let cat = GpuCatalog::builtin();
    let cost = CostModel::new(cat.clone(), EtaProvider::Analytic);
    let space = SearchSpace::new(SpaceConfig::default());
    let mut rng = Rng::new(0x50_u64);
    let mut mm = MoneyModel::default();
    let mut checked = 0usize;
    for case in 0..12 {
        mm.book.use_spot = rng.bool();
        let model = *rng.choose(&reg.paper_seven());
        let count = *rng.choose(&[16usize, 32, 64, 128]);
        let gpu = rng.below(cat.len() as u64) as usize;
        let strategies = space.homogeneous(model, &cat, gpu, count);
        if strategies.is_empty() {
            continue;
        }
        for s in strategies.iter().step_by(1 + rng.below(80) as usize).take(40) {
            let gpus = s.cluster.gpus_by_type(s.tp, s.dp);
            let (ub_tput, lb_usd) = mm.pool_bounds(model, &gpus, &cat);
            let bd = cost.evaluate(model, s);
            let usd = mm.cost_usd(model, s, &cat, bd.step_time);
            assert!(
                bd.tokens_per_s <= ub_tput * (1.0 + 1e-9),
                "case {case}: {} tput {} above bound {} ({})",
                model.name,
                bd.tokens_per_s,
                ub_tput,
                s.summary()
            );
            assert!(
                usd >= lb_usd * (1.0 - 1e-9),
                "case {case}: {} ${usd} below bound ${lb_usd} ({})",
                model.name,
                s.summary()
            );
            checked += 1;
        }
    }
    assert!(checked > 100, "too few strategies checked: {checked}");
}

/// Frontier repricing: for *rate-only* price-book changes (same GPU
/// names, arbitrary new rates / time-of-day multipliers / spot flag /
/// hour), `SearchReport::reprice` on a frontier report equals a cold
/// frontier search under the new book — byte-for-byte on the canonical
/// report JSON. This is the property the service's reprice-without-
/// re-search cache path rests on; membership changes (a new GPU type)
/// are out of scope here and force a re-search at the service layer.
#[test]
fn prop_frontier_reprice_equals_cold_search_under_rate_changes() {
    use astra::coordinator::{AstraEngine, EngineConfig, SearchRequest};
    use astra::report::report_json;

    let space = SpaceConfig {
        tp_candidates: vec![1, 2],
        max_pp: 2,
        mbs_candidates: vec![1],
        vpp_candidates: vec![1],
        seq_parallel_options: vec![true],
        dist_opt_options: vec![true],
        offload_options: vec![false],
        recompute_none: true,
        recompute_selective: false,
        recompute_full: false,
        ..SpaceConfig::default()
    };
    let engine_with = |book: PriceBook| {
        AstraEngine::new(
            GpuCatalog::builtin(),
            EngineConfig {
                use_forests: false,
                space: space.clone(),
                money: MoneyModel { book, ..MoneyModel::default() },
                ..EngineConfig::default()
            },
        )
    };
    let catalog = GpuCatalog::builtin();
    let model = ModelRegistry::builtin().get("llama2-7b").unwrap().clone();
    let req = SearchRequest::frontier(&[("a800", 4), ("h100", 4)], model.clone()).unwrap();

    let base_book = PriceBook::builtin();
    let cold_a = engine_with(base_book.clone()).search(&req).unwrap();
    assert!(cold_a.frontier.is_some(), "frontier mode must carry the skeleton");
    assert!(!cold_a.pool.is_empty(), "frontier search found no points");

    let mut rng = Rng::new(0xFA57_CA5E);
    for case in 0..6 {
        // Rate-only mutation: every listed GPU keeps its name, everything
        // priced about it is redrawn.
        let mut book = base_book.clone();
        for e in base_book.entries() {
            let od = rng.range_f64(0.2, 12.0);
            let spot = od * rng.range_f64(0.1, 1.0);
            book.upsert(PriceEntry {
                gpu: e.gpu.clone(),
                on_demand_per_hour: od,
                spot_per_hour: spot,
            });
        }
        for m in book.tod_multipliers.iter_mut() {
            *m = rng.range_f64(0.25, 2.0);
        }
        book.use_spot = rng.bool();
        book.hour = if rng.bool() { Some(rng.below(24) as usize) } else { None };
        book.validate().unwrap_or_else(|e| panic!("case {case}: {e}"));

        let money = MoneyModel { book: book.clone(), ..MoneyModel::default() };
        let repriced =
            cold_a.reprice(&model, &catalog, &money).expect("frontier report must reprice");
        let cold_b = engine_with(book).search(&req).unwrap();
        let got = astra::json::to_string_pretty(&report_json(&repriced, &catalog));
        let want = astra::json::to_string_pretty(&report_json(&cold_b, &catalog));
        assert_eq!(got, want, "case {case}: reprice diverged from a cold search");
    }
}

/// The pruner itself: random admit/observe streams never reject a point
/// that genuinely improves on everything scored so far.
#[test]
fn prop_pruner_never_rejects_improvements() {
    let mut rng = Rng::new(4096);
    for case in 0..50 {
        let budget = rng.range_f64(50.0, 500.0);
        let mut pr = DominancePruner::new(budget);
        let mut scored: Vec<(f64, f64)> = Vec::new();
        for _ in 0..200 {
            let tput = rng.range_f64(1.0, 1000.0);
            let cost = rng.range_f64(1.0, 1000.0);
            // A candidate pool whose bounds bracket this point.
            let ub = tput * rng.range_f64(1.0, 1.5);
            let lb = cost * rng.range_f64(0.5, 1.0);
            let improves = cost <= budget
                && !scored.iter().any(|&(p, c)| p >= tput && c <= cost);
            let admitted = pr.admit(ub, lb).is_admitted();
            if improves {
                assert!(
                    admitted,
                    "case {case}: rejected pool holding improvement ({tput}, {cost}) \
                     with bounds ({ub}, {lb})"
                );
            }
            if admitted {
                pr.observe(tput, cost);
                scored.push((tput, cost));
            }
        }
        assert_eq!(
            pr.pruned(),
            pr.pruned_budget + pr.pruned_dominated,
            "case {case}: prune counters inconsistent"
        );
    }
}
