//! Golden snapshots of compiled [`astra::coordinator::SearchPlan`]s: one
//! fixed request per mode compiles to a canonical [`plan_json`] document
//! that must byte-match the checked-in snapshot under
//! `rust/tests/golden/plan_<mode>.json` — so plan-compilation regressions
//! (pool enumeration order, sweep totals, bounds, space pinning) are
//! caught without running a single search.
//!
//! ## Regeneration
//!
//! After an *intentional* compiler change:
//!
//! ```text
//! ASTRA_REGEN_GOLDEN=1 cargo test --test golden_plan
//! git diff rust/tests/golden/plan_*.json   # review, then commit
//! ```
//!
//! Missing snapshots (fresh checkout state) bootstrap in place and pass
//! with a notice — commit the generated files to arm the byte-match.

use astra::coordinator::{plan_json, EngineConfig, ScoringCore, SearchRequest};
use astra::gpu::GpuCatalog;
use astra::model::ModelRegistry;
use astra::strategy::SpaceConfig;
use std::path::PathBuf;

/// Deterministic compiler: analytic η (no forest dependence), a tiny fixed
/// space so snapshots stay small and reviewable.
fn core() -> ScoringCore {
    let space = SpaceConfig {
        tp_candidates: vec![1, 2],
        max_pp: 2,
        mbs_candidates: vec![1],
        vpp_candidates: vec![1],
        seq_parallel_options: vec![true],
        dist_opt_options: vec![true],
        offload_options: vec![false],
        recompute_none: true,
        recompute_selective: false,
        recompute_full: false,
        ..SpaceConfig::default()
    };
    ScoringCore::new(
        GpuCatalog::builtin(),
        EngineConfig { use_forests: false, space, ..Default::default() },
    )
}

fn requests() -> Vec<(&'static str, SearchRequest)> {
    let model = ModelRegistry::builtin().get("llama2-7b").unwrap().clone();
    vec![
        ("homogeneous", SearchRequest::homogeneous("a800", 8, model.clone()).unwrap()),
        (
            "heterogeneous",
            SearchRequest::heterogeneous(&[("a800", 4), ("h100", 4)], 8, model.clone())
                .unwrap(),
        ),
        ("cost", SearchRequest::cost("a800", 8, 1e5, model.clone()).unwrap()),
        (
            "hetero_cost",
            SearchRequest::hetero_cost(&[("a800", 4), ("h100", 4)], 1e5, model.clone())
                .unwrap(),
        ),
        (
            "frontier",
            SearchRequest::frontier(&[("a800", 4), ("h100", 4)], model).unwrap(),
        ),
    ]
}

fn golden_dir() -> PathBuf {
    let manifest = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    for rel in ["tests/golden", "rust/tests/golden"] {
        let dir = manifest.join(rel);
        if dir.is_dir() {
            return dir;
        }
    }
    manifest.join("tests/golden")
}

fn render(mode: &str) -> String {
    let c = core();
    let req = requests().into_iter().find(|(m, _)| *m == mode).unwrap().1;
    let plan = c.compile_plan(&req).expect("compile");
    astra::json::to_string_pretty(&plan_json(&plan, &c.catalog))
}

#[test]
fn plan_snapshots_match_golden() {
    let regen = std::env::var("ASTRA_REGEN_GOLDEN").as_deref() == Ok("1");
    for (mode, _) in requests() {
        let got = render(mode);

        // Shape assertions that hold regardless of the snapshot state.
        let v = astra::json::parse(&got).unwrap();
        assert_eq!(v.get("astra_plan").and_then(astra::json::Value::as_u64), Some(1));
        assert!(
            v.get("pool_count").and_then(astra::json::Value::as_usize).unwrap() > 0,
            "{mode}: plan compiled no pools"
        );

        let path = golden_dir().join(format!("plan_{mode}.json"));
        if regen || !path.exists() {
            let write = std::fs::create_dir_all(path.parent().unwrap())
                .and_then(|_| std::fs::write(&path, &got));
            match write {
                Ok(()) => eprintln!(
                    "golden_plan: {} snapshot at {} — commit it to arm the byte-match",
                    if regen { "regenerated" } else { "bootstrapped" },
                    path.display()
                ),
                Err(e) => {
                    eprintln!("golden_plan: SKIP byte-match (cannot write {}: {e})", path.display())
                }
            }
            continue;
        }
        let want = std::fs::read_to_string(&path).unwrap();
        if got != want {
            for (i, (g, w)) in got.lines().zip(want.lines()).enumerate() {
                assert_eq!(
                    g, w,
                    "{mode}: plan snapshot line {i} diverged from {} — if the change is \
                     intentional, regenerate with ASTRA_REGEN_GOLDEN=1 (see module docs)",
                    path.display()
                );
            }
            panic!(
                "{mode}: plan snapshot length changed ({} vs {} lines) — regenerate with \
                 ASTRA_REGEN_GOLDEN=1 if intentional",
                got.lines().count(),
                want.lines().count()
            );
        }
    }
}

/// The snapshot surface itself must be replay-stable: two fresh cores
/// compile byte-identical documents (pins compiler nondeterminism even
/// while snapshots are in their bootstrapped first-run state).
#[test]
fn plan_snapshots_are_deterministic_across_cores() {
    for (mode, _) in requests() {
        assert_eq!(render(mode), render(mode), "{mode}: plan snapshot is not replay-stable");
    }
}
