//! Differential harness for the shared-memo streaming scorer: the fast
//! path (`streaming: true`, fused per-pool passes over a `SharedCostMemo`,
//! speculative-wave hetero-cost sweep) must select **exactly** what the
//! pre-refactor reference path (`streaming: false`, collect → filter →
//! score with per-chunk memos) selects, on every search mode.
//!
//! Comparison is on [`astra::report::report_json`] — the canonical result
//! view (counts, pruning statistics, ranked `top`, full Pareto pool) with
//! the observability fields (wall times, memo counters) excluded — and is
//! *byte*-equality of the serialized JSON, so float drift of any kind
//! fails loudly.

use astra::coordinator::{AstraEngine, EngineConfig, SearchReport, SearchRequest};
use astra::gpu::GpuCatalog;
use astra::model::ModelRegistry;
use astra::report::report_json;
use astra::strategy::SpaceConfig;

/// Narrow space so the whole matrix stays debug-profile fast.
fn small_space() -> SpaceConfig {
    SpaceConfig {
        tp_candidates: vec![1, 2],
        max_pp: 4,
        mbs_candidates: vec![1, 2],
        vpp_candidates: vec![1],
        seq_parallel_options: vec![true],
        dist_opt_options: vec![true],
        offload_options: vec![false],
        recompute_none: true,
        recompute_selective: false,
        recompute_full: false,
        ..SpaceConfig::default()
    }
}

fn engine_with(streaming: bool, workers: usize, sweep_wave: usize) -> AstraEngine {
    AstraEngine::new(
        GpuCatalog::builtin(),
        EngineConfig {
            use_forests: false,
            streaming,
            workers,
            sweep_wave,
            space: small_space(),
            ..Default::default()
        },
    )
}

fn canon(report: &SearchReport) -> String {
    astra::json::to_string(&report_json(report, &GpuCatalog::builtin()))
}

fn requests() -> Vec<(&'static str, SearchRequest)> {
    let model = ModelRegistry::builtin().get("llama2-7b").unwrap().clone();
    vec![
        ("homogeneous", SearchRequest::homogeneous("a800", 16, model.clone()).unwrap()),
        (
            "heterogeneous",
            SearchRequest::heterogeneous(&[("a800", 8), ("h100", 8)], 8, model.clone())
                .unwrap(),
        ),
        ("cost", SearchRequest::cost("a800", 16, 1e7, model.clone()).unwrap()),
        (
            "hetero-cost",
            SearchRequest::hetero_cost(&[("a800", 8), ("h100", 8)], f64::INFINITY, model.clone())
                .unwrap(),
        ),
        (
            "hetero-cost-budgeted",
            SearchRequest::hetero_cost(&[("a800", 8), ("h100", 8), ("v100", 8)], 5e4, model)
                .unwrap(),
        ),
    ]
}

/// The acceptance differential: fast path == slow path, every mode,
/// byte-for-byte over counts, `top` and the Pareto pool (which covers the
/// `budget_pick` promotion — it reorders `top[0]`).
#[test]
fn streaming_selects_exactly_what_reference_selects() {
    let fast = engine_with(true, 4, 2);
    let slow = engine_with(false, 4, 2);
    for (name, req) in requests() {
        let a = fast.search(&req).unwrap();
        let b = slow.search(&req).unwrap();
        assert_eq!(canon(&a), canon(&b), "mode {name}: fast path diverged from reference");
    }
}

/// Memo warmth must never leak into results: repeating every request on
/// the *same* engine (memo fully warm the second time) reproduces the
/// exact same report, and the warm pass is measurably warmer.
#[test]
fn warm_memo_changes_speed_not_results() {
    let eng = engine_with(true, 4, 2);
    for (name, req) in requests() {
        let cold = eng.search(&req).unwrap();
        let warm = eng.search(&req).unwrap();
        assert_eq!(canon(&cold), canon(&warm), "mode {name}: memo warmth changed results");
        assert!(
            warm.memo_misses == 0,
            "mode {name}: warm pass still missed {} profiles",
            warm.memo_misses
        );
        if cold.scored > 0 {
            assert!(cold.memo_misses > 0, "mode {name}: cold pass must populate the memo");
        }
    }
}

/// The speculative-wave sweep is byte-identical to the serial sweep —
/// including `pruned_pools` — at every wave size, with pruning on and a
/// budget tight enough to actually prune.
#[test]
fn hetero_cost_wave_sizes_are_byte_identical() {
    let model = ModelRegistry::builtin().get("llama2-7b").unwrap().clone();
    let caps = [("a800", 8usize), ("h100", 8usize), ("v100", 8usize)];
    // Learn the cost scale, then pick a budget near the cheap end so the
    // dominance/budget pruner has real work.
    let free = engine_with(true, 4, 1)
        .search(&SearchRequest::hetero_cost(&caps, f64::INFINITY, model.clone()).unwrap())
        .unwrap();
    let cheap = free.pool.entries().last().expect("empty frontier").cost;
    for budget in [cheap * 1.05, cheap * 2.0, f64::INFINITY] {
        let req = SearchRequest::hetero_cost(&caps, budget, model.clone()).unwrap();
        let serial = engine_with(true, 4, 1).search(&req).unwrap();
        if budget.is_finite() {
            assert!(serial.pruned_pools > 0, "budget ${budget} pruned nothing — weak test");
        }
        for wave in [2, 3, 64] {
            let waved = engine_with(true, 4, wave).search(&req).unwrap();
            assert_eq!(
                waved.pruned_pools, serial.pruned_pools,
                "wave {wave}, budget ${budget}: pruning counts drifted"
            );
            assert_eq!(
                canon(&waved),
                canon(&serial),
                "wave {wave}, budget ${budget}: wave sweep diverged from serial"
            );
        }
        // And the whole family agrees with the unpruned streaming and the
        // non-streaming references on the canonical result.
        let unpruned = AstraEngine::new(
            GpuCatalog::builtin(),
            EngineConfig {
                use_forests: false,
                streaming: true,
                money_prune: false,
                space: small_space(),
                ..Default::default()
            },
        )
        .search(&req)
        .unwrap();
        let pick = |r: &SearchReport| {
            r.pool.best_within_budget(budget).map(|e| (e.throughput.to_bits(), e.cost.to_bits()))
        };
        assert_eq!(pick(&serial), pick(&unpruned), "budget ${budget}: pruning changed the pick");
        let reference = engine_with(false, 4, 1).search(&req).unwrap();
        assert_eq!(canon(&serial), canon(&reference), "budget ${budget}: fast != reference");
    }
}
