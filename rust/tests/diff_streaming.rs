//! Differential harness for the plan executor: any parallel configuration
//! (workers > 1, speculative waves, adaptive schedule) must select
//! **exactly** what the strictly serial oracle selects, on every search
//! mode. The oracle is the `workers = 1, wave = 1/1` execution of the same
//! [`astra::coordinator::SearchPlan`] — since the pre-refactor reference
//! pipeline was retired, `EngineConfig::streaming = false` *is* that
//! oracle (it compiles the identical plan with the wave pinned to 1/1 and
//! executes single-worker), which the flag-compatibility test pins.
//!
//! Comparison is on [`astra::report::report_json`] — the canonical result
//! view (counts, pruning statistics, ranked `top`, full Pareto pool) with
//! the observability fields (wall times, memo counters) excluded — and is
//! *byte*-equality of the serialized JSON, so float drift of any kind
//! fails loudly.

use astra::coordinator::{AstraEngine, EngineConfig, SearchReport, SearchRequest};
use astra::gpu::GpuCatalog;
use astra::model::ModelRegistry;
use astra::report::report_json;
use astra::strategy::SpaceConfig;

/// Narrow space so the whole matrix stays debug-profile fast.
fn small_space() -> SpaceConfig {
    SpaceConfig {
        tp_candidates: vec![1, 2],
        max_pp: 4,
        mbs_candidates: vec![1, 2],
        vpp_candidates: vec![1],
        seq_parallel_options: vec![true],
        dist_opt_options: vec![true],
        offload_options: vec![false],
        recompute_none: true,
        recompute_selective: false,
        recompute_full: false,
        ..SpaceConfig::default()
    }
}

fn engine_with(streaming: bool, workers: usize, sweep_wave: usize) -> AstraEngine {
    AstraEngine::new(
        GpuCatalog::builtin(),
        EngineConfig {
            use_forests: false,
            streaming,
            workers,
            sweep_wave,
            space: small_space(),
            ..Default::default()
        },
    )
}

/// The strictly serial oracle: one worker, wave pinned to 1/1.
fn oracle_engine() -> AstraEngine {
    AstraEngine::new(
        GpuCatalog::builtin(),
        EngineConfig {
            use_forests: false,
            workers: 1,
            sweep_wave: 1,
            sweep_wave_max: 1,
            space: small_space(),
            ..Default::default()
        },
    )
}

fn canon(report: &SearchReport) -> String {
    astra::json::to_string(&report_json(report, &GpuCatalog::builtin()))
}

fn requests() -> Vec<(&'static str, SearchRequest)> {
    let model = ModelRegistry::builtin().get("llama2-7b").unwrap().clone();
    vec![
        ("homogeneous", SearchRequest::homogeneous("a800", 16, model.clone()).unwrap()),
        (
            "heterogeneous",
            SearchRequest::heterogeneous(&[("a800", 8), ("h100", 8)], 8, model.clone())
                .unwrap(),
        ),
        ("cost", SearchRequest::cost("a800", 16, 1e7, model.clone()).unwrap()),
        (
            "hetero-cost",
            SearchRequest::hetero_cost(&[("a800", 8), ("h100", 8)], f64::INFINITY, model.clone())
                .unwrap(),
        ),
        (
            "hetero-cost-budgeted",
            SearchRequest::hetero_cost(&[("a800", 8), ("h100", 8), ("v100", 8)], 5e4, model)
                .unwrap(),
        ),
    ]
}

/// The acceptance differential: parallel executor == serial oracle, every
/// mode, byte-for-byte over counts, `top` and the Pareto pool (which
/// covers the `budget_pick` promotion — it reorders `top[0]`).
#[test]
fn parallel_executor_selects_exactly_what_serial_oracle_selects() {
    let fast = engine_with(true, 4, 2);
    let oracle = oracle_engine();
    for (name, req) in requests() {
        let a = fast.search(&req).unwrap();
        let b = oracle.search(&req).unwrap();
        assert_eq!(canon(&a), canon(&b), "mode {name}: executor diverged from serial oracle");
    }
}

/// `streaming: false` is the oracle spelled as a compatibility flag: it
/// must compile a 1/1-wave plan and reproduce the oracle's bytes exactly —
/// whatever workers/wave the config asks for (the executor overrides them).
#[test]
fn no_streaming_flag_is_the_serial_oracle() {
    let flagged = engine_with(false, 8, 64);
    let oracle = oracle_engine();
    for (name, req) in requests() {
        let plan = flagged.core().compile_plan(&req).unwrap();
        assert_eq!(
            (plan.wave_base, plan.wave_max),
            (1, 1),
            "mode {name}: streaming=false must pin the serial wave"
        );
        let a = flagged.search(&req).unwrap();
        let b = oracle.search(&req).unwrap();
        assert_eq!(canon(&a), canon(&b), "mode {name}: streaming=false diverged from oracle");
    }
}

/// The η batch kernel is pure mechanism: the batched parallel executor
/// must reproduce the scalar-η serial oracle's bytes with *both* knobs
/// crossed — batching on + workers 4 vs batching off + the 1/1 wave.
/// (Kernel-level bit-identity lives in `rust/tests/diff_forest.rs`; this
/// pins the executor integration.)
#[test]
fn batched_eta_matches_scalar_eta_oracle() {
    let fast = engine_with(true, 4, 2); // batch_eta: true via Default
    let scalar_oracle = AstraEngine::new(
        GpuCatalog::builtin(),
        EngineConfig {
            use_forests: false,
            workers: 1,
            sweep_wave: 1,
            sweep_wave_max: 1,
            batch_eta: false,
            space: small_space(),
            ..Default::default()
        },
    );
    for (name, req) in requests() {
        let a = fast.search(&req).unwrap();
        let b = scalar_oracle.search(&req).unwrap();
        assert_eq!(canon(&a), canon(&b), "mode {name}: batched η diverged from scalar-η oracle");
    }
}

/// Memo warmth must never leak into results: repeating every request on
/// the *same* engine (memo fully warm the second time) reproduces the
/// exact same report, and the warm pass is measurably warmer.
#[test]
fn warm_memo_changes_speed_not_results() {
    let eng = engine_with(true, 4, 2);
    for (name, req) in requests() {
        let cold = eng.search(&req).unwrap();
        let warm = eng.search(&req).unwrap();
        assert_eq!(canon(&cold), canon(&warm), "mode {name}: memo warmth changed results");
        assert!(
            warm.memo_misses == 0,
            "mode {name}: warm pass still missed {} profiles",
            warm.memo_misses
        );
        if cold.scored > 0 {
            assert!(cold.memo_misses > 0, "mode {name}: cold pass must populate the memo");
        }
    }
}

/// The speculative-wave sweep is byte-identical to the serial sweep —
/// including `pruned_pools` — at every wave size, with pruning on and a
/// budget tight enough to actually prune.
#[test]
fn hetero_cost_wave_sizes_are_byte_identical() {
    let model = ModelRegistry::builtin().get("llama2-7b").unwrap().clone();
    let caps = [("a800", 8usize), ("h100", 8usize), ("v100", 8usize)];
    // Learn the cost scale, then pick a budget near the cheap end so the
    // dominance/budget pruner has real work.
    let free = engine_with(true, 4, 1)
        .search(&SearchRequest::hetero_cost(&caps, f64::INFINITY, model.clone()).unwrap())
        .unwrap();
    let cheap = free.pool.entries().last().expect("empty frontier").cost;
    for budget in [cheap * 1.05, cheap * 2.0, f64::INFINITY] {
        let req = SearchRequest::hetero_cost(&caps, budget, model.clone()).unwrap();
        let serial = oracle_engine().search(&req).unwrap();
        if budget.is_finite() {
            assert!(serial.pruned_pools > 0, "budget ${budget} pruned nothing — weak test");
        }
        for wave in [2, 3, 64] {
            let waved = engine_with(true, 4, wave).search(&req).unwrap();
            assert_eq!(
                waved.pruned_pools, serial.pruned_pools,
                "wave {wave}, budget ${budget}: pruning counts drifted"
            );
            assert_eq!(
                canon(&waved),
                canon(&serial),
                "wave {wave}, budget ${budget}: wave sweep diverged from serial"
            );
        }
        // And the whole family agrees with the unpruned executor on the
        // canonical pick (pruning soundness).
        let unpruned = AstraEngine::new(
            GpuCatalog::builtin(),
            EngineConfig {
                use_forests: false,
                streaming: true,
                money_prune: false,
                space: small_space(),
                ..Default::default()
            },
        )
        .search(&req)
        .unwrap();
        let pick = |r: &SearchReport| {
            r.pool.best_within_budget(budget).map(|e| (e.throughput.to_bits(), e.cost.to_bits()))
        };
        assert_eq!(pick(&serial), pick(&unpruned), "budget ${budget}: pruning changed the pick");
        let flagged = engine_with(false, 4, 1).search(&req).unwrap();
        assert_eq!(canon(&serial), canon(&flagged), "budget ${budget}: oracle != streaming:false");
    }
}
