//! Cost model vs discrete-event simulator — the paper's >95% accuracy claim
//! (abstract / §1), evaluated over the full valid strategy population of a
//! real setting, not just the winner.

use astra::cost::{CostModel, EtaProvider};
use astra::gpu::GpuCatalog;
use astra::memory::MemoryModel;
use astra::model::ModelRegistry;
use astra::simulator::{PipelineSimulator, SimConfig};
use astra::strategy::{SearchSpace, SpaceConfig};

#[test]
fn cost_model_accuracy_over_population() {
    let catalog = GpuCatalog::builtin();
    let reg = ModelRegistry::builtin();
    let cost = CostModel::new(catalog.clone(), EtaProvider::Analytic);
    let sim = PipelineSimulator::new(catalog.clone(), SimConfig::default());
    let mem = MemoryModel::default();

    let mut accs: Vec<f64> = Vec::new();
    for (model_name, count) in [("llama2-7b", 64usize), ("llama2-13b", 128)] {
        let model = reg.get(model_name).unwrap();
        let gpu = catalog.find("a800").unwrap();
        let space = SearchSpace::new(SpaceConfig::default());
        let valid: Vec<_> = space
            .homogeneous(model, &catalog, gpu, count)
            .into_iter()
            .filter(|s| mem.fits(model, s, &catalog))
            .step_by(97)
            .take(60)
            .collect();
        assert!(valid.len() >= 30);
        for s in &valid {
            let predicted = cost.evaluate(model, s).step_time;
            let measured = sim.measure(model, s).step_time;
            accs.push(1.0 - (predicted - measured).abs() / measured);
        }
    }
    let mean = accs.iter().sum::<f64>() / accs.len() as f64;
    let min = accs.iter().cloned().fold(f64::INFINITY, f64::min);
    eprintln!("accuracy over {} strategies: mean {:.4}, min {:.4}", accs.len(), mean, min);
    assert!(mean > 0.95, "paper claims >95% accuracy; got mean {mean:.4}");
    assert!(min > 0.85, "worst-case accuracy collapsed: {min:.4}");
}

#[test]
fn ranking_agreement_top_candidate() {
    // Prediction quality that matters for search: the cost model's chosen
    // winner must be within 2% of the simulator's true best among a sample.
    let catalog = GpuCatalog::builtin();
    let reg = ModelRegistry::builtin();
    let model = reg.get("llama2-7b").unwrap();
    let cost = CostModel::new(catalog.clone(), EtaProvider::Analytic);
    let sim = PipelineSimulator::new(catalog.clone(), SimConfig::default());
    let mem = MemoryModel::default();
    let gpu = catalog.find("a800").unwrap();
    let space = SearchSpace::new(SpaceConfig::default());
    let valid: Vec<_> = space
        .homogeneous(model, &catalog, gpu, 64)
        .into_iter()
        .filter(|s| mem.fits(model, s, &catalog))
        .step_by(41)
        .take(80)
        .collect();

    let predicted_best = valid
        .iter()
        .min_by(|a, b| {
            cost.evaluate(model, a)
                .step_time
                .partial_cmp(&cost.evaluate(model, b).step_time)
                .unwrap()
        })
        .unwrap();
    let sim_times: Vec<f64> = valid.iter().map(|s| sim.measure(model, s).step_time).collect();
    let true_best = sim_times.iter().cloned().fold(f64::INFINITY, f64::min);
    let chosen = sim.measure(model, predicted_best).step_time;
    assert!(
        chosen <= true_best * 1.02,
        "model-chosen winner {chosen:.4}s vs simulator best {true_best:.4}s"
    );
}

#[test]
fn noise_does_not_flip_clear_orderings() {
    // Failure-injection style check: with 2% measurement noise, a 2×
    // throughput gap must never invert across seeds.
    let catalog = GpuCatalog::builtin();
    let reg = ModelRegistry::builtin();
    let model = reg.get("llama2-7b").unwrap();
    let mem = MemoryModel::default();
    let gpu = catalog.find("a800").unwrap();
    let space = SearchSpace::new(SpaceConfig::default());
    let cost = CostModel::new(catalog.clone(), EtaProvider::Analytic);
    let valid: Vec<_> = space
        .homogeneous(model, &catalog, gpu, 64)
        .into_iter()
        .filter(|s| mem.fits(model, s, &catalog))
        .collect();
    let mut scored: Vec<(f64, &_)> =
        valid.iter().map(|s| (cost.evaluate(model, s).step_time, s)).collect();
    scored.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    let fast = scored.first().unwrap().1;
    let slow = scored.iter().find(|(t, _)| *t > 2.0 * scored[0].0).map(|(_, s)| *s);
    let Some(slow) = slow else {
        return; // population too uniform — nothing to test
    };
    for seed in 0..10u64 {
        let sim = PipelineSimulator::new(catalog.clone(), SimConfig { seed, noise_sigma: 0.02 });
        let tf = sim.measure(model, fast).step_time;
        let ts = sim.measure(model, slow).step_time;
        assert!(tf < ts, "seed {seed}: ordering flipped ({tf} vs {ts})");
    }
}
