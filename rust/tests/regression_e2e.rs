//! Tier-1 regression of the repo's headline accuracy claim (promoted from
//! `examples/e2e_validation.rs`): the analytic cost model must predict the
//! discrete-event simulator's step time within the paper's >95% accuracy
//! on a fixed strategy set. The example remains the full-grid driver; this
//! test pins the claim on a deterministic subset cheap enough for CI.

use astra::coordinator::{AstraEngine, EngineConfig, SearchRequest};
use astra::gpu::GpuCatalog;
use astra::model::ModelRegistry;
use astra::simulator::{PipelineSimulator, SimConfig};
use astra::strategy::SpaceConfig;

/// Fixed, deterministic workload: top-5 strategies of a narrowed-space
/// mode-1 search per model (the narrowed space keeps debug-profile CI
/// fast; determinism comes from the generator + analytic η + fixed
/// simulator seed).
fn top5(
    engine: &AstraEngine,
    model: &astra::model::ModelSpec,
) -> Vec<astra::coordinator::ScoredStrategy> {
    let req = SearchRequest::homogeneous("a800", 64, model.clone()).expect("request");
    let rep = engine.search(&req).expect("search");
    assert!(rep.scored >= 5, "{}: only {} strategies scored", model.name, rep.scored);
    rep.top.iter().take(5).cloned().collect()
}

#[test]
fn cost_model_matches_simulator_above_95_percent() {
    let catalog = GpuCatalog::builtin();
    let registry = ModelRegistry::builtin();
    let space = SpaceConfig {
        tp_candidates: vec![1, 2, 4],
        max_pp: 8,
        mbs_candidates: vec![1, 2],
        vpp_candidates: vec![1, 2],
        offload_options: vec![false],
        ..SpaceConfig::default()
    };
    let engine = AstraEngine::new(
        catalog.clone(),
        EngineConfig { use_forests: false, space, ..Default::default() },
    );
    let sim = PipelineSimulator::new(catalog.clone(), SimConfig::default());

    let mut accs: Vec<f64> = Vec::new();
    for name in ["llama2-7b", "llama2-13b", "llama3-8b"] {
        let model = registry.get(name).unwrap().clone();
        for s in top5(&engine, &model) {
            let r = sim.measure(&model, &s.strategy);
            let acc = 1.0 - (s.cost.step_time - r.step_time).abs() / r.step_time;
            assert!(
                acc > 0.85,
                "{name}: single-strategy accuracy collapsed to {:.1}% ({})",
                acc * 100.0,
                s.strategy.summary()
            );
            accs.push(acc);
        }
    }
    let mean = accs.iter().sum::<f64>() / accs.len() as f64;
    assert!(accs.len() >= 15, "fixed set shrank to {} strategies", accs.len());
    assert!(
        mean > 0.95,
        "mean cost-model accuracy {:.2}% ≤ paper's 95% headline",
        mean * 100.0
    );
}

/// The same contract holds on a heterogeneous plan — the Eq. 22 hetero
/// pipeline composition is part of the headline, not just mode 1.
#[test]
fn hetero_plan_accuracy_above_90_percent() {
    let catalog = GpuCatalog::builtin();
    let registry = ModelRegistry::builtin();
    let model = registry.get("llama2-7b").unwrap().clone();
    let space = SpaceConfig {
        tp_candidates: vec![1, 2],
        max_pp: 4,
        mbs_candidates: vec![1, 2],
        vpp_candidates: vec![1],
        offload_options: vec![false],
        recompute_selective: false,
        recompute_full: false,
        ..SpaceConfig::default()
    };
    let engine = AstraEngine::new(
        catalog.clone(),
        EngineConfig { use_forests: false, space, ..Default::default() },
    );
    let caps = vec![(catalog.find("a800").unwrap(), 24), (catalog.find("h100").unwrap(), 24)];
    let rep = engine
        .search(&SearchRequest {
            mode: astra::strategy::GpuPoolMode::Heterogeneous { total: 32, caps },
            model: model.clone(),
        })
        .unwrap();
    let sim = PipelineSimulator::new(catalog, SimConfig::default());
    let best = rep.best().expect("hetero search empty");
    let r = sim.measure(&model, &best.strategy);
    let acc = 1.0 - (best.cost.step_time - r.step_time).abs() / r.step_time;
    assert!(
        acc > 0.90,
        "hetero accuracy {:.1}% ({})",
        acc * 100.0,
        best.strategy.summary()
    );
}
