//! Property tests for the search decision audit (`coordinator::audit`):
//! every recorded prune must carry evidence that *certifies* it, the
//! audited pool set must exactly partition the compiled plan's pool set,
//! and the candidate funnel must conserve candidates. These are the
//! machine-checkable halves of the determinism contract documented on
//! `astra::coordinator::audit` (the byte-identity half lives in
//! `rust/tests/determinism.rs`).
//!
//! Certification means re-deriving each decision from its own evidence:
//! a `pruned_budget` pool must satisfy `lb_usd > budget` with the pool's
//! own lower bound and the request's own ceiling; a `pruned_dominated`
//! pool's recorded frontier point must be at least as fast as the pool's
//! upper-bound throughput AND at most as expensive as its lower-bound
//! bill — the exact predicate `DominancePruner::admit` prunes on.

use astra::coordinator::{
    AstraEngine, AuditDecision, EngineConfig, SearchRequest,
};
use astra::gpu::GpuCatalog;
use astra::model::ModelRegistry;
use astra::strategy::SpaceConfig;

fn small_space() -> SpaceConfig {
    SpaceConfig {
        tp_candidates: vec![1, 2],
        max_pp: 4,
        mbs_candidates: vec![1, 2],
        vpp_candidates: vec![1],
        seq_parallel_options: vec![true],
        dist_opt_options: vec![true],
        offload_options: vec![false],
        recompute_none: true,
        recompute_selective: false,
        recompute_full: false,
        ..SpaceConfig::default()
    }
}

fn engine(workers: usize, sweep_wave: usize) -> AstraEngine {
    AstraEngine::new(
        GpuCatalog::builtin(),
        EngineConfig {
            use_forests: false,
            workers,
            sweep_wave,
            space: small_space(),
            ..Default::default()
        },
    )
}

fn hetero_cost_req(budget: f64) -> SearchRequest {
    let model = ModelRegistry::builtin().get("llama2-7b").unwrap().clone();
    SearchRequest::hetero_cost(&[("a800", 8), ("h100", 8), ("v100", 8)], budget, model).unwrap()
}

/// Deterministic budget generator (LCG) so the property sweeps a seeded
/// spread of ceilings — from prune-everything-tight to prune-nothing-loose —
/// without depending on an RNG crate or wall-clock entropy.
fn seeded_budgets(seed: u64, n: usize, lo: f64, hi: f64) -> Vec<f64> {
    let mut state = seed;
    (0..n)
        .map(|_| {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            let unit = (state >> 11) as f64 / (1u64 << 53) as f64;
            lo + unit * (hi - lo)
        })
        .collect()
}

/// Every prune in the audit is certified by its own evidence, and the
/// evidence is copied verbatim from the pool's bounds and the request's
/// budget — across a seeded spread of budgets.
///
/// The budgets are derived, not guessed: a free (infinite-budget) search
/// learns the cost scale, and the spread covers the floor below every
/// pool's lower bound (everything must budget-prune) through the band
/// just above the cheapest frontier point where `diff_streaming.rs`
/// proves pruning has real work.
#[test]
fn every_prune_is_certified_by_its_evidence() {
    let free = engine(1, 1).search(&hetero_cost_req(f64::INFINITY)).unwrap();
    let cheap = free.pool.entries().last().expect("empty frontier").cost;
    let plan = engine(4, 2).core().compile_plan(&hetero_cost_req(f64::INFINITY)).unwrap();
    let min_lb = plan
        .rounds
        .iter()
        .flat_map(|r| r.pools.iter())
        .map(|p| p.lb_usd)
        .fold(f64::INFINITY, f64::min);
    assert!(
        min_lb.is_finite() && min_lb > 0.0,
        "hetero-cost pools must carry positive lower-bound bills, got {min_lb}"
    );
    let mut budgets = vec![min_lb * 0.5, cheap * 1.05, cheap * 2.0];
    budgets.extend(seeded_budgets(0xA57_2A, 3, cheap * 1.05, cheap * 2.0));
    let mut saw_budget_prune = false;
    let mut saw_dominance_prune = false;
    for budget in budgets {
        let req = hetero_cost_req(budget);
        let report = engine(4, 2).search_audited(&req).unwrap();
        let audit = report.audit.as_ref().expect("audited search carries an audit");
        for round in &audit.rounds {
            for p in &round.pools {
                match p.decision {
                    AuditDecision::Admitted => {
                        assert!(
                            p.funnel.is_some(),
                            "budget {budget:.0}: admitted pool {}/{} has no funnel",
                            round.round,
                            p.pool
                        );
                    }
                    AuditDecision::PrunedBudget { lb_usd, budget: b } => {
                        saw_budget_prune = true;
                        assert!(
                            lb_usd > b,
                            "budget {budget:.0}: pool {}/{} pruned on budget but \
                             lb ${lb_usd} ≤ ${b}",
                            round.round,
                            p.pool
                        );
                        assert_eq!(
                            lb_usd.to_bits(),
                            p.lb_usd.to_bits(),
                            "evidence lb must be the pool's own lower bound"
                        );
                        assert_eq!(
                            b.to_bits(),
                            budget.to_bits(),
                            "evidence budget must be the request's ceiling"
                        );
                    }
                    AuditDecision::PrunedDominated { by: (tput, usd) } => {
                        saw_dominance_prune = true;
                        assert!(
                            tput >= p.ub_tput && usd <= p.lb_usd,
                            "budget {budget:.0}: pool {}/{} pruned as dominated but \
                             ({tput}, {usd}) does not dominate bounds ({}, {})",
                            round.round,
                            p.pool,
                            p.ub_tput,
                            p.lb_usd
                        );
                    }
                }
            }
        }
        // The report's prune split is exactly the audit's.
        assert_eq!(report.pruned_budget, audit.pruned_budget(), "budget {budget:.0}");
        assert_eq!(report.pruned_dominated, audit.pruned_dominated(), "budget {budget:.0}");
        assert_eq!(
            report.pruned_pools,
            report.pruned_budget + report.pruned_dominated,
            "budget {budget:.0}: prune split must sum to the total"
        );
    }
    // The floor budget sits below every pool's lower bound, so budget
    // prunes are guaranteed to have been exercised. Dominance prunes are
    // workload-shaped; record whether the sweep saw them so a silent
    // weakening shows up in test output.
    assert!(saw_budget_prune, "the sub-lower-bound floor budget pruned nothing");
    if !saw_dominance_prune {
        eprintln!("audit: note — this sweep exercised no dominance prunes");
    }
}

/// The audited pool set partitions the compiled plan's pool set exactly:
/// same rounds, same totals, same pool count per round, pools in replay
/// (index) order — no pool unaccounted for, none invented.
#[test]
fn audit_partitions_the_plan_pool_set() {
    for budget in [5e4, f64::INFINITY] {
        let req = hetero_cost_req(budget);
        let eng = engine(4, 2);
        let plan = eng.core().compile_plan(&req).unwrap();
        let report = eng.search_audited(&req).unwrap();
        let audit = report.audit.as_ref().expect("audit");
        assert_eq!(audit.rounds.len(), plan.rounds.len(), "budget {budget}: round count");
        for (ar, pr) in audit.rounds.iter().zip(&plan.rounds) {
            assert_eq!(ar.total, pr.total, "round {} GPU total", ar.round);
            assert_eq!(
                ar.pools.len(),
                pr.pools.len(),
                "round {}: audited pools must cover the plan's pools",
                ar.round
            );
            for (i, p) in ar.pools.iter().enumerate() {
                assert_eq!(p.pool, i, "round {}: pools must be in replay order", ar.round);
            }
        }
        assert_eq!(
            audit.pool_count(),
            audit.admitted() + audit.pruned_budget() + audit.pruned_dominated(),
            "decisions must partition the audited set"
        );
    }
}

/// Candidate conservation through the funnel: every expanded candidate is
/// either rejected by rules, rejected by the memory model, or scored.
#[test]
fn admitted_funnels_conserve_candidates() {
    // Infinite budget: no budget prunes, so admitted pools (and their
    // funnels) are guaranteed to exist.
    let req = hetero_cost_req(f64::INFINITY);
    let report = engine(4, 2).search_audited(&req).unwrap();
    let audit = report.audit.as_ref().expect("audit");
    let mut funnels = 0usize;
    for round in &audit.rounds {
        for p in &round.pools {
            let Some(f) = p.funnel else { continue };
            funnels += 1;
            assert_eq!(
                f.expanded,
                f.rules_rejected + f.mem_rejected + f.scored,
                "round {} pool {}: candidates leaked from the funnel",
                round.round,
                p.pool
            );
        }
    }
    assert!(funnels > 0, "no pool carried a funnel — the property is vacuous");
    // The report's global funnel is the sum of the admitted pools' funnels.
    let sum = |pick: fn(&astra::coordinator::AuditFunnel) -> usize| -> usize {
        audit
            .rounds
            .iter()
            .flat_map(|r| r.pools.iter())
            .filter(|p| p.decision.is_admitted())
            .filter_map(|p| p.funnel.as_ref().map(pick))
            .sum()
    };
    assert_eq!(report.generated, sum(|f| f.expanded), "generated != Σ expanded");
    assert_eq!(report.rule_filtered, sum(|f| f.rules_rejected), "rule_filtered != Σ rules");
    assert_eq!(report.mem_filtered, sum(|f| f.mem_rejected), "mem_filtered != Σ mem");
    assert_eq!(report.scored, sum(|f| f.scored), "scored != Σ scored");
}

/// The margins block mirrors the final ranking: the winner is `top[0]`,
/// the runner-up is `top[1]`, and each margin is the literal difference.
#[test]
fn margins_mirror_the_final_ranking() {
    // Infinite budget guarantees a non-empty ranking to take margins of.
    let req = hetero_cost_req(f64::INFINITY);
    let report = engine(4, 2).search_audited(&req).unwrap();
    let audit = report.audit.as_ref().expect("audit");
    let m = audit.margins.as_ref().expect("a non-empty search has margins");
    let top0 = &report.top[0];
    assert_eq!(m.winner.summary, top0.strategy.summary());
    assert_eq!(m.winner.step_time_s.to_bits(), top0.cost.step_time.to_bits());
    assert_eq!(m.winner.tokens_per_s.to_bits(), top0.cost.tokens_per_s.to_bits());
    assert_eq!(m.winner.money_usd.to_bits(), top0.money_usd.to_bits());
    match (&m.runner_up, report.top.get(1)) {
        (Some(r), Some(top1)) => {
            assert_eq!(r.summary, top1.strategy.summary());
            assert_eq!(
                m.step_time_margin_s.to_bits(),
                (top1.cost.step_time - top0.cost.step_time).to_bits()
            );
            assert_eq!(
                m.tokens_per_s_margin.to_bits(),
                (top0.cost.tokens_per_s - top1.cost.tokens_per_s).to_bits()
            );
            assert_eq!(
                m.money_margin_usd.to_bits(),
                (top0.money_usd - top1.money_usd).to_bits()
            );
        }
        (None, None) => {}
        (got, want) => panic!(
            "runner-up mismatch: audit {:?} vs ranking {:?}",
            got.is_some(),
            want.is_some()
        ),
    }
}

/// An unaudited search carries no audit, on every mode — the plane is
/// strictly opt-in.
#[test]
fn unaudited_searches_carry_no_audit() {
    let model = ModelRegistry::builtin().get("llama2-7b").unwrap().clone();
    let reqs = vec![
        SearchRequest::homogeneous("a800", 16, model.clone()).unwrap(),
        SearchRequest::hetero_cost(&[("a800", 8), ("h100", 8)], 1e5, model).unwrap(),
    ];
    let eng = engine(4, 2);
    for req in reqs {
        assert!(eng.search(&req).unwrap().audit.is_none());
        assert!(eng.search_audited(&req).unwrap().audit.is_some());
    }
}
