//! Persistence determinism pins for `astra::persist`:
//!
//! * a search on a restored-memo engine must produce **byte-identical**
//!   canonical report JSON (counts, pruning statistics, ranked `top`, full
//!   Pareto pool) to a cold search, in all four modes, with zero memo
//!   misses — restore really does skip the cold pass;
//! * corrupt / version-mismatched / partially-written snapshots must
//!   silently degrade to a cold start — same bytes as cold, never an error
//!   and never a wrong answer;
//! * the service's result cache survives a restart: a fresh service built
//!   over the spilled snapshot serves the same reports from cache without
//!   re-searching.

use astra::coordinator::{AstraEngine, EngineConfig, SearchRequest};
use astra::gpu::GpuCatalog;
use astra::model::ModelRegistry;
use astra::report::report_json;
use astra::service::{SearchService, ServiceConfig, WarmConfig};
use astra::strategy::SpaceConfig;
use std::path::PathBuf;

/// Narrow space so the whole matrix stays debug-profile fast.
fn small_space() -> SpaceConfig {
    SpaceConfig {
        tp_candidates: vec![1, 2],
        max_pp: 4,
        mbs_candidates: vec![1, 2],
        vpp_candidates: vec![1],
        seq_parallel_options: vec![true],
        dist_opt_options: vec![true],
        offload_options: vec![false],
        recompute_none: true,
        recompute_selective: false,
        recompute_full: false,
        ..SpaceConfig::default()
    }
}

fn engine() -> AstraEngine {
    AstraEngine::new(
        GpuCatalog::builtin(),
        EngineConfig { use_forests: false, space: small_space(), ..Default::default() },
    )
}

fn canon(eng: &AstraEngine, req: &SearchRequest) -> String {
    astra::json::to_string(&report_json(&eng.search(req).unwrap(), &GpuCatalog::builtin()))
}

fn requests() -> Vec<(&'static str, SearchRequest)> {
    let model = ModelRegistry::builtin().get("llama2-7b").unwrap().clone();
    vec![
        ("homogeneous", SearchRequest::homogeneous("a800", 16, model.clone()).unwrap()),
        (
            "heterogeneous",
            SearchRequest::heterogeneous(&[("a800", 8), ("h100", 8)], 8, model.clone())
                .unwrap(),
        ),
        ("cost", SearchRequest::cost("a800", 16, 1e7, model.clone()).unwrap()),
        (
            "hetero-cost",
            SearchRequest::hetero_cost(&[("a800", 8), ("h100", 8)], 2e5, model).unwrap(),
        ),
    ]
}

/// Unique temp path per test so the parallel test runner never collides.
fn tmppath(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("astra_persist_{}_{}.jsonl", tag, std::process::id()))
}

#[test]
fn restored_memo_search_is_byte_identical_and_fully_warm() {
    for (name, req) in requests() {
        // Cold oracle on a completely fresh engine.
        let cold = canon(&engine(), &req);

        // Heat a second engine with the same request and spill it.
        let warm_eng = engine();
        let warm_rep = warm_eng.search(&req).unwrap();
        assert!(warm_rep.memo_misses > 0, "mode {name}: cold pass must populate the memo");
        let path = tmppath(&format!("modes_{name}"));
        let spill = warm_eng.core().save_warm(&path).unwrap();
        assert_eq!(spill.scopes, 1, "mode {name}: one model scope expected");
        assert!(spill.bytes > 0);

        // A fresh engine (simulated restarted process) restores and must
        // reproduce the cold report byte-for-byte without a single miss —
        // the restored hit-rate is 1.0, far above the 0.50 bench floor.
        let restored_eng = engine();
        let st = restored_eng.core().load_warm(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        assert_eq!(st.scopes_restored, 1, "mode {name}: scope must restore");
        assert_eq!(st.scopes_rejected, 0, "mode {name}: nothing to reject");
        assert!(st.stage_rows + st.sync_rows > 0);
        let report = restored_eng.search(&req).unwrap();
        assert_eq!(
            report.memo_misses, 0,
            "mode {name}: restored memo missed {} profiles",
            report.memo_misses
        );
        assert!(report.memo_hits > 0);
        let got = astra::json::to_string(&report_json(&report, &GpuCatalog::builtin()));
        assert_eq!(got, cold, "mode {name}: restored search diverged from cold");
        // Persistence counters reflect the traffic.
        let p = restored_eng.core().persist_stats();
        assert_eq!((p.scopes_restored, p.scopes_rejected), (1, 0));
    }
}

#[test]
fn corrupt_snapshots_degrade_to_cold_never_error_or_lie() {
    let (_, req) = requests().remove(3); // hetero-cost: exercises pruning too
    let cold = canon(&engine(), &req);

    let warm_eng = engine();
    warm_eng.search(&req).unwrap();
    let path = tmppath("corrupt");
    warm_eng.core().save_warm(&path).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    let _ = std::fs::remove_file(&path);

    let n_lines = text.lines().count();
    let truncated: String =
        text.lines().take(n_lines / 2).map(|l| format!("{l}\n")).collect();
    let version_bumped = text.replace("{\"astra_warm\":1}", "{\"astra_warm\":2}");
    // Tamper one value's bit pattern: pick the first value hex out of a
    // row line and flip its last digit. The row stays well-formed JSON —
    // only the footer checksum can catch it.
    let tampered = {
        let row = text
            .lines()
            .find(|l| l.contains("\"t\":\"stage\""))
            .expect("no stage row in snapshot");
        let start = row.find("\"v\":[\"").expect("no value array") + "\"v\":[\"".len();
        let hex = &row[start..start + 16];
        let flipped: String = hex
            .chars()
            .take(15)
            .chain(std::iter::once(if hex.ends_with('0') { '1' } else { '0' }))
            .collect();
        text.replacen(hex, &flipped, 1)
    };
    let garbage = "definitely not a snapshot\n{\"scope\":oops\n".to_string();
    let scope_digest_tampered = {
        // Zero out the consts digest in the scope header only.
        let header = text
            .lines()
            .find(|l| l.contains("\"scope\""))
            .expect("no scope header");
        let start = header.find("\"consts\":\"").expect("no consts digest")
            + "\"consts\":\"".len();
        let hex = header[start..start + 16].to_string();
        text.replacen(&hex, "0000000000000000", 1)
    };

    for (case, bad) in [
        ("truncated", truncated),
        ("version_bumped", version_bumped),
        ("value_tampered", tampered),
        ("garbage", garbage),
        ("digest_tampered", scope_digest_tampered),
    ] {
        let bad_path = tmppath(&format!("corrupt_{case}"));
        std::fs::write(&bad_path, &bad).unwrap();
        let eng = engine();
        // Loading must not error…
        let st = eng.core().load_warm(&bad_path).unwrap();
        let _ = std::fs::remove_file(&bad_path);
        assert_eq!(st.scopes_restored, 0, "case {case}: must not import anything");
        assert!(st.scopes_rejected >= 1, "case {case}: rejection must be counted");
        // …and the next search is a correct cold start.
        let report = eng.search(&req).unwrap();
        assert!(report.memo_misses > 0, "case {case}: engine must start cold");
        let got = astra::json::to_string(&report_json(&report, &GpuCatalog::builtin()));
        assert_eq!(got, cold, "case {case}: degraded start produced wrong bytes");
    }

    // A missing file is the only hard error (callers gate on existence).
    assert!(engine().core().load_warm(&tmppath("never_written")).is_err());
}

fn warm_service(dir: &std::path::Path, spill_every: u64) -> SearchService {
    let core = astra::coordinator::ScoringCore::new(
        GpuCatalog::builtin(),
        EngineConfig { use_forests: false, space: small_space(), ..Default::default() },
    );
    SearchService::new(
        core,
        ServiceConfig {
            warm: WarmConfig {
                dir: Some(dir.to_path_buf()),
                spill_every,
                include_cache: true,
                max_snapshot_bytes: 0,
            },
            ..Default::default()
        },
    )
}

#[test]
fn service_cache_survives_a_restart() {
    let dir = std::env::temp_dir().join(format!("astra_warm_svc_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let model = ModelRegistry::builtin().get("llama2-7b").unwrap().clone();
    let req = SearchRequest::homogeneous("a800", 16, model.clone()).unwrap();
    let req2 = SearchRequest::homogeneous("a800", 8, model).unwrap();

    // First process: two searches, manual spill on "shutdown".
    let first = warm_service(&dir, 0);
    let a = first.handle(&req).unwrap();
    let b = first.handle(&req2).unwrap();
    assert_eq!(first.core().searches_run(), 2);
    let spill = first.spill_warm().unwrap().expect("warm dir configured");
    assert_eq!(spill.scopes, 1, "both requests share one model scope");
    assert_eq!(spill.cache_entries, 2);

    // Second process: restore on boot; both requests come from the cache,
    // the engine never runs, and the reports are byte-identical.
    let second = warm_service(&dir, 0);
    let ra = second.handle(&req).unwrap();
    let rb = second.handle(&req2).unwrap();
    assert_eq!(second.core().searches_run(), 0, "restored cache must serve without searching");
    assert_eq!(ra.source, astra::service::ResponseSource::Cache);
    assert_eq!(rb.source, astra::service::ResponseSource::Cache);
    assert_eq!(ra.fingerprint, a.fingerprint);
    assert_eq!(rb.fingerprint, b.fingerprint);
    let cat = GpuCatalog::builtin();
    for (fresh, restored) in [(&a, &ra), (&b, &rb)] {
        assert_eq!(
            astra::json::to_string(&report_json(&fresh.report, &cat)),
            astra::json::to_string(&report_json(&restored.report, &cat)),
            "restored cache entry drifted from the original report"
        );
    }
    // Persistence counters surface on the stats line.
    let p = second.core().persist_stats();
    assert_eq!(p.scopes_restored, 1);
    assert_eq!(p.cache_entries_restored, 2);
    let stats = astra::service::server::stats_json(&second);
    assert_eq!(
        stats.pointer("/stats/persist_scopes_restored").and_then(astra::json::Value::as_u64),
        Some(1)
    );
    assert_eq!(
        stats.pointer("/stats/persist_cache_restored").and_then(astra::json::Value::as_u64),
        Some(2)
    );
    // And a third process's restored *memo* pre-warms even a request the
    // cache has never seen: the mode-3 count sweep over ≤16 GPUs revisits
    // the count-8 and count-16 pools whose profiles were spilled, so it
    // must miss strictly less than the same search on a cold engine.
    let model = ModelRegistry::builtin().get("llama2-7b").unwrap().clone();
    let req3 = SearchRequest::cost("a800", 16, f64::INFINITY, model).unwrap();
    let cold3 = engine().search(&req3).unwrap();
    assert!(cold3.memo_misses > 0);
    let third = warm_service(&dir, 0);
    let rc = third.handle(&req3).unwrap();
    assert_eq!(rc.source, astra::service::ResponseSource::Search);
    assert!(
        rc.report.memo_misses < cold3.memo_misses,
        "restored scope must pre-warm unseen requests: {} misses vs cold {}",
        rc.report.memo_misses,
        cold3.memo_misses
    );
    assert_eq!(
        astra::json::to_string(&report_json(&rc.report, &cat)),
        astra::json::to_string(&report_json(&cold3, &cat)),
        "pre-warming must not change the selection"
    );

    // include_cache: false gates the restore direction too — the snapshot
    // on disk still carries cache entries, but none may be served; the
    // memo scopes, by contrast, still restore.
    let core = astra::coordinator::ScoringCore::new(
        GpuCatalog::builtin(),
        EngineConfig { use_forests: false, space: small_space(), ..Default::default() },
    );
    let no_cache = SearchService::new(
        core,
        ServiceConfig {
            warm: WarmConfig {
                dir: Some(dir.clone()),
                spill_every: 0,
                include_cache: false,
                max_snapshot_bytes: 0,
            },
            ..Default::default()
        },
    );
    let r = no_cache.handle(&req).unwrap();
    assert_eq!(
        r.source,
        astra::service::ResponseSource::Search,
        "include_cache=false must not serve restored cache entries"
    );
    assert_eq!(r.report.memo_misses, 0, "memo scopes still restore without the cache");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn spill_every_n_admissions_writes_in_the_background() {
    let dir = std::env::temp_dir().join(format!("astra_warm_auto_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let svc = warm_service(&dir, 1); // spill after every admission
    let model = ModelRegistry::builtin().get("llama2-7b").unwrap().clone();
    svc.handle(&SearchRequest::homogeneous("a800", 8, model.clone()).unwrap()).unwrap();
    let path = svc.warm_path().unwrap();
    assert!(path.exists(), "first admission must have spilled");
    let first_spill = std::fs::metadata(&path).unwrap().len();
    assert!(first_spill > 0);
    // A cache hit is not an admission: the file is not rewritten with new
    // state (byte size is a cheap stand-in — one scope either way).
    svc.handle(&SearchRequest::homogeneous("a800", 8, model.clone()).unwrap()).unwrap();
    let p = svc.core().persist_stats();
    assert_eq!(p.scopes_spilled, 1, "cache hit must not trigger a spill");
    // A second distinct admission re-spills (now with two cache entries).
    svc.handle(&SearchRequest::homogeneous("a800", 16, model).unwrap()).unwrap();
    let p = svc.core().persist_stats();
    assert_eq!(p.scopes_spilled, 2);
    assert!(std::fs::metadata(&path).unwrap().len() > first_spill);
    let _ = std::fs::remove_dir_all(&dir);
}

/// `max_snapshot_bytes`: a budgeted spill drops least-recently-used scopes
/// first, counts them, and what survives still restores bit-exactly.
#[test]
fn snapshot_byte_budget_drops_lru_scopes_first() {
    let reg = ModelRegistry::builtin();
    let m7 = reg.get("llama2-7b").unwrap().clone();
    let m8 = reg.get("llama3-8b").unwrap().clone();
    let req7 = SearchRequest::homogeneous("a800", 8, m7).unwrap();
    let req8 = SearchRequest::homogeneous("a800", 8, m8).unwrap();

    // Heat two model scopes in a known recency order: 7b first, 8b last —
    // so the llama3-8b scope is the most recently used.
    let eng = engine();
    eng.search(&req7).unwrap();
    eng.search(&req8).unwrap();

    let full_path = tmppath("budget_full");
    let full = eng.core().save_warm(&full_path).unwrap();
    let _ = std::fs::remove_file(&full_path);
    assert_eq!(full.scopes, 2, "two model scopes expected");

    // One byte under the full size: the most-recent scope that fits is
    // kept, the LRU one is dropped and counted.
    let capped_path = tmppath("budget_capped");
    let capped = eng.core().save_warm_within(&capped_path, full.bytes - 1).unwrap();
    assert_eq!(capped.scopes, 1, "budget must drop exactly the LRU scope");
    assert!(capped.bytes < full.bytes);
    let p = eng.core().persist_stats();
    assert_eq!(p.scopes_dropped, 1, "dropped scope must be counted");

    // The surviving scope is the most recently used (llama3-8b): a fresh
    // engine restoring the capped snapshot runs that search with zero
    // misses while the 7b search starts cold.
    let fresh = engine();
    let st = fresh.core().load_warm(&capped_path).unwrap();
    let _ = std::fs::remove_file(&capped_path);
    assert_eq!((st.scopes_restored, st.scopes_rejected), (1, 0));
    let warm8 = fresh.search(&req8).unwrap();
    assert_eq!(warm8.memo_misses, 0, "kept scope must be the most recently used (llama3-8b)");
    let cold7 = fresh.search(&req7).unwrap();
    assert!(cold7.memo_misses > 0, "dropped scope must start cold");

    // A budget below even the file header + smallest scope keeps nothing,
    // but the snapshot stays well-formed (restores to a clean cold start).
    let tiny_path = tmppath("budget_tiny");
    let tiny = eng.core().save_warm_within(&tiny_path, 32).unwrap();
    assert_eq!(tiny.scopes, 0);
    let st = engine().core().load_warm(&tiny_path).unwrap();
    let _ = std::fs::remove_file(&tiny_path);
    assert_eq!((st.scopes_restored, st.scopes_rejected), (0, 0));

    // The counter surfaces on the service stats line.
    let dir = std::env::temp_dir().join(format!("astra_warm_budget_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let core = astra::coordinator::ScoringCore::new(
        GpuCatalog::builtin(),
        EngineConfig { use_forests: false, space: small_space(), ..Default::default() },
    );
    let svc = SearchService::new(
        core,
        ServiceConfig {
            warm: WarmConfig {
                dir: Some(dir.clone()),
                spill_every: 0,
                include_cache: false,
                // Comfortably below one serialized scope, forcing a drop.
                max_snapshot_bytes: 256,
            },
            ..Default::default()
        },
    );
    let model = ModelRegistry::builtin().get("llama2-7b").unwrap().clone();
    svc.handle(&SearchRequest::homogeneous("a800", 8, model).unwrap()).unwrap();
    svc.spill_warm().unwrap().expect("warm dir configured");
    let stats = astra::service::server::stats_json(&svc);
    assert!(
        stats
            .pointer("/stats/persist_scopes_dropped")
            .and_then(astra::json::Value::as_u64)
            .unwrap()
            >= 1,
        "budget drops must surface on the stats line"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// The adaptive sweep schedule (grow-on-zero-waste, reset-on-waste) must
/// be invisible in the report, like every other schedule knob.
#[test]
fn adaptive_wave_cap_does_not_change_results() {
    let model = ModelRegistry::builtin().get("llama2-7b").unwrap().clone();
    let req =
        SearchRequest::hetero_cost(&[("a800", 8), ("h100", 8), ("v100", 8)], 1e5, model)
            .unwrap();
    let mk = |wave: usize, wave_max: usize| {
        AstraEngine::new(
            GpuCatalog::builtin(),
            EngineConfig {
                use_forests: false,
                sweep_wave: wave,
                sweep_wave_max: wave_max,
                space: small_space(),
                ..Default::default()
            },
        )
    };
    let serial = canon(&mk(1, 1), &req);
    for (wave, wave_max) in [(1, 8), (2, 2), (2, 64), (4, 4), (3, 1)] {
        assert_eq!(
            canon(&mk(wave, wave_max), &req),
            serial,
            "wave {wave} / cap {wave_max} drifted from the serial sweep"
        );
    }
}
