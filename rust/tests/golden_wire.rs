//! Golden-snapshot test of the line-delimited JSON wire protocol: a fixed
//! request script through the `astra serve`/`batch` machinery must
//! byte-match the checked-in transcript, including the hetero-cost request
//! shape, success/error/stats lines and field order.
//!
//! Wall-clock fields are zeroed through
//! [`astra::service::server::normalize_response_line`] before comparison —
//! everything else (fingerprints, counts, scored payloads, error strings)
//! is pinned byte-for-byte.
//!
//! ## Regeneration
//!
//! After an *intentional* wire change:
//!
//! ```text
//! ASTRA_REGEN_GOLDEN=1 cargo test --test golden_wire
//! git diff rust/tests/golden/serve_transcript.jsonl   # review, then commit
//! ```
//!
//! If the transcript is missing entirely (fresh checkout state), the test
//! bootstraps it in place and passes with a notice — commit the generated
//! file to arm the byte-match for every later run.

use astra::coordinator::EngineConfig;
use astra::gpu::GpuCatalog;
use astra::service::server::{normalize_response_line, run_batch_lines, ServeOpts};
use astra::service::{SearchService, ServiceConfig};
use astra::strategy::SpaceConfig;
use std::path::PathBuf;

/// The fixed request script: every mode, a cache repeat, a frontier
/// request plus its cache-repeat (pins the reprice-from-cache path on the
/// wire), five error shapes (including two typed `deadline`/`config`
/// refusals), a deadline-exempt cache hit, a stats line and a metrics
/// line — then one *audited* hetero-cost request (a distinct budget, so
/// it searches rather than hitting `hc`'s cache entry and the response
/// carries a fresh decision audit) and a health line (normalized: `ready`
/// and shape pinned, load-dependent window numbers zeroed). One request
/// per admitted batch (max_batch 1) keeps sources deterministic
/// (`search`/`cache`, never `coalesced`).
const SCRIPT: &str = "\
{\"id\":\"homog\",\"model\":\"llama2-7b\",\"gpu\":\"a800\",\"gpus\":8}\n\
{\"id\":\"repeat\",\"model\":\"llama2-7b\",\"gpu\":\"a800\",\"gpus\":8}\n\
{\"id\":\"hetero\",\"model\":\"llama2-7b\",\"mode\":\"heterogeneous\",\"gpus\":8,\"caps\":{\"a800\":8,\"h100\":8}}\n\
{\"id\":\"cost\",\"model\":\"llama2-7b\",\"mode\":\"cost\",\"gpu\":\"a800\",\"gpus\":8,\"max_money\":100000}\n\
{\"id\":\"hc\",\"model\":\"llama2-7b\",\"mode\":\"hetero-cost\",\"caps\":{\"a800\":4,\"h100\":4},\"max_money\":100000}\n\
{\"id\":\"fr\",\"model\":\"llama2-7b\",\"mode\":\"frontier\",\"caps\":{\"a800\":4,\"h100\":4}}\n\
{\"id\":\"fr2\",\"model\":\"llama2-7b\",\"mode\":\"frontier\",\"caps\":{\"a800\":4,\"h100\":4}}\n\
not json at all\n\
{\"id\":\"badmodel\",\"model\":\"gpt-5\",\"gpu\":\"a800\",\"gpus\":8}\n\
{\"id\":\"badbudget\",\"model\":\"llama2-7b\",\"mode\":\"cost\",\"gpu\":\"a800\",\"gpus\":8,\"max_money\":-1}\n\
{\"id\":\"dl0\",\"model\":\"llama2-7b\",\"gpu\":\"a800\",\"gpus\":8,\"deadline_ms\":0}\n\
{\"id\":\"dlcold\",\"model\":\"llama2-13b\",\"gpu\":\"a800\",\"gpus\":8,\"deadline_ms\":0}\n\
{\"id\":\"badmode\",\"model\":\"llama2-7b\",\"mode\":\"quantum\",\"gpus\":8}\n\
{\"cmd\":\"stats\",\"id\":\"stats\"}\n\
{\"cmd\":\"metrics\",\"id\":\"metrics\"}\n\
{\"id\":\"hcaudit\",\"model\":\"llama2-7b\",\"mode\":\"hetero-cost\",\"caps\":{\"a800\":4,\"h100\":4},\"max_money\":50000,\"audit\":true}\n\
{\"cmd\":\"health\",\"id\":\"health\"}\n";

/// Deterministic engine: analytic η (no forest dependence), fixed narrow
/// space so the transcript stays small and debug-profile CI fast.
fn service() -> SearchService {
    let space = SpaceConfig {
        tp_candidates: vec![1, 2],
        max_pp: 2,
        mbs_candidates: vec![1],
        vpp_candidates: vec![1],
        seq_parallel_options: vec![true],
        dist_opt_options: vec![true],
        offload_options: vec![false],
        recompute_none: true,
        recompute_selective: false,
        recompute_full: false,
        ..SpaceConfig::default()
    };
    SearchService::new(
        astra::coordinator::ScoringCore::new(
            GpuCatalog::builtin(),
            EngineConfig { use_forests: false, space, ..Default::default() },
        ),
        ServiceConfig::default(),
    )
}

fn golden_path() -> PathBuf {
    let manifest = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    for rel in ["tests/golden", "rust/tests/golden"] {
        let dir = manifest.join(rel);
        if dir.is_dir() {
            return dir.join("serve_transcript.jsonl");
        }
    }
    manifest.join("tests/golden/serve_transcript.jsonl")
}

fn run_script() -> String {
    let svc = service();
    let mut out: Vec<u8> = Vec::new();
    let opts = ServeOpts { max_batch: 1, top: 1, ..Default::default() };
    let stats = run_batch_lines(&svc, SCRIPT, &mut out, &opts).unwrap();
    assert_eq!(stats.lines, 17, "script drifted");
    assert_eq!(stats.errors, 5, "exactly the five error lines fail");
    let text = String::from_utf8(out).unwrap();
    let mut normalized = String::new();
    for line in text.lines() {
        normalized.push_str(&normalize_response_line(line).unwrap());
        normalized.push('\n');
    }
    normalized
}

#[test]
fn wire_protocol_matches_golden_transcript() {
    let got = run_script();

    // Shape assertions that hold regardless of the snapshot state — the
    // hetero-cost line must be a well-formed success with a priced plan.
    let lines: Vec<astra::json::Value> =
        got.lines().map(|l| astra::json::parse(l).unwrap()).collect();
    assert_eq!(lines.len(), 17);
    assert_eq!(lines[1].opt_str("source"), Some("cache"), "repeat must hit the cache");
    // The metrics line is a success carrying the (normalized) registry
    // dump: the three metric families are present, values are zeroed.
    let metrics = &lines[14];
    assert_eq!(metrics.opt_str("id"), Some("metrics"));
    assert_eq!(metrics.get("ok").and_then(astra::json::Value::as_bool), Some(true));
    for family in ["counters", "gauges", "histograms"] {
        assert!(
            metrics.pointer(&format!("/metrics/{family}")).is_some(),
            "metrics payload missing the {family} family"
        );
    }
    assert!(
        metrics
            .pointer("/metrics/counters/astra_searches_total")
            .and_then(astra::json::Value::as_f64)
            == Some(0.0),
        "normalization must zero metric values"
    );
    let hc = &lines[4];
    assert_eq!(hc.opt_str("id"), Some("hc"));
    assert_eq!(hc.get("ok").and_then(astra::json::Value::as_bool), Some(true));
    assert!(hc.pointer("/best/money_usd").and_then(astra::json::Value::as_f64).unwrap() > 0.0);
    assert!(hc.pointer("/engine/pruned_pools").is_some());
    // The frontier line is a success carrying the full Pareto curve, and
    // its immediate repeat is served (repriced) from the cache — the wire
    // evidence that rate-only price changes never trigger a re-search.
    let fr = &lines[5];
    assert_eq!(fr.opt_str("id"), Some("fr"));
    assert_eq!(fr.get("ok").and_then(astra::json::Value::as_bool), Some(true));
    let points = fr
        .pointer("/frontier/points")
        .and_then(astra::json::Value::as_arr)
        .expect("frontier response must carry frontier.points");
    assert!(!points.is_empty(), "frontier must hold at least one (tput, USD) point");
    assert_eq!(lines[6].opt_str("id"), Some("fr2"));
    assert_eq!(lines[6].opt_str("source"), Some("cache"), "frontier repeat must hit the cache");
    for (i, id, kind) in [
        (8usize, "badmodel", "config"),
        (9, "badbudget", "config"),
        (11, "dlcold", "deadline"),
        (12, "badmode", "config"),
    ] {
        assert_eq!(lines[i].get("ok").and_then(astra::json::Value::as_bool), Some(false));
        assert_eq!(lines[i].opt_str("id"), Some(id));
        assert_eq!(lines[i].opt_str("kind"), Some(kind), "line {i} wrong error kind");
        assert_eq!(
            lines[i].get("retryable").and_then(astra::json::Value::as_bool),
            Some(false),
            "none of the scripted errors are retryable"
        );
    }
    // `dl0` repeats `homog` with an already-expired deadline: cached
    // results are deadline-exempt, so it must still answer from the cache.
    assert_eq!(lines[10].opt_str("id"), Some("dl0"));
    assert_eq!(
        lines[10].opt_str("source"),
        Some("cache"),
        "deadline_ms:0 on a cached request must serve the cache hit"
    );
    // The stats line counts exactly the one cold deadline refusal.
    assert_eq!(lines[13].opt_str("id"), Some("stats"));
    assert_eq!(
        lines[13].pointer("/stats/requests_deadline").and_then(astra::json::Value::as_f64),
        Some(1.0),
        "dlcold is the single deadline event"
    );
    assert_eq!(
        lines[13].pointer("/stats/requests_shed").and_then(astra::json::Value::as_f64),
        Some(0.0)
    );
    assert_eq!(
        lines[13].pointer("/stats/requests_panicked").and_then(astra::json::Value::as_f64),
        Some(0.0)
    );
    // The audited request answers with the explain plane attached: a
    // fresh search (distinct budget from `hc`) whose `audit` object
    // partitions its pools and certifies every prune.
    let hcaudit = &lines[15];
    assert_eq!(hcaudit.opt_str("id"), Some("hcaudit"));
    assert_eq!(hcaudit.get("ok").and_then(astra::json::Value::as_bool), Some(true));
    assert_eq!(hcaudit.opt_str("source"), Some("search"), "hcaudit must not share hc's cache entry");
    assert_eq!(
        hcaudit.pointer("/audit/astra_audit").and_then(astra::json::Value::as_u64),
        Some(1)
    );
    let n = |k: &str| {
        hcaudit
            .pointer(&format!("/audit/{k}"))
            .and_then(astra::json::Value::as_u64)
            .unwrap_or_else(|| panic!("audit missing {k}"))
    };
    assert_eq!(n("pools"), n("admitted") + n("pruned_budget") + n("pruned_dominated"));
    assert!(
        hcaudit.pointer("/audit/margins/winner/summary").is_some(),
        "audit must explain the winner"
    );
    assert!(hcaudit.pointer("/engine/pruned_budget").is_some());
    // The health line: readiness and shape are pinned; the load-dependent
    // window numbers are zeroed and the per-mode objects emptied by
    // normalization (the registry is process-global).
    let health = &lines[16];
    assert_eq!(health.opt_str("id"), Some("health"));
    assert_eq!(health.get("ok").and_then(astra::json::Value::as_bool), Some(true));
    assert_eq!(
        health.pointer("/health/ready").and_then(astra::json::Value::as_bool),
        Some(true),
        "an unbounded queue is always ready"
    );
    assert_eq!(
        health.pointer("/health/window/requests").and_then(astra::json::Value::as_f64),
        Some(0.0),
        "normalization must zero the window counts"
    );
    for mode in ["homogeneous", "heterogeneous", "cost", "hetero-cost", "frontier"] {
        let modes = health
            .pointer(&format!("/health/window/modes/{mode}"))
            .and_then(astra::json::Value::as_obj)
            .unwrap_or_else(|| panic!("health window missing mode {mode}"));
        assert!(modes.is_empty(), "mode {mode} payload must be emptied by normalization");
    }

    let path = golden_path();
    let regen = std::env::var("ASTRA_REGEN_GOLDEN").as_deref() == Ok("1");
    if regen || !path.exists() {
        // Bootstrap (or regenerate) in place; a read-only checkout cannot
        // arm the byte-match, but the determinism test below still runs.
        let write = std::fs::create_dir_all(path.parent().unwrap())
            .and_then(|_| std::fs::write(&path, &got));
        match write {
            Ok(()) => eprintln!(
                "golden_wire: {} transcript at {} — commit it to arm the byte-match",
                if regen { "regenerated" } else { "bootstrapped" },
                path.display()
            ),
            Err(e) => eprintln!(
                "golden_wire: SKIP byte-match (cannot write {}: {e})",
                path.display()
            ),
        }
        return;
    }
    let want = std::fs::read_to_string(&path).unwrap();
    if got != want {
        // Byte-level diff with a per-line first-divergence pointer.
        for (i, (g, w)) in got.lines().zip(want.lines()).enumerate() {
            assert_eq!(
                g, w,
                "wire transcript line {i} diverged from {} — if the change is \
                 intentional, regenerate with ASTRA_REGEN_GOLDEN=1 (see module docs)",
                path.display()
            );
        }
        panic!(
            "wire transcript length changed ({} vs {} lines) — regenerate with \
             ASTRA_REGEN_GOLDEN=1 if intentional",
            got.lines().count(),
            want.lines().count()
        );
    }
}

/// The transcript itself must be replay-stable: running the script twice
/// in two fresh services yields identical bytes (pins nondeterminism bugs
/// even while the snapshot is in its bootstrapped first-run state).
#[test]
fn wire_transcript_is_deterministic_across_services() {
    assert_eq!(run_script(), run_script());
}
