//! Differential tests: the pruned layer-assignment solver vs the exhaustive
//! Eq. 23 reference on small instances (N≤12 layers, M≤3 types), and the
//! branch-and-bound hetero-cost search vs its unpruned reference — both
//! must agree on the optimum under the real cost model.

use astra::coordinator::{AstraEngine, EngineConfig, SearchRequest};
use astra::cost::{CostModel, EtaProvider};
use astra::gpu::GpuCatalog;
use astra::hetero::{HeteroSolver, TypeBudget};
use astra::model::ModelRegistry;
use astra::strategy::{
    ClusterAssignment, ParallelStrategy, Recompute, RecomputeMethod, SpaceConfig,
};

fn budgets(cat: &GpuCatalog, names: &[&str], cap: usize, tp: usize, dp: usize) -> Vec<TypeBudget> {
    let caps: Vec<(usize, usize)> = names.iter().map(|n| (cat.find(n).unwrap(), cap)).collect();
    HeteroSolver::budgets(cat, &caps, tp, dp)
}

/// Bind an assignment to a concrete small-model strategy so the *real*
/// cost model can rank it.
fn strategy_for(m: &astra::model::ModelSpec, ca: &ClusterAssignment) -> ParallelStrategy {
    ParallelStrategy {
        cluster: ca.clone(),
        tp: 2,
        dp: 2,
        micro_batch: 1,
        global_batch: m.global_batch,
        vpp: 1,
        sequence_parallel: true,
        use_distributed_optimizer: true,
        recompute: Recompute::None,
        recompute_method: RecomputeMethod::Uniform,
        recompute_num_layers: 0,
        offload_optimizer: false,
        overlap_grad_reduce: true,
        overlap_param_gather: true,
        overlap_p2p: true,
        tp_comm_overlap: true,
        use_flash_attn: true,
        ep: 1,
    }
}

/// A small model whose layer count we can vary per instance.
fn small_model(layers: usize) -> astra::model::ModelSpec {
    let mut m = ModelRegistry::builtin().get("llama2-7b").unwrap().clone();
    m.layers = layers;
    m
}

/// Both enumerations respect `Σ m_i·n_i = N` and the per-type stage caps
/// for every emitted assignment, across the whole small-instance grid.
#[test]
fn diff_both_enumerations_respect_invariants() {
    let cat = GpuCatalog::builtin();
    let solver = HeteroSolver::default();
    for names in [vec!["a800", "h100"], vec!["a800", "h100", "v100"]] {
        for layers in [6usize, 8, 9, 11, 12] {
            for pp in 2..=4usize {
                if pp > layers {
                    continue;
                }
                let b = budgets(&cat, &names, 16, 2, 2);
                for (tag, set) in [
                    ("exhaustive", solver.enumerate_exhaustive(layers, pp, &b)),
                    ("pruned", solver.enumerate_pruned(layers, pp, &b)),
                ] {
                    for ca in &set {
                        assert_eq!(ca.pp(), pp, "{tag} N={layers} P={pp}");
                        assert_eq!(ca.layers(), layers, "{tag} N={layers} P={pp}: Σ m·n ≠ N");
                        for seg in &ca.segments {
                            let budget = b.iter().find(|tb| tb.gpu == seg.gpu).unwrap();
                            assert!(
                                seg.stages <= budget.max_stages,
                                "{tag} N={layers} P={pp}: cap violated"
                            );
                            assert!(seg.layers_per_stage >= 1);
                        }
                    }
                }
            }
        }
    }
}

/// With a radius covering the whole layer range, the pruned enumeration
/// *is* the exhaustive one — an exact set-equality differential.
#[test]
fn diff_full_radius_pruned_equals_exhaustive() {
    let cat = GpuCatalog::builtin();
    let wide = HeteroSolver { prune_radius: 12, max_assignments: 2_000_000 };
    for names in [vec!["a800", "h100"], vec!["a800", "h100", "v100"]] {
        for layers in [6usize, 8, 10, 12] {
            for pp in 2..=3usize {
                let b = budgets(&cat, &names, 16, 2, 2);
                let key = |c: &ClusterAssignment| format!("{:?}", c.segments);
                let ex: std::collections::BTreeSet<String> =
                    wide.enumerate_exhaustive(layers, pp, &b).iter().map(key).collect();
                let pr: std::collections::BTreeSet<String> =
                    wide.enumerate_pruned(layers, pp, &b).iter().map(key).collect();
                assert_eq!(
                    ex, pr,
                    "N={layers} P={pp} types={names:?}: full-radius pruned ≠ exhaustive"
                );
            }
        }
    }
}

/// On small instances the default-config pruned solver finds the same
/// optimal assignment as the exhaustive reference under the real cost
/// model (the seed-∝-speed heuristic preserves the optimum; radius 6
/// covers every non-pathological split at N≤12).
#[test]
fn diff_pruned_finds_exhaustive_optimum_small() {
    let cat = GpuCatalog::builtin();
    let cost = CostModel::new(cat.clone(), EtaProvider::Analytic);
    let solver = HeteroSolver { prune_radius: 6, max_assignments: 2_000_000 };
    for names in [vec!["a800", "h100"], vec!["a800", "h100", "v100"]] {
        for layers in [8usize, 10, 12] {
            for pp in 2..=3usize {
                let m = small_model(layers);
                let b = budgets(&cat, &names, 16, 2, 2);
                let best_of = |set: &[ClusterAssignment]| -> f64 {
                    set.iter()
                        .map(|ca| cost.evaluate(&m, &strategy_for(&m, ca)).step_time)
                        .fold(f64::INFINITY, f64::min)
                };
                let ex = solver.enumerate_exhaustive(layers, pp, &b);
                let pr = solver.enumerate_pruned(layers, pp, &b);
                assert!(!ex.is_empty() && !pr.is_empty(), "N={layers} P={pp}");
                let (t_ex, t_pr) = (best_of(&ex), best_of(&pr));
                // pruned ⊆ exhaustive, so t_pr ≥ t_ex; equality means the
                // optimum survived pruning.
                assert!(
                    t_pr <= t_ex * (1.0 + 1e-9),
                    "N={layers} P={pp} types={names:?}: pruned optimum {t_pr:.6}s \
                     vs exhaustive {t_ex:.6}s"
                );
            }
        }
    }
}

/// The hetero-cost acceptance differential: on small configs the pruned
/// search returns the same budget-optimal `(tokens/s, USD)` as the
/// unpruned exhaustive-reference search, across several budgets.
#[test]
fn diff_hetero_cost_prune_preserves_budget_optimum() {
    let space = SpaceConfig {
        tp_candidates: vec![1, 2],
        max_pp: 4,
        mbs_candidates: vec![1, 2],
        vpp_candidates: vec![1],
        seq_parallel_options: vec![true],
        dist_opt_options: vec![true],
        offload_options: vec![false],
        recompute_none: true,
        recompute_selective: false,
        recompute_full: false,
        ..SpaceConfig::default()
    };
    let engine = |prune: bool| {
        AstraEngine::new(
            GpuCatalog::builtin(),
            EngineConfig {
                use_forests: false,
                money_prune: prune,
                space: space.clone(),
                ..Default::default()
            },
        )
    };
    let model = ModelRegistry::builtin().get("llama2-7b").unwrap().clone();
    let caps = [("a800", 8usize), ("h100", 8usize)];
    let pruned_eng = engine(true);
    let reference_eng = engine(false);

    // Learn the cost scale once from the unpruned reference.
    let free = reference_eng
        .search(&SearchRequest::hetero_cost(&caps, f64::INFINITY, model.clone()).unwrap())
        .unwrap();
    assert!(!free.pool.is_empty());
    assert_eq!(free.pruned_pools, 0, "reference must not prune");
    let lo = free.pool.entries().last().unwrap().cost;

    for frac in [0.5, 1.02, 1.3, 2.0, f64::INFINITY] {
        let budget = if frac.is_finite() { lo * frac } else { f64::INFINITY };
        let req = SearchRequest::hetero_cost(&caps, budget, model.clone()).unwrap();
        let a = pruned_eng.search(&req).unwrap();
        let b = reference_eng.search(&req).unwrap();
        let pick = |r: &astra::coordinator::SearchReport| {
            r.pool.best_within_budget(budget).map(|e| (e.throughput, e.cost))
        };
        match (pick(&a), pick(&b)) {
            (Some((ta, ca)), Some((tb, cb))) => {
                assert!(
                    (ta - tb).abs() <= 1e-6 * tb.max(1.0) && (ca - cb).abs() <= 1e-6 * cb.max(1.0),
                    "budget ${budget}: pruned ({ta:.2}, ${ca:.2}) != reference ({tb:.2}, ${cb:.2})"
                );
                // The promoted top-of-report pick agrees too.
                let best = a.best().expect("pruned search selected nothing");
                assert!(best.money_usd <= budget * (1.0 + 1e-9));
            }
            (None, None) => {}
            other => panic!("budget ${budget}: feasibility disagreement {other:?}"),
        }
        // Pruning must never *add* candidates.
        assert!(a.generated <= b.generated, "budget ${budget}");
    }
}
