//! HLO ↔ native engine parity — the cross-layer correctness anchor.
//!
//! The AOT scorer (Layer-2 JAX graph + Layer-1 Pallas kernels, compiled via
//! PJRT) and the pure-rust cost model implement the same math; this test
//! drives both over a corpus of real strategies and requires tight
//! agreement. Skipped (with a loud message) when `make artifacts` has not
//! been run.

use astra::cost::{CostModel, EtaProvider};
use astra::gbdt::EtaForests;
use astra::gpu::GpuCatalog;
use astra::model::ModelRegistry;
use astra::runtime::{artifacts_dir, artifacts_present, ScorerRuntime};
use astra::strategy::{SearchSpace, SpaceConfig};

fn skip_if_no_artifacts() -> bool {
    if !artifacts_present() {
        eprintln!("SKIP: artifacts missing — run `make artifacts` first");
        return true;
    }
    false
}

#[test]
fn scorer_loads_and_runs() {
    if skip_if_no_artifacts() {
        return;
    }
    let rt = ScorerRuntime::load(&artifacts_dir()).expect("load scorer");
    let b = rt.batch;
    use astra::cost::features::{FG, FS, PMAX};
    // All-padding batch: must run and return finite numbers.
    let stage_feats = vec![0.0f32; b * PMAX * FS];
    let stage_mask = vec![0.0f32; b * PMAX];
    let mut strat_feats = vec![0.0f32; b * FG];
    for i in 0..b {
        strat_feats[i * FG] = 1.0; // K
        strat_feats[i * FG + 1] = 1.0; // vpp
        strat_feats[i * FG + 2] = 1.0; // dp
    }
    let rows = rt.execute(&stage_feats, &stage_mask, &strat_feats).expect("execute");
    assert_eq!(rows.len(), b);
    for r in &rows {
        assert!(r.iter().all(|v| v.is_finite()), "non-finite scorer output {r:?}");
    }
}

#[test]
fn hlo_matches_native_cost_model() {
    if skip_if_no_artifacts() {
        return;
    }
    let catalog = GpuCatalog::builtin();
    let reg = ModelRegistry::builtin();
    let forests = EtaForests::from_file(&artifacts_dir().join("forest.json")).expect("forest");
    let cost = CostModel::new(catalog.clone(), EtaProvider::Forests(forests));
    let rt = ScorerRuntime::load(&artifacts_dir()).expect("load scorer");

    let mem = astra::memory::MemoryModel::default();
    let mut checked = 0usize;
    let mut worst: f64 = 0.0;
    for (model_name, gpu_name, count) in
        [("llama2-7b", "a800", 64usize), ("llama2-70b", "h100", 256), ("glm-67b", "a800", 128)]
    {
        let model = reg.get(model_name).unwrap();
        let gpu = catalog.find(gpu_name).unwrap();
        let space = SearchSpace::new(SpaceConfig::default());
        let all = space.homogeneous(model, &catalog, gpu, count);
        // Deterministic thinning: every Nth valid strategy up to one batch.
        let valid: Vec<_> = all
            .into_iter()
            .filter(|s| mem.fits(model, s, &catalog))
            .step_by(37)
            .take(rt.batch)
            .collect();
        assert!(!valid.is_empty(), "{model_name}: no valid strategies");
        let refs: Vec<&astra::strategy::ParallelStrategy> = valid.iter().collect();
        let pb = astra::cost::features::pack_batch(model, &refs, &catalog, rt.batch);
        let rows = rt.execute(&pb.stage_feats, &pb.stage_mask, &pb.strat_feats).unwrap();
        for (i, s) in valid.iter().enumerate() {
            let native = cost.evaluate(model, s);
            let hlo_step = rows[i][0] as f64;
            let rel = (native.step_time - hlo_step).abs() / native.step_time;
            assert!(
                rel < 0.02,
                "{model_name} strategy {}: native {:.6}s vs hlo {:.6}s (rel {:.4})",
                s.summary(),
                native.step_time,
                hlo_step,
                rel
            );
            worst = worst.max(rel);
            checked += 1;
        }
    }
    eprintln!("parity checked on {checked} strategies, worst rel diff {worst:.3e}");
    assert!(checked > 100, "parity corpus too small: {checked}");
}

#[test]
fn forest_json_loads_with_sane_etas() {
    if skip_if_no_artifacts() {
        return;
    }
    let forests = EtaForests::from_file(&artifacts_dir().join("forest.json")).expect("forest");
    // Predictions over the feature range stay in (0, 1].
    let catalog = GpuCatalog::builtin();
    let spec = catalog.spec(catalog.find("a800").unwrap());
    for flops in [1e7f64, 1e10, 1e13] {
        for dim in [32.0f64, 1024.0] {
            for inten in [5.0f64, 500.0] {
                let f = astra::hw::comp_features(spec, flops, dim, inten);
                let x: Vec<f32> = f.iter().map(|&v| v as f32).collect();
                let eta = forests.eta_comp(&x);
                assert!(eta > 0.0 && eta <= 1.0, "eta_comp {eta}");
                // Within 15% of the hardware truth on in-range points; near
                // the 1e-4 clamp floor only absolute agreement matters.
                let truth = astra::hw::eta_comp(spec, flops, dim, inten);
                let rel = (eta - truth).abs() / truth;
                assert!(
                    rel < 0.15 || (eta - truth).abs() < 5e-3,
                    "forest {eta:.4} vs truth {truth:.4} (rel {rel:.3})"
                );
            }
        }
    }
}

#[test]
fn hlo_matches_native_on_heterogeneous_strategies() {
    if skip_if_no_artifacts() {
        return;
    }
    use astra::hetero::HeteroSolver;
    use astra::strategy::SpaceConfig;
    let catalog = GpuCatalog::builtin();
    let reg = ModelRegistry::builtin();
    let forests = EtaForests::from_file(&artifacts_dir().join("forest.json")).expect("forest");
    let cost = CostModel::new(catalog.clone(), EtaProvider::Forests(forests));
    let rt = ScorerRuntime::load(&artifacts_dir()).expect("load scorer");

    let model = reg.get("llama2-13b").unwrap();
    let caps = [(catalog.find("a800").unwrap(), 48usize), (catalog.find("h100").unwrap(), 48)];
    let solver = HeteroSolver::default();
    let space = SearchSpace::new(SpaceConfig { vpp_candidates: vec![1], ..Default::default() });
    let mut strategies = Vec::new();
    for tp in [2usize, 4] {
        for pp in [4usize, 8] {
            let total = 64;
            if total % (tp * pp) != 0 {
                continue;
            }
            let dp = total / (tp * pp);
            let budgets = HeteroSolver::budgets(&catalog, &caps, tp, dp);
            if budgets.iter().map(|b| b.max_stages).sum::<usize>() < pp {
                continue;
            }
            for ca in solver.enumerate_pruned(model.layers, pp, &budgets).into_iter().take(8) {
                space.expand_params(model, &ca, tp, dp, &mut strategies);
            }
        }
    }
    let mem = astra::memory::MemoryModel::default();
    let valid: Vec<_> = strategies
        .into_iter()
        .filter(|s| s.validate(model).is_ok() && mem.fits(model, s, &catalog))
        .step_by(7)
        .take(rt.batch)
        .collect();
    assert!(valid.len() > 20, "hetero parity corpus too small: {}", valid.len());
    let refs: Vec<&astra::strategy::ParallelStrategy> = valid.iter().collect();
    let pb = astra::cost::features::pack_batch(model, &refs, &catalog, rt.batch);
    let rows = rt.execute(&pb.stage_feats, &pb.stage_mask, &pb.strat_feats).unwrap();
    for (i, s) in valid.iter().enumerate() {
        let native = cost.evaluate(model, s);
        let rel = (native.step_time - rows[i][0] as f64).abs() / native.step_time;
        assert!(
            rel < 0.02,
            "hetero parity broke on {}: native {} vs hlo {} (rel {rel:.4})",
            s.summary(),
            native.step_time,
            rows[i][0]
        );
    }
    eprintln!("hetero parity checked on {} strategies", valid.len());
}

#[test]
fn hlo_matches_native_on_moe_strategies() {
    if skip_if_no_artifacts() {
        return;
    }
    let catalog = GpuCatalog::builtin();
    let reg = ModelRegistry::builtin();
    let forests = EtaForests::from_file(&artifacts_dir().join("forest.json")).expect("forest");
    let cost = CostModel::new(catalog.clone(), EtaProvider::Forests(forests));
    let rt = ScorerRuntime::load(&artifacts_dir()).expect("load scorer");

    let model = reg.get("mixtral-8x7b").unwrap();
    let gpu = catalog.find("h100").unwrap();
    let space = SearchSpace::new(SpaceConfig::default());
    let mem = astra::memory::MemoryModel::default();
    let valid: Vec<_> = space
        .homogeneous(model, &catalog, gpu, 64)
        .into_iter()
        .filter(|s| mem.fits(model, s, &catalog))
        .step_by(53)
        .take(rt.batch)
        .collect();
    assert!(valid.len() > 30, "MoE corpus too small: {}", valid.len());
    assert!(valid.iter().any(|s| s.ep > 1), "no expert-parallel strategies in corpus");
    let refs: Vec<&astra::strategy::ParallelStrategy> = valid.iter().collect();
    let pb = astra::cost::features::pack_batch(model, &refs, &catalog, rt.batch);
    let rows = rt.execute(&pb.stage_feats, &pb.stage_mask, &pb.strat_feats).unwrap();
    for (i, s) in valid.iter().enumerate() {
        let native = cost.evaluate(model, s);
        let rel = (native.step_time - rows[i][0] as f64).abs() / native.step_time;
        assert!(
            rel < 0.02,
            "MoE parity broke on {}: native {} vs hlo {} (rel {rel:.4})",
            s.summary(),
            native.step_time,
            rows[i][0]
        );
    }
    eprintln!("MoE parity checked on {} strategies", valid.len());
}
