//! Determinism pins for the plan compiler and the streaming executor:
//!
//! * the canonical report JSON ([`astra::report::report_json`] — counts,
//!   pruning statistics, ranked `top`, full Pareto pool; observability
//!   fields excluded) must be byte-identical across worker counts, across
//!   repeated runs, and across hetero-cost sweep schedules — the
//!   executor's fan-out (`par_for_indices`) returns pool outcomes in task
//!   order and the wave sweep replays its pruning decisions serially, so
//!   *nothing* about thread timing may reach the result;
//! * the compiled [`astra::coordinator::SearchPlan`] itself must be
//!   byte-identical ([`astra::coordinator::plan_json`]) across repeats and
//!   worker counts — compilation is pure, and `workers` never enters a
//!   plan.

use astra::coordinator::{plan_json, AstraEngine, EngineConfig, ScoringCore, SearchRequest};
use astra::gpu::GpuCatalog;
use astra::model::ModelRegistry;
use astra::report::report_json;
use astra::strategy::SpaceConfig;

fn small_space() -> SpaceConfig {
    SpaceConfig {
        tp_candidates: vec![1, 2],
        max_pp: 4,
        mbs_candidates: vec![1, 2],
        vpp_candidates: vec![1],
        seq_parallel_options: vec![true],
        dist_opt_options: vec![true],
        offload_options: vec![false],
        recompute_none: true,
        recompute_selective: false,
        recompute_full: false,
        ..SpaceConfig::default()
    }
}

fn canon(eng: &AstraEngine, req: &SearchRequest) -> String {
    let report = eng.search(req).unwrap();
    astra::json::to_string(&report_json(&report, &GpuCatalog::builtin()))
}

fn engine(streaming: bool, workers: usize, sweep_wave: usize) -> AstraEngine {
    AstraEngine::new(
        GpuCatalog::builtin(),
        EngineConfig {
            use_forests: false,
            streaming,
            workers,
            sweep_wave,
            space: small_space(),
            ..Default::default()
        },
    )
}

fn requests() -> Vec<(&'static str, SearchRequest)> {
    let model = ModelRegistry::builtin().get("llama2-7b").unwrap().clone();
    vec![
        ("homogeneous", SearchRequest::homogeneous("a800", 32, model.clone()).unwrap()),
        (
            "heterogeneous",
            SearchRequest::heterogeneous(&[("a800", 8), ("h100", 8)], 8, model.clone())
                .unwrap(),
        ),
        ("cost", SearchRequest::cost("a800", 16, f64::INFINITY, model.clone()).unwrap()),
        (
            "hetero-cost",
            SearchRequest::hetero_cost(&[("a800", 8), ("h100", 8)], 2e5, model).unwrap(),
        ),
    ]
}

/// workers=1 vs workers=N: byte-identical canonical reports on every mode,
/// with the streaming flag in both positions (`false` = the serial-oracle
/// compatibility mapping). Fresh engines per run so memo state cannot
/// differ either.
#[test]
fn workers_do_not_change_report_json() {
    for streaming in [true, false] {
        for (name, req) in requests() {
            let serial = canon(&engine(streaming, 1, 2), &req);
            for workers in [2, 4, 8] {
                let parallel = canon(&engine(streaming, workers, 2), &req);
                assert_eq!(
                    serial, parallel,
                    "mode {name} (streaming={streaming}): workers={workers} drifted"
                );
            }
        }
    }
}

/// Serial vs parallel hetero-cost sweep (wave 1 vs wider), crossed with
/// worker counts — the full schedule matrix collapses to one report.
#[test]
fn sweep_schedule_does_not_change_report_json() {
    let model = ModelRegistry::builtin().get("llama2-7b").unwrap().clone();
    let req =
        SearchRequest::hetero_cost(&[("a800", 8), ("h100", 8), ("v100", 8)], 1e5, model).unwrap();
    let baseline = canon(&engine(true, 1, 1), &req);
    for workers in [1, 4] {
        for wave in [1, 2, 4, 64] {
            let got = canon(&engine(true, workers, wave), &req);
            assert_eq!(got, baseline, "workers={workers} wave={wave} drifted from serial");
        }
    }
}

/// Same engine, same request, back to back: the second (memo-warm) run is
/// byte-identical — warmth is speed, never results.
#[test]
fn repeat_runs_on_one_engine_are_byte_identical() {
    let eng = engine(true, 4, 2);
    for (name, req) in requests() {
        let first = canon(&eng, &req);
        let second = canon(&eng, &req);
        assert_eq!(first, second, "mode {name}: repeat run drifted");
    }
}

/// Plan-level matrix: the same request compiles to a byte-identical
/// [`astra::coordinator::SearchPlan`] across repeats and worker counts, on
/// every mode. (Wave knobs *are* part of the plan — they are pinned by the
/// golden plan snapshots instead — but `workers` must never enter it.)
#[test]
fn plan_compilation_is_deterministic_and_worker_invariant() {
    let cat = GpuCatalog::builtin();
    let core = |workers: usize| {
        ScoringCore::new(
            cat.clone(),
            EngineConfig {
                use_forests: false,
                workers,
                space: small_space(),
                ..Default::default()
            },
        )
    };
    for (name, req) in requests() {
        let base_core = core(1);
        let plan = |c: &ScoringCore| {
            astra::json::to_string(&plan_json(&c.compile_plan(&req).unwrap(), &cat))
        };
        let base = plan(&base_core);
        assert_eq!(base, plan(&base_core), "mode {name}: repeat compile drifted");
        for workers in [2, 8] {
            assert_eq!(
                base,
                plan(&core(workers)),
                "mode {name}: workers={workers} changed the compiled plan"
            );
        }
    }
}
