//! Determinism pins for the plan compiler and the streaming executor:
//!
//! * the canonical report JSON ([`astra::report::report_json`] — counts,
//!   pruning statistics, ranked `top`, full Pareto pool; observability
//!   fields excluded) must be byte-identical across worker counts, across
//!   repeated runs, and across hetero-cost sweep schedules — the
//!   executor's fan-out (`par_for_indices`) returns pool outcomes in task
//!   order and the wave sweep replays its pruning decisions serially, so
//!   *nothing* about thread timing may reach the result;
//! * the compiled [`astra::coordinator::SearchPlan`] itself must be
//!   byte-identical ([`astra::coordinator::plan_json`]) across repeats and
//!   worker counts — compilation is pure, and `workers` never enters a
//!   plan.

use astra::coordinator::{plan_json, AstraEngine, EngineConfig, ScoringCore, SearchRequest};
use astra::gpu::GpuCatalog;
use astra::model::ModelRegistry;
use astra::report::report_json;
use astra::strategy::SpaceConfig;

fn small_space() -> SpaceConfig {
    SpaceConfig {
        tp_candidates: vec![1, 2],
        max_pp: 4,
        mbs_candidates: vec![1, 2],
        vpp_candidates: vec![1],
        seq_parallel_options: vec![true],
        dist_opt_options: vec![true],
        offload_options: vec![false],
        recompute_none: true,
        recompute_selective: false,
        recompute_full: false,
        ..SpaceConfig::default()
    }
}

fn canon(eng: &AstraEngine, req: &SearchRequest) -> String {
    let report = eng.search(req).unwrap();
    astra::json::to_string(&report_json(&report, &GpuCatalog::builtin()))
}

fn engine(streaming: bool, workers: usize, sweep_wave: usize) -> AstraEngine {
    AstraEngine::new(
        GpuCatalog::builtin(),
        EngineConfig {
            use_forests: false,
            streaming,
            workers,
            sweep_wave,
            space: small_space(),
            ..Default::default()
        },
    )
}

fn requests() -> Vec<(&'static str, SearchRequest)> {
    let model = ModelRegistry::builtin().get("llama2-7b").unwrap().clone();
    vec![
        ("homogeneous", SearchRequest::homogeneous("a800", 32, model.clone()).unwrap()),
        (
            "heterogeneous",
            SearchRequest::heterogeneous(&[("a800", 8), ("h100", 8)], 8, model.clone())
                .unwrap(),
        ),
        ("cost", SearchRequest::cost("a800", 16, f64::INFINITY, model.clone()).unwrap()),
        (
            "hetero-cost",
            SearchRequest::hetero_cost(&[("a800", 8), ("h100", 8)], 2e5, model).unwrap(),
        ),
    ]
}

/// workers=1 vs workers=N: byte-identical canonical reports on every mode,
/// with the streaming flag in both positions (`false` = the serial-oracle
/// compatibility mapping). Fresh engines per run so memo state cannot
/// differ either.
#[test]
fn workers_do_not_change_report_json() {
    for streaming in [true, false] {
        for (name, req) in requests() {
            let serial = canon(&engine(streaming, 1, 2), &req);
            for workers in [2, 4, 8] {
                let parallel = canon(&engine(streaming, workers, 2), &req);
                assert_eq!(
                    serial, parallel,
                    "mode {name} (streaming={streaming}): workers={workers} drifted"
                );
            }
        }
    }
}

/// Serial vs parallel hetero-cost sweep (wave 1 vs wider), crossed with
/// worker counts — the full schedule matrix collapses to one report.
#[test]
fn sweep_schedule_does_not_change_report_json() {
    let model = ModelRegistry::builtin().get("llama2-7b").unwrap().clone();
    let req =
        SearchRequest::hetero_cost(&[("a800", 8), ("h100", 8), ("v100", 8)], 1e5, model).unwrap();
    let baseline = canon(&engine(true, 1, 1), &req);
    for workers in [1, 4] {
        for wave in [1, 2, 4, 64] {
            let got = canon(&engine(true, workers, wave), &req);
            assert_eq!(got, baseline, "workers={workers} wave={wave} drifted from serial");
        }
    }
}

/// Same engine, same request, back to back: the second (memo-warm) run is
/// byte-identical — warmth is speed, never results.
#[test]
fn repeat_runs_on_one_engine_are_byte_identical() {
    let eng = engine(true, 4, 2);
    for (name, req) in requests() {
        let first = canon(&eng, &req);
        let second = canon(&eng, &req);
        assert_eq!(first, second, "mode {name}: repeat run drifted");
    }
}

/// Telemetry must be pure observation: running the full mode matrix with
/// the flight recorder streaming (and the metrics registry live — it
/// always is) produces byte-identical canonical reports, and the trace
/// file itself is valid JSONL with nondecreasing timestamps.
#[test]
fn telemetry_and_tracing_do_not_change_reports() {
    let mut baselines = Vec::new();
    for (_, req) in requests() {
        baselines.push(canon(&engine(true, 4, 2), &req));
    }

    let path = std::env::temp_dir()
        .join(format!("astra_determinism_trace_{}.jsonl", std::process::id()));
    astra::telemetry::trace::enable(&path).unwrap();
    let mut traced = Vec::new();
    for (_, req) in requests() {
        traced.push(canon(&engine(true, 4, 2), &req));
    }
    astra::telemetry::trace::disable();

    for ((name, _), (base, got)) in requests().iter().zip(baselines.iter().zip(&traced)) {
        assert_eq!(base, got, "mode {name}: tracing changed the canonical report");
    }

    // The recorder side: every line parses, ts never goes backwards.
    let text = std::fs::read_to_string(&path).unwrap();
    let _ = std::fs::remove_file(&path);
    assert!(!text.is_empty(), "tracing produced no span events");
    let mut last_ts = f64::NEG_INFINITY;
    for line in text.lines() {
        let v = astra::json::parse(line).expect("trace line must be valid JSON");
        assert_eq!(v.get("ph").and_then(astra::json::Value::as_str), Some("X"));
        let ts = v.get("ts").and_then(astra::json::Value::as_f64).expect("numeric ts");
        assert!(ts >= last_ts, "trace ts went backwards: {ts} < {last_ts}");
        last_ts = ts;
    }
}

/// The decision audit is a view switch, not a different search: on every
/// mode the canonical report JSON is byte-identical with auditing on or
/// off (`report_json` never serializes the audit field).
#[test]
fn audit_does_not_change_report_json() {
    for (name, req) in requests() {
        let plain = canon(&engine(true, 4, 2), &req);
        let audited_rep = engine(true, 4, 2).search_audited(&req).unwrap();
        assert!(audited_rep.audit.is_some(), "mode {name}: audited search lost its audit");
        let audited =
            astra::json::to_string(&report_json(&audited_rep, &GpuCatalog::builtin()));
        assert_eq!(plain, audited, "mode {name}: auditing changed the canonical report");
    }
}

/// The canonical audit JSON collapses the whole executor schedule matrix
/// to one byte string: workers 1/2/4/8 × waves 1/2/64 on the three-type
/// hetero-cost sweep all replay the same (round, pool) decisions against
/// the same true frontier — so `report::audit_json` (which excludes the
/// load-dependent wave/memo observability) cannot tell them apart.
#[test]
fn audit_json_is_schedule_invariant() {
    let model = ModelRegistry::builtin().get("llama2-7b").unwrap().clone();
    let caps = [("a800", 8), ("h100", 8), ("v100", 8)];
    // Learn the cost scale free of any budget, then pin one just above the
    // cheapest frontier point — the band where `diff_streaming.rs` proves
    // the pruner has real work, so the schedule pin is never vacuous.
    let free = engine(true, 1, 1)
        .search(&SearchRequest::hetero_cost(&caps, f64::INFINITY, model.clone()).unwrap())
        .unwrap();
    let cheap = free.pool.entries().last().expect("empty frontier").cost;
    let req = SearchRequest::hetero_cost(&caps, cheap * 1.05, model).unwrap();
    let audit_canon = |workers: usize, wave: usize| {
        let rep = engine(true, workers, wave).search_audited(&req).unwrap();
        let v = astra::report::audit_json(&rep).expect("audited search emits audit JSON");
        astra::json::to_string(&v)
    };
    let baseline = audit_canon(1, 1);
    let v = astra::json::parse(&baseline).unwrap();
    let count = |k: &str| v.get(k).and_then(astra::json::Value::as_u64).unwrap_or(0);
    assert!(
        count("pruned_budget") + count("pruned_dominated") > 0,
        "sweep produced no prunes — the schedule pin would be vacuous"
    );
    for workers in [1, 2, 4, 8] {
        for wave in [1, 2, 64] {
            assert_eq!(
                audit_canon(workers, wave),
                baseline,
                "workers={workers} wave={wave}: audit drifted from the serial schedule"
            );
        }
    }
}

/// The per-phase breakdown is not an estimate alongside the wall fields —
/// it *is* the wall fields: `search_secs` and `simulate_secs` are derived
/// from the phase sums, so they agree bit-for-bit.
#[test]
fn phase_breakdown_sums_to_wall_fields() {
    for streaming in [true, false] {
        let eng = engine(streaming, 4, 2);
        for (name, req) in requests() {
            let r = eng.search(&req).unwrap();
            assert_eq!(
                r.search_secs.to_bits(),
                r.phases.search_secs().to_bits(),
                "mode {name} (streaming={streaming}): search_secs != phase sum"
            );
            assert_eq!(
                r.simulate_secs.to_bits(),
                r.phases.simulate_secs().to_bits(),
                "mode {name} (streaming={streaming}): simulate_secs != phase sum"
            );
            for (phase, secs) in r.phases.rows() {
                assert!(
                    secs.is_finite() && secs >= 0.0,
                    "mode {name}: phase {phase} has invalid duration {secs}"
                );
            }
        }
    }
}

/// Plan-level matrix: the same request compiles to a byte-identical
/// [`astra::coordinator::SearchPlan`] across repeats and worker counts, on
/// every mode. (Wave knobs *are* part of the plan — they are pinned by the
/// golden plan snapshots instead — but `workers` must never enter it.)
#[test]
fn plan_compilation_is_deterministic_and_worker_invariant() {
    let cat = GpuCatalog::builtin();
    let core = |workers: usize| {
        ScoringCore::new(
            cat.clone(),
            EngineConfig {
                use_forests: false,
                workers,
                space: small_space(),
                ..Default::default()
            },
        )
    };
    for (name, req) in requests() {
        let base_core = core(1);
        let plan = |c: &ScoringCore| {
            astra::json::to_string(&plan_json(&c.compile_plan(&req).unwrap(), &cat))
        };
        let base = plan(&base_core);
        assert_eq!(base, plan(&base_core), "mode {name}: repeat compile drifted");
        for workers in [2, 8] {
            assert_eq!(
                base,
                plan(&core(workers)),
                "mode {name}: workers={workers} changed the compiled plan"
            );
        }
    }
}
