//! Metric-name drift guard: the golden README's metric table and the
//! pinned `telemetry::core_metric_names()` list must describe exactly the
//! same set. A metric added, renamed or dropped in code without a
//! matching documentation row (or a documented metric that no longer
//! exists) fails here — before an operator's dashboard finds out.
//!
//! The README may compress families with shell-style braces
//! (`astra_request_{homogeneous,…}_seconds`); the parser expands them, so
//! docs stay readable without weakening the guard.

use std::collections::BTreeSet;

/// Expand one `{a,b,c}` brace group (the table never nests them).
fn expand_braces(name: &str) -> Vec<String> {
    let (Some(open), Some(close)) = (name.find('{'), name.find('}')) else {
        return vec![name.to_string()];
    };
    assert!(open < close, "malformed brace family in metric row: {name}");
    let (head, rest) = name.split_at(open);
    let body = &rest[1..close - open];
    let tail = &rest[close - open + 1..];
    assert!(
        !tail.contains('{'),
        "nested/multiple brace families are not supported: {name}"
    );
    body.split(',').map(|alt| format!("{head}{}{tail}", alt.trim())).collect()
}

/// Every metric name documented in the golden README's table, families
/// expanded. Rows look like `` | `name` | type | meaning | ``.
fn documented_names() -> BTreeSet<String> {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/tests/golden/README.md");
    let text = std::fs::read_to_string(path).expect("golden README must exist");
    let mut names = BTreeSet::new();
    for line in text.lines() {
        let line = line.trim();
        // A table row whose first cell is a backticked metric name.
        let Some(cell) = line.strip_prefix("| `") else { continue };
        let Some(end) = cell.find('`') else { continue };
        let name = &cell[..end];
        if !name.starts_with("astra_") {
            continue;
        }
        for expanded in expand_braces(name) {
            assert!(
                names.insert(expanded.clone()),
                "metric {expanded} documented twice in the golden README"
            );
        }
    }
    names
}

#[test]
fn documented_metrics_match_the_pinned_registry_set() {
    let documented = documented_names();
    let pinned: BTreeSet<String> =
        astra::telemetry::core_metric_names().into_iter().map(String::from).collect();
    assert!(!pinned.is_empty(), "pinned metric list is empty");

    let undocumented: Vec<_> = pinned.difference(&documented).collect();
    let stale: Vec<_> = documented.difference(&pinned).collect();
    assert!(
        undocumented.is_empty() && stale.is_empty(),
        "metric-name drift:\n  pinned but not in the golden README table: {undocumented:?}\n  \
         documented but not pinned in telemetry::core_metric_names(): {stale:?}"
    );
}

/// The pinned list itself is duplicate-free and well-formed — a duplicate
/// would silently collapse in the set comparison above.
#[test]
fn pinned_names_are_unique_and_prefixed() {
    let names = astra::telemetry::core_metric_names();
    let set: BTreeSet<_> = names.iter().collect();
    assert_eq!(set.len(), names.len(), "duplicate name in the pinned metric list");
    for n in &names {
        assert!(n.starts_with("astra_"), "unprefixed metric name: {n}");
    }
}

/// The brace expander the guard relies on.
#[test]
fn brace_families_expand() {
    assert_eq!(expand_braces("astra_x_total"), vec!["astra_x_total"]);
    assert_eq!(
        expand_braces("astra_request_{a,b}_seconds"),
        vec!["astra_request_a_seconds", "astra_request_b_seconds"]
    );
}
