//! Deterministic chaos schedules against the production failpoint seams
//! (`astra::resilience::failpoint`), in their own process so arming
//! process-global failpoints cannot perturb the other test binaries.
//!
//! Every schedule asserts the same three resilience invariants:
//!
//! 1. **No panic escapes** — the serve loop and the service API return
//!    typed errors (`kind` ∈ {fault, panic, deadline, overloaded}) for
//!    every injected failure; the process never dies.
//! 2. **Exactly one terminal response per request** — lines in, lines
//!    out, no drops and no duplicates, under every schedule.
//! 3. **Clean recovery** — once faults clear, reports and warm snapshots
//!    are byte-identical to an undisturbed run: no fault leaves residue
//!    in the cache, the memo, or the single-flight table.
//!
//! The failpoint registry is process-global and the test harness is
//! multi-threaded, so every test (arming or searching) serializes on
//! [`FP_LOCK`].

use astra::coordinator::{EngineConfig, ScoringCore, SearchRequest};
use astra::gpu::GpuCatalog;
use astra::model::ModelRegistry;
use astra::resilience::failpoint::{self, FailAction, FailSpec};
use astra::resilience::CancelToken;
use astra::service::server::{normalize_response_line, run_batch_lines, run_serve_loop, ServeOpts};
use astra::service::{ResponseSource, SearchService, ServiceConfig, WarmConfig};
use astra::strategy::SpaceConfig;
use astra::AstraError;
use std::sync::Mutex;

/// Serializes every test in this binary: failpoints are process-global,
/// so an armed seam in one test must never fire inside another.
static FP_LOCK: Mutex<()> = Mutex::new(());

fn locked() -> std::sync::MutexGuard<'static, ()> {
    // A previous test failing while holding the lock poisons it; the
    // guard state (nothing) is trivially valid, and `disarm_all` on entry
    // re-establishes the failpoint invariant.
    let g = FP_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    failpoint::disarm_all();
    failpoint::set_seed(0);
    g
}

/// Deliberately narrow space: large enough to stream real waves, small
/// enough that a debug-profile chaos run stays fast.
fn core() -> ScoringCore {
    let space = SpaceConfig {
        tp_candidates: vec![1, 2],
        max_pp: 4,
        mbs_candidates: vec![1, 2],
        vpp_candidates: vec![1],
        seq_parallel_options: vec![true],
        dist_opt_options: vec![true],
        offload_options: vec![false],
        recompute_none: true,
        recompute_selective: false,
        recompute_full: false,
        ..SpaceConfig::default()
    };
    ScoringCore::new(
        GpuCatalog::builtin(),
        EngineConfig { use_forests: false, space, ..Default::default() },
    )
}

fn service() -> SearchService {
    SearchService::new(core(), ServiceConfig::default())
}

fn warm_service(dir: &std::path::Path) -> SearchService {
    SearchService::new(
        core(),
        ServiceConfig {
            warm: WarmConfig {
                dir: Some(dir.to_path_buf()),
                spill_every: 0,
                include_cache: true,
                max_snapshot_bytes: 0,
            },
            ..Default::default()
        },
    )
}

fn req(count: usize) -> SearchRequest {
    let model = ModelRegistry::builtin().get("llama2-7b").unwrap().clone();
    SearchRequest::homogeneous("a800", count, model).unwrap()
}

fn temp_dir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("astra_chaos_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

/// Canonical (wall-clock-free) view of a report for byte comparison.
fn report_bytes(svc: &SearchService, resp: &astra::service::ServiceResponse) -> String {
    astra::json::to_string(&astra::report::report_json(&resp.report, &svc.core().catalog))
}

/// Run one fixed script through the serve loop, returning (stats, lines).
fn serve_script(svc: &SearchService, script: &str) -> (astra::service::server::ServeStats, Vec<String>) {
    let mut out: Vec<u8> = Vec::new();
    let input = std::io::Cursor::new(script.as_bytes().to_vec());
    let opts = ServeOpts { max_batch: 1, top: 1, ..Default::default() };
    let stats = run_serve_loop(svc, input, &mut out, &opts).unwrap();
    let text = String::from_utf8(out).unwrap();
    (stats, text.lines().map(String::from).collect())
}

fn parsed(line: &str) -> astra::json::Value {
    astra::json::parse(line).unwrap_or_else(|e| panic!("unparseable response {line}: {e}"))
}

// ---------------------------------------------------------------------------
// Schedule 1: persist IO failure (`persist.spill`)
// ---------------------------------------------------------------------------

#[test]
fn spill_fault_is_isolated_and_recovery_is_byte_identical() {
    let _g = locked();
    let dir_a = temp_dir("spill_a");
    let dir_b = temp_dir("spill_b");

    // Disturbed service: search, then spill into an armed seam.
    let svc = warm_service(&dir_a);
    svc.handle(&req(8)).unwrap();
    failpoint::arm("persist.spill", FailSpec::always(FailAction::Error));
    let err = svc.spill_warm().unwrap_err();
    assert_eq!(err.kind(), "fault", "{err}");
    assert!(
        !dir_a.join("warm.jsonl").exists(),
        "a failed spill must not leave a partial snapshot"
    );
    // The service keeps serving through the spill fault (cache hit).
    assert_eq!(svc.handle(&req(8)).unwrap().source, ResponseSource::Cache);

    // Faults clear → the spill succeeds and the snapshot is byte-identical
    // to an undisturbed twin's.
    failpoint::disarm_all();
    svc.spill_warm().unwrap().expect("configured spill must run");

    let twin = warm_service(&dir_b);
    twin.handle(&req(8)).unwrap();
    twin.spill_warm().unwrap().expect("configured spill must run");
    let a = std::fs::read_to_string(dir_a.join("warm.jsonl")).unwrap();
    let b = std::fs::read_to_string(dir_b.join("warm.jsonl")).unwrap();
    assert_eq!(a, b, "post-recovery snapshot must match the undisturbed run");
    assert!(failpoint::faults_injected() > 0, "the schedule must actually have fired");

    let _ = std::fs::remove_dir_all(&dir_a);
    let _ = std::fs::remove_dir_all(&dir_b);
}

// ---------------------------------------------------------------------------
// Schedule 2: snapshot corruption (`persist.decode`) + restore IO
// (`persist.restore`)
// ---------------------------------------------------------------------------

#[test]
fn decode_fault_degrades_to_cold_start_and_clears() {
    let _g = locked();
    let dir = temp_dir("decode");

    // Seed a valid snapshot.
    let svc = warm_service(&dir);
    svc.handle(&req(8)).unwrap();
    svc.spill_warm().unwrap().expect("configured spill must run");

    // Corrupt decode: the snapshot is rejected wholesale — cold start,
    // never an error, never a partial restore.
    failpoint::arm("persist.decode", FailSpec::always(FailAction::Error));
    let cold = warm_service(&dir);
    assert!(
        cold.core().persist_stats().scopes_rejected >= 1,
        "corrupt snapshot must be counted as rejected"
    );
    assert_eq!(cold.cache_stats().entries, 0, "nothing restores from a corrupt snapshot");
    let r = cold.handle(&req(8)).unwrap();
    assert_eq!(r.source, ResponseSource::Search, "cold start must re-search");

    // Fault cleared: the same snapshot restores and serves from cache.
    failpoint::disarm_all();
    let warm = warm_service(&dir);
    let r = warm.handle(&req(8)).unwrap();
    assert_eq!(r.source, ResponseSource::Cache, "intact snapshot must restore");
    assert_eq!(warm.core().searches_run(), 0);

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn restore_fault_is_a_typed_error_from_load_warm() {
    let _g = locked();
    let dir = temp_dir("restore");
    let svc = warm_service(&dir);
    svc.handle(&req(8)).unwrap();
    svc.spill_warm().unwrap().expect("configured spill must run");

    failpoint::arm("persist.restore", FailSpec::always(FailAction::Error));
    let err = core().load_warm(&dir.join("warm.jsonl")).unwrap_err();
    assert_eq!(err.kind(), "fault", "{err}");
    failpoint::disarm_all();
    let st = core().load_warm(&dir.join("warm.jsonl")).unwrap();
    assert!(st.scopes_restored >= 1, "restore must work once the fault clears");

    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------------
// Schedule 3: scoring panic (`engine.score`)
// ---------------------------------------------------------------------------

#[test]
fn scoring_panic_is_isolated_and_recovery_is_byte_identical() {
    let _g = locked();
    let svc = service();

    failpoint::arm("engine.score", FailSpec::once(FailAction::Panic));
    let err = svc.handle(&req(8)).unwrap_err();
    assert_eq!(err.kind(), "panic", "{err}");
    assert!(err.to_string().contains("isolated"), "{err}");
    assert_eq!(svc.resilience_counters().2, 1, "the panic must be counted");
    assert_eq!(svc.cache_stats().insertions, 0, "a panicked search must not cache");

    // The failpoint is fire-capped: the identical request now succeeds,
    // and its report byte-matches an undisturbed service's.
    let recovered = svc.handle(&req(8)).unwrap();
    assert_eq!(recovered.source, ResponseSource::Search);
    let twin = service();
    let undisturbed = twin.handle(&req(8)).unwrap();
    assert_eq!(
        report_bytes(&svc, &recovered),
        report_bytes(&twin, &undisturbed),
        "post-panic report must match the undisturbed run byte-for-byte"
    );
    failpoint::disarm_all();
}

#[test]
fn serve_loop_survives_a_panic_on_every_search() {
    let _g = locked();
    let svc = service();
    failpoint::arm("engine.score", FailSpec::always(FailAction::Panic));
    let script = "\
{\"id\":\"a\",\"model\":\"llama2-7b\",\"gpu\":\"a800\",\"gpus\":8}\n\
{\"id\":\"b\",\"model\":\"llama2-7b\",\"mode\":\"heterogeneous\",\"gpus\":8,\"caps\":{\"a800\":8,\"h100\":8}}\n\
garbage line\n\
{\"id\":\"c\",\"model\":\"llama2-7b\",\"gpu\":\"h100\",\"gpus\":8}\n";
    let (stats, lines) = serve_script(&svc, script);
    assert_eq!(stats.lines, 4);
    assert_eq!(lines.len(), 4, "exactly one terminal response per request line");
    for (i, id) in [(0usize, "a"), (1, "b"), (3, "c")] {
        let v = parsed(&lines[i]);
        assert_eq!(v.opt_str("id"), Some(id));
        assert_eq!(v.opt_str("kind"), Some("panic"), "line {i}: {}", lines[i]);
    }
    assert_eq!(parsed(&lines[2]).opt_str("kind"), Some("json"), "{}", lines[2]);
    assert_eq!(svc.resilience_counters().2, 3, "three isolated panics");

    // Disarm → the same service serves the same requests normally: no
    // wedged single-flight slots, no poisoned shard locks.
    failpoint::disarm_all();
    let (stats, lines) = serve_script(&svc, script);
    assert_eq!((stats.ok, stats.errors), (3, 1));
    assert_eq!(parsed(&lines[0]).opt_str("source"), Some("search"));
}

// ---------------------------------------------------------------------------
// Schedule 4: wire garbage (`wire.parse`)
// ---------------------------------------------------------------------------

#[test]
fn wire_parse_faults_degrade_lines_without_killing_the_loop() {
    let _g = locked();
    let svc = service();
    failpoint::arm(
        "wire.parse",
        FailSpec { action: FailAction::Error, probability: 1.0, max_fires: 2 },
    );
    let script = "\
{\"id\":\"a\",\"model\":\"llama2-7b\",\"gpu\":\"a800\",\"gpus\":8}\n\
{\"id\":\"b\",\"model\":\"llama2-7b\",\"gpu\":\"a800\",\"gpus\":8}\n\
{\"id\":\"c\",\"model\":\"llama2-7b\",\"gpu\":\"a800\",\"gpus\":8}\n";
    let (stats, lines) = serve_script(&svc, script);
    assert_eq!(lines.len(), 3, "one response per line under parse faults");
    assert_eq!((stats.ok, stats.errors), (1, 2));
    for line in &lines[..2] {
        let v = parsed(line);
        assert_eq!(v.opt_str("kind"), Some("fault"), "{line}");
        assert_eq!(v.get("retryable").and_then(astra::json::Value::as_bool), Some(false));
        assert!(v.opt_str("id").is_none(), "a line that failed to parse has no id echo");
    }
    let ok = parsed(&lines[2]);
    assert_eq!(ok.opt_str("id"), Some("c"));
    assert_eq!(ok.opt_str("source"), Some("search"));
    failpoint::disarm_all();
}

// ---------------------------------------------------------------------------
// Schedule 5: deadline overrun (cooperative cancellation)
// ---------------------------------------------------------------------------

#[test]
fn pre_expired_deadline_cancels_before_the_search_starts() {
    let _g = locked();
    let c = core();
    let err = c
        .search_with_cancel(&req(8), &CancelToken::with_deadline_ms(0))
        .unwrap_err();
    assert!(matches!(err, AstraError::Deadline(_)), "{err}");
    assert_eq!(c.searches_run(), 0, "a cancelled-before-start search never counts");
    // The engine is not poisoned: the same core searches fine afterwards.
    let report = c.search_with_cancel(&req(8), &CancelToken::unlimited()).unwrap();
    assert!(report.best().is_some());
    assert_eq!(c.searches_run(), 1);
}

#[test]
fn mid_search_cancel_is_clean_never_partial() {
    let _g = locked();
    let c = core();
    let cancel = CancelToken::unlimited();
    let result = std::thread::scope(|s| {
        let h = s.spawn(|| c.search_with_cancel(&req(32), &cancel));
        // Let the search get going, then pull the plug; the executor
        // notices at the next wave boundary.
        std::thread::sleep(std::time::Duration::from_millis(3));
        cancel.cancel();
        h.join().unwrap()
    });
    match result {
        // Finished before the boundary check: must be a *complete* report.
        Ok(report) => assert!(report.best().is_some(), "an Ok result is never partial"),
        // Cancelled at a boundary: typed, no partial payload by construction.
        Err(e) => assert_eq!(e.kind(), "deadline", "{e}"),
    }
    // Either way the core still serves.
    assert!(c.search_with_cancel(&req(8), &CancelToken::unlimited()).is_ok());
}

// ---------------------------------------------------------------------------
// Schedule 6: queue overflow (load shedding + client retry)
// ---------------------------------------------------------------------------

#[test]
fn shed_request_is_typed_retryable_and_slot_frees() {
    let _g = locked();
    let svc = SearchService::new(
        core(),
        ServiceConfig { max_queue_depth: 1, ..Default::default() },
    );
    std::thread::scope(|s| {
        let leader = s.spawn(|| svc.handle(&req(32)));
        // Wait until the leader holds the single admission slot.
        while svc.active_requests() == 0 && !leader.is_finished() {
            std::thread::yield_now();
        }
        // Depth 1 is occupied → the distinct cold request is shed with
        // the one retryable kind. (If the leader finished in the tiny gap
        // since the poll, the probe legitimately admits instead — the
        // deterministic shed mechanics are pinned by the unit test in
        // `service::tests`.)
        match svc.handle(&req(16)) {
            Err(err) => {
                assert!(matches!(err, AstraError::Overloaded(_)), "{err}");
                assert!(err.retryable());
                assert!(svc.resilience_counters().0 >= 1, "shed must be counted");
            }
            Ok(r) => assert!(r.report.best().is_some()),
        }
        leader.join().unwrap().unwrap();
    });
    // The admission slot is released with the leader: no residue.
    assert_eq!(svc.active_requests(), 0);
    assert!(svc.handle(&req(16)).is_ok(), "shedding must not be sticky");
}

#[test]
fn batch_retry_converges_under_shedding() {
    let _g = locked();
    // Depth 1 with two distinct cold requests fanned out concurrently:
    // whichever loses admission is shed, then retried with backoff. The
    // *final* state is deterministic regardless of interleaving: every
    // line ends Ok.
    let svc = SearchService::new(
        core(),
        ServiceConfig { max_queue_depth: 1, batch_workers: 2, ..Default::default() },
    );
    let script = "\
{\"id\":\"a\",\"model\":\"llama2-7b\",\"gpu\":\"a800\",\"gpus\":8}\n\
{\"id\":\"b\",\"model\":\"llama2-7b\",\"gpu\":\"h100\",\"gpus\":8}\n";
    let mut out: Vec<u8> = Vec::new();
    let opts = ServeOpts {
        max_batch: 8,
        top: 1,
        retries: 5,
        retry_base_ms: 1,
        retry_seed: 42,
    };
    let stats = run_batch_lines(&svc, script, &mut out, &opts).unwrap();
    assert_eq!((stats.lines, stats.ok, stats.errors), (2, 2, 0), "retries must converge");
    let text = String::from_utf8(out).unwrap();
    assert_eq!(text.lines().count(), 2, "one terminal response per request");
    for line in text.lines() {
        let v = parsed(line);
        assert_eq!(v.get("ok").and_then(astra::json::Value::as_bool), Some(true), "{line}");
    }
}

// ---------------------------------------------------------------------------
// Cross-schedule: disarmed failpoints are byte-free
// ---------------------------------------------------------------------------

#[test]
fn disarmed_seams_leave_the_wire_transcript_untouched() {
    let _g = locked();
    // The seams are compiled in; disarmed they must cost nothing and
    // change nothing. Two fresh services, one script, identical bytes —
    // and a third run after an arm/disarm cycle stays identical too.
    let script = "\
{\"id\":\"a\",\"model\":\"llama2-7b\",\"gpu\":\"a800\",\"gpus\":8}\n\
{\"id\":\"a2\",\"model\":\"llama2-7b\",\"gpu\":\"a800\",\"gpus\":8}\n\
{\"id\":\"dl\",\"model\":\"llama2-7b\",\"gpu\":\"a800\",\"gpus\":8,\"deadline_ms\":0}\n\
{\"id\":\"bad\",\"model\":\"llama2-7b\",\"mode\":\"quantum\",\"gpus\":8}\n";
    let normalize = |lines: Vec<String>| -> Vec<String> {
        lines.iter().map(|l| normalize_response_line(l).unwrap()).collect()
    };
    let (_, first) = serve_script(&service(), script);
    let (_, second) = serve_script(&service(), script);
    assert_eq!(normalize(first.clone()), normalize(second), "transcript must be replay-stable");

    failpoint::arm("engine.score", FailSpec::once(FailAction::Panic));
    failpoint::disarm_all();
    let (_, third) = serve_script(&service(), script);
    assert_eq!(
        normalize(first),
        normalize(third),
        "an arm/disarm cycle must leave no residue in the transcript"
    );
    // The deadline-0 repeat request hits the cache (deadline-exempt); the
    // cold `dl` line in a fresh service... is actually the same
    // fingerprint as `a`, so it serves from cache — pinned here.
    let (_, lines) = serve_script(&service(), script);
    assert_eq!(parsed(&lines[2]).opt_str("source"), Some("cache"));
    assert_eq!(parsed(&lines[3]).opt_str("kind"), Some("config"));
}
