//! Differential harness for the flattened GBDT η-kernel.
//!
//! Two layers of evidence that the level-synchronous batch kernel
//! (`astra::gbdt::FlatForest`) can never change a pick:
//!
//! 1. **Kernel-level**: seeded randomized forests/inputs — including
//!    exact threshold ties (`x[f] == t`), signed zeros and NaN rows —
//!    where every batch prediction must be *bit*-identical to the scalar
//!    `Forest::predict` walk, on both the quantized fast path (with its
//!    exact-tie fallback) and the float-compare reference path.
//! 2. **Engine-level**: full searches with `batch_eta` on vs off must
//!    produce byte-identical canonical reports across every search mode
//!    and worker count, under the Analytic provider *and* under a real
//!    `Forests` provider injected via `$ASTRA_ARTIFACTS` (this test binary
//!    owns its process, so the env override is safe to pin once).

use astra::coordinator::{AstraEngine, EngineConfig, SearchReport, SearchRequest};
use astra::cost::EtaProvider;
use astra::gbdt::{EtaForests, FlatForest, FlatScratch, Forest, Tree};
use astra::gpu::GpuCatalog;
use astra::model::ModelRegistry;
use astra::prng::Rng;
use astra::report::report_json;
use astra::strategy::SpaceConfig;

// ---------------------------------------------------------------------------
// Kernel-level differential
// ---------------------------------------------------------------------------

fn random_forest(rng: &mut Rng, n_features: usize) -> Forest {
    let n_trees = 1 + rng.below(20) as usize;
    let trees: Vec<Tree> = (0..n_trees)
        .map(|_| {
            let depth = 1 + rng.below(6) as usize;
            let internal = (1usize << depth) - 1;
            Tree {
                depth,
                feat: (0..internal).map(|_| rng.below(n_features as u64) as u32).collect(),
                thresh: (0..internal).map(|_| rng.range_f64(-4.0, 4.0) as f32).collect(),
                leaf: (0..1usize << depth).map(|_| rng.range_f64(-2.0, 2.0) as f32).collect(),
            }
        })
        .collect();
    Forest {
        trees,
        base: rng.range_f64(-1.0, 1.0) as f32,
        lr: rng.range_f64(0.01, 0.3) as f32,
        n_features,
    }
}

/// Random input rows with adversarial structure: a share of features are
/// copied verbatim from the forest's own thresholds (exact ties for the
/// quantized path's fallback), signed zeros appear on both sides, and some
/// rows carry NaN.
fn random_rows(rng: &mut Rng, forest: &Forest, rows: usize, with_nan: bool) -> Vec<f32> {
    let nf = forest.n_features;
    let thresholds: Vec<f32> =
        forest.trees.iter().flat_map(|t| t.thresh.iter().copied()).collect();
    let mut xs = Vec::with_capacity(rows * nf);
    for r in 0..rows {
        for _ in 0..nf {
            let v = match rng.below(8) {
                // Exact tie with a random split of this forest.
                0 | 1 => *rng.choose(&thresholds),
                2 => 0.0,
                3 => -0.0,
                4 if with_nan && r % 7 == 3 => f32::NAN,
                _ => rng.range_f64(-4.0, 4.0) as f32,
            };
            xs.push(v);
        }
    }
    xs
}

#[test]
fn flat_batch_is_bit_identical_to_scalar_walk() {
    let mut scratch = FlatScratch::default();
    for seed in 0..40u64 {
        let mut rng = Rng::new(0xd1ff_f04e_5700 + seed);
        let nf = 1 + rng.below(8) as usize;
        let forest = random_forest(&mut rng, nf);
        let flat = FlatForest::from_forest(&forest);
        let rows = 1 + rng.below(96) as usize;
        let xs = random_rows(&mut rng, &forest, rows, true);

        let mut quantized = Vec::new();
        flat.predict_batch_with(&xs, nf, &mut scratch, &mut quantized);
        let mut float_ref = Vec::new();
        flat.predict_batch_float_into(&xs, &mut float_ref);

        for r in 0..rows {
            let row = &xs[r * nf..(r + 1) * nf];
            let want = forest.predict(row);
            assert_eq!(
                quantized[r].to_bits(),
                want.to_bits(),
                "seed {seed} row {r}: quantized path diverged (row {row:?})"
            );
            assert_eq!(
                float_ref[r].to_bits(),
                want.to_bits(),
                "seed {seed} row {r}: float-reference path diverged (row {row:?})"
            );
            assert_eq!(
                flat.predict_row_float(row).to_bits(),
                want.to_bits(),
                "seed {seed} row {r}: scalar flat walk diverged"
            );
        }
    }
}

#[test]
fn quantized_tie_fallback_routes_exactly_like_float_compare() {
    // Every feature equals a threshold somewhere: descent hits the
    // key-equality fallback at (nearly) every node, and `x == t` must go
    // right — exactly like `x >= t` in the scalar walk.
    let tree = Tree {
        depth: 2,
        feat: vec![0, 1, 1],
        thresh: vec![0.5, 0.25, 0.5],
        leaf: vec![10.0, 20.0, 30.0, 40.0],
    };
    let forest = Forest { trees: vec![tree], base: 0.0, lr: 1.0, n_features: 2 };
    let flat = FlatForest::from_forest(&forest);
    let cases: Vec<([f32; 2], f32)> = vec![
        ([0.5, 0.5], 40.0),   // tie at root (→R), tie at level 1 (→R)
        ([0.5, 0.25], 30.0),  // tie →R, then 0.25 < 0.5 →L
        ([0.25, 0.25], 20.0), // 0.25 < 0.5 →L, tie on 0.25 →R
        ([-0.0, 0.0], 10.0),  // -0.0 < 0.25: both zeros route identically
        ([0.0, -0.0], 10.0),
    ];
    let xs: Vec<f32> = cases.iter().flat_map(|(row, _)| row.iter().copied()).collect();
    let mut out = Vec::new();
    flat.predict_batch_into(&xs, &mut out);
    for (i, (row, want)) in cases.iter().enumerate() {
        assert_eq!(out[i], *want, "case {i} {row:?}");
        assert_eq!(out[i].to_bits(), forest.predict(row).to_bits(), "case {i} vs scalar");
    }
}

// ---------------------------------------------------------------------------
// Engine-level differential (batch_eta on vs off)
// ---------------------------------------------------------------------------

fn small_space() -> SpaceConfig {
    SpaceConfig {
        tp_candidates: vec![1, 2],
        max_pp: 4,
        mbs_candidates: vec![1, 2],
        vpp_candidates: vec![1],
        seq_parallel_options: vec![true],
        dist_opt_options: vec![true],
        offload_options: vec![false],
        recompute_none: true,
        recompute_selective: false,
        recompute_full: false,
        ..SpaceConfig::default()
    }
}

fn engine(use_forests: bool, batch_eta: bool, workers: usize) -> AstraEngine {
    AstraEngine::new(
        GpuCatalog::builtin(),
        EngineConfig {
            use_forests,
            batch_eta,
            workers,
            space: small_space(),
            ..Default::default()
        },
    )
}

fn canon(report: &SearchReport) -> String {
    astra::json::to_string(&report_json(report, &GpuCatalog::builtin()))
}

fn requests() -> Vec<(&'static str, SearchRequest)> {
    let model = ModelRegistry::builtin().get("llama2-7b").unwrap().clone();
    vec![
        ("homogeneous", SearchRequest::homogeneous("a800", 16, model.clone()).unwrap()),
        (
            "heterogeneous",
            SearchRequest::heterogeneous(&[("a800", 8), ("h100", 8)], 8, model.clone()).unwrap(),
        ),
        ("cost", SearchRequest::cost("a800", 16, 1e7, model.clone()).unwrap()),
        (
            "hetero-cost",
            SearchRequest::hetero_cost(&[("a800", 8), ("h100", 8)], f64::INFINITY, model.clone())
                .unwrap(),
        ),
        (
            "hetero-cost-budgeted",
            SearchRequest::hetero_cost(&[("a800", 8), ("h100", 8), ("v100", 8)], 5e4, model)
                .unwrap(),
        ),
    ]
}

/// The acceptance differential: with the Analytic provider, the batched
/// executor path must reproduce the scalar walk's bytes on every mode at
/// workers 1/2/4/8.
#[test]
fn batch_eta_reports_are_byte_identical_analytic() {
    for (name, req) in requests() {
        let scalar = engine(false, false, 1).search(&req).unwrap();
        let want = canon(&scalar);
        for workers in [1usize, 2, 4, 8] {
            let batched = engine(false, true, workers).search(&req).unwrap();
            assert_eq!(
                canon(&batched),
                want,
                "mode {name}, workers {workers}: batch_eta diverged from scalar walk"
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Engine-level differential with a real Forests provider
// ---------------------------------------------------------------------------

/// Serialize a forest into the `artifacts/forest.json` interchange format.
/// `{:?}` on f32 prints the shortest decimal that round-trips, so parsing
/// it back (f64 → f32 cast, as `Forest::from_json` does) is lossless.
fn forest_json(f: &Forest) -> String {
    let mut trees = Vec::new();
    for t in &f.trees {
        let feat: Vec<String> = t.feat.iter().map(|v| v.to_string()).collect();
        let thresh: Vec<String> = t.thresh.iter().map(|v| format!("{v:?}")).collect();
        let leaf: Vec<String> = t.leaf.iter().map(|v| format!("{v:?}")).collect();
        trees.push(format!(
            "{{\"depth\":{},\"feat\":[{}],\"thresh\":[{}],\"leaf\":[{}]}}",
            t.depth,
            feat.join(","),
            thresh.join(","),
            leaf.join(",")
        ));
    }
    format!(
        "{{\"n_features\":{},\"base\":{:?},\"lr\":{:?},\"trees\":[{}]}}",
        f.n_features,
        f.base,
        f.lr,
        trees.join(",")
    )
}

/// Pin `$ASTRA_ARTIFACTS` (once per process) to a temp dir holding a
/// synthetic `forest.json` whose predictions stay inside the η clamp band.
fn install_synthetic_forest() {
    static INSTALL: std::sync::Once = std::sync::Once::new();
    INSTALL.call_once(|| {
        let mut rng = Rng::new(0xa57a_f04e_57);
        let mut eta_forest = |n_features: usize| {
            let trees: Vec<Tree> = (0..16)
                .map(|_| {
                    let depth = 1 + rng.below(4) as usize;
                    let internal = (1usize << depth) - 1;
                    Tree {
                        depth,
                        feat: (0..internal)
                            .map(|_| rng.below(n_features as u64) as u32)
                            .collect(),
                        thresh: (0..internal).map(|_| rng.range_f64(-2.0, 12.0) as f32).collect(),
                        leaf: (0..1usize << depth)
                            .map(|_| rng.range_f64(0.01, 0.06) as f32)
                            .collect(),
                    }
                })
                .collect();
            Forest { trees, base: 0.1, lr: 1.0, n_features }
        };
        let comp = eta_forest(astra::hw::COMP_FEATURES);
        let comm = eta_forest(astra::hw::COMM_FEATURES);
        let dir = std::env::temp_dir().join(format!("astra_diff_forest_{}", std::process::id()));
        std::fs::create_dir_all(&dir).expect("create artifacts dir");
        std::fs::write(
            dir.join("forest.json"),
            format!("{{\"comp\":{},\"comm\":{}}}", forest_json(&comp), forest_json(&comm)),
        )
        .expect("write forest.json");
        std::env::set_var("ASTRA_ARTIFACTS", &dir);
    });
}

/// Same differential through the *forest* η provider: the flat kernel is
/// live on memo misses, and the reports must not move by a byte.
#[test]
fn batch_eta_reports_are_byte_identical_forests() {
    install_synthetic_forest();
    let scalar = engine(true, false, 1);
    assert!(
        matches!(scalar.core().cost_model().eta, EtaProvider::Forests(_)),
        "synthetic forest.json failed to load — test would be vacuous"
    );
    for (name, req) in requests() {
        let want = canon(&scalar.search(&req).unwrap());
        for workers in [1usize, 2, 4, 8] {
            let batched = engine(true, true, workers);
            assert!(matches!(batched.core().cost_model().eta, EtaProvider::Forests(_)));
            assert_eq!(
                canon(&batched.search(&req).unwrap()),
                want,
                "mode {name}, workers {workers}: forest batch_eta diverged from scalar walk"
            );
        }
    }
}

/// The loaded forest provider must also agree between the engine-level
/// scalar walk and a direct `EtaForests` round trip — guards the
/// `from_file` → flat-kernel plumbing end to end.
#[test]
fn installed_forest_round_trips_through_flat_kernel() {
    install_synthetic_forest();
    let path = astra::runtime::artifacts_dir().join("forest.json");
    let ef = EtaForests::from_file(&path).expect("forest.json parses");
    let mut rng = Rng::new(7);
    let nf = astra::hw::COMP_FEATURES;
    let xs: Vec<f32> = (0..64 * nf).map(|_| rng.range_f64(-2.0, 12.0) as f32).collect();
    let mut scratch = FlatScratch::default();
    let mut pred = Vec::new();
    let mut etas = Vec::new();
    ef.eta_comp_batch(&xs, nf, &mut scratch, &mut pred, &mut etas);
    for (r, row) in xs.chunks_exact(nf).enumerate() {
        assert_eq!(etas[r].to_bits(), ef.eta_comp(row).to_bits(), "row {r}");
    }
}
