//! Property tests of the rule DSL: parser totality, evaluator soundness,
//! and the paper's Eq. 10 semantics ("any rule matches ⇒ dropped").

use astra::gpu::GpuCatalog;
use astra::model::ModelRegistry;
use astra::prng::Rng;
use astra::rules::{Rule, RuleSet};
use astra::strategy::{SearchSpace, SpaceConfig};

/// Random well-formed expressions parse and evaluate without panicking.
#[test]
fn prop_random_expressions_total() {
    let mut rng = Rng::new(42);
    let fields = [
        "tensor_model_parallel_size",
        "pipeline_model_parallel_size",
        "num_gpus",
        "micro_batch_size",
        "recompute_num_layers",
    ];
    let ops = ["==", "!=", ">", ">=", "<", "<=", "+", "-", "*", "%"];
    let reg = ModelRegistry::builtin();
    let cat = GpuCatalog::builtin();
    let model = reg.get("llama2-7b").unwrap();
    let space = SearchSpace::new(SpaceConfig::default());
    let strategies = space.homogeneous(model, &cat, 0, 64);

    for case in 0..300 {
        // Build a random comparison chain: atom op atom [&&/|| ...]
        let mut src = String::new();
        let clauses = 1 + rng.below(3);
        for ci in 0..clauses {
            if ci > 0 {
                src.push_str(if rng.bool() { " && " } else { " || " });
            }
            let lhs = format!("${}", rng.choose(&fields));
            let rhs: String = if rng.bool() {
                format!("{}", 1 + rng.below(64))
            } else {
                format!("${}", rng.choose(&fields))
            };
            let op = rng.choose(&ops);
            // Arithmetic ops need a comparison to be a valid rule clause.
            if ["+", "-", "*", "%"].contains(op) {
                src.push_str(&format!("{lhs} {op} {rhs} != 0"));
            } else {
                src.push_str(&format!("{lhs} {op} {rhs}"));
            }
        }
        let rule = Rule::compile(&src).unwrap_or_else(|e| panic!("case {case} '{src}': {e}"));
        let s = &strategies[rng.below(strategies.len() as u64) as usize];
        // Must evaluate to a clean bool (no panic; Err only for div-by-zero
        // which our construction can hit via `% $field` when field is 0 —
        // never the case for these fields).
        rule.matches(s).unwrap_or_else(|e| panic!("case {case} '{src}': {e}"));
    }
}

/// Eq. 10: a strategy passes iff NO rule matches; adding a tautology rule
/// must filter everything, adding a contradiction must change nothing.
#[test]
fn prop_ruleset_semantics() {
    let reg = ModelRegistry::builtin();
    let cat = GpuCatalog::builtin();
    let model = reg.get("llama2-13b").unwrap();
    let space = SearchSpace::new(SpaceConfig::default());
    let strategies = space.homogeneous(model, &cat, 0, 128);

    let base = RuleSet::paper_defaults();
    let kept: Vec<bool> =
        strategies.iter().map(|s| !base.filters_out(s).unwrap()).collect();
    assert!(kept.iter().any(|&k| k), "paper rules filtered everything");
    assert!(kept.iter().any(|&k| !k), "paper rules filtered nothing");

    let mut with_taut = base.clone();
    with_taut.add("1 == 1").unwrap();
    assert!(strategies.iter().all(|s| with_taut.filters_out(s).unwrap()));

    let mut with_contra = base.clone();
    with_contra.add("1 == 2").unwrap();
    for (s, &k) in strategies.iter().zip(&kept) {
        assert_eq!(!with_contra.filters_out(s).unwrap(), k);
    }
}

/// The three paper rules do exactly what §3.3 says, checked against the
/// generator's population (not hand-built fixtures).
#[test]
fn prop_paper_rules_semantics_on_population() {
    let reg = ModelRegistry::builtin();
    let cat = GpuCatalog::builtin();
    let model = reg.get("llama2-7b").unwrap();
    let space = SearchSpace::new(SpaceConfig::default());
    let strategies = space.homogeneous(model, &cat, 0, 64);
    let rules = RuleSet::paper_defaults();

    for s in &strategies {
        let dropped = rules.filters_out(s).unwrap();
        let flash_selective = s.use_flash_attn
            && s.recompute == astra::strategy::Recompute::Selective;
        let rc_too_deep = s.recompute_num_layers > s.pp();
        let bad_division = s.num_gpus() % (s.pp() * s.tp) != 0;
        let sp_no_tp = s.sequence_parallel && s.tp == 1;
        let vpp_no_pp = s.vpp > 1 && s.pp() == 1;
        let expect = flash_selective || rc_too_deep || bad_division || sp_no_tp || vpp_no_pp;
        assert_eq!(dropped, expect, "rule semantics diverged on {}", s.summary());
    }
}

/// Operator precedence: `a || b && c` groups as `a || (b && c)` and
/// arithmetic binds tighter than comparison.
#[test]
fn prop_precedence_reference_cases() {
    use astra::rules::{FieldSource, Val};
    struct S;
    impl FieldSource for S {
        fn field(&self, name: &str) -> Option<Val> {
            Some(match name {
                "a" => Val::Int(0),
                "b" => Val::Int(1),
                "c" => Val::Int(1),
                "x" => Val::Int(10),
                _ => return None,
            })
        }
    }
    let cases = [
        ("$a || $b && $c", true),        // 0 || (1 && 1)
        ("$a && $b || $c", true),        // (0 && 1) || 1
        ("$x + 2 * 3 == 16", true),      // 10 + 6
        ("($x + 2) * 3 == 36", true),
        ("$x % 4 + 1 == 3", true),       // (10 % 4) + 1
        ("!($b == $c)", false),
    ];
    for (src, want) in cases {
        let r = Rule::compile(src).unwrap();
        assert_eq!(r.matches(&S).unwrap(), want, "{src}");
    }
}
