//! Mode-3 money-limit search end-to-end (paper §3.6 / §5.3 / Fig. 7 shapes).

use astra::coordinator::{AstraEngine, EngineConfig, SearchRequest};
use astra::gpu::GpuCatalog;
use astra::model::ModelRegistry;
use astra::pareto::MoneyModel;
use astra::strategy::GpuPoolMode;

fn engine() -> AstraEngine {
    AstraEngine::new(GpuCatalog::builtin(), EngineConfig { use_forests: false, ..Default::default() })
}

fn cost_request(model: &str, gpu: &str, max_count: usize, max_money: f64) -> SearchRequest {
    let reg = ModelRegistry::builtin();
    let cat = GpuCatalog::builtin();
    SearchRequest {
        mode: GpuPoolMode::Cost { gpu: cat.find(gpu).unwrap(), max_count, max_money },
        model: reg.get(model).unwrap().clone(),
    }
}

#[test]
fn pareto_pool_valid_and_monotone() {
    let rep = engine().search(&cost_request("llama2-7b", "h100", 64, f64::INFINITY)).unwrap();
    assert!(rep.pool.len() >= 3, "frontier too small: {}", rep.pool.len());
    assert!(rep.pool.is_valid_frontier());
    // Fig. 7's shape: along the frontier, paying more buys throughput.
    let e = rep.pool.entries();
    for w in e.windows(2) {
        assert!(w[0].throughput > w[1].throughput);
        assert!(w[0].cost > w[1].cost);
    }
}

#[test]
fn tighter_budget_means_slower_or_equal_plan() {
    let eng = engine();
    let rep = eng.search(&cost_request("llama2-13b", "a800", 64, f64::INFINITY)).unwrap();
    let frontier = rep.pool.entries();
    let rich = frontier.first().unwrap();
    let mid_budget = (rich.cost + frontier.last().unwrap().cost) / 2.0;
    let mid = rep.pool.best_within_budget(mid_budget).unwrap();
    assert!(mid.throughput <= rich.throughput);
    assert!(mid.cost <= mid_budget);
}

#[test]
fn money_scales_with_gpu_price() {
    // Same strategy priced on H100 must cost more per hour than on A800
    // when it runs proportionally faster than the price ratio or not —
    // here we check the raw Eq. 32 accounting.
    let cat = GpuCatalog::builtin();
    let reg = ModelRegistry::builtin();
    let m = reg.get("llama2-7b").unwrap();
    let mm = MoneyModel::default();
    let eng = engine();
    let rep = eng.search(&SearchRequest::homogeneous("a800", 64, m.clone()).unwrap()).unwrap();
    let s = rep.best().unwrap();
    let usd = mm.cost_usd(m, &s.strategy, &cat, s.cost.step_time);
    // Recompute by hand: steps × step_time × 64 × fee.
    let a800 = cat.spec(cat.find("a800").unwrap());
    let expect = mm.steps(m) * s.cost.step_time * 64.0 * a800.price_per_second();
    assert!((usd - expect).abs() / expect < 1e-9);
}

#[test]
fn cheaper_gpu_can_win_under_tight_budget() {
    // The economic crossover the paper's mode 3 exists for: under a tight
    // budget the optimal pool should offer small/cheap configurations.
    let eng = engine();
    let rep = eng.search(&cost_request("llama2-7b", "h100", 128, f64::INFINITY)).unwrap();
    let cheapest = rep.pool.entries().last().unwrap();
    let fastest = rep.pool.entries().first().unwrap();
    // Money is roughly N·step_time, so with near-linear scaling the *cost*
    // spread is modest — but the throughput spread must be wide (that's the
    // trade the Pareto pool exposes), and cheaper is strictly cheaper.
    assert!(cheapest.cost < fastest.cost);
    assert!(
        fastest.throughput > 2.0 * cheapest.throughput,
        "frontier throughput spread too small: {:.0} vs {:.0}",
        fastest.throughput,
        cheapest.throughput
    );
}

#[test]
fn impossible_budget_yields_no_selection() {
    let eng = engine();
    let rep = eng.search(&cost_request("llama2-7b", "h100", 32, f64::INFINITY)).unwrap();
    assert!(rep.pool.best_within_budget(0.0).is_none());
}
