//! End-to-end mode-1 searches across the paper's model grid, validating
//! the whole coordinator pipeline and the Astra-vs-expert claim (Fig. 5's
//! shape) on the discrete-event simulator.

use astra::coordinator::{AstraEngine, EngineConfig, SearchRequest};
use astra::expert::ExpertPanel;
use astra::gpu::GpuCatalog;
use astra::model::ModelRegistry;
use astra::simulator::{PipelineSimulator, SimConfig};
use astra::strategy::SpaceConfig;

fn engine() -> AstraEngine {
    AstraEngine::new(GpuCatalog::builtin(), EngineConfig { use_forests: false, ..Default::default() })
}

#[test]
fn search_succeeds_for_all_paper_models_at_64() {
    let reg = ModelRegistry::builtin();
    let eng = engine();
    for model in reg.paper_seven() {
        let req = SearchRequest::homogeneous("a800", 64, model.clone()).unwrap();
        let rep = eng.search(&req).unwrap_or_else(|e| panic!("{}: {e}", model.name));
        assert!(rep.scored > 0, "{}: nothing survived filtering", model.name);
        let best = rep.best().unwrap();
        best.strategy.validate(model).unwrap();
        assert!(
            best.cost.mfu > 0.05 && best.cost.mfu < 0.65,
            "{}: implausible best MFU {:.3}",
            model.name,
            best.cost.mfu
        );
    }
}

#[test]
fn astra_beats_or_matches_expert_panel() {
    // Fig. 5's claim, evaluated on the simulator as the "real cluster":
    // Astra's best must be ≥ the best of the six expert proposals (small
    // tolerance for cost-model-vs-simulator mismatch).
    let reg = ModelRegistry::builtin();
    let cat = GpuCatalog::builtin();
    let eng = engine();
    let sim = PipelineSimulator::new(cat.clone(), SimConfig::default());
    let panel = ExpertPanel::default();
    let a800 = cat.find("a800").unwrap();

    for (model_name, count) in [("llama2-7b", 32usize), ("llama2-13b", 128), ("llama3-8b", 64)] {
        let model = reg.get(model_name).unwrap();
        let rep = eng
            .search(&SearchRequest::homogeneous("a800", count, model.clone()).unwrap())
            .unwrap();
        let astra_tput = sim.measure(model, &rep.best().unwrap().strategy).tokens_per_s;
        let expert_tput = panel
            .proposals(model, &cat, a800, count)
            .iter()
            .map(|(_, s)| sim.measure(model, s).tokens_per_s)
            .fold(0.0f64, f64::max);
        assert!(expert_tput > 0.0, "{model_name}: no expert baseline");
        assert!(
            astra_tput >= 0.97 * expert_tput,
            "{model_name}@{count}: astra {astra_tput:.0} < expert {expert_tput:.0}"
        );
    }
}

#[test]
fn dp_only_space_is_strictly_worse_at_scale() {
    // Fig. 8's shape: with 256 GPUs the hybrid space must beat DP-only.
    let reg = ModelRegistry::builtin();
    let model = reg.get("llama2-13b").unwrap().clone();
    let full = engine();
    let dp_only = AstraEngine::new(
        GpuCatalog::builtin(),
        EngineConfig { use_forests: false, space: SpaceConfig::dp_only(), ..Default::default() },
    );
    let req = SearchRequest::homogeneous("a800", 256, model).unwrap();
    let full_rep = full.search(&req).unwrap();
    let dp_rep = dp_only.search(&req).unwrap();
    let full_best = full_rep.best().unwrap().cost.tokens_per_s;
    match dp_rep.best() {
        Some(dp_best) => assert!(
            full_best > dp_best.cost.tokens_per_s,
            "hybrid {full_best:.0} ≤ dp-only {:.0}",
            dp_best.cost.tokens_per_s
        ),
        None => { /* DP-only can't even fit — an even stronger version of the claim */ }
    }
}

#[test]
fn search_time_headline_claim() {
    // §1: "search time ≤ 1.27 s in a single-GPU setting" — generation +
    // filtering must stay within the same order on this testbed.
    let reg = ModelRegistry::builtin();
    let model = reg.get("llama2-7b").unwrap().clone();
    let eng = engine();
    let rep = eng.search(&SearchRequest::homogeneous("a800", 256, model).unwrap()).unwrap();
    assert!(
        rep.search_secs < 5.0,
        "search phase took {:.2}s (paper: ~1.27s)",
        rep.search_secs
    );
}

#[test]
fn deterministic_given_same_request() {
    let reg = ModelRegistry::builtin();
    let model = reg.get("llama2-7b").unwrap().clone();
    let eng = engine();
    let req = SearchRequest::homogeneous("a800", 64, model).unwrap();
    let a = eng.search(&req).unwrap();
    let b = eng.search(&req).unwrap();
    assert_eq!(a.scored, b.scored);
    assert_eq!(a.best().unwrap().strategy, b.best().unwrap().strategy);
}
