//! End-to-end tests of the `astra::service` layer: fingerprint stability,
//! cache reuse, single-flight coalescing, the serve loop, and the batched
//! admission queue.

use astra::coordinator::{EngineConfig, ScoringCore, SearchRequest};
use astra::gpu::GpuCatalog;
use astra::json;
use astra::model::ModelRegistry;
use astra::service::server::{run_batch_lines, run_serve_loop, ServeOpts};
use astra::service::{
    fingerprint, CacheConfig, Fingerprint, ResponseSource, SearchService, ServiceConfig,
};
use astra::strategy::SpaceConfig;
use std::io::Cursor;
use std::time::Instant;

/// A narrowed space so each cold search takes milliseconds, not seconds.
fn small_config() -> EngineConfig {
    let space = SpaceConfig {
        tp_candidates: vec![1, 2],
        max_pp: 4,
        mbs_candidates: vec![1, 2],
        vpp_candidates: vec![1],
        seq_parallel_options: vec![true],
        dist_opt_options: vec![true],
        offload_options: vec![false],
        recompute_none: true,
        recompute_selective: false,
        recompute_full: false,
        ..SpaceConfig::default()
    };
    EngineConfig { use_forests: false, space, ..Default::default() }
}

fn small_service() -> SearchService {
    SearchService::new(
        ScoringCore::new(GpuCatalog::builtin(), small_config()),
        ServiceConfig::default(),
    )
}

fn req(model: &str, count: usize) -> SearchRequest {
    let m = ModelRegistry::builtin().get(model).unwrap().clone();
    SearchRequest::homogeneous("a800", count, m).unwrap()
}

#[test]
fn fingerprints_stable_and_distinct() {
    let cat = GpuCatalog::builtin();
    let cfg = EngineConfig::default();
    // Stability across construction paths.
    assert_eq!(
        fingerprint(&req("llama2-7b", 64), &cat, &cfg),
        fingerprint(&req("llama2-7b", 64), &cat, &cfg)
    );
    // Capacity-order insensitivity.
    let m = ModelRegistry::builtin().get("llama2-7b").unwrap().clone();
    let a = SearchRequest::heterogeneous(&[("a800", 48), ("h100", 48)], 64, m.clone()).unwrap();
    let b = SearchRequest::heterogeneous(&[("h100", 48), ("a800", 48)], 64, m).unwrap();
    assert_eq!(fingerprint(&a, &cat, &cfg), fingerprint(&b, &cat, &cfg));
    // Distinct requests key apart.
    let mut fps: Vec<Fingerprint> = vec![
        fingerprint(&req("llama2-7b", 64), &cat, &cfg),
        fingerprint(&req("llama2-7b", 128), &cat, &cfg),
        fingerprint(&req("llama2-13b", 64), &cat, &cfg),
        fingerprint(&a, &cat, &cfg),
    ];
    fps.sort();
    fps.dedup();
    assert_eq!(fps.len(), 4, "fingerprint collision among distinct requests");
}

#[test]
fn repeat_request_skips_engine_and_is_100x_faster() {
    // The acceptance anchor: an identical repeat must not re-enter
    // `search` and must be at least 100× faster than the cold run. Uses the
    // full default space so the cold search is a realistic multi-ms run.
    let svc = SearchService::new(
        ScoringCore::new(
            GpuCatalog::builtin(),
            EngineConfig { use_forests: false, ..Default::default() },
        ),
        ServiceConfig::default(),
    );
    let r = req("llama2-7b", 64);

    let t0 = Instant::now();
    let cold = svc.handle(&r).unwrap();
    let cold_secs = t0.elapsed().as_secs_f64();
    assert_eq!(cold.source, ResponseSource::Search);
    assert_eq!(svc.core().searches_run(), 1);

    let t1 = Instant::now();
    let warm = svc.handle(&r).unwrap();
    let warm_secs = t1.elapsed().as_secs_f64();
    assert_eq!(warm.source, ResponseSource::Cache);
    assert_eq!(svc.core().searches_run(), 1, "cache hit re-entered the engine");
    assert_eq!(cold.fingerprint, warm.fingerprint);
    assert!(
        warm_secs * 100.0 < cold_secs,
        "cache hit not ≥100× faster: cold {cold_secs:.6}s vs warm {warm_secs:.6}s"
    );
}

#[test]
fn serve_loop_three_requests_two_identical() {
    // The end-to-end loop of the issue: 3 requests (2 identical) through
    // the wire protocol → exactly 2 engine searches, 1 cache hit.
    let svc = small_service();
    let input = "\
{\"id\":\"a\",\"model\":\"llama2-7b\",\"gpu\":\"a800\",\"gpus\":64}\n\
{\"id\":\"b\",\"model\":\"llama2-7b\",\"gpu\":\"a800\",\"gpus\":64}\n\
{\"id\":\"c\",\"model\":\"llama2-7b\",\"gpu\":\"a800\",\"gpus\":32}\n";
    let mut out: Vec<u8> = Vec::new();
    // max_batch = 1 ⇒ strictly sequential admission ⇒ the repeat is a
    // deterministic cache hit (not an in-batch coalesce).
    let opts = ServeOpts { max_batch: 1, top: 1, ..Default::default() };
    let stats =
        run_serve_loop(&svc, Cursor::new(input.as_bytes().to_vec()), &mut out, &opts).unwrap();
    assert_eq!((stats.lines, stats.ok, stats.errors), (3, 3, 0));
    assert_eq!(svc.core().searches_run(), 2, "two distinct requests → two searches");
    assert_eq!(svc.cache_stats().hits, 1, "the repeat must hit the cache");

    let lines: Vec<json::Value> = String::from_utf8(out)
        .unwrap()
        .lines()
        .map(|l| json::parse(l).unwrap())
        .collect();
    assert_eq!(lines.len(), 3);
    for (v, id) in lines.iter().zip(["a", "b", "c"]) {
        assert_eq!(v.get("ok").and_then(json::Value::as_bool), Some(true));
        assert_eq!(v.opt_str("id"), Some(id), "responses must keep input order");
    }
    assert_eq!(lines[0].opt_str("source"), Some("search"));
    assert_eq!(lines[1].opt_str("source"), Some("cache"));
    assert_eq!(lines[2].opt_str("source"), Some("search"));
    assert_eq!(lines[0].opt_str("fingerprint"), lines[1].opt_str("fingerprint"));
    assert_ne!(lines[0].opt_str("fingerprint"), lines[2].opt_str("fingerprint"));
    // Identical requests ⇒ identical result payloads.
    assert_eq!(lines[0].get("best"), lines[1].get("best"));
}

#[test]
fn serve_loop_reports_errors_inline() {
    let svc = small_service();
    let input = "\
not json at all\n\
{\"id\":\"x\",\"model\":\"gpt-5\",\"gpu\":\"a800\",\"gpus\":64}\n\
{\"id\":\"y\",\"model\":\"llama2-7b\",\"gpu\":\"a800\",\"gpus\":16}\n\
{\"cmd\":\"stats\"}\n";
    let mut out: Vec<u8> = Vec::new();
    let opts = ServeOpts { max_batch: 1, top: 1, ..Default::default() };
    let stats =
        run_serve_loop(&svc, Cursor::new(input.as_bytes().to_vec()), &mut out, &opts).unwrap();
    assert_eq!(stats.lines, 4);
    assert_eq!(stats.errors, 2, "bad JSON + unknown model");
    let lines: Vec<json::Value> = String::from_utf8(out)
        .unwrap()
        .lines()
        .map(|l| json::parse(l).unwrap())
        .collect();
    assert_eq!(lines[0].get("ok").and_then(json::Value::as_bool), Some(false));
    assert_eq!(lines[1].get("ok").and_then(json::Value::as_bool), Some(false));
    assert_eq!(lines[1].opt_str("id"), Some("x"), "errors echo the request id");
    assert_eq!(lines[2].get("ok").and_then(json::Value::as_bool), Some(true));
    // The control line exposes service counters.
    let stats_obj = lines[3].get("stats").expect("stats payload");
    assert_eq!(stats_obj.opt_usize("searches_run"), Some(1));
}

#[test]
fn batch_of_eight_distinct_requests_is_deterministic() {
    // Acceptance: ≥8 distinct requests complete concurrently through the
    // admission queue with deterministic, fingerprint-keyed output.
    let mk_lines = || -> String {
        let mut s = String::new();
        for (model, gpus) in [
            ("llama2-7b", 8usize),
            ("llama2-7b", 16),
            ("llama2-7b", 32),
            ("llama2-7b", 64),
            ("llama2-13b", 16),
            ("llama2-13b", 32),
            ("llama3-8b", 16),
            ("llama3-8b", 32),
        ] {
            s.push_str(&format!(
                "{{\"model\":\"{model}\",\"gpu\":\"a800\",\"gpus\":{gpus}}}\n"
            ));
        }
        s
    };

    let run = || -> Vec<(String, String, String)> {
        let svc = small_service();
        let mut out: Vec<u8> = Vec::new();
        let opts = ServeOpts { max_batch: 32, top: 1, ..Default::default() };
        let stats = run_batch_lines(&svc, &mk_lines(), &mut out, &opts).unwrap();
        assert_eq!((stats.lines, stats.ok, stats.errors), (8, 8, 0));
        assert_eq!(svc.core().searches_run(), 8, "all eight are distinct");
        String::from_utf8(out)
            .unwrap()
            .lines()
            .map(|l| {
                let v = json::parse(l).unwrap();
                assert_eq!(v.get("ok").and_then(json::Value::as_bool), Some(true));
                (
                    v.opt_str("fingerprint").unwrap().to_string(),
                    v.get("best").map(json::to_string).unwrap_or_default(),
                    v.opt_str("source").unwrap().to_string(),
                )
            })
            .collect()
    };

    let a = run();
    let b = run();
    assert_eq!(a.len(), 8);
    let mut fps: Vec<&String> = a.iter().map(|(fp, _, _)| fp).collect();
    fps.sort();
    fps.dedup();
    assert_eq!(fps.len(), 8, "eight distinct fingerprints");
    for (i, ((fa, ba, _), (fb, bb, _))) in a.iter().zip(&b).enumerate() {
        assert_eq!(fa, fb, "request {i}: fingerprint not deterministic");
        assert_eq!(ba, bb, "request {i}: best strategy not deterministic");
    }
}

#[test]
fn batch_mixes_modes_and_coalesces_duplicates() {
    let svc = small_service();
    let lines = "\
{\"model\":\"llama2-7b\",\"gpu\":\"a800\",\"gpus\":16}\n\
{\"model\":\"llama2-7b\",\"mode\":\"heterogeneous\",\"gpus\":16,\"caps\":{\"a800\":8,\"h100\":8}}\n\
{\"model\":\"llama2-7b\",\"gpu\":\"a800\",\"gpus\":16}\n";
    let mut out: Vec<u8> = Vec::new();
    let opts = ServeOpts { max_batch: 8, top: 1, ..Default::default() };
    let stats = run_batch_lines(&svc, lines, &mut out, &opts).unwrap();
    assert_eq!((stats.ok, stats.errors), (3, 0));
    assert_eq!(svc.core().searches_run(), 2, "duplicate inside the batch must coalesce");
    let lines: Vec<json::Value> = String::from_utf8(out)
        .unwrap()
        .lines()
        .map(|l| json::parse(l).unwrap())
        .collect();
    assert_eq!(lines[0].opt_str("fingerprint"), lines[2].opt_str("fingerprint"));
    assert_eq!(lines[2].opt_str("source"), Some("coalesced"));
}

#[test]
fn ttl_zero_cache_still_single_flights() {
    // A TTL so short every entry is stale on re-lookup: repeats re-search,
    // proving TTL actually expires (control experiment for the cache test).
    let cfg = ServiceConfig {
        cache: CacheConfig { ttl: Some(std::time::Duration::ZERO), ..Default::default() },
        ..Default::default()
    };
    let svc = SearchService::new(ScoringCore::new(GpuCatalog::builtin(), small_config()), cfg);
    let r = req("llama2-7b", 16);
    svc.handle(&r).unwrap();
    let second = svc.handle(&r).unwrap();
    assert_eq!(second.source, ResponseSource::Search, "expired entry must re-search");
    assert_eq!(svc.core().searches_run(), 2);
    assert_eq!(svc.cache_stats().expirations, 1);
}
