//! Mode-2 heterogeneous search end-to-end (paper §3.4 / §5.2 shapes).

use astra::coordinator::{AstraEngine, EngineConfig, SearchRequest};
use astra::expert::ExpertPanel;
use astra::gpu::GpuCatalog;
use astra::model::ModelRegistry;
use astra::simulator::{PipelineSimulator, SimConfig};
use astra::strategy::GpuPoolMode;

fn engine(exhaustive: bool) -> AstraEngine {
    AstraEngine::new(
        GpuCatalog::builtin(),
        EngineConfig { use_forests: false, hetero_exhaustive: exhaustive, ..Default::default() },
    )
}

fn caps(cat: &GpuCatalog, a: usize, h: usize) -> Vec<(usize, usize)> {
    vec![(cat.find("a800").unwrap(), a), (cat.find("h100").unwrap(), h)]
}

#[test]
fn hetero_search_valid_and_uses_both_types() {
    let reg = ModelRegistry::builtin();
    let cat = GpuCatalog::builtin();
    let model = reg.get("llama2-13b").unwrap().clone();
    let rep = engine(false)
        .search(&SearchRequest {
            mode: GpuPoolMode::Heterogeneous { total: 64, caps: caps(&cat, 48, 48) },
            model: model.clone(),
        })
        .unwrap();
    assert!(rep.scored > 0);
    for s in &rep.top {
        s.strategy.validate(&model).unwrap();
        assert_eq!(s.strategy.num_gpus(), 64);
        // Per-type usage must respect the caps.
        for (g, n) in s.strategy.cluster.gpus_by_type(s.strategy.tp, s.strategy.dp) {
            let cap = caps(&cat, 48, 48).iter().find(|&&(t, _)| t == g).unwrap().1;
            assert!(n <= cap, "type {g} uses {n} > cap {cap}");
        }
    }
    assert!(rep.top.iter().any(|s| s.strategy.cluster.is_heterogeneous()));
}

#[test]
fn pruned_close_to_exhaustive() {
    // The pruned solver must find ≥99% of the exhaustive optimum's
    // throughput (our ablation claim; also guards the solver's seeding).
    let reg = ModelRegistry::builtin();
    let cat = GpuCatalog::builtin();
    let model = reg.get("llama2-7b").unwrap().clone();
    let req = SearchRequest {
        mode: GpuPoolMode::Heterogeneous { total: 32, caps: caps(&cat, 24, 24) },
        model,
    };
    let fast = engine(false).search(&req).unwrap();
    let full = engine(true).search(&req).unwrap();
    let t_fast = fast.best().unwrap().cost.tokens_per_s;
    let t_full = full.best().unwrap().cost.tokens_per_s;
    assert!(fast.generated <= full.generated);
    assert!(
        t_fast >= 0.99 * t_full,
        "pruned {t_fast:.0} vs exhaustive {t_full:.0} ({} vs {} candidates)",
        fast.generated,
        full.generated
    );
}

#[test]
fn astra_beats_experts_in_hetero() {
    // Fig. 6's shape: heterogeneous is where manual layer-splitting breaks
    // down, so Astra must clearly beat the panel on the simulator.
    let reg = ModelRegistry::builtin();
    let cat = GpuCatalog::builtin();
    let model = reg.get("llama2-13b").unwrap();
    let sim = PipelineSimulator::new(cat.clone(), SimConfig::default());
    let total = 64;
    let c = caps(&cat, 48, 48);

    let rep = engine(false)
        .search(&SearchRequest {
            mode: GpuPoolMode::Heterogeneous { total, caps: c.clone() },
            model: model.clone(),
        })
        .unwrap();
    let astra_tput = sim.measure(model, &rep.best().unwrap().strategy).tokens_per_s;

    let panel = ExpertPanel::default();
    let expert_tput = panel
        .proposals_hetero(model, &cat, &c, total)
        .iter()
        .map(|(_, s)| sim.measure(model, s).tokens_per_s)
        .fold(0.0f64, f64::max);
    assert!(expert_tput > 0.0, "no expert hetero baseline");
    assert!(
        astra_tput >= expert_tput,
        "astra {astra_tput:.0} < expert {expert_tput:.0} in hetero mode"
    );
}

#[test]
fn hetero_between_pure_slow_and_pure_fast() {
    // Table 2's shape: mixed A800+H100 throughput sits between pure-A800
    // and pure-H100 at the same total GPU count.
    let reg = ModelRegistry::builtin();
    let cat = GpuCatalog::builtin();
    let model = reg.get("llama2-7b").unwrap().clone();
    let eng = engine(false);
    let total = 64;

    let pure = |gpu: &str| {
        eng.search(&SearchRequest::homogeneous(gpu, total, model.clone()).expect("request"))
            .unwrap()
            .best()
            .unwrap()
            .cost
            .tokens_per_s
    };
    let t_a800 = pure("a800");
    let t_h100 = pure("h100");
    let mixed = eng
        .search(&SearchRequest {
            mode: GpuPoolMode::Heterogeneous { total, caps: caps(&cat, total / 2, total / 2) },
            model: model.clone(),
        })
        .unwrap()
        .best()
        .unwrap()
        .cost
        .tokens_per_s;
    assert!(t_h100 > t_a800);
    assert!(
        mixed > t_a800 * 0.95 && mixed < t_h100 * 1.02,
        "mixed {mixed:.0} outside [a800 {t_a800:.0}, h100 {t_h100:.0}]"
    );
}

#[test]
fn rejects_infeasible_caps() {
    let reg = ModelRegistry::builtin();
    let cat = GpuCatalog::builtin();
    let model = reg.get("llama2-7b").unwrap().clone();
    let err = engine(false).search(&SearchRequest {
        mode: GpuPoolMode::Heterogeneous { total: 128, caps: caps(&cat, 32, 32) },
        model,
    });
    assert!(err.is_err(), "caps sum 64 < total 128 must be rejected");
}
