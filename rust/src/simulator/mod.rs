//! Discrete-event 1F1B pipeline training simulator — the *ground truth*.
//!
//! The paper validates its cost model against real Megatron-LM runs on real
//! clusters. We have neither, so this simulator plays the cluster's role
//! (DESIGN.md §3): it executes the exact 1F1B dependency graph —
//! per-microbatch forward/backward ops per stage, p2p hand-offs, warmup /
//! steady / cooldown phases — over the *hardware-truth* op times
//! ([`crate::hw`]) perturbed by seeded measurement noise, then appends the
//! data-parallel, optimizer and offload phases with the same overlap
//! semantics as the cost model.
//!
//! The closed-form cost model (Eq. 22) must predict this simulator's step
//! time to >95% accuracy — that is the paper's headline accuracy claim, and
//! `examples/e2e_validation.rs` measures it.

use crate::cost::{CostConsts, CostModel, EtaProvider};
use crate::gpu::GpuCatalog;
use crate::memory::MemoryModel;
use crate::model::ModelSpec;
use crate::prng::Rng;
use crate::strategy::ParallelStrategy;

/// Simulation configuration.
#[derive(Debug, Clone)]
pub struct SimConfig {
    /// Seed for the measurement-noise stream.
    pub seed: u64,
    /// Lognormal σ of per-op noise (0 = noiseless).
    pub noise_sigma: f64,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig { seed: 0xA57A, noise_sigma: 0.02 }
    }
}

/// Simulator output.
#[derive(Debug, Clone, Default)]
pub struct SimResult {
    /// 1F1B makespan (fwd+bwd pipeline, seconds).
    pub pipeline_time: f64,
    pub dp_time: f64,
    pub optimizer_time: f64,
    pub offload_time: f64,
    pub step_time: f64,
    pub tokens_per_s: f64,
}

/// The simulator.
#[derive(Debug, Clone)]
pub struct PipelineSimulator {
    cost: CostModel,
    pub config: SimConfig,
}

impl PipelineSimulator {
    pub fn new(catalog: GpuCatalog, config: SimConfig) -> Self {
        // The simulator's physics are always the hardware-truth curves.
        PipelineSimulator { cost: CostModel::new(catalog, EtaProvider::Analytic), config }
    }

    pub fn consts(&self) -> &CostConsts {
        &self.cost.consts
    }

    /// "Run" one training step of the strategy and measure it.
    pub fn measure(&self, m: &ModelSpec, s: &ParallelStrategy) -> SimResult {
        let pp = s.pp();
        let k = s.num_microbatches();
        let mut rng = Rng::new(self.config.seed ^ (pp as u64) << 32 ^ k as u64);

        // Per-stage base op times from the hardware truth.
        let base: Vec<crate::cost::StageTime> =
            (0..pp).map(|i| self.cost.stage_time(m, s, i)).collect();

        // Noisy per-(stage, microbatch) durations.
        let noise = |rng: &mut Rng, sigma: f64| -> f64 {
            if sigma == 0.0 {
                1.0
            } else {
                (sigma * rng.normal()).exp()
            }
        };
        let mut fwd = vec![vec![0.0f64; k]; pp];
        let mut bwd = vec![vec![0.0f64; k]; pp];
        let mut p2p = vec![vec![0.0f64; k]; pp];
        for st in 0..pp {
            for mb in 0..k {
                fwd[st][mb] = base[st].fwd * noise(&mut rng, self.config.noise_sigma);
                bwd[st][mb] = base[st].bwd * noise(&mut rng, self.config.noise_sigma);
                p2p[st][mb] = base[st].p2p * noise(&mut rng, self.config.noise_sigma);
            }
        }

        let makespan_v1 = self.run_1f1b(pp, k, &fwd, &bwd, &p2p);
        // Interleaving (vpp > 1): the schedule shrinks only the fill/drain
        // bubble; the steady-state K·max term is untouched (same closed-form
        // correction the paper's Eq. 22 extension uses — DESIGN.md §6).
        let pipeline_time = if s.vpp > 1 {
            let bottleneck: f64 = (0..pp)
                .map(|st| {
                    (0..k).map(|mb| fwd[st][mb] + bwd[st][mb] + 2.0 * p2p[st][mb]).sum::<f64>()
                        / k as f64
                })
                .fold(0.0, f64::max);
            let steady = k as f64 * bottleneck;
            steady + (makespan_v1 - steady).max(0.0) / s.vpp as f64
        } else {
            makespan_v1
        };

        // DP / optimizer / offload phases share the cost model's semantics,
        // with one noise draw each (they are single collectives/kernels).
        let mem = MemoryModel::default();
        let dp_time = self.cost.dp_time(m, s, &mem) * noise(&mut rng, self.config.noise_sigma);
        let (opt, off) = self.cost.optimizer_time(m, s, &mem);
        let optimizer_time = opt * noise(&mut rng, self.config.noise_sigma);
        let offload_time = off * noise(&mut rng, self.config.noise_sigma);

        let step_time = pipeline_time + dp_time + optimizer_time + offload_time;
        let tokens = (s.global_batch * m.seq_len) as f64;
        SimResult {
            pipeline_time,
            dp_time,
            optimizer_time,
            offload_time,
            step_time,
            tokens_per_s: tokens / step_time,
        }
    }

    /// Exact event-driven 1F1B makespan.
    ///
    /// Stage `st` executes its op sequence in Megatron's 1F1B order:
    /// `w = min(K, P−st)` warmup forwards, then (bwd, fwd) pairs, then the
    /// remaining backwards. Dependencies: `fwd(st, mb)` needs
    /// `fwd(st−1, mb)` + p2p; `bwd(st, mb)` needs `bwd(st+1, mb)` + p2p.
    fn run_1f1b(
        &self,
        pp: usize,
        k: usize,
        fwd: &[Vec<f64>],
        bwd: &[Vec<f64>],
        p2p: &[Vec<f64>],
    ) -> f64 {
        #[derive(Clone, Copy, Debug)]
        enum Op {
            F(usize),
            B(usize),
        }
        // Static per-stage op order.
        let mut order: Vec<Vec<Op>> = Vec::with_capacity(pp);
        for st in 0..pp {
            let w = k.min(pp - st);
            let mut ops = Vec::with_capacity(2 * k);
            for mb in 0..w {
                ops.push(Op::F(mb));
            }
            for i in w..k {
                ops.push(Op::B(i - w));
                ops.push(Op::F(i));
            }
            for mb in (k - w)..k {
                ops.push(Op::B(mb));
            }
            order.push(ops);
        }

        let mut fwd_done = vec![vec![f64::INFINITY; k]; pp];
        let mut bwd_done = vec![vec![f64::INFINITY; k]; pp];
        let mut cursor = vec![0usize; pp]; // next op index per stage
        let mut free_at = vec![0.0f64; pp]; // device availability
        let total_ops = pp * 2 * k;
        let mut done = 0usize;

        // Greedy fixed-point: repeatedly execute any stage whose next op's
        // dependency is satisfied. The 1F1B order guarantees progress.
        while done < total_ops {
            let mut progressed = false;
            for st in 0..pp {
                while cursor[st] < order[st].len() {
                    let op = order[st][cursor[st]];
                    let ready = match op {
                        Op::F(mb) => {
                            if st == 0 {
                                Some(0.0)
                            } else if fwd_done[st - 1][mb].is_finite() {
                                Some(fwd_done[st - 1][mb] + p2p[st - 1][mb])
                            } else {
                                None
                            }
                        }
                        Op::B(mb) => {
                            if st == pp - 1 {
                                // Backward of the last stage needs its own fwd.
                                if fwd_done[st][mb].is_finite() {
                                    Some(fwd_done[st][mb])
                                } else {
                                    None
                                }
                            } else if bwd_done[st + 1][mb].is_finite() {
                                Some(bwd_done[st + 1][mb] + p2p[st][mb])
                            } else {
                                None
                            }
                        }
                    };
                    let Some(ready) = ready else { break };
                    let start = ready.max(free_at[st]);
                    let (dur, slot): (f64, &mut f64) = match op {
                        Op::F(mb) => (fwd[st][mb], &mut fwd_done[st][mb]),
                        Op::B(mb) => (bwd[st][mb], &mut bwd_done[st][mb]),
                    };
                    let end = start + dur;
                    *slot = end;
                    free_at[st] = end;
                    cursor[st] += 1;
                    done += 1;
                    progressed = true;
                }
            }
            assert!(progressed, "1F1B schedule deadlocked (bug)");
        }
        free_at.iter().fold(0.0f64, |a, &b| a.max(b))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{ModelRegistry, ModelSpec};
    use crate::strategy::{ClusterAssignment, Recompute, RecomputeMethod, Segment};

    fn strat(m: &ModelSpec, tp: usize, pp: usize, dp: usize, mbs: usize) -> ParallelStrategy {
        ParallelStrategy {
            cluster: ClusterAssignment::homogeneous(1, pp, m.layers / pp),
            tp,
            dp,
            micro_batch: mbs,
            global_batch: m.global_batch,
            vpp: 1,
            sequence_parallel: tp > 1,
            use_distributed_optimizer: true,
            recompute: Recompute::None,
            recompute_method: RecomputeMethod::Uniform,
            recompute_num_layers: 0,
            offload_optimizer: false,
            overlap_grad_reduce: true,
            overlap_param_gather: true,
            overlap_p2p: true,
            tp_comm_overlap: true,
            use_flash_attn: true,
            ep: 1,
        }
    }

    fn sim() -> PipelineSimulator {
        PipelineSimulator::new(GpuCatalog::builtin(), SimConfig::default())
    }

    fn noiseless() -> PipelineSimulator {
        PipelineSimulator::new(GpuCatalog::builtin(), SimConfig { seed: 1, noise_sigma: 0.0 })
    }

    #[test]
    fn deterministic_for_seed() {
        let reg = ModelRegistry::builtin();
        let m = reg.get("llama2-7b").unwrap();
        let s = strat(m, 2, 4, 8, 2);
        let a = sim().measure(m, &s).step_time;
        let b = sim().measure(m, &s).step_time;
        assert_eq!(a, b);
    }

    #[test]
    fn noiseless_single_stage_equals_sum() {
        // pp=1: makespan must equal K·(fwd+bwd) exactly.
        let reg = ModelRegistry::builtin();
        let m = reg.get("llama2-7b").unwrap();
        let s = strat(m, 8, 1, 8, 2);
        let sv = noiseless();
        let cost = CostModel::new(GpuCatalog::builtin(), EtaProvider::Analytic);
        let st = cost.stage_time(m, &s, 0);
        let k = s.num_microbatches() as f64;
        let r = sv.measure(m, &s);
        let expect = k * (st.fwd + st.bwd);
        assert!(
            (r.pipeline_time - expect).abs() / expect < 1e-9,
            "sim {} vs closed {}",
            r.pipeline_time,
            expect
        );
    }

    #[test]
    fn closed_form_matches_sim_homogeneous() {
        // The paper's accuracy claim: Eq. 22 vs the event-driven truth
        // within 5% (homogeneous, noiseless).
        let reg = ModelRegistry::builtin();
        let cost = CostModel::new(GpuCatalog::builtin(), EtaProvider::Analytic);
        let m = reg.get("llama2-13b").unwrap();
        for (tp, pp, dp, mbs) in [(2, 4, 8, 2), (4, 8, 2, 1), (1, 2, 32, 4)] {
            let s = strat(m, tp, pp, dp, mbs);
            let r = noiseless().measure(m, &s);
            let b = cost.evaluate(m, &s);
            let rel = (b.step_time - r.step_time).abs() / r.step_time;
            assert!(
                rel < 0.05,
                "tp={tp} pp={pp}: model {:.4} vs sim {:.4} (rel {rel:.3})",
                b.step_time,
                r.step_time
            );
        }
    }

    #[test]
    fn hetero_bottleneck_dominates() {
        // A slow stage should pin the makespan near K × its per-mb time.
        let reg = ModelRegistry::builtin();
        let cat = GpuCatalog::builtin();
        let m = reg.get("llama2-7b").unwrap();
        let h100 = cat.find("h100").unwrap();
        let a800 = cat.find("a800").unwrap();
        let mut s = strat(m, 2, 4, 4, 1);
        s.cluster = ClusterAssignment {
            segments: vec![
                Segment { gpu: h100, stages: 2, layers_per_stage: 8 },
                Segment { gpu: a800, stages: 2, layers_per_stage: 8 },
            ],
        };
        let sv = noiseless();
        let r = sv.measure(m, &s);
        let cost = CostModel::new(cat, EtaProvider::Analytic);
        let worst = (0..4)
            .map(|i| {
                let t = cost.stage_time(m, &s, i);
                t.fwd + t.bwd + 2.0 * t.p2p
            })
            .fold(0.0f64, f64::max);
        let k = s.num_microbatches() as f64;
        assert!(r.pipeline_time >= k * worst * 0.999);
        assert!(r.pipeline_time <= k * worst * 1.15, "bubble should be small for K>>P");
    }

    #[test]
    fn deeper_pipeline_bigger_bubble() {
        let reg = ModelRegistry::builtin();
        let m = reg.get("llama2-7b").unwrap();
        let sv = noiseless();
        // Same device count, same microbatches: pp=8 has more bubble than
        // pp=2 relative to total work, but less work per stage. Check the
        // bubble *fraction* grows with pp.
        let frac = |pp: usize| {
            let mut s = strat(m, 2, pp, 32 / pp, 1);
            s.global_batch = 64 * s.dp; // keep K = 64
            let r = sv.measure(m, &s);
            let cost = CostModel::new(GpuCatalog::builtin(), EtaProvider::Analytic);
            let worst = (0..pp)
                .map(|i| {
                    let t = cost.stage_time(m, &s, i);
                    t.fwd + t.bwd + 2.0 * t.p2p
                })
                .fold(0.0f64, f64::max);
            let steady = s.num_microbatches() as f64 * worst;
            (r.pipeline_time - steady) / r.pipeline_time
        };
        assert!(frac(8) > frac(2));
    }

    #[test]
    fn vpp_reduces_pipeline_time() {
        let reg = ModelRegistry::builtin();
        let m = reg.get("llama2-70b").unwrap();
        let mut s = strat(m, 8, 8, 2, 1);
        s.global_batch = 32 * s.dp * s.micro_batch; // small K → visible bubble
        let sv = noiseless();
        let base = sv.measure(m, &s).pipeline_time;
        s.vpp = 4;
        let inter = sv.measure(m, &s).pipeline_time;
        assert!(inter < base, "vpp=4 {inter} vs vpp=1 {base}");
    }

    #[test]
    fn noise_shifts_results_slightly() {
        let reg = ModelRegistry::builtin();
        let m = reg.get("llama2-7b").unwrap();
        let s = strat(m, 2, 4, 8, 2);
        let clean = noiseless().measure(m, &s).step_time;
        let noisy = sim().measure(m, &s).step_time;
        let rel = (noisy - clean).abs() / clean;
        assert!(rel < 0.1, "noise should be a few percent, got {rel}");
        assert!(noisy != clean);
    }
}
