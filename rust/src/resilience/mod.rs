//! `astra::resilience` — deadlines, cooperative cancellation, retry
//! policy, poison-tolerant locking, and the fault-injection substrate
//! ([`failpoint`]). Zero external dependencies, like [`crate::telemetry`].
//!
//! The paper's headline guarantee is *bounded* search latency; this module
//! is how the service keeps that promise under real traffic:
//!
//! * [`CancelToken`] — a shared deadline + cancellation flag carried from
//!   the wire (`deadline_ms`) into the search-plan executor, which checks
//!   it at wave boundaries. A cancelled search returns a typed
//!   [`AstraError::Deadline`] and never a partial report: waves that
//!   already ran are discarded whole, so the determinism contract (byte-
//!   identical reports at any worker/wave count) is untouched — a request
//!   either gets the full report or a clean typed error.
//! * [`RetryPolicy`] — deterministic full-jitter exponential backoff for
//!   retryable (`overloaded`) responses, seeded via [`crate::prng`] so
//!   tests can pin the exact delay sequence.
//! * [`lock_unpoisoned`] — mutex poisoning is a side effect of panic
//!   isolation: once per-request handling is wrapped in `catch_unwind`,
//!   a panicking request must not wedge every later request that touches
//!   the same shard/registry lock. The data under our locks is
//!   append/replace-style (cache shards, inflight markers, metric maps),
//!   valid at every intermediate state, so recovering the guard is safe.
//! * [`failpoint`] — env/registry-armed deterministic fault injection at
//!   the seams that matter (persist IO, snapshot decode, engine scoring,
//!   wire parse); `rust/tests/chaos.rs` drives the serve loop through
//!   scripted fault schedules against the invariants above.

pub mod failpoint;

pub use failpoint::{FailAction, FailSpec};

use crate::{AstraError, Result};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, MutexGuard, PoisonError};
use std::time::{Duration, Instant};

/// Lock a mutex, recovering the guard if a previous holder panicked.
///
/// Used on locks that protect always-valid data (cache shards, the
/// inflight map, the telemetry registry, worker result vectors): a panic
/// mid-critical-section there can at worst lose one in-flight update,
/// never corrupt an invariant, so inheriting the poisoned state beats
/// propagating a second panic to every subsequent request.
pub fn lock_unpoisoned<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Shared cancellation token: an optional absolute deadline plus a manual
/// cancellation flag. Cheap to check (one relaxed load, plus one clock
/// read when a deadline is armed), safe to share across worker threads by
/// reference or `Arc`.
///
/// The executor polls [`check`](CancelToken::check) at wave boundaries and
/// [`is_cancelled`](CancelToken::is_cancelled) inside per-pool closures;
/// the service layer builds one per admitted cold request from the
/// effective `deadline_ms`.
#[derive(Debug)]
pub struct CancelToken {
    deadline: Option<Instant>,
    /// The original budget, kept for deterministic error messages
    /// (elapsed times would break byte-stable wire transcripts).
    budget_ms: Option<u64>,
    cancelled: AtomicBool,
}

impl CancelToken {
    /// A token that never fires (the default for direct engine use).
    pub fn unlimited() -> Self {
        CancelToken { deadline: None, budget_ms: None, cancelled: AtomicBool::new(false) }
    }

    /// A token that fires once `budget` has elapsed from now.
    pub fn with_deadline(budget: Duration) -> Self {
        CancelToken {
            deadline: Some(Instant::now() + budget),
            budget_ms: Some(budget.as_millis() as u64),
            cancelled: AtomicBool::new(false),
        }
    }

    /// Convenience: `0` means "already expired" (the wire contract for
    /// `deadline_ms: 0` — serve from cache or fail immediately).
    pub fn with_deadline_ms(ms: u64) -> Self {
        Self::with_deadline(Duration::from_millis(ms))
    }

    /// Manually cancel (idempotent).
    pub fn cancel(&self) {
        self.cancelled.store(true, Ordering::Relaxed);
    }

    /// Has this token fired? Latches: once the deadline has passed the
    /// token stays cancelled even if the clock could not be re-read.
    pub fn is_cancelled(&self) -> bool {
        if self.cancelled.load(Ordering::Relaxed) {
            return true;
        }
        if let Some(d) = self.deadline {
            if Instant::now() >= d {
                self.cancelled.store(true, Ordering::Relaxed);
                return true;
            }
        }
        false
    }

    /// Time left before the deadline (`None` when unlimited, zero once
    /// expired).
    pub fn remaining(&self) -> Option<Duration> {
        self.deadline.map(|d| d.saturating_duration_since(Instant::now()))
    }

    /// Checkpoint: `Ok(())` to keep going, a typed [`AstraError::Deadline`]
    /// once cancelled. The executor calls this at wave boundaries so a
    /// cancelled search unwinds without assembling a partial report.
    pub fn check(&self) -> Result<()> {
        if !self.is_cancelled() {
            return Ok(());
        }
        Err(match self.budget_ms {
            Some(ms) => AstraError::Deadline(format!(
                "deadline of {ms} ms exceeded; search cancelled at a wave boundary"
            )),
            None => AstraError::Deadline("search cancelled".to_string()),
        })
    }
}

/// Deterministic full-jitter exponential backoff for client-side retries
/// of retryable (`overloaded`) responses.
///
/// Attempt `k` (0-based) sleeps a uniform duration in `[d/2, d]` where
/// `d = min(base_ms << k, cap_ms)`; the jitter stream is seeded, so a
/// fixed seed yields a fixed delay sequence (pinned in tests).
#[derive(Debug, Clone, Copy)]
pub struct RetryPolicy {
    pub max_retries: u32,
    pub base_ms: u64,
    pub cap_ms: u64,
    pub seed: u64,
}

impl RetryPolicy {
    pub fn new(max_retries: u32, base_ms: u64, seed: u64) -> Self {
        RetryPolicy { max_retries, base_ms: base_ms.max(1), cap_ms: 5_000, seed }
    }

    /// The backoff delay before retry attempt `attempt` (0-based).
    pub fn delay(&self, attempt: u32) -> Duration {
        let exp = self
            .base_ms
            .saturating_mul(1u64 << attempt.min(20))
            .min(self.cap_ms.max(self.base_ms))
            .max(1);
        // One independent, deterministic stream per attempt index.
        let mut rng =
            crate::prng::Rng::new(self.seed ^ (attempt as u64 + 1).wrapping_mul(0x9E3779B97F4A7C15));
        Duration::from_millis(rng.range_u64(exp.div_ceil(2), exp))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unlimited_token_never_fires() {
        let t = CancelToken::unlimited();
        assert!(!t.is_cancelled());
        assert!(t.check().is_ok());
        assert!(t.remaining().is_none());
    }

    #[test]
    fn zero_deadline_is_immediately_cancelled() {
        let t = CancelToken::with_deadline_ms(0);
        assert!(t.is_cancelled());
        let err = t.check().unwrap_err();
        assert_eq!(err.kind(), "deadline");
        assert!(err.to_string().contains("deadline of 0 ms exceeded"), "{err}");
    }

    #[test]
    fn manual_cancel_latches() {
        let t = CancelToken::with_deadline(Duration::from_secs(3600));
        assert!(t.check().is_ok());
        t.cancel();
        assert!(t.is_cancelled());
        assert_eq!(t.check().unwrap_err().kind(), "deadline");
        assert!(t.is_cancelled(), "cancellation must latch");
    }

    #[test]
    fn generous_deadline_not_cancelled_yet() {
        let t = CancelToken::with_deadline(Duration::from_secs(3600));
        assert!(!t.is_cancelled());
        assert!(t.remaining().unwrap() > Duration::from_secs(3000));
    }

    #[test]
    fn retry_delays_are_deterministic_and_bounded() {
        let p = RetryPolicy::new(4, 25, 42);
        let a: Vec<_> = (0..4).map(|k| p.delay(k)).collect();
        let b: Vec<_> = (0..4).map(|k| p.delay(k)).collect();
        assert_eq!(a, b, "same seed, same delays");
        for (k, d) in a.iter().enumerate() {
            let full = (25u64 << k).min(5_000);
            let ms = d.as_millis() as u64;
            assert!(ms >= full.div_ceil(2) && ms <= full, "attempt {k}: {ms} ms vs cap {full}");
        }
        let other = RetryPolicy::new(4, 25, 43);
        assert_ne!(
            (0..4).map(|k| other.delay(k)).collect::<Vec<_>>(),
            a,
            "different seed should shift the jitter"
        );
    }

    #[test]
    fn lock_unpoisoned_recovers_after_holder_panic() {
        let m = std::sync::Arc::new(Mutex::new(7u32));
        let m2 = m.clone();
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison it");
        })
        .join();
        assert!(m.lock().is_err(), "mutex should be poisoned");
        assert_eq!(*lock_unpoisoned(&m), 7, "guard recovered, data intact");
        *lock_unpoisoned(&m) = 8;
        assert_eq!(*lock_unpoisoned(&m), 8);
    }
}
