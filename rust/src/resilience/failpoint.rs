//! Zero-dependency deterministic fault injection.
//!
//! A *failpoint* is a named seam in production code where a test (or an
//! operator, via environment variables) can inject a failure:
//!
//! ```text
//! failpoint!("persist.spill");          // in a Result<_, AstraError> fn
//! failpoint::fire_as_panic("engine.score"); // in a non-Result closure
//! ```
//!
//! Disarmed cost is two relaxed atomic loads — no allocation, no lock, no
//! branch on the data path — so the seams stay compiled into release
//! builds and chaos schedules exercise the exact production binary.
//!
//! ## Arming
//!
//! * Tests: [`arm`]`("name", FailSpec::once(FailAction::Panic))` /
//!   [`disarm_all`]. The registry is process-global, so tests that arm
//!   production seam names must serialize (see `rust/tests/chaos.rs`).
//! * Environment (the `ci.sh` chaos smoke lane):
//!   `ASTRA_FAILPOINTS="name=action[:prob[:max_fires]];…"` with
//!   `action ∈ {error, panic}`, e.g.
//!   `ASTRA_FAILPOINTS="engine.score=panic:1:1;wire.parse=error:0.5"`.
//!   `ASTRA_FAILPOINT_SEED=<u64>` seeds the firing hash.
//!
//! ## Determinism
//!
//! Probabilistic firing is *not* sampled from a clock or an OS RNG: hit
//! `i` of failpoint `name` fires iff `hash(seed, name, i)` maps below the
//! armed probability. The same seed and the same hit sequence therefore
//! reproduce the same fault schedule on every run — a chaos failure is
//! replayable by re-running the test.
//!
//! ## Production seams
//!
//! | name | site | armed effect |
//! |---|---|---|
//! | `persist.spill` | `persist::WarmWriter::finish_to` | spill returns a typed fault before touching disk |
//! | `persist.restore` | `coordinator::ScoringCore::load_warm_set` | warm load fails like unreadable IO |
//! | `persist.decode` | `persist::read_warm_filtered` | snapshot treated as corrupt (cold start) |
//! | `engine.score` | `coordinator` wave streaming closure | scoring panics mid-wave (panic either way) |
//! | `wire.parse` | `service::server::process_batch` | a parsed request line errors at admission |

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, Once, OnceLock};

/// What an armed failpoint does when it fires.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FailAction {
    /// Surface a typed [`crate::AstraError::Fault`] from the seam.
    Error,
    /// Panic at the seam (exercises the service's `catch_unwind` wall).
    Panic,
}

/// Arming spec for one named failpoint.
#[derive(Debug, Clone, Copy)]
pub struct FailSpec {
    pub action: FailAction,
    /// Firing probability per hit in `[0, 1]`; `1.0` fires every hit.
    pub probability: f64,
    /// Cap on total fires (`0` = unlimited).
    pub max_fires: u64,
}

impl FailSpec {
    /// Fire on every hit, forever.
    pub fn always(action: FailAction) -> Self {
        FailSpec { action, probability: 1.0, max_fires: 0 }
    }

    /// Fire on the first hit only, then fall silent.
    pub fn once(action: FailAction) -> Self {
        FailSpec { action, probability: 1.0, max_fires: 1 }
    }
}

struct Entry {
    spec: FailSpec,
    hits: u64,
    fires: u64,
}

struct Registry {
    points: HashMap<String, Entry>,
    seed: u64,
}

/// Fast-path switch: flipped on whenever any failpoint is armed. The
/// disarmed data path is this single relaxed load (plus the one-time
/// `Once` fence below).
static ARMED: AtomicBool = AtomicBool::new(false);
/// Total fires across all failpoints since process start (mirrored into
/// the `astra_faults_injected_total` telemetry counter at fire time).
static FIRED: AtomicU64 = AtomicU64::new(0);

fn registry() -> &'static Mutex<Registry> {
    static REG: OnceLock<Mutex<Registry>> = OnceLock::new();
    REG.get_or_init(|| Mutex::new(Registry { points: HashMap::new(), seed: 0 }))
}

/// One-time environment arming: `ASTRA_FAILPOINTS` / `ASTRA_FAILPOINT_SEED`
/// are read on the first failpoint hit (or the first registry call), so
/// the serve binary needs no wiring to become chaos-testable.
fn init_from_env() {
    static INIT: Once = Once::new();
    INIT.call_once(|| {
        let mut reg = crate::resilience::lock_unpoisoned(registry());
        if let Ok(s) = std::env::var("ASTRA_FAILPOINT_SEED") {
            if let Ok(n) = s.trim().parse::<u64>() {
                reg.seed = n;
            }
        }
        if let Ok(s) = std::env::var("ASTRA_FAILPOINTS") {
            for (name, spec) in parse_env(&s) {
                reg.points.insert(name, Entry { spec, hits: 0, fires: 0 });
            }
        }
        if !reg.points.is_empty() {
            ARMED.store(true, Ordering::Relaxed);
        }
    });
}

/// Parse the `ASTRA_FAILPOINTS` grammar: `name=action[:prob[:max_fires]]`
/// entries separated by `;` or `,`; malformed entries are skipped (chaos
/// tooling must never take the process down by itself).
pub(crate) fn parse_env(s: &str) -> Vec<(String, FailSpec)> {
    let mut out = Vec::new();
    for item in s.split([';', ',']) {
        let item = item.trim();
        if item.is_empty() {
            continue;
        }
        let Some((name, rhs)) = item.split_once('=') else { continue };
        let mut parts = rhs.split(':');
        let action = match parts.next().map(str::trim) {
            Some("error") => FailAction::Error,
            Some("panic") => FailAction::Panic,
            _ => continue,
        };
        let probability = match parts.next() {
            None => 1.0,
            Some(p) => match p.trim().parse::<f64>() {
                Ok(v) if (0.0..=1.0).contains(&v) => v,
                _ => continue,
            },
        };
        let max_fires = match parts.next() {
            None => 0,
            Some(m) => match m.trim().parse::<u64>() {
                Ok(v) => v,
                _ => continue,
            },
        };
        out.push((name.trim().to_string(), FailSpec { action, probability, max_fires }));
    }
    out
}

/// Arm (or re-arm, resetting hit/fire counts) a named failpoint.
pub fn arm(name: &str, spec: FailSpec) {
    init_from_env();
    let mut reg = crate::resilience::lock_unpoisoned(registry());
    reg.points.insert(name.to_string(), Entry { spec, hits: 0, fires: 0 });
    ARMED.store(true, Ordering::Relaxed);
}

/// Disarm one failpoint; the fast path stays hot only while any remain.
pub fn disarm(name: &str) {
    init_from_env();
    let mut reg = crate::resilience::lock_unpoisoned(registry());
    reg.points.remove(name);
    if reg.points.is_empty() {
        ARMED.store(false, Ordering::Relaxed);
    }
}

/// Disarm everything (chaos tests call this on entry and exit).
pub fn disarm_all() {
    init_from_env();
    let mut reg = crate::resilience::lock_unpoisoned(registry());
    reg.points.clear();
    ARMED.store(false, Ordering::Relaxed);
}

/// Set the firing-hash seed (also settable via `ASTRA_FAILPOINT_SEED`).
pub fn set_seed(seed: u64) {
    init_from_env();
    crate::resilience::lock_unpoisoned(registry()).seed = seed;
}

/// Total injected faults fired so far in this process.
pub fn faults_injected() -> u64 {
    FIRED.load(Ordering::Relaxed)
}

/// The seam primitive: did failpoint `name` fire on this hit, and with
/// which action? Disarmed cost: two relaxed atomic loads.
pub fn should_fire(name: &str) -> Option<FailAction> {
    init_from_env();
    if !ARMED.load(Ordering::Relaxed) {
        return None;
    }
    let mut reg = crate::resilience::lock_unpoisoned(registry());
    let seed = reg.seed;
    let entry = reg.points.get_mut(name)?;
    let hit = entry.hits;
    entry.hits += 1;
    if entry.spec.max_fires > 0 && entry.fires >= entry.spec.max_fires {
        return None;
    }
    let fire = if entry.spec.probability >= 1.0 {
        true
    } else if entry.spec.probability <= 0.0 {
        false
    } else {
        // Deterministic "coin": 53 high bits of the mixed hash → [0, 1).
        let u = (fire_hash(seed, name, hit) >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
        u < entry.spec.probability
    };
    if !fire {
        return None;
    }
    entry.fires += 1;
    let action = entry.spec.action;
    drop(reg);
    FIRED.fetch_add(1, Ordering::Relaxed);
    crate::telemetry_counter!("astra_faults_injected_total").inc();
    Some(action)
}

/// FNV-1a over (name, hit index) folded with the seed, finished with the
/// SplitMix64 avalanche so high bits are well mixed for the `[0,1)` map.
fn fire_hash(seed: u64, name: &str, hit: u64) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64 ^ seed;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    for b in hit.to_le_bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    let mut z = h;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Seam helper for closures with no `Result` channel (worker-pool scoring
/// bodies): any armed action becomes a panic, which the service layer's
/// `catch_unwind` isolates into a typed `panic`-kind error response.
pub fn fire_as_panic(name: &str) {
    if should_fire(name).is_some() {
        panic!("failpoint '{name}' fired (injected panic)");
    }
}

/// Inject a fault at a named seam inside a `Result<_, AstraError>`
/// function: an armed `Error` action returns a typed
/// [`crate::AstraError::Fault`] from the *enclosing* function; an armed
/// `Panic` action panics there (isolated by the service layer).
#[macro_export]
macro_rules! failpoint {
    ($name:expr) => {
        if let Some(action) = $crate::resilience::failpoint::should_fire($name) {
            match action {
                $crate::resilience::failpoint::FailAction::Panic => {
                    panic!("failpoint '{}' fired (injected panic)", $name)
                }
                $crate::resilience::failpoint::FailAction::Error => {
                    return Err($crate::AstraError::Fault(format!(
                        "failpoint '{}' fired (injected fault)",
                        $name
                    )));
                }
            }
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    // The registry is process-global and the lib test binary is
    // multi-threaded: every test here uses `test.*` seam names that no
    // production code hits, so arming them cannot perturb concurrently
    // running searches. End-to-end schedules against production seam
    // names live in `rust/tests/chaos.rs` (its own process).

    #[test]
    fn disarmed_points_never_fire() {
        assert!(should_fire("test.never.armed").is_none());
    }

    #[test]
    fn always_fires_until_disarmed() {
        arm("test.always", FailSpec::always(FailAction::Error));
        assert_eq!(should_fire("test.always"), Some(FailAction::Error));
        assert_eq!(should_fire("test.always"), Some(FailAction::Error));
        disarm("test.always");
        assert!(should_fire("test.always").is_none());
    }

    #[test]
    fn once_caps_at_one_fire() {
        arm("test.once", FailSpec::once(FailAction::Panic));
        assert_eq!(should_fire("test.once"), Some(FailAction::Panic));
        assert!(should_fire("test.once").is_none(), "max_fires=1 must cap");
        assert!(should_fire("test.once").is_none());
        disarm("test.once");
    }

    #[test]
    fn probabilistic_firing_is_seeded_deterministic() {
        let run = |seed: u64| -> Vec<bool> {
            set_seed(seed);
            arm(
                "test.prob",
                FailSpec { action: FailAction::Error, probability: 0.5, max_fires: 0 },
            );
            let out = (0..64).map(|_| should_fire("test.prob").is_some()).collect();
            disarm("test.prob");
            set_seed(0);
            out
        };
        let a = run(42);
        let b = run(42);
        assert_eq!(a, b, "same seed, same schedule");
        let fired = a.iter().filter(|&&x| x).count();
        assert!((8..=56).contains(&fired), "p=0.5 over 64 hits fired {fired}");
        let c = run(43);
        assert_ne!(a, c, "different seed should reshuffle the schedule");
    }

    #[test]
    fn fires_bump_the_global_count() {
        let before = faults_injected();
        arm("test.count", FailSpec::once(FailAction::Error));
        let _ = should_fire("test.count");
        disarm("test.count");
        assert!(faults_injected() > before);
    }

    #[test]
    fn macro_error_action_returns_typed_fault() {
        fn seam() -> crate::Result<u32> {
            failpoint!("test.macro.err");
            Ok(5)
        }
        assert_eq!(seam().unwrap(), 5, "disarmed: pass through");
        arm("test.macro.err", FailSpec::always(FailAction::Error));
        let err = seam().unwrap_err();
        disarm("test.macro.err");
        assert_eq!(err.kind(), "fault");
        assert!(err.to_string().contains("failpoint 'test.macro.err' fired"), "{err}");
        assert_eq!(seam().unwrap(), 5, "disarmed again: pass through");
    }

    #[test]
    fn fire_as_panic_panics_with_seam_name() {
        arm("test.panic.seam", FailSpec::once(FailAction::Panic));
        let caught = std::panic::catch_unwind(|| fire_as_panic("test.panic.seam"));
        disarm("test.panic.seam");
        let payload = caught.unwrap_err();
        let msg = payload.downcast_ref::<String>().cloned().unwrap_or_default();
        assert!(msg.contains("failpoint 'test.panic.seam' fired"), "{msg}");
        fire_as_panic("test.panic.seam"); // disarmed: no-op
    }

    #[test]
    fn env_grammar_parses_and_skips_garbage() {
        let specs = parse_env(
            "persist.spill=error; engine.score=panic:1:1 , wire.parse=error:0.25:4;\
             bogus;also=bogus;bad=error:2.0;neg=panic:-1",
        );
        let names: Vec<&str> = specs.iter().map(|(n, _)| n.as_str()).collect();
        assert_eq!(names, ["persist.spill", "engine.score", "wire.parse"]);
        let (_, spill) = &specs[0];
        assert_eq!(spill.action, FailAction::Error);
        assert_eq!(spill.probability, 1.0);
        assert_eq!(spill.max_fires, 0);
        let (_, score) = &specs[1];
        assert_eq!(score.action, FailAction::Panic);
        assert_eq!(score.max_fires, 1);
        let (_, wire) = &specs[2];
        assert_eq!(wire.probability, 0.25);
        assert_eq!(wire.max_fires, 4);
        assert!(parse_env("").is_empty());
    }
}
