//! Leveled logging substrate (the `log` facade is cached but a full env-logger
//! is not; a 60-line logger keeps the dependency surface at zero).
//!
//! Level is process-global, settable via `ASTRA_LOG` (error|warn|info|debug|
//! trace) or [`set_level`]. Output goes to stderr so bench tables on stdout
//! stay machine-readable.

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(2); // default Info
static INIT: std::sync::Once = std::sync::Once::new();

/// Process start instant for the elapsed-time log column. Shared with the
/// flight recorder's span timestamps ([`crate::telemetry::process_epoch`])
/// so log lines and trace events line up on one clock.
fn start() -> Instant {
    INIT.call_once(|| {
        if let Ok(env) = std::env::var("ASTRA_LOG") {
            if let Some(l) = parse_level(&env) {
                LEVEL.store(l as u8, Ordering::Relaxed);
            }
        }
    });
    crate::telemetry::process_epoch()
}

fn parse_level(s: &str) -> Option<Level> {
    match s.to_ascii_lowercase().as_str() {
        "error" => Some(Level::Error),
        "warn" => Some(Level::Warn),
        "info" => Some(Level::Info),
        "debug" => Some(Level::Debug),
        "trace" => Some(Level::Trace),
        _ => None,
    }
}

/// Set the global level programmatically (CLI `-v` flags).
pub fn set_level(l: Level) {
    start();
    LEVEL.store(l as u8, Ordering::Relaxed);
}

pub fn enabled(l: Level) -> bool {
    (l as u8) <= LEVEL.load(Ordering::Relaxed)
}

/// Core log entry point; use the `info!`-style macros instead.
pub fn log(l: Level, module: &str, msg: std::fmt::Arguments<'_>) {
    let t0 = start();
    if !enabled(l) {
        return;
    }
    let tag = match l {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
        Level::Trace => "TRACE",
    };
    eprintln!("[{:9.3}s {tag} {module}] {msg}", t0.elapsed().as_secs_f64());
}

#[macro_export]
macro_rules! log_error { ($($a:tt)*) => { $crate::logging::log($crate::logging::Level::Error, module_path!(), format_args!($($a)*)) } }
#[macro_export]
macro_rules! log_warn { ($($a:tt)*) => { $crate::logging::log($crate::logging::Level::Warn, module_path!(), format_args!($($a)*)) } }
#[macro_export]
macro_rules! log_info { ($($a:tt)*) => { $crate::logging::log($crate::logging::Level::Info, module_path!(), format_args!($($a)*)) } }
#[macro_export]
macro_rules! log_debug { ($($a:tt)*) => { $crate::logging::log($crate::logging::Level::Debug, module_path!(), format_args!($($a)*)) } }
#[macro_export]
macro_rules! log_trace { ($($a:tt)*) => { $crate::logging::log($crate::logging::Level::Trace, module_path!(), format_args!($($a)*)) } }

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info); // restore default for other tests
    }

    #[test]
    fn parse_levels() {
        assert_eq!(parse_level("DEBUG"), Some(Level::Debug));
        assert_eq!(parse_level("nope"), None);
    }
}
