//! Heterogeneous-GPU strategy search (paper §3.4, Eq. 22–23).
//!
//! Deploying `P` pipeline stages over `M` GPU types reduces (after the
//! paper's rearrangement argument) to choosing an *ordered sequence of
//! contiguous segments*: which types appear, in which pipeline order, how
//! many stages `m_i` each gets (`Σ m_i = P`, `m_i·T·D ≤ l_i`), and how many
//! layers `n_i` each of its stages holds (`Σ m_i·n_i = N`). That is
//! `C(P−1, M−1)·(M−1)! ≈ O(P^{M−1})` segment shapes × `O(N^{M−1})` layer
//! assignments (the paper's complexity analysis — implemented verbatim by
//! [`HeteroSolver::enumerate_exhaustive`]).
//!
//! [`HeteroSolver::enumerate_pruned`] is our optimized variant (ablated in
//! `benches/ablation_hetero_solver.rs`): for each segment shape it seeds
//! the layer assignment proportional to per-layer GPU speed and explores a
//! ±`radius` neighbourhood, which preserves the optimum in practice while
//! cutting the `O(N^{M−1})` factor to a constant.

use crate::gpu::{GpuCatalog, GpuType};
use crate::strategy::{ClusterAssignment, Segment};

/// Caps per GPU type, already divided down to "stages available":
/// `max_stages_i = l_i / (T·D)`.
#[derive(Debug, Clone)]
pub struct TypeBudget {
    pub gpu: GpuType,
    pub max_stages: usize,
    /// Relative per-layer speed (higher = faster), used by the pruned
    /// solver to seed layer assignments.
    pub speed: f64,
}

/// Enumeration/solver for heterogeneous cluster assignments.
#[derive(Debug, Clone)]
pub struct HeteroSolver {
    /// Neighbourhood radius of the pruned layer-assignment search.
    pub prune_radius: i64,
    /// Hard cap on emitted assignments (guards pathological inputs).
    pub max_assignments: usize,
}

impl Default for HeteroSolver {
    fn default() -> Self {
        HeteroSolver { prune_radius: 2, max_assignments: 2_000_000 }
    }
}

impl HeteroSolver {
    /// Build per-type budgets from raw GPU caps (`l_i`), tp and dp.
    pub fn budgets(
        catalog: &GpuCatalog,
        caps: &[(GpuType, usize)],
        tp: usize,
        dp: usize,
    ) -> Vec<TypeBudget> {
        caps.iter()
            .map(|&(g, l)| TypeBudget {
                gpu: g,
                max_stages: l / (tp * dp),
                speed: catalog.spec(g).peak_flops() * catalog.spec(g).eff.util_max,
            })
            .collect()
    }

    /// All ordered sequences of distinct types (non-empty subsets ×
    /// permutations) — the segment *orderings* of §3.4.
    pub fn type_orderings(n_types: usize) -> Vec<Vec<usize>> {
        let mut out = Vec::new();
        let mut current = Vec::new();
        let mut used = vec![false; n_types];
        fn rec(
            n: usize,
            used: &mut [bool],
            current: &mut Vec<usize>,
            out: &mut Vec<Vec<usize>>,
        ) {
            if !current.is_empty() {
                out.push(current.clone());
            }
            for i in 0..n {
                if !used[i] {
                    used[i] = true;
                    current.push(i);
                    rec(n, used, current, out);
                    current.pop();
                    used[i] = false;
                }
            }
        }
        rec(n_types, &mut used, &mut current, &mut out);
        out
    }

    /// Positive compositions of `total` into exactly `parts` parts subject
    /// to per-part caps.
    pub fn compositions(total: usize, caps: &[usize]) -> Vec<Vec<usize>> {
        let mut out = Vec::new();
        let mut cur = vec![0usize; caps.len()];
        fn rec(
            idx: usize,
            remaining: usize,
            caps: &[usize],
            cur: &mut Vec<usize>,
            out: &mut Vec<Vec<usize>>,
        ) {
            if idx == caps.len() {
                if remaining == 0 {
                    out.push(cur.clone());
                }
                return;
            }
            let tail_min = caps.len() - idx - 1; // each later part ≥ 1
            for m in 1..=caps[idx].min(remaining.saturating_sub(tail_min)) {
                cur[idx] = m;
                rec(idx + 1, remaining - m, caps, cur, out);
            }
            cur[idx] = 0;
        }
        if !caps.is_empty() && total >= caps.len() {
            rec(0, total, caps, &mut cur, &mut out);
        }
        out
    }

    /// Dispatch between the exhaustive Eq. 23 enumeration and the pruned
    /// variant (the coordinator's `hetero_exhaustive` knob).
    pub fn enumerate(
        &self,
        layers: usize,
        pp: usize,
        budgets: &[TypeBudget],
        exhaustive: bool,
    ) -> Vec<ClusterAssignment> {
        if exhaustive {
            self.enumerate_exhaustive(layers, pp, budgets)
        } else {
            self.enumerate_pruned(layers, pp, budgets)
        }
    }

    /// Exhaustive Eq. 23 enumeration: every ordering × composition × layer
    /// assignment with `Σ m_i·n_i = N`, `n_i ≥ 1`.
    pub fn enumerate_exhaustive(
        &self,
        layers: usize,
        pp: usize,
        budgets: &[TypeBudget],
    ) -> Vec<ClusterAssignment> {
        let mut out = Vec::new();
        for ordering in Self::type_orderings(budgets.len()) {
            let caps: Vec<usize> = ordering.iter().map(|&i| budgets[i].max_stages).collect();
            for stages in Self::compositions(pp, &caps) {
                self.layer_assignments_all(layers, &stages, &ordering, budgets, &mut out);
                if out.len() >= self.max_assignments {
                    crate::log_warn!("hetero enumeration truncated at {}", out.len());
                    return out;
                }
            }
        }
        out
    }

    fn layer_assignments_all(
        &self,
        layers: usize,
        stages: &[usize],
        ordering: &[usize],
        budgets: &[TypeBudget],
        out: &mut Vec<ClusterAssignment>,
    ) {
        // Recursively pick n_i for each segment.
        fn rec(
            idx: usize,
            remaining: usize,
            stages: &[usize],
            ns: &mut Vec<usize>,
            emit: &mut dyn FnMut(&[usize]),
        ) {
            if idx == stages.len() {
                if remaining == 0 {
                    emit(ns);
                }
                return;
            }
            let m = stages[idx];
            // Remaining segments need at least Σ m_j layers (n_j ≥ 1).
            let tail_min: usize = stages[idx + 1..].iter().sum();
            let max_n = (remaining.saturating_sub(tail_min)) / m;
            for n in 1..=max_n {
                if idx + 1 == stages.len() && m * n != remaining {
                    continue;
                }
                ns.push(n);
                rec(idx + 1, remaining - m * n, stages, ns, emit);
                ns.pop();
            }
        }
        let mut ns = Vec::new();
        let mut emit = |ns: &[usize]| {
            out.push(ClusterAssignment {
                segments: ns
                    .iter()
                    .zip(stages)
                    .zip(ordering)
                    .map(|((&n, &m), &ty)| Segment {
                        gpu: budgets[ty].gpu,
                        stages: m,
                        layers_per_stage: n,
                    })
                    .collect(),
            });
        };
        rec(0, layers, stages, &mut ns, &mut emit);
    }

    /// Pruned enumeration: same orderings × compositions, but layer counts
    /// are seeded ∝ segment speed and searched only in a ±radius box.
    pub fn enumerate_pruned(
        &self,
        layers: usize,
        pp: usize,
        budgets: &[TypeBudget],
    ) -> Vec<ClusterAssignment> {
        let mut out = Vec::new();
        for ordering in Self::type_orderings(budgets.len()) {
            let caps: Vec<usize> = ordering.iter().map(|&i| budgets[i].max_stages).collect();
            for stages in Self::compositions(pp, &caps) {
                self.layer_assignments_pruned(layers, &stages, &ordering, budgets, &mut out);
                if out.len() >= self.max_assignments {
                    return out;
                }
            }
        }
        out
    }

    fn layer_assignments_pruned(
        &self,
        layers: usize,
        stages: &[usize],
        ordering: &[usize],
        budgets: &[TypeBudget],
        out: &mut Vec<ClusterAssignment>,
    ) {
        let k = stages.len();
        // Seed: a stage on a GPU with speed c should take layers ∝ c so all
        // stage times equalize (the Eq. 22 max term dominates).
        let speeds: Vec<f64> = ordering.iter().map(|&i| budgets[i].speed).collect();
        let denom: f64 = stages.iter().zip(&speeds).map(|(&m, &c)| m as f64 * c).sum();
        let seed: Vec<i64> = speeds
            .iter()
            .map(|&c| ((layers as f64 * c / denom).round() as i64).max(1))
            .collect();
        // Explore the ±radius box around the seed for the first k−1
        // segments; the last is determined by the layer-sum constraint.
        let r = self.prune_radius;
        let mut choice = vec![0i64; k];
        fn rec(
            idx: usize,
            layers: i64,
            stages: &[usize],
            seed: &[i64],
            r: i64,
            choice: &mut Vec<i64>,
            emit: &mut dyn FnMut(&[i64]),
        ) {
            let k = stages.len();
            if idx == k - 1 {
                let used: i64 = (0..k - 1).map(|i| choice[i] * stages[i] as i64).sum();
                let rem = layers - used;
                let m = stages[k - 1] as i64;
                if rem > 0 && rem % m == 0 {
                    choice[k - 1] = rem / m;
                    emit(choice);
                }
                return;
            }
            for n in (seed[idx] - r).max(1)..=(seed[idx] + r) {
                choice[idx] = n;
                rec(idx + 1, layers, stages, seed, r, choice, emit);
            }
        }
        let mut emit = |ns: &[i64]| {
            out.push(ClusterAssignment {
                segments: ns
                    .iter()
                    .zip(stages)
                    .zip(ordering)
                    .map(|((&n, &m), &ty)| Segment {
                        gpu: budgets[ty].gpu,
                        stages: m,
                        layers_per_stage: n as usize,
                    })
                    .collect(),
            });
        };
        rec(0, layers as i64, stages, &seed, r, &mut choice, &mut emit);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::GpuCatalog;

    fn budgets2() -> Vec<TypeBudget> {
        let cat = GpuCatalog::builtin();
        HeteroSolver::budgets(
            &cat,
            &[(cat.find("a800").unwrap(), 64), (cat.find("h100").unwrap(), 64)],
            2,
            2,
        )
    }

    #[test]
    fn orderings_count() {
        // Non-empty subset permutations of M types: Σ_k C(M,k)·k!.
        assert_eq!(HeteroSolver::type_orderings(1).len(), 1);
        assert_eq!(HeteroSolver::type_orderings(2).len(), 4); // {0},{1},{0,1},{1,0}
        assert_eq!(HeteroSolver::type_orderings(3).len(), 15);
    }

    #[test]
    fn compositions_respect_caps_and_sum() {
        let comps = HeteroSolver::compositions(8, &[4, 6]);
        assert!(!comps.is_empty());
        for c in &comps {
            assert_eq!(c.iter().sum::<usize>(), 8);
            assert!(c[0] >= 1 && c[0] <= 4);
            assert!(c[1] >= 1 && c[1] <= 6);
        }
        // m1 in 2..=4 (m2 = 8-m1 ≤ 6) → 3 compositions.
        assert_eq!(comps.len(), 3);
    }

    #[test]
    fn exhaustive_covers_all_layer_splits() {
        let solver = HeteroSolver::default();
        let budgets = budgets2();
        let all = solver.enumerate_exhaustive(16, 4, &budgets);
        assert!(!all.is_empty());
        for ca in &all {
            assert_eq!(ca.pp(), 4);
            assert_eq!(ca.layers(), 16);
            for seg in &ca.segments {
                assert!(seg.layers_per_stage >= 1);
            }
        }
        // Single-type assignments appear too (ordering subsets).
        assert!(all.iter().any(|ca| ca.segments.len() == 1));
        assert!(all.iter().any(|ca| ca.segments.len() == 2));
    }

    #[test]
    fn exhaustive_matches_closed_form_small() {
        // P=2 stages, both types must appear in order (A,B): m=(1,1),
        // n1+n2=N → N−1 assignments; ordering (B,A) doubles; single-type
        // orderings: m=(2), 2·n=N → N/2 valid iff N even (1 each).
        let solver = HeteroSolver::default();
        let budgets = budgets2();
        let n = 10usize;
        let all = solver.enumerate_exhaustive(n, 2, &budgets);
        let two_seg = all.iter().filter(|c| c.segments.len() == 2).count();
        let one_seg = all.iter().filter(|c| c.segments.len() == 1).count();
        assert_eq!(two_seg, 2 * (n - 1));
        assert_eq!(one_seg, 2); // n=10 even → n1=5 for each type
    }

    #[test]
    fn pruned_subset_of_exhaustive() {
        let solver = HeteroSolver::default();
        let budgets = budgets2();
        let ex = solver.enumerate_exhaustive(32, 4, &budgets);
        let pr = solver.enumerate_pruned(32, 4, &budgets);
        assert!(!pr.is_empty());
        assert!(pr.len() < ex.len());
        let key = |c: &ClusterAssignment| format!("{:?}", c.segments);
        let exset: std::collections::BTreeSet<String> = ex.iter().map(key).collect();
        for c in &pr {
            assert!(exset.contains(&key(c)), "pruned emitted non-valid assignment {c:?}");
        }
    }

    #[test]
    fn pruned_seeds_follow_speed() {
        // H100 ~3× faster than A800: in a 2-segment split with equal stage
        // counts, H100 segments should carry more layers in the pruned set.
        let solver = HeteroSolver { prune_radius: 0, max_assignments: 10_000 };
        let budgets = budgets2(); // [a800, h100]
        let pr = solver.enumerate_pruned(64, 2, &budgets);
        let mixed: Vec<_> = pr.iter().filter(|c| c.segments.len() == 2).collect();
        assert!(!mixed.is_empty());
        for ca in mixed {
            let (a_layers, h_layers): (usize, usize) = {
                let cat = GpuCatalog::builtin();
                let h = cat.find("h100").unwrap();
                let mut a_l = 0;
                let mut h_l = 0;
                for s in &ca.segments {
                    if s.gpu == h {
                        h_l = s.layers_per_stage;
                    } else {
                        a_l = s.layers_per_stage;
                    }
                }
                (a_l, h_l)
            };
            assert!(h_layers > a_layers, "h100 {h_layers} vs a800 {a_layers}");
        }
    }

    #[test]
    fn budgets_divide_caps() {
        let cat = GpuCatalog::builtin();
        let b = HeteroSolver::budgets(&cat, &[(0, 100)], 4, 8);
        assert_eq!(b[0].max_stages, 3); // 100 / 32
    }
}
