//! Price books: per-GPU-type cloud rates for the money-saving search.
//!
//! The paper's mode 3 (§3.6) prices a training run at a single fixed hourly
//! fee per GPU. Real clusters are billed from a *rate card*: every GPU type
//! has an on-demand rate and a (much cheaper, preemptible) spot rate, and
//! some providers scale prices by time of day. [`PriceBook`] models that
//! card and replaces the scalar `price_per_hour` lookup inside
//! [`crate::pareto::MoneyModel`], which is what makes the heterogeneous
//! money search ([`crate::strategy::GpuPoolMode::HeteroCost`]) meaningful:
//! mixing cheap older GPUs with a few fast ones only pays off when each
//! type is billed at its own rate.
//!
//! Like the hardware profile (`data/hw_profile.json` ↔
//! [`crate::gpu::GpuCatalog`]), the book is loadable from
//! `data/price_book.json` with a compiled-in default that must mirror the
//! file value-for-value; `python/compile/pricing.py` reads the same file so
//! the two languages stay in lockstep.

use crate::json::Value;
use crate::{AstraError, Result};

/// Rates for one GPU type, USD per GPU-hour.
#[derive(Debug, Clone, PartialEq)]
pub struct PriceEntry {
    /// GPU name as in the catalog (`a800`, `h100`, …) — books key by name,
    /// not index, so a reordered catalog cannot shuffle rates.
    pub gpu: String,
    pub on_demand_per_hour: f64,
    /// Preemptible/spot rate; providers typically quote ~40% of on-demand.
    pub spot_per_hour: f64,
}

/// A rate card: per-type on-demand + spot rates with optional time-of-day
/// multipliers. Entries are kept sorted by GPU name so serialization,
/// fingerprinting and iteration are canonical.
#[derive(Debug, Clone, PartialEq)]
pub struct PriceBook {
    entries: Vec<PriceEntry>,
    /// 24 hourly multipliers on the base rate (flat pricing = all 1.0).
    pub tod_multipliers: Vec<f64>,
    /// Bill at spot rates instead of on-demand.
    pub use_spot: bool,
    /// Hour of day `0..24` the run is priced at; `None` = flat (×1.0).
    pub hour: Option<usize>,
}

impl Default for PriceBook {
    fn default() -> Self {
        PriceBook::builtin()
    }
}

impl PriceBook {
    /// Empty book (all lookups miss; callers fall back to catalog rates).
    pub fn empty() -> PriceBook {
        PriceBook {
            entries: Vec::new(),
            tod_multipliers: vec![1.0; 24],
            use_spot: false,
            hour: None,
        }
    }

    /// Compiled-in card mirroring `data/price_book.json`. On-demand rates
    /// equal the catalog's `price_per_hour` (so flat on-demand pricing
    /// reproduces the pre-book behavior bit-for-bit); spot is 40% of
    /// on-demand across the board.
    pub fn builtin() -> PriceBook {
        let mut book = PriceBook::empty();
        for (gpu, on_demand, spot) in [
            ("a100", 3.00, 1.20),
            ("a800", 2.60, 1.04),
            ("h100", 4.10, 1.64),
            ("h800", 3.40, 1.36),
            ("v100", 1.50, 0.60),
        ] {
            book.upsert(PriceEntry {
                gpu: gpu.to_string(),
                on_demand_per_hour: on_demand,
                spot_per_hour: spot,
            });
        }
        book
    }

    /// Load from the `data/price_book.json` shape:
    ///
    /// ```text
    /// {"gpus": [{"name": "a800", "on_demand_per_hour": 2.6,
    ///            "spot_per_hour": 1.04}, …],
    ///  "tod_multipliers": [1.0, …24…]}   // optional
    /// ```
    pub fn from_json(v: &Value) -> Result<PriceBook> {
        let mut book = PriceBook::empty();
        for g in v.req_arr("gpus")? {
            let on_demand = g.req_f64("on_demand_per_hour")?;
            let spot = g.opt_f64("spot_per_hour").unwrap_or(on_demand);
            book.upsert(PriceEntry {
                gpu: g.req_str("name")?.to_string(),
                on_demand_per_hour: on_demand,
                spot_per_hour: spot,
            });
        }
        if v.get("tod_multipliers").is_some() {
            book.tod_multipliers = v.req_f64_arr("tod_multipliers")?;
        }
        book.validate()?;
        Ok(book)
    }

    pub fn from_file(path: &std::path::Path) -> Result<PriceBook> {
        Self::from_json(&crate::json::from_file(path)?)
    }

    /// Insert or replace an entry, keeping the book sorted by GPU name.
    pub fn upsert(&mut self, entry: PriceEntry) {
        match self.entries.binary_search_by(|e| e.gpu.as_str().cmp(entry.gpu.as_str())) {
            Ok(i) => self.entries[i] = entry,
            Err(i) => self.entries.insert(i, entry),
        }
    }

    /// Entries, sorted by GPU name.
    pub fn entries(&self) -> &[PriceEntry] {
        &self.entries
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn get(&self, gpu_name: &str) -> Option<&PriceEntry> {
        self.entries
            .binary_search_by(|e| e.gpu.as_str().cmp(gpu_name))
            .ok()
            .map(|i| &self.entries[i])
    }

    /// Time-of-day multiplier in effect (`1.0` when `hour` is unset).
    pub fn tod_multiplier(&self) -> f64 {
        match self.hour {
            Some(h) => self.tod_multipliers.get(h).copied().unwrap_or(1.0),
            None => 1.0,
        }
    }

    /// Effective USD per GPU-hour for a type: spot or on-demand rate times
    /// the time-of-day multiplier. `None` for types the book does not list.
    pub fn rate_per_hour(&self, gpu_name: &str) -> Option<f64> {
        self.get(gpu_name).map(|e| {
            let base = if self.use_spot { e.spot_per_hour } else { e.on_demand_per_hour };
            base * self.tod_multiplier()
        })
    }

    pub fn rate_per_second(&self, gpu_name: &str) -> Option<f64> {
        self.rate_per_hour(gpu_name).map(|r| r / 3600.0)
    }

    /// Structural sanity: positive finite rates, spot ≤ on-demand, 24
    /// positive multipliers, hour in range.
    pub fn validate(&self) -> Result<()> {
        let fail = |m: String| Err(AstraError::Config(m));
        for e in &self.entries {
            if !(e.on_demand_per_hour.is_finite() && e.on_demand_per_hour > 0.0) {
                return fail(format!("'{}': bad on-demand rate {}", e.gpu, e.on_demand_per_hour));
            }
            if !(e.spot_per_hour.is_finite() && e.spot_per_hour > 0.0) {
                return fail(format!("'{}': bad spot rate {}", e.gpu, e.spot_per_hour));
            }
            if e.spot_per_hour > e.on_demand_per_hour {
                return fail(format!("'{}': spot rate exceeds on-demand", e.gpu));
            }
        }
        if self.tod_multipliers.len() != 24 {
            return fail(format!("{} tod multipliers (need 24)", self.tod_multipliers.len()));
        }
        if self.tod_multipliers.iter().any(|m| !(m.is_finite() && *m > 0.0)) {
            return fail("non-positive tod multiplier".into());
        }
        if let Some(h) = self.hour {
            if h >= 24 {
                return fail(format!("hour {h} out of range 0..24"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::GpuCatalog;

    #[test]
    fn builtin_covers_catalog_at_catalog_rates() {
        let book = PriceBook::builtin();
        let cat = GpuCatalog::builtin();
        assert_eq!(book.len(), cat.len());
        for spec in cat.all() {
            let e = book.get(&spec.name).unwrap_or_else(|| panic!("{} unlisted", spec.name));
            // On-demand mirrors the catalog so flat pricing is unchanged.
            assert_eq!(e.on_demand_per_hour, spec.price_per_hour, "{}", spec.name);
            assert!(e.spot_per_hour < e.on_demand_per_hour);
        }
        book.validate().unwrap();
    }

    #[test]
    fn spot_and_tod_change_rates() {
        let mut book = PriceBook::builtin();
        let flat = book.rate_per_hour("a800").unwrap();
        assert_eq!(flat, 2.60);
        book.use_spot = true;
        assert_eq!(book.rate_per_hour("a800").unwrap(), 1.04);
        book.use_spot = false;
        book.tod_multipliers[3] = 0.5;
        book.hour = Some(3);
        assert_eq!(book.rate_per_hour("a800").unwrap(), 1.30);
        book.hour = None;
        assert_eq!(book.rate_per_hour("a800").unwrap(), 2.60);
        assert_eq!(book.rate_per_second("a800").unwrap(), 2.60 / 3600.0);
        assert!(book.rate_per_hour("b200").is_none());
    }

    #[test]
    fn upsert_keeps_sorted_and_replaces() {
        let mut book = PriceBook::empty();
        for name in ["h100", "a800", "v100"] {
            book.upsert(PriceEntry {
                gpu: name.to_string(),
                on_demand_per_hour: 1.0,
                spot_per_hour: 0.5,
            });
        }
        let names: Vec<&str> = book.entries().iter().map(|e| e.gpu.as_str()).collect();
        assert_eq!(names, vec!["a800", "h100", "v100"]);
        book.upsert(PriceEntry {
            gpu: "h100".to_string(),
            on_demand_per_hour: 9.0,
            spot_per_hour: 3.0,
        });
        assert_eq!(book.len(), 3);
        assert_eq!(book.get("h100").unwrap().on_demand_per_hour, 9.0);
    }

    #[test]
    fn validate_rejects_bad_books() {
        let mut bad = PriceBook::builtin();
        bad.tod_multipliers.pop();
        assert!(bad.validate().is_err(), "23 multipliers");

        let mut bad = PriceBook::builtin();
        bad.tod_multipliers[0] = 0.0;
        assert!(bad.validate().is_err(), "zero multiplier");

        let mut bad = PriceBook::builtin();
        bad.hour = Some(24);
        assert!(bad.validate().is_err(), "hour out of range");

        let mut bad = PriceBook::empty();
        bad.upsert(PriceEntry {
            gpu: "x".into(),
            on_demand_per_hour: 1.0,
            spot_per_hour: 2.0,
        });
        assert!(bad.validate().is_err(), "spot above on-demand");

        let mut bad = PriceBook::empty();
        bad.upsert(PriceEntry {
            gpu: "x".into(),
            on_demand_per_hour: f64::NAN,
            spot_per_hour: 0.5,
        });
        assert!(bad.validate().is_err(), "NaN rate");
    }

    #[test]
    fn json_roundtrip_and_defaults() {
        let v = crate::json::parse(
            r#"{"gpus":[{"name":"a800","on_demand_per_hour":2.6},
                        {"name":"h100","on_demand_per_hour":4.1,"spot_per_hour":1.64}]}"#,
        )
        .unwrap();
        let book = PriceBook::from_json(&v).unwrap();
        // Missing spot defaults to on-demand; missing multipliers to flat.
        assert_eq!(book.get("a800").unwrap().spot_per_hour, 2.6);
        assert_eq!(book.tod_multipliers, vec![1.0; 24]);
        assert_eq!(book.rate_per_hour("h100").unwrap(), 4.1);
    }

    /// `from_json` must reject malformed `tod_multipliers` arrays at load
    /// time — short, long, NaN and non-positive values all fail validation
    /// before the book can reach the money model (re: frontier repricing,
    /// where a bad multiplier would otherwise poison every cached curve).
    #[test]
    fn from_json_rejects_bad_tod_multipliers() {
        let gpus = r#""gpus":[{"name":"a800","on_demand_per_hour":2.6}]"#;
        let ok = |mults: &str| {
            let v = crate::json::parse(&format!("{{{gpus},\"tod_multipliers\":{mults}}}"))
                .unwrap();
            PriceBook::from_json(&v)
        };

        let flat24: Vec<String> = (0..24).map(|_| "1.0".to_string()).collect();
        assert!(ok(&format!("[{}]", flat24.join(","))).is_ok(), "24 flat multipliers");

        let short23 = format!("[{}]", flat24[..23].join(","));
        let err = ok(&short23).unwrap_err().to_string();
        assert!(err.contains("23"), "short array names its length: {err}");

        let mut long25 = flat24.clone();
        long25.push("1.0".to_string());
        let err = ok(&format!("[{}]", long25.join(","))).unwrap_err().to_string();
        assert!(err.contains("25"), "long array names its length: {err}");

        // RFC 8259 has no NaN literal, so inject one past the parser: the
        // validator must still catch it.
        let mut v = crate::json::parse(&format!(
            "{{{gpus},\"tod_multipliers\":[{}]}}",
            flat24.join(",")
        ))
        .unwrap();
        if let crate::json::Value::Obj(m) = &mut v {
            if let Some(crate::json::Value::Arr(a)) = m.get_mut("tod_multipliers") {
                a[7] = crate::json::Value::Num(f64::NAN);
            }
        }
        let err = PriceBook::from_json(&v).unwrap_err().to_string();
        assert!(err.contains("non-positive"), "NaN multiplier rejected: {err}");

        let mut with_neg = flat24.clone();
        with_neg[11] = "-0.5".to_string();
        let err = ok(&format!("[{}]", with_neg.join(","))).unwrap_err().to_string();
        assert!(err.contains("non-positive"), "negative multiplier rejected: {err}");

        let mut with_zero = flat24;
        with_zero[0] = "0.0".to_string();
        assert!(ok(&format!("[{}]", with_zero.join(","))).is_err(), "zero multiplier");
    }

    #[test]
    fn json_matches_builtin() {
        // data/price_book.json must agree with the compiled-in card. The
        // manifest may sit at the repo root or inside rust/; probe both
        // (plus $ASTRA_DATA) and skip loudly if the file is absent.
        let manifest = std::path::Path::new(env!("CARGO_MANIFEST_DIR"));
        let mut candidates = vec![
            manifest.join("data/price_book.json"),
            manifest.join("../data/price_book.json"),
            manifest.join("rust/data/price_book.json"),
        ];
        if let Ok(d) = std::env::var("ASTRA_DATA") {
            candidates.insert(0, std::path::Path::new(&d).join("price_book.json"));
        }
        let Some(path) = candidates.into_iter().find(|p| p.exists()) else {
            eprintln!("SKIP: data/price_book.json not found near {manifest:?}");
            return;
        };
        let from_file = PriceBook::from_file(&path).unwrap();
        assert_eq!(from_file, PriceBook::builtin());
    }
}
