//! PJRT runtime: load and execute the AOT-compiled scorer.
//!
//! Wraps the `xla` crate (`PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `compile` → `execute`) to run
//! `artifacts/scorer.hlo.txt` from the Layer-3 hot path. Python never runs
//! here — the HLO text was produced once by `make artifacts`
//! (`python/compile/aot.py`), which also wrote `scorer_meta.json` pinning
//! the batch geometry; we validate it against the crate's compiled-in
//! [`crate::cost::features`] layout at load time.
//!
//! Interchange is HLO *text*, not serialized protos: jax ≥ 0.5 emits
//! 64-bit instruction ids that xla_extension 0.5.1 rejects, while the text
//! parser reassigns ids (see /opt/xla-example/README.md).

use crate::cost::features::{FG, FS, OUT, PMAX};
use crate::{AstraError, Result};
use std::path::Path;

/// A compiled scorer executable plus its batch geometry.
pub struct ScorerRuntime {
    exe: xla::PjRtLoadedExecutable,
    /// Strategies per execute call (HLO shapes are static).
    pub batch: usize,
}

impl ScorerRuntime {
    /// Load `scorer.hlo.txt` + `scorer_meta.json` from the artifacts dir.
    pub fn load(dir: &Path) -> Result<ScorerRuntime> {
        let meta = crate::json::from_file(&dir.join("scorer_meta.json"))?;
        let batch = meta
            .get("batch")
            .and_then(crate::json::Value::as_usize)
            .ok_or_else(|| AstraError::Runtime("scorer_meta.json: missing batch".into()))?;
        for (key, expect) in [("pmax", PMAX), ("fs", FS), ("fg", FG), ("out", OUT)] {
            let got = meta
                .get(key)
                .and_then(crate::json::Value::as_usize)
                .ok_or_else(|| AstraError::Runtime(format!("scorer_meta.json: missing {key}")))?;
            if got != expect {
                return Err(AstraError::Runtime(format!(
                    "scorer geometry mismatch: {key}={got} in artifacts but crate expects {expect} — re-run `make artifacts`"
                )));
            }
        }
        let hlo_path = dir.join("scorer.hlo.txt");
        let client = xla::PjRtClient::cpu()
            .map_err(|e| AstraError::Runtime(format!("PJRT cpu client: {e}")))?;
        let proto = xla::HloModuleProto::from_text_file(
            hlo_path
                .to_str()
                .ok_or_else(|| AstraError::Runtime("non-utf8 artifacts path".into()))?,
        )
        .map_err(|e| AstraError::Runtime(format!("parse {hlo_path:?}: {e}")))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .map_err(|e| AstraError::Runtime(format!("compile scorer: {e}")))?;
        crate::log_info!("scorer loaded: batch={batch} pmax={PMAX} fs={FS} fg={FG}");
        Ok(ScorerRuntime { exe, batch })
    }

    /// Execute one padded batch. Inputs must be exactly
    /// `batch·PMAX·FS`, `batch·PMAX` and `batch·FG` long; returns
    /// `batch` rows of `[step_time, pipeline_time, dp_time, extra_time]`.
    pub fn execute(
        &self,
        stage_feats: &[f32],
        stage_mask: &[f32],
        strat_feats: &[f32],
    ) -> Result<Vec<[f32; OUT]>> {
        let b = self.batch;
        if stage_feats.len() != b * PMAX * FS
            || stage_mask.len() != b * PMAX
            || strat_feats.len() != b * FG
        {
            return Err(AstraError::Runtime(format!(
                "scorer input shape mismatch: got {}/{}/{} want {}/{}/{}",
                stage_feats.len(),
                stage_mask.len(),
                strat_feats.len(),
                b * PMAX * FS,
                b * PMAX,
                b * FG
            )));
        }
        let rt = |e: xla::Error| AstraError::Runtime(format!("scorer execute: {e}"));
        let x_sf = xla::Literal::vec1(stage_feats)
            .reshape(&[b as i64, PMAX as i64, FS as i64])
            .map_err(rt)?;
        let x_mask =
            xla::Literal::vec1(stage_mask).reshape(&[b as i64, PMAX as i64]).map_err(rt)?;
        let x_gf = xla::Literal::vec1(strat_feats).reshape(&[b as i64, FG as i64]).map_err(rt)?;
        let result = self.exe.execute::<xla::Literal>(&[x_sf, x_mask, x_gf]).map_err(rt)?[0][0]
            .to_literal_sync()
            .map_err(rt)?;
        // aot.py lowers with return_tuple=True → 1-tuple of f32[b, OUT].
        let out = result.to_tuple1().map_err(rt)?;
        let flat = out.to_vec::<f32>().map_err(rt)?;
        if flat.len() != b * OUT {
            return Err(AstraError::Runtime(format!(
                "scorer output length {} != {}",
                flat.len(),
                b * OUT
            )));
        }
        Ok(flat
            .chunks_exact(OUT)
            .map(|c| {
                let mut row = [0.0f32; OUT];
                row.copy_from_slice(c);
                row
            })
            .collect())
    }
}

/// Default artifacts directory: `$ASTRA_ARTIFACTS` or `<manifest>/artifacts`.
pub fn artifacts_dir() -> std::path::PathBuf {
    if let Ok(d) = std::env::var("ASTRA_ARTIFACTS") {
        return d.into();
    }
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// True if the scorer artifacts exist (benches skip the HLO engine
/// otherwise instead of failing).
pub fn artifacts_present() -> bool {
    let d = artifacts_dir();
    d.join("scorer.hlo.txt").exists() && d.join("scorer_meta.json").exists()
}
