//! Hardware-truth efficiency model.
//!
//! The paper fits its η factors (Eq. 25/26) on *measured* cluster data. We
//! have no cluster, so this module is the synthetic "physics" that plays the
//! role of the real hardware (DESIGN.md §3): principled saturation curves —
//! launch-overhead-limited small ops, skinny-GEMM penalty, roofline
//! memory-bound clamp for compute; latency-vs-bandwidth saturation for
//! collectives. The discrete-event simulator consumes these curves directly
//! ("measurement"); the GBDT is trained on noisy samples of them
//! (`python/compile/effdata.py` mirrors the formulas — kept in lockstep by
//! `rust/tests/crosscheck_hw.rs` against `artifacts/eff_samples.json`).

use crate::gpu::GpuSpec;

/// Number of features fed to the computation-efficiency forest.
pub const COMP_FEATURES: usize = 6;
/// Number of features fed to the communication-efficiency forest.
pub const COMM_FEATURES: usize = 4;

/// A dense GEMM workload descriptor (per-GPU shard shapes).
#[derive(Debug, Clone, Copy)]
pub struct Gemm {
    pub m: f64,
    pub n: f64,
    pub k: f64,
}

impl Gemm {
    pub fn new(m: f64, n: f64, k: f64) -> Self {
        Gemm { m, n, k }
    }

    pub fn flops(&self) -> f64 {
        2.0 * self.m * self.n * self.k
    }

    /// Bytes moved assuming bf16 operands/output, one pass.
    pub fn bytes(&self) -> f64 {
        2.0 * (self.m * self.k + self.k * self.n + self.m * self.n)
    }

    pub fn min_dim(&self) -> f64 {
        self.m.min(self.n).min(self.k)
    }

    /// Arithmetic intensity (flop/byte).
    pub fn intensity(&self) -> f64 {
        self.flops() / self.bytes().max(1.0)
    }
}

/// Ground-truth computation efficiency η_comp ∈ (0, 1] for an op of `flops`
/// total work, smallest GEMM dimension `min_dim`, arithmetic intensity
/// `intensity`, on GPU `spec`.
pub fn eta_comp(spec: &GpuSpec, flops: f64, min_dim: f64, intensity: f64) -> f64 {
    let e = &spec.eff;
    // Launch-overhead saturation: an op must amortize the fixed kernel cost.
    let f_half = spec.peak_flops() * e.launch_overhead_s;
    let sat = flops / (flops + f_half);
    // Skinny-GEMM penalty ramps linearly below the tile-friendly dimension.
    let skinny = if min_dim >= e.skinny_dim {
        1.0
    } else {
        e.skinny_penalty + (1.0 - e.skinny_penalty) * (min_dim / e.skinny_dim)
    };
    // Roofline clamp: memory-bound ops cannot reach peak FLOPs.
    let roof = (intensity / e.mem_bound_intensity).min(1.0);
    (e.util_max * sat * skinny * roof).clamp(1e-4, 1.0)
}

/// Ground-truth communication efficiency η_comm ∈ (0, 1] for a collective
/// moving `bytes` per rank over links of `bw_gbs` with `participants` ranks.
pub fn eta_comm(spec: &GpuSpec, bytes: f64, bw_gbs: f64, participants: f64) -> f64 {
    let e = &spec.eff;
    // Latency term grows with group size (ring has n-1 sequential steps).
    let b_half = bw_gbs * 1e9 * e.comm_latency_s * participants.max(1.0);
    let sat = bytes / (bytes + b_half);
    (e.comm_eff_max * sat).clamp(1e-4, 1.0)
}

/// Feature vector for the computation forest. MUST stay in lockstep with
/// `python/compile/effdata.py::comp_features`.
pub fn comp_features(spec: &GpuSpec, flops: f64, min_dim: f64, intensity: f64) -> [f64; COMP_FEATURES] {
    [
        flops.max(1.0).log10(),
        min_dim.max(1.0).log10(),
        intensity.max(1e-3).log10(),
        spec.peak_tflops_bf16 / 1000.0,
        spec.hbm_gbs / 1000.0,
        spec.eff.util_max,
    ]
}

/// Feature vector for the communication forest. MUST stay in lockstep with
/// `python/compile/effdata.py::comm_features`.
pub fn comm_features(spec: &GpuSpec, bytes: f64, bw_gbs: f64, participants: f64) -> [f64; COMM_FEATURES] {
    [
        bytes.max(1.0).log10(),
        bw_gbs.max(1e-3).log10(),
        participants.max(1.0).log10(),
        spec.eff.comm_eff_max,
    ]
}

/// Append one η_comp feature row, as f32, to a caller-owned scratch
/// buffer (the batched η path packs many rows before one kernel call).
/// Routes through [`comp_features`] so the feature definition — which must
/// stay in lockstep with `python/compile/effdata.py` — lives in exactly
/// one place, and the f64→f32 cast matches the scalar η path's cast.
pub fn comp_features_into(
    spec: &GpuSpec,
    flops: f64,
    min_dim: f64,
    intensity: f64,
    out: &mut Vec<f32>,
) {
    let f = comp_features(spec, flops, min_dim, intensity);
    out.extend(f.iter().map(|&v| v as f32));
}

/// Append one η_comm feature row, as f32; see [`comp_features_into`].
pub fn comm_features_into(
    spec: &GpuSpec,
    bytes: f64,
    bw_gbs: f64,
    participants: f64,
    out: &mut Vec<f32>,
) {
    let f = comm_features(spec, bytes, bw_gbs, participants);
    out.extend(f.iter().map(|&v| v as f32));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::GpuCatalog;

    fn a800() -> GpuSpec {
        let c = GpuCatalog::builtin();
        c.spec(c.find("a800").unwrap()).clone()
    }

    #[test]
    fn eta_comp_monotone_in_size() {
        let g = a800();
        let small = eta_comp(&g, 1e6, 512.0, 200.0);
        let big = eta_comp(&g, 1e12, 512.0, 200.0);
        assert!(big > small);
        assert!(big <= g.eff.util_max + 1e-12);
    }

    #[test]
    fn eta_comp_penalizes_skinny() {
        let g = a800();
        let fat = eta_comp(&g, 1e11, 512.0, 200.0);
        let thin = eta_comp(&g, 1e11, 16.0, 200.0);
        assert!(thin < fat);
    }

    #[test]
    fn eta_comp_memory_bound_clamp() {
        let g = a800();
        let compute_bound = eta_comp(&g, 1e11, 512.0, 400.0);
        let mem_bound = eta_comp(&g, 1e11, 512.0, 10.0);
        assert!(mem_bound < compute_bound * 0.3);
    }

    #[test]
    fn eta_comm_latency_saturation() {
        let g = a800();
        let tiny = eta_comm(&g, 1e4, 400.0, 8.0);
        let huge = eta_comm(&g, 1e9, 400.0, 8.0);
        assert!(huge > 5.0 * tiny);
        assert!(huge <= g.eff.comm_eff_max);
        // Larger groups are less efficient at fixed size.
        assert!(eta_comm(&g, 1e7, 400.0, 64.0) < eta_comm(&g, 1e7, 400.0, 8.0));
    }

    #[test]
    fn bounds_hold_everywhere() {
        let g = a800();
        for flops in [1.0, 1e6, 1e12, 1e15] {
            for d in [1.0, 64.0, 4096.0] {
                for i in [0.1, 10.0, 1000.0] {
                    let e = eta_comp(&g, flops, d, i);
                    assert!(e > 0.0 && e <= 1.0);
                }
            }
        }
    }

    #[test]
    fn gemm_descriptor() {
        let g = Gemm::new(4096.0, 4096.0, 4096.0);
        assert_eq!(g.flops(), 2.0 * 4096f64.powi(3));
        assert_eq!(g.min_dim(), 4096.0);
        // Large cube GEMM is strongly compute bound.
        assert!(g.intensity() > 100.0);
    }
}
