//! Search-request modes (§3.2 input integration, Eq. 1–3 + the hetero
//! money mode) and their validation.
//!
//! A [`SearchRequest`] is pure input: a model plus a [`GpuPoolMode`]. The
//! named constructors resolve GPU names against the builtin catalog and
//! reject bad budgets / unknown types as recoverable [`AstraError::Config`]
//! errors (service requests must never abort the process). Everything
//! downstream of a request is the plan compiler ([`super::plan`]): requests
//! never carry engine state.

use crate::gpu::GpuCatalog;
use crate::model::ModelSpec;
use crate::strategy::GpuPoolMode;
use crate::{AstraError, Result};

/// A search request: model + GPU-pool mode (§3.2 input integration, Eq. 7).
#[derive(Debug, Clone)]
pub struct SearchRequest {
    pub mode: GpuPoolMode,
    pub model: ModelSpec,
}

impl SearchRequest {
    /// Mode 1 (Eq. 1): one GPU type, fixed count. Unknown GPU names are a
    /// recoverable [`AstraError::Config`] (service requests must not abort
    /// the process).
    pub fn homogeneous(gpu_name: &str, count: usize, model: ModelSpec) -> Result<SearchRequest> {
        let catalog = GpuCatalog::builtin();
        let gpu = catalog.find(gpu_name)?;
        Ok(SearchRequest { mode: GpuPoolMode::Homogeneous { gpu, count }, model })
    }

    /// Mode 2 (Eq. 2): total cluster size + per-type caps, named by GPU.
    /// Caps are a per-type *map*: duplicate entries of the same type merge
    /// by summation (matching the JSON wire form, which is an object).
    pub fn heterogeneous(
        caps: &[(&str, usize)],
        total: usize,
        model: ModelSpec,
    ) -> Result<SearchRequest> {
        let catalog = GpuCatalog::builtin();
        let mut resolved: Vec<(crate::gpu::GpuType, usize)> = Vec::with_capacity(caps.len());
        for &(name, cap) in caps {
            resolved.push((catalog.find(name)?, cap));
        }
        let resolved = crate::strategy::merge_caps(resolved);
        Ok(SearchRequest { mode: GpuPoolMode::Heterogeneous { total, caps: resolved }, model })
    }

    /// Mode 3 (Eq. 3): count sweep under a money ceiling. NaN and
    /// non-positive budgets are recoverable [`AstraError::Config`]s, like
    /// the unknown-GPU paths (`+inf` means "no ceiling" and is fine).
    pub fn cost(
        gpu_name: &str,
        max_count: usize,
        max_money: f64,
        model: ModelSpec,
    ) -> Result<SearchRequest> {
        let catalog = GpuCatalog::builtin();
        let gpu = catalog.find(gpu_name)?;
        validate_budget(max_money)?;
        Ok(SearchRequest { mode: GpuPoolMode::Cost { gpu, max_count, max_money }, model })
    }

    /// Heterogeneous money search: per-type caps (a map — duplicate names
    /// merge by summation) swept under a money ceiling.
    pub fn hetero_cost(
        caps: &[(&str, usize)],
        max_money: f64,
        model: ModelSpec,
    ) -> Result<SearchRequest> {
        let catalog = GpuCatalog::builtin();
        validate_budget(max_money)?;
        let mut resolved: Vec<(crate::gpu::GpuType, usize)> = Vec::with_capacity(caps.len());
        for &(name, cap) in caps {
            resolved.push((catalog.find(name)?, cap));
        }
        let resolved = crate::strategy::merge_caps(resolved);
        if resolved.iter().map(|&(_, c)| c).sum::<usize>() < 2 {
            return Err(AstraError::Config("hetero-cost caps admit fewer than 2 GPUs".into()));
        }
        Ok(SearchRequest { mode: GpuPoolMode::HeteroCost { caps: resolved, max_money }, model })
    }

    /// Frontier mode: the hetero-cost sweep with no budget and no money
    /// pruning — the result is the full (throughput, USD) Pareto frontier
    /// over mixed pools, re-priceable without re-search. Caps are a map
    /// like [`Self::hetero_cost`]'s (duplicate names merge by summation).
    pub fn frontier(caps: &[(&str, usize)], model: ModelSpec) -> Result<SearchRequest> {
        let catalog = GpuCatalog::builtin();
        let mut resolved: Vec<(crate::gpu::GpuType, usize)> = Vec::with_capacity(caps.len());
        for &(name, cap) in caps {
            resolved.push((catalog.find(name)?, cap));
        }
        let resolved = crate::strategy::merge_caps(resolved);
        if resolved.iter().map(|&(_, c)| c).sum::<usize>() < 2 {
            return Err(AstraError::Config("frontier caps admit fewer than 2 GPUs".into()));
        }
        Ok(SearchRequest { mode: GpuPoolMode::Frontier { caps: resolved }, model })
    }
}

/// Money ceilings must be positive and not NaN (`+inf` = unlimited). Shared
/// by the request constructors, the wire parser and the plan compiler so
/// hand-built modes cannot smuggle a bad budget past validation.
pub fn validate_budget(max_money: f64) -> Result<()> {
    if max_money.is_nan() || max_money <= 0.0 {
        return Err(AstraError::Config(format!(
            "max_money must be a positive number of USD (got {max_money})"
        )));
    }
    Ok(())
}
