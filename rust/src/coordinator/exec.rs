//! The streaming executor: runs any compiled [`SearchPlan`] through the
//! one fused expand → rule-filter → memory-filter → score pipeline, for
//! **both** scoring engines.
//!
//! ## Invariants
//!
//! * **Deterministic at any parallelism.** The unit of work is a
//!   [`PoolSpec`]; `par_for_indices` returns pool outcomes in task order
//!   whatever the worker count, and every result-relevant decision replays
//!   serially, so the canonical report bytes are identical across worker
//!   counts, wave schedules and repeat runs (pinned by `determinism.rs` and
//!   `diff_streaming.rs`).
//!
//! * **Snapshot–speculate–replay.** Rounds are processed in speculative
//!   waves: pools are admitted against a *snapshot* of the dominance
//!   frontier taken at wave start (phase 1), every speculated pool streams
//!   through the fused pipeline concurrently (phase 2), and the admissions
//!   replay serially against the true running frontier in (round, pool)
//!   order (phase 3), discarding outcomes the frontier rejects. Snapshot
//!   coverage is a subset of every later frontier's coverage, so
//!   speculation only ever *over*-admits — the replay has an outcome for
//!   every accepted pool and the counts, `pruned_pools`, frontier and picks
//!   are byte-identical to the strictly serial sweep. The wave grows by one
//!   after a zero-waste replay (up to `wave_max`) and resets on waste; the
//!   schedule is a pure function of the deterministic frontier evolution
//!   and can never reach the report.
//!
//! * **Audit from the replay, never from speculation.** When a search is
//!   audited ([`super::audit`]), the per-pool decision records are
//!   assembled inside the phase-3 serial replay — the same place the
//!   counting admissions happen — so the audit's decisions and certifying
//!   evidence inherit the report's determinism at any worker count or wave
//!   schedule. Speculation-waste accounting ([`super::AuditWave`]) and
//!   per-pool memo counters are recorded too, but flagged as
//!   load-/schedule-dependent observability: the canonical
//!   [`crate::report::audit_json`] excludes them.
//!
//! * **Serial oracle.** `EngineConfig::streaming == false` does not select
//!   a second pipeline (the pre-refactor reference path is gone): it
//!   compiles the same plan with a pinned `1/1` wave and executes with one
//!   worker. The differential harness uses that configuration as its
//!   oracle.
//!
//! * **Both engines, one pipeline.** The native engine scores inside the
//!   fused per-pool pass through the core's [`SharedCostMemo`]. The HLO
//!   engine's PJRT executable is batch-oriented and thread-confined, so its
//!   pools are expanded/filtered on the worker pool and then **packed per
//!   pool** into padded `ScorerRuntime::batch`-row batches, executed
//!   serially on the calling thread. Per-strategy rows are independent, so
//!   per-pool packing scores exactly what whole-run packing scored;
//!   `score_hlo`'s old detour through the reference path is gone.

use super::audit::{
    AuditContender, AuditFunnel, AuditMargins, AuditPool, AuditRound, AuditWave, SearchAudit,
};
use super::plan::{plan_json, PoolSpec, SearchPlan};
use super::{
    FrontierCandidate, FrontierReport, PhaseBreakdown, ScoredStrategy, ScoringCore, SearchReport,
};
use crate::pareto::AdmitDecision;
use crate::cost::features::{pack_batch_into, PackScratch, OUT};
use crate::cost::{CostBreakdown, EtaBatchScratch, MemoStats, SharedCostMemo};
use crate::memory::MemoryModel;
use crate::model::ModelSpec;
use crate::pareto::{DominancePruner, OptimalPool, PoolEntry};
use crate::pool::par_for_indices;
use crate::resilience::CancelToken;
use crate::runtime::ScorerRuntime;
use crate::strategy::{ParallelStrategy, SearchSpace};
use crate::Result;
use std::sync::atomic::Ordering;
use std::sync::Mutex;
use std::time::Instant;

thread_local! {
    /// Per-worker η-batch scratch for the `batch_eta` scoring path.
    /// `par_for_indices` hands one worker many pools per wave but shares
    /// the closure (`Fn`), so per-worker mutable state lives here: the
    /// gather/answer buffers amortize across every pool a worker scores
    /// within a wave (worker threads are scoped per wave).
    static ETA_SCRATCH: std::cell::RefCell<EtaBatchScratch> =
        std::cell::RefCell::new(EtaBatchScratch::default());
}

/// Outcome of streaming one pool. Counts and scored strategies are
/// deterministic (pure functions of the pool); the wall-second fields are
/// per-worker accumulations used only to apportion the report's search vs
/// simulation times.
#[derive(Default)]
struct PoolOutcome {
    generated: usize,
    rule_filtered: usize,
    mem_filtered: usize,
    scored: Vec<ScoredStrategy>,
    memo: MemoStats,
    filter_secs: f64,
    /// Memory-filter slice of `filter_secs` (the phase breakdown splits the
    /// fused pass into expand+rules vs memory-filter shares).
    mem_secs: f64,
    score_secs: f64,
}

/// Pool-order filter outcome of the HLO path's parallel phase: survivors
/// are collected (not scored) because the PJRT handle is thread-confined.
struct FilteredPool {
    generated: usize,
    rule_filtered: usize,
    mem_filtered: usize,
    survivors: Vec<ParallelStrategy>,
    filter_secs: f64,
    mem_secs: f64,
}

impl ScoringCore {
    /// Execute a compiled plan. `rt` diverts scoring to the HLO engine when
    /// the config asks for it and the runtime loaded; `t0` anchors the
    /// request-to-now share (plan compilation) of "Search Time".
    ///
    /// `cancel` is polled at wave boundaries (and cheaply inside the
    /// per-pool streaming closures): a fired token unwinds with a typed
    /// [`crate::AstraError::Deadline`] and every partial wave is discarded
    /// whole — a caller gets either the complete, deterministic report or
    /// the error, never a truncated report.
    pub(crate) fn execute_plan(
        &self,
        model: &ModelSpec,
        plan: &SearchPlan,
        rt: Option<&Mutex<ScorerRuntime>>,
        t0: Instant,
        cancel: &CancelToken,
        audit: bool,
    ) -> Result<SearchReport> {
        // A pre-expired deadline never enters the pipeline (and never
        // counts as a search): the caller gets the typed error immediately.
        cancel.check()?;
        self.searches.fetch_add(1, Ordering::Relaxed);
        crate::telemetry::counter_macro!("astra_searches_total").inc();
        let hlo_rt = match (self.config.engine, rt) {
            (super::ScoringEngine::Hlo, Some(rt)) => Some(rt),
            _ => None,
        };
        // The native path scores through the model scope's shared memo; the
        // HLO path never touches the registry (its scorer has no memo).
        let memo = if hlo_rt.is_none() { Some(self.memos.for_model(model)) } else { None };
        let workers = if self.config.streaming { self.config.workers } else { 1 };

        // Flight-recorder context, computed only when the recorder is on —
        // the disabled path pays one relaxed load per guard and nothing
        // else. The plan id ties every span of this search together.
        let trace = crate::telemetry::trace::enabled();
        let plan_id = if trace {
            crate::telemetry::trace::plan_id(&crate::json::to_string(&plan_json(
                plan,
                &self.catalog,
            )))
        } else {
            String::new()
        };

        let mut pruner = DominancePruner::new(plan.budget.unwrap_or(f64::INFINITY));
        // The audit accumulator: `None` costs nothing on the unaudited
        // path; `Some` is filled exclusively inside the serial replay, so
        // audited searches stay deterministic at any parallelism.
        let mut audit_acc: Option<SearchAudit> = audit.then(SearchAudit::default);
        let base_wave = plan.wave_base.max(1);
        let wave_cap = plan.wave_max.max(base_wave);
        let mut wave = base_wave;

        let mut n_generated = 0usize;
        let mut rule_filtered = 0usize;
        let mut mem_filtered = 0usize;
        let mut phases = PhaseBreakdown { compile_secs: t0.elapsed().as_secs_f64(), ..Default::default() };
        let mut memo_stats = MemoStats::default();
        let mut scored_all: Vec<ScoredStrategy> = Vec::new();
        if trace {
            crate::telemetry::trace::emit(
                "compile",
                "search",
                phases.compile_secs,
                crate::json::Value::obj()
                    .set("plan", plan_id.as_str())
                    .set("rounds", plan.rounds.len())
                    .set("pools", plan.pool_count()),
            );
        }

        let mut next = 0usize;
        while next < plan.rounds.len() {
            // Wave boundary: the only cancellation point that can surface.
            // Everything merged so far is dropped with this early return,
            // so cancellation can never yield a partial report.
            cancel.check()?;
            let round_base = next;
            let wave_rounds = &plan.rounds[next..plan.rounds.len().min(next + wave)];
            next += wave_rounds.len();

            // Phase 1 (serial, cheap): speculative admission against a
            // frontier snapshot; admitted pools join one flat task list in
            // (round, pool) order.
            let t_gen = Instant::now();
            let snapshot = pruner.clone();
            let mut tasks: Vec<&PoolSpec> = Vec::new();
            let mut spec_flags: Vec<bool> = Vec::new();
            for round in wave_rounds {
                for pool in &round.pools {
                    let spec = !plan.prune || snapshot.would_admit(pool.ub_tput, pool.lb_usd);
                    spec_flags.push(spec);
                    if spec {
                        tasks.push(pool);
                    }
                }
            }
            let gen_secs = t_gen.elapsed().as_secs_f64();

            // Phase 2: one streaming pass over the whole wave.
            let t_run = Instant::now();
            let mut outcomes = match hlo_rt {
                Some(rt) => {
                    self.stream_pools_hlo(model, &plan.space, &tasks, rt, workers, cancel)?
                }
                None => {
                    let memo = memo.as_ref().expect("native path always has a memo");
                    self.stream_pools(model, &plan.space, &tasks, memo, workers, cancel)
                }
            };
            let wall = t_run.elapsed().as_secs_f64();

            // Phase 3: deterministic serial replay of the admissions.
            let (mut filter_busy, mut mem_busy, mut score_busy) = (0.0f64, 0.0f64, 0.0f64);
            let mut flag_idx = 0usize;
            let mut oc_idx = 0usize;
            let mut wasted = 0usize;
            let mut wave_scored = 0usize;
            for (ri, round) in wave_rounds.iter().enumerate() {
                if let Some(a) = audit_acc.as_mut() {
                    a.rounds.push(AuditRound {
                        round: round_base + ri,
                        total: round.total,
                        pools: Vec::new(),
                    });
                }
                let mut round_scored: Vec<ScoredStrategy> = Vec::new();
                for (pi, pool) in round.pools.iter().enumerate() {
                    let spec = spec_flags[flag_idx];
                    flag_idx += 1;
                    let decision = if plan.prune {
                        pruner.admit(pool.ub_tput, pool.lb_usd)
                    } else {
                        AdmitDecision::Admitted
                    };
                    let admit = decision.is_admitted();
                    if let Some(a) = audit_acc.as_mut() {
                        // Recorded for EVERY pool of the plan — admitted,
                        // pruned, or never even speculated — so the audit
                        // partitions the plan's pool set exactly.
                        let gpus = pool
                            .cluster
                            .gpus_by_type(pool.tp, pool.dp)
                            .into_iter()
                            .map(|(g, n)| (self.catalog.spec(g).name.clone(), n))
                            .collect();
                        a.rounds.last_mut().expect("round pushed above").pools.push(AuditPool {
                            pool: pi,
                            gpus,
                            tp: pool.tp,
                            dp: pool.dp,
                            ub_tput: pool.ub_tput,
                            lb_usd: pool.lb_usd,
                            decision: decision.into(),
                            funnel: None,
                        });
                    }
                    if !spec {
                        debug_assert!(!admit, "snapshot admitted what the frontier rejects");
                        continue;
                    }
                    let oc = &mut outcomes[oc_idx];
                    oc_idx += 1;
                    if let Some(a) = audit_acc.as_mut() {
                        // The funnel is captured before the scored vector
                        // is drained into the round below.
                        let p = a
                            .rounds
                            .last_mut()
                            .and_then(|r| r.pools.last_mut())
                            .expect("pool record pushed above");
                        p.funnel = Some(AuditFunnel {
                            expanded: oc.generated,
                            rules_rejected: oc.rule_filtered,
                            mem_rejected: oc.mem_filtered,
                            scored: oc.scored.len(),
                            memo_hits: oc.memo.hits,
                            memo_misses: oc.memo.misses,
                        });
                    }
                    filter_busy += oc.filter_secs;
                    mem_busy += oc.mem_secs;
                    score_busy += oc.score_secs;
                    if trace {
                        crate::telemetry::trace::emit(
                            "pool",
                            "search",
                            oc.filter_secs + oc.score_secs,
                            crate::json::Value::obj()
                                .set("plan", plan_id.as_str())
                                .set("round", round_base + ri)
                                .set("pool", pi)
                                .set("generated", oc.generated)
                                .set("scored", oc.scored.len())
                                .set("admitted", admit),
                        );
                    }
                    if !admit {
                        // Speculation waste: scored in phase 2, pruned by
                        // the true frontier — dropped so the report matches
                        // the serial sweep exactly.
                        wasted += 1;
                        continue;
                    }
                    n_generated += oc.generated;
                    rule_filtered += oc.rule_filtered;
                    mem_filtered += oc.mem_filtered;
                    memo_stats.merge(oc.memo);
                    round_scored.append(&mut oc.scored);
                }
                // Observe only after the round completes: admissions within
                // a round never see the round's own strategies. Non-pruning
                // plans skip the frontier entirely (`admit` above is never
                // reached either, so the report cannot tell).
                if plan.prune {
                    for s in &round_scored {
                        pruner.observe(s.cost.tokens_per_s, s.money_usd);
                    }
                }
                wave_scored += round_scored.len();
                scored_all.extend(round_scored);
            }
            if let Some(a) = audit_acc.as_mut() {
                // Schedule-dependent observability (a serial wave never
                // wastes); canonical views exclude this section.
                a.waves.push(AuditWave {
                    wave: a.waves.len(),
                    rounds: wave_rounds.len(),
                    speculated: tasks.len(),
                    wasted,
                });
            }

            // Split the wave's wall time across the pipeline phases in
            // proportion to worker busy time — the fused pass has no phase
            // barrier to time directly, but the phase breakdown (and so
            // search + simulate, which are derived from it) still sums to
            // the true wall clock. The HLO engine's scoring share is its
            // pack+execute time; the native engine's is memo'd evaluation.
            phases.speculate_secs += gen_secs;
            let busy = filter_busy + score_busy;
            if busy > 0.0 {
                let mem_share = mem_busy.min(filter_busy);
                phases.expand_rules_secs += wall * (filter_busy - mem_share) / busy;
                phases.mem_filter_secs += wall * mem_share / busy;
                let score_share = wall * score_busy / busy;
                if hlo_rt.is_some() {
                    phases.hlo_pack_secs += score_share;
                } else {
                    phases.score_secs += score_share;
                }
            } else {
                phases.expand_rules_secs += wall;
            }
            if trace {
                let (h, m) = (memo_stats.hits, memo_stats.misses);
                let hit_rate = if h + m > 0 { h as f64 / (h + m) as f64 } else { 0.0 };
                crate::telemetry::trace::emit(
                    "wave",
                    "search",
                    gen_secs + wall,
                    crate::json::Value::obj()
                        .set("plan", plan_id.as_str())
                        .set("round", round_base)
                        .set("rounds", wave_rounds.len())
                        .set("wave", wave)
                        .set("pools", tasks.len())
                        .set("wasted", wasted)
                        .set("scored", wave_scored)
                        .set("memo_hit_rate", hit_rate),
                );
            }
            // Adaptive schedule: grow while speculation is free, reset to
            // the base on the first wasted pool.
            wave = if wasted == 0 { (wave + 1).min(wave_cap) } else { base_wave };
        }

        // Registry + histogram recording (process-wide totals; the report
        // itself stays per-search).
        {
            use crate::telemetry::{counter_macro, gauge_macro, histogram_macro};
            counter_macro!("astra_strategies_generated_total").add(n_generated as u64);
            counter_macro!("astra_strategies_scored_total").add(scored_all.len() as u64);
            gauge_macro!("astra_memo_scopes").set(self.memos.scopes() as i64);
            histogram_macro!("astra_search_e2e_seconds").observe(phases.total_secs());
            histogram_macro!("astra_phase_compile_seconds").observe(phases.compile_secs);
            histogram_macro!("astra_phase_speculate_seconds").observe(phases.speculate_secs);
            histogram_macro!("astra_phase_expand_rules_seconds").observe(phases.expand_rules_secs);
            histogram_macro!("astra_phase_mem_filter_seconds").observe(phases.mem_filter_secs);
            histogram_macro!("astra_phase_score_seconds").observe(phases.score_secs);
            histogram_macro!("astra_phase_hlo_pack_seconds").observe(phases.hlo_pack_secs);
        }
        if trace {
            let (h, m) = (memo_stats.hits, memo_stats.misses);
            let hit_rate = if h + m > 0 { h as f64 / (h + m) as f64 } else { 0.0 };
            crate::telemetry::trace::emit(
                "search",
                "search",
                phases.total_secs(),
                crate::json::Value::obj()
                    .set("plan", plan_id.as_str())
                    .set("generated", n_generated)
                    .set("scored", scored_all.len())
                    .set("pruned_pools", pruner.pruned())
                    .set("memo_hit_rate", hit_rate),
            );
        }

        Ok(assemble_report(
            n_generated,
            rule_filtered,
            mem_filtered,
            &pruner,
            phases,
            plan.budget,
            plan.top_k,
            plan.frontier,
            memo_stats,
            scored_all,
            audit_acc,
        ))
    }

    /// The fused native streaming pass: expand → rule filter → memory
    /// filter → score, one pool per work item on the scoped worker pool,
    /// scoring through the shared memo. No candidate vector is ever
    /// materialized — each strategy goes from the generator's visitor
    /// straight through the filters into (at most) one [`ScoredStrategy`].
    fn stream_pools(
        &self,
        model: &ModelSpec,
        space: &SearchSpace,
        tasks: &[&PoolSpec],
        memo: &SharedCostMemo,
        workers: usize,
        cancel: &CancelToken,
    ) -> Vec<PoolOutcome> {
        let rules = &self.config.rules;
        let catalog = &self.catalog;
        let cost = &self.cost;
        let money = &self.config.money;
        let mem = MemoryModel::default();
        let batch_eta = self.config.batch_eta;
        par_for_indices(tasks.len(), workers, |i| {
            // Cancelled mid-wave: stop burning workers on pools whose
            // outcomes the wave boundary is about to discard anyway. The
            // empty outcome never reaches a report (the boundary check
            // errors first), so determinism is unaffected.
            if cancel.is_cancelled() {
                return PoolOutcome::default();
            }
            // Chaos seam: an armed `engine.score` failpoint panics inside
            // the worker closure — `par_for_indices` propagates it to the
            // requesting thread, where the service's `catch_unwind` turns
            // it into a typed `panic`-kind response.
            crate::resilience::failpoint::fire_as_panic("engine.score");
            let task = tasks[i];
            let mut oc = PoolOutcome::default();
            let t_pool = Instant::now();
            if batch_eta {
                // Batched scoring: collect the pool's filter survivors,
                // then push the memo misses through the flat-forest batch
                // kernel in one `evaluate_pool_shared` call. Byte-identical
                // to the per-strategy path below (pinned by
                // `rust/tests/diff_forest.rs`).
                let mut survivors: Vec<ParallelStrategy> = Vec::new();
                space.expand_params_each(model, &task.cluster, task.tp, task.dp, &mut |s| {
                    oc.generated += 1;
                    if rules.filters_out(&s).unwrap_or(true) {
                        oc.rule_filtered += 1;
                        return;
                    }
                    let t_mem = Instant::now();
                    let fits = mem.fits(model, &s, catalog);
                    oc.mem_secs += t_mem.elapsed().as_secs_f64();
                    if !fits {
                        oc.mem_filtered += 1;
                        return;
                    }
                    survivors.push(s);
                });
                let t_score = Instant::now();
                let costs = ETA_SCRATCH.with(|sc| {
                    cost.evaluate_pool_shared(
                        model,
                        &survivors,
                        memo,
                        &mut oc.memo,
                        &mut sc.borrow_mut(),
                    )
                });
                for (s, breakdown) in survivors.into_iter().zip(costs) {
                    let money_usd = money.cost_usd(model, &s, catalog, breakdown.step_time);
                    oc.scored.push(ScoredStrategy { strategy: s, cost: breakdown, money_usd });
                }
                oc.score_secs = t_score.elapsed().as_secs_f64();
            } else {
                // Per-strategy scalar walk — the differential reference.
                space.expand_params_each(model, &task.cluster, task.tp, task.dp, &mut |s| {
                    oc.generated += 1;
                    if rules.filters_out(&s).unwrap_or(true) {
                        oc.rule_filtered += 1;
                        return;
                    }
                    let t_mem = Instant::now();
                    let fits = mem.fits(model, &s, catalog);
                    oc.mem_secs += t_mem.elapsed().as_secs_f64();
                    if !fits {
                        oc.mem_filtered += 1;
                        return;
                    }
                    let t_score = Instant::now();
                    let breakdown = cost.evaluate_shared(model, &s, memo, &mut oc.memo);
                    let money_usd = money.cost_usd(model, &s, catalog, breakdown.step_time);
                    oc.score_secs += t_score.elapsed().as_secs_f64();
                    oc.scored.push(ScoredStrategy { strategy: s, cost: breakdown, money_usd });
                });
            }
            oc.filter_secs = (t_pool.elapsed().as_secs_f64() - oc.score_secs).max(0.0);
            oc
        })
    }

    /// The HLO streaming pass: the same fused expand/filter runs on the
    /// worker pool, but survivors are collected per pool and scored through
    /// the PJRT executable — packed **per pool** into padded batches of the
    /// artifact's geometry, executed serially on this thread (the handle is
    /// thread-confined). Outcomes keep task order like the native pass.
    fn stream_pools_hlo(
        &self,
        model: &ModelSpec,
        space: &SearchSpace,
        tasks: &[&PoolSpec],
        rt: &Mutex<ScorerRuntime>,
        workers: usize,
        cancel: &CancelToken,
    ) -> Result<Vec<PoolOutcome>> {
        let rules = &self.config.rules;
        let catalog = &self.catalog;
        let mem = MemoryModel::default();
        let filtered: Vec<FilteredPool> = par_for_indices(tasks.len(), workers, |i| {
            let task = tasks[i];
            let t_pool = Instant::now();
            let mut fp = FilteredPool {
                generated: 0,
                rule_filtered: 0,
                mem_filtered: 0,
                survivors: Vec::new(),
                filter_secs: 0.0,
                mem_secs: 0.0,
            };
            if cancel.is_cancelled() {
                // Same contract as the native pass: discarded at the wave
                // boundary before any report assembly.
                return fp;
            }
            crate::resilience::failpoint::fire_as_panic("engine.score");
            space.expand_params_each(model, &task.cluster, task.tp, task.dp, &mut |s| {
                fp.generated += 1;
                if rules.filters_out(&s).unwrap_or(true) {
                    fp.rule_filtered += 1;
                    return;
                }
                let t_mem = Instant::now();
                let fits = mem.fits(model, &s, catalog);
                fp.mem_secs += t_mem.elapsed().as_secs_f64();
                if !fits {
                    fp.mem_filtered += 1;
                    return;
                }
                fp.survivors.push(s);
            });
            fp.filter_secs = t_pool.elapsed().as_secs_f64();
            fp
        });

        let batch = rt.lock().unwrap().batch.max(1);
        let money = &self.config.money;
        let mut outcomes = Vec::with_capacity(filtered.len());
        // One set of scorer tensors, re-zeroed per chunk — the serial
        // scoring loop used to allocate three fresh Vecs per pool.
        let mut pack = PackScratch::default();
        for fp in filtered {
            let mut oc = PoolOutcome {
                generated: fp.generated,
                rule_filtered: fp.rule_filtered,
                mem_filtered: fp.mem_filtered,
                filter_secs: fp.filter_secs,
                mem_secs: fp.mem_secs,
                ..Default::default()
            };
            let t_score = Instant::now();
            let mut costs: Vec<CostBreakdown> = Vec::with_capacity(fp.survivors.len());
            for chunk in fp.survivors.chunks(batch) {
                let refs: Vec<&ParallelStrategy> = chunk.iter().collect();
                pack_batch_into(model, &refs, catalog, batch, &mut pack);
                let rows: Vec<[f32; OUT]> = rt
                    .lock()
                    .unwrap()
                    .execute(&pack.stage_feats, &pack.stage_mask, &pack.strat_feats)?;
                for (j, s) in chunk.iter().enumerate() {
                    let r = rows[j];
                    let step_time = r[0] as f64;
                    let tokens = (s.global_batch * model.seq_len) as f64;
                    costs.push(CostBreakdown {
                        stage_times: Vec::new(),
                        pipeline_fwd: 0.0,
                        pipeline_bwd: r[1] as f64,
                        dp_time: r[2] as f64,
                        optimizer_time: r[3] as f64,
                        offload_time: 0.0,
                        step_time,
                        tokens_per_s: tokens / step_time,
                        mfu: 0.0,
                    });
                }
            }
            for (strategy, cost) in fp.survivors.into_iter().zip(costs) {
                let money_usd = money.cost_usd(model, &strategy, catalog, cost.step_time);
                oc.scored.push(ScoredStrategy { strategy, cost, money_usd });
            }
            oc.score_secs = t_score.elapsed().as_secs_f64();
            outcomes.push(oc);
        }
        Ok(outcomes)
    }
}

/// Pool construction + ranking tail shared by every plan. With a `budget`,
/// the fastest within-budget plan is promoted to `top[0]` (Eq. 33
/// selection) *before* truncation, so the pick survives even when `top_k`
/// faster-but-over-budget plans exist. The wall fields are derived from
/// the phase breakdown, so `phases` always sums to them exactly.
#[allow(clippy::too_many_arguments)]
fn assemble_report(
    generated: usize,
    rule_filtered: usize,
    mem_filtered: usize,
    pruner: &DominancePruner,
    phases: PhaseBreakdown,
    budget: Option<f64>,
    top_k: usize,
    frontier: bool,
    memo: MemoStats,
    mut scored: Vec<ScoredStrategy>,
    mut audit: Option<SearchAudit>,
) -> SearchReport {
    let pool = OptimalPool::build(
        scored
            .iter()
            .enumerate()
            .map(|(idx, s)| PoolEntry {
                idx,
                throughput: s.cost.tokens_per_s,
                cost: s.money_usd,
            })
            .collect(),
    );
    // Frontier plans carry the reprice skeleton, built against the same
    // replay-order index space as the pool (before the ranking sort).
    let frontier = frontier.then(|| FrontierReport { candidates: frontier_skeleton(&scored) });
    let n_scored = scored.len();
    scored.sort_by(|a, b| a.cost.step_time.total_cmp(&b.cost.step_time));
    if let Some(b) = budget {
        // Step-time ascending is throughput descending (tokens/step is
        // fixed per model), so the first within-budget entry is the
        // fastest affordable plan.
        if let Some(pos) = scored.iter().position(|s| s.money_usd <= b) {
            if pos > 0 {
                let pick = scored.remove(pos);
                scored.insert(0, pick);
            }
        }
    }
    scored.truncate(top_k);
    // Winner/runner-up margins come from the final ranking — after the
    // within-budget promotion, so the "winner" the audit explains is the
    // one the report actually returns.
    if let Some(a) = audit.as_mut() {
        let contender = |s: &ScoredStrategy| AuditContender {
            summary: s.strategy.summary(),
            step_time_s: s.cost.step_time,
            tokens_per_s: s.cost.tokens_per_s,
            money_usd: s.money_usd,
        };
        a.margins = scored.first().map(|w| {
            let winner = contender(w);
            let runner_up = scored.get(1).map(contender);
            let (dt, dtput, dusd) = match &runner_up {
                Some(r) => (
                    r.step_time_s - winner.step_time_s,
                    winner.tokens_per_s - r.tokens_per_s,
                    winner.money_usd - r.money_usd,
                ),
                None => (0.0, 0.0, 0.0),
            };
            AuditMargins {
                winner,
                runner_up,
                step_time_margin_s: dt,
                tokens_per_s_margin: dtput,
                money_margin_usd: dusd,
            }
        });
    }
    SearchReport {
        generated,
        rule_filtered,
        mem_filtered,
        scored: n_scored,
        pruned_pools: pruner.pruned(),
        pruned_budget: pruner.pruned_budget,
        pruned_dominated: pruner.pruned_dominated,
        search_secs: phases.search_secs(),
        simulate_secs: phases.simulate_secs(),
        phases,
        memo_hits: memo.hits,
        memo_misses: memo.misses,
        top: scored,
        pool,
        frontier,
        audit,
    }
}

/// The reprice skeleton: keep exactly the scored strategies that could sit
/// on the (throughput, USD) Pareto frontier under *some* positive price
/// book. A strategy's bill under any book is `steps × Σ_g w_g·rate_g` with
/// per-type coefficients `w_g = step_time × count_g`, so candidate `e` can
/// be dropped iff some `e'` has `tput' ≥ tput`, `w' ≤ w` componentwise
/// (types missing from `e'` count as 0) and wins the [`OptimalPool::build`]
/// tie-break (`tput' > tput`, or an earlier replay index) — such an `e` is
/// filtered by every book's frontier build, so removing it changes nothing.
/// The scan processes candidates in (throughput desc, idx asc) order and
/// tests only already-kept entries; dominance is transitive along that
/// order, so the reduction is complete as well as sound.
fn frontier_skeleton(scored: &[ScoredStrategy]) -> Vec<FrontierCandidate> {
    // Entries that can never pass the pool's validity retain are out
    // entirely (they are no-ops in every build).
    let eligible: Vec<usize> = (0..scored.len())
        .filter(|&i| {
            let c = &scored[i].cost;
            c.tokens_per_s.is_finite()
                && c.tokens_per_s >= 0.0
                && c.step_time.is_finite()
                && c.step_time >= 0.0
        })
        .collect();
    let weights: Vec<Vec<(crate::gpu::GpuType, f64)>> = eligible
        .iter()
        .map(|&i| {
            let s = &scored[i].strategy;
            s.cluster
                .gpus_by_type(s.tp, s.dp)
                .into_iter()
                .map(|(g, n)| (g, scored[i].cost.step_time * n as f64))
                .collect()
        })
        .collect();
    // `a`'s coefficients ≤ `b`'s componentwise over the type union.
    let le = |a: &[(crate::gpu::GpuType, f64)], b: &[(crate::gpu::GpuType, f64)]| {
        a.iter().all(|&(g, wa)| b.iter().any(|&(h, wb)| h == g && wa <= wb))
    };
    let mut order: Vec<usize> = (0..eligible.len()).collect();
    order.sort_by(|&a, &b| {
        scored[eligible[b]]
            .cost
            .tokens_per_s
            .total_cmp(&scored[eligible[a]].cost.tokens_per_s)
            .then(eligible[a].cmp(&eligible[b]))
    });
    let mut kept: Vec<usize> = Vec::new();
    'next: for &o in &order {
        let (i, w) = (eligible[o], &weights[o]);
        let tput = scored[i].cost.tokens_per_s;
        for &k in &kept {
            let (j, wk) = (eligible[k], &weights[k]);
            let beats_tie = scored[j].cost.tokens_per_s > tput || j < i;
            if beats_tie && le(wk, w) {
                continue 'next;
            }
        }
        kept.push(o);
    }
    let mut idxs: Vec<usize> = kept.into_iter().map(|o| eligible[o]).collect();
    idxs.sort_unstable();
    idxs.into_iter()
        .map(|idx| FrontierCandidate { idx, scored: scored[idx].clone() })
        .collect()
}
