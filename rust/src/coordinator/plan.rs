//! The search-plan IR: every [`SearchRequest`] mode **compiles** — purely,
//! with no scoring — into one [`SearchPlan`], and a single streaming
//! executor ([`super::exec`]) runs any plan for either engine.
//!
//! ## Why an IR
//!
//! The four pool modes (Eq. 1–3 plus the hetero money sweep) differ only in
//! *which pools* they enumerate and *under which objective* they select;
//! the expand → rule-filter → memory-filter → score pipeline is identical.
//! Before this refactor each mode owned a near-duplicate driver; now the
//! mode dispatch lives entirely in [`ScoringCore::compile_plan`] and the
//! pipeline exists exactly once.
//!
//! A plan is:
//!
//! * the [`SearchSpace`] whose parameter cross-product every pool expands
//!   (heterogeneous modes pin `vpp = 1` — interleaving over heterogeneous
//!   segments is not supported by the Megatron runtime, DESIGN.md §6);
//! * ordered [`PlanRound`]s of [`PoolSpec`]s — one round per sweep
//!   coordinate (GPU total). Pruning state carries **across** rounds and a
//!   round's own strategies never influence its own admissions, which is
//!   what makes the executor's speculative waves replayable;
//! * the objective: optional money `budget` (drives the within-budget
//!   promotion and the [`crate::pareto::DominancePruner`]), the `prune`
//!   switch, the speculative-wave schedule `(wave_base, wave_max)` and
//!   `top_k`.
//!
//! Compilation is deterministic: the same request and result-relevant
//! config always produce byte-identical [`plan_json`] — pinned by the
//! determinism matrix (worker counts never enter a plan) and the golden
//! plan snapshots under `rust/tests/golden/`.
//!
//! Branch-and-bound bounds (`ub_tput`, `lb_usd` per pool) are part of the
//! IR, not the executor: [`crate::pareto::MoneyModel::pool_bounds`] is pure
//! FLOPs arithmetic, so baking the bounds in at compile time keeps the
//! executor's admission replay free of model math. Pools of non-pruning
//! plans carry the trivial bounds `(+inf, 0)`.

use super::{ScoringCore, SearchRequest};
use crate::hetero::HeteroSolver;
use crate::json::Value;
use crate::model::ModelSpec;
use crate::pareto::MoneyModel;
use crate::strategy::{ClusterAssignment, GpuPoolMode, SearchSpace, SpaceConfig};
use crate::{AstraError, Result};

/// One candidate `(cluster, tp, dp)` pool: the unit of streaming work. The
/// executor expands, filters and scores a pool's parameter cross-product in
/// one fused per-worker pass.
#[derive(Debug, Clone)]
pub struct PoolSpec {
    pub cluster: ClusterAssignment,
    pub tp: usize,
    pub dp: usize,
    /// Branch-and-bound upper bound on the pool's throughput (tokens/s);
    /// `+inf` when the plan does not prune.
    pub ub_tput: f64,
    /// Branch-and-bound lower bound on the pool's bill (USD); `0` when the
    /// plan does not prune.
    pub lb_usd: f64,
}

impl PoolSpec {
    fn unbounded((cluster, tp, dp): (ClusterAssignment, usize, usize)) -> PoolSpec {
        PoolSpec { cluster, tp, dp, ub_tput: f64::INFINITY, lb_usd: 0.0 }
    }
}

/// One sweep round: all candidate pools of one cluster size. The executor
/// admits round `k+1`'s pools against a dominance frontier that has
/// observed rounds `0..=k`'s scored strategies.
#[derive(Debug, Clone)]
pub struct PlanRound {
    /// The GPU total this round covers (the sweep coordinate; for the
    /// single-round modes, the request's count ceiling).
    pub total: usize,
    pub pools: Vec<PoolSpec>,
}

/// A compiled search plan — see the module docs.
#[derive(Debug, Clone)]
pub struct SearchPlan {
    /// Parameter cross-product spec every pool expands under.
    pub space: SearchSpace,
    /// Ordered sweep rounds.
    pub rounds: Vec<PlanRound>,
    /// Money ceiling: `Some` for the cost modes (promotes the fastest
    /// within-budget plan to `top[0]`), `None` otherwise.
    pub budget: Option<f64>,
    /// Run the branch-and-bound [`crate::pareto::DominancePruner`] over the
    /// pools' bounds (hetero-cost only).
    pub prune: bool,
    /// Base speculative-wave size (rounds scored concurrently against a
    /// frontier snapshot); `1` = strictly serial sweep.
    pub wave_base: usize,
    /// Adaptive-wave ceiling (grow-on-zero-waste, reset-on-waste).
    pub wave_max: usize,
    /// Ranked strategies kept in the report.
    pub top_k: usize,
    /// Carry the full Pareto frontier (with its reprice skeleton) in the
    /// report. Frontier plans never prune and never carry a budget, so
    /// their candidate set is price-book-independent — the property the
    /// service's reprice-without-re-search path rests on.
    pub frontier: bool,
}

impl SearchPlan {
    /// Total candidate pools across every round.
    pub fn pool_count(&self) -> usize {
        self.rounds.iter().map(|r| r.pools.len()).sum()
    }
}

impl ScoringCore {
    /// Compile a request into its [`SearchPlan`]. Pure: no scoring, no memo
    /// traffic, no engine state — only enumeration (space × solver) and
    /// closed-form pool bounds. Validation errors (bad budgets, caps below
    /// the cluster size) surface here, before anything is counted.
    pub fn compile_plan(&self, req: &SearchRequest) -> Result<SearchPlan> {
        let cfg = &self.config;
        // `streaming: false` is kept as a compatibility flag (it stays in
        // the request fingerprint): it compiles the same rounds but pins
        // the wave schedule to the strictly serial 1/1 — together with the
        // executor's workers=1 override this is the differential oracle.
        let (wave_base, wave_max) = if cfg.streaming {
            let base = cfg.sweep_wave.max(1);
            (base, cfg.sweep_wave_max.max(base))
        } else {
            (1, 1)
        };
        let model = &req.model;
        let (space, rounds, budget, prune) = match &req.mode {
            GpuPoolMode::Homogeneous { gpu, count } => {
                let space = SearchSpace::new(cfg.space.clone());
                let pools: Vec<PoolSpec> = space
                    .homogeneous_pools(model, &self.catalog, *gpu, *count)
                    .into_iter()
                    .map(PoolSpec::unbounded)
                    .collect();
                (space, vec![PlanRound { total: *count, pools }], None, false)
            }
            GpuPoolMode::Heterogeneous { total, caps } => {
                // Canonicalize caps as a per-type map here, not just in the
                // named constructor: hand-built modes with split duplicate
                // entries must see the same budgets the fingerprint hashes,
                // or the result cache would conflate different searches.
                let caps = crate::strategy::merge_caps(caps.iter().copied());
                if caps.iter().map(|&(_, l)| l).sum::<usize>() < *total {
                    return Err(AstraError::Config(format!(
                        "type caps sum below cluster size {total}"
                    )));
                }
                let space = self.hetero_space();
                let solver = HeteroSolver::default();
                let mut pools = Vec::new();
                self.hetero_pools(model, *total, &caps, &space, &solver, None, &mut pools);
                (space, vec![PlanRound { total: *total, pools }], None, false)
            }
            GpuPoolMode::Cost { gpu, max_count, max_money } => {
                super::validate_budget(*max_money)?;
                let space = SearchSpace::new(cfg.space.clone());
                // The whole count sweep is one round: there is no pruner,
                // so nothing distinguishes rounds, and one fan-out lets the
                // shared memo carry stage profiles across every count
                // instead of rebuilding them per round.
                let mut pools = Vec::new();
                for count in SearchSpace::count_sweep(*max_count) {
                    pools.extend(
                        space
                            .homogeneous_pools(model, &self.catalog, *gpu, count)
                            .into_iter()
                            .map(PoolSpec::unbounded),
                    );
                }
                (space, vec![PlanRound { total: *max_count, pools }], Some(*max_money), false)
            }
            GpuPoolMode::HeteroCost { caps, max_money } => {
                super::validate_budget(*max_money)?;
                // Same per-type-map canonicalization as mode 2.
                let caps = crate::strategy::merge_caps(caps.iter().copied());
                let cap_sum: usize = caps.iter().map(|&(_, c)| c).sum();
                if caps.is_empty() || cap_sum < 2 {
                    return Err(AstraError::Config(
                        "hetero-cost caps admit fewer than 2 GPUs".into(),
                    ));
                }
                let space = self.hetero_space();
                let solver = HeteroSolver::default();
                // Power-of-two sweep plus the full pool when it is not a
                // power of two (callers stating exact caps expect the whole
                // pool tried).
                let mut totals = SearchSpace::count_sweep(cap_sum);
                if totals.last() != Some(&cap_sum) {
                    totals.push(cap_sum);
                }
                let money = cfg.money_prune.then_some(&cfg.money);
                let rounds: Vec<PlanRound> = totals
                    .into_iter()
                    .map(|total| {
                        let mut pools = Vec::new();
                        self.hetero_pools(model, total, &caps, &space, &solver, money, &mut pools);
                        PlanRound { total, pools }
                    })
                    .collect();
                (space, rounds, Some(*max_money), cfg.money_prune)
            }
            GpuPoolMode::Frontier { caps } => {
                // The hetero-cost sweep minus everything price-dependent:
                // no budget, no money pruning, trivial pool bounds. Every
                // pool is scored, so the candidate set — and with it the
                // report counts and the frontier skeleton — is a pure
                // function of (model, catalog, caps, space): the same
                // search serves every price book via reprice.
                let caps = crate::strategy::merge_caps(caps.iter().copied());
                let cap_sum: usize = caps.iter().map(|&(_, c)| c).sum();
                if caps.is_empty() || cap_sum < 2 {
                    return Err(AstraError::Config(
                        "frontier caps admit fewer than 2 GPUs".into(),
                    ));
                }
                let space = self.hetero_space();
                let solver = HeteroSolver::default();
                let mut totals = SearchSpace::count_sweep(cap_sum);
                if totals.last() != Some(&cap_sum) {
                    totals.push(cap_sum);
                }
                let rounds: Vec<PlanRound> = totals
                    .into_iter()
                    .map(|total| {
                        let mut pools = Vec::new();
                        self.hetero_pools(model, total, &caps, &space, &solver, None, &mut pools);
                        PlanRound { total, pools }
                    })
                    .collect();
                (space, rounds, None, false)
            }
        };
        Ok(SearchPlan {
            space,
            rounds,
            budget,
            prune,
            wave_base,
            wave_max,
            top_k: cfg.top_k,
            frontier: matches!(req.mode, GpuPoolMode::Frontier { .. }),
        })
    }

    /// Search space used by the heterogeneous modes: interleaving over
    /// heterogeneous segments is not supported by the Megatron runtime, so
    /// vpp is fixed to 1 (DESIGN.md §6).
    fn hetero_space(&self) -> SearchSpace {
        SearchSpace::new(SpaceConfig { vpp_candidates: vec![1], ..self.config.space.clone() })
    }

    /// Heterogeneous pool enumeration for one fixed cluster size: tp × pp ×
    /// dp splits × segment/layer assignments from the [`HeteroSolver`].
    /// With `money` set, each pool carries its branch-and-bound bounds
    /// (hetero-cost); without, the trivial `(+inf, 0)` (mode 2, or pruning
    /// disabled). Both hetero modes compile through this one enumeration,
    /// so their pool order cannot drift.
    fn hetero_pools(
        &self,
        model: &ModelSpec,
        total: usize,
        caps: &[(crate::gpu::GpuType, usize)],
        space: &SearchSpace,
        solver: &HeteroSolver,
        money: Option<&MoneyModel>,
        out: &mut Vec<PoolSpec>,
    ) {
        for tp in space.valid_tps(model, &self.catalog) {
            for pp in 2..=space.config.max_pp.min(model.layers).min(total / tp) {
                if total % (tp * pp) != 0 {
                    continue;
                }
                let dp = total / (tp * pp);
                let budgets = HeteroSolver::budgets(&self.catalog, caps, tp, dp);
                if budgets.iter().map(|b| b.max_stages).sum::<usize>() < pp {
                    continue;
                }
                let assignments =
                    solver.enumerate(model.layers, pp, &budgets, self.config.hetero_exhaustive);
                for ca in assignments {
                    let (ub_tput, lb_usd) = match money {
                        Some(m) => m.pool_bounds(model, &ca.gpus_by_type(tp, dp), &self.catalog),
                        None => (f64::INFINITY, 0.0),
                    };
                    out.push(PoolSpec { cluster: ca, tp, dp, ub_tput, lb_usd });
                }
            }
        }
    }
}

/// Non-finite-safe number rendering: JSON has no `inf`, so infinite bounds
/// and budgets serialize as the string `"inf"`.
fn num_or_inf(x: f64) -> Value {
    if x.is_finite() {
        Value::Num(x)
    } else {
        Value::Str("inf".to_string())
    }
}

fn usizes(xs: &[usize]) -> Value {
    Value::Arr(xs.iter().map(|&x| Value::Num(x as f64)).collect())
}

fn bools(xs: &[bool]) -> Value {
    Value::Arr(xs.iter().map(|&x| Value::Bool(x)).collect())
}

fn space_json(s: &SpaceConfig) -> Value {
    Value::obj()
        .set("tp", usizes(&s.tp_candidates))
        .set("max_pp", s.max_pp)
        .set("mbs", usizes(&s.mbs_candidates))
        .set("vpp", usizes(&s.vpp_candidates))
        .set("ep", usizes(&s.ep_candidates))
        .set("seq_parallel", bools(&s.seq_parallel_options))
        .set("dist_opt", bools(&s.dist_opt_options))
        .set("offload", bools(&s.offload_options))
        .set("recompute_none", s.recompute_none)
        .set("recompute_selective", s.recompute_selective)
        .set("recompute_full", s.recompute_full)
        .set("overlap", s.overlap)
        .set("use_flash_attn", s.use_flash_attn)
}

/// Canonical JSON view of a [`SearchPlan`] — the golden-snapshot and
/// determinism-matrix surface. Everything result-relevant is present (GPUs
/// by catalog *name*, bounds as shortest-round-trip decimals); two plans
/// that would drive the executor identically serialize byte-identically.
pub fn plan_json(plan: &SearchPlan, catalog: &crate::gpu::GpuCatalog) -> Value {
    let rounds: Vec<Value> = plan
        .rounds
        .iter()
        .map(|round| {
            let pools: Vec<Value> = round
                .pools
                .iter()
                .map(|p| {
                    let segments: Vec<Value> = p
                        .cluster
                        .segments
                        .iter()
                        .map(|seg| {
                            Value::obj()
                                .set("gpu", catalog.spec(seg.gpu).name.as_str())
                                .set("stages", seg.stages)
                                .set("layers_per_stage", seg.layers_per_stage)
                        })
                        .collect();
                    Value::obj()
                        .set("segments", Value::Arr(segments))
                        .set("tp", p.tp)
                        .set("dp", p.dp)
                        .set("ub_tput", num_or_inf(p.ub_tput))
                        .set("lb_usd", num_or_inf(p.lb_usd))
                })
                .collect();
            Value::obj().set("total", round.total).set("pools", Value::Arr(pools))
        })
        .collect();
    let budget = match plan.budget {
        None => Value::Str("none".to_string()),
        Some(b) => num_or_inf(b),
    };
    Value::obj()
        .set("astra_plan", 1u64)
        .set("space", space_json(&plan.space.config))
        .set("budget", budget)
        .set("prune", plan.prune)
        .set("frontier", plan.frontier)
        .set("wave_base", plan.wave_base)
        .set("wave_max", plan.wave_max)
        .set("top_k", plan.top_k)
        .set("pool_count", plan.pool_count())
        .set("rounds", Value::Arr(rounds))
}
