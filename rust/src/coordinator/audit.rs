//! The search decision audit — the "explain plane" of a [`super::SearchReport`].
//!
//! An opt-in (`"audit":true` on the wire, `--audit`/`astra explain` on the
//! CLI) per-request [`SearchAudit`] recording *why* the search decided what
//! it decided: per-round, per-pool admitted-vs-pruned outcomes with the
//! certifying evidence (budget prunes carry the offending `lb_usd` against
//! the budget; dominance prunes carry the exact dominating frontier point,
//! straight from [`crate::pareto::AdmitDecision`]), the candidate funnel
//! (expanded → rules-rejected → memory-rejected → scored) per pool, and
//! the winner/runner-up margins of the final ranking.
//!
//! ## Determinism contract
//!
//! * **The audit comes from the serial replay, never from speculation.**
//!   The executor's phase-3 replay walks every pool of every round in
//!   (round, pool) order against the true running frontier — the audit is
//!   assembled exactly there, so its decisions and evidence are
//!   byte-identical at any worker count and any wave schedule, like the
//!   report itself.
//! * **The audit never enters fingerprints.** `"audit":true` is a view
//!   switch, not a different search: request fingerprints, the result
//!   cache key and the canonical `report_json` bytes are all unchanged
//!   whether auditing is on or off. A cached report may therefore carry an
//!   audit from an earlier audited leader (served as-is) or none at all
//!   (an audited request hitting an unaudited cache entry answers without
//!   an audit) — the audit is best-effort observability, never a result.
//! * **Canonical vs observability fields.** Two audit members are honest
//!   observability and *load-dependent*: per-pool memo hit/miss counts
//!   (workers race on the shared memo) and the per-wave speculation-waste
//!   records in [`SearchAudit::waves`] (a `wave=1` schedule never wastes;
//!   wider waves may). Both are carried in the struct for the human
//!   `astra explain` view but are **excluded from the canonical
//!   [`crate::report::audit_json`]**, exactly as `report_json` excludes
//!   wall times and memo counters — which is what makes the canonical
//!   audit bytes identical across the whole worker/wave matrix. For the
//!   same reason the canonical view emits the funnel only for *admitted*
//!   pools: a pruned pool's funnel exists only when a stale snapshot
//!   speculated it, which is schedule-dependent.
//!
//! Every recorded prune is machine-checkable: `rust/tests/audit.rs`
//! property-tests that budget-pruned pools satisfy `lb_usd > budget`, that
//! dominance-pruned pools are actually dominated by their recorded frontier
//! point, and that the audited pool set exactly partitions the plan's pool
//! set (no pool unaccounted for).

use crate::pareto::AdmitDecision;

/// Why one pool was admitted or pruned, with the certifying evidence.
/// Mirrors [`AdmitDecision`]; a separate type so the audit can be stored,
/// serialized and persisted without coupling the pruner to the codec.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum AuditDecision {
    /// The pool was expanded and scored.
    Admitted,
    /// Pruned: the pool's lower-bound bill exceeds the budget.
    PrunedBudget { lb_usd: f64, budget: f64 },
    /// Pruned: the recorded `(tokens_per_s, money_usd)` frontier point is
    /// at least as fast AND at least as cheap as the pool's best case.
    PrunedDominated { by: (f64, f64) },
}

impl AuditDecision {
    pub fn is_admitted(&self) -> bool {
        matches!(self, AuditDecision::Admitted)
    }

    /// Stable machine tag (the `decision` field of the canonical JSON).
    pub fn tag(&self) -> &'static str {
        match self {
            AuditDecision::Admitted => "admitted",
            AuditDecision::PrunedBudget { .. } => "pruned_budget",
            AuditDecision::PrunedDominated { .. } => "pruned_dominated",
        }
    }
}

impl From<AdmitDecision> for AuditDecision {
    fn from(d: AdmitDecision) -> AuditDecision {
        match d {
            AdmitDecision::Admitted => AuditDecision::Admitted,
            AdmitDecision::PrunedBudget { lb_usd, budget } => {
                AuditDecision::PrunedBudget { lb_usd, budget }
            }
            AdmitDecision::PrunedDominated { by } => AuditDecision::PrunedDominated { by },
        }
    }
}

/// The candidate funnel of one streamed pool: where candidates died on the
/// expand → rules → memory → score pipeline. `expanded` always equals
/// `rules_rejected + mem_rejected + scored`. The memo counters are
/// load-dependent observability (see the module docs) — canonical views
/// must not serialize them.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct AuditFunnel {
    pub expanded: usize,
    pub rules_rejected: usize,
    pub mem_rejected: usize,
    pub scored: usize,
    /// Load-dependent: stage/sync memo hits while scoring this pool.
    pub memo_hits: u64,
    /// Load-dependent: memo misses while scoring this pool.
    pub memo_misses: u64,
}

/// One pool's audit record. Identity is positional — `(round, pool)` index
/// into the compiled [`super::SearchPlan`] — plus the human-meaningful GPU
/// mix and parallelism split.
#[derive(Debug, Clone, PartialEq)]
pub struct AuditPool {
    /// Index of this pool within its round.
    pub pool: usize,
    /// Per-type GPU mix `(catalog name, count)`, merged across segments.
    pub gpus: Vec<(String, usize)>,
    pub tp: usize,
    pub dp: usize,
    /// Branch-and-bound upper-bound throughput (tokens/s); `+inf` for
    /// non-pruning plans.
    pub ub_tput: f64,
    /// Branch-and-bound lower-bound bill (USD); `0` for non-pruning plans.
    pub lb_usd: f64,
    pub decision: AuditDecision,
    /// Present when the pool streamed through the pipeline (always, for
    /// admitted pools; for pruned pools only when a stale snapshot
    /// speculated it — schedule-dependent, so canonical views emit the
    /// funnel for admitted pools only).
    pub funnel: Option<AuditFunnel>,
}

/// One sweep round's audit: every pool of the round, in replay order.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct AuditRound {
    /// Round index within the plan.
    pub round: usize,
    /// The round's GPU total (the sweep coordinate).
    pub total: usize,
    pub pools: Vec<AuditPool>,
}

/// One speculative wave's waste accounting (load-dependent observability:
/// the wave schedule itself adapts, and a serial `wave=1` run never
/// wastes). Excluded from the canonical JSON.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AuditWave {
    /// Wave sequence number (0-based).
    pub wave: usize,
    /// Rounds covered by this wave.
    pub rounds: usize,
    /// Pools speculatively streamed in phase 2.
    pub speculated: usize,
    /// Speculated pools the serial replay then pruned (wasted work).
    pub wasted: usize,
}

/// One contender in the final ranking (the winner or the runner-up).
#[derive(Debug, Clone, PartialEq)]
pub struct AuditContender {
    /// `ParallelStrategy::summary()` — the human-readable strategy line.
    pub summary: String,
    pub step_time_s: f64,
    pub tokens_per_s: f64,
    pub money_usd: f64,
}

/// Winner vs runner-up margins of the final ranking (`top[0]` vs `top[1]`
/// after the within-budget promotion). Positive step-time/throughput
/// margins mean the winner is strictly faster; the money margin may go
/// either way (a budget promotion picks a slower-but-affordable winner).
#[derive(Debug, Clone, PartialEq)]
pub struct AuditMargins {
    pub winner: AuditContender,
    /// `None` when the ranking holds a single strategy.
    pub runner_up: Option<AuditContender>,
    /// `runner_up.step_time_s - winner.step_time_s` (0 without a runner-up).
    pub step_time_margin_s: f64,
    /// `winner.tokens_per_s - runner_up.tokens_per_s` (0 without one).
    pub tokens_per_s_margin: f64,
    /// `winner.money_usd - runner_up.money_usd` (0 without one).
    pub money_margin_usd: f64,
}

/// The full decision audit of one search. Attached to
/// [`super::SearchReport::audit`] when requested; `None` otherwise (and the
/// report is byte-identical either way outside this field).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct SearchAudit {
    /// Every round of the plan, every pool of every round, in replay order.
    pub rounds: Vec<AuditRound>,
    /// Per-wave speculation-waste records (observability; excluded from
    /// the canonical JSON — see the module docs).
    pub waves: Vec<AuditWave>,
    /// Winner/runner-up margins; `None` when nothing scored.
    pub margins: Option<AuditMargins>,
}

impl SearchAudit {
    /// Total pools recorded across every round.
    pub fn pool_count(&self) -> usize {
        self.rounds.iter().map(|r| r.pools.len()).sum()
    }

    /// Pools admitted (expanded and scored).
    pub fn admitted(&self) -> usize {
        self.rounds
            .iter()
            .flat_map(|r| r.pools.iter())
            .filter(|p| p.decision.is_admitted())
            .count()
    }

    /// Pools pruned on the budget bound.
    pub fn pruned_budget(&self) -> usize {
        self.rounds
            .iter()
            .flat_map(|r| r.pools.iter())
            .filter(|p| matches!(p.decision, AuditDecision::PrunedBudget { .. }))
            .count()
    }

    /// Pools pruned by dominance.
    pub fn pruned_dominated(&self) -> usize {
        self.rounds
            .iter()
            .flat_map(|r| r.pools.iter())
            .filter(|p| matches!(p.decision, AuditDecision::PrunedDominated { .. }))
            .count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pool(pool: usize, decision: AuditDecision) -> AuditPool {
        AuditPool {
            pool,
            gpus: vec![("a800".to_string(), 4)],
            tp: 1,
            dp: 4,
            ub_tput: 100.0,
            lb_usd: 5.0,
            decision,
            funnel: None,
        }
    }

    #[test]
    fn counts_partition_by_decision() {
        let audit = SearchAudit {
            rounds: vec![
                AuditRound {
                    round: 0,
                    total: 4,
                    pools: vec![
                        pool(0, AuditDecision::Admitted),
                        pool(1, AuditDecision::PrunedBudget { lb_usd: 9.0, budget: 5.0 }),
                    ],
                },
                AuditRound {
                    round: 1,
                    total: 8,
                    pools: vec![pool(0, AuditDecision::PrunedDominated { by: (50.0, 1.0) })],
                },
            ],
            waves: Vec::new(),
            margins: None,
        };
        assert_eq!(audit.pool_count(), 3);
        assert_eq!(audit.admitted(), 1);
        assert_eq!(audit.pruned_budget(), 1);
        assert_eq!(audit.pruned_dominated(), 1);
        assert_eq!(
            audit.pool_count(),
            audit.admitted() + audit.pruned_budget() + audit.pruned_dominated(),
            "decisions partition the pool set"
        );
    }

    #[test]
    fn decision_tags_are_stable() {
        assert_eq!(AuditDecision::Admitted.tag(), "admitted");
        assert_eq!(AuditDecision::PrunedBudget { lb_usd: 1.0, budget: 0.5 }.tag(), "pruned_budget");
        assert_eq!(
            AuditDecision::PrunedDominated { by: (1.0, 1.0) }.tag(),
            "pruned_dominated"
        );
    }

    #[test]
    fn admit_decision_converts_with_evidence_intact() {
        let d: AuditDecision =
            crate::pareto::AdmitDecision::PrunedBudget { lb_usd: 7.0, budget: 3.0 }.into();
        assert_eq!(d, AuditDecision::PrunedBudget { lb_usd: 7.0, budget: 3.0 });
        let d: AuditDecision =
            crate::pareto::AdmitDecision::PrunedDominated { by: (9.0, 2.0) }.into();
        assert_eq!(d, AuditDecision::PrunedDominated { by: (9.0, 2.0) });
        assert!(AuditDecision::from(crate::pareto::AdmitDecision::Admitted).is_admitted());
    }
}
