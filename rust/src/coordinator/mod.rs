//! The Astra engine — Layer-3 coordinator tying the whole pipeline together
//! (paper Fig. 2): input preprocess → search-space generation → rule filter
//! → memory filter → cost simulation → selection (throughput or money).
//!
//! Scoring runs on one of two engines with identical math:
//!
//! * `native` — the pure-rust [`CostModel`] (η from GBDT forests when
//!   `artifacts/forest.json` exists, hardware-truth curves otherwise);
//! * `hlo` — the AOT-compiled Layer-2 scorer executed through PJRT
//!   ([`crate::runtime::ScorerRuntime`]), exercising the Pallas kernels.
//!
//! Search is fanned out over a scoped thread pool; the per-phase wall times
//! reported in [`SearchReport`] correspond to Table 1's "Search Time" and
//! "Simulation Time" columns.
//!
//! ## Streaming scoring engine
//!
//! With `EngineConfig::streaming` (the default), the native pipeline never
//! materializes a round's full candidate vector: the unit of parallel work
//! is a `(cluster, tp, dp)` *pool*, and each worker fuses parameter
//! expansion → rule filter → memory filter → cost scoring into one pass
//! per pool, scoring through the core's [`SharedCostMemo`] (shared across
//! chunks, sweep rounds and requests — see the [`crate::cost`] module docs
//! for the memo architecture). The hetero-cost sweep additionally runs its
//! pool totals in speculative waves ([`ScoringCore::hetero_cost_streaming`])
//! whose deterministic replay keeps reports byte-identical to the serial
//! sweep. `streaming: false` keeps the pre-refactor collect-then-filter
//! pipeline as the reference half of the differential harness
//! (`rust/tests/diff_streaming.rs`); the HLO engine always takes the
//! reference path because its PJRT handle is batch-oriented.
//!
//! ## Engine anatomy: [`ScoringCore`] vs [`AstraEngine`]
//!
//! The PJRT executable handle is thread-confined (the `xla` wrappers are
//! neither `Send` nor `Sync`), which would make the whole engine unshareable
//! across threads. The state the native pipeline actually needs — catalog,
//! config, cost model — is plain data, so it lives in [`ScoringCore`], a
//! `Sync` scoring entry point that one process can share across many
//! concurrent requests (this is what [`crate::service`] fans out over).
//! [`AstraEngine`] is `ScoringCore` plus the optional HLO runtime; it keeps
//! the historical single-owner API and is what the CLI constructs.

use crate::cost::features::{pack_batch, OUT};
use crate::cost::{CostBreakdown, CostModel, EtaProvider, MemoRegistry, MemoStats, SharedCostMemo};
use crate::gbdt::EtaForests;
use crate::gpu::GpuCatalog;
use crate::hetero::HeteroSolver;
use crate::memory::MemoryModel;
use crate::model::ModelSpec;
use crate::pareto::{DominancePruner, MoneyModel, OptimalPool, PoolEntry};
use crate::pool::{default_workers, par_for_indices, par_map_chunks};
use crate::rules::RuleSet;
use crate::runtime::ScorerRuntime;
use crate::strategy::{ClusterAssignment, GpuPoolMode, ParallelStrategy, SearchSpace, SpaceConfig};
use crate::{AstraError, Result};
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// Which scorer executes the cost simulation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScoringEngine {
    Native,
    Hlo,
}

/// Engine configuration.
pub struct EngineConfig {
    pub space: SpaceConfig,
    pub rules: RuleSet,
    pub engine: ScoringEngine,
    /// Use GBDT forests for η when available (`artifacts/forest.json`).
    pub use_forests: bool,
    pub workers: usize,
    pub money: MoneyModel,
    /// Exhaustive Eq. 23 layer enumeration instead of the pruned solver.
    pub hetero_exhaustive: bool,
    /// Branch-and-bound pool pruning in the hetero-cost search (turn off
    /// for the exhaustive differential reference; results are identical,
    /// only the search time changes).
    pub money_prune: bool,
    /// Stream generation → rule filter → memory filter → scoring in fused
    /// per-worker passes over `(cluster, tp, dp)` pools, scoring through
    /// the core's [`SharedCostMemo`] (the fast path; native engine only).
    /// Off = the pre-refactor reference pipeline that materializes the full
    /// candidate vector per round and memoizes per worker chunk — kept for
    /// the differential harness, which proves the two paths select
    /// identically.
    pub streaming: bool,
    /// Pool totals per speculative wave of the parallel hetero-cost sweep.
    /// 1 = fully serial (each round's pruner sees every earlier round's
    /// frontier, zero speculation waste); larger waves score consecutive
    /// totals concurrently against a frontier *snapshot* and then replay
    /// the admission decisions serially, so reports — including pruning
    /// counts — stay byte-identical to the serial sweep at any wave size.
    /// This is the *base* wave; the sweep adapts upward from it (see
    /// `sweep_wave_max`).
    pub sweep_wave: usize,
    /// Adaptive-wave ceiling: after a wave whose speculative admissions
    /// were all replayed without waste, the next wave grows by one total
    /// (more cross-total overlap for free); any waste resets the wave to
    /// `sweep_wave`. Growth is driven only by the deterministic admission
    /// replay, so — like `sweep_wave` itself — the schedule never changes
    /// the report and stays out of the request fingerprint.
    pub sweep_wave_max: usize,
    /// Keep this many best strategies in the report.
    pub top_k: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            space: SpaceConfig::default(),
            rules: RuleSet::paper_defaults(),
            engine: ScoringEngine::Native,
            use_forests: true,
            workers: default_workers(),
            money: MoneyModel::default(),
            hetero_exhaustive: false,
            money_prune: true,
            streaming: true,
            sweep_wave: 2,
            sweep_wave_max: 8,
            top_k: 16,
        }
    }
}

/// A search request: model + GPU-pool mode (§3.2 input integration, Eq. 7).
#[derive(Debug, Clone)]
pub struct SearchRequest {
    pub mode: GpuPoolMode,
    pub model: ModelSpec,
}

impl SearchRequest {
    /// Mode 1 (Eq. 1): one GPU type, fixed count. Unknown GPU names are a
    /// recoverable [`AstraError::Config`] (service requests must not abort
    /// the process).
    pub fn homogeneous(gpu_name: &str, count: usize, model: ModelSpec) -> Result<SearchRequest> {
        let catalog = GpuCatalog::builtin();
        let gpu = catalog.find(gpu_name)?;
        Ok(SearchRequest { mode: GpuPoolMode::Homogeneous { gpu, count }, model })
    }

    /// Mode 2 (Eq. 2): total cluster size + per-type caps, named by GPU.
    /// Caps are a per-type *map*: duplicate entries of the same type merge
    /// by summation (matching the JSON wire form, which is an object).
    pub fn heterogeneous(
        caps: &[(&str, usize)],
        total: usize,
        model: ModelSpec,
    ) -> Result<SearchRequest> {
        let catalog = GpuCatalog::builtin();
        let mut resolved: Vec<(crate::gpu::GpuType, usize)> = Vec::with_capacity(caps.len());
        for &(name, cap) in caps {
            resolved.push((catalog.find(name)?, cap));
        }
        let resolved = crate::strategy::merge_caps(resolved);
        Ok(SearchRequest { mode: GpuPoolMode::Heterogeneous { total, caps: resolved }, model })
    }

    /// Mode 3 (Eq. 3): count sweep under a money ceiling. NaN and
    /// non-positive budgets are recoverable [`AstraError::Config`]s, like
    /// the unknown-GPU paths (`+inf` means "no ceiling" and is fine).
    pub fn cost(
        gpu_name: &str,
        max_count: usize,
        max_money: f64,
        model: ModelSpec,
    ) -> Result<SearchRequest> {
        let catalog = GpuCatalog::builtin();
        let gpu = catalog.find(gpu_name)?;
        validate_budget(max_money)?;
        Ok(SearchRequest { mode: GpuPoolMode::Cost { gpu, max_count, max_money }, model })
    }

    /// Heterogeneous money search: per-type caps (a map — duplicate names
    /// merge by summation) swept under a money ceiling.
    pub fn hetero_cost(
        caps: &[(&str, usize)],
        max_money: f64,
        model: ModelSpec,
    ) -> Result<SearchRequest> {
        let catalog = GpuCatalog::builtin();
        validate_budget(max_money)?;
        let mut resolved: Vec<(crate::gpu::GpuType, usize)> = Vec::with_capacity(caps.len());
        for &(name, cap) in caps {
            resolved.push((catalog.find(name)?, cap));
        }
        let resolved = crate::strategy::merge_caps(resolved);
        if resolved.iter().map(|&(_, c)| c).sum::<usize>() < 2 {
            return Err(AstraError::Config("hetero-cost caps admit fewer than 2 GPUs".into()));
        }
        Ok(SearchRequest { mode: GpuPoolMode::HeteroCost { caps: resolved, max_money }, model })
    }
}

/// Money ceilings must be positive and not NaN (`+inf` = unlimited). Shared
/// by the request constructors, the wire parser and the engine dispatch so
/// hand-built modes cannot smuggle a bad budget past validation.
pub fn validate_budget(max_money: f64) -> Result<()> {
    if max_money.is_nan() || max_money <= 0.0 {
        return Err(AstraError::Config(format!(
            "max_money must be a positive number of USD (got {max_money})"
        )));
    }
    Ok(())
}


/// One scored strategy.
#[derive(Debug, Clone)]
pub struct ScoredStrategy {
    pub strategy: ParallelStrategy,
    pub cost: CostBreakdown,
    pub money_usd: f64,
}

impl ScoredStrategy {
    pub fn summary(&self) -> String {
        format!(
            "{} | step={:.4}s tput={:.0} tok/s mfu={:.3} ${:.0}",
            self.strategy.summary(),
            self.cost.step_time,
            self.cost.tokens_per_s,
            self.cost.mfu,
            self.money_usd
        )
    }
}

/// Search outcome + phase accounting (Table 1 columns).
#[derive(Debug, Clone)]
pub struct SearchReport {
    /// Raw search-space size |S| (Eq. 9). Pools skipped by the hetero-cost
    /// pruner never reach generation, so they are not counted here.
    pub generated: usize,
    pub rule_filtered: usize,
    pub mem_filtered: usize,
    pub scored: usize,
    /// Candidate pools rejected by the hetero-cost branch-and-bound pruner
    /// before strategy expansion (0 for the other modes).
    pub pruned_pools: usize,
    /// Generation + filtering wall time ("Search Time").
    pub search_secs: f64,
    /// Scoring wall time ("Simulation Time").
    pub simulate_secs: f64,
    /// Shared-cost-memo hits accumulated by this search's scoring passes
    /// (0 on the non-streaming reference path and the HLO engine). Like
    /// the wall times these are observability, not results: a memo warmed
    /// by earlier traffic raises hits, and concurrent workers may both
    /// miss a key one of them is about to insert — so golden transcripts
    /// and determinism diffs normalize them out.
    pub memo_hits: u64,
    /// Shared-cost-memo misses (see `memo_hits`).
    pub memo_misses: u64,
    /// Best strategies, ascending step time.
    pub top: Vec<ScoredStrategy>,
    /// Pareto pool over (throughput, money) — all scored candidates.
    pub pool: OptimalPool,
}

impl SearchReport {
    pub fn best(&self) -> Option<&ScoredStrategy> {
        self.top.first()
    }

    pub fn e2e_secs(&self) -> f64 {
        self.search_secs + self.simulate_secs
    }
}

/// The `Sync` heart of the engine: catalog + config + cost model, no
/// thread-confined runtime handles. One instance can serve concurrent
/// searches from many threads (each search additionally fans its own
/// scoring out over the scoped worker pool).
pub struct ScoringCore {
    pub catalog: GpuCatalog,
    pub config: EngineConfig,
    cost: CostModel,
    /// Shared cost memos, one per model scope ([`crate::cost::model_scope_key`]):
    /// reused across worker chunks, sweep rounds and service requests. The
    /// catalog/η/consts dimension of memo validity is pinned by `cost`
    /// being immutable for the core's lifetime.
    memos: MemoRegistry,
    /// Lifetime count of searches that entered the filter/score pipeline —
    /// the cache-effectiveness anchor for [`crate::service`] tests.
    searches: AtomicU64,
    /// Warm-start spill/restore accounting ([`crate::persist`]), surfaced
    /// through `astra stats` and the wire `stats` response.
    persist: crate::persist::PersistCounters,
    /// Snapshot identity of this core, digested once at construction
    /// (forest digests walk every tree node — too costly per spill).
    warm_meta: crate::persist::EngineMeta,
}

/// One unit of streaming scoring work: a fixed `(cluster, tp, dp)` pool
/// whose parameter cross-product is expanded, filtered and scored in a
/// single per-worker pass.
struct PoolTask {
    cluster: ClusterAssignment,
    tp: usize,
    dp: usize,
}

/// Outcome of streaming one pool. Counts and scored strategies are
/// deterministic (pure functions of the pool); the wall-second fields are
/// per-worker accumulations used only to apportion the report's search vs
/// simulation times.
#[derive(Default)]
struct PoolOutcome {
    generated: usize,
    rule_filtered: usize,
    mem_filtered: usize,
    scored: Vec<ScoredStrategy>,
    memo: MemoStats,
    filter_secs: f64,
    score_secs: f64,
}

/// Aggregation of a streaming pass over many pools.
struct StreamedBatch {
    generated: usize,
    rule_filtered: usize,
    mem_filtered: usize,
    scored: Vec<ScoredStrategy>,
    memo: MemoStats,
    /// Wall-clock share attributed to generation + filtering.
    search_secs: f64,
    /// Wall-clock share attributed to cost scoring.
    simulate_secs: f64,
}

impl StreamedBatch {
    /// Fold per-pool outcomes (in pool order) and split the pass's wall
    /// time between the filter and scoring phases in proportion to the
    /// workers' accumulated busy time in each — the fused pass has no
    /// phase barrier to time directly, but `search + simulate` still sums
    /// to the true wall clock.
    fn collect(outcomes: Vec<PoolOutcome>, wall_secs: f64) -> StreamedBatch {
        let mut b = StreamedBatch {
            generated: 0,
            rule_filtered: 0,
            mem_filtered: 0,
            scored: Vec::new(),
            memo: MemoStats::default(),
            search_secs: 0.0,
            simulate_secs: 0.0,
        };
        let (mut filter_busy, mut score_busy) = (0.0f64, 0.0f64);
        for mut oc in outcomes {
            b.generated += oc.generated;
            b.rule_filtered += oc.rule_filtered;
            b.mem_filtered += oc.mem_filtered;
            b.memo.merge(oc.memo);
            b.scored.append(&mut oc.scored);
            filter_busy += oc.filter_secs;
            score_busy += oc.score_secs;
        }
        let busy = filter_busy + score_busy;
        if busy > 0.0 {
            b.search_secs = wall_secs * filter_busy / busy;
            b.simulate_secs = wall_secs * score_busy / busy;
        } else {
            b.search_secs = wall_secs;
        }
        b
    }
}

impl ScoringCore {
    /// Build a core; loads `artifacts/forest.json` (η forests) when
    /// `config.use_forests` is set.
    pub fn new(catalog: GpuCatalog, config: EngineConfig) -> Self {
        let dir = crate::runtime::artifacts_dir();
        let eta = if config.use_forests {
            match EtaForests::from_file(&dir.join("forest.json")) {
                Ok(f) => {
                    crate::log_info!("η source: GBDT forests ({} + {} trees)",
                        f.comp.trees.len(), f.comm.trees.len());
                    EtaProvider::Forests(f)
                }
                Err(e) => {
                    crate::log_warn!("forest.json unavailable ({e}); falling back to analytic η");
                    EtaProvider::Analytic
                }
            }
        } else {
            EtaProvider::Analytic
        };
        let cost = CostModel::new(catalog.clone(), eta);
        let warm_meta = crate::persist::EngineMeta::new(
            &catalog,
            &cost.eta,
            &cost.consts,
            &config.money.book,
        );
        ScoringCore {
            catalog,
            config,
            cost,
            memos: MemoRegistry::new(16),
            searches: AtomicU64::new(0),
            persist: crate::persist::PersistCounters::default(),
            warm_meta,
        }
    }

    /// Immutable access to the underlying cost model (tests/benches).
    pub fn cost_model(&self) -> &CostModel {
        &self.cost
    }

    /// The shared memo for a model's scope (tests/benches; searches fetch
    /// their own through the same registry).
    pub fn memo_for(&self, model: &ModelSpec) -> std::sync::Arc<SharedCostMemo> {
        self.memos.for_model(model)
    }

    /// `(scopes, lifetime hits, lifetime misses)` across every live memo —
    /// the service stats-line payload.
    pub fn memo_counters(&self) -> (usize, u64, u64) {
        let (h, m) = self.memos.counters();
        (self.memos.scopes(), h, m)
    }

    /// Lifetime warm-start spill/restore counters (shared with the service
    /// layer, which also spills the result cache through them).
    pub fn persist_counters(&self) -> &crate::persist::PersistCounters {
        &self.persist
    }

    /// Plain-data view of [`Self::persist_counters`] for the stats line.
    pub fn persist_stats(&self) -> crate::persist::PersistSnapshot {
        self.persist.snapshot()
    }

    /// This core's snapshot identity, digested once at construction.
    pub fn engine_meta(&self) -> &crate::persist::EngineMeta {
        &self.warm_meta
    }

    /// Append every live memo scope (with this core's identity header) to a
    /// snapshot under construction. The service layer uses this to combine
    /// memo scopes and its result cache into one file.
    pub fn export_warm(&self, w: &mut crate::persist::WarmWriter) {
        for (key, memo) in self.memos.export_scopes() {
            let rows = memo.export_rows();
            if rows.is_empty() {
                continue;
            }
            w.memo_scope(key, &rows, &self.warm_meta);
        }
    }

    /// Spill every live memo scope to a versioned snapshot at `path`
    /// (atomic temp-file + rename). See [`crate::persist`] for the format
    /// and the invalidation contract.
    pub fn save_warm(&self, path: &Path) -> Result<crate::persist::SpillStats> {
        let mut w = crate::persist::WarmWriter::new();
        self.export_warm(&mut w);
        let stats = w.finish_to(path)?;
        self.persist.note_spill(&stats);
        Ok(stats)
    }

    /// Import an already-parsed restore set's memo scopes into the
    /// registry (cache entries, if any, are the service layer's to insert).
    pub fn restore_warm_set(&self, set: &crate::persist::RestoreSet) {
        for (key, rows) in &set.memo_scopes {
            self.memos.restore_scope(*key, rows);
        }
        self.persist.note_restore(&set.stats());
    }

    /// Restore memo scopes from a snapshot at `path`. Scopes whose headers
    /// do not match this core's identity — or whose rows fail validation —
    /// are skipped (counted in `scopes_rejected`), so a stale or corrupt
    /// snapshot degrades to a cold start, never an error or a wrong
    /// answer. Only a missing/unreadable file is an `Err`.
    pub fn load_warm(&self, path: &Path) -> Result<crate::persist::RestoreStats> {
        // Memo-only consumer: cache sections are checksummed for the
        // accounting but their reports are not decoded.
        self.load_warm_set(path, false).map(|set| set.stats())
    }

    /// [`Self::load_warm`] returning the full [`crate::persist::RestoreSet`]
    /// — the service layer layers its cache insertion on top of this one
    /// load path instead of duplicating it. `want_cache` skips the
    /// per-report decode when the caller would discard the entries anyway.
    pub fn load_warm_set(
        &self,
        path: &Path,
        want_cache: bool,
    ) -> Result<crate::persist::RestoreSet> {
        let text = std::fs::read_to_string(path)?;
        let set =
            crate::persist::read_warm_filtered(&text, &self.catalog, &self.warm_meta, want_cache);
        self.restore_warm_set(&set);
        self.persist.note_snapshot_bytes(text.len() as u64);
        Ok(set)
    }

    /// Whether this search runs the fused streaming pipeline: configured
    /// on, and not diverted to the thread-confined HLO scorer.
    fn streaming_native(&self, rt: Option<&Mutex<ScorerRuntime>>) -> bool {
        self.config.streaming && !(self.config.engine == ScoringEngine::Hlo && rt.is_some())
    }

    /// How many searches have entered the filter/score pipeline (cache hits
    /// in the service layer do NOT increment this).
    pub fn searches_run(&self) -> u64 {
        self.searches.load(Ordering::Relaxed)
    }

    /// Run a search request with native scoring (mode dispatch).
    pub fn search(&self, req: &SearchRequest) -> Result<SearchReport> {
        self.search_with(req, None)
    }

    fn search_with(
        &self,
        req: &SearchRequest,
        rt: Option<&Mutex<ScorerRuntime>>,
    ) -> Result<SearchReport> {
        match &req.mode {
            GpuPoolMode::Homogeneous { gpu, count } => {
                self.search_homogeneous_with(&req.model, *gpu, *count, rt)
            }
            GpuPoolMode::Heterogeneous { total, caps } => {
                self.search_heterogeneous_with(&req.model, *total, caps, rt)
            }
            GpuPoolMode::Cost { gpu, max_count, max_money } => {
                self.search_cost_with(&req.model, *gpu, *max_count, *max_money, rt)
            }
            GpuPoolMode::HeteroCost { caps, max_money } => {
                self.search_hetero_cost_with(&req.model, caps, *max_money, rt)
            }
        }
    }

    /// Mode 1 (Eq. 1).
    pub fn search_homogeneous(
        &self,
        model: &ModelSpec,
        gpu: crate::gpu::GpuType,
        count: usize,
    ) -> Result<SearchReport> {
        self.search_homogeneous_with(model, gpu, count, None)
    }

    fn search_homogeneous_with(
        &self,
        model: &ModelSpec,
        gpu: crate::gpu::GpuType,
        count: usize,
        rt: Option<&Mutex<ScorerRuntime>>,
    ) -> Result<SearchReport> {
        let t0 = Instant::now();
        let space = SearchSpace::new(self.config.space.clone());
        if self.streaming_native(rt) {
            let tasks: Vec<PoolTask> = space
                .homogeneous_pools(model, &self.catalog, gpu, count)
                .into_iter()
                .map(|(cluster, tp, dp)| PoolTask { cluster, tp, dp })
                .collect();
            return self.stream_and_report(model, &space, tasks, t0, None);
        }
        let generated = space.homogeneous(model, &self.catalog, gpu, count);
        self.filter_and_score(model, generated, t0, None, rt)
    }

    /// Mode 2 (Eq. 2): heterogeneous pipeline partition search (§3.4).
    pub fn search_heterogeneous(
        &self,
        model: &ModelSpec,
        total: usize,
        caps: &[(crate::gpu::GpuType, usize)],
    ) -> Result<SearchReport> {
        self.search_heterogeneous_with(model, total, caps, None)
    }

    fn search_heterogeneous_with(
        &self,
        model: &ModelSpec,
        total: usize,
        caps: &[(crate::gpu::GpuType, usize)],
        rt: Option<&Mutex<ScorerRuntime>>,
    ) -> Result<SearchReport> {
        let t0 = Instant::now();
        // Canonicalize caps as a per-type map here, not just in the named
        // constructor: hand-built modes with split duplicate entries must
        // see the same budgets the fingerprint hashes, or the result cache
        // would conflate genuinely different searches.
        let caps = crate::strategy::merge_caps(caps.iter().copied());
        if caps.iter().map(|&(_, l)| l).sum::<usize>() < total {
            return Err(AstraError::Config(format!(
                "type caps sum below cluster size {total}"
            )));
        }
        let space = self.hetero_space();
        let solver = HeteroSolver::default();
        if self.streaming_native(rt) {
            let mut tasks: Vec<PoolTask> = Vec::new();
            self.hetero_pool_tasks(model, total, &caps, &space, &solver, |_, _, _| true, &mut tasks);
            return self.stream_and_report(model, &space, tasks, t0, None);
        }
        let mut generated: Vec<ParallelStrategy> = Vec::new();
        self.generate_hetero_pools(model, total, &caps, &space, &solver, |_, _, _| true, &mut generated);
        self.filter_and_score(model, generated, t0, None, rt)
    }

    /// Search space used by the heterogeneous paths: interleaving over
    /// heterogeneous segments is not supported by the Megatron runtime, so
    /// vpp is fixed to 1 (DESIGN.md §6).
    fn hetero_space(&self) -> SearchSpace {
        SearchSpace::new(SpaceConfig { vpp_candidates: vec![1], ..self.config.space.clone() })
    }

    /// Mode-2-style pool enumeration for one fixed cluster size: tp × pp ×
    /// dp splits × segment/layer assignments from the [`HeteroSolver`].
    /// `admit` sees each candidate pool `(assignment, tp, dp)` before it is
    /// emitted — the hetero-cost pruner hooks in there; mode 2 admits
    /// everything. Both the streaming fan-out and the reference generator
    /// ([`Self::generate_hetero_pools`]) consume this one enumeration, so
    /// their pool order cannot drift.
    fn hetero_pool_tasks(
        &self,
        model: &ModelSpec,
        total: usize,
        caps: &[(crate::gpu::GpuType, usize)],
        space: &SearchSpace,
        solver: &HeteroSolver,
        mut admit: impl FnMut(&ClusterAssignment, usize, usize) -> bool,
        out: &mut Vec<PoolTask>,
    ) {
        for tp in space.valid_tps(model, &self.catalog) {
            for pp in 2..=space.config.max_pp.min(model.layers).min(total / tp) {
                if total % (tp * pp) != 0 {
                    continue;
                }
                let dp = total / (tp * pp);
                let budgets = HeteroSolver::budgets(&self.catalog, caps, tp, dp);
                if budgets.iter().map(|b| b.max_stages).sum::<usize>() < pp {
                    continue;
                }
                let assignments =
                    solver.enumerate(model.layers, pp, &budgets, self.config.hetero_exhaustive);
                for ca in assignments {
                    if !admit(&ca, tp, dp) {
                        continue;
                    }
                    out.push(PoolTask { cluster: ca, tp, dp });
                }
            }
        }
    }

    /// Collected form of [`Self::hetero_pool_tasks`] for the non-streaming
    /// reference pipeline: expand every admitted pool into one flat
    /// candidate vector.
    fn generate_hetero_pools(
        &self,
        model: &ModelSpec,
        total: usize,
        caps: &[(crate::gpu::GpuType, usize)],
        space: &SearchSpace,
        solver: &HeteroSolver,
        admit: impl FnMut(&ClusterAssignment, usize, usize) -> bool,
        out: &mut Vec<ParallelStrategy>,
    ) {
        let mut tasks: Vec<PoolTask> = Vec::new();
        self.hetero_pool_tasks(model, total, caps, space, solver, admit, &mut tasks);
        for t in &tasks {
            space.expand_params(model, &t.cluster, t.tp, t.dp, out);
        }
    }

    /// Mode 3 (Eq. 3): sweep GPU counts, Pareto-pool everything, pick the
    /// fastest plan under the money ceiling (§3.6).
    pub fn search_cost(
        &self,
        model: &ModelSpec,
        gpu: crate::gpu::GpuType,
        max_count: usize,
        max_money: f64,
    ) -> Result<SearchReport> {
        self.search_cost_with(model, gpu, max_count, max_money, None)
    }

    fn search_cost_with(
        &self,
        model: &ModelSpec,
        gpu: crate::gpu::GpuType,
        max_count: usize,
        max_money: f64,
        rt: Option<&Mutex<ScorerRuntime>>,
    ) -> Result<SearchReport> {
        let t0 = Instant::now();
        validate_budget(max_money)?;
        let space = SearchSpace::new(self.config.space.clone());
        if self.streaming_native(rt) {
            // Every count's pools stream through one fan-out: the shared
            // memo carries stage profiles across the whole sweep instead
            // of rebuilding them per round.
            let mut tasks: Vec<PoolTask> = Vec::new();
            for count in SearchSpace::count_sweep(max_count) {
                tasks.extend(
                    space
                        .homogeneous_pools(model, &self.catalog, gpu, count)
                        .into_iter()
                        .map(|(cluster, tp, dp)| PoolTask { cluster, tp, dp }),
                );
            }
            return self.stream_and_report(model, &space, tasks, t0, Some(max_money));
        }
        let mut generated: Vec<ParallelStrategy> = Vec::new();
        for count in SearchSpace::count_sweep(max_count) {
            generated.extend(space.homogeneous(model, &self.catalog, gpu, count));
        }
        self.filter_and_score(model, generated, t0, Some(max_money), rt)
    }

    /// Heterogeneous money search (§3.6 fused with §3.4): sweep mixed-type
    /// cluster sizes under per-type caps, price every candidate per type
    /// per hour through the [`crate::pricing::PriceBook`], and select the
    /// fastest plan under the money ceiling. A branch-and-bound pruner
    /// ([`DominancePruner`]) skips whole pools whose bounds prove them
    /// over-budget or dominated before any strategy is expanded.
    pub fn search_hetero_cost(
        &self,
        model: &ModelSpec,
        caps: &[(crate::gpu::GpuType, usize)],
        max_money: f64,
    ) -> Result<SearchReport> {
        self.search_hetero_cost_with(model, caps, max_money, None)
    }

    fn search_hetero_cost_with(
        &self,
        model: &ModelSpec,
        caps: &[(crate::gpu::GpuType, usize)],
        max_money: f64,
        rt: Option<&Mutex<ScorerRuntime>>,
    ) -> Result<SearchReport> {
        validate_budget(max_money)?;
        // Same per-type-map canonicalization as the fingerprint (see the
        // mode-2 path above) — duplicate entries merge by summation.
        let caps = crate::strategy::merge_caps(caps.iter().copied());
        let cap_sum: usize = caps.iter().map(|&(_, c)| c).sum();
        if caps.is_empty() || cap_sum < 2 {
            return Err(AstraError::Config("hetero-cost caps admit fewer than 2 GPUs".into()));
        }
        self.searches.fetch_add(1, Ordering::Relaxed);
        let space = self.hetero_space();
        let solver = HeteroSolver::default();
        let money = &self.config.money;
        let prune = self.config.money_prune;
        let mut pruner = DominancePruner::new(max_money);
        // Power-of-two sweep plus the full pool when it is not a power of
        // two (callers stating exact caps expect the whole pool tried).
        let mut totals = SearchSpace::count_sweep(cap_sum);
        if totals.last() != Some(&cap_sum) {
            totals.push(cap_sum);
        }
        if self.streaming_native(rt) {
            return Ok(self.hetero_cost_streaming(
                model, &caps, max_money, &space, &solver, prune, pruner, &totals,
            ));
        }
        // Pre-refactor reference sweep: strictly serial rounds, full
        // candidate vector per round, per-chunk memoization. Kept as the
        // slow half of the differential harness.
        let mut n_generated = 0usize;
        let mut rule_filtered = 0usize;
        let mut mem_filtered = 0usize;
        let mut search_secs = 0.0f64;
        let mut simulate_secs = 0.0f64;
        let mut scored_all: Vec<ScoredStrategy> = Vec::new();
        // One sweep round per cluster size: earlier rounds' scored points
        // feed the pruner's dominance frontier for later rounds.
        for total in totals {
            let tgen = Instant::now();
            let mut generated: Vec<ParallelStrategy> = Vec::new();
            self.generate_hetero_pools(
                model,
                total,
                &caps,
                &space,
                &solver,
                |ca, tp, dp| {
                    if !prune {
                        return true;
                    }
                    let (ub_tput, lb_usd) =
                        money.pool_bounds(model, &ca.gpus_by_type(tp, dp), &self.catalog);
                    pruner.admit(ub_tput, lb_usd)
                },
                &mut generated,
            );
            let gen_secs = tgen.elapsed().as_secs_f64();
            n_generated += generated.len();
            let (rf, mf, scored, filter_secs, score_secs) =
                self.score_candidates(model, generated, rt)?;
            rule_filtered += rf;
            mem_filtered += mf;
            search_secs += gen_secs + filter_secs;
            simulate_secs += score_secs;
            for s in &scored {
                pruner.observe(s.cost.tokens_per_s, s.money_usd);
            }
            scored_all.extend(scored);
        }
        Ok(self.assemble_report(
            n_generated,
            rule_filtered,
            mem_filtered,
            pruner.pruned(),
            search_secs,
            simulate_secs,
            Some(max_money),
            MemoStats::default(),
            scored_all,
        ))
    }

    /// The parallel hetero-cost sweep: pool totals are processed in
    /// *speculative waves* of `config.sweep_wave` consecutive rounds.
    ///
    /// Phase 1 (serial, cheap) enumerates each round's candidate pools
    /// with their branch-and-bound bounds and admits them *speculatively*
    /// against a snapshot of the dominance frontier taken at the wave
    /// start. Phase 2 (parallel) streams every speculatively admitted pool
    /// of the wave — across totals — through the fused expand/filter/score
    /// pass. Phase 3 (serial) replays the admissions in round order
    /// against the true running frontier, observing each round's accepted
    /// strategies before the next round's decisions, and discards the
    /// outcomes of pools the true frontier rejects (bounded speculation
    /// waste, the price of cross-total parallelism).
    ///
    /// Because snapshot coverage is a subset of every later frontier's
    /// coverage, speculation only ever *over*-admits — so the replay has an
    /// outcome for every pool it accepts, and the reported counts, pruning
    /// statistics, frontier and picks are byte-identical to the serial
    /// sweep (`sweep_wave = 1`) at any wave size or worker count.
    ///
    /// The wave size is *adaptive*: after a wave whose speculative
    /// admissions all survived the replay (zero waste), the next wave grows
    /// by one total, up to `config.sweep_wave_max`; any waste resets it to
    /// the configured base. Waste is a pure function of the deterministic
    /// frontier evolution, so the schedule — like the wave size itself —
    /// can never reach the report.
    #[allow(clippy::too_many_arguments)]
    fn hetero_cost_streaming(
        &self,
        model: &ModelSpec,
        caps: &[(crate::gpu::GpuType, usize)],
        max_money: f64,
        space: &SearchSpace,
        solver: &HeteroSolver,
        prune: bool,
        mut pruner: DominancePruner,
        totals: &[usize],
    ) -> SearchReport {
        let memo = self.memos.for_model(model);
        let money = &self.config.money;
        let base_wave = self.config.sweep_wave.max(1);
        let wave_cap = self.config.sweep_wave_max.max(base_wave);
        let mut wave = base_wave;
        let mut n_generated = 0usize;
        let mut rule_filtered = 0usize;
        let mut mem_filtered = 0usize;
        let mut search_secs = 0.0f64;
        let mut simulate_secs = 0.0f64;
        let mut memo_stats = MemoStats::default();
        let mut scored_all: Vec<ScoredStrategy> = Vec::new();
        let mut next = 0usize;
        while next < totals.len() {
            let wave_totals = &totals[next..totals.len().min(next + wave)];
            next += wave_totals.len();
            let t_gen = Instant::now();
            let snapshot = pruner.clone();
            // Phase 1: per round, every pool's (ub tput, lb USD, admitted
            // vs snapshot); speculatively admitted pools append to one
            // flat task list in (round, pool) order.
            let mut rounds: Vec<Vec<(f64, f64, bool)>> = Vec::with_capacity(wave_totals.len());
            let mut tasks: Vec<PoolTask> = Vec::new();
            for &total in wave_totals {
                let mut meta: Vec<(f64, f64, bool)> = Vec::new();
                self.hetero_pool_tasks(
                    model,
                    total,
                    caps,
                    space,
                    solver,
                    |ca, tp, dp| {
                        let (ub, lb) = if prune {
                            money.pool_bounds(model, &ca.gpus_by_type(tp, dp), &self.catalog)
                        } else {
                            (f64::INFINITY, 0.0)
                        };
                        let spec = !prune || snapshot.would_admit(ub, lb);
                        meta.push((ub, lb, spec));
                        spec
                    },
                    &mut tasks,
                );
                rounds.push(meta);
            }
            let gen_secs = t_gen.elapsed().as_secs_f64();

            // Phase 2: one parallel streaming pass over the whole wave.
            let t_run = Instant::now();
            let mut outcomes = self.stream_pools(model, space, &tasks, &memo);
            let wall = t_run.elapsed().as_secs_f64();

            // Phase 3: deterministic serial replay of the admissions.
            let (mut filter_busy, mut score_busy) = (0.0f64, 0.0f64);
            let mut oc_idx = 0usize;
            let mut wasted = 0usize;
            for meta in &rounds {
                let mut round_scored: Vec<ScoredStrategy> = Vec::new();
                for &(ub, lb, spec) in meta {
                    let admit = !prune || pruner.admit(ub, lb);
                    if !spec {
                        debug_assert!(!admit, "snapshot admitted what the frontier rejects");
                        continue;
                    }
                    let oc = &mut outcomes[oc_idx];
                    oc_idx += 1;
                    filter_busy += oc.filter_secs;
                    score_busy += oc.score_secs;
                    if !admit {
                        // Speculation waste: scored in phase 2, pruned by
                        // the true frontier — dropped so the report matches
                        // the serial sweep exactly.
                        wasted += 1;
                        continue;
                    }
                    n_generated += oc.generated;
                    rule_filtered += oc.rule_filtered;
                    mem_filtered += oc.mem_filtered;
                    memo_stats.merge(oc.memo);
                    round_scored.append(&mut oc.scored);
                }
                // Observe only after the round completes, exactly like the
                // serial sweep: admissions within a round never see the
                // round's own strategies.
                for s in &round_scored {
                    pruner.observe(s.cost.tokens_per_s, s.money_usd);
                }
                scored_all.extend(round_scored);
            }
            let busy = filter_busy + score_busy;
            if busy > 0.0 {
                search_secs += gen_secs + wall * filter_busy / busy;
                simulate_secs += wall * score_busy / busy;
            } else {
                search_secs += gen_secs + wall;
            }
            // Adaptive schedule: grow while speculation is free, reset to
            // the base on the first wasted pool.
            wave = if wasted == 0 { (wave + 1).min(wave_cap) } else { base_wave };
        }
        self.assemble_report(
            n_generated,
            rule_filtered,
            mem_filtered,
            pruner.pruned(),
            search_secs,
            simulate_secs,
            Some(max_money),
            memo_stats,
            scored_all,
        )
    }

    /// The fused streaming pass: expand → rule filter → memory filter →
    /// score, one pool per work item on the scoped worker pool, scoring
    /// through the shared memo. No candidate vector is ever materialized —
    /// each strategy goes from the generator's visitor straight through the
    /// filters into (at most) one `ScoredStrategy`. `par_for_indices`
    /// returns outcomes in task order whatever the worker count, so
    /// downstream ranking is deterministic.
    fn stream_pools(
        &self,
        model: &ModelSpec,
        space: &SearchSpace,
        tasks: &[PoolTask],
        memo: &SharedCostMemo,
    ) -> Vec<PoolOutcome> {
        let rules = &self.config.rules;
        let catalog = &self.catalog;
        let cost = &self.cost;
        let money = &self.config.money;
        let mem = MemoryModel::default();
        par_for_indices(tasks.len(), self.config.workers, |i| {
            let task = &tasks[i];
            let mut oc = PoolOutcome::default();
            let t_pool = Instant::now();
            space.expand_params_each(model, &task.cluster, task.tp, task.dp, &mut |s| {
                oc.generated += 1;
                if rules.filters_out(&s).unwrap_or(true) {
                    oc.rule_filtered += 1;
                    return;
                }
                if !mem.fits(model, &s, catalog) {
                    oc.mem_filtered += 1;
                    return;
                }
                let t_score = Instant::now();
                let breakdown = cost.evaluate_shared(model, &s, memo, &mut oc.memo);
                let money_usd = money.cost_usd(model, &s, catalog, breakdown.step_time);
                oc.score_secs += t_score.elapsed().as_secs_f64();
                oc.scored.push(ScoredStrategy { strategy: s, cost: breakdown, money_usd });
            });
            oc.filter_secs = (t_pool.elapsed().as_secs_f64() - oc.score_secs).max(0.0);
            oc
        })
    }

    /// Streaming-path tail for the single-sweep modes (1, 2 and 3): fan the
    /// pool tasks out, aggregate, assemble. `t0` anchors the task
    /// enumeration share of "Search Time".
    fn stream_and_report(
        &self,
        model: &ModelSpec,
        space: &SearchSpace,
        tasks: Vec<PoolTask>,
        t0: Instant,
        budget: Option<f64>,
    ) -> Result<SearchReport> {
        self.searches.fetch_add(1, Ordering::Relaxed);
        let memo = self.memos.for_model(model);
        let setup_secs = t0.elapsed().as_secs_f64();
        let t_run = Instant::now();
        let outcomes = self.stream_pools(model, space, &tasks, &memo);
        let batch = StreamedBatch::collect(outcomes, t_run.elapsed().as_secs_f64());
        Ok(self.assemble_report(
            batch.generated,
            batch.rule_filtered,
            batch.mem_filtered,
            0,
            setup_secs + batch.search_secs,
            batch.simulate_secs,
            budget,
            batch.memo,
            batch.scored,
        ))
    }

    /// Shared tail: rules → memory → scoring → ranking (bumps the search
    /// counter and assembles the report; `t0` anchors "Search Time";
    /// `budget` triggers the mode-3 within-budget promotion).
    fn filter_and_score(
        &self,
        model: &ModelSpec,
        generated: Vec<ParallelStrategy>,
        t0: Instant,
        budget: Option<f64>,
        rt: Option<&Mutex<ScorerRuntime>>,
    ) -> Result<SearchReport> {
        self.searches.fetch_add(1, Ordering::Relaxed);
        let n_generated = generated.len();
        let t_call = Instant::now();
        let (rule_filtered, mem_filtered, scored, filter_secs, simulate_secs) =
            self.score_candidates(model, generated, rt)?;
        let search_secs = t_call.duration_since(t0).as_secs_f64() + filter_secs;
        Ok(self.assemble_report(
            n_generated,
            rule_filtered,
            mem_filtered,
            0,
            search_secs,
            simulate_secs,
            budget,
            MemoStats::default(),
            scored,
        ))
    }

    /// Filter + score one candidate batch without touching counters or
    /// assembling a report (the hetero-cost sweep calls this once per
    /// round). Returns `(rule_filtered, mem_filtered, scored strategies,
    /// filter wall secs, scoring wall secs)`.
    fn score_candidates(
        &self,
        model: &ModelSpec,
        generated: Vec<ParallelStrategy>,
        rt: Option<&Mutex<ScorerRuntime>>,
    ) -> Result<(usize, usize, Vec<ScoredStrategy>, f64, f64)> {
        let n_generated = generated.len();
        let workers = self.config.workers;
        let t0 = Instant::now();

        // --- rule filter (Eq. 10) ---
        let rules = &self.config.rules;
        let rule_keep: Vec<bool> = par_map_chunks(&generated, workers, |_, chunk| {
            chunk.iter().map(|s| !rules.filters_out(s).unwrap_or(true)).collect()
        });
        let after_rules: Vec<ParallelStrategy> = generated
            .into_iter()
            .zip(&rule_keep)
            .filter_map(|(s, &keep)| keep.then_some(s))
            .collect();
        let rule_filtered = n_generated - after_rules.len();

        // --- memory filter (Eq. 20/21) ---
        let mem = MemoryModel::default();
        let catalog = &self.catalog;
        let mem_keep: Vec<bool> = par_map_chunks(&after_rules, workers, |_, chunk| {
            chunk.iter().map(|s| mem.fits(model, s, catalog)).collect()
        });
        let valid: Vec<ParallelStrategy> = after_rules
            .into_iter()
            .zip(&mem_keep)
            .filter_map(|(s, &keep)| keep.then_some(s))
            .collect();
        let mem_filtered = n_generated - rule_filtered - valid.len();
        let filter_secs = t0.elapsed().as_secs_f64();

        // --- cost simulation (§3.5) ---
        let t1 = Instant::now();
        let costs: Vec<CostBreakdown> = match rt {
            Some(rt) if self.config.engine == ScoringEngine::Hlo => {
                self.score_hlo(model, &valid, rt)?
            }
            _ => {
                // Capture only the Sync cost model, not &self (the PJRT
                // runtime handle is intentionally thread-confined). Each
                // chunk scores through a memoized batch — strategies share
                // stage profiles massively (§Perf).
                let cost = &self.cost;
                par_map_chunks(&valid, workers, |_, chunk| {
                    let refs: Vec<&ParallelStrategy> = chunk.iter().collect();
                    cost.evaluate_batch(model, &refs)
                })
            }
        };
        let simulate_secs = t1.elapsed().as_secs_f64();

        // --- pricing (Eq. 32) ---
        let money = &self.config.money;
        let scored: Vec<ScoredStrategy> = valid
            .into_iter()
            .zip(costs)
            .map(|(strategy, cost)| {
                let money_usd = money.cost_usd(model, &strategy, catalog, cost.step_time);
                ScoredStrategy { strategy, cost, money_usd }
            })
            .collect();
        Ok((rule_filtered, mem_filtered, scored, filter_secs, simulate_secs))
    }

    /// Pool construction + ranking tail shared by every mode. With a
    /// `budget`, the fastest within-budget plan is promoted to `top[0]`
    /// (Eq. 33 selection) *before* truncation, so the pick survives even
    /// when `top_k` faster-but-over-budget plans exist.
    #[allow(clippy::too_many_arguments)]
    fn assemble_report(
        &self,
        generated: usize,
        rule_filtered: usize,
        mem_filtered: usize,
        pruned_pools: usize,
        search_secs: f64,
        simulate_secs: f64,
        budget: Option<f64>,
        memo: MemoStats,
        mut scored: Vec<ScoredStrategy>,
    ) -> SearchReport {
        let pool = OptimalPool::build(
            scored
                .iter()
                .enumerate()
                .map(|(idx, s)| PoolEntry {
                    idx,
                    throughput: s.cost.tokens_per_s,
                    cost: s.money_usd,
                })
                .collect(),
        );
        let n_scored = scored.len();
        scored.sort_by(|a, b| a.cost.step_time.partial_cmp(&b.cost.step_time).unwrap());
        if let Some(b) = budget {
            // Step-time ascending is throughput descending (tokens/step is
            // fixed per model), so the first within-budget entry is the
            // fastest affordable plan.
            if let Some(pos) = scored.iter().position(|s| s.money_usd <= b) {
                if pos > 0 {
                    let pick = scored.remove(pos);
                    scored.insert(0, pick);
                }
            }
        }
        scored.truncate(self.config.top_k);
        SearchReport {
            generated,
            rule_filtered,
            mem_filtered,
            scored: n_scored,
            pruned_pools,
            search_secs,
            simulate_secs,
            memo_hits: memo.hits,
            memo_misses: memo.misses,
            top: scored,
            pool,
        }
    }

    /// Score through the PJRT executable, chunked to the artifact's batch.
    fn score_hlo(
        &self,
        model: &ModelSpec,
        valid: &[ParallelStrategy],
        rt: &Mutex<ScorerRuntime>,
    ) -> Result<Vec<CostBreakdown>> {
        let batch = rt.lock().unwrap().batch;
        let n_chunks = valid.len().div_ceil(batch.max(1));
        let chunks: Vec<&[ParallelStrategy]> = valid.chunks(batch).collect();
        // PJRT executables are not Sync-safe to share blindly; packing is
        // parallel, execution serialized through the mutex.
        let catalog = &self.catalog;
        let packed = par_for_indices(n_chunks, self.config.workers, |i| {
            let refs: Vec<&ParallelStrategy> = chunks[i].iter().collect();
            pack_batch(model, &refs, catalog, batch)
        });
        let mut out = Vec::with_capacity(valid.len());
        for (i, pb) in packed.iter().enumerate() {
            let rows: Vec<[f32; OUT]> = rt
                .lock()
                .unwrap()
                .execute(&pb.stage_feats, &pb.stage_mask, &pb.strat_feats)?;
            for (j, s) in chunks[i].iter().enumerate() {
                let r = rows[j];
                let step_time = r[0] as f64;
                let tokens = (s.global_batch * model.seq_len) as f64;
                out.push(CostBreakdown {
                    stage_times: Vec::new(),
                    pipeline_fwd: 0.0,
                    pipeline_bwd: r[1] as f64,
                    dp_time: r[2] as f64,
                    optimizer_time: r[3] as f64,
                    offload_time: 0.0,
                    step_time,
                    tokens_per_s: tokens / step_time,
                    mfu: 0.0,
                });
            }
        }
        Ok(out)
    }
}

/// The engine: a [`ScoringCore`] plus the optional thread-confined HLO
/// runtime. Use this from single-owner contexts (CLI, benches); use
/// [`ScoringCore`] (or [`crate::service::SearchService`]) when the engine
/// must be shared across threads.
pub struct AstraEngine {
    core: ScoringCore,
    runtime: Option<Mutex<ScorerRuntime>>,
}

impl AstraEngine {
    /// Build an engine; loads `artifacts/forest.json` (η forests) and — for
    /// the HLO engine — `artifacts/scorer.hlo.txt`.
    pub fn new(catalog: GpuCatalog, config: EngineConfig) -> Self {
        let runtime = if config.engine == ScoringEngine::Hlo {
            match ScorerRuntime::load(&crate::runtime::artifacts_dir()) {
                Ok(rt) => Some(Mutex::new(rt)),
                Err(e) => {
                    crate::log_warn!("HLO scorer unavailable ({e}); using native engine");
                    None
                }
            }
        } else {
            None
        };
        AstraEngine { core: ScoringCore::new(catalog, config), runtime }
    }

    /// The shared, `Sync` part of the engine.
    pub fn core(&self) -> &ScoringCore {
        &self.core
    }

    /// Take the core out (drops the HLO runtime); used to hand the engine
    /// to the multi-threaded service layer.
    pub fn into_core(self) -> ScoringCore {
        self.core
    }

    /// Immutable access to the underlying cost model (tests/benches).
    pub fn cost_model(&self) -> &CostModel {
        self.core.cost_model()
    }

    /// Whether the HLO engine is actually live.
    pub fn hlo_active(&self) -> bool {
        self.runtime.is_some()
    }

    /// Run a search request (mode dispatch).
    pub fn search(&self, req: &SearchRequest) -> Result<SearchReport> {
        self.core.search_with(req, self.runtime.as_ref())
    }

    /// Mode 1 (Eq. 1).
    pub fn search_homogeneous(
        &self,
        model: &ModelSpec,
        gpu: crate::gpu::GpuType,
        count: usize,
    ) -> Result<SearchReport> {
        self.core.search_homogeneous_with(model, gpu, count, self.runtime.as_ref())
    }

    /// Mode 2 (Eq. 2): heterogeneous pipeline partition search (§3.4).
    pub fn search_heterogeneous(
        &self,
        model: &ModelSpec,
        total: usize,
        caps: &[(crate::gpu::GpuType, usize)],
    ) -> Result<SearchReport> {
        self.core.search_heterogeneous_with(model, total, caps, self.runtime.as_ref())
    }

    /// Mode 3 (Eq. 3).
    pub fn search_cost(
        &self,
        model: &ModelSpec,
        gpu: crate::gpu::GpuType,
        max_count: usize,
        max_money: f64,
    ) -> Result<SearchReport> {
        self.core.search_cost_with(model, gpu, max_count, max_money, self.runtime.as_ref())
    }

    /// Heterogeneous money search (mode 3 over mixed pools).
    pub fn search_hetero_cost(
        &self,
        model: &ModelSpec,
        caps: &[(crate::gpu::GpuType, usize)],
        max_money: f64,
    ) -> Result<SearchReport> {
        self.core.search_hetero_cost_with(model, caps, max_money, self.runtime.as_ref())
    }
}

impl std::ops::Deref for AstraEngine {
    type Target = ScoringCore;

    fn deref(&self) -> &ScoringCore {
        &self.core
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelRegistry;

    fn engine() -> AstraEngine {
        AstraEngine::new(
            GpuCatalog::builtin(),
            EngineConfig { use_forests: false, ..Default::default() },
        )
    }

    #[test]
    fn homogeneous_search_finds_valid_best() {
        let reg = ModelRegistry::builtin();
        let model = reg.get("llama2-7b").unwrap().clone();
        let req = SearchRequest::homogeneous("a800", 64, model.clone()).unwrap();
        let report = engine().search(&req).unwrap();
        assert!(report.generated > 1000);
        assert!(report.scored > 0);
        assert_eq!(report.generated, report.rule_filtered + report.mem_filtered + report.scored);
        let best = report.best().unwrap();
        best.strategy.validate(&model).unwrap();
        assert!(best.cost.tokens_per_s > 0.0);
        // Best-first ordering.
        for w in report.top.windows(2) {
            assert!(w[0].cost.step_time <= w[1].cost.step_time);
        }
    }

    #[test]
    fn filters_actually_fire() {
        let reg = ModelRegistry::builtin();
        let model = reg.get("llama2-70b").unwrap().clone();
        let req = SearchRequest::homogeneous("a800", 64, model).unwrap();
        let report = engine().search(&req).unwrap();
        assert!(report.rule_filtered > 0, "rule filter idle");
        assert!(report.mem_filtered > 0, "memory filter idle (70B must OOM somewhere)");
    }

    #[test]
    fn bad_gpu_names_are_recoverable_errors() {
        let reg = ModelRegistry::builtin();
        let model = reg.get("llama2-7b").unwrap().clone();
        assert!(SearchRequest::homogeneous("b200", 64, model.clone()).is_err());
        assert!(SearchRequest::heterogeneous(&[("a800", 32), ("nope", 32)], 64, model.clone())
            .is_err());
        assert!(SearchRequest::cost("gtx1080", 64, 1e9, model).is_err());
    }

    #[test]
    fn hetero_constructor_resolves_names() {
        let reg = ModelRegistry::builtin();
        let model = reg.get("llama2-7b").unwrap().clone();
        let req =
            SearchRequest::heterogeneous(&[("a800", 48), ("h100", 48)], 64, model).unwrap();
        match &req.mode {
            GpuPoolMode::Heterogeneous { total, caps } => {
                assert_eq!(*total, 64);
                assert_eq!(caps.len(), 2);
            }
            other => panic!("wrong mode {other:?}"),
        }
    }

    #[test]
    fn cost_mode_respects_budget() {
        let reg = ModelRegistry::builtin();
        let cat = GpuCatalog::builtin();
        let model = reg.get("llama2-7b").unwrap().clone();
        let gpu = cat.find("h100").unwrap();
        let eng = engine();
        let rep = eng
            .search(&SearchRequest {
                mode: GpuPoolMode::Cost { gpu, max_count: 64, max_money: f64::INFINITY },
                model: model.clone(),
            })
            .unwrap();
        assert!(!rep.pool.is_empty());
        assert!(rep.pool.is_valid_frontier());
        // A tight budget must select a cheaper (≤) plan than an infinite one.
        let cheap = rep.pool.entries().last().unwrap().cost * 1.01;
        let pick = rep.pool.best_within_budget(cheap).unwrap();
        assert!(pick.cost <= cheap);
    }

    #[test]
    fn hetero_search_produces_mixed_assignments() {
        let reg = ModelRegistry::builtin();
        let cat = GpuCatalog::builtin();
        let model = reg.get("llama2-7b").unwrap().clone();
        let caps = vec![(cat.find("a800").unwrap(), 48), (cat.find("h100").unwrap(), 48)];
        let eng = engine();
        let rep = eng
            .search(&SearchRequest {
                mode: GpuPoolMode::Heterogeneous { total: 64, caps },
                model,
            })
            .unwrap();
        assert!(rep.scored > 0, "no valid hetero strategies");
        // The pool contains at least one genuinely mixed assignment.
        assert!(rep.top.iter().any(|s| s.strategy.cluster.is_heterogeneous()));
    }

    #[test]
    fn best_beats_median_noticeably() {
        // Search must actually discriminate: best ≥ 1.5× median throughput.
        let reg = ModelRegistry::builtin();
        let model = reg.get("llama2-13b").unwrap().clone();
        let eng = AstraEngine::new(
            GpuCatalog::builtin(),
            EngineConfig { use_forests: false, top_k: usize::MAX, ..Default::default() },
        );
        let rep = eng
            .search(&SearchRequest::homogeneous("a800", 128, model).unwrap())
            .unwrap();
        let tputs: Vec<f64> = rep.top.iter().map(|s| s.cost.tokens_per_s).collect();
        let best = tputs[0];
        let median = tputs[tputs.len() / 2];
        assert!(best > 1.1 * median, "best {best:.0} vs median {median:.0}");
    }

    #[test]
    fn bad_budgets_are_recoverable_errors() {
        let reg = ModelRegistry::builtin();
        let model = reg.get("llama2-7b").unwrap().clone();
        for bad in [f64::NAN, 0.0, -1.0, f64::NEG_INFINITY] {
            assert!(
                SearchRequest::cost("a800", 64, bad, model.clone()).is_err(),
                "cost accepted budget {bad}"
            );
            assert!(
                SearchRequest::hetero_cost(&[("a800", 16)], bad, model.clone()).is_err(),
                "hetero_cost accepted budget {bad}"
            );
        }
        // +inf means "no ceiling" and must keep working.
        assert!(SearchRequest::cost("a800", 64, f64::INFINITY, model.clone()).is_ok());
        // Hand-built modes cannot smuggle a bad budget past the engine.
        let eng = engine();
        let gpu = GpuCatalog::builtin().find("a800").unwrap();
        let hand = SearchRequest {
            mode: GpuPoolMode::Cost { gpu, max_count: 16, max_money: f64::NAN },
            model,
        };
        assert!(eng.search(&hand).is_err());
    }

    /// Narrowed space so the hetero-cost tests stay fast in debug profile.
    fn small_engine() -> AstraEngine {
        let space = crate::strategy::SpaceConfig {
            tp_candidates: vec![1, 2],
            max_pp: 4,
            mbs_candidates: vec![1, 2],
            vpp_candidates: vec![1],
            seq_parallel_options: vec![true],
            dist_opt_options: vec![true],
            offload_options: vec![false],
            recompute_none: true,
            recompute_selective: false,
            recompute_full: false,
            ..crate::strategy::SpaceConfig::default()
        };
        AstraEngine::new(
            GpuCatalog::builtin(),
            EngineConfig { use_forests: false, space, ..Default::default() },
        )
    }

    #[test]
    fn hetero_cost_search_prices_mixed_pools() {
        let reg = ModelRegistry::builtin();
        let cat = GpuCatalog::builtin();
        let model = reg.get("llama2-7b").unwrap().clone();
        let caps = [("a800", 16usize), ("h100", 16usize)];
        let req =
            SearchRequest::hetero_cost(&caps, f64::INFINITY, model.clone()).unwrap();
        let rep = small_engine().search(&req).unwrap();
        assert!(rep.scored > 0, "no valid hetero-cost strategies");
        assert!(!rep.pool.is_empty());
        assert!(rep.pool.is_valid_frontier());
        // Mixed assignments survive into the ranking, and every plan's
        // per-type usage respects the caps.
        assert!(rep.top.iter().any(|s| s.strategy.cluster.is_heterogeneous()));
        let by_name: Vec<(crate::gpu::GpuType, usize)> =
            caps.iter().map(|&(n, c)| (cat.find(n).unwrap(), c)).collect();
        for s in &rep.top {
            s.strategy.validate(&model).unwrap();
            for (g, n) in s.strategy.cluster.gpus_by_type(s.strategy.tp, s.strategy.dp) {
                let cap = by_name
                    .iter()
                    .find(|&&(t, _)| t == g)
                    .unwrap_or_else(|| panic!("unexpected type {g}"))
                    .1;
                assert!(n <= cap, "type {g} uses {n} > cap {cap}");
            }
            assert!(s.money_usd.is_finite() && s.money_usd > 0.0);
        }
    }

    #[test]
    fn hand_built_duplicate_caps_merge_in_engine() {
        // Split duplicate cap entries must see the same budgets the
        // fingerprint hashes — otherwise the service cache would conflate
        // genuinely different searches.
        let reg = ModelRegistry::builtin();
        let model = reg.get("llama2-7b").unwrap().clone();
        let cat = GpuCatalog::builtin();
        let a800 = cat.find("a800").unwrap();
        let h100 = cat.find("h100").unwrap();
        let eng = small_engine();
        let search = |caps: Vec<(crate::gpu::GpuType, usize)>| {
            eng.search(&SearchRequest {
                mode: GpuPoolMode::HeteroCost { caps, max_money: f64::INFINITY },
                model: model.clone(),
            })
            .unwrap()
        };
        let split = search(vec![(a800, 4), (h100, 8), (a800, 4)]);
        let merged = search(vec![(a800, 8), (h100, 8)]);
        assert_eq!(split.generated, merged.generated);
        assert_eq!(split.pool.len(), merged.pool.len());
        for (x, y) in split.pool.entries().iter().zip(merged.pool.entries()) {
            assert!(
                (x.throughput - y.throughput).abs() < 1e-9 && (x.cost - y.cost).abs() < 1e-9,
                "split/merged caps diverged: {x:?} vs {y:?}"
            );
        }
    }

    #[test]
    fn hetero_cost_budget_prunes_and_still_selects_within_budget() {
        let reg = ModelRegistry::builtin();
        let model = reg.get("llama2-7b").unwrap().clone();
        let eng = small_engine();
        // v100s are ~3× pricier per effective FLOP than h100s here, so a
        // budget near the frontier's cheap end provably strands the
        // v100-heavy pools above their lower bound.
        let caps = [("a800", 8usize), ("h100", 8usize), ("v100", 8usize)];
        // First pass without a ceiling to learn the cost scale.
        let free = eng
            .search(&SearchRequest::hetero_cost(&caps, f64::INFINITY, model.clone()).unwrap())
            .unwrap();
        assert!(!free.pool.is_empty());
        let cheap = free.pool.entries().last().unwrap().cost;
        let budget = cheap * 1.05;
        let tight = eng
            .search(&SearchRequest::hetero_cost(&caps, budget, model).unwrap())
            .unwrap();
        // The ceiling must actually cut the space…
        assert!(tight.pruned_pools > 0, "tight budget pruned nothing");
        assert!(tight.generated < free.generated, "pruning generated no savings");
        // …and the selected plan must respect it.
        let pick = tight.best().expect("no plan under budget");
        assert!(
            pick.money_usd <= budget * (1.0 + 1e-9),
            "pick ${} > budget ${budget}",
            pick.money_usd
        );
    }

    #[test]
    fn streaming_reports_memo_counters_and_warms_up() {
        let reg = ModelRegistry::builtin();
        let model = reg.get("llama2-7b").unwrap().clone();
        let eng = engine(); // streaming is the default
        let req = SearchRequest::homogeneous("a800", 16, model.clone()).unwrap();
        let cold = eng.search(&req).unwrap();
        assert!(cold.memo_hits + cold.memo_misses > 0, "streaming path must count memo traffic");
        assert!(cold.memo_misses > 0, "a fresh memo must miss");
        let warm = eng.search(&req).unwrap();
        assert_eq!(warm.memo_misses, 0, "second identical search must be fully memo-warm");
        assert!(warm.memo_hits > 0);
        // Warmth is observability only — results are unchanged.
        assert_eq!(cold.generated, warm.generated);
        assert_eq!(cold.scored, warm.scored);
        assert_eq!(
            cold.best().unwrap().cost.step_time.to_bits(),
            warm.best().unwrap().cost.step_time.to_bits()
        );
        // Per-report deltas reconcile with the scope's lifetime counters
        // (both searches hit the same registry scope for this model).
        let scope = eng.core().memo_for(&model);
        assert_eq!(scope.hits(), cold.memo_hits + warm.memo_hits);
        assert_eq!(scope.misses(), cold.memo_misses + warm.memo_misses);
        let (scopes, hits, misses) = eng.core().memo_counters();
        assert_eq!(scopes, 1);
        assert_eq!((hits, misses), (scope.hits(), scope.misses()));
    }

    #[test]
    fn reference_path_reports_zero_memo_counters() {
        let reg = ModelRegistry::builtin();
        let model = reg.get("llama2-7b").unwrap().clone();
        let eng = AstraEngine::new(
            GpuCatalog::builtin(),
            EngineConfig { use_forests: false, streaming: false, ..Default::default() },
        );
        let rep = eng.search(&SearchRequest::homogeneous("a800", 16, model).unwrap()).unwrap();
        assert_eq!((rep.memo_hits, rep.memo_misses), (0, 0));
        assert!(rep.scored > 0);
    }

    #[test]
    fn streaming_matches_reference_counts_and_best() {
        let reg = ModelRegistry::builtin();
        let model = reg.get("llama2-7b").unwrap().clone();
        let mk = |streaming: bool| {
            AstraEngine::new(
                GpuCatalog::builtin(),
                EngineConfig { use_forests: false, streaming, ..Default::default() },
            )
        };
        let req = SearchRequest::homogeneous("a800", 32, model).unwrap();
        let fast = mk(true).search(&req).unwrap();
        let slow = mk(false).search(&req).unwrap();
        assert_eq!(fast.generated, slow.generated);
        assert_eq!(fast.rule_filtered, slow.rule_filtered);
        assert_eq!(fast.mem_filtered, slow.mem_filtered);
        assert_eq!(fast.scored, slow.scored);
        assert_eq!(fast.top.len(), slow.top.len());
        for (a, b) in fast.top.iter().zip(&slow.top) {
            assert_eq!(a.strategy, b.strategy, "streaming selected different strategies");
            assert_eq!(a.cost.step_time.to_bits(), b.cost.step_time.to_bits());
            assert_eq!(a.money_usd.to_bits(), b.money_usd.to_bits());
        }
    }

    #[test]
    fn search_counter_tracks_pipeline_entries() {
        let reg = ModelRegistry::builtin();
        let model = reg.get("llama2-7b").unwrap().clone();
        let eng = engine();
        assert_eq!(eng.core().searches_run(), 0);
        let req = SearchRequest::homogeneous("a800", 64, model).unwrap();
        eng.search(&req).unwrap();
        eng.search(&req).unwrap();
        assert_eq!(eng.core().searches_run(), 2);
    }
}
