//! The Astra engine — Layer-3 coordinator tying the whole pipeline together
//! (paper Fig. 2): input preprocess → search-space generation → rule filter
//! → memory filter → cost simulation → selection (throughput or money).
//!
//! Scoring runs on one of two engines with identical math:
//!
//! * `native` — the pure-rust [`CostModel`] (η from GBDT forests when
//!   `artifacts/forest.json` exists, hardware-truth curves otherwise);
//! * `hlo` — the AOT-compiled Layer-2 scorer executed through PJRT
//!   ([`crate::runtime::ScorerRuntime`]), exercising the Pallas kernels.
//!
//! Search is fanned out over a scoped thread pool; the per-phase wall times
//! reported in [`SearchReport`] correspond to Table 1's "Search Time" and
//! "Simulation Time" columns.
//!
//! ## Engine anatomy: [`ScoringCore`] vs [`AstraEngine`]
//!
//! The PJRT executable handle is thread-confined (the `xla` wrappers are
//! neither `Send` nor `Sync`), which would make the whole engine unshareable
//! across threads. The state the native pipeline actually needs — catalog,
//! config, cost model — is plain data, so it lives in [`ScoringCore`], a
//! `Sync` scoring entry point that one process can share across many
//! concurrent requests (this is what [`crate::service`] fans out over).
//! [`AstraEngine`] is `ScoringCore` plus the optional HLO runtime; it keeps
//! the historical single-owner API and is what the CLI constructs.

use crate::cost::features::{pack_batch, OUT};
use crate::cost::{CostBreakdown, CostModel, EtaProvider};
use crate::gbdt::EtaForests;
use crate::gpu::GpuCatalog;
use crate::hetero::HeteroSolver;
use crate::memory::MemoryModel;
use crate::model::ModelSpec;
use crate::pareto::{MoneyModel, OptimalPool, PoolEntry};
use crate::pool::{default_workers, par_for_indices, par_map_chunks};
use crate::rules::RuleSet;
use crate::runtime::ScorerRuntime;
use crate::strategy::{GpuPoolMode, ParallelStrategy, SearchSpace, SpaceConfig};
use crate::{AstraError, Result};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// Which scorer executes the cost simulation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScoringEngine {
    Native,
    Hlo,
}

/// Engine configuration.
pub struct EngineConfig {
    pub space: SpaceConfig,
    pub rules: RuleSet,
    pub engine: ScoringEngine,
    /// Use GBDT forests for η when available (`artifacts/forest.json`).
    pub use_forests: bool,
    pub workers: usize,
    pub money: MoneyModel,
    /// Exhaustive Eq. 23 layer enumeration instead of the pruned solver.
    pub hetero_exhaustive: bool,
    /// Keep this many best strategies in the report.
    pub top_k: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            space: SpaceConfig::default(),
            rules: RuleSet::paper_defaults(),
            engine: ScoringEngine::Native,
            use_forests: true,
            workers: default_workers(),
            money: MoneyModel::default(),
            hetero_exhaustive: false,
            top_k: 16,
        }
    }
}

/// A search request: model + GPU-pool mode (§3.2 input integration, Eq. 7).
#[derive(Debug, Clone)]
pub struct SearchRequest {
    pub mode: GpuPoolMode,
    pub model: ModelSpec,
}

impl SearchRequest {
    /// Mode 1 (Eq. 1): one GPU type, fixed count. Unknown GPU names are a
    /// recoverable [`AstraError::Config`] (service requests must not abort
    /// the process).
    pub fn homogeneous(gpu_name: &str, count: usize, model: ModelSpec) -> Result<SearchRequest> {
        let catalog = GpuCatalog::builtin();
        let gpu = catalog.find(gpu_name)?;
        Ok(SearchRequest { mode: GpuPoolMode::Homogeneous { gpu, count }, model })
    }

    /// Mode 2 (Eq. 2): total cluster size + per-type caps, named by GPU.
    /// Caps are a per-type *map*: duplicate entries of the same type merge
    /// by summation (matching the JSON wire form, which is an object).
    pub fn heterogeneous(
        caps: &[(&str, usize)],
        total: usize,
        model: ModelSpec,
    ) -> Result<SearchRequest> {
        let catalog = GpuCatalog::builtin();
        let mut resolved: Vec<(crate::gpu::GpuType, usize)> = Vec::with_capacity(caps.len());
        for &(name, cap) in caps {
            resolved.push((catalog.find(name)?, cap));
        }
        let resolved = crate::strategy::merge_caps(resolved);
        Ok(SearchRequest { mode: GpuPoolMode::Heterogeneous { total, caps: resolved }, model })
    }

    /// Mode 3 (Eq. 3): count sweep under a money ceiling.
    pub fn cost(
        gpu_name: &str,
        max_count: usize,
        max_money: f64,
        model: ModelSpec,
    ) -> Result<SearchRequest> {
        let catalog = GpuCatalog::builtin();
        let gpu = catalog.find(gpu_name)?;
        Ok(SearchRequest { mode: GpuPoolMode::Cost { gpu, max_count, max_money }, model })
    }
}

/// One scored strategy.
#[derive(Debug, Clone)]
pub struct ScoredStrategy {
    pub strategy: ParallelStrategy,
    pub cost: CostBreakdown,
    pub money_usd: f64,
}

impl ScoredStrategy {
    pub fn summary(&self) -> String {
        format!(
            "{} | step={:.4}s tput={:.0} tok/s mfu={:.3} ${:.0}",
            self.strategy.summary(),
            self.cost.step_time,
            self.cost.tokens_per_s,
            self.cost.mfu,
            self.money_usd
        )
    }
}

/// Search outcome + phase accounting (Table 1 columns).
#[derive(Debug, Clone)]
pub struct SearchReport {
    /// Raw search-space size |S| (Eq. 9).
    pub generated: usize,
    pub rule_filtered: usize,
    pub mem_filtered: usize,
    pub scored: usize,
    /// Generation + filtering wall time ("Search Time").
    pub search_secs: f64,
    /// Scoring wall time ("Simulation Time").
    pub simulate_secs: f64,
    /// Best strategies, ascending step time.
    pub top: Vec<ScoredStrategy>,
    /// Pareto pool over (throughput, money) — all scored candidates.
    pub pool: OptimalPool,
}

impl SearchReport {
    pub fn best(&self) -> Option<&ScoredStrategy> {
        self.top.first()
    }

    pub fn e2e_secs(&self) -> f64 {
        self.search_secs + self.simulate_secs
    }
}

/// The `Sync` heart of the engine: catalog + config + cost model, no
/// thread-confined runtime handles. One instance can serve concurrent
/// searches from many threads (each search additionally fans its own
/// scoring out over the scoped worker pool).
pub struct ScoringCore {
    pub catalog: GpuCatalog,
    pub config: EngineConfig,
    cost: CostModel,
    /// Lifetime count of searches that entered the filter/score pipeline —
    /// the cache-effectiveness anchor for [`crate::service`] tests.
    searches: AtomicU64,
}

impl ScoringCore {
    /// Build a core; loads `artifacts/forest.json` (η forests) when
    /// `config.use_forests` is set.
    pub fn new(catalog: GpuCatalog, config: EngineConfig) -> Self {
        let dir = crate::runtime::artifacts_dir();
        let eta = if config.use_forests {
            match EtaForests::from_file(&dir.join("forest.json")) {
                Ok(f) => {
                    crate::log_info!("η source: GBDT forests ({} + {} trees)",
                        f.comp.trees.len(), f.comm.trees.len());
                    EtaProvider::Forests(f)
                }
                Err(e) => {
                    crate::log_warn!("forest.json unavailable ({e}); falling back to analytic η");
                    EtaProvider::Analytic
                }
            }
        } else {
            EtaProvider::Analytic
        };
        let cost = CostModel::new(catalog.clone(), eta);
        ScoringCore { catalog, config, cost, searches: AtomicU64::new(0) }
    }

    /// Immutable access to the underlying cost model (tests/benches).
    pub fn cost_model(&self) -> &CostModel {
        &self.cost
    }

    /// How many searches have entered the filter/score pipeline (cache hits
    /// in the service layer do NOT increment this).
    pub fn searches_run(&self) -> u64 {
        self.searches.load(Ordering::Relaxed)
    }

    /// Run a search request with native scoring (mode dispatch).
    pub fn search(&self, req: &SearchRequest) -> Result<SearchReport> {
        self.search_with(req, None)
    }

    fn search_with(
        &self,
        req: &SearchRequest,
        rt: Option<&Mutex<ScorerRuntime>>,
    ) -> Result<SearchReport> {
        match &req.mode {
            GpuPoolMode::Homogeneous { gpu, count } => {
                self.search_homogeneous_with(&req.model, *gpu, *count, rt)
            }
            GpuPoolMode::Heterogeneous { total, caps } => {
                self.search_heterogeneous_with(&req.model, *total, caps, rt)
            }
            GpuPoolMode::Cost { gpu, max_count, max_money } => {
                self.search_cost_with(&req.model, *gpu, *max_count, *max_money, rt)
            }
        }
    }

    /// Mode 1 (Eq. 1).
    pub fn search_homogeneous(
        &self,
        model: &ModelSpec,
        gpu: crate::gpu::GpuType,
        count: usize,
    ) -> Result<SearchReport> {
        self.search_homogeneous_with(model, gpu, count, None)
    }

    fn search_homogeneous_with(
        &self,
        model: &ModelSpec,
        gpu: crate::gpu::GpuType,
        count: usize,
        rt: Option<&Mutex<ScorerRuntime>>,
    ) -> Result<SearchReport> {
        let t0 = Instant::now();
        let space = SearchSpace::new(self.config.space.clone());
        let generated = space.homogeneous(model, &self.catalog, gpu, count);
        self.filter_and_score(model, generated, t0, rt)
    }

    /// Mode 2 (Eq. 2): heterogeneous pipeline partition search (§3.4).
    pub fn search_heterogeneous(
        &self,
        model: &ModelSpec,
        total: usize,
        caps: &[(crate::gpu::GpuType, usize)],
    ) -> Result<SearchReport> {
        self.search_heterogeneous_with(model, total, caps, None)
    }

    fn search_heterogeneous_with(
        &self,
        model: &ModelSpec,
        total: usize,
        caps: &[(crate::gpu::GpuType, usize)],
        rt: Option<&Mutex<ScorerRuntime>>,
    ) -> Result<SearchReport> {
        let t0 = Instant::now();
        if caps.iter().map(|&(_, l)| l).sum::<usize>() < total {
            return Err(AstraError::Config(format!(
                "type caps sum below cluster size {total}"
            )));
        }
        let space = SearchSpace::new(SpaceConfig {
            // Interleaving over heterogeneous segments is not supported by
            // the Megatron runtime; fix vpp=1 (DESIGN.md §6).
            vpp_candidates: vec![1],
            ..self.config.space.clone()
        });
        let solver = HeteroSolver::default();
        let mut generated: Vec<ParallelStrategy> = Vec::new();
        for tp in space.valid_tps(model, &self.catalog) {
            for pp in 2..=space.config.max_pp.min(model.layers).min(total / tp) {
                if total % (tp * pp) != 0 {
                    continue;
                }
                let dp = total / (tp * pp);
                let budgets = HeteroSolver::budgets(&self.catalog, caps, tp, dp);
                if budgets.iter().map(|b| b.max_stages).sum::<usize>() < pp {
                    continue;
                }
                let assignments = if self.config.hetero_exhaustive {
                    solver.enumerate_exhaustive(model.layers, pp, &budgets)
                } else {
                    solver.enumerate_pruned(model.layers, pp, &budgets)
                };
                for ca in assignments {
                    space.expand_params(model, &ca, tp, dp, &mut generated);
                }
            }
        }
        self.filter_and_score(model, generated, t0, rt)
    }

    /// Mode 3 (Eq. 3): sweep GPU counts, Pareto-pool everything, pick the
    /// fastest plan under the money ceiling (§3.6).
    pub fn search_cost(
        &self,
        model: &ModelSpec,
        gpu: crate::gpu::GpuType,
        max_count: usize,
        max_money: f64,
    ) -> Result<SearchReport> {
        self.search_cost_with(model, gpu, max_count, max_money, None)
    }

    fn search_cost_with(
        &self,
        model: &ModelSpec,
        gpu: crate::gpu::GpuType,
        max_count: usize,
        max_money: f64,
        rt: Option<&Mutex<ScorerRuntime>>,
    ) -> Result<SearchReport> {
        let t0 = Instant::now();
        let space = SearchSpace::new(self.config.space.clone());
        let mut generated: Vec<ParallelStrategy> = Vec::new();
        for count in SearchSpace::count_sweep(max_count) {
            generated.extend(space.homogeneous(model, &self.catalog, gpu, count));
        }
        let mut report = self.filter_and_score(model, generated, t0, rt)?;
        // Mode-3 selection: fastest within budget from the optimal pool.
        if let Some(best) = report.pool.best_within_budget(max_money) {
            let chosen = report
                .top
                .iter()
                .position(|s| (s.money_usd - best.cost).abs() < 1e-9
                    && (s.cost.tokens_per_s - best.throughput).abs() < 1e-6);
            if let Some(pos) = chosen {
                report.top.swap(0, pos);
            }
        }
        Ok(report)
    }

    /// Shared tail: rules → memory → scoring → ranking.
    fn filter_and_score(
        &self,
        model: &ModelSpec,
        generated: Vec<ParallelStrategy>,
        t0: Instant,
        rt: Option<&Mutex<ScorerRuntime>>,
    ) -> Result<SearchReport> {
        self.searches.fetch_add(1, Ordering::Relaxed);
        let n_generated = generated.len();
        let workers = self.config.workers;

        // --- rule filter (Eq. 10) ---
        let rules = &self.config.rules;
        let rule_keep: Vec<bool> = par_map_chunks(&generated, workers, |_, chunk| {
            chunk.iter().map(|s| !rules.filters_out(s).unwrap_or(true)).collect()
        });
        let after_rules: Vec<ParallelStrategy> = generated
            .into_iter()
            .zip(&rule_keep)
            .filter_map(|(s, &keep)| keep.then_some(s))
            .collect();
        let rule_filtered = n_generated - after_rules.len();

        // --- memory filter (Eq. 20/21) ---
        let mem = MemoryModel::default();
        let catalog = &self.catalog;
        let mem_keep: Vec<bool> = par_map_chunks(&after_rules, workers, |_, chunk| {
            chunk.iter().map(|s| mem.fits(model, s, catalog)).collect()
        });
        let valid: Vec<ParallelStrategy> = after_rules
            .into_iter()
            .zip(&mem_keep)
            .filter_map(|(s, &keep)| keep.then_some(s))
            .collect();
        let mem_filtered = n_generated - rule_filtered - valid.len();
        let search_secs = t0.elapsed().as_secs_f64();

        // --- cost simulation (§3.5) ---
        let t1 = Instant::now();
        let costs: Vec<CostBreakdown> = match rt {
            Some(rt) if self.config.engine == ScoringEngine::Hlo => {
                self.score_hlo(model, &valid, rt)?
            }
            _ => {
                // Capture only the Sync cost model, not &self (the PJRT
                // runtime handle is intentionally thread-confined). Each
                // chunk scores through a memoized batch — strategies share
                // stage profiles massively (§Perf).
                let cost = &self.cost;
                par_map_chunks(&valid, workers, |_, chunk| {
                    let refs: Vec<&ParallelStrategy> = chunk.iter().collect();
                    cost.evaluate_batch(model, &refs)
                })
            }
        };
        let simulate_secs = t1.elapsed().as_secs_f64();

        // --- selection ---
        let money = &self.config.money;
        let mut scored: Vec<ScoredStrategy> = valid
            .into_iter()
            .zip(costs)
            .map(|(strategy, cost)| {
                let money_usd = money.cost_usd(model, &strategy, catalog, cost.step_time);
                ScoredStrategy { strategy, cost, money_usd }
            })
            .collect();
        let pool = OptimalPool::build(
            scored
                .iter()
                .enumerate()
                .map(|(idx, s)| PoolEntry {
                    idx,
                    throughput: s.cost.tokens_per_s,
                    cost: s.money_usd,
                })
                .collect(),
        );
        let n_scored = scored.len();
        scored.sort_by(|a, b| a.cost.step_time.partial_cmp(&b.cost.step_time).unwrap());
        scored.truncate(self.config.top_k);

        Ok(SearchReport {
            generated: n_generated,
            rule_filtered,
            mem_filtered,
            scored: n_scored,
            search_secs,
            simulate_secs,
            top: scored,
            pool,
        })
    }

    /// Score through the PJRT executable, chunked to the artifact's batch.
    fn score_hlo(
        &self,
        model: &ModelSpec,
        valid: &[ParallelStrategy],
        rt: &Mutex<ScorerRuntime>,
    ) -> Result<Vec<CostBreakdown>> {
        let batch = rt.lock().unwrap().batch;
        let n_chunks = valid.len().div_ceil(batch.max(1));
        let chunks: Vec<&[ParallelStrategy]> = valid.chunks(batch).collect();
        // PJRT executables are not Sync-safe to share blindly; packing is
        // parallel, execution serialized through the mutex.
        let catalog = &self.catalog;
        let packed = par_for_indices(n_chunks, self.config.workers, |i| {
            let refs: Vec<&ParallelStrategy> = chunks[i].iter().collect();
            pack_batch(model, &refs, catalog, batch)
        });
        let mut out = Vec::with_capacity(valid.len());
        for (i, pb) in packed.iter().enumerate() {
            let rows: Vec<[f32; OUT]> = rt
                .lock()
                .unwrap()
                .execute(&pb.stage_feats, &pb.stage_mask, &pb.strat_feats)?;
            for (j, s) in chunks[i].iter().enumerate() {
                let r = rows[j];
                let step_time = r[0] as f64;
                let tokens = (s.global_batch * model.seq_len) as f64;
                out.push(CostBreakdown {
                    stage_times: Vec::new(),
                    pipeline_fwd: 0.0,
                    pipeline_bwd: r[1] as f64,
                    dp_time: r[2] as f64,
                    optimizer_time: r[3] as f64,
                    offload_time: 0.0,
                    step_time,
                    tokens_per_s: tokens / step_time,
                    mfu: 0.0,
                });
            }
        }
        Ok(out)
    }
}

/// The engine: a [`ScoringCore`] plus the optional thread-confined HLO
/// runtime. Use this from single-owner contexts (CLI, benches); use
/// [`ScoringCore`] (or [`crate::service::SearchService`]) when the engine
/// must be shared across threads.
pub struct AstraEngine {
    core: ScoringCore,
    runtime: Option<Mutex<ScorerRuntime>>,
}

impl AstraEngine {
    /// Build an engine; loads `artifacts/forest.json` (η forests) and — for
    /// the HLO engine — `artifacts/scorer.hlo.txt`.
    pub fn new(catalog: GpuCatalog, config: EngineConfig) -> Self {
        let runtime = if config.engine == ScoringEngine::Hlo {
            match ScorerRuntime::load(&crate::runtime::artifacts_dir()) {
                Ok(rt) => Some(Mutex::new(rt)),
                Err(e) => {
                    crate::log_warn!("HLO scorer unavailable ({e}); using native engine");
                    None
                }
            }
        } else {
            None
        };
        AstraEngine { core: ScoringCore::new(catalog, config), runtime }
    }

    /// The shared, `Sync` part of the engine.
    pub fn core(&self) -> &ScoringCore {
        &self.core
    }

    /// Take the core out (drops the HLO runtime); used to hand the engine
    /// to the multi-threaded service layer.
    pub fn into_core(self) -> ScoringCore {
        self.core
    }

    /// Immutable access to the underlying cost model (tests/benches).
    pub fn cost_model(&self) -> &CostModel {
        self.core.cost_model()
    }

    /// Whether the HLO engine is actually live.
    pub fn hlo_active(&self) -> bool {
        self.runtime.is_some()
    }

    /// Run a search request (mode dispatch).
    pub fn search(&self, req: &SearchRequest) -> Result<SearchReport> {
        self.core.search_with(req, self.runtime.as_ref())
    }

    /// Mode 1 (Eq. 1).
    pub fn search_homogeneous(
        &self,
        model: &ModelSpec,
        gpu: crate::gpu::GpuType,
        count: usize,
    ) -> Result<SearchReport> {
        self.core.search_homogeneous_with(model, gpu, count, self.runtime.as_ref())
    }

    /// Mode 2 (Eq. 2): heterogeneous pipeline partition search (§3.4).
    pub fn search_heterogeneous(
        &self,
        model: &ModelSpec,
        total: usize,
        caps: &[(crate::gpu::GpuType, usize)],
    ) -> Result<SearchReport> {
        self.core.search_heterogeneous_with(model, total, caps, self.runtime.as_ref())
    }

    /// Mode 3 (Eq. 3).
    pub fn search_cost(
        &self,
        model: &ModelSpec,
        gpu: crate::gpu::GpuType,
        max_count: usize,
        max_money: f64,
    ) -> Result<SearchReport> {
        self.core.search_cost_with(model, gpu, max_count, max_money, self.runtime.as_ref())
    }
}

impl std::ops::Deref for AstraEngine {
    type Target = ScoringCore;

    fn deref(&self) -> &ScoringCore {
        &self.core
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelRegistry;

    fn engine() -> AstraEngine {
        AstraEngine::new(
            GpuCatalog::builtin(),
            EngineConfig { use_forests: false, ..Default::default() },
        )
    }

    #[test]
    fn homogeneous_search_finds_valid_best() {
        let reg = ModelRegistry::builtin();
        let model = reg.get("llama2-7b").unwrap().clone();
        let req = SearchRequest::homogeneous("a800", 64, model.clone()).unwrap();
        let report = engine().search(&req).unwrap();
        assert!(report.generated > 1000);
        assert!(report.scored > 0);
        assert_eq!(report.generated, report.rule_filtered + report.mem_filtered + report.scored);
        let best = report.best().unwrap();
        best.strategy.validate(&model).unwrap();
        assert!(best.cost.tokens_per_s > 0.0);
        // Best-first ordering.
        for w in report.top.windows(2) {
            assert!(w[0].cost.step_time <= w[1].cost.step_time);
        }
    }

    #[test]
    fn filters_actually_fire() {
        let reg = ModelRegistry::builtin();
        let model = reg.get("llama2-70b").unwrap().clone();
        let req = SearchRequest::homogeneous("a800", 64, model).unwrap();
        let report = engine().search(&req).unwrap();
        assert!(report.rule_filtered > 0, "rule filter idle");
        assert!(report.mem_filtered > 0, "memory filter idle (70B must OOM somewhere)");
    }

    #[test]
    fn bad_gpu_names_are_recoverable_errors() {
        let reg = ModelRegistry::builtin();
        let model = reg.get("llama2-7b").unwrap().clone();
        assert!(SearchRequest::homogeneous("b200", 64, model.clone()).is_err());
        assert!(SearchRequest::heterogeneous(&[("a800", 32), ("nope", 32)], 64, model.clone())
            .is_err());
        assert!(SearchRequest::cost("gtx1080", 64, 1e9, model).is_err());
    }

    #[test]
    fn hetero_constructor_resolves_names() {
        let reg = ModelRegistry::builtin();
        let model = reg.get("llama2-7b").unwrap().clone();
        let req =
            SearchRequest::heterogeneous(&[("a800", 48), ("h100", 48)], 64, model).unwrap();
        match &req.mode {
            GpuPoolMode::Heterogeneous { total, caps } => {
                assert_eq!(*total, 64);
                assert_eq!(caps.len(), 2);
            }
            other => panic!("wrong mode {other:?}"),
        }
    }

    #[test]
    fn cost_mode_respects_budget() {
        let reg = ModelRegistry::builtin();
        let cat = GpuCatalog::builtin();
        let model = reg.get("llama2-7b").unwrap().clone();
        let gpu = cat.find("h100").unwrap();
        let eng = engine();
        let rep = eng
            .search(&SearchRequest {
                mode: GpuPoolMode::Cost { gpu, max_count: 64, max_money: f64::INFINITY },
                model: model.clone(),
            })
            .unwrap();
        assert!(!rep.pool.is_empty());
        assert!(rep.pool.is_valid_frontier());
        // A tight budget must select a cheaper (≤) plan than an infinite one.
        let cheap = rep.pool.entries().last().unwrap().cost * 1.01;
        let pick = rep.pool.best_within_budget(cheap).unwrap();
        assert!(pick.cost <= cheap);
    }

    #[test]
    fn hetero_search_produces_mixed_assignments() {
        let reg = ModelRegistry::builtin();
        let cat = GpuCatalog::builtin();
        let model = reg.get("llama2-7b").unwrap().clone();
        let caps = vec![(cat.find("a800").unwrap(), 48), (cat.find("h100").unwrap(), 48)];
        let eng = engine();
        let rep = eng
            .search(&SearchRequest {
                mode: GpuPoolMode::Heterogeneous { total: 64, caps },
                model,
            })
            .unwrap();
        assert!(rep.scored > 0, "no valid hetero strategies");
        // The pool contains at least one genuinely mixed assignment.
        assert!(rep.top.iter().any(|s| s.strategy.cluster.is_heterogeneous()));
    }

    #[test]
    fn best_beats_median_noticeably() {
        // Search must actually discriminate: best ≥ 1.5× median throughput.
        let reg = ModelRegistry::builtin();
        let model = reg.get("llama2-13b").unwrap().clone();
        let eng = AstraEngine::new(
            GpuCatalog::builtin(),
            EngineConfig { use_forests: false, top_k: usize::MAX, ..Default::default() },
        );
        let rep = eng
            .search(&SearchRequest::homogeneous("a800", 128, model).unwrap())
            .unwrap();
        let tputs: Vec<f64> = rep.top.iter().map(|s| s.cost.tokens_per_s).collect();
        let best = tputs[0];
        let median = tputs[tputs.len() / 2];
        assert!(best > 1.1 * median, "best {best:.0} vs median {median:.0}");
    }

    #[test]
    fn search_counter_tracks_pipeline_entries() {
        let reg = ModelRegistry::builtin();
        let model = reg.get("llama2-7b").unwrap().clone();
        let eng = engine();
        assert_eq!(eng.core().searches_run(), 0);
        let req = SearchRequest::homogeneous("a800", 64, model).unwrap();
        eng.search(&req).unwrap();
        eng.search(&req).unwrap();
        assert_eq!(eng.core().searches_run(), 2);
    }
}
