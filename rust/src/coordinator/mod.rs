//! The Astra engine — Layer-3 coordinator tying the whole pipeline together
//! (paper Fig. 2): input preprocess → search-space generation → rule filter
//! → memory filter → cost simulation → selection (throughput or money).
//!
//! ## Architecture: plan IR + one executor
//!
//! Every [`SearchRequest`] mode (Eq. 1–3 plus the heterogeneous money
//! sweep) **compiles** into a [`SearchPlan`] — ordered rounds of
//! `(cluster, tp, dp)` [`PoolSpec`]s plus an objective/pruning spec — and a
//! single streaming executor runs any plan. The split lives in three
//! submodules:
//!
//! * [`modes`] — [`SearchRequest`] constructors and budget validation
//!   (pure input; no engine state);
//! * [`plan`] — the IR and [`ScoringCore::compile_plan`] (pure compilation:
//!   enumeration and closed-form branch-and-bound bounds, no scoring);
//! * [`exec`] — the executor: fused expand → rules → memory → score per
//!   pool, speculative-wave sweep with snapshot–speculate–replay admission,
//!   byte-identical reports at any worker count or wave schedule (its
//!   module docs state the invariants);
//! * [`audit`] — the opt-in decision audit ("explain plane"): per-pool
//!   admitted/pruned records with certifying evidence, assembled inside
//!   the executor's serial replay so the audit is as deterministic as the
//!   report (its module docs state the contract).
//!
//! Scoring runs on one of two engines with identical math, **both** through
//! the same executor:
//!
//! * `native` — the pure-rust [`CostModel`] (η from GBDT forests when
//!   `artifacts/forest.json` exists, hardware-truth curves otherwise),
//!   scored inside the fused per-pool pass through the core's
//!   [`SharedCostMemo`] (shared across chunks, sweep rounds and requests —
//!   see the [`crate::cost`] module docs for the memo architecture);
//! * `hlo` — the AOT-compiled Layer-2 scorer executed through PJRT
//!   ([`crate::runtime::ScorerRuntime`]): pools are filtered on the worker
//!   pool, then packed *per pool* into the artifact's padded batch geometry
//!   and executed serially (the PJRT handle is thread-confined).
//!
//! `EngineConfig::streaming` is a compatibility flag, not a second
//! pipeline: `false` compiles the same plan with a pinned serial `1/1` wave
//! and executes with one worker — the differential harness's oracle. The
//! per-phase wall times reported in [`SearchReport`] correspond to Table
//! 1's "Search Time" and "Simulation Time" columns.
//!
//! ## Engine anatomy: [`ScoringCore`] vs [`AstraEngine`]
//!
//! The PJRT executable handle is thread-confined (the `xla` wrappers are
//! neither `Send` nor `Sync`), which would make the whole engine unshareable
//! across threads. The state the pipeline actually needs — catalog, config,
//! cost model, memo registry — is plain data, so it lives in
//! [`ScoringCore`], a `Sync` scoring entry point that one process can share
//! across many concurrent requests (this is what [`crate::service`] fans
//! out over). [`AstraEngine`] is `ScoringCore` plus the optional HLO
//! runtime; it keeps the historical single-owner API and is what the CLI
//! constructs.

pub mod audit;
pub mod exec;
pub mod modes;
pub mod plan;

pub use audit::{
    AuditContender, AuditDecision, AuditFunnel, AuditMargins, AuditPool, AuditRound, AuditWave,
    SearchAudit,
};
pub use modes::{validate_budget, SearchRequest};
pub use plan::{plan_json, PlanRound, PoolSpec, SearchPlan};

use crate::cost::{CostBreakdown, CostModel, EtaProvider, MemoRegistry, SharedCostMemo};
use crate::gbdt::EtaForests;
use crate::gpu::GpuCatalog;
use crate::model::ModelSpec;
use crate::pareto::{MoneyModel, OptimalPool};
use crate::pool::default_workers;
use crate::rules::RuleSet;
use crate::runtime::ScorerRuntime;
use crate::strategy::{ParallelStrategy, SpaceConfig};
use crate::Result;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// Which scorer executes the cost simulation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScoringEngine {
    Native,
    Hlo,
}

/// Engine configuration.
pub struct EngineConfig {
    pub space: SpaceConfig,
    pub rules: RuleSet,
    pub engine: ScoringEngine,
    /// Use GBDT forests for η when available (`artifacts/forest.json`).
    pub use_forests: bool,
    pub workers: usize,
    pub money: MoneyModel,
    /// Exhaustive Eq. 23 layer enumeration instead of the pruned solver.
    pub hetero_exhaustive: bool,
    /// Branch-and-bound pool pruning in the hetero-cost search (turn off
    /// for the exhaustive differential reference; results are identical,
    /// only the search time changes).
    pub money_prune: bool,
    /// Compatibility flag (stays in the request fingerprint). `true` — the
    /// default — executes plans with the configured workers and wave
    /// schedule. `false` compiles the *same* plan pinned to a `1/1` wave
    /// and executes with one worker: the strictly serial oracle the
    /// differential harness (`rust/tests/diff_streaming.rs`) compares
    /// against. There is no second pipeline behind it.
    pub streaming: bool,
    /// Pool-total rounds per speculative wave of the sweep executor.
    /// 1 = fully serial (each round's pruner sees every earlier round's
    /// frontier, zero speculation waste); larger waves score consecutive
    /// rounds concurrently against a frontier *snapshot* and then replay
    /// the admission decisions serially, so reports — including pruning
    /// counts — stay byte-identical to the serial sweep at any wave size.
    /// This is the *base* wave; the sweep adapts upward from it (see
    /// `sweep_wave_max`).
    pub sweep_wave: usize,
    /// Adaptive-wave ceiling: after a wave whose speculative admissions
    /// were all replayed without waste, the next wave grows by one round
    /// (more cross-total overlap for free); any waste resets the wave to
    /// `sweep_wave`. Growth is driven only by the deterministic admission
    /// replay, so — like `sweep_wave` itself — the schedule never changes
    /// the report and stays out of the request fingerprint.
    pub sweep_wave_max: usize,
    /// Score each pool's memo-miss candidates through the flattened GBDT
    /// batch kernel (`CostModel::evaluate_pool_shared`) instead of one η
    /// call at a time. `false` is the per-strategy scalar walk — the
    /// differential reference (`rust/tests/diff_forest.rs`). Results are
    /// byte-identical either way (the batch kernel is bit-identical by
    /// construction), so — like `workers` and the wave schedule — this
    /// flag never enters the request fingerprint.
    pub batch_eta: bool,
    /// Keep this many best strategies in the report.
    pub top_k: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            space: SpaceConfig::default(),
            rules: RuleSet::paper_defaults(),
            engine: ScoringEngine::Native,
            use_forests: true,
            workers: default_workers(),
            money: MoneyModel::default(),
            hetero_exhaustive: false,
            money_prune: true,
            streaming: true,
            sweep_wave: 2,
            sweep_wave_max: 8,
            batch_eta: true,
            top_k: 16,
        }
    }
}

/// One scored strategy.
#[derive(Debug, Clone)]
pub struct ScoredStrategy {
    pub strategy: ParallelStrategy,
    pub cost: CostBreakdown,
    pub money_usd: f64,
}

impl ScoredStrategy {
    pub fn summary(&self) -> String {
        format!(
            "{} | step={:.4}s tput={:.0} tok/s mfu={:.3} ${:.0}",
            self.strategy.summary(),
            self.cost.step_time,
            self.cost.tokens_per_s,
            self.cost.mfu,
            self.money_usd
        )
    }
}

/// Per-phase wall-time breakdown of one search — the `phases` section
/// every [`SearchReport`] carries. The executor accumulates these and then
/// *derives* the two Table-1 wall fields from them
/// ([`PhaseBreakdown::search_secs`]/[`PhaseBreakdown::simulate_secs`]), so
/// the phases sum to the wall fields exactly, by construction.
///
/// Like the wall fields, phase times are observability, never results:
/// they stay out of [`crate::report::report_json`] and the request
/// fingerprint, and the wire layer normalizes them in golden transcripts
/// ([`crate::service::server::normalize_response_line`]).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct PhaseBreakdown {
    /// Request → [`SearchPlan`] compilation (enumeration + bounds), plus
    /// executor setup up to the first wave.
    pub compile_secs: f64,
    /// Speculative-wave admission: the serial phase-1 snapshot walk that
    /// decides which pools join each wave (and its replay bookkeeping).
    pub speculate_secs: f64,
    /// Strategy expansion + rule-filter share of the fused streaming pass.
    pub expand_rules_secs: f64,
    /// Memory-filter share of the fused streaming pass.
    pub mem_filter_secs: f64,
    /// Native-engine scoring share (0 when the HLO engine scored).
    pub score_secs: f64,
    /// HLO pack+execute share (0 on the native engine).
    pub hlo_pack_secs: f64,
}

impl PhaseBreakdown {
    /// Generation + filtering phases — the "Search Time" wall field.
    pub fn search_secs(&self) -> f64 {
        self.compile_secs + self.speculate_secs + self.expand_rules_secs + self.mem_filter_secs
    }

    /// Scoring phases — the "Simulation Time" wall field.
    pub fn simulate_secs(&self) -> f64 {
        self.score_secs + self.hlo_pack_secs
    }

    /// End-to-end: every phase.
    pub fn total_secs(&self) -> f64 {
        self.search_secs() + self.simulate_secs()
    }

    /// `(name, seconds)` rows in fixed order — one loop serves the wire
    /// JSON, the phase histograms and the flight recorder.
    pub fn rows(&self) -> [(&'static str, f64); 6] {
        [
            ("compile", self.compile_secs),
            ("speculate", self.speculate_secs),
            ("expand_rules", self.expand_rules_secs),
            ("mem_filter", self.mem_filter_secs),
            ("score", self.score_secs),
            ("hlo_pack", self.hlo_pack_secs),
        ]
    }
}

/// One frontier-mode reprice candidate: a scored strategy plus its index
/// in the executor's deterministic replay order (the same index space the
/// report's [`OptimalPool`] entries use, so frontier points join back to
/// full strategies exactly).
#[derive(Debug, Clone)]
pub struct FrontierCandidate {
    /// Position in the replay-order scored list of the search that built
    /// this report.
    pub idx: usize,
    pub scored: ScoredStrategy,
}

/// The frontier mode's reprice skeleton: every scored strategy that could
/// sit on the (throughput, USD) Pareto frontier under *any* positive price
/// book, in replay-order (`idx` ascending). A strategy is dropped iff some
/// other strategy has throughput ≥ its own and a per-GPU-type cost
/// coefficient vector (`step_time × count` per type) that is ≤ component-
/// wise — such a strategy is dominated under every book, so the skeleton
/// rebuilds the exact cold-search pool for any book via
/// [`SearchReport::reprice`].
#[derive(Debug, Clone)]
pub struct FrontierReport {
    pub candidates: Vec<FrontierCandidate>,
}

/// Search outcome + phase accounting (Table 1 columns).
#[derive(Debug, Clone)]
pub struct SearchReport {
    /// Raw search-space size |S| (Eq. 9). Pools skipped by the hetero-cost
    /// pruner never reach generation, so they are not counted here.
    pub generated: usize,
    pub rule_filtered: usize,
    pub mem_filtered: usize,
    pub scored: usize,
    /// Candidate pools rejected by the hetero-cost branch-and-bound pruner
    /// before strategy expansion (0 for the other modes). Always equals
    /// `pruned_budget + pruned_dominated`.
    pub pruned_pools: usize,
    /// Pools rejected because their lower-bound bill exceeds the budget.
    pub pruned_budget: usize,
    /// Pools rejected as dominated by an already-scored strategy.
    pub pruned_dominated: usize,
    /// Generation + filtering wall time ("Search Time"). Derived from
    /// `phases` ([`PhaseBreakdown::search_secs`]) so the breakdown sums to
    /// this field exactly.
    pub search_secs: f64,
    /// Scoring wall time ("Simulation Time"); equals
    /// [`PhaseBreakdown::simulate_secs`] of `phases`.
    pub simulate_secs: f64,
    /// Where the wall time went, phase by phase (see [`PhaseBreakdown`]).
    /// Observability like the wall fields: excluded from the canonical
    /// report JSON and normalized in golden wire transcripts.
    pub phases: PhaseBreakdown,
    /// Shared-cost-memo hits accumulated by this search's scoring passes
    /// (0 on the HLO engine, whose scorer has no memo). Like the wall
    /// times these are observability, not results: a memo warmed by
    /// earlier traffic raises hits, and concurrent workers may both miss a
    /// key one of them is about to insert — so golden transcripts and
    /// determinism diffs normalize them out.
    pub memo_hits: u64,
    /// Shared-cost-memo misses (see `memo_hits`).
    pub memo_misses: u64,
    /// Best strategies, ascending step time.
    pub top: Vec<ScoredStrategy>,
    /// Pareto pool over (throughput, money) — all scored candidates.
    pub pool: OptimalPool,
    /// Frontier mode only: the reprice skeleton ([`FrontierReport`]).
    /// `None` for every other mode.
    pub frontier: Option<FrontierReport>,
    /// Opt-in decision audit ([`SearchAudit`]), assembled by the executor's
    /// serial replay when the request asked for it; `None` otherwise. Never
    /// fingerprinted and never part of [`crate::report::report_json`] — a
    /// report is byte-identical there whether or not it carries an audit.
    pub audit: Option<SearchAudit>,
}

impl SearchReport {
    pub fn best(&self) -> Option<&ScoredStrategy> {
        self.top.first()
    }

    pub fn e2e_secs(&self) -> f64 {
        self.search_secs + self.simulate_secs
    }

    /// Re-bill a frontier report under a (possibly different) price book
    /// without re-searching: recompute every skeleton candidate's and
    /// every top strategy's bill through the same [`MoneyModel::cost_usd`]
    /// path the executor used, then rebuild the pool. `None` when the
    /// report carries no skeleton (non-frontier modes).
    ///
    /// Byte-identity with a cold re-search under `money.book` holds by
    /// construction: the candidate set, counts and `top`
    /// membership/order are price-independent for frontier plans (no
    /// budget, no pruning, `top` sorts by step time), the bills are
    /// recomputed bit-identically, and the skeleton provably contains
    /// every possible frontier member (see [`FrontierReport`]).
    pub fn reprice(
        &self,
        model: &ModelSpec,
        catalog: &GpuCatalog,
        money: &MoneyModel,
    ) -> Option<SearchReport> {
        self.frontier.as_ref()?;
        let mut out = self.clone();
        if let Some(fr) = out.frontier.as_mut() {
            for c in fr.candidates.iter_mut() {
                c.scored.money_usd =
                    money.cost_usd(model, &c.scored.strategy, catalog, c.scored.cost.step_time);
            }
        }
        for s in out.top.iter_mut() {
            s.money_usd = money.cost_usd(model, &s.strategy, catalog, s.cost.step_time);
        }
        let entries = out
            .frontier
            .as_ref()
            .map(|fr| {
                fr.candidates
                    .iter()
                    .map(|c| crate::pareto::PoolEntry {
                        idx: c.idx,
                        throughput: c.scored.cost.tokens_per_s,
                        cost: c.scored.money_usd,
                    })
                    .collect()
            })
            .unwrap_or_default();
        out.pool = OptimalPool::build(entries);
        Some(out)
    }
}

/// The `Sync` heart of the engine: catalog + config + cost model, no
/// thread-confined runtime handles. One instance can serve concurrent
/// searches from many threads (each search additionally fans its own
/// scoring out over the scoped worker pool).
pub struct ScoringCore {
    pub catalog: GpuCatalog,
    pub config: EngineConfig,
    pub(crate) cost: CostModel,
    /// Shared cost memos, one per model scope ([`crate::cost::model_scope_key`]):
    /// reused across worker chunks, sweep rounds and service requests. The
    /// catalog/η/consts dimension of memo validity is pinned by `cost`
    /// being immutable for the core's lifetime.
    pub(crate) memos: MemoRegistry,
    /// Lifetime count of searches that entered the filter/score pipeline —
    /// the cache-effectiveness anchor for [`crate::service`] tests.
    pub(crate) searches: AtomicU64,
    /// Warm-start spill/restore accounting ([`crate::persist`]), surfaced
    /// through `astra stats` and the wire `stats` response.
    persist: crate::persist::PersistCounters,
    /// Snapshot identity of this core, digested once at construction
    /// (forest digests walk every tree node — too costly per spill).
    warm_meta: crate::persist::EngineMeta,
}

impl ScoringCore {
    /// Build a core; loads `artifacts/forest.json` (η forests) when
    /// `config.use_forests` is set.
    pub fn new(catalog: GpuCatalog, config: EngineConfig) -> Self {
        // Pre-register the well-known metric set so one `{"cmd":"metrics"}`
        // dump always shows the whole picture (and the golden transcript's
        // metric *name* set is deterministic from the first request on).
        crate::telemetry::register_core_metrics();
        // Opt-in flight recorder via ASTRA_TRACE=<path> (Once-guarded).
        crate::telemetry::trace::init_from_env();
        let dir = crate::runtime::artifacts_dir();
        let eta = if config.use_forests {
            match EtaForests::from_file(&dir.join("forest.json")) {
                Ok(f) => {
                    crate::log_info!("η source: GBDT forests ({} + {} trees)",
                        f.comp.trees.len(), f.comm.trees.len());
                    EtaProvider::Forests(f)
                }
                Err(e) => {
                    crate::log_warn!("forest.json unavailable ({e}); falling back to analytic η");
                    EtaProvider::Analytic
                }
            }
        } else {
            EtaProvider::Analytic
        };
        let cost = CostModel::new(catalog.clone(), eta);
        let warm_meta = crate::persist::EngineMeta::new(
            &catalog,
            &cost.eta,
            &cost.consts,
            &config.money.book,
        );
        ScoringCore {
            catalog,
            config,
            cost,
            memos: MemoRegistry::new(16),
            searches: AtomicU64::new(0),
            persist: crate::persist::PersistCounters::default(),
            warm_meta,
        }
    }

    /// Immutable access to the underlying cost model (tests/benches).
    pub fn cost_model(&self) -> &CostModel {
        &self.cost
    }

    /// The shared memo for a model's scope (tests/benches; searches fetch
    /// their own through the same registry).
    pub fn memo_for(&self, model: &ModelSpec) -> std::sync::Arc<SharedCostMemo> {
        self.memos.for_model(model)
    }

    /// `(scopes, lifetime hits, lifetime misses)` across every live memo —
    /// the service stats-line payload.
    pub fn memo_counters(&self) -> (usize, u64, u64) {
        let (h, m) = self.memos.counters();
        (self.memos.scopes(), h, m)
    }

    /// Lifetime warm-start spill/restore counters (shared with the service
    /// layer, which also spills the result cache through them).
    pub fn persist_counters(&self) -> &crate::persist::PersistCounters {
        &self.persist
    }

    /// Plain-data view of [`Self::persist_counters`] for the stats line.
    pub fn persist_stats(&self) -> crate::persist::PersistSnapshot {
        self.persist.snapshot()
    }

    /// This core's snapshot identity, digested once at construction.
    pub fn engine_meta(&self) -> &crate::persist::EngineMeta {
        &self.warm_meta
    }

    /// Append every live memo scope (with this core's identity header) to a
    /// snapshot under construction. The service layer uses this to combine
    /// memo scopes and its result cache into one file.
    pub fn export_warm(&self, w: &mut crate::persist::WarmWriter) {
        self.export_warm_within(w, 0);
    }

    /// [`Self::export_warm`] under a snapshot byte budget (`0` =
    /// unlimited). When the serialized scopes would push the snapshot past
    /// `max_bytes`, least-recently-used scopes are dropped first: sections
    /// are sized individually, the registry's LRU clock orders candidates
    /// (most recent kept first), and whatever does not fit is counted in
    /// the `persist_scopes_dropped` stats counter. Kept scopes still land
    /// in key order, so budgeted snapshots stay deterministic and diffable
    /// for a fixed request history.
    pub fn export_warm_within(&self, w: &mut crate::persist::WarmWriter, max_bytes: u64) {
        if max_bytes == 0 {
            // Unbudgeted: stream each scope straight into the writer (no
            // per-section buffering — spills can be large).
            for (key, _, memo) in self.memos.export_scopes_with_recency() {
                let rows = memo.export_rows();
                if !rows.is_empty() {
                    w.memo_scope(key, &rows, &self.warm_meta);
                }
            }
            return;
        }
        // Budgeted: size each section individually so LRU scopes can be
        // dropped first. (last_use, key, serialized section) per scope.
        let mut sections: Vec<(u64, u64, String)> = Vec::new();
        for (key, last_use, memo) in self.memos.export_scopes_with_recency() {
            let rows = memo.export_rows();
            if rows.is_empty() {
                continue;
            }
            sections.push((
                last_use,
                key,
                crate::persist::WarmWriter::memo_scope_section(key, &rows, &self.warm_meta),
            ));
        }
        // Most-recently-used first; keep what fits, count the rest.
        sections.sort_by(|a, b| b.0.cmp(&a.0).then(a.1.cmp(&b.1)));
        let mut used = w.len() as u64;
        let mut dropped = 0u64;
        sections.retain(|(_, _, sec)| {
            if used + sec.len() as u64 <= max_bytes {
                used += sec.len() as u64;
                true
            } else {
                dropped += 1;
                false
            }
        });
        if dropped > 0 {
            self.persist.note_scopes_dropped(dropped);
        }
        // Deterministic file order whatever the recency ordering was.
        sections.sort_by(|a, b| a.1.cmp(&b.1));
        for (_, _, sec) in &sections {
            w.push_memo_section(sec);
        }
    }

    /// Spill every live memo scope to a versioned snapshot at `path`
    /// (atomic temp-file + rename). See [`crate::persist`] for the format
    /// and the invalidation contract.
    pub fn save_warm(&self, path: &Path) -> Result<crate::persist::SpillStats> {
        self.save_warm_within(path, 0)
    }

    /// [`Self::save_warm`] under a snapshot byte budget (`0` = unlimited);
    /// see [`Self::export_warm_within`] for the LRU drop policy.
    pub fn save_warm_within(
        &self,
        path: &Path,
        max_bytes: u64,
    ) -> Result<crate::persist::SpillStats> {
        let mut w = crate::persist::WarmWriter::new();
        self.export_warm_within(&mut w, max_bytes);
        let stats = w.finish_to(path)?;
        self.persist.note_spill(&stats);
        Ok(stats)
    }

    /// Import an already-parsed restore set's memo scopes into the
    /// registry (cache entries, if any, are the service layer's to insert).
    pub fn restore_warm_set(&self, set: &crate::persist::RestoreSet) {
        for (key, rows) in &set.memo_scopes {
            self.memos.restore_scope(*key, rows);
        }
        self.persist.note_restore(&set.stats());
    }

    /// Restore memo scopes from a snapshot at `path`. Scopes whose headers
    /// do not match this core's identity — or whose rows fail validation —
    /// are skipped (counted in `scopes_rejected`), so a stale or corrupt
    /// snapshot degrades to a cold start, never an error or a wrong
    /// answer. Only a missing/unreadable file is an `Err`.
    pub fn load_warm(&self, path: &Path) -> Result<crate::persist::RestoreStats> {
        // Memo-only consumer: cache sections are checksummed for the
        // accounting but their reports are not decoded.
        self.load_warm_set(path, false).map(|set| set.stats())
    }

    /// [`Self::load_warm`] returning the full [`crate::persist::RestoreSet`]
    /// — the service layer layers its cache insertion on top of this one
    /// load path instead of duplicating it. `want_cache` skips the
    /// per-report decode when the caller would discard the entries anyway.
    pub fn load_warm_set(
        &self,
        path: &Path,
        want_cache: bool,
    ) -> Result<crate::persist::RestoreSet> {
        // Chaos seam: an armed `persist.restore` fails the load like an
        // unreadable snapshot file (the caller degrades to a cold start).
        crate::failpoint!("persist.restore");
        let text = std::fs::read_to_string(path)?;
        let set =
            crate::persist::read_warm_filtered(&text, &self.catalog, &self.warm_meta, want_cache);
        self.restore_warm_set(&set);
        self.persist.note_snapshot_bytes(text.len() as u64);
        Ok(set)
    }

    /// How many searches have entered the filter/score pipeline (cache hits
    /// in the service layer do NOT increment this).
    pub fn searches_run(&self) -> u64 {
        self.searches.load(Ordering::Relaxed)
    }

    /// Run a search request with native scoring: compile the plan, execute
    /// it. All four modes take exactly this path.
    pub fn search(&self, req: &SearchRequest) -> Result<SearchReport> {
        self.search_with(req, None)
    }

    /// [`Self::search`] with the decision audit attached
    /// ([`SearchReport::audit`]). Auditing changes nothing outside that
    /// field: the core report is byte-identical to an unaudited search
    /// (pinned by `rust/tests/determinism.rs`).
    pub fn search_audited(&self, req: &SearchRequest) -> Result<SearchReport> {
        self.search_full(req, None, &crate::resilience::CancelToken::unlimited(), true)
    }

    /// [`Self::search`] under a cancellation token: the executor polls the
    /// token at wave boundaries, so a fired deadline unwinds with a typed
    /// [`crate::AstraError::Deadline`] — never a partial report. The
    /// service layer builds one token per admitted cold request from the
    /// effective `deadline_ms`.
    pub fn search_with_cancel(
        &self,
        req: &SearchRequest,
        cancel: &crate::resilience::CancelToken,
    ) -> Result<SearchReport> {
        self.search_full(req, None, cancel, false)
    }

    /// [`Self::search_with_cancel`] with the decision audit attached — the
    /// service layer's leader path for `"audit":true` requests.
    pub fn search_with_cancel_audited(
        &self,
        req: &SearchRequest,
        cancel: &crate::resilience::CancelToken,
    ) -> Result<SearchReport> {
        self.search_full(req, None, cancel, true)
    }

    fn search_with(
        &self,
        req: &SearchRequest,
        rt: Option<&Mutex<ScorerRuntime>>,
    ) -> Result<SearchReport> {
        self.search_full(req, rt, &crate::resilience::CancelToken::unlimited(), false)
    }

    fn search_full(
        &self,
        req: &SearchRequest,
        rt: Option<&Mutex<ScorerRuntime>>,
        cancel: &crate::resilience::CancelToken,
        audit: bool,
    ) -> Result<SearchReport> {
        let t0 = Instant::now();
        let plan = self.compile_plan(req)?;
        self.execute_plan(&req.model, &plan, rt, t0, cancel, audit)
    }
}

/// The engine: a [`ScoringCore`] plus the optional thread-confined HLO
/// runtime. Use this from single-owner contexts (CLI, benches); use
/// [`ScoringCore`] (or [`crate::service::SearchService`]) when the engine
/// must be shared across threads.
pub struct AstraEngine {
    core: ScoringCore,
    runtime: Option<Mutex<ScorerRuntime>>,
}

impl AstraEngine {
    /// Build an engine; loads `artifacts/forest.json` (η forests) and — for
    /// the HLO engine — `artifacts/scorer.hlo.txt`.
    pub fn new(catalog: GpuCatalog, config: EngineConfig) -> Self {
        let runtime = if config.engine == ScoringEngine::Hlo {
            match ScorerRuntime::load(&crate::runtime::artifacts_dir()) {
                Ok(rt) => Some(Mutex::new(rt)),
                Err(e) => {
                    crate::log_warn!("HLO scorer unavailable ({e}); using native engine");
                    None
                }
            }
        } else {
            None
        };
        AstraEngine { core: ScoringCore::new(catalog, config), runtime }
    }

    /// The shared, `Sync` part of the engine.
    pub fn core(&self) -> &ScoringCore {
        &self.core
    }

    /// Take the core out (drops the HLO runtime); used to hand the engine
    /// to the multi-threaded service layer.
    pub fn into_core(self) -> ScoringCore {
        self.core
    }

    /// Immutable access to the underlying cost model (tests/benches).
    pub fn cost_model(&self) -> &CostModel {
        self.core.cost_model()
    }

    /// Whether the HLO engine is actually live.
    pub fn hlo_active(&self) -> bool {
        self.runtime.is_some()
    }

    /// Run a search request: compile, then execute — on the HLO engine
    /// when it is live, natively otherwise.
    pub fn search(&self, req: &SearchRequest) -> Result<SearchReport> {
        self.core.search_with(req, self.runtime.as_ref())
    }

    /// [`Self::search`] with the decision audit attached (`--audit` /
    /// `astra explain`). Core report bytes are unchanged by auditing.
    pub fn search_audited(&self, req: &SearchRequest) -> Result<SearchReport> {
        self.core.search_full(
            req,
            self.runtime.as_ref(),
            &crate::resilience::CancelToken::unlimited(),
            true,
        )
    }
}

impl std::ops::Deref for AstraEngine {
    type Target = ScoringCore;

    fn deref(&self) -> &ScoringCore {
        &self.core
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelRegistry;
    use crate::strategy::GpuPoolMode;

    fn engine() -> AstraEngine {
        AstraEngine::new(
            GpuCatalog::builtin(),
            EngineConfig { use_forests: false, ..Default::default() },
        )
    }

    #[test]
    fn homogeneous_search_finds_valid_best() {
        let reg = ModelRegistry::builtin();
        let model = reg.get("llama2-7b").unwrap().clone();
        let req = SearchRequest::homogeneous("a800", 64, model.clone()).unwrap();
        let report = engine().search(&req).unwrap();
        assert!(report.generated > 1000);
        assert!(report.scored > 0);
        assert_eq!(report.generated, report.rule_filtered + report.mem_filtered + report.scored);
        let best = report.best().unwrap();
        best.strategy.validate(&model).unwrap();
        assert!(best.cost.tokens_per_s > 0.0);
        // Best-first ordering.
        for w in report.top.windows(2) {
            assert!(w[0].cost.step_time <= w[1].cost.step_time);
        }
    }

    #[test]
    fn filters_actually_fire() {
        let reg = ModelRegistry::builtin();
        let model = reg.get("llama2-70b").unwrap().clone();
        let req = SearchRequest::homogeneous("a800", 64, model).unwrap();
        let report = engine().search(&req).unwrap();
        assert!(report.rule_filtered > 0, "rule filter idle");
        assert!(report.mem_filtered > 0, "memory filter idle (70B must OOM somewhere)");
    }

    #[test]
    fn bad_gpu_names_are_recoverable_errors() {
        let reg = ModelRegistry::builtin();
        let model = reg.get("llama2-7b").unwrap().clone();
        assert!(SearchRequest::homogeneous("b200", 64, model.clone()).is_err());
        assert!(SearchRequest::heterogeneous(&[("a800", 32), ("nope", 32)], 64, model.clone())
            .is_err());
        assert!(SearchRequest::cost("gtx1080", 64, 1e9, model).is_err());
    }

    #[test]
    fn hetero_constructor_resolves_names() {
        let reg = ModelRegistry::builtin();
        let model = reg.get("llama2-7b").unwrap().clone();
        let req =
            SearchRequest::heterogeneous(&[("a800", 48), ("h100", 48)], 64, model).unwrap();
        match &req.mode {
            GpuPoolMode::Heterogeneous { total, caps } => {
                assert_eq!(*total, 64);
                assert_eq!(caps.len(), 2);
            }
            other => panic!("wrong mode {other:?}"),
        }
    }

    #[test]
    fn cost_mode_respects_budget() {
        let reg = ModelRegistry::builtin();
        let cat = GpuCatalog::builtin();
        let model = reg.get("llama2-7b").unwrap().clone();
        let gpu = cat.find("h100").unwrap();
        let eng = engine();
        let rep = eng
            .search(&SearchRequest {
                mode: GpuPoolMode::Cost { gpu, max_count: 64, max_money: f64::INFINITY },
                model: model.clone(),
            })
            .unwrap();
        assert!(!rep.pool.is_empty());
        assert!(rep.pool.is_valid_frontier());
        // A tight budget must select a cheaper (≤) plan than an infinite one.
        let cheap = rep.pool.entries().last().unwrap().cost * 1.01;
        let pick = rep.pool.best_within_budget(cheap).unwrap();
        assert!(pick.cost <= cheap);
    }

    #[test]
    fn hetero_search_produces_mixed_assignments() {
        let reg = ModelRegistry::builtin();
        let cat = GpuCatalog::builtin();
        let model = reg.get("llama2-7b").unwrap().clone();
        let caps = vec![(cat.find("a800").unwrap(), 48), (cat.find("h100").unwrap(), 48)];
        let eng = engine();
        let rep = eng
            .search(&SearchRequest {
                mode: GpuPoolMode::Heterogeneous { total: 64, caps },
                model,
            })
            .unwrap();
        assert!(rep.scored > 0, "no valid hetero strategies");
        // The pool contains at least one genuinely mixed assignment.
        assert!(rep.top.iter().any(|s| s.strategy.cluster.is_heterogeneous()));
    }

    #[test]
    fn best_beats_median_noticeably() {
        // Search must actually discriminate: best ≥ 1.5× median throughput.
        let reg = ModelRegistry::builtin();
        let model = reg.get("llama2-13b").unwrap().clone();
        let eng = AstraEngine::new(
            GpuCatalog::builtin(),
            EngineConfig { use_forests: false, top_k: usize::MAX, ..Default::default() },
        );
        let rep = eng
            .search(&SearchRequest::homogeneous("a800", 128, model).unwrap())
            .unwrap();
        let tputs: Vec<f64> = rep.top.iter().map(|s| s.cost.tokens_per_s).collect();
        let best = tputs[0];
        let median = tputs[tputs.len() / 2];
        assert!(best > 1.1 * median, "best {best:.0} vs median {median:.0}");
    }

    #[test]
    fn bad_budgets_are_recoverable_errors() {
        let reg = ModelRegistry::builtin();
        let model = reg.get("llama2-7b").unwrap().clone();
        for bad in [f64::NAN, 0.0, -1.0, f64::NEG_INFINITY] {
            assert!(
                SearchRequest::cost("a800", 64, bad, model.clone()).is_err(),
                "cost accepted budget {bad}"
            );
            assert!(
                SearchRequest::hetero_cost(&[("a800", 16)], bad, model.clone()).is_err(),
                "hetero_cost accepted budget {bad}"
            );
        }
        // +inf means "no ceiling" and must keep working.
        assert!(SearchRequest::cost("a800", 64, f64::INFINITY, model.clone()).is_ok());
        // Hand-built modes cannot smuggle a bad budget past the compiler.
        let eng = engine();
        let gpu = GpuCatalog::builtin().find("a800").unwrap();
        let hand = SearchRequest {
            mode: GpuPoolMode::Cost { gpu, max_count: 16, max_money: f64::NAN },
            model,
        };
        assert!(eng.search(&hand).is_err());
        assert!(eng.core().compile_plan(&hand).is_err());
    }

    /// Degenerate budgets at the float edges: zero (either sign) is a hard
    /// request error, while a subnormal-but-positive budget is accepted,
    /// searched and answered with an *explicitly empty* report — every pool
    /// falls to the money bound in
    /// `DominancePruner::new(plan.budget.unwrap_or(f64::INFINITY))`, and
    /// nothing panics or fabricates an over-budget pick.
    #[test]
    fn zero_and_subnormal_budgets_are_explicit() {
        let reg = ModelRegistry::builtin();
        let model = reg.get("llama2-7b").unwrap().clone();
        for zero in [0.0_f64, -0.0] {
            assert!(
                SearchRequest::cost("a800", 8, zero, model.clone()).is_err(),
                "cost accepted budget {zero}"
            );
            assert!(
                SearchRequest::hetero_cost(&[("a800", 4), ("h100", 4)], zero, model.clone())
                    .is_err(),
                "hetero_cost accepted budget {zero}"
            );
        }
        let eng = small_engine();
        for tiny in [f64::from_bits(1), f64::MIN_POSITIVE] {
            let req =
                SearchRequest::hetero_cost(&[("a800", 4), ("h100", 4)], tiny, model.clone())
                    .unwrap();
            let rep = eng.search(&req).unwrap();
            assert_eq!(rep.scored, 0, "budget {tiny:e} scored a strategy");
            assert!(rep.best().is_none(), "budget {tiny:e} bought a plan");
            assert!(rep.top.is_empty(), "budget {tiny:e} left entries in top");
            assert!(rep.pool.is_empty(), "empty sweep still built a pool");
            assert!(rep.pool.best_within_budget(tiny).is_none());
            assert!(rep.pruned_pools > 0, "nothing was pruned at budget {tiny:e}");
        }
    }

    /// Narrowed space so the hetero-cost tests stay fast in debug profile.
    fn small_engine() -> AstraEngine {
        let space = crate::strategy::SpaceConfig {
            tp_candidates: vec![1, 2],
            max_pp: 4,
            mbs_candidates: vec![1, 2],
            vpp_candidates: vec![1],
            seq_parallel_options: vec![true],
            dist_opt_options: vec![true],
            offload_options: vec![false],
            recompute_none: true,
            recompute_selective: false,
            recompute_full: false,
            ..crate::strategy::SpaceConfig::default()
        };
        AstraEngine::new(
            GpuCatalog::builtin(),
            EngineConfig { use_forests: false, space, ..Default::default() },
        )
    }

    #[test]
    fn hetero_cost_search_prices_mixed_pools() {
        let reg = ModelRegistry::builtin();
        let cat = GpuCatalog::builtin();
        let model = reg.get("llama2-7b").unwrap().clone();
        let caps = [("a800", 16usize), ("h100", 16usize)];
        let req =
            SearchRequest::hetero_cost(&caps, f64::INFINITY, model.clone()).unwrap();
        let rep = small_engine().search(&req).unwrap();
        assert!(rep.scored > 0, "no valid hetero-cost strategies");
        assert!(!rep.pool.is_empty());
        assert!(rep.pool.is_valid_frontier());
        // Mixed assignments survive into the ranking, and every plan's
        // per-type usage respects the caps.
        assert!(rep.top.iter().any(|s| s.strategy.cluster.is_heterogeneous()));
        let by_name: Vec<(crate::gpu::GpuType, usize)> =
            caps.iter().map(|&(n, c)| (cat.find(n).unwrap(), c)).collect();
        for s in &rep.top {
            s.strategy.validate(&model).unwrap();
            for (g, n) in s.strategy.cluster.gpus_by_type(s.strategy.tp, s.strategy.dp) {
                let cap = by_name
                    .iter()
                    .find(|&&(t, _)| t == g)
                    .unwrap_or_else(|| panic!("unexpected type {g}"))
                    .1;
                assert!(n <= cap, "type {g} uses {n} > cap {cap}");
            }
            assert!(s.money_usd.is_finite() && s.money_usd > 0.0);
        }
    }

    #[test]
    fn hand_built_duplicate_caps_merge_in_compiler() {
        // Split duplicate cap entries must see the same budgets the
        // fingerprint hashes — otherwise the service cache would conflate
        // genuinely different searches.
        let reg = ModelRegistry::builtin();
        let model = reg.get("llama2-7b").unwrap().clone();
        let cat = GpuCatalog::builtin();
        let a800 = cat.find("a800").unwrap();
        let h100 = cat.find("h100").unwrap();
        let eng = small_engine();
        let search = |caps: Vec<(crate::gpu::GpuType, usize)>| {
            eng.search(&SearchRequest {
                mode: GpuPoolMode::HeteroCost { caps, max_money: f64::INFINITY },
                model: model.clone(),
            })
            .unwrap()
        };
        let split = search(vec![(a800, 4), (h100, 8), (a800, 4)]);
        let merged = search(vec![(a800, 8), (h100, 8)]);
        assert_eq!(split.generated, merged.generated);
        assert_eq!(split.pool.len(), merged.pool.len());
        for (x, y) in split.pool.entries().iter().zip(merged.pool.entries()) {
            assert!(
                (x.throughput - y.throughput).abs() < 1e-9 && (x.cost - y.cost).abs() < 1e-9,
                "split/merged caps diverged: {x:?} vs {y:?}"
            );
        }
    }

    #[test]
    fn hetero_cost_budget_prunes_and_still_selects_within_budget() {
        let reg = ModelRegistry::builtin();
        let model = reg.get("llama2-7b").unwrap().clone();
        let eng = small_engine();
        // v100s are ~3× pricier per effective FLOP than h100s here, so a
        // budget near the frontier's cheap end provably strands the
        // v100-heavy pools above their lower bound.
        let caps = [("a800", 8usize), ("h100", 8usize), ("v100", 8usize)];
        // First pass without a ceiling to learn the cost scale.
        let free = eng
            .search(&SearchRequest::hetero_cost(&caps, f64::INFINITY, model.clone()).unwrap())
            .unwrap();
        assert!(!free.pool.is_empty());
        let cheap = free.pool.entries().last().unwrap().cost;
        let budget = cheap * 1.05;
        let tight = eng
            .search(&SearchRequest::hetero_cost(&caps, budget, model).unwrap())
            .unwrap();
        // The ceiling must actually cut the space…
        assert!(tight.pruned_pools > 0, "tight budget pruned nothing");
        assert!(tight.generated < free.generated, "pruning generated no savings");
        // …and the selected plan must respect it.
        let pick = tight.best().expect("no plan under budget");
        assert!(
            pick.money_usd <= budget * (1.0 + 1e-9),
            "pick ${} > budget ${budget}",
            pick.money_usd
        );
    }

    #[test]
    fn streaming_reports_memo_counters_and_warms_up() {
        let reg = ModelRegistry::builtin();
        let model = reg.get("llama2-7b").unwrap().clone();
        let eng = engine(); // streaming is the default
        let req = SearchRequest::homogeneous("a800", 16, model.clone()).unwrap();
        let cold = eng.search(&req).unwrap();
        assert!(cold.memo_hits + cold.memo_misses > 0, "streaming path must count memo traffic");
        assert!(cold.memo_misses > 0, "a fresh memo must miss");
        let warm = eng.search(&req).unwrap();
        assert_eq!(warm.memo_misses, 0, "second identical search must be fully memo-warm");
        assert!(warm.memo_hits > 0);
        // Warmth is observability only — results are unchanged.
        assert_eq!(cold.generated, warm.generated);
        assert_eq!(cold.scored, warm.scored);
        assert_eq!(
            cold.best().unwrap().cost.step_time.to_bits(),
            warm.best().unwrap().cost.step_time.to_bits()
        );
        // Per-report deltas reconcile with the scope's lifetime counters
        // (both searches hit the same registry scope for this model).
        let scope = eng.core().memo_for(&model);
        assert_eq!(scope.hits(), cold.memo_hits + warm.memo_hits);
        assert_eq!(scope.misses(), cold.memo_misses + warm.memo_misses);
        let (scopes, hits, misses) = eng.core().memo_counters();
        assert_eq!(scopes, 1);
        assert_eq!((hits, misses), (scope.hits(), scope.misses()));
    }

    #[test]
    fn no_streaming_flag_maps_to_serial_plan() {
        // The `streaming: false` compatibility flag is not a second
        // pipeline: it compiles the same rounds with a pinned 1/1 wave and
        // scores through the same executor (so memo counters are live).
        let reg = ModelRegistry::builtin();
        let model = reg.get("llama2-7b").unwrap().clone();
        let eng = AstraEngine::new(
            GpuCatalog::builtin(),
            EngineConfig { use_forests: false, streaming: false, ..Default::default() },
        );
        let req = SearchRequest::homogeneous("a800", 16, model).unwrap();
        let plan = eng.core().compile_plan(&req).unwrap();
        assert_eq!((plan.wave_base, plan.wave_max), (1, 1));
        let rep = eng.search(&req).unwrap();
        assert!(rep.scored > 0);
        assert!(rep.memo_hits + rep.memo_misses > 0, "oracle scores through the memo too");
    }

    #[test]
    fn serial_oracle_matches_streaming_counts_and_best() {
        let reg = ModelRegistry::builtin();
        let model = reg.get("llama2-7b").unwrap().clone();
        let mk = |streaming: bool| {
            AstraEngine::new(
                GpuCatalog::builtin(),
                EngineConfig { use_forests: false, streaming, ..Default::default() },
            )
        };
        let req = SearchRequest::homogeneous("a800", 32, model).unwrap();
        let fast = mk(true).search(&req).unwrap();
        let slow = mk(false).search(&req).unwrap();
        assert_eq!(fast.generated, slow.generated);
        assert_eq!(fast.rule_filtered, slow.rule_filtered);
        assert_eq!(fast.mem_filtered, slow.mem_filtered);
        assert_eq!(fast.scored, slow.scored);
        assert_eq!(fast.top.len(), slow.top.len());
        for (a, b) in fast.top.iter().zip(&slow.top) {
            assert_eq!(a.strategy, b.strategy, "streaming selected different strategies");
            assert_eq!(a.cost.step_time.to_bits(), b.cost.step_time.to_bits());
            assert_eq!(a.money_usd.to_bits(), b.money_usd.to_bits());
        }
    }

    #[test]
    fn plans_compile_for_every_mode() {
        let reg = ModelRegistry::builtin();
        let cat = GpuCatalog::builtin();
        let model = reg.get("llama2-7b").unwrap().clone();
        let eng = small_engine();
        let core = eng.core();

        let homog = core
            .compile_plan(&SearchRequest::homogeneous("a800", 16, model.clone()).unwrap())
            .unwrap();
        assert_eq!(homog.rounds.len(), 1);
        assert!(homog.pool_count() > 0);
        assert!(homog.budget.is_none() && !homog.prune);
        // Homogeneous pools carry the trivial bounds.
        assert!(homog.rounds[0].pools.iter().all(|p| p.ub_tput.is_infinite() && p.lb_usd == 0.0));

        let hetero = core
            .compile_plan(
                &SearchRequest::heterogeneous(&[("a800", 8), ("h100", 8)], 8, model.clone())
                    .unwrap(),
            )
            .unwrap();
        assert_eq!(hetero.rounds.len(), 1);
        assert!(hetero.pool_count() > 0);
        // Heterogeneous modes pin vpp to 1.
        assert_eq!(hetero.space.config.vpp_candidates, vec![1]);

        let cost = core
            .compile_plan(&SearchRequest::cost("a800", 16, 1e7, model.clone()).unwrap())
            .unwrap();
        assert_eq!(cost.rounds.len(), 1, "mode 3 sweeps inside one round");
        assert_eq!(cost.budget, Some(1e7));
        assert!(!cost.prune);

        let hc = core
            .compile_plan(
                &SearchRequest::hetero_cost(&[("a800", 8), ("h100", 8)], 1e7, model).unwrap(),
            )
            .unwrap();
        // Power-of-two totals over cap_sum = 16: [2, 4, 8, 16].
        assert_eq!(
            hc.rounds.iter().map(|r| r.total).collect::<Vec<_>>(),
            vec![2, 4, 8, 16]
        );
        assert!(hc.prune, "money_prune defaults on");
        assert_eq!(hc.budget, Some(1e7));
        // Pruning plans carry finite bounds on every pool.
        assert!(hc
            .rounds
            .iter()
            .flat_map(|r| &r.pools)
            .all(|p| p.ub_tput.is_finite() && p.lb_usd > 0.0));
        // The compiled plan serializes (smoke; byte-pinning lives in the
        // golden snapshots and the determinism matrix).
        let js = crate::json::to_string(&plan_json(&hc, &cat));
        assert!(js.contains("\"astra_plan\":1"));
    }

    #[test]
    fn search_counter_tracks_pipeline_entries() {
        let reg = ModelRegistry::builtin();
        let model = reg.get("llama2-7b").unwrap().clone();
        let eng = engine();
        assert_eq!(eng.core().searches_run(), 0);
        let req = SearchRequest::homogeneous("a800", 64, model).unwrap();
        eng.search(&req).unwrap();
        eng.search(&req).unwrap();
        assert_eq!(eng.core().searches_run(), 2);
    }
}
