//! Money-limit search (paper §3.6, Eq. 29–33).
//!
//! Builds the throughput/cost *optimal pool* (the Pareto frontier: no other
//! strategy is simultaneously faster and cheaper), prices strategies with
//! `M_i = T_i · N_g · F_g` (Eq. 32, summed per GPU type for heterogeneous
//! clusters), and selects the highest-throughput strategy under a money
//! ceiling using the Eq. 33 sort order.

use crate::gpu::GpuCatalog;
use crate::model::ModelSpec;
use crate::strategy::ParallelStrategy;

/// Converts step time into a training bill.
#[derive(Debug, Clone)]
pub struct MoneyModel {
    /// Token budget of the training run being priced (the paper prices a
    /// full training; we default to a 1B-token fine-tune-scale run so the
    /// numbers stay readable).
    pub train_tokens: f64,
}

impl Default for MoneyModel {
    fn default() -> Self {
        MoneyModel { train_tokens: 1e9 }
    }
}

impl MoneyModel {
    /// Number of optimizer steps for the token budget.
    pub fn steps(&self, m: &ModelSpec) -> f64 {
        (self.train_tokens / (m.global_batch as f64 * m.seq_len as f64)).ceil()
    }

    /// Total wall-clock seconds for the run.
    pub fn wall_seconds(&self, m: &ModelSpec, step_time: f64) -> f64 {
        self.steps(m) * step_time
    }

    /// Eq. 32: money cost in USD (per-type Σ count·fee·time for hetero).
    pub fn cost_usd(
        &self,
        m: &ModelSpec,
        s: &ParallelStrategy,
        catalog: &GpuCatalog,
        step_time: f64,
    ) -> f64 {
        let t = self.wall_seconds(m, step_time);
        s.cluster
            .gpus_by_type(s.tp, s.dp)
            .iter()
            .map(|&(g, n)| t * n as f64 * catalog.spec(g).price_per_second())
            .sum()
    }
}

/// One pooled candidate.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PoolEntry {
    /// Index into the caller's strategy list.
    pub idx: usize,
    /// Throughput `P_i` (tokens/s).
    pub throughput: f64,
    /// Money cost `C_i` (USD).
    pub cost: f64,
}

/// The optimal pool (Eq. 30–31): the Pareto frontier over (P, C), kept
/// sorted by Eq. 33 (throughput desc, cost asc on ties).
#[derive(Debug, Clone, Default)]
pub struct OptimalPool {
    entries: Vec<PoolEntry>,
}

impl OptimalPool {
    /// Build the frontier in O(n log n): sort by cost ascending and keep
    /// strictly-increasing throughput.
    pub fn build(mut candidates: Vec<PoolEntry>) -> OptimalPool {
        candidates.retain(|e| e.throughput.is_finite() && e.cost.is_finite());
        candidates.sort_by(|a, b| {
            a.cost
                .partial_cmp(&b.cost)
                .unwrap()
                .then(b.throughput.partial_cmp(&a.throughput).unwrap())
        });
        let mut frontier: Vec<PoolEntry> = Vec::new();
        let mut best = f64::NEG_INFINITY;
        for e in candidates {
            if e.throughput > best {
                best = e.throughput;
                frontier.push(e);
            }
        }
        // Eq. 33 order: throughput descending (cost ascending follows).
        frontier.reverse();
        OptimalPool { entries: frontier }
    }

    /// Frontier entries in Eq. 33 order.
    pub fn entries(&self) -> &[PoolEntry] {
        &self.entries
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Highest-throughput strategy with `cost ≤ max_money` (the mode-3
    /// selection rule).
    pub fn best_within_budget(&self, max_money: f64) -> Option<&PoolEntry> {
        self.entries.iter().find(|e| e.cost <= max_money)
    }

    /// Frontier invariant check (used by property tests): no entry is
    /// dominated by another (Eq. 29).
    pub fn is_valid_frontier(&self) -> bool {
        for a in &self.entries {
            for b in &self.entries {
                if b.throughput > a.throughput && b.cost < a.cost {
                    return false;
                }
            }
        }
        // Eq. 33 order: throughput strictly descending, cost strictly
        // descending as well (frontier ⇒ faster is pricier).
        self.entries.windows(2).all(|w| {
            w[0].throughput > w[1].throughput && w[0].cost > w[1].cost
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Rng;

    fn e(idx: usize, p: f64, c: f64) -> PoolEntry {
        PoolEntry { idx, throughput: p, cost: c }
    }

    #[test]
    fn dominated_points_removed() {
        let pool = OptimalPool::build(vec![
            e(0, 100.0, 10.0),
            e(1, 90.0, 12.0),  // dominated: slower AND pricier than 0
            e(2, 120.0, 20.0),
            e(3, 80.0, 5.0),
        ]);
        let idxs: Vec<usize> = pool.entries().iter().map(|x| x.idx).collect();
        assert_eq!(idxs, vec![2, 0, 3]);
        assert!(pool.is_valid_frontier());
    }

    #[test]
    fn budget_selection() {
        let pool = OptimalPool::build(vec![e(0, 100.0, 10.0), e(1, 200.0, 50.0), e(2, 50.0, 2.0)]);
        assert_eq!(pool.best_within_budget(100.0).unwrap().idx, 1);
        assert_eq!(pool.best_within_budget(20.0).unwrap().idx, 0);
        assert_eq!(pool.best_within_budget(3.0).unwrap().idx, 2);
        assert!(pool.best_within_budget(1.0).is_none());
    }

    #[test]
    fn frontier_invariant_random() {
        let mut rng = Rng::new(11);
        for _ in 0..50 {
            let n = 1 + rng.below(200) as usize;
            let cands: Vec<PoolEntry> = (0..n)
                .map(|i| e(i, rng.range_f64(1.0, 1000.0), rng.range_f64(1.0, 1000.0)))
                .collect();
            let pool = OptimalPool::build(cands.clone());
            assert!(pool.is_valid_frontier());
            // Every candidate is dominated-or-equal by something on the frontier.
            for c in &cands {
                assert!(pool.entries().iter().any(|f| f.throughput >= c.throughput
                    && f.cost <= c.cost
                    || (f.idx == c.idx)));
            }
        }
    }

    #[test]
    fn ties_kept_single() {
        let pool = OptimalPool::build(vec![e(0, 100.0, 10.0), e(1, 100.0, 10.0)]);
        assert_eq!(pool.len(), 1);
    }

    #[test]
    fn money_model_steps() {
        let reg = crate::model::ModelRegistry::builtin();
        let m = reg.get("llama2-7b").unwrap(); // gbs 2048 × seq 4096 = 8.4M tokens/step
        let mm = MoneyModel { train_tokens: 1e9 };
        assert_eq!(mm.steps(m), (1e9f64 / (2048.0 * 4096.0)).ceil());
    }
}
