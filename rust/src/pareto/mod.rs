//! Money-limit search (paper §3.6, Eq. 29–33).
//!
//! Builds the throughput/cost *optimal pool* (the Pareto frontier: no other
//! strategy is simultaneously faster and cheaper), prices strategies with
//! `M_i = T_i · N_g · F_g` (Eq. 32, summed per GPU type for heterogeneous
//! clusters), and selects the highest-throughput strategy under a money
//! ceiling using the Eq. 33 sort order.
//!
//! ## Frontier mode (`"mode":"frontier"`)
//!
//! The frontier search promotes this module's pool to a first-class
//! result: the report carries the full (throughput, USD) Pareto curve
//! plus a *reprice skeleton* (`coordinator::FrontierReport`) — every
//! scored strategy that could sit on the frontier under **any** positive
//! price book. Wire shape (one line per response, key-sorted like every
//! other payload):
//!
//! ```text
//! {"id":..,"ok":true,"fingerprint":..,"source":"search|cache",
//!  "frontier":{"astra_frontier":1,"count":N,
//!              "points":[{strategy.., "money_usd":.., "tokens_per_s":..}, ..]},
//!  "best":{..}, "engine":{..}}
//! ```
//!
//! Points arrive in Eq. 33 order (throughput descending, cost descending
//! — faster is pricier on a frontier).
//!
//! ### Cache keying: what is (and is not) in the money axis
//!
//! Frontier candidate *membership* is price-independent by construction
//! (frontier plans compile with no budget and no money pruning), so the
//! service caches frontiers under a fingerprint whose money axis keeps
//! only the price book's **GPU-type name set** (membership) and drops the
//! rates: on-demand/spot dollar figures, `use_spot`, the billing hour and
//! the 24 time-of-day multipliers are all *out* of the frontier cache
//! key. Model, catalog identity, caps, search space and `train_tokens`
//! stay *in* — changing any of those is a different search.
//!
//! ### Reprice vs re-search
//!
//! | price-book change                          | served by            |
//! | ------------------------------------------ | -------------------- |
//! | on-demand / spot rate moved                | reprice (cache hit)  |
//! | `use_spot` toggled                         | reprice (cache hit)  |
//! | billing hour / time-of-day multiplier      | reprice (cache hit)  |
//! | GPU type added to or removed from the book | re-search (new key)  |
//! | catalog / model / caps / space changed     | re-search (new key)  |
//!
//! Reprice recomputes every candidate's bill through the *same*
//! [`MoneyModel::cost_usd`] code path the executor used, then rebuilds
//! the pool with [`OptimalPool::build`] — the result is byte-identical to
//! a cold re-search under the new book (property-tested in
//! `rust/tests/prop_money.rs`).

use crate::gpu::{GpuCatalog, GpuType};
use crate::model::ModelSpec;
use crate::pricing::PriceBook;
use crate::strategy::ParallelStrategy;

/// Safety margin on the branch-and-bound step-time lower bound. The census
/// FLOPs are pinned to the closed-form model analytics (see
/// `cost::ops::tests::census_flops_match_model_analytics`), so the ideal
/// time `flops / Σ(count·peak·util_max)` is already a true lower bound
/// under the cost model; the slack only absorbs f64 rounding and future
/// census drift — pruning decisions stay sound even if the census gains a
/// few percent of unaccounted work.
pub const BOUND_SLACK: f64 = 0.97;

/// Converts step time into a training bill.
#[derive(Debug, Clone)]
pub struct MoneyModel {
    /// Token budget of the training run being priced (the paper prices a
    /// full training; we default to a 1B-token fine-tune-scale run so the
    /// numbers stay readable).
    pub train_tokens: f64,
    /// Per-type rate card. GPU types the book does not list fall back to
    /// the catalog's `price_per_hour`, which keeps hand-built catalogs and
    /// the pre-book behavior working unchanged.
    pub book: PriceBook,
}

impl Default for MoneyModel {
    fn default() -> Self {
        MoneyModel { train_tokens: 1e9, book: PriceBook::builtin() }
    }
}

impl MoneyModel {
    /// Number of optimizer steps for the token budget.
    pub fn steps(&self, m: &ModelSpec) -> f64 {
        (self.train_tokens / (m.global_batch as f64 * m.seq_len as f64)).ceil()
    }

    /// Total wall-clock seconds for the run.
    pub fn wall_seconds(&self, m: &ModelSpec, step_time: f64) -> f64 {
        self.steps(m) * step_time
    }

    /// Effective USD per GPU-second for a type: the book's rate when
    /// listed, the catalog's otherwise.
    pub fn rate_per_second(&self, gpu: GpuType, catalog: &GpuCatalog) -> f64 {
        let spec = catalog.spec(gpu);
        self.book.rate_per_second(&spec.name).unwrap_or_else(|| spec.price_per_second())
    }

    /// Eq. 32: money cost in USD (per-type Σ count·fee·time for hetero).
    pub fn cost_usd(
        &self,
        m: &ModelSpec,
        s: &ParallelStrategy,
        catalog: &GpuCatalog,
        step_time: f64,
    ) -> f64 {
        let t = self.wall_seconds(m, step_time);
        s.cluster
            .gpus_by_type(s.tp, s.dp)
            .iter()
            .map(|&(g, n)| t * n as f64 * self.rate_per_second(g, catalog))
            .sum()
    }

    /// Branch-and-bound bounds for a candidate pool (per-type GPU counts):
    /// `(upper-bound tokens/s, lower-bound USD)` over *every* strategy the
    /// pool could run. The step-time lower bound is the ideal compute time
    /// `model FLOPs / Σ(count·peak·util_max)` — no strategy under the cost
    /// model can beat the pool's aggregate effective peak (comm, pipeline
    /// bubble, recompute and optimizer work only add time).
    pub fn pool_bounds(
        &self,
        m: &ModelSpec,
        gpus: &[(GpuType, usize)],
        catalog: &GpuCatalog,
    ) -> (f64, f64) {
        let eff_peak: f64 = gpus
            .iter()
            .map(|&(g, n)| {
                let spec = catalog.spec(g);
                n as f64 * spec.peak_flops() * spec.eff.util_max
            })
            .sum();
        if eff_peak <= 0.0 {
            return (0.0, f64::INFINITY);
        }
        let model_flops = 3.0 * crate::cost::ops::model_fwd_flops(m, m.global_batch);
        let t_lb = BOUND_SLACK * model_flops / eff_peak;
        let tokens = (m.global_batch * m.seq_len) as f64;
        let rate: f64 =
            gpus.iter().map(|&(g, n)| n as f64 * self.rate_per_second(g, catalog)).sum();
        (tokens / t_lb, self.steps(m) * t_lb * rate)
    }
}

/// Branch-and-bound dominance pruner for the heterogeneous money search
/// (`GpuPoolMode::HeteroCost`). Candidate pools are admitted through their
/// [`MoneyModel::pool_bounds`]: a pool whose *lower-bound* bill already
/// exceeds the budget cannot contain a feasible plan, and a pool whose
/// *upper-bound* throughput is dominated by an already-scored strategy
/// (faster-or-equal AND cheaper-or-equal) cannot improve the frontier or
/// the budget pick — both are skipped before strategy expansion, which is
/// what keeps the enlarged mixed-type space within Table-1-class search
/// times. Soundness: bounds are true bounds, so pruning never changes the
/// budget-optimal `(throughput, cost)` (differential-tested against the
/// unpruned reference).
#[derive(Debug, Clone)]
pub struct DominancePruner {
    budget: f64,
    /// Non-dominated `(throughput, cost)` points scored so far.
    frontier: Vec<(f64, f64)>,
    /// Pools rejected because their lower-bound bill exceeds the budget.
    pub pruned_budget: usize,
    /// Pools rejected as dominated by an already-scored strategy.
    pub pruned_dominated: usize,
}

/// The attributed outcome of one [`DominancePruner::admit`] call: not just
/// *whether* a pool was pruned but the certificate for *why* — the budget a
/// lower-bound bill exceeded, or the exact frontier point that dominated
/// the pool's bounds. The audit plane (`coordinator::audit`) records these
/// verbatim so every prune in a report is machine-checkable after the fact.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum AdmitDecision {
    /// The pool may still matter; it proceeds to strategy expansion.
    Admitted,
    /// `lb_usd > budget`: no plan in the pool can be affordable.
    PrunedBudget {
        /// The pool's lower-bound bill (USD).
        lb_usd: f64,
        /// The budget it exceeded.
        budget: f64,
    },
    /// An already-scored `(tokens/s, USD)` point is at least as fast AND
    /// at least as cheap as the pool's best-case bounds.
    PrunedDominated {
        /// The dominating frontier point `(tokens_per_s, money_usd)`.
        by: (f64, f64),
    },
}

impl AdmitDecision {
    pub fn is_admitted(&self) -> bool {
        matches!(self, AdmitDecision::Admitted)
    }
}

impl DominancePruner {
    pub fn new(budget: f64) -> DominancePruner {
        DominancePruner {
            budget,
            frontier: Vec::new(),
            pruned_budget: 0,
            pruned_dominated: 0,
        }
    }

    /// Whether a pool with these bounds may still matter. Counts the
    /// rejection reason when it does not, and returns the attributed
    /// [`AdmitDecision`] carrying the certifying evidence (budget exceeded,
    /// or the exact dominating frontier point).
    pub fn admit(&mut self, ub_throughput: f64, lb_cost: f64) -> AdmitDecision {
        if lb_cost > self.budget {
            self.pruned_budget += 1;
            return AdmitDecision::PrunedBudget { lb_usd: lb_cost, budget: self.budget };
        }
        if let Some(by) = self.dominating(ub_throughput, lb_cost) {
            self.pruned_dominated += 1;
            return AdmitDecision::PrunedDominated { by };
        }
        AdmitDecision::Admitted
    }

    /// Read-only form of [`Self::admit`]: same predicate, no counter
    /// mutation. The parallel hetero-cost sweep speculates against a
    /// frontier *snapshot* with this, then replays the counting `admit`
    /// serially so pruning statistics stay byte-identical to the serial
    /// sweep. Sound to speculate with because dominance coverage only
    /// grows under [`Self::observe`]: whatever a snapshot rejects, every
    /// later frontier rejects too.
    pub fn would_admit(&self, ub_throughput: f64, lb_cost: f64) -> bool {
        lb_cost <= self.budget && self.dominating(ub_throughput, lb_cost).is_none()
    }

    /// The first frontier point dominating these bounds, if any. First-match
    /// (insertion-order) so the attributed evidence is deterministic: the
    /// frontier's content at any replay step depends only on the serial
    /// (round, pool) order, never on worker interleaving.
    fn dominating(&self, ub_throughput: f64, lb_cost: f64) -> Option<(f64, f64)> {
        self.frontier.iter().find(|&&(p, c)| p >= ub_throughput && c <= lb_cost).copied()
    }

    /// The money ceiling this pruner enforces.
    pub fn budget(&self) -> f64 {
        self.budget
    }

    /// Record a scored strategy (keeps the internal frontier minimal).
    pub fn observe(&mut self, throughput: f64, cost: f64) {
        if !(throughput.is_finite() && cost.is_finite()) {
            return;
        }
        if self.frontier.iter().any(|&(p, c)| p >= throughput && c <= cost) {
            return;
        }
        self.frontier.retain(|&(p, c)| !(throughput >= p && cost <= c));
        self.frontier.push((throughput, cost));
    }

    /// Total pools rejected.
    pub fn pruned(&self) -> usize {
        self.pruned_budget + self.pruned_dominated
    }
}

/// One pooled candidate.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PoolEntry {
    /// Index into the caller's strategy list.
    pub idx: usize,
    /// Throughput `P_i` (tokens/s).
    pub throughput: f64,
    /// Money cost `C_i` (USD).
    pub cost: f64,
}

impl PoolEntry {
    /// Validated construction: the frontier invariant ("no NaN, nothing
    /// negative on either axis") is enforced once, here. Callers building
    /// entries from untrusted numbers (degenerate price books, restored
    /// snapshots) get `None` instead of a poisoned pool.
    pub fn new(idx: usize, throughput: f64, cost: f64) -> Option<PoolEntry> {
        if throughput.is_finite() && cost.is_finite() && throughput >= 0.0 && cost >= 0.0 {
            Some(PoolEntry { idx, throughput, cost })
        } else {
            None
        }
    }
}

/// The optimal pool (Eq. 30–31): the Pareto frontier over (P, C), kept
/// sorted by Eq. 33 (throughput desc, cost asc on ties).
#[derive(Debug, Clone, Default)]
pub struct OptimalPool {
    entries: Vec<PoolEntry>,
}

impl OptimalPool {
    /// Build the frontier in O(n log n): sort by cost ascending and keep
    /// strictly-increasing throughput. Entries violating the frontier
    /// invariant (NaN or negative on either axis) are dropped up front —
    /// the sort itself is `total_cmp`, so even a hand-built `PoolEntry`
    /// that smuggled a NaN past [`PoolEntry::new`] can no longer panic
    /// the search.
    pub fn build(mut candidates: Vec<PoolEntry>) -> OptimalPool {
        candidates.retain(|e| {
            e.throughput.is_finite() && e.cost.is_finite() && e.throughput >= 0.0 && e.cost >= 0.0
        });
        candidates.sort_by(|a, b| {
            a.cost.total_cmp(&b.cost).then(b.throughput.total_cmp(&a.throughput))
        });
        let mut frontier: Vec<PoolEntry> = Vec::new();
        let mut best = f64::NEG_INFINITY;
        for e in candidates {
            if e.throughput > best {
                best = e.throughput;
                frontier.push(e);
            }
        }
        // Eq. 33 order: throughput descending (cost ascending follows).
        frontier.reverse();
        OptimalPool { entries: frontier }
    }

    /// Reconstruct a pool from already-built frontier entries — the
    /// persist restore path, which replays exactly what [`Self::build`]
    /// produced before the spill. Trusts the input to be in Eq. 33 order;
    /// use [`Self::build`] for raw candidates.
    pub fn from_entries(entries: Vec<PoolEntry>) -> OptimalPool {
        OptimalPool { entries }
    }

    /// Frontier entries in Eq. 33 order.
    pub fn entries(&self) -> &[PoolEntry] {
        &self.entries
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Highest-throughput strategy with `cost ≤ max_money` (the mode-3
    /// selection rule).
    pub fn best_within_budget(&self, max_money: f64) -> Option<&PoolEntry> {
        self.entries.iter().find(|e| e.cost <= max_money)
    }

    /// Frontier invariant check (used by property tests): no entry is
    /// dominated by another (Eq. 29).
    pub fn is_valid_frontier(&self) -> bool {
        for a in &self.entries {
            for b in &self.entries {
                if b.throughput > a.throughput && b.cost < a.cost {
                    return false;
                }
            }
        }
        // Eq. 33 order: throughput strictly descending, cost strictly
        // descending as well (frontier ⇒ faster is pricier).
        self.entries.windows(2).all(|w| {
            w[0].throughput > w[1].throughput && w[0].cost > w[1].cost
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Rng;

    fn e(idx: usize, p: f64, c: f64) -> PoolEntry {
        PoolEntry { idx, throughput: p, cost: c }
    }

    #[test]
    fn dominated_points_removed() {
        let pool = OptimalPool::build(vec![
            e(0, 100.0, 10.0),
            e(1, 90.0, 12.0),  // dominated: slower AND pricier than 0
            e(2, 120.0, 20.0),
            e(3, 80.0, 5.0),
        ]);
        let idxs: Vec<usize> = pool.entries().iter().map(|x| x.idx).collect();
        assert_eq!(idxs, vec![2, 0, 3]);
        assert!(pool.is_valid_frontier());
    }

    #[test]
    fn budget_selection() {
        let pool = OptimalPool::build(vec![e(0, 100.0, 10.0), e(1, 200.0, 50.0), e(2, 50.0, 2.0)]);
        assert_eq!(pool.best_within_budget(100.0).unwrap().idx, 1);
        assert_eq!(pool.best_within_budget(20.0).unwrap().idx, 0);
        assert_eq!(pool.best_within_budget(3.0).unwrap().idx, 2);
        assert!(pool.best_within_budget(1.0).is_none());
    }

    #[test]
    fn frontier_invariant_random() {
        let mut rng = Rng::new(11);
        for _ in 0..50 {
            let n = 1 + rng.below(200) as usize;
            let cands: Vec<PoolEntry> = (0..n)
                .map(|i| e(i, rng.range_f64(1.0, 1000.0), rng.range_f64(1.0, 1000.0)))
                .collect();
            let pool = OptimalPool::build(cands.clone());
            assert!(pool.is_valid_frontier());
            // Every candidate is dominated-or-equal by something on the frontier.
            for c in &cands {
                assert!(pool.entries().iter().any(|f| f.throughput >= c.throughput
                    && f.cost <= c.cost
                    || (f.idx == c.idx)));
            }
        }
    }

    #[test]
    fn ties_kept_single() {
        let pool = OptimalPool::build(vec![e(0, 100.0, 10.0), e(1, 100.0, 10.0)]);
        assert_eq!(pool.len(), 1);
    }

    #[test]
    fn nan_and_negative_entries_never_panic_or_pollute() {
        // Regression: the old sort used partial_cmp().unwrap() — one NaN
        // cost (a degenerate price book) panicked the whole search.
        let pool = OptimalPool::build(vec![
            e(0, f64::NAN, 10.0),
            e(1, 100.0, f64::NAN),
            e(2, -5.0, 10.0),
            e(3, 100.0, -1.0),
            e(4, f64::INFINITY, 1.0),
            e(5, 100.0, 10.0),
        ]);
        let idxs: Vec<usize> = pool.entries().iter().map(|x| x.idx).collect();
        assert_eq!(idxs, vec![5], "only the finite non-negative entry survives");
        assert!(pool.is_valid_frontier());
        // All-invalid input degrades to an empty pool, not a panic.
        assert!(OptimalPool::build(vec![e(0, f64::NAN, f64::NAN)]).is_empty());
    }

    #[test]
    fn pool_entry_constructor_rejects_invalid_pairs() {
        assert!(PoolEntry::new(0, 100.0, 10.0).is_some());
        assert!(PoolEntry::new(0, 0.0, 0.0).is_some(), "zero is a legal boundary");
        for (p, c) in [
            (f64::NAN, 1.0),
            (1.0, f64::NAN),
            (-1.0, 1.0),
            (1.0, -1.0),
            (f64::INFINITY, 1.0),
            (1.0, f64::INFINITY),
        ] {
            assert!(PoolEntry::new(0, p, c).is_none(), "accepted ({p}, {c})");
        }
    }

    #[test]
    fn money_model_steps() {
        let reg = crate::model::ModelRegistry::builtin();
        let m = reg.get("llama2-7b").unwrap(); // gbs 2048 × seq 4096 = 8.4M tokens/step
        let mm = MoneyModel { train_tokens: 1e9, ..Default::default() };
        assert_eq!(mm.steps(m), (1e9f64 / (2048.0 * 4096.0)).ceil());
    }

    #[test]
    fn book_rates_replace_catalog_scalar() {
        use crate::gpu::GpuCatalog;
        let cat = GpuCatalog::builtin();
        let a800 = cat.find("a800").unwrap();
        let mut mm = MoneyModel::default();
        // Default book mirrors the catalog exactly.
        assert!((mm.rate_per_second(a800, &cat) - cat.spec(a800).price_per_second()).abs() < 1e-15);
        // Spot billing cuts the rate to the book's spot price.
        mm.book.use_spot = true;
        assert!((mm.rate_per_second(a800, &cat) - 1.04 / 3600.0).abs() < 1e-15);
        // Types missing from the book fall back to the catalog.
        mm.book = crate::pricing::PriceBook::empty();
        assert_eq!(mm.rate_per_second(a800, &cat), cat.spec(a800).price_per_second());
    }

    #[test]
    fn pool_bounds_scale_sanely() {
        use crate::gpu::GpuCatalog;
        let cat = GpuCatalog::builtin();
        let reg = crate::model::ModelRegistry::builtin();
        let m = reg.get("llama2-7b").unwrap();
        let mm = MoneyModel::default();
        let a800 = cat.find("a800").unwrap();
        let h100 = cat.find("h100").unwrap();
        let (ub_small, lb_small) = mm.pool_bounds(m, &[(a800, 8)], &cat);
        let (ub_big, _lb_big) = mm.pool_bounds(m, &[(a800, 8), (h100, 8)], &cat);
        assert!(ub_small > 0.0 && lb_small > 0.0);
        assert!(ub_big > ub_small, "more silicon raises the throughput bound");
        // Empty pools are never admissible bargains.
        let (ub0, lb0) = mm.pool_bounds(m, &[], &cat);
        assert_eq!(ub0, 0.0);
        assert!(lb0.is_infinite());
    }

    #[test]
    fn pruner_budget_and_dominance() {
        let mut pr = DominancePruner::new(100.0);
        assert!(pr.admit(1000.0, 50.0).is_admitted(), "within budget, empty frontier");
        assert_eq!(
            pr.admit(1000.0, 100.1),
            AdmitDecision::PrunedBudget { lb_usd: 100.1, budget: 100.0 },
            "lower bound above budget carries the certificate"
        );
        assert_eq!(pr.pruned_budget, 1);
        pr.observe(500.0, 20.0);
        assert_eq!(
            pr.admit(400.0, 30.0),
            AdmitDecision::PrunedDominated { by: (500.0, 20.0) },
            "dominated: slower and pricier than scored, evidence is the scored point"
        );
        assert_eq!(pr.pruned_dominated, 1);
        assert!(pr.admit(600.0, 30.0).is_admitted(), "faster upper bound survives");
        assert!(pr.admit(400.0, 10.0).is_admitted(), "cheaper lower bound survives");
        assert_eq!(pr.pruned(), 2);
        // Infinite budget never rejects on money.
        let mut inf = DominancePruner::new(f64::INFINITY);
        assert!(inf.admit(1.0, 1e30).is_admitted());
    }

    #[test]
    fn would_admit_matches_admit_without_counting() {
        let mut pr = DominancePruner::new(100.0);
        pr.observe(500.0, 20.0);
        for &(ub, lb) in
            &[(1000.0, 50.0), (1000.0, 100.1), (400.0, 30.0), (600.0, 30.0), (400.0, 10.0)]
        {
            let speculative = pr.would_admit(ub, lb);
            let counted = pr.clone().admit(ub, lb).is_admitted();
            assert_eq!(speculative, counted, "predicates diverged on ({ub}, {lb})");
        }
        assert_eq!(pr.pruned(), 0, "would_admit must not count");
        // Coverage monotonicity under observe: a snapshot rejection is
        // permanent — the speculative wave machinery relies on this.
        let snapshot = pr.clone();
        pr.observe(800.0, 15.0);
        pr.observe(450.0, 8.0);
        for ub in [100, 300, 450, 500, 650, 900] {
            for lb in [5, 9, 15, 21, 50, 101] {
                let (ub, lb) = (ub as f64, lb as f64);
                if !snapshot.would_admit(ub, lb) {
                    assert!(!pr.would_admit(ub, lb), "coverage shrank at ({ub}, {lb})");
                }
            }
        }
    }

    #[test]
    fn pruner_frontier_stays_minimal() {
        let mut pr = DominancePruner::new(f64::INFINITY);
        pr.observe(100.0, 10.0);
        pr.observe(90.0, 20.0); // dominated, dropped
        pr.observe(200.0, 5.0); // dominates the first, replaces it
        assert_eq!(
            pr.admit(150.0, 7.0),
            AdmitDecision::PrunedDominated { by: (200.0, 5.0) },
            "dominated by (200, 5)"
        );
        assert!(pr.admit(250.0, 7.0).is_admitted());
    }
}
