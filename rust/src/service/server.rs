//! Line-delimited JSON wire protocol + the `serve`/`batch` front ends.
//!
//! ## Request lines
//!
//! One JSON object per line (field order free; unknown fields rejected by
//! omission — they are simply ignored):
//!
//! ```text
//! {"id":"r1","model":"llama2-7b","mode":"homogeneous","gpu":"a800","gpus":64}
//! {"model":"llama2-13b","mode":"heterogeneous","gpus":64,"caps":{"a800":48,"h100":48}}
//! {"model":"llama2-7b","mode":"cost","gpu":"h100","gpus":64,"max_money":50000}
//! {"model":"llama2-7b","mode":"hetero-cost","caps":{"a800":16,"h100":16},"max_money":50000}
//! {"model":"llama2-7b","mode":"frontier","caps":{"a800":16,"h100":16}}
//! {"cmd":"stats"}
//! {"cmd":"metrics"}
//! {"cmd":"health"}
//! ```
//!
//! * `model` — required, a [`crate::model::ModelRegistry`] name.
//! * `mode` — `homogeneous` (default) | `heterogeneous` | `cost` |
//!   `hetero-cost` | `frontier`.
//! * `gpu` / `gpus` — GPU type and count (for `cost`: the count ceiling;
//!   `hetero-cost` and `frontier` need neither — pool sizes are swept from
//!   the caps).
//! * `caps` — per-type caps, `{gpu_name: max_count}` (`heterogeneous`,
//!   `hetero-cost` and `frontier`).
//! * `max_money` — optional money ceiling in USD (`cost` / `hetero-cost`);
//!   must be positive when present. Rejected for `frontier`, which returns
//!   the whole (throughput, $) curve instead of the best plan under one
//!   budget.
//! * `id` — optional opaque tag echoed back in the response.
//! * `deadline_ms` — optional per-request deadline in milliseconds. The
//!   search is cancelled cooperatively at wave boundaries once it expires
//!   and the response is a typed `deadline` error (never a partial
//!   report). `0` means "cache or fail now". Cached results are served
//!   regardless of deadline. Not part of the fingerprint.
//! * `audit` — optional boolean. `true` asks for a decision audit
//!   ([`crate::report::audit_json`]) on the response: per-round, per-pool
//!   admitted/pruned decisions with certifying evidence, candidate
//!   funnels and winner margins. Not part of the fingerprint — the core
//!   report is byte-identical with auditing on or off, and a request that
//!   hits a cached report without a stored audit answers without one
//!   (best-effort).
//!
//! `frontier` responses additionally carry a `frontier` object (see
//! [`crate::report::frontier_json`]): the full Pareto curve of
//! (tokens/s, USD) plans in throughput-descending order.
//!
//! ## Response lines
//!
//! One JSON object per request line, in input order:
//!
//! ```text
//! {"id":"r1","ok":true,"fingerprint":"91c4…","source":"search|cache|coalesced",
//!  "service_ms":…, "engine":{"generated":…,"scored":…,…}, "best":{…}, "top":[…]}
//! {"id":"r2","ok":false,"kind":"config","retryable":false,
//!  "error":"config error: unknown model 'gpt-5' (…)"}
//! ```
//!
//! Error lines carry the stable [`AstraError::kind`] tag (`json`, `config`,
//! `deadline`, `overloaded`, `fault`, `panic`, …) and a `retryable` flag;
//! only `overloaded` (load shedding) is retryable — `astra batch` retries
//! those client-side with seeded exponential backoff (`--retries`).
//!
//! Identical requests always carry the same `fingerprint`, making responses
//! join-able across batches and tenants.
//!
//! ## Control lines
//!
//! * `{"cmd":"stats"}` — service/engine counters (cache, memo, persist,
//!   searches run), backward-compatible keys only appended.
//! * `{"cmd":"metrics"}` — the full process-global telemetry registry
//!   ([`crate::telemetry::registry_json`]) as canonical JSON: every named
//!   counter/gauge/histogram, including the per-phase search latency
//!   histograms. Values are load-dependent, so golden transcripts zero
//!   every number under `metrics` (names and shape stay pinned).
//! * `{"cmd":"health"}` — live readiness and the rolling request window
//!   ([`SearchService::health`]): `ready` (admission-queue headroom),
//!   active/max queue depth, the boot warm-restore summary, and windowed
//!   per-mode p50/p95/p99 latency plus cache-hit/shed/deadline/panic
//!   rates, computed from [`crate::telemetry::window`] snapshot deltas —
//!   never from the search path's locks. Golden transcripts zero the
//!   numbers and collapse the per-mode objects (traffic-dependent), but
//!   `ready` and the shape stay pinned.

use crate::coordinator::{SearchReport, SearchRequest};
use crate::gpu::GpuCatalog;
use crate::json::{self, Value};
use crate::model::ModelRegistry;
use crate::report::scored_strategy_json;
use crate::resilience::RetryPolicy;
use crate::strategy::GpuPoolMode;
use crate::{AstraError, Result};
use std::io::{BufRead, Write};
use std::sync::mpsc;
use std::sync::Arc;

use super::{RequestOpts, SearchService, ServiceResponse};

/// A parsed request line.
#[derive(Debug, Clone)]
pub struct WireRequest {
    /// Opaque client tag, echoed back verbatim.
    pub id: Option<String>,
    pub request: SearchRequest,
    /// Per-request deadline (ms); `None` defers to the service default.
    pub deadline_ms: Option<u64>,
    /// `"audit":true` on the wire — attach a decision audit to a fresh
    /// search for this request.
    pub audit: bool,
}

/// Serve-loop options.
#[derive(Debug, Clone)]
pub struct ServeOpts {
    /// Max request lines admitted into one fan-out batch.
    pub max_batch: usize,
    /// Strategies included in each response's `top` array.
    pub top: usize,
    /// Client-side retry budget for *retryable* errors (load shedding).
    /// `0` disables — the right setting for `astra serve`, where the
    /// remote client owns the retry decision; `astra batch` defaults on.
    pub retries: u32,
    /// Base backoff delay (ms) for the retry schedule (exponential,
    /// jittered; see [`RetryPolicy`]).
    pub retry_base_ms: u64,
    /// Seed for the deterministic retry jitter.
    pub retry_seed: u64,
}

impl Default for ServeOpts {
    fn default() -> Self {
        ServeOpts { max_batch: 32, top: 3, retries: 0, retry_base_ms: 25, retry_seed: 0 }
    }
}

/// Counters returned by the serve/batch loops.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ServeStats {
    pub lines: usize,
    pub ok: usize,
    pub errors: usize,
}

/// Parse one request object (already JSON-decoded).
/// The `id` echo: strings verbatim, anything else as its JSON text (so
/// numeric ids survive both the success and error paths).
fn wire_id(v: &Value) -> Option<String> {
    v.get("id").map(|x| match x {
        Value::Str(s) => s.clone(),
        other => json::to_string(other),
    })
}

pub fn parse_request(
    v: &Value,
    catalog: &GpuCatalog,
    registry: &ModelRegistry,
) -> Result<WireRequest> {
    let id = wire_id(v);
    let deadline_ms = match v.get("deadline_ms") {
        None => None,
        Some(x) => Some(x.as_u64().ok_or_else(|| {
            AstraError::Json("'deadline_ms' is not a non-negative integer".into())
        })?),
    };
    let audit = match v.get("audit") {
        None => false,
        Some(x) => x
            .as_bool()
            .ok_or_else(|| AstraError::Json("'audit' is not a boolean".into()))?,
    };
    let model = registry.get(v.req_str("model")?)?.clone();
    let mode = v.get("mode").and_then(Value::as_str).unwrap_or("homogeneous");
    let request = match mode {
        "homogeneous" => {
            let gpu = catalog.find(v.req_str("gpu")?)?;
            let count = v.req_usize("gpus")?;
            SearchRequest { mode: GpuPoolMode::Homogeneous { gpu, count }, model }
        }
        "heterogeneous" => {
            let total = v.req_usize("gpus")?;
            let caps = parse_caps(v, catalog)?;
            SearchRequest { mode: GpuPoolMode::Heterogeneous { total, caps }, model }
        }
        "cost" => {
            let gpu = catalog.find(v.req_str("gpu")?)?;
            let max_count = v.req_usize("gpus")?;
            let max_money = parse_budget(v)?;
            SearchRequest { mode: GpuPoolMode::Cost { gpu, max_count, max_money }, model }
        }
        "hetero-cost" => {
            let caps = parse_caps(v, catalog)?;
            let max_money = parse_budget(v)?;
            SearchRequest { mode: GpuPoolMode::HeteroCost { caps, max_money }, model }
        }
        "frontier" => {
            if v.get("max_money").is_some() {
                return Err(AstraError::Config(
                    "'max_money' does not apply to mode 'frontier': the full \
                     (throughput, money) Pareto curve is returned; pick a budget \
                     client-side or use 'hetero-cost'"
                        .into(),
                ));
            }
            let caps = parse_caps(v, catalog)?;
            SearchRequest { mode: GpuPoolMode::Frontier { caps }, model }
        }
        other => {
            return Err(AstraError::Config(format!(
                "unknown mode '{other}' (homogeneous | heterogeneous | cost | hetero-cost | frontier)"
            )));
        }
    };
    Ok(WireRequest { id, request, deadline_ms, audit })
}

/// The `caps` object, `{gpu_name: max_count}`.
fn parse_caps(
    v: &Value,
    catalog: &GpuCatalog,
) -> Result<Vec<(crate::gpu::GpuType, usize)>> {
    let caps_obj = v
        .get("caps")
        .and_then(Value::as_obj)
        .ok_or_else(|| AstraError::Json("missing/invalid object field 'caps'".into()))?;
    let mut caps = Vec::with_capacity(caps_obj.len());
    for (name, cap) in caps_obj {
        let cap = cap.as_usize().ok_or_else(|| {
            AstraError::Json(format!("caps['{name}'] is not a non-negative integer"))
        })?;
        caps.push((catalog.find(name)?, cap));
    }
    Ok(caps)
}

/// Optional `max_money` (absent = unlimited); validated like the request
/// constructors so the wire cannot smuggle NaN or non-positive budgets.
fn parse_budget(v: &Value) -> Result<f64> {
    match v.get("max_money") {
        None => Ok(f64::INFINITY),
        Some(m) => {
            let money = m
                .as_f64()
                .ok_or_else(|| AstraError::Json("'max_money' is not a number".into()))?;
            crate::coordinator::validate_budget(money)?;
            Ok(money)
        }
    }
}

/// Serialize a request back to its wire form (round-trip tested: the wire
/// form re-parses to the same fingerprint).
pub fn request_to_json(req: &SearchRequest, catalog: &GpuCatalog) -> Value {
    let base = Value::obj().set("model", req.model.name.as_str());
    match &req.mode {
        GpuPoolMode::Homogeneous { gpu, count } => base
            .set("mode", "homogeneous")
            .set("gpu", catalog.spec(*gpu).name.as_str())
            .set("gpus", *count),
        GpuPoolMode::Heterogeneous { total, caps } => {
            // Caps are a per-type map on the wire: [`merge_caps`] matches
            // the fingerprint canonicalization, so the round-trip
            // preserves the key even for split duplicate inputs.
            let merged = crate::strategy::merge_caps(
                caps.iter().map(|&(g, c)| (catalog.spec(g).name.as_str(), c)),
            );
            let mut obj = Value::obj();
            for (name, c) in merged {
                obj = obj.set(name, c);
            }
            base.set("mode", "heterogeneous").set("gpus", *total).set("caps", obj)
        }
        GpuPoolMode::Cost { gpu, max_count, max_money } => {
            let v = base
                .set("mode", "cost")
                .set("gpu", catalog.spec(*gpu).name.as_str())
                .set("gpus", *max_count);
            if max_money.is_finite() {
                v.set("max_money", *max_money)
            } else {
                v
            }
        }
        GpuPoolMode::HeteroCost { caps, max_money } => {
            let merged = crate::strategy::merge_caps(
                caps.iter().map(|&(g, c)| (catalog.spec(g).name.as_str(), c)),
            );
            let mut obj = Value::obj();
            for (name, c) in merged {
                obj = obj.set(name, c);
            }
            let v = base.set("mode", "hetero-cost").set("caps", obj);
            if max_money.is_finite() {
                v.set("max_money", *max_money)
            } else {
                v
            }
        }
        GpuPoolMode::Frontier { caps } => {
            let merged = crate::strategy::merge_caps(
                caps.iter().map(|&(g, c)| (catalog.spec(g).name.as_str(), c)),
            );
            let mut obj = Value::obj();
            for (name, c) in merged {
                obj = obj.set(name, c);
            }
            base.set("mode", "frontier").set("caps", obj)
        }
    }
}

fn report_counts_json(r: &SearchReport) -> Value {
    let mut phases = Value::obj();
    for (name, secs) in r.phases.rows() {
        phases = phases.set(name, secs);
    }
    Value::obj()
        .set("generated", r.generated)
        .set("rule_filtered", r.rule_filtered)
        .set("mem_filtered", r.mem_filtered)
        .set("scored", r.scored)
        .set("pruned_pools", r.pruned_pools)
        .set("pruned_budget", r.pruned_budget)
        .set("pruned_dominated", r.pruned_dominated)
        .set("search_secs", r.search_secs)
        .set("simulate_secs", r.simulate_secs)
        .set("phases", phases)
        .set("memo_hits", r.memo_hits)
        .set("memo_misses", r.memo_misses)
}

/// Success response line. `audit` is the *request's* wish: the audit
/// object rides only when asked for AND the served report carries one (a
/// cached report stored by an unaudited leader answers without).
pub fn response_json(
    id: &Option<String>,
    resp: &ServiceResponse,
    top: usize,
    catalog: &GpuCatalog,
    audit: bool,
) -> Value {
    let mut v = Value::obj()
        .set("ok", true)
        .set("fingerprint", resp.fingerprint.to_string())
        .set("source", resp.source.as_str())
        .set("service_ms", resp.service_secs * 1e3)
        .set("engine", report_counts_json(&resp.report));
    if let Some(id) = id {
        v = v.set("id", id.as_str());
    }
    if let Some(best) = resp.report.best() {
        v = v.set("best", scored_strategy_json(best, catalog));
    }
    let tops: Vec<Value> = resp
        .report
        .top
        .iter()
        .take(top)
        .map(|s| scored_strategy_json(s, catalog))
        .collect();
    // Frontier-mode responses carry the whole Pareto curve next to `top`.
    if let Some(f) = crate::report::frontier_json(&resp.report, catalog) {
        v = v.set("frontier", f);
    }
    if audit {
        if let Some(a) = crate::report::audit_json(&resp.report) {
            v = v.set("audit", a);
        }
    }
    v.set("top", Value::Arr(tops))
}

/// Strip wall-clock and load-dependent fields from one response line so
/// transcripts are byte-stable across machines and runs (the golden wire
/// test pins everything else). Fields are zeroed rather than removed, so
/// their *presence* in the shape stays pinned too. Memo hit/miss counters
/// are normalized like the wall times: they depend on memo warmth (earlier
/// traffic) and on worker interleaving (two workers may both miss a key),
/// never on the selected strategies.
pub fn normalize_response_line(line: &str) -> Result<String> {
    let mut v = json::parse(line)?;
    if let Value::Obj(m) = &mut v {
        if m.contains_key("service_ms") {
            m.insert("service_ms".to_string(), Value::Num(0.0));
        }
        if let Some(Value::Obj(engine)) = m.get_mut("engine") {
            for k in ["search_secs", "simulate_secs", "memo_hits", "memo_misses"] {
                if engine.contains_key(k) {
                    engine.insert(k.to_string(), Value::Num(0.0));
                }
            }
            // The phase breakdown is wall time by another name.
            if let Some(phases) = engine.get_mut("phases") {
                zero_numbers(phases);
            }
        }
        // Cache byte accounting is an estimate that may drift with struct
        // layout, and snapshot bytes drift with the persist format; the
        // entry/hit counters stay pinned. Memo counters are load-dependent
        // (see above).
        if let Some(Value::Obj(stats)) = m.get_mut("stats") {
            // `metrics_registered` counts *names* in the process-global
            // registry, which other code in the same process may grow;
            // `faults_injected` is process-global too (other tests in the
            // same binary may arm failpoints).
            for k in [
                "cache_bytes",
                "memo_hits",
                "memo_misses",
                "persist_bytes",
                "metrics_registered",
                "faults_injected",
            ] {
                if stats.contains_key(k) {
                    stats.insert(k.to_string(), Value::Num(0.0));
                }
            }
        }
        // Health is a live probe: every number is load-dependent, and the
        // per-mode p50/p95/p99 keys only exist for modes that saw window
        // traffic (the histograms are process-global, so other tests'
        // requests leak into the window). Zero the numbers and collapse
        // the per-mode objects; `ready` (a boolean) and the rest of the
        // shape stay pinned.
        if let Some(health) = m.get_mut("health") {
            zero_numbers(health);
            if let Value::Obj(hm) = health {
                if let Some(Value::Obj(w)) = hm.get_mut("window") {
                    if let Some(Value::Obj(modes)) = w.get_mut("modes") {
                        for mv in modes.values_mut() {
                            *mv = Value::obj();
                        }
                    }
                }
            }
        }
        // Every metric value is load-dependent (process-global counters see
        // traffic from the whole test run); pin the registry's *names and
        // shape*, zero the numbers. Histogram buckets are elided when empty,
        // so their objects are normalized to `{}` for stability.
        if let Some(metrics) = m.get_mut("metrics") {
            zero_numbers(metrics);
            if let Value::Obj(mm) = metrics {
                if let Some(Value::Obj(hists)) = mm.get_mut("histograms") {
                    for h in hists.values_mut() {
                        if let Value::Obj(hm) = h {
                            hm.insert("buckets".to_string(), Value::obj());
                        }
                    }
                }
            }
        }
    }
    Ok(json::to_string(&v))
}

/// Recursively zero every number under `v` (normalization helper for the
/// load-dependent `metrics`/`phases` payloads).
fn zero_numbers(v: &mut Value) {
    match v {
        Value::Num(n) => *n = 0.0,
        Value::Obj(m) => m.values_mut().for_each(zero_numbers),
        Value::Arr(a) => a.iter_mut().for_each(zero_numbers),
        _ => {}
    }
}

/// Error response line: the full `Display` text plus the stable machine
/// `kind` tag and the `retryable` flag clients key their backoff on.
pub fn error_json(id: &Option<String>, err: &AstraError) -> Value {
    let mut v = Value::obj()
        .set("ok", false)
        .set("kind", err.kind())
        .set("retryable", err.retryable())
        .set("error", err.to_string().as_str());
    if let Some(id) = id {
        v = v.set("id", id.as_str());
    }
    v
}

/// Cache/engine statistics line (the `{"cmd":"stats"}` control request).
/// The `persist_*` counters make warm-start state observable across
/// restarts: scopes spilled/restored/rejected, cache entries moved, and
/// the latest snapshot's size on disk.
pub fn stats_json(service: &SearchService) -> Value {
    let s = service.cache_stats();
    let (memo_scopes, memo_hits, memo_misses) = service.core().memo_counters();
    let p = service.core().persist_stats();
    let (shed, deadline, panicked) = service.resilience_counters();
    Value::obj()
        .set("ok", true)
        .set("stats", Value::obj()
            .set("searches_run", service.core().searches_run())
            .set("cache_hits", s.hits)
            .set("cache_misses", s.misses)
            .set("cache_insertions", s.insertions)
            .set("cache_evictions", s.evictions)
            .set("cache_expirations", s.expirations)
            .set("cache_entries", s.entries)
            .set("cache_bytes", s.bytes)
            .set("memo_scopes", memo_scopes)
            .set("memo_hits", memo_hits)
            .set("memo_misses", memo_misses)
            .set("persist_scopes_spilled", p.scopes_spilled)
            .set("persist_scopes_restored", p.scopes_restored)
            .set("persist_scopes_rejected", p.scopes_rejected)
            .set("persist_scopes_dropped", p.scopes_dropped)
            .set("persist_bytes", p.bytes_on_disk)
            .set("persist_cache_spilled", p.cache_entries_spilled)
            .set("persist_cache_restored", p.cache_entries_restored)
            .set("requests_shed", shed)
            .set("requests_deadline", deadline)
            .set("requests_panicked", panicked)
            .set("faults_injected", crate::resilience::failpoint::faults_injected())
            .set("metrics_registered", crate::telemetry::metric_count()))
}

/// Telemetry registry line (the `{"cmd":"metrics"}` control request): the
/// whole process-global registry as canonical JSON. One command, the whole
/// picture — cache, memo, persist, per-phase latency histograms.
pub fn metrics_json() -> Value {
    Value::obj().set("ok", true).set("metrics", crate::telemetry::registry_json())
}

/// Live health line (the `{"cmd":"health"}` control request): readiness
/// plus the rolling window since the previous probe. See
/// [`SearchService::health`] for the lock discipline (registry snapshot
/// deltas only — a probe never waits on the search path).
pub fn health_json(service: &SearchService) -> Value {
    let h = service.health();
    let mut modes = Value::obj();
    for m in &h.modes {
        let mut mv = Value::obj().set("requests", m.requests);
        if let Some(p) = m.latency {
            mv = mv
                .set("p50_ms", p.p50 * 1e3)
                .set("p95_ms", p.p95 * 1e3)
                .set("p99_ms", p.p99 * 1e3);
        }
        modes = modes.set(m.mode, mv);
    }
    let mut health = Value::obj()
        .set("ready", h.ready)
        .set("active_requests", h.active_requests)
        .set("max_queue_depth", h.max_queue_depth)
        .set(
            "window",
            Value::obj()
                .set("requests", h.window_requests)
                .set("cache_hit_rate", h.cache_hit_rate)
                .set("shed_rate", h.shed_rate)
                .set("deadline_rate", h.deadline_rate)
                .set("panic_rate", h.panic_rate)
                .set("modes", modes),
        );
    health = match &h.warm_restore {
        Some(w) => health.set(
            "warm_restore",
            Value::obj()
                .set("scopes_restored", w.scopes_restored)
                .set("rows", w.rows)
                .set("cache_entries", w.cache_entries)
                .set("scopes_rejected", w.scopes_rejected),
        ),
        None => health.set("warm_restore", Value::Null),
    };
    Value::obj().set("ok", true).set("health", health)
}

/// What one admitted line turned into.
enum Admitted {
    /// Index into the batch's request vector.
    Request { id: Option<String>, slot: usize },
    /// Immediate error response (parse/validation failure).
    Immediate(Value),
    /// `{"cmd":"stats"}` — rendered at emission time, after the batch's
    /// requests have run, so the counters reflect them. Carries the echo id.
    Stats(Option<String>),
    /// `{"cmd":"metrics"}` — the telemetry registry dump; rendered at
    /// emission time like `stats`.
    Metrics(Option<String>),
    /// `{"cmd":"health"}` — readiness + rolling window; rendered at
    /// emission time so the window includes this batch's requests.
    Health(Option<String>),
}

/// Process one admitted batch of raw lines: parse, fan out the valid
/// requests through the admission queue, and write one response per line in
/// input order.
fn process_batch<W: Write>(
    service: &SearchService,
    lines: &[String],
    out: &mut W,
    opts: &ServeOpts,
    stats: &mut ServeStats,
) -> Result<()> {
    let catalog = &service.core().catalog;
    let registry = ModelRegistry::builtin();
    let mut admitted: Vec<Admitted> = Vec::with_capacity(lines.len());
    let mut requests: Vec<SearchRequest> = Vec::new();
    let mut request_opts: Vec<RequestOpts> = Vec::new();
    for line in lines {
        // The parse seam: a fired `wire.parse` failpoint degrades this
        // line to an error response — never a panic, never a lost line.
        let parsed = (|| -> Result<Value> {
            crate::failpoint!("wire.parse");
            json::parse(line)
        })();
        match parsed {
            Ok(v) => {
                match v.get("cmd").and_then(Value::as_str) {
                    Some("stats") => {
                        admitted.push(Admitted::Stats(wire_id(&v)));
                        continue;
                    }
                    Some("metrics") => {
                        admitted.push(Admitted::Metrics(wire_id(&v)));
                        continue;
                    }
                    Some("health") => {
                        admitted.push(Admitted::Health(wire_id(&v)));
                        continue;
                    }
                    _ => {}
                }
                match parse_request(&v, catalog, &registry) {
                    Ok(w) => {
                        admitted.push(Admitted::Request { id: w.id, slot: requests.len() });
                        requests.push(w.request);
                        request_opts
                            .push(RequestOpts { deadline_ms: w.deadline_ms, audit: w.audit });
                    }
                    Err(e) => {
                        admitted.push(Admitted::Immediate(error_json(&wire_id(&v), &e)));
                    }
                }
            }
            Err(e) => {
                admitted.push(Admitted::Immediate(error_json(&None, &e)));
            }
        }
    }
    let mut responses = service.handle_batch_opts(&requests, &request_opts);
    // Client-side retry of *retryable* errors (load shedding) with seeded
    // exponential backoff; everything else is deterministic and final.
    if opts.retries > 0 {
        let policy = RetryPolicy::new(opts.retries, opts.retry_base_ms, opts.retry_seed);
        for attempt in 0..opts.retries {
            let again: Vec<usize> = responses
                .iter()
                .enumerate()
                .filter(|(_, r)| matches!(r, Err(e) if e.retryable()))
                .map(|(i, _)| i)
                .collect();
            if again.is_empty() {
                break;
            }
            std::thread::sleep(policy.delay(attempt));
            let reqs: Vec<SearchRequest> = again.iter().map(|&i| requests[i].clone()).collect();
            let ro: Vec<RequestOpts> = again.iter().map(|&i| request_opts[i]).collect();
            for (k, r) in service.handle_batch_opts(&reqs, &ro).into_iter().enumerate() {
                responses[again[k]] = r;
            }
        }
    }
    for a in &admitted {
        let line = match a {
            Admitted::Immediate(v) => {
                stats.errors += 1;
                json::to_string(v)
            }
            Admitted::Stats(id) => {
                stats.ok += 1;
                let mut v = stats_json(service);
                if let Some(id) = id {
                    v = v.set("id", id.as_str());
                }
                json::to_string(&v)
            }
            Admitted::Metrics(id) => {
                stats.ok += 1;
                let mut v = metrics_json();
                if let Some(id) = id {
                    v = v.set("id", id.as_str());
                }
                json::to_string(&v)
            }
            Admitted::Health(id) => {
                stats.ok += 1;
                let mut v = health_json(service);
                if let Some(id) = id {
                    v = v.set("id", id.as_str());
                }
                json::to_string(&v)
            }
            Admitted::Request { id, slot } => match &responses[*slot] {
                Ok(resp) => {
                    stats.ok += 1;
                    json::to_string(&response_json(
                        id,
                        resp,
                        opts.top,
                        catalog,
                        request_opts[*slot].audit,
                    ))
                }
                Err(e) => {
                    stats.errors += 1;
                    json::to_string(&error_json(id, e))
                }
            },
        };
        out.write_all(line.as_bytes())?;
        out.write_all(b"\n")?;
    }
    out.flush()?;
    stats.lines += lines.len();
    Ok(())
}

/// The serve loop: a reader thread feeds an admission channel; the main
/// loop blocks for the first pending line, then greedily drains up to
/// `max_batch` already-buffered lines so bursts are admitted as one batch
/// and fanned out together, while interactive use still gets per-line
/// latency. Blank lines are ignored; EOF ends the loop.
pub fn run_serve_loop<R, W>(
    service: &SearchService,
    input: R,
    out: &mut W,
    opts: &ServeOpts,
) -> Result<ServeStats>
where
    R: BufRead + Send + 'static,
    W: Write,
{
    let mut stats = ServeStats::default();
    let (tx, rx) = mpsc::sync_channel::<String>(4096);
    // The reader is a *detached* thread, not a scoped one: on a write
    // error the loop must return immediately, but a reader parked inside a
    // blocking read syscall cannot be joined until more input (or EOF)
    // arrives. Detached, it notices the dropped `rx` at its next send and
    // exits on its own; on the normal path it has already finished at EOF.
    std::thread::spawn(move || {
        for line in input.lines() {
            match line {
                Ok(l) => {
                    if l.trim().is_empty() {
                        continue;
                    }
                    if tx.send(l).is_err() {
                        break;
                    }
                }
                Err(_) => break,
            }
        }
        // tx drops here → recv() below unblocks with Err → loop ends.
    });
    loop {
        let first = match rx.recv() {
            Ok(l) => l,
            Err(_) => break,
        };
        let mut batch = vec![first];
        while batch.len() < opts.max_batch.max(1) {
            match rx.try_recv() {
                Ok(l) => batch.push(l),
                Err(_) => break,
            }
        }
        process_batch(service, &batch, out, opts, &mut stats)?;
    }
    Ok(stats)
}

/// `astra batch <file>`: admit the whole file through the same machinery,
/// `max_batch` lines at a time, writing responses in input order.
pub fn run_batch_lines<W: Write>(
    service: &SearchService,
    text: &str,
    out: &mut W,
    opts: &ServeOpts,
) -> Result<ServeStats> {
    let mut stats = ServeStats::default();
    let lines: Vec<String> =
        text.lines().filter(|l| !l.trim().is_empty()).map(String::from).collect();
    for chunk in lines.chunks(opts.max_batch.max(1)) {
        process_batch(service, chunk, out, opts, &mut stats)?;
    }
    Ok(stats)
}

/// TCP front end: one thread per connection, each running the serve loop
/// against the shared service. Never returns except on bind error.
pub fn serve_tcp(service: Arc<SearchService>, addr: &str, opts: &ServeOpts) -> Result<()> {
    let listener = std::net::TcpListener::bind(addr)?;
    crate::log_info!("astra serve listening on {addr}");
    for stream in listener.incoming() {
        let stream = match stream {
            Ok(s) => s,
            Err(e) => {
                crate::log_warn!("accept failed: {e}");
                continue;
            }
        };
        let service = service.clone();
        let opts = opts.clone();
        std::thread::spawn(move || {
            let reader = match stream.try_clone() {
                Ok(s) => std::io::BufReader::new(s),
                Err(e) => {
                    crate::log_warn!("clone stream: {e}");
                    return;
                }
            };
            let mut writer = std::io::BufWriter::new(stream);
            if let Err(e) = run_serve_loop(&service, reader, &mut writer, &opts) {
                crate::log_warn!("connection ended with error: {e}");
            }
            // The TCP front end has no process-shutdown hook, so each
            // connection close doubles as one: with --warm-dir configured
            // this keeps `--warm-spill-every 0` meaningful under --listen.
            match service.spill_warm() {
                Ok(Some(s)) => crate::log_info!(
                    "warm spill on connection close: {} scope(s), {} cache entries",
                    s.scopes,
                    s.cache_entries
                ),
                Ok(None) => {}
                Err(e) => crate::log_warn!("warm spill failed: {e}"),
            }
        });
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::service::fingerprint::fingerprint;

    fn catalog() -> GpuCatalog {
        GpuCatalog::builtin()
    }

    #[test]
    fn parse_minimal_homogeneous() {
        let v = json::parse(r#"{"model":"llama2-7b","gpu":"a800","gpus":64}"#).unwrap();
        let w = parse_request(&v, &catalog(), &ModelRegistry::builtin()).unwrap();
        assert!(w.id.is_none());
        assert!(w.deadline_ms.is_none());
        let GpuPoolMode::Homogeneous { count, .. } = &w.request.mode else {
            unreachable!("parsed the wrong mode: {:?}", w.request.mode)
        };
        assert_eq!(*count, 64);
    }

    #[test]
    fn parse_deadline_ms() {
        let v = json::parse(r#"{"model":"llama2-7b","gpu":"a800","gpus":64,"deadline_ms":250}"#)
            .unwrap();
        let w = parse_request(&v, &catalog(), &ModelRegistry::builtin()).unwrap();
        assert_eq!(w.deadline_ms, Some(250));
        // 0 parses fine — "cache or fail now" is decided by the service.
        let v = json::parse(r#"{"model":"llama2-7b","gpu":"a800","gpus":64,"deadline_ms":0}"#)
            .unwrap();
        let w = parse_request(&v, &catalog(), &ModelRegistry::builtin()).unwrap();
        assert_eq!(w.deadline_ms, Some(0));
        // Garbage deadlines are typed json errors, not panics or silence.
        for bad in [
            r#"{"model":"llama2-7b","gpu":"a800","gpus":64,"deadline_ms":-5}"#,
            r#"{"model":"llama2-7b","gpu":"a800","gpus":64,"deadline_ms":1.5}"#,
            r#"{"model":"llama2-7b","gpu":"a800","gpus":64,"deadline_ms":"soon"}"#,
        ] {
            let v = json::parse(bad).unwrap();
            let err = parse_request(&v, &catalog(), &ModelRegistry::builtin()).unwrap_err();
            assert_eq!(err.kind(), "json", "{bad} → {err}");
        }
    }

    #[test]
    fn parse_hetero_cost() {
        let v = json::parse(
            r#"{"model":"llama2-7b","mode":"hetero-cost","caps":{"a800":16,"h100":8},"max_money":1234.5}"#,
        )
        .unwrap();
        let w = parse_request(&v, &catalog(), &ModelRegistry::builtin()).unwrap();
        let GpuPoolMode::HeteroCost { caps, max_money } = &w.request.mode else {
            unreachable!("parsed the wrong mode: {:?}", w.request.mode)
        };
        assert_eq!(caps.len(), 2);
        assert_eq!(*max_money, 1234.5);
        let cat = catalog();
        let total: usize = caps.iter().map(|&(_, c)| c).sum();
        assert_eq!(total, 24);
        assert!(caps.iter().any(|&(g, c)| cat.spec(g).name == "a800" && c == 16));
        // Budget omitted = unlimited.
        let v = json::parse(r#"{"model":"llama2-7b","mode":"hetero-cost","caps":{"a800":8}}"#)
            .unwrap();
        let w = parse_request(&v, &catalog(), &ModelRegistry::builtin()).unwrap();
        let GpuPoolMode::HeteroCost { max_money, .. } = &w.request.mode else {
            unreachable!("parsed the wrong mode: {:?}", w.request.mode)
        };
        assert!(max_money.is_infinite());
    }

    #[test]
    fn parse_frontier() {
        let v = json::parse(r#"{"model":"llama2-7b","mode":"frontier","caps":{"a800":16,"h100":8}}"#)
            .unwrap();
        let w = parse_request(&v, &catalog(), &ModelRegistry::builtin()).unwrap();
        let GpuPoolMode::Frontier { caps } = &w.request.mode else {
            unreachable!("parsed the wrong mode: {:?}", w.request.mode)
        };
        assert_eq!(caps.len(), 2);
        let total: usize = caps.iter().map(|&(_, c)| c).sum();
        assert_eq!(total, 24);
        // Frontier mode has no budget axis: a `max_money` is a client bug
        // and must be rejected loudly, not silently ignored.
        let v = json::parse(
            r#"{"model":"llama2-7b","mode":"frontier","caps":{"a800":16},"max_money":100}"#,
        )
        .unwrap();
        let err = parse_request(&v, &catalog(), &ModelRegistry::builtin()).unwrap_err();
        assert!(err.to_string().contains("max_money"), "{err}");
    }

    #[test]
    fn normalization_zeroes_only_wall_clock_fields() {
        let line = r#"{"engine":{"generated":10,"search_secs":0.123,"simulate_secs":4.5},"fingerprint":"00000000000000ff","ok":true,"service_ms":9.87,"source":"search"}"#;
        let norm = normalize_response_line(line).unwrap();
        let v = json::parse(&norm).unwrap();
        assert_eq!(v.opt_f64("service_ms"), Some(0.0));
        assert_eq!(v.pointer("/engine/search_secs").and_then(Value::as_f64), Some(0.0));
        assert_eq!(v.pointer("/engine/simulate_secs").and_then(Value::as_f64), Some(0.0));
        assert_eq!(v.pointer("/engine/generated").and_then(Value::as_usize), Some(10));
        assert_eq!(v.opt_str("fingerprint"), Some("00000000000000ff"));
        // Error lines (no timing fields) pass through unchanged.
        let err = r#"{"error":"nope","ok":false}"#;
        assert_eq!(normalize_response_line(err).unwrap(), err);
    }

    #[test]
    fn normalization_zeroes_phases_and_metrics_payloads() {
        // The phase breakdown is wall time; every number zeroes, counts stay.
        let line = r#"{"engine":{"generated":3,"phases":{"compile":0.1,"score":0.2}},"ok":true}"#;
        let v = json::parse(&normalize_response_line(line).unwrap()).unwrap();
        assert_eq!(v.pointer("/engine/phases/compile").and_then(Value::as_f64), Some(0.0));
        assert_eq!(v.pointer("/engine/phases/score").and_then(Value::as_f64), Some(0.0));
        assert_eq!(v.pointer("/engine/generated").and_then(Value::as_usize), Some(3));
        // A metrics line keeps its names/shape but zeroes every value and
        // empties the (load-dependent) histogram bucket maps.
        let line = r#"{"metrics":{"counters":{"astra_searches_total":7},"gauges":{"astra_memo_scopes":2},"histograms":{"astra_search_e2e_seconds":{"buckets":{"b21":4},"count":4,"sum_secs":1.5}}},"ok":true}"#;
        let v = json::parse(&normalize_response_line(line).unwrap()).unwrap();
        assert_eq!(
            v.pointer("/metrics/counters/astra_searches_total").and_then(Value::as_f64),
            Some(0.0)
        );
        assert_eq!(
            v.pointer("/metrics/gauges/astra_memo_scopes").and_then(Value::as_f64),
            Some(0.0)
        );
        let h = v.pointer("/metrics/histograms/astra_search_e2e_seconds").unwrap();
        assert_eq!(h.get("count").and_then(Value::as_f64), Some(0.0));
        assert!(h.get("buckets").and_then(Value::as_obj).unwrap().is_empty());
    }

    #[test]
    fn parse_errors_are_recoverable() {
        let reg = ModelRegistry::builtin();
        for bad in [
            r#"{"gpu":"a800","gpus":64}"#,                         // no model
            r#"{"model":"gpt-5","gpu":"a800","gpus":64}"#,         // unknown model
            r#"{"model":"llama2-7b","gpu":"b200","gpus":64}"#,     // unknown gpu
            r#"{"model":"llama2-7b","mode":"quantum","gpus":64}"#, // unknown mode
            r#"{"model":"llama2-7b","mode":"heterogeneous","gpus":64}"#, // no caps
            r#"{"model":"llama2-7b","mode":"hetero-cost","max_money":100}"#, // no caps
            r#"{"model":"llama2-7b","mode":"frontier"}"#,                // no caps
            r#"{"model":"llama2-7b","mode":"frontier","caps":{"a800":8},"max_money":100}"#,
            r#"{"model":"llama2-7b","mode":"cost","gpu":"h100","gpus":64,"max_money":0}"#,
            r#"{"model":"llama2-7b","mode":"cost","gpu":"h100","gpus":64,"max_money":-5}"#,
            r#"{"model":"llama2-7b","mode":"hetero-cost","caps":{"a800":8},"max_money":-1}"#,
            r#"{"model":"llama2-7b","mode":"cost","gpu":"h100","gpus":64,"max_money":"lots"}"#,
        ] {
            let v = json::parse(bad).unwrap();
            assert!(parse_request(&v, &catalog(), &reg).is_err(), "accepted: {bad}");
        }
    }

    #[test]
    fn wire_roundtrip_preserves_fingerprint() {
        let cat = catalog();
        let reg = ModelRegistry::builtin();
        let cfg = crate::coordinator::EngineConfig::default();
        for src in [
            r#"{"model":"llama2-7b","gpu":"a800","gpus":64}"#,
            r#"{"model":"llama2-13b","mode":"heterogeneous","gpus":64,"caps":{"a800":48,"h100":48}}"#,
            r#"{"model":"llama2-7b","mode":"cost","gpu":"h100","gpus":64,"max_money":50000}"#,
            r#"{"model":"llama2-7b","mode":"hetero-cost","caps":{"a800":16,"h100":16},"max_money":50000}"#,
            r#"{"model":"llama2-7b","mode":"hetero-cost","caps":{"a800":16,"v100":8}}"#,
            r#"{"model":"llama2-7b","mode":"frontier","caps":{"a800":16,"h100":16}}"#,
        ] {
            let w = parse_request(&json::parse(src).unwrap(), &cat, &reg).unwrap();
            let wire = request_to_json(&w.request, &cat);
            let back = parse_request(&wire, &cat, &reg).unwrap();
            assert_eq!(
                fingerprint(&w.request, &cat, &cfg),
                fingerprint(&back.request, &cat, &cfg),
                "round-trip changed the fingerprint for {src}"
            );
        }
    }

    #[test]
    fn json_field_order_does_not_change_fingerprint() {
        let cat = catalog();
        let reg = ModelRegistry::builtin();
        let cfg = crate::coordinator::EngineConfig::default();
        let a = parse_request(
            &json::parse(r#"{"model":"llama2-7b","gpu":"a800","gpus":64}"#).unwrap(),
            &cat,
            &reg,
        )
        .unwrap();
        let b = parse_request(
            &json::parse(r#"{"gpus":64,"gpu":"a800","model":"llama2-7b"}"#).unwrap(),
            &cat,
            &reg,
        )
        .unwrap();
        assert_eq!(fingerprint(&a.request, &cat, &cfg), fingerprint(&b.request, &cat, &cfg));
    }

    /// Every mode's malformed payload must come back as a typed error
    /// *line* — the serve loop never panics, never drops a line, and keeps
    /// serving afterwards.
    #[test]
    fn malformed_payloads_per_mode_become_error_lines() {
        let svc = crate::service::SearchService::new(
            crate::service::tests::small_core(),
            crate::service::ServiceConfig::default(),
        );
        let cases: &[(&str, &str)] = &[
            (r#"{"model":"llama2-7b","mode":"homogeneous","gpu":"a800"}"#, "json"),
            (r#"{"model":"llama2-7b","mode":"heterogeneous","gpus":64}"#, "json"),
            (r#"{"model":"llama2-7b","mode":"cost","gpu":"h100","gpus":64,"max_money":0}"#, "config"),
            (r#"{"model":"llama2-7b","mode":"hetero-cost","caps":{"a800":"many"}}"#, "json"),
            (r#"{"model":"llama2-7b","mode":"frontier","caps":{"a800":8},"max_money":9}"#, "config"),
            (r#"{"model":"llama2-7b","mode":"quantum","gpus":64}"#, "config"),
            (r#"this is not json"#, "json"),
        ];
        let input: String =
            cases.iter().map(|(l, _)| format!("{l}\n")).collect::<Vec<_>>().concat();
        let mut out = Vec::new();
        let stats =
            run_batch_lines(&svc, &input, &mut out, &ServeOpts::default()).unwrap();
        assert_eq!(stats.lines, cases.len());
        assert_eq!(stats.errors, cases.len(), "every malformed line is an error line");
        let text = String::from_utf8(out).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), cases.len(), "exactly one response per request line");
        for (line, (src, kind)) in lines.iter().zip(cases) {
            let v = json::parse(line).unwrap();
            assert_eq!(v.get("ok").and_then(Value::as_bool), Some(false), "{src}");
            assert_eq!(v.opt_str("kind"), Some(*kind), "{src} → {line}");
            assert_eq!(v.get("retryable").and_then(Value::as_bool), Some(false), "{src}");
        }
        // The loop is not poisoned: a well-formed request still succeeds.
        let mut out = Vec::new();
        let good = r#"{"model":"llama2-7b","gpu":"a800","gpus":16}"#;
        let stats = run_batch_lines(&svc, good, &mut out, &ServeOpts::default()).unwrap();
        assert_eq!((stats.ok, stats.errors), (1, 0));
    }

    #[test]
    fn parse_audit_flag() {
        let reg = ModelRegistry::builtin();
        let v = json::parse(r#"{"model":"llama2-7b","gpu":"a800","gpus":64}"#).unwrap();
        assert!(!parse_request(&v, &catalog(), &reg).unwrap().audit, "default is off");
        let v = json::parse(r#"{"model":"llama2-7b","gpu":"a800","gpus":64,"audit":true}"#)
            .unwrap();
        assert!(parse_request(&v, &catalog(), &reg).unwrap().audit);
        let v = json::parse(r#"{"model":"llama2-7b","gpu":"a800","gpus":64,"audit":false}"#)
            .unwrap();
        assert!(!parse_request(&v, &catalog(), &reg).unwrap().audit);
        // Non-boolean audit is a typed json error, not a silent default.
        let v =
            json::parse(r#"{"model":"llama2-7b","gpu":"a800","gpus":64,"audit":1}"#).unwrap();
        assert_eq!(parse_request(&v, &catalog(), &reg).unwrap_err().kind(), "json");
    }

    #[test]
    fn audited_request_carries_audit_and_unaudited_never_does() {
        let svc = crate::service::SearchService::new(
            crate::service::tests::small_core(),
            crate::service::ServiceConfig::default(),
        );
        let input = r#"{"id":"a1","model":"llama2-7b","gpu":"a800","gpus":16,"audit":true}"#;
        let mut out = Vec::new();
        run_batch_lines(&svc, input, &mut out, &ServeOpts::default()).unwrap();
        let text = String::from_utf8(out).unwrap();
        let v = json::parse(text.lines().next().unwrap()).unwrap();
        assert_eq!(v.get("ok").and_then(Value::as_bool), Some(true));
        let audit = v.get("audit").expect("audited response carries an audit object");
        assert_eq!(audit.pointer("/astra_audit").and_then(Value::as_u64), Some(1));
        // Decisions partition the audited pool set.
        let pools = audit.get("pools").and_then(Value::as_u64).unwrap();
        let admitted = audit.get("admitted").and_then(Value::as_u64).unwrap();
        let pb = audit.get("pruned_budget").and_then(Value::as_u64).unwrap();
        let pd = audit.get("pruned_dominated").and_then(Value::as_u64).unwrap();
        assert_eq!(pools, admitted + pb + pd);
        assert!(pools > 0, "a homogeneous search audits its one pool");
        // The engine counters carry the prune split everywhere.
        assert!(v.pointer("/engine/pruned_budget").is_some());
        assert!(v.pointer("/engine/pruned_dominated").is_some());
        // An unaudited repeat of the same request hits the cache — whose
        // stored report DOES carry an audit — and must not leak it.
        let input = r#"{"id":"a2","model":"llama2-7b","gpu":"a800","gpus":16}"#;
        let mut out = Vec::new();
        run_batch_lines(&svc, input, &mut out, &ServeOpts::default()).unwrap();
        let text = String::from_utf8(out).unwrap();
        let v = json::parse(text.lines().next().unwrap()).unwrap();
        assert_eq!(v.opt_str("source"), Some("cache"));
        assert!(v.get("audit").is_none(), "audit rides only when asked for");
        // An audited repeat served from that same cache entry gets the
        // stored audit back without re-searching.
        let input = r#"{"id":"a3","model":"llama2-7b","gpu":"a800","gpus":16,"audit":true}"#;
        let mut out = Vec::new();
        run_batch_lines(&svc, input, &mut out, &ServeOpts::default()).unwrap();
        let text = String::from_utf8(out).unwrap();
        let v = json::parse(text.lines().next().unwrap()).unwrap();
        assert_eq!(v.opt_str("source"), Some("cache"));
        assert!(v.get("audit").is_some(), "cached audit is served back");
    }

    #[test]
    fn health_line_reports_ready_and_normalizes_stably() {
        let svc = crate::service::SearchService::new(
            crate::service::tests::small_core(),
            crate::service::ServiceConfig::default(),
        );
        let input = "{\"model\":\"llama2-7b\",\"gpu\":\"a800\",\"gpus\":16}\n{\"cmd\":\"health\",\"id\":\"h\"}";
        let mut out = Vec::new();
        let stats = run_batch_lines(&svc, input, &mut out, &ServeOpts::default()).unwrap();
        assert_eq!((stats.ok, stats.errors), (2, 0));
        let text = String::from_utf8(out).unwrap();
        let line = text.lines().nth(1).unwrap();
        let v = json::parse(line).unwrap();
        assert_eq!(v.get("ok").and_then(Value::as_bool), Some(true));
        assert_eq!(v.opt_str("id"), Some("h"));
        assert_eq!(
            v.pointer("/health/ready").and_then(Value::as_bool),
            Some(true),
            "unbounded queue is always ready"
        );
        // This batch's request landed in the window (histograms are
        // process-global so other tests may add more — never fewer).
        let reqs = v.pointer("/health/window/requests").and_then(Value::as_u64).unwrap();
        assert!(reqs >= 1, "the batch's own request is in the window");
        assert!(
            v.pointer("/health/window/modes/homogeneous").is_some(),
            "every mode is present in the window"
        );
        assert!(v.pointer("/health/warm_restore").is_some(), "warm state is reported");
        // Normalization: readiness and shape pinned, numbers zeroed,
        // traffic-dependent per-mode payloads collapsed.
        let norm = json::parse(&normalize_response_line(line).unwrap()).unwrap();
        assert_eq!(norm.pointer("/health/ready").and_then(Value::as_bool), Some(true));
        assert_eq!(
            norm.pointer("/health/window/requests").and_then(Value::as_f64),
            Some(0.0)
        );
        assert!(norm
            .pointer("/health/window/modes/homogeneous")
            .and_then(Value::as_obj)
            .unwrap()
            .is_empty());
    }
}
