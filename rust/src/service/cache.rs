//! Sharded result cache: fingerprint → [`SearchReport`], LRU with TTL and
//! byte-budget eviction.
//!
//! The cache is split into independently locked shards so concurrent
//! requests on different keys never contend; a hit costs one shard lock,
//! one `HashMap` probe and an `Arc` clone (microseconds against the
//! multi-second cold search it replaces). Eviction is least-recently-used
//! within the shard holding the insertion, driven by both an entry budget
//! and an approximate byte budget; entries older than the TTL are dropped
//! lazily at lookup time.

use crate::coordinator::SearchReport;
use crate::pareto::PoolEntry;
use crate::resilience::lock_unpoisoned;
use crate::strategy::Segment;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use super::fingerprint::Fingerprint;

/// Cache tuning knobs. Budgets are totals; each shard gets an equal slice.
#[derive(Debug, Clone)]
pub struct CacheConfig {
    /// Number of independently locked shards (≥ 1).
    pub shards: usize,
    /// Maximum cached reports across all shards.
    pub max_entries: usize,
    /// Approximate maximum resident bytes across all shards.
    pub max_bytes: usize,
    /// Entries older than this are expired at lookup; `None` = no TTL.
    pub ttl: Option<Duration>,
}

impl Default for CacheConfig {
    fn default() -> Self {
        CacheConfig {
            shards: 8,
            max_entries: 1024,
            max_bytes: 256 << 20,
            ttl: None,
        }
    }
}

/// Monotonic counters exposed for the CLI `stats` line and tests.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub insertions: u64,
    pub evictions: u64,
    pub expirations: u64,
    /// Inserts refused because one report exceeded the per-shard byte
    /// budget (caching it would flush the shard and then evict itself).
    pub oversize_rejects: u64,
    /// Current resident entries / approximate bytes (gauges, not counters).
    pub entries: usize,
    pub bytes: usize,
}

struct Entry {
    report: Arc<SearchReport>,
    bytes: usize,
    inserted: Instant,
    last_used: u64,
}

#[derive(Default)]
struct Shard {
    map: HashMap<u64, Entry>,
    bytes: usize,
}

impl Shard {
    /// Evict least-recently-used entries until within the given budgets.
    /// Returns how many entries were evicted.
    fn evict_to(&mut self, max_entries: usize, max_bytes: usize) -> u64 {
        let mut evicted = 0;
        while self.map.len() > max_entries || self.bytes > max_bytes {
            let Some((&victim, _)) =
                self.map.iter().min_by_key(|(_, e)| e.last_used)
            else {
                break;
            };
            if let Some(e) = self.map.remove(&victim) {
                self.bytes -= e.bytes;
                evicted += 1;
            }
        }
        evicted
    }
}

/// Process-global registry mirror: the per-instance atomics below stay
/// authoritative for [`ShardedCache::stats`] (tests build many independent
/// caches), while these handles additionally accumulate process-wide
/// totals behind `{"cmd":"metrics"}` (see [`crate::telemetry`]).
struct RegistryMirror {
    hits: Arc<crate::telemetry::Counter>,
    misses: Arc<crate::telemetry::Counter>,
    insertions: Arc<crate::telemetry::Counter>,
    evictions: Arc<crate::telemetry::Counter>,
    expirations: Arc<crate::telemetry::Counter>,
    oversize_rejects: Arc<crate::telemetry::Counter>,
}

impl RegistryMirror {
    fn new() -> RegistryMirror {
        RegistryMirror {
            hits: crate::telemetry::counter("astra_cache_hits_total"),
            misses: crate::telemetry::counter("astra_cache_misses_total"),
            insertions: crate::telemetry::counter("astra_cache_insertions_total"),
            evictions: crate::telemetry::counter("astra_cache_evictions_total"),
            expirations: crate::telemetry::counter("astra_cache_expirations_total"),
            oversize_rejects: crate::telemetry::counter("astra_cache_oversize_rejects_total"),
        }
    }
}

/// The sharded LRU+TTL result cache.
pub struct ShardedCache {
    shards: Vec<Mutex<Shard>>,
    config: CacheConfig,
    /// Global logical clock for LRU ordering (cheaper than Instant reads
    /// and immune to clock adjustments).
    tick: AtomicU64,
    hits: AtomicU64,
    misses: AtomicU64,
    insertions: AtomicU64,
    evictions: AtomicU64,
    expirations: AtomicU64,
    oversize_rejects: AtomicU64,
    mirror: RegistryMirror,
}

impl ShardedCache {
    pub fn new(config: CacheConfig) -> ShardedCache {
        let n = config.shards.max(1);
        ShardedCache {
            shards: (0..n).map(|_| Mutex::new(Shard::default())).collect(),
            config,
            tick: AtomicU64::new(0),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            insertions: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            expirations: AtomicU64::new(0),
            oversize_rejects: AtomicU64::new(0),
            mirror: RegistryMirror::new(),
        }
    }

    fn shard(&self, fp: Fingerprint) -> &Mutex<Shard> {
        // FNV output is well mixed; fold high bits in anyway so shard
        // count never correlates with low-bit structure.
        let k = fp.0 ^ (fp.0 >> 32);
        &self.shards[(k as usize) % self.shards.len()]
    }

    fn per_shard_entries(&self) -> usize {
        (self.config.max_entries.max(1)).div_ceil(self.shards.len())
    }

    fn per_shard_bytes(&self) -> usize {
        (self.config.max_bytes.max(1)).div_ceil(self.shards.len())
    }

    /// Look a fingerprint up; bumps LRU recency on hit, expires on TTL.
    pub fn get(&self, fp: Fingerprint) -> Option<Arc<SearchReport>> {
        self.lookup(fp, true)
    }

    /// Like [`ShardedCache::get`] (including LRU bump and TTL expiry) but
    /// without touching the hit/miss counters — for internal double-checks
    /// that would otherwise double-count one logical lookup.
    pub fn peek(&self, fp: Fingerprint) -> Option<Arc<SearchReport>> {
        self.lookup(fp, false)
    }

    fn lookup(&self, fp: Fingerprint, count: bool) -> Option<Arc<SearchReport>> {
        let now = Instant::now();
        // Poison-tolerant locks throughout: the service isolates request
        // panics (`catch_unwind`), so a shard must stay usable even if a
        // panic ever unwound through it — its state is a plain map that is
        // valid at every step.
        let mut shard = lock_unpoisoned(self.shard(fp));
        match shard.map.get_mut(&fp.0) {
            Some(e) => {
                if let Some(ttl) = self.config.ttl {
                    if now.duration_since(e.inserted) >= ttl {
                        let bytes = e.bytes;
                        shard.map.remove(&fp.0);
                        shard.bytes -= bytes;
                        self.expirations.fetch_add(1, Ordering::Relaxed);
                        self.mirror.expirations.inc();
                        if count {
                            self.misses.fetch_add(1, Ordering::Relaxed);
                            self.mirror.misses.inc();
                        }
                        return None;
                    }
                }
                e.last_used = self.tick.fetch_add(1, Ordering::Relaxed);
                if count {
                    self.hits.fetch_add(1, Ordering::Relaxed);
                    self.mirror.hits.inc();
                }
                Some(e.report.clone())
            }
            None => {
                if count {
                    self.misses.fetch_add(1, Ordering::Relaxed);
                    self.mirror.misses.inc();
                }
                None
            }
        }
    }

    /// Insert (or refresh) a report under its fingerprint, then evict the
    /// shard back under budget, least-recently-used first.
    pub fn insert(&self, fp: Fingerprint, report: Arc<SearchReport>) {
        let bytes = report_bytes(&report);
        if bytes > self.per_shard_bytes() {
            // Refuse oversized entries outright: admitting one would evict
            // every co-resident entry in the shard and then be evicted
            // itself, leaving the shard empty and the report uncached.
            self.oversize_rejects.fetch_add(1, Ordering::Relaxed);
            self.mirror.oversize_rejects.inc();
            return;
        }
        let last_used = self.tick.fetch_add(1, Ordering::Relaxed);
        let mut shard = lock_unpoisoned(self.shard(fp));
        if let Some(old) = shard.map.insert(
            fp.0,
            Entry { report, bytes, inserted: Instant::now(), last_used },
        ) {
            shard.bytes -= old.bytes;
        }
        shard.bytes += bytes;
        self.insertions.fetch_add(1, Ordering::Relaxed);
        self.mirror.insertions.inc();
        let evicted = shard.evict_to(self.per_shard_entries(), self.per_shard_bytes());
        self.evictions.fetch_add(evicted, Ordering::Relaxed);
        self.mirror.evictions.add(evicted);
    }

    /// Every resident entry `(fingerprint, report)`, sorted by fingerprint
    /// so spills are deterministic. TTL is *not* re-checked here: restore
    /// re-inserts with a fresh timestamp, so an entry's TTL restarts with
    /// the process (the snapshot stores no wall clock to age against).
    pub fn export_entries(&self) -> Vec<(u64, Arc<SearchReport>)> {
        let mut v: Vec<(u64, Arc<SearchReport>)> = Vec::new();
        for s in &self.shards {
            for (k, e) in lock_unpoisoned(s).map.iter() {
                v.push((*k, e.report.clone()));
            }
        }
        v.sort_unstable_by_key(|&(k, _)| k);
        v
    }

    /// Drop every entry (tests / `astra serve` SIGHUP-style reset).
    pub fn clear(&self) {
        for s in &self.shards {
            let mut s = lock_unpoisoned(s);
            s.map.clear();
            s.bytes = 0;
        }
    }

    /// Current resident entry count.
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| lock_unpoisoned(s).map.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn stats(&self) -> CacheStats {
        let (mut entries, mut bytes) = (0, 0);
        for s in &self.shards {
            let s = lock_unpoisoned(s);
            entries += s.map.len();
            bytes += s.bytes;
        }
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            insertions: self.insertions.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            expirations: self.expirations.load(Ordering::Relaxed),
            oversize_rejects: self.oversize_rejects.load(Ordering::Relaxed),
            entries,
            bytes,
        }
    }
}

/// Approximate resident size of a report: the struct plus its heap blocks
/// (top strategies with their segment/stage vectors, and the Pareto pool).
/// Used only for the byte budget — exactness is not required.
pub fn report_bytes(r: &SearchReport) -> usize {
    let mut b = std::mem::size_of::<SearchReport>();
    for s in &r.top {
        b += std::mem::size_of_val(s);
        b += s.strategy.cluster.segments.len() * std::mem::size_of::<Segment>();
        b += s.cost.stage_times.len() * std::mem::size_of::<f64>();
    }
    b += r.pool.len() * std::mem::size_of::<PoolEntry>();
    if let Some(fr) = &r.frontier {
        for c in &fr.candidates {
            b += std::mem::size_of_val(c);
            b += c.scored.strategy.cluster.segments.len() * std::mem::size_of::<Segment>();
            b += c.scored.cost.stage_times.len() * std::mem::size_of::<f64>();
        }
    }
    b
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pareto::OptimalPool;

    fn report(tag: usize) -> Arc<SearchReport> {
        Arc::new(SearchReport {
            generated: tag,
            rule_filtered: 0,
            mem_filtered: 0,
            scored: 0,
            pruned_pools: 0,
            search_secs: 0.0,
            simulate_secs: 0.0,
            phases: Default::default(),
            memo_hits: 0,
            memo_misses: 0,
            top: Vec::new(),
            pool: OptimalPool::default(),
            frontier: None,
        })
    }

    fn fp(n: u64) -> Fingerprint {
        Fingerprint(n)
    }

    #[test]
    fn hit_miss_accounting() {
        let c = ShardedCache::new(CacheConfig::default());
        assert!(c.get(fp(1)).is_none());
        c.insert(fp(1), report(1));
        assert_eq!(c.get(fp(1)).unwrap().generated, 1);
        assert!(c.get(fp(2)).is_none());
        let s = c.stats();
        assert_eq!((s.hits, s.misses, s.insertions), (1, 2, 1));
        assert_eq!(s.entries, 1);
        assert!(s.bytes > 0);
    }

    #[test]
    fn ttl_expires_entries() {
        let c = ShardedCache::new(CacheConfig {
            ttl: Some(Duration::from_millis(25)),
            ..Default::default()
        });
        c.insert(fp(7), report(7));
        assert!(c.get(fp(7)).is_some());
        std::thread::sleep(Duration::from_millis(60));
        assert!(c.get(fp(7)).is_none(), "entry outlived its TTL");
        let s = c.stats();
        assert_eq!(s.expirations, 1);
        assert_eq!(s.entries, 0);
    }

    #[test]
    fn lru_eviction_order_by_entry_budget() {
        // One shard → deterministic eviction.
        let c = ShardedCache::new(CacheConfig {
            shards: 1,
            max_entries: 2,
            ..Default::default()
        });
        c.insert(fp(1), report(1));
        c.insert(fp(2), report(2));
        // Touch 1 so 2 becomes the LRU victim.
        assert!(c.get(fp(1)).is_some());
        c.insert(fp(3), report(3));
        assert!(c.get(fp(2)).is_none(), "LRU entry should have been evicted");
        assert!(c.get(fp(1)).is_some());
        assert!(c.get(fp(3)).is_some());
        assert_eq!(c.stats().evictions, 1);
        assert_eq!(c.len(), 2);
    }

    #[test]
    fn byte_budget_evicts() {
        let one = report_bytes(&report(0));
        let c = ShardedCache::new(CacheConfig {
            shards: 1,
            max_entries: usize::MAX,
            // Room for two empty reports but not three.
            max_bytes: one * 2 + one / 2,
            ttl: None,
        });
        for i in 0..3 {
            c.insert(fp(i), report(i as usize));
        }
        assert!(c.stats().evictions >= 1, "byte budget never fired");
        assert!(c.stats().bytes <= one * 2 + one / 2);
        assert!(c.get(fp(2)).is_some(), "most recent entry must survive");
    }

    #[test]
    fn oversized_entry_rejected_without_flushing_shard() {
        let one = report_bytes(&report(0));
        // An entry exactly at the shard budget is still cacheable…
        let c = ShardedCache::new(CacheConfig {
            shards: 1,
            max_entries: usize::MAX,
            max_bytes: one,
            ttl: None,
        });
        c.insert(fp(1), report(1));
        assert_eq!(c.len(), 1, "exactly-at-budget entry is cacheable");

        // …while anything over it is refused without touching residents.
        let tight = ShardedCache::new(CacheConfig {
            shards: 1,
            max_entries: usize::MAX,
            max_bytes: one - 1,
            ttl: None,
        });
        tight.insert(fp(1), report(1));
        tight.insert(fp(2), report(2));
        assert_eq!(tight.len(), 0, "oversized entries must not be admitted");
        let s = tight.stats();
        assert_eq!(s.oversize_rejects, 2);
        assert_eq!(s.insertions, 0);
        assert_eq!(s.evictions, 0, "rejection must not evict residents");
    }

    #[test]
    fn reinsert_replaces_without_leaking_bytes() {
        let c = ShardedCache::new(CacheConfig { shards: 1, ..Default::default() });
        c.insert(fp(1), report(1));
        let b1 = c.stats().bytes;
        c.insert(fp(1), report(2));
        assert_eq!(c.len(), 1);
        assert_eq!(c.stats().bytes, b1, "replacing an entry must not grow bytes");
        assert_eq!(c.get(fp(1)).unwrap().generated, 2);
    }

    #[test]
    fn clear_empties_everything() {
        let c = ShardedCache::new(CacheConfig::default());
        for i in 0..10 {
            c.insert(fp(i), report(i as usize));
        }
        assert_eq!(c.len(), 10);
        c.clear();
        assert!(c.is_empty());
        assert_eq!(c.stats().bytes, 0);
    }
}
