//! `astra::service` — the multi-tenant search service layer.
//!
//! The paper's headline is that search is fast enough (≈1.27 s single-GPU)
//! to run on demand; this module turns the one-shot engine into a
//! long-running service that amortizes the enumerate→filter→score pipeline
//! across many tenants:
//!
//! * **[`fingerprint`]** — canonical, order-insensitive request keys, so
//!   semantically identical `(model, pool, config)` requests collide;
//! * **[`cache`]** — a sharded LRU result cache with TTL and byte budget,
//!   serving repeats in microseconds instead of re-searching;
//! * **[`SearchService`]** — single-flight admission (concurrent identical
//!   requests coalesce onto one search) plus a batched admission queue that
//!   fans *distinct* requests out over the scoped worker pool
//!   ([`crate::pool`]) so a mixed batch saturates every core;
//! * **[`server`]** — the line-delimited JSON wire protocol behind the
//!   `astra serve` and `astra batch` subcommands.
//!
//! The engine side of this is [`ScoringCore`]: the `Sync` scoring entry
//! point extracted from [`crate::coordinator::AstraEngine`] so one engine
//! instance can be shared across request threads (the HLO runtime handle is
//! thread-confined and stays out of the service path — the service always
//! scores native). Below the result cache sits a second amortization
//! layer: the core's shared cost memo (`cost::SharedCostMemo`, scoped per
//! model), so even *distinct* requests over the same model — different
//! pool sizes, budgets or modes — score mostly warm; the `{"cmd":"stats"}`
//! line reports the memo scope/hit/miss counters next to the cache's.
//!
//! Both layers of warmth survive restarts: with a [`WarmConfig::dir`]
//! configured (`astra serve --warm-dir`), the service restores memo scopes
//! and cache entries from the versioned [`crate::persist`] snapshot on
//! boot, re-spills every N admissions and on clean shutdown, and reports
//! `persist_*` counters on the stats line.
//!
//! ## Request lifecycle (admit → single-flight → execute → publish)
//!
//! Every request walks one path, [`SearchService::handle_opts`], with four
//! typed early exits (wire `kind` tags in parentheses):
//!
//! 1. **Cache.** The canonical fingerprint is looked up first. Hits are
//!    served in microseconds and are exempt from deadlines and shedding —
//!    answering from the cache is cheaper than refusing, so even
//!    `deadline_ms: 0` gets a cached result.
//! 2. **Deadline gate.** The effective deadline — the request's
//!    `deadline_ms`, else [`ServiceConfig::default_deadline_ms`] — is
//!    resolved; an already-expired budget (`0`) fails immediately
//!    (`deadline`) without ever starting a search.
//! 3. **Admission.** Cold requests count against
//!    [`ServiceConfig::max_queue_depth`]; past it they are shed with an
//!    immediate *retryable* error (`overloaded`) — `astra batch` retries
//!    these client-side with seeded exponential backoff.
//! 4. **Single-flight.** One leader per cache key searches; followers
//!    block on the slot with `Condvar::wait_timeout`, bounded by their own
//!    deadline (`deadline`) and by [`ServiceConfig::flight_wait_ms`]
//!    (`fault`) — a wedged leader can never strand followers forever.
//! 5. **Execute.** The leader runs the executor under a
//!    [`crate::resilience::CancelToken`] polled at wave boundaries — a fired
//!    deadline returns a typed error (`deadline`), never a partial report
//!    — wrapped in `catch_unwind`, so a poisoned request is counted and
//!    isolated (`panic`) instead of killing the serve loop.
//! 6. **Publish.** Success inserts into the cache *before* waking waiters
//!    and clearing the in-flight marker; errors fan out to every waiter
//!    as `(kind, message)` so all coalesced requests receive the same
//!    typed error. Either way each request gets exactly one terminal
//!    response.
//!
//! The resilience counters (`requests_shed`, `requests_deadline`,
//! `requests_panicked`, plus the failpoint module's `faults_injected`)
//! ride the `{"cmd":"stats"}` line and the telemetry registry.
//!
//! ## Live ops plane
//!
//! `{"cmd":"health"}` on the wire (and `astra health` on the CLI) answers
//! from [`SearchService::health`]: readiness (admission-queue headroom
//! against `max_queue_depth`, plus the boot warm-restore summary) and a
//! rolling window of per-mode p50/p95/p99 request latency and windowed
//! cache-hit/shed/deadline/panic rates. The window is computed as
//! [`crate::telemetry::window`] deltas between consecutive probes'
//! registry snapshots — relaxed atomic reads only, so a health probe
//! never takes the in-flight map or cache shard locks the search path
//! contends on.

pub mod cache;
pub mod fingerprint;
pub mod server;

pub use cache::{CacheConfig, CacheStats, ShardedCache};
pub use fingerprint::{fingerprint, frontier_fingerprint, Fingerprint};

use crate::coordinator::{ScoringCore, SearchReport, SearchRequest};
use crate::resilience::{lock_unpoisoned, CancelToken};
use crate::strategy::GpuPoolMode;
use crate::telemetry::window;
use crate::persist;
use crate::pool::par_for_indices;
use crate::{AstraError, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, PoisonError};
use std::time::{Duration, Instant};

/// Warm-start persistence policy ([`crate::persist`]).
#[derive(Debug, Clone)]
pub struct WarmConfig {
    /// Directory holding the `warm.jsonl` snapshot. `None` disables
    /// persistence entirely (the pre-PR-4 behavior).
    pub dir: Option<PathBuf>,
    /// Spill in the background after every N engine admissions (cache hits
    /// and coalesced requests do not count — they add no new warmth).
    /// 0 = spill only on shutdown or explicit [`SearchService::spill_warm`].
    pub spill_every: u64,
    /// Also spill the sharded result cache (not just the memo scopes).
    pub include_cache: bool,
    /// Snapshot byte budget for the memo scopes (0 = unlimited): when the
    /// serialized scopes would exceed it, least-recently-used scopes are
    /// dropped first (counted in `persist_scopes_dropped`). The cache
    /// section, when included, is written after the budgeted scopes.
    pub max_snapshot_bytes: u64,
}

impl Default for WarmConfig {
    fn default() -> Self {
        WarmConfig { dir: None, spill_every: 32, include_cache: true, max_snapshot_bytes: 0 }
    }
}

/// Service tuning knobs.
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    pub cache: CacheConfig,
    /// Max requests admitted into one fan-out batch; larger batches are
    /// processed in chunks of this size.
    pub max_batch: usize,
    /// Worker threads for batch fan-out (0 ⇒ auto). Each search already
    /// fans its scoring out over the engine's full worker pool, so the
    /// outer queue only needs enough concurrency to overlap requests of
    /// uneven length — auto caps it at 4 to avoid workers² thread
    /// oversubscription on cold batches.
    pub batch_workers: usize,
    /// Warm-start spill/restore policy.
    pub warm: WarmConfig,
    /// Deadline (ms) applied to requests that carry none of their own
    /// (`0` = unlimited). An explicit wire `deadline_ms` always wins.
    pub default_deadline_ms: u64,
    /// Load-shedding bound: max cold requests (leaders + coalesced
    /// waiters) past admission at once (`0` = unbounded). Beyond it new
    /// cold requests get an immediate retryable `overloaded` error; cache
    /// hits are never shed.
    pub max_queue_depth: usize,
    /// Ceiling (ms) on how long a coalesced follower waits for its search
    /// leader before giving up with a `fault` error. Generous by design —
    /// it only fires when a leader is wedged beyond any plausible search.
    pub flight_wait_ms: u64,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            cache: CacheConfig::default(),
            max_batch: 32,
            batch_workers: 0,
            warm: WarmConfig::default(),
            default_deadline_ms: 0,
            max_queue_depth: 0,
            flight_wait_ms: 300_000,
        }
    }
}

/// Per-request serving options (everything here is out of the request
/// fingerprint: two requests differing only in deadline share one cache
/// entry and one single-flight slot).
#[derive(Debug, Clone, Copy, Default)]
pub struct RequestOpts {
    /// Deadline for this request in ms. `None` falls back to
    /// [`ServiceConfig::default_deadline_ms`]; `Some(0)` is an
    /// already-expired budget (cache-or-fail, never a search).
    pub deadline_ms: Option<u64>,
    /// Attach a decision audit ([`crate::coordinator::SearchAudit`]) when
    /// this request runs a fresh search. Out of the fingerprint like
    /// everything here — an audited and an unaudited request share one
    /// cache entry and one single-flight slot, so an audited request may
    /// be served a cached report without an audit (best-effort: the wire
    /// layer simply omits the audit payload then).
    pub audit: bool,
}

/// Where a response came from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ResponseSource {
    /// A fresh engine search ran for this request.
    Search,
    /// Served from the result cache.
    Cache,
    /// Coalesced onto an identical in-flight request (single-flight) or an
    /// identical earlier request in the same admitted batch.
    Coalesced,
}

impl ResponseSource {
    pub fn as_str(&self) -> &'static str {
        match self {
            ResponseSource::Search => "search",
            ResponseSource::Cache => "cache",
            ResponseSource::Coalesced => "coalesced",
        }
    }
}

/// One serviced request.
#[derive(Clone)]
pub struct ServiceResponse {
    pub fingerprint: Fingerprint,
    pub source: ResponseSource,
    /// Wall time spent inside the service for this request (seconds).
    pub service_secs: f64,
    pub report: Arc<SearchReport>,
}

/// Typed error payload carried across the single-flight slot: the
/// leader's [`AstraError::kind`] tag plus its prefix-free message, so
/// every coalesced waiter rebuilds the same typed error (`AstraError` is
/// not `Clone`).
type FlightErr = (String, String);

/// Single-flight slot: the leader publishes into `done` and notifies.
struct FlightSlot {
    done: Mutex<Option<std::result::Result<Arc<SearchReport>, FlightErr>>>,
    cv: Condvar,
}

impl FlightSlot {
    fn new() -> FlightSlot {
        FlightSlot { done: Mutex::new(None), cv: Condvar::new() }
    }

    /// Wait for the leader's result, at most `ceiling`. On timeout the
    /// waiter gets a typed error of `timeout_kind` — `"deadline"` when the
    /// request's own deadline is the binding bound, `"fault"` when the
    /// generous [`ServiceConfig::flight_wait_ms`] ceiling fired (a wedged
    /// leader must never strand followers forever).
    fn wait(
        &self,
        ceiling: Duration,
        timeout_kind: &str,
    ) -> std::result::Result<Arc<SearchReport>, FlightErr> {
        let deadline = Instant::now() + ceiling;
        let mut g = lock_unpoisoned(&self.done);
        while g.is_none() {
            let now = Instant::now();
            if now >= deadline {
                return Err((
                    timeout_kind.to_string(),
                    "timed out waiting for the in-flight search leader".to_string(),
                ));
            }
            let (ng, _timed_out) = self
                .cv
                .wait_timeout(g, deadline - now)
                .unwrap_or_else(PoisonError::into_inner);
            g = ng;
        }
        g.as_ref().unwrap().clone()
    }

    fn publish(&self, r: std::result::Result<Arc<SearchReport>, FlightErr>) {
        *lock_unpoisoned(&self.done) = Some(r);
        self.cv.notify_all();
    }
}

/// Leader-side unwind guard: publishes an error and clears the in-flight
/// marker if the search panics *outside* the `catch_unwind` wall (cache
/// insertion, publication). Disarmed on the normal path.
struct FlightGuard<'a> {
    inflight: &'a Mutex<HashMap<u64, Arc<FlightSlot>>>,
    slot: &'a FlightSlot,
    key: u64,
    armed: bool,
}

impl FlightGuard<'_> {
    fn disarm(&mut self) {
        self.armed = false;
    }
}

impl Drop for FlightGuard<'_> {
    fn drop(&mut self) {
        if !self.armed {
            return;
        }
        self.slot.publish(Err(("panic".to_string(), "search leader panicked".to_string())));
        lock_unpoisoned(self.inflight).remove(&self.key);
    }
}

/// Admission token: holding one counts against the shedding bound;
/// dropping it (normal return *or* unwind) releases the slot.
struct AdmitGuard<'a>(&'a SearchService);

impl Drop for AdmitGuard<'_> {
    fn drop(&mut self) {
        self.0.active.fetch_sub(1, Ordering::Relaxed);
    }
}

/// Extract a human-readable message from a caught panic payload.
fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Wire-mode spelling and per-mode request-latency histogram, index-aligned
/// with the health baseline (and with [`mode_index`]).
const MODE_METRICS: &[(&str, &str)] = &[
    ("homogeneous", "astra_request_homogeneous_seconds"),
    ("heterogeneous", "astra_request_heterogeneous_seconds"),
    ("cost", "astra_request_cost_seconds"),
    ("hetero-cost", "astra_request_hetero_cost_seconds"),
    ("frontier", "astra_request_frontier_seconds"),
];

fn mode_index(mode: &GpuPoolMode) -> usize {
    match mode {
        GpuPoolMode::Homogeneous { .. } => 0,
        GpuPoolMode::Heterogeneous { .. } => 1,
        GpuPoolMode::Cost { .. } => 2,
        GpuPoolMode::HeteroCost { .. } => 3,
        GpuPoolMode::Frontier { .. } => 4,
    }
}

/// Registry counters the health window rates are diffed from,
/// index-aligned with the baseline's counter snapshot.
const RATE_COUNTERS: &[&str] = &[
    "astra_cache_hits_total",
    "astra_cache_misses_total",
    "astra_requests_shed_total",
    "astra_requests_deadline_total",
    "astra_requests_panicked_total",
];

/// What the boot-time warm restore actually did (the log line, kept for
/// the health surface).
#[derive(Debug, Clone)]
pub struct WarmRestoreSummary {
    pub scopes_restored: usize,
    /// Stage + sync memo rows imported.
    pub rows: usize,
    pub cache_entries: usize,
    pub scopes_rejected: usize,
}

/// The previous probe's registry snapshots; the next probe diffs against
/// these, so consecutive `health` calls see disjoint windows.
struct HealthBaseline {
    hists: Vec<window::HistSnapshot>,
    counters: Vec<u64>,
}

impl Default for HealthBaseline {
    fn default() -> Self {
        HealthBaseline {
            hists: (0..MODE_METRICS.len()).map(|_| window::HistSnapshot::zero()).collect(),
            counters: vec![0; RATE_COUNTERS.len()],
        }
    }
}

/// One mode's slice of the health window.
#[derive(Debug, Clone, Copy)]
pub struct ModeWindow {
    /// Wire spelling of the mode (`"hetero-cost"` etc.).
    pub mode: &'static str,
    /// Requests of this mode completed inside the window.
    pub requests: u64,
    /// p50/p95/p99 latency of those requests; `None` for an idle mode.
    pub latency: Option<window::Percentiles>,
}

/// One `health` probe's answer ([`SearchService::health`]).
#[derive(Debug, Clone)]
pub struct HealthReport {
    /// `true` when the admission queue has headroom (`max_queue_depth`
    /// unset, or fewer active requests than the bound).
    pub ready: bool,
    pub active_requests: usize,
    pub max_queue_depth: usize,
    /// The boot warm restore, when one happened.
    pub warm_restore: Option<WarmRestoreSummary>,
    /// Per-mode latency windows, in [`MODE_METRICS`] order.
    pub modes: Vec<ModeWindow>,
    /// Requests (all modes) completed inside the window.
    pub window_requests: u64,
    /// Result-cache hits over lookups inside the window (`0` when idle).
    pub cache_hit_rate: f64,
    pub shed_rate: f64,
    pub deadline_rate: f64,
    pub panic_rate: f64,
}

/// The multi-tenant search service: one shared [`ScoringCore`], a sharded
/// result cache, and single-flight admission.
pub struct SearchService {
    core: Arc<ScoringCore>,
    cache: ShardedCache,
    inflight: Mutex<HashMap<u64, Arc<FlightSlot>>>,
    config: ServiceConfig,
    /// Engine admissions (source = `Search`) since boot; drives the
    /// every-N spill policy.
    admissions: AtomicU64,
    /// At most one spill writes at a time; late arrivals skip (the next
    /// admission will spill strictly more warmth anyway).
    spilling: Mutex<()>,
    /// Cold requests currently past admission (leaders + coalesced
    /// waiters); compared against `config.max_queue_depth` for shedding.
    active: AtomicUsize,
    /// Requests shed by the queue-depth bound since boot.
    shed: AtomicU64,
    /// Requests that exited with a `deadline` error since boot.
    deadline_hits: AtomicU64,
    /// Requests whose search panicked and was isolated since boot.
    panicked: AtomicU64,
    /// What the boot warm restore did; `None` without one.
    warm_restore: Option<WarmRestoreSummary>,
    /// Previous health probe's registry snapshots (health-only lock — the
    /// search path never touches it).
    health_baseline: Mutex<HealthBaseline>,
}

impl SearchService {
    /// Build the service; when `config.warm.dir` holds a snapshot from an
    /// earlier process, memo scopes and cache entries that validate
    /// against this engine's identity are restored before the first
    /// request (anything else is skipped — cold start, never an error).
    pub fn new(core: ScoringCore, config: ServiceConfig) -> SearchService {
        let mut svc = SearchService {
            core: Arc::new(core),
            cache: ShardedCache::new(config.cache.clone()),
            inflight: Mutex::new(HashMap::new()),
            config,
            admissions: AtomicU64::new(0),
            spilling: Mutex::new(()),
            active: AtomicUsize::new(0),
            shed: AtomicU64::new(0),
            deadline_hits: AtomicU64::new(0),
            panicked: AtomicU64::new(0),
            warm_restore: None,
            health_baseline: Mutex::new(HealthBaseline::default()),
        };
        if let Some(path) = svc.warm_path() {
            if path.exists() {
                match svc.restore_warm(&path) {
                    Ok(st) => {
                        crate::log_info!(
                            "warm restore: {} scope(s) ({} rows), {} cache entries, {} rejected",
                            st.scopes_restored,
                            st.stage_rows + st.sync_rows,
                            st.cache_entries,
                            st.scopes_rejected
                        );
                        svc.warm_restore = Some(WarmRestoreSummary {
                            scopes_restored: st.scopes_restored,
                            rows: st.stage_rows + st.sync_rows,
                            cache_entries: st.cache_entries,
                            scopes_rejected: st.scopes_rejected,
                        });
                    }
                    Err(e) => crate::log_warn!("warm restore failed (starting cold): {e}"),
                }
            }
        }
        svc
    }

    /// Where this service spills/restores, when persistence is configured.
    pub fn warm_path(&self) -> Option<PathBuf> {
        self.config.warm.dir.as_ref().map(|d| d.join("warm.jsonl"))
    }

    /// Restore memo scopes and cache entries from a snapshot. Mismatching
    /// or corrupt scopes are skipped and counted; only an unreadable file
    /// is an `Err`. Cache entries are inserted only when
    /// `warm.include_cache` is set — the flag governs both directions, so
    /// an operator who excluded the result cache from persistence never
    /// serves restored entries from a snapshot another config wrote.
    pub fn restore_warm(&self, path: &Path) -> Result<persist::RestoreStats> {
        let set = self.core.load_warm_set(path, self.config.warm.include_cache)?;
        let stats = set.stats();
        if !set.cache.is_empty() {
            let n = set.cache.len() as u64;
            for (fp, report) in set.cache {
                self.cache.insert(Fingerprint(fp), Arc::new(report));
            }
            self.core.persist_counters().note_cache_restored(n);
        }
        Ok(stats)
    }

    /// Spill the live memo scopes (and, per config, the result cache) to
    /// the warm snapshot. `Ok(None)` when persistence is unconfigured or a
    /// concurrent spill is already writing.
    pub fn spill_warm(&self) -> Result<Option<persist::SpillStats>> {
        let Some(path) = self.warm_path() else { return Ok(None) };
        let Ok(_guard) = self.spilling.try_lock() else { return Ok(None) };
        if let Some(dir) = &self.config.warm.dir {
            std::fs::create_dir_all(dir)?;
        }
        let mut w = persist::WarmWriter::new();
        self.core.export_warm_within(&mut w, self.config.warm.max_snapshot_bytes);
        if self.config.warm.include_cache {
            // Frontier reports spill into their own scope: it is pinned to
            // the book's *membership* digest instead of the full rate card,
            // so a restart under a rate-only book change keeps the frontier
            // (repriced at serve time) while ordinary cached results are
            // correctly invalidated with the rates they were billed under.
            let (frontier, regular): (Vec<_>, Vec<_>) = self
                .cache
                .export_entries()
                .into_iter()
                .partition(|(_, r)| r.frontier.is_some());
            w.cache_section(&regular, &self.core.catalog, self.core.engine_meta());
            w.frontier_cache_section(&frontier, &self.core.catalog, self.core.engine_meta());
        }
        let stats = w.finish_to(&path)?;
        self.core.persist_counters().note_spill(&stats);
        Ok(Some(stats))
    }

    /// Periodic spill policy: every `warm.spill_every`-th engine admission
    /// rewrites the snapshot, so a crash loses at most one spill interval
    /// of warmth. The write runs *inline on the admitting request's
    /// thread* (memo rows are a few hundred; with `include_cache` the cost
    /// grows with cache occupancy — raise `spill_every` or disable
    /// `include_cache` if the every-Nth-request tail matters more than
    /// restart warmth). Concurrent admissions skip via the try-lock.
    fn note_admission(&self) {
        if self.config.warm.dir.is_none() || self.config.warm.spill_every == 0 {
            return;
        }
        let n = self.admissions.fetch_add(1, Ordering::Relaxed) + 1;
        if n % self.config.warm.spill_every == 0 {
            if let Err(e) = self.spill_warm() {
                crate::log_warn!("warm spill failed: {e}");
            }
        }
    }

    /// The shared engine core.
    pub fn core(&self) -> &ScoringCore {
        &self.core
    }

    pub fn config(&self) -> &ServiceConfig {
        &self.config
    }

    pub fn cache_stats(&self) -> CacheStats {
        self.cache.stats()
    }

    /// Drop all cached results.
    pub fn clear_cache(&self) {
        self.cache.clear();
    }

    /// Canonical key of a request under this service's engine config.
    pub fn fingerprint_of(&self, req: &SearchRequest) -> Fingerprint {
        fingerprint(req, &self.core.catalog, &self.core.config)
    }

    /// The *cache* key of a request. Frontier requests key through
    /// [`frontier_fingerprint`] — the price book's rates are out of the
    /// key's money axis (membership only), so a rate-only book change
    /// lands on the same cached frontier and is served by reprice. Every
    /// other mode keys through the full [`fingerprint`].
    pub fn cache_key_of(&self, req: &SearchRequest) -> Fingerprint {
        match req.mode {
            GpuPoolMode::Frontier { .. } => {
                frontier_fingerprint(req, &self.core.catalog, &self.core.config)
            }
            _ => self.fingerprint_of(req),
        }
    }

    /// Serve a cached report. Frontier hits are re-billed under the
    /// engine's *current* price book on the way out ([`SearchReport::reprice`]
    /// — identity for an in-process hit, the whole point after a warm
    /// restart under a changed book). Reprice is pure recomputation: the
    /// engine admission counter never moves. `None` when a frontier entry
    /// carries no skeleton (treated as a miss, falls through to search).
    fn serve_cached(
        &self,
        req: &SearchRequest,
        fp: Fingerprint,
        is_frontier: bool,
        report: Arc<SearchReport>,
        t0: &Instant,
    ) -> Option<ServiceResponse> {
        let report = if is_frontier {
            Arc::new(report.reprice(&req.model, &self.core.catalog, &self.core.config.money)?)
        } else {
            report
        };
        Some(ServiceResponse {
            fingerprint: fp,
            source: ResponseSource::Cache,
            service_secs: t0.elapsed().as_secs_f64(),
            report,
        })
    }

    /// Cold requests currently past admission (leaders plus coalesced
    /// waiters) — the live value the shedding bound compares against.
    pub fn active_requests(&self) -> usize {
        self.active.load(Ordering::Relaxed)
    }

    /// One live health probe: readiness plus the rolling window since the
    /// *previous* probe (the first window covers everything since boot).
    ///
    /// Lock discipline: reads only relaxed registry atomics plus the
    /// health-only baseline mutex — never the in-flight map or a cache
    /// shard, so a probe can neither stall admissions nor be stalled by a
    /// wedged search.
    pub fn health(&self) -> HealthReport {
        crate::telemetry::counter_macro!("astra_health_checks_total").inc();
        let mut base = lock_unpoisoned(&self.health_baseline);
        let mut modes = Vec::with_capacity(MODE_METRICS.len());
        let mut window_requests = 0u64;
        for (i, (mode, metric)) in MODE_METRICS.iter().enumerate() {
            let snap = window::HistSnapshot::of(&crate::telemetry::histogram(metric));
            let d = snap.delta(&base.hists[i]);
            base.hists[i] = snap;
            window_requests += d.count();
            modes.push(ModeWindow {
                mode,
                requests: d.count(),
                latency: window::percentiles(&d),
            });
        }
        let now: Vec<u64> =
            RATE_COUNTERS.iter().map(|n| crate::telemetry::counter(n).get()).collect();
        let d: Vec<u64> =
            now.iter().zip(base.counters.iter()).map(|(n, b)| n.saturating_sub(*b)).collect();
        base.counters = now;
        let (hits, misses, shed, deadline, panicked) = (d[0], d[1], d[2], d[3], d[4]);
        let active = self.active_requests();
        let depth = self.config.max_queue_depth;
        HealthReport {
            ready: depth == 0 || active < depth,
            active_requests: active,
            max_queue_depth: depth,
            warm_restore: self.warm_restore.clone(),
            modes,
            window_requests,
            cache_hit_rate: window::ratio(hits, hits + misses),
            shed_rate: window::ratio(shed, window_requests),
            deadline_rate: window::ratio(deadline, window_requests),
            panic_rate: window::ratio(panicked, window_requests),
        }
    }

    /// Lifetime resilience counters: `(shed, deadline, panicked)`.
    pub fn resilience_counters(&self) -> (u64, u64, u64) {
        (
            self.shed.load(Ordering::Relaxed),
            self.deadline_hits.load(Ordering::Relaxed),
            self.panicked.load(Ordering::Relaxed),
        )
    }

    fn note_deadline(&self) {
        self.deadline_hits.fetch_add(1, Ordering::Relaxed);
        crate::telemetry::counter_macro!("astra_requests_deadline_total").inc();
    }

    /// Admission gate for cold requests: over `max_queue_depth`, shed with
    /// an immediate retryable `overloaded` error instead of queueing.
    fn try_admit(&self) -> Result<AdmitGuard<'_>> {
        let depth = self.config.max_queue_depth;
        let now = self.active.fetch_add(1, Ordering::Relaxed) + 1;
        if depth > 0 && now > depth {
            self.active.fetch_sub(1, Ordering::Relaxed);
            self.shed.fetch_add(1, Ordering::Relaxed);
            crate::telemetry::counter_macro!("astra_requests_shed_total").inc();
            return Err(AstraError::Overloaded(format!(
                "admission queue full (depth {depth}); retry after backoff"
            )));
        }
        Ok(AdmitGuard(self))
    }

    /// Serve one request: cache → single-flight coalescing → engine search.
    pub fn handle(&self, req: &SearchRequest) -> Result<ServiceResponse> {
        self.handle_opts(req, RequestOpts::default())
    }

    /// [`Self::handle`] with per-request serving options (deadline,
    /// audit). See the module docs for the lifecycle and its typed exits.
    /// Every completed request — success or typed error — lands one
    /// observation in its mode's `astra_request_*_seconds` histogram,
    /// which is exactly the data the health window diffs.
    pub fn handle_opts(&self, req: &SearchRequest, opts: RequestOpts) -> Result<ServiceResponse> {
        let t0 = Instant::now();
        let result = self.handle_opts_impl(req, opts);
        crate::telemetry::histogram(MODE_METRICS[mode_index(&req.mode)].1)
            .observe(t0.elapsed().as_secs_f64());
        result
    }

    fn handle_opts_impl(&self, req: &SearchRequest, opts: RequestOpts) -> Result<ServiceResponse> {
        let t0 = Instant::now();
        let fp = self.fingerprint_of(req);
        let is_frontier = matches!(req.mode, GpuPoolMode::Frontier { .. });
        // The response fingerprint stays the full, book-dependent one even
        // for frontier requests — a repriced hit and a cold search under
        // the same book answer byte-identically.
        let key = if is_frontier { self.cache_key_of(req) } else { fp };
        // Cache first, before any deadline/shed gate: a hit is cheaper
        // than the refusal, so cached results are served even when the
        // deadline would reject a cold search.
        if let Some(report) = self.cache.get(key) {
            if let Some(resp) = self.serve_cached(req, fp, is_frontier, report, &t0) {
                return Ok(resp);
            }
        }
        // Effective deadline: the wire value wins; otherwise the service
        // default (where 0 means "no default" rather than "expired").
        let deadline_ms = opts
            .deadline_ms
            .or((self.config.default_deadline_ms > 0).then_some(self.config.default_deadline_ms));
        if deadline_ms == Some(0) {
            self.note_deadline();
            return Err(AstraError::Deadline(
                "deadline_ms is 0 and the result is not cached".to_string(),
            ));
        }
        // Load shedding: only cold requests consume an admission slot; the
        // guard releases it on every exit path, unwinds included.
        let _admit = self.try_admit()?;
        // Single-flight: exactly one thread (the leader) runs the search;
        // everyone else arriving with the same cache key waits on it.
        let (slot, leader) = {
            let mut map = lock_unpoisoned(&self.inflight);
            // Re-check the cache under the in-flight lock: a finishing
            // leader publishes to the cache *before* clearing its marker,
            // so a miss here is authoritative and we cannot double-search.
            if let Some(report) = self.cache.peek(key) {
                if let Some(resp) = self.serve_cached(req, fp, is_frontier, report, &t0) {
                    return Ok(resp);
                }
            }
            match map.get(&key.0) {
                Some(s) => (s.clone(), false),
                None => {
                    let s = Arc::new(FlightSlot::new());
                    map.insert(key.0, s.clone());
                    (s, true)
                }
            }
        };
        if leader {
            // Unwind safety, two layers: `catch_unwind` turns an engine
            // panic into a typed `panic`-kind error right here; the guard
            // is the backstop for panics *outside* that wall (publication,
            // cache insertion) so waiters can never wedge on the slot.
            let mut guard = FlightGuard {
                inflight: &self.inflight,
                slot: slot.as_ref(),
                key: key.0,
                armed: true,
            };
            let cancel = match deadline_ms {
                Some(ms) => CancelToken::with_deadline_ms(ms),
                None => CancelToken::unlimited(),
            };
            let result = match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                if opts.audit {
                    crate::telemetry::counter_macro!("astra_audited_searches_total").inc();
                    self.core.search_with_cancel_audited(req, &cancel).map(Arc::new)
                } else {
                    self.core.search_with_cancel(req, &cancel).map(Arc::new)
                }
            })) {
                Ok(r) => r,
                Err(payload) => {
                    self.panicked.fetch_add(1, Ordering::Relaxed);
                    crate::telemetry::counter_macro!("astra_requests_panicked_total").inc();
                    Err(AstraError::Panicked(format!(
                        "search panicked (isolated): {}",
                        panic_message(payload.as_ref())
                    )))
                }
            };
            if matches!(result, Err(AstraError::Deadline(_))) {
                self.note_deadline();
            }
            // Publish to the cache *before* waking waiters and clearing the
            // in-flight marker, so a racing request either joins the flight
            // or hits the cache — never re-searches.
            if let Ok(report) = &result {
                self.cache.insert(key, report.clone());
            }
            slot.publish(match &result {
                Ok(r) => Ok(r.clone()),
                Err(e) => Err((e.kind().to_string(), e.message())),
            });
            lock_unpoisoned(&self.inflight).remove(&key.0);
            guard.disarm();
            let resp = result.map(|report| ServiceResponse {
                fingerprint: fp,
                source: ResponseSource::Search,
                service_secs: t0.elapsed().as_secs_f64(),
                report,
            });
            if resp.is_ok() {
                // New warmth entered the registry/cache; maybe spill.
                self.note_admission();
            }
            resp
        } else {
            // Followers bound their wait by their own deadline and by the
            // generous flight ceiling, whichever is tighter; the timeout
            // kind tells the client which bound fired.
            let flight_ceiling = Duration::from_millis(self.config.flight_wait_ms.max(1));
            let (ceiling, timeout_kind) = match deadline_ms {
                Some(ms) if Duration::from_millis(ms) < flight_ceiling => {
                    (Duration::from_millis(ms), "deadline")
                }
                _ => (flight_ceiling, "fault"),
            };
            match slot.wait(ceiling, timeout_kind) {
                Ok(report) => Ok(ServiceResponse {
                    fingerprint: fp,
                    source: ResponseSource::Coalesced,
                    service_secs: t0.elapsed().as_secs_f64(),
                    report,
                }),
                Err((kind, msg)) => {
                    if kind == "deadline" {
                        self.note_deadline();
                    }
                    Err(AstraError::from_kind(
                        &kind,
                        format!("coalesced request failed: {msg}"),
                    ))
                }
            }
        }
    }

    /// Batched admission: deduplicate fingerprints inside the batch, fan
    /// the distinct requests out over scoped workers, and return responses
    /// in input order. Duplicates of an earlier batch entry are reported as
    /// [`ResponseSource::Coalesced`] and share the leader's report.
    pub fn handle_batch(&self, reqs: &[SearchRequest]) -> Vec<Result<ServiceResponse>> {
        self.handle_batch_opts(reqs, &[])
    }

    /// [`Self::handle_batch`] with per-request serving options, matched to
    /// `reqs` by index (missing entries default to no deadline).
    pub fn handle_batch_opts(
        &self,
        reqs: &[SearchRequest],
        opts: &[RequestOpts],
    ) -> Vec<Result<ServiceResponse>> {
        let fps: Vec<Fingerprint> = reqs.iter().map(|r| self.fingerprint_of(r)).collect();
        // First occurrence of each fingerprint runs; later ones coalesce.
        let mut first_of: HashMap<u64, usize> = HashMap::new();
        let mut distinct: Vec<usize> = Vec::new();
        for (i, fp) in fps.iter().enumerate() {
            first_of.entry(fp.0).or_insert_with(|| {
                distinct.push(i);
                i
            });
        }
        // Each search already saturates the engine's worker pool; the outer
        // fan-out only needs to overlap requests of uneven length. Cap it
        // (auto: ≤4) so a cold batch does not spawn ~workers² threads.
        let workers = if self.config.batch_workers > 0 {
            self.config.batch_workers
        } else {
            self.core.config.workers.min(4)
        };
        // Admit at most `max_batch` distinct requests per fan-out round.
        // The queue-depth gauge tracks how many distinct requests are in
        // fan-out right now, across every concurrent batch.
        let depth = crate::telemetry::gauge_macro!("astra_admission_queue_depth");
        let mut leader_results: Vec<Result<ServiceResponse>> =
            Vec::with_capacity(distinct.len());
        for chunk in distinct.chunks(self.config.max_batch.max(1)) {
            depth.add(chunk.len() as i64);
            let mut part = par_for_indices(chunk.len(), workers, |i| {
                self.handle_opts(
                    &reqs[chunk[i]],
                    opts.get(chunk[i]).copied().unwrap_or_default(),
                )
            });
            depth.add(-(chunk.len() as i64));
            leader_results.append(&mut part);
        }
        // Map distinct-index → result, then assemble per-input responses.
        let mut by_leader: HashMap<usize, &Result<ServiceResponse>> = HashMap::new();
        for (k, &input_idx) in distinct.iter().enumerate() {
            by_leader.insert(input_idx, &leader_results[k]);
        }
        fps.iter()
            .enumerate()
            .map(|(i, fp)| {
                let leader_idx = first_of[&fp.0];
                let leader = by_leader[&leader_idx];
                match leader {
                    Ok(resp) => {
                        let mut resp = resp.clone();
                        if i != leader_idx {
                            resp.source = ResponseSource::Coalesced;
                        }
                        Ok(resp)
                    }
                    // Rebuild from (kind, message) so duplicates keep the
                    // leader's typed kind (and retryability) instead of
                    // degrading to a prefix-stacked `Search` error.
                    Err(e) => Err(AstraError::from_kind(e.kind(), e.message())),
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::EngineConfig;
    use crate::gpu::GpuCatalog;
    use crate::model::ModelRegistry;
    use crate::pareto::MoneyModel;
    use crate::pricing::{PriceBook, PriceEntry};
    use crate::strategy::SpaceConfig;

    /// A deliberately small space so unit tests stay fast.
    pub(crate) fn small_core() -> ScoringCore {
        small_core_with_book(PriceBook::builtin())
    }

    fn small_core_with_book(book: PriceBook) -> ScoringCore {
        let space = SpaceConfig {
            tp_candidates: vec![1, 2],
            max_pp: 4,
            mbs_candidates: vec![1, 2],
            vpp_candidates: vec![1],
            seq_parallel_options: vec![true],
            dist_opt_options: vec![true],
            offload_options: vec![false],
            recompute_none: true,
            recompute_selective: false,
            recompute_full: false,
            ..SpaceConfig::default()
        };
        ScoringCore::new(
            GpuCatalog::builtin(),
            EngineConfig {
                use_forests: false,
                space,
                money: MoneyModel { book, ..Default::default() },
                ..Default::default()
            },
        )
    }

    fn req(count: usize) -> SearchRequest {
        let model = ModelRegistry::builtin().get("llama2-7b").unwrap().clone();
        SearchRequest::homogeneous("a800", count, model).unwrap()
    }

    #[test]
    fn repeat_request_hits_cache_not_engine() {
        let svc = SearchService::new(small_core(), ServiceConfig::default());
        let a = svc.handle(&req(16)).unwrap();
        assert_eq!(a.source, ResponseSource::Search);
        let b = svc.handle(&req(16)).unwrap();
        assert_eq!(b.source, ResponseSource::Cache);
        assert_eq!(a.fingerprint, b.fingerprint);
        assert_eq!(svc.core().searches_run(), 1, "cache hit must not re-search");
        assert!(Arc::ptr_eq(&a.report, &b.report), "hit must share the cached report");
    }

    #[test]
    fn bad_requests_fail_without_caching() {
        let svc = SearchService::new(small_core(), ServiceConfig::default());
        // Heterogeneous caps below total is a config error from the engine.
        let model = ModelRegistry::builtin().get("llama2-7b").unwrap().clone();
        let bad = SearchRequest::heterogeneous(&[("a800", 8)], 64, model).unwrap();
        assert!(svc.handle(&bad).is_err());
        assert_eq!(svc.cache_stats().insertions, 0, "errors must not be cached");
        // And the error is not sticky: nothing is left in-flight.
        assert!(svc.handle(&bad).is_err());
    }

    #[test]
    fn concurrent_identical_requests_single_flight() {
        let svc = SearchService::new(small_core(), ServiceConfig::default());
        let sources: Vec<ResponseSource> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..4)
                .map(|_| s.spawn(|| svc.handle(&req(32)).unwrap().source))
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        assert_eq!(svc.core().searches_run(), 1, "identical requests must coalesce");
        assert_eq!(
            sources.iter().filter(|&&s| s == ResponseSource::Search).count(),
            1,
            "exactly one leader: {sources:?}"
        );
    }

    fn frontier_req() -> SearchRequest {
        let model = ModelRegistry::builtin().get("llama2-7b").unwrap().clone();
        SearchRequest::frontier(&[("a800", 4), ("h100", 4)], model).unwrap()
    }

    #[test]
    fn frontier_repeat_repriced_from_cache_not_engine() {
        let svc = SearchService::new(small_core(), ServiceConfig::default());
        let a = svc.handle(&frontier_req()).unwrap();
        assert_eq!(a.source, ResponseSource::Search);
        assert!(a.report.frontier.is_some(), "frontier mode must return a skeleton");
        assert!(!a.report.pool.is_empty(), "frontier must be non-empty");
        let b = svc.handle(&frontier_req()).unwrap();
        assert_eq!(b.source, ResponseSource::Cache);
        assert_eq!(svc.core().searches_run(), 1, "repeat must reprice, not re-search");
        assert_eq!(a.fingerprint, b.fingerprint);
        // Same book ⇒ the serve-time reprice is the identity on the wire.
        let catalog = &svc.core().catalog;
        assert_eq!(
            crate::json::to_string(&crate::report::report_json(&a.report, catalog)),
            crate::json::to_string(&crate::report::report_json(&b.report, catalog)),
        );
    }

    #[test]
    fn repriced_frontier_after_restart_matches_cold_search_under_new_book() {
        let dir = std::env::temp_dir()
            .join(format!("astra_warm_frontier_restart_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let cfg = ServiceConfig {
            warm: WarmConfig {
                dir: Some(dir.clone()),
                spill_every: 0,
                include_cache: true,
                max_snapshot_bytes: 0,
            },
            ..Default::default()
        };
        // Book B differs from the builtin card by rates only: a price move
        // plus spot billing. Membership is unchanged.
        let mut book_b = PriceBook::builtin();
        book_b.upsert(PriceEntry {
            gpu: "h100".to_string(),
            on_demand_per_hour: 9.99,
            spot_per_hour: 3.99,
        });
        book_b.use_spot = true;

        // Boot 1: search a frontier under the builtin book and spill.
        let svc_a = SearchService::new(small_core(), cfg.clone());
        let a = svc_a.handle(&frontier_req()).unwrap();
        assert_eq!(a.source, ResponseSource::Search);
        svc_a.spill_warm().unwrap().expect("configured spill must run");

        // Boot 2: same engine, rates changed. The spilled frontier must
        // restore (membership pin) and serve repriced — no engine admission.
        let svc_b = SearchService::new(small_core_with_book(book_b.clone()), cfg);
        let b = svc_b.handle(&frontier_req()).unwrap();
        assert_eq!(b.source, ResponseSource::Cache, "restored frontier must serve from cache");
        assert_eq!(svc_b.core().searches_run(), 0, "reprice must not admit the engine");

        // Reference: a cold search under book B. The repriced cached answer
        // must match it byte-for-byte on the canonical wire view.
        let svc_c = SearchService::new(small_core_with_book(book_b), ServiceConfig::default());
        let c = svc_c.handle(&frontier_req()).unwrap();
        assert_eq!(c.source, ResponseSource::Search);
        let catalog = &svc_c.core().catalog;
        assert_eq!(
            crate::json::to_string(&crate::report::report_json(&b.report, catalog)),
            crate::json::to_string(&crate::report::report_json(&c.report, catalog)),
            "reprice-from-cache must equal a cold re-search under the new book"
        );
        assert_eq!(b.report.top[0].money_usd.to_bits(), c.report.top[0].money_usd.to_bits());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn batch_dedupes_and_preserves_order() {
        let svc = SearchService::new(small_core(), ServiceConfig::default());
        let reqs = vec![req(8), req(16), req(8), req(32)];
        let out = svc.handle_batch(&reqs);
        assert_eq!(out.len(), 4);
        let resp: Vec<&ServiceResponse> = out.iter().map(|r| r.as_ref().unwrap()).collect();
        assert_eq!(resp[0].fingerprint, resp[2].fingerprint);
        assert_ne!(resp[0].fingerprint, resp[1].fingerprint);
        assert_eq!(resp[2].source, ResponseSource::Coalesced);
        assert_eq!(svc.core().searches_run(), 3, "3 distinct requests in the batch");
    }

    #[test]
    fn flight_wait_times_out_with_the_binding_kind() {
        let slot = FlightSlot::new();
        // Nobody publishes: the wait must end at the ceiling, not hang,
        // and surface whichever bound was binding as the error kind.
        let err = slot.wait(Duration::from_millis(10), "fault").unwrap_err();
        assert_eq!(err.0, "fault");
        let err = slot.wait(Duration::from_millis(10), "deadline").unwrap_err();
        assert_eq!(err.0, "deadline");
        assert!(err.1.contains("in-flight search leader"), "{}", err.1);
    }

    #[test]
    fn flight_guard_drop_publishes_panic_marker_and_clears_marker() {
        let inflight: Mutex<HashMap<u64, Arc<FlightSlot>>> = Mutex::new(HashMap::new());
        let slot = Arc::new(FlightSlot::new());
        inflight.lock().unwrap().insert(7, slot.clone());
        drop(FlightGuard { inflight: &inflight, slot: &slot, key: 7, armed: true });
        // Waiters are released with the pinned marker, not stranded.
        let err = slot.wait(Duration::from_millis(10), "fault").unwrap_err();
        assert_eq!(err, ("panic".to_string(), "search leader panicked".to_string()));
        assert!(!inflight.lock().unwrap().contains_key(&7), "marker must be cleared");
    }

    #[test]
    fn deadline_zero_fails_immediately_without_searching() {
        let svc = SearchService::new(small_core(), ServiceConfig::default());
        let err = svc.handle_opts(&req(16), RequestOpts { deadline_ms: Some(0), ..Default::default() }).unwrap_err();
        assert!(matches!(err, AstraError::Deadline(_)), "got {err}");
        assert_eq!(err.kind(), "deadline");
        assert!(!err.retryable(), "deadline errors are not retryable");
        assert_eq!(svc.core().searches_run(), 0, "an expired budget must never search");
        assert_eq!(svc.resilience_counters(), (0, 1, 0));
        // Not sticky: the same request with budget succeeds afterwards.
        assert!(svc.handle(&req(16)).is_ok());
    }

    #[test]
    fn cached_hit_served_even_at_deadline_zero() {
        let svc = SearchService::new(small_core(), ServiceConfig::default());
        svc.handle(&req(16)).unwrap();
        let hit = svc.handle_opts(&req(16), RequestOpts { deadline_ms: Some(0), ..Default::default() }).unwrap();
        assert_eq!(hit.source, ResponseSource::Cache, "cache is checked before the gate");
        assert_eq!(svc.resilience_counters().1, 0, "a hit is not a deadline event");
    }

    #[test]
    fn admission_sheds_past_queue_depth_and_recovers() {
        let cfg = ServiceConfig { max_queue_depth: 2, ..Default::default() };
        let svc = SearchService::new(small_core(), cfg);
        let a = svc.try_admit().unwrap();
        let _b = svc.try_admit().unwrap();
        assert_eq!(svc.active_requests(), 2);
        let err = svc.try_admit().unwrap_err();
        assert!(matches!(err, AstraError::Overloaded(_)), "got {err}");
        assert!(err.retryable(), "shedding must be the retryable kind");
        assert_eq!(svc.resilience_counters().0, 1);
        drop(a);
        // A freed slot re-admits; the guard released its count on drop.
        let _c = svc.try_admit().unwrap();
        assert_eq!(svc.active_requests(), 2);
    }

    #[test]
    fn explicit_deadline_overrides_service_default() {
        // Default of 0 means "no default": a plain request is unlimited,
        // while an explicit 0 on the wire still refuses immediately.
        let cfg = ServiceConfig { default_deadline_ms: 0, ..Default::default() };
        let svc = SearchService::new(small_core(), cfg);
        assert!(svc.handle(&req(16)).is_ok());
        let err = svc.handle_opts(&req(24), RequestOpts { deadline_ms: Some(0), ..Default::default() }).unwrap_err();
        assert_eq!(err.kind(), "deadline");
        // A generous explicit deadline still completes the search.
        let ok = svc
            .handle_opts(&req(24), RequestOpts { deadline_ms: Some(600_000), ..Default::default() })
            .unwrap();
        assert_eq!(ok.source, ResponseSource::Search);
    }
}
