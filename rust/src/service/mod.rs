//! `astra::service` — the multi-tenant search service layer.
//!
//! The paper's headline is that search is fast enough (≈1.27 s single-GPU)
//! to run on demand; this module turns the one-shot engine into a
//! long-running service that amortizes the enumerate→filter→score pipeline
//! across many tenants:
//!
//! * **[`fingerprint`]** — canonical, order-insensitive request keys, so
//!   semantically identical `(model, pool, config)` requests collide;
//! * **[`cache`]** — a sharded LRU result cache with TTL and byte budget,
//!   serving repeats in microseconds instead of re-searching;
//! * **[`SearchService`]** — single-flight admission (concurrent identical
//!   requests coalesce onto one search) plus a batched admission queue that
//!   fans *distinct* requests out over the scoped worker pool
//!   ([`crate::pool`]) so a mixed batch saturates every core;
//! * **[`server`]** — the line-delimited JSON wire protocol behind the
//!   `astra serve` and `astra batch` subcommands.
//!
//! The engine side of this is [`ScoringCore`]: the `Sync` scoring entry
//! point extracted from [`crate::coordinator::AstraEngine`] so one engine
//! instance can be shared across request threads (the HLO runtime handle is
//! thread-confined and stays out of the service path — the service always
//! scores native). Below the result cache sits a second amortization
//! layer: the core's shared cost memo (`cost::SharedCostMemo`, scoped per
//! model), so even *distinct* requests over the same model — different
//! pool sizes, budgets or modes — score mostly warm; the `{"cmd":"stats"}`
//! line reports the memo scope/hit/miss counters next to the cache's.
//!
//! Both layers of warmth survive restarts: with a [`WarmConfig::dir`]
//! configured (`astra serve --warm-dir`), the service restores memo scopes
//! and cache entries from the versioned [`crate::persist`] snapshot on
//! boot, re-spills every N admissions and on clean shutdown, and reports
//! `persist_*` counters on the stats line.

pub mod cache;
pub mod fingerprint;
pub mod server;

pub use cache::{CacheConfig, CacheStats, ShardedCache};
pub use fingerprint::{fingerprint, frontier_fingerprint, Fingerprint};

use crate::coordinator::{ScoringCore, SearchReport, SearchRequest};
use crate::strategy::GpuPoolMode;
use crate::persist;
use crate::pool::par_for_indices;
use crate::{AstraError, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

/// Warm-start persistence policy ([`crate::persist`]).
#[derive(Debug, Clone)]
pub struct WarmConfig {
    /// Directory holding the `warm.jsonl` snapshot. `None` disables
    /// persistence entirely (the pre-PR-4 behavior).
    pub dir: Option<PathBuf>,
    /// Spill in the background after every N engine admissions (cache hits
    /// and coalesced requests do not count — they add no new warmth).
    /// 0 = spill only on shutdown or explicit [`SearchService::spill_warm`].
    pub spill_every: u64,
    /// Also spill the sharded result cache (not just the memo scopes).
    pub include_cache: bool,
    /// Snapshot byte budget for the memo scopes (0 = unlimited): when the
    /// serialized scopes would exceed it, least-recently-used scopes are
    /// dropped first (counted in `persist_scopes_dropped`). The cache
    /// section, when included, is written after the budgeted scopes.
    pub max_snapshot_bytes: u64,
}

impl Default for WarmConfig {
    fn default() -> Self {
        WarmConfig { dir: None, spill_every: 32, include_cache: true, max_snapshot_bytes: 0 }
    }
}

/// Service tuning knobs.
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    pub cache: CacheConfig,
    /// Max requests admitted into one fan-out batch; larger batches are
    /// processed in chunks of this size.
    pub max_batch: usize,
    /// Worker threads for batch fan-out (0 ⇒ auto). Each search already
    /// fans its scoring out over the engine's full worker pool, so the
    /// outer queue only needs enough concurrency to overlap requests of
    /// uneven length — auto caps it at 4 to avoid workers² thread
    /// oversubscription on cold batches.
    pub batch_workers: usize,
    /// Warm-start spill/restore policy.
    pub warm: WarmConfig,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            cache: CacheConfig::default(),
            max_batch: 32,
            batch_workers: 0,
            warm: WarmConfig::default(),
        }
    }
}

/// Where a response came from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ResponseSource {
    /// A fresh engine search ran for this request.
    Search,
    /// Served from the result cache.
    Cache,
    /// Coalesced onto an identical in-flight request (single-flight) or an
    /// identical earlier request in the same admitted batch.
    Coalesced,
}

impl ResponseSource {
    pub fn as_str(&self) -> &'static str {
        match self {
            ResponseSource::Search => "search",
            ResponseSource::Cache => "cache",
            ResponseSource::Coalesced => "coalesced",
        }
    }
}

/// One serviced request.
#[derive(Clone)]
pub struct ServiceResponse {
    pub fingerprint: Fingerprint,
    pub source: ResponseSource,
    /// Wall time spent inside the service for this request (seconds).
    pub service_secs: f64,
    pub report: Arc<SearchReport>,
}

/// Single-flight slot: the leader publishes into `done` and notifies.
/// Errors are carried as strings (the engine error is not `Clone`).
struct FlightSlot {
    done: Mutex<Option<std::result::Result<Arc<SearchReport>, String>>>,
    cv: Condvar,
}

impl FlightSlot {
    fn new() -> FlightSlot {
        FlightSlot { done: Mutex::new(None), cv: Condvar::new() }
    }

    fn wait(&self) -> std::result::Result<Arc<SearchReport>, String> {
        let mut g = self.done.lock().unwrap();
        while g.is_none() {
            g = self.cv.wait(g).unwrap();
        }
        g.as_ref().unwrap().clone()
    }

    fn publish(&self, r: std::result::Result<Arc<SearchReport>, String>) {
        *self.done.lock().unwrap() = Some(r);
        self.cv.notify_all();
    }
}

/// Leader-side unwind guard: publishes an error and clears the in-flight
/// marker if the search panics. Disarmed on the normal path.
struct FlightGuard<'a> {
    inflight: &'a Mutex<HashMap<u64, Arc<FlightSlot>>>,
    slot: &'a FlightSlot,
    key: u64,
    armed: bool,
}

impl FlightGuard<'_> {
    fn disarm(&mut self) {
        self.armed = false;
    }
}

impl Drop for FlightGuard<'_> {
    fn drop(&mut self) {
        if !self.armed {
            return;
        }
        self.slot.publish(Err("search leader panicked".to_string()));
        // `lock()` may be poisoned during unwind; best-effort removal.
        if let Ok(mut m) = self.inflight.lock() {
            m.remove(&self.key);
        }
    }
}

/// The multi-tenant search service: one shared [`ScoringCore`], a sharded
/// result cache, and single-flight admission.
pub struct SearchService {
    core: Arc<ScoringCore>,
    cache: ShardedCache,
    inflight: Mutex<HashMap<u64, Arc<FlightSlot>>>,
    config: ServiceConfig,
    /// Engine admissions (source = `Search`) since boot; drives the
    /// every-N spill policy.
    admissions: AtomicU64,
    /// At most one spill writes at a time; late arrivals skip (the next
    /// admission will spill strictly more warmth anyway).
    spilling: Mutex<()>,
}

impl SearchService {
    /// Build the service; when `config.warm.dir` holds a snapshot from an
    /// earlier process, memo scopes and cache entries that validate
    /// against this engine's identity are restored before the first
    /// request (anything else is skipped — cold start, never an error).
    pub fn new(core: ScoringCore, config: ServiceConfig) -> SearchService {
        let svc = SearchService {
            core: Arc::new(core),
            cache: ShardedCache::new(config.cache.clone()),
            inflight: Mutex::new(HashMap::new()),
            config,
            admissions: AtomicU64::new(0),
            spilling: Mutex::new(()),
        };
        if let Some(path) = svc.warm_path() {
            if path.exists() {
                match svc.restore_warm(&path) {
                    Ok(st) => crate::log_info!(
                        "warm restore: {} scope(s) ({} rows), {} cache entries, {} rejected",
                        st.scopes_restored,
                        st.stage_rows + st.sync_rows,
                        st.cache_entries,
                        st.scopes_rejected
                    ),
                    Err(e) => crate::log_warn!("warm restore failed (starting cold): {e}"),
                }
            }
        }
        svc
    }

    /// Where this service spills/restores, when persistence is configured.
    pub fn warm_path(&self) -> Option<PathBuf> {
        self.config.warm.dir.as_ref().map(|d| d.join("warm.jsonl"))
    }

    /// Restore memo scopes and cache entries from a snapshot. Mismatching
    /// or corrupt scopes are skipped and counted; only an unreadable file
    /// is an `Err`. Cache entries are inserted only when
    /// `warm.include_cache` is set — the flag governs both directions, so
    /// an operator who excluded the result cache from persistence never
    /// serves restored entries from a snapshot another config wrote.
    pub fn restore_warm(&self, path: &Path) -> Result<persist::RestoreStats> {
        let set = self.core.load_warm_set(path, self.config.warm.include_cache)?;
        let stats = set.stats();
        if !set.cache.is_empty() {
            let n = set.cache.len() as u64;
            for (fp, report) in set.cache {
                self.cache.insert(Fingerprint(fp), Arc::new(report));
            }
            self.core.persist_counters().note_cache_restored(n);
        }
        Ok(stats)
    }

    /// Spill the live memo scopes (and, per config, the result cache) to
    /// the warm snapshot. `Ok(None)` when persistence is unconfigured or a
    /// concurrent spill is already writing.
    pub fn spill_warm(&self) -> Result<Option<persist::SpillStats>> {
        let Some(path) = self.warm_path() else { return Ok(None) };
        let Ok(_guard) = self.spilling.try_lock() else { return Ok(None) };
        if let Some(dir) = &self.config.warm.dir {
            std::fs::create_dir_all(dir)?;
        }
        let mut w = persist::WarmWriter::new();
        self.core.export_warm_within(&mut w, self.config.warm.max_snapshot_bytes);
        if self.config.warm.include_cache {
            // Frontier reports spill into their own scope: it is pinned to
            // the book's *membership* digest instead of the full rate card,
            // so a restart under a rate-only book change keeps the frontier
            // (repriced at serve time) while ordinary cached results are
            // correctly invalidated with the rates they were billed under.
            let (frontier, regular): (Vec<_>, Vec<_>) = self
                .cache
                .export_entries()
                .into_iter()
                .partition(|(_, r)| r.frontier.is_some());
            w.cache_section(&regular, &self.core.catalog, self.core.engine_meta());
            w.frontier_cache_section(&frontier, &self.core.catalog, self.core.engine_meta());
        }
        let stats = w.finish_to(&path)?;
        self.core.persist_counters().note_spill(&stats);
        Ok(Some(stats))
    }

    /// Periodic spill policy: every `warm.spill_every`-th engine admission
    /// rewrites the snapshot, so a crash loses at most one spill interval
    /// of warmth. The write runs *inline on the admitting request's
    /// thread* (memo rows are a few hundred; with `include_cache` the cost
    /// grows with cache occupancy — raise `spill_every` or disable
    /// `include_cache` if the every-Nth-request tail matters more than
    /// restart warmth). Concurrent admissions skip via the try-lock.
    fn note_admission(&self) {
        if self.config.warm.dir.is_none() || self.config.warm.spill_every == 0 {
            return;
        }
        let n = self.admissions.fetch_add(1, Ordering::Relaxed) + 1;
        if n % self.config.warm.spill_every == 0 {
            if let Err(e) = self.spill_warm() {
                crate::log_warn!("warm spill failed: {e}");
            }
        }
    }

    /// The shared engine core.
    pub fn core(&self) -> &ScoringCore {
        &self.core
    }

    pub fn config(&self) -> &ServiceConfig {
        &self.config
    }

    pub fn cache_stats(&self) -> CacheStats {
        self.cache.stats()
    }

    /// Drop all cached results.
    pub fn clear_cache(&self) {
        self.cache.clear();
    }

    /// Canonical key of a request under this service's engine config.
    pub fn fingerprint_of(&self, req: &SearchRequest) -> Fingerprint {
        fingerprint(req, &self.core.catalog, &self.core.config)
    }

    /// The *cache* key of a request. Frontier requests key through
    /// [`frontier_fingerprint`] — the price book's rates are out of the
    /// key's money axis (membership only), so a rate-only book change
    /// lands on the same cached frontier and is served by reprice. Every
    /// other mode keys through the full [`fingerprint`].
    pub fn cache_key_of(&self, req: &SearchRequest) -> Fingerprint {
        match req.mode {
            GpuPoolMode::Frontier { .. } => {
                frontier_fingerprint(req, &self.core.catalog, &self.core.config)
            }
            _ => self.fingerprint_of(req),
        }
    }

    /// Serve a cached report. Frontier hits are re-billed under the
    /// engine's *current* price book on the way out ([`SearchReport::reprice`]
    /// — identity for an in-process hit, the whole point after a warm
    /// restart under a changed book). Reprice is pure recomputation: the
    /// engine admission counter never moves. `None` when a frontier entry
    /// carries no skeleton (treated as a miss, falls through to search).
    fn serve_cached(
        &self,
        req: &SearchRequest,
        fp: Fingerprint,
        is_frontier: bool,
        report: Arc<SearchReport>,
        t0: &Instant,
    ) -> Option<ServiceResponse> {
        let report = if is_frontier {
            Arc::new(report.reprice(&req.model, &self.core.catalog, &self.core.config.money)?)
        } else {
            report
        };
        Some(ServiceResponse {
            fingerprint: fp,
            source: ResponseSource::Cache,
            service_secs: t0.elapsed().as_secs_f64(),
            report,
        })
    }

    /// Serve one request: cache → single-flight coalescing → engine search.
    pub fn handle(&self, req: &SearchRequest) -> Result<ServiceResponse> {
        let t0 = Instant::now();
        let fp = self.fingerprint_of(req);
        let is_frontier = matches!(req.mode, GpuPoolMode::Frontier { .. });
        // The response fingerprint stays the full, book-dependent one even
        // for frontier requests — a repriced hit and a cold search under
        // the same book answer byte-identically.
        let key = if is_frontier { self.cache_key_of(req) } else { fp };
        if let Some(report) = self.cache.get(key) {
            if let Some(resp) = self.serve_cached(req, fp, is_frontier, report, &t0) {
                return Ok(resp);
            }
        }
        // Single-flight: exactly one thread (the leader) runs the search;
        // everyone else arriving with the same cache key waits on it.
        let (slot, leader) = {
            let mut map = self.inflight.lock().unwrap();
            // Re-check the cache under the in-flight lock: a finishing
            // leader publishes to the cache *before* clearing its marker,
            // so a miss here is authoritative and we cannot double-search.
            if let Some(report) = self.cache.peek(key) {
                if let Some(resp) = self.serve_cached(req, fp, is_frontier, report, &t0) {
                    return Ok(resp);
                }
            }
            match map.get(&key.0) {
                Some(s) => (s.clone(), false),
                None => {
                    let s = Arc::new(FlightSlot::new());
                    map.insert(key.0, s.clone());
                    (s, true)
                }
            }
        };
        if leader {
            // Unwind safety: if the engine panics, the guard still
            // publishes a failure and clears the marker — otherwise every
            // waiter (condvar, no timeout) and all future requests with
            // this fingerprint would wedge for the server's lifetime.
            let mut guard = FlightGuard {
                inflight: &self.inflight,
                slot: slot.as_ref(),
                key: key.0,
                armed: true,
            };
            let result = self.core.search(req).map(Arc::new);
            // Publish to the cache *before* waking waiters and clearing the
            // in-flight marker, so a racing request either joins the flight
            // or hits the cache — never re-searches.
            if let Ok(report) = &result {
                self.cache.insert(key, report.clone());
            }
            slot.publish(match &result {
                Ok(r) => Ok(r.clone()),
                Err(e) => Err(e.to_string()),
            });
            self.inflight.lock().unwrap().remove(&key.0);
            guard.disarm();
            let resp = result.map(|report| ServiceResponse {
                fingerprint: fp,
                source: ResponseSource::Search,
                service_secs: t0.elapsed().as_secs_f64(),
                report,
            });
            if resp.is_ok() {
                // New warmth entered the registry/cache; maybe spill.
                self.note_admission();
            }
            resp
        } else {
            match slot.wait() {
                Ok(report) => Ok(ServiceResponse {
                    fingerprint: fp,
                    source: ResponseSource::Coalesced,
                    service_secs: t0.elapsed().as_secs_f64(),
                    report,
                }),
                Err(msg) => Err(AstraError::Search(format!("coalesced request failed: {msg}"))),
            }
        }
    }

    /// Batched admission: deduplicate fingerprints inside the batch, fan
    /// the distinct requests out over scoped workers, and return responses
    /// in input order. Duplicates of an earlier batch entry are reported as
    /// [`ResponseSource::Coalesced`] and share the leader's report.
    pub fn handle_batch(&self, reqs: &[SearchRequest]) -> Vec<Result<ServiceResponse>> {
        let fps: Vec<Fingerprint> = reqs.iter().map(|r| self.fingerprint_of(r)).collect();
        // First occurrence of each fingerprint runs; later ones coalesce.
        let mut first_of: HashMap<u64, usize> = HashMap::new();
        let mut distinct: Vec<usize> = Vec::new();
        for (i, fp) in fps.iter().enumerate() {
            first_of.entry(fp.0).or_insert_with(|| {
                distinct.push(i);
                i
            });
        }
        // Each search already saturates the engine's worker pool; the outer
        // fan-out only needs to overlap requests of uneven length. Cap it
        // (auto: ≤4) so a cold batch does not spawn ~workers² threads.
        let workers = if self.config.batch_workers > 0 {
            self.config.batch_workers
        } else {
            self.core.config.workers.min(4)
        };
        // Admit at most `max_batch` distinct requests per fan-out round.
        // The queue-depth gauge tracks how many distinct requests are in
        // fan-out right now, across every concurrent batch.
        let depth = crate::telemetry::gauge_macro!("astra_admission_queue_depth");
        let mut leader_results: Vec<Result<ServiceResponse>> =
            Vec::with_capacity(distinct.len());
        for chunk in distinct.chunks(self.config.max_batch.max(1)) {
            depth.add(chunk.len() as i64);
            let mut part =
                par_for_indices(chunk.len(), workers, |i| self.handle(&reqs[chunk[i]]));
            depth.add(-(chunk.len() as i64));
            leader_results.append(&mut part);
        }
        // Map distinct-index → result, then assemble per-input responses.
        let mut by_leader: HashMap<usize, &Result<ServiceResponse>> = HashMap::new();
        for (k, &input_idx) in distinct.iter().enumerate() {
            by_leader.insert(input_idx, &leader_results[k]);
        }
        fps.iter()
            .enumerate()
            .map(|(i, fp)| {
                let leader_idx = first_of[&fp.0];
                let leader = by_leader[&leader_idx];
                match leader {
                    Ok(resp) => {
                        let mut resp = resp.clone();
                        if i != leader_idx {
                            resp.source = ResponseSource::Coalesced;
                        }
                        Ok(resp)
                    }
                    Err(e) => Err(AstraError::Search(e.to_string())),
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::EngineConfig;
    use crate::gpu::GpuCatalog;
    use crate::model::ModelRegistry;
    use crate::pareto::MoneyModel;
    use crate::pricing::{PriceBook, PriceEntry};
    use crate::strategy::SpaceConfig;

    /// A deliberately small space so unit tests stay fast.
    pub(crate) fn small_core() -> ScoringCore {
        small_core_with_book(PriceBook::builtin())
    }

    fn small_core_with_book(book: PriceBook) -> ScoringCore {
        let space = SpaceConfig {
            tp_candidates: vec![1, 2],
            max_pp: 4,
            mbs_candidates: vec![1, 2],
            vpp_candidates: vec![1],
            seq_parallel_options: vec![true],
            dist_opt_options: vec![true],
            offload_options: vec![false],
            recompute_none: true,
            recompute_selective: false,
            recompute_full: false,
            ..SpaceConfig::default()
        };
        ScoringCore::new(
            GpuCatalog::builtin(),
            EngineConfig {
                use_forests: false,
                space,
                money: MoneyModel { book, ..Default::default() },
                ..Default::default()
            },
        )
    }

    fn req(count: usize) -> SearchRequest {
        let model = ModelRegistry::builtin().get("llama2-7b").unwrap().clone();
        SearchRequest::homogeneous("a800", count, model).unwrap()
    }

    #[test]
    fn repeat_request_hits_cache_not_engine() {
        let svc = SearchService::new(small_core(), ServiceConfig::default());
        let a = svc.handle(&req(16)).unwrap();
        assert_eq!(a.source, ResponseSource::Search);
        let b = svc.handle(&req(16)).unwrap();
        assert_eq!(b.source, ResponseSource::Cache);
        assert_eq!(a.fingerprint, b.fingerprint);
        assert_eq!(svc.core().searches_run(), 1, "cache hit must not re-search");
        assert!(Arc::ptr_eq(&a.report, &b.report), "hit must share the cached report");
    }

    #[test]
    fn bad_requests_fail_without_caching() {
        let svc = SearchService::new(small_core(), ServiceConfig::default());
        // Heterogeneous caps below total is a config error from the engine.
        let model = ModelRegistry::builtin().get("llama2-7b").unwrap().clone();
        let bad = SearchRequest::heterogeneous(&[("a800", 8)], 64, model).unwrap();
        assert!(svc.handle(&bad).is_err());
        assert_eq!(svc.cache_stats().insertions, 0, "errors must not be cached");
        // And the error is not sticky: nothing is left in-flight.
        assert!(svc.handle(&bad).is_err());
    }

    #[test]
    fn concurrent_identical_requests_single_flight() {
        let svc = SearchService::new(small_core(), ServiceConfig::default());
        let sources: Vec<ResponseSource> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..4)
                .map(|_| s.spawn(|| svc.handle(&req(32)).unwrap().source))
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        assert_eq!(svc.core().searches_run(), 1, "identical requests must coalesce");
        assert_eq!(
            sources.iter().filter(|&&s| s == ResponseSource::Search).count(),
            1,
            "exactly one leader: {sources:?}"
        );
    }

    fn frontier_req() -> SearchRequest {
        let model = ModelRegistry::builtin().get("llama2-7b").unwrap().clone();
        SearchRequest::frontier(&[("a800", 4), ("h100", 4)], model).unwrap()
    }

    #[test]
    fn frontier_repeat_repriced_from_cache_not_engine() {
        let svc = SearchService::new(small_core(), ServiceConfig::default());
        let a = svc.handle(&frontier_req()).unwrap();
        assert_eq!(a.source, ResponseSource::Search);
        assert!(a.report.frontier.is_some(), "frontier mode must return a skeleton");
        assert!(!a.report.pool.is_empty(), "frontier must be non-empty");
        let b = svc.handle(&frontier_req()).unwrap();
        assert_eq!(b.source, ResponseSource::Cache);
        assert_eq!(svc.core().searches_run(), 1, "repeat must reprice, not re-search");
        assert_eq!(a.fingerprint, b.fingerprint);
        // Same book ⇒ the serve-time reprice is the identity on the wire.
        let catalog = &svc.core().catalog;
        assert_eq!(
            crate::json::to_string(&crate::report::report_json(&a.report, catalog)),
            crate::json::to_string(&crate::report::report_json(&b.report, catalog)),
        );
    }

    #[test]
    fn repriced_frontier_after_restart_matches_cold_search_under_new_book() {
        let dir = std::env::temp_dir()
            .join(format!("astra_warm_frontier_restart_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let cfg = ServiceConfig {
            warm: WarmConfig {
                dir: Some(dir.clone()),
                spill_every: 0,
                include_cache: true,
                max_snapshot_bytes: 0,
            },
            ..Default::default()
        };
        // Book B differs from the builtin card by rates only: a price move
        // plus spot billing. Membership is unchanged.
        let mut book_b = PriceBook::builtin();
        book_b.upsert(PriceEntry {
            gpu: "h100".to_string(),
            on_demand_per_hour: 9.99,
            spot_per_hour: 3.99,
        });
        book_b.use_spot = true;

        // Boot 1: search a frontier under the builtin book and spill.
        let svc_a = SearchService::new(small_core(), cfg.clone());
        let a = svc_a.handle(&frontier_req()).unwrap();
        assert_eq!(a.source, ResponseSource::Search);
        svc_a.spill_warm().unwrap().expect("configured spill must run");

        // Boot 2: same engine, rates changed. The spilled frontier must
        // restore (membership pin) and serve repriced — no engine admission.
        let svc_b = SearchService::new(small_core_with_book(book_b.clone()), cfg);
        let b = svc_b.handle(&frontier_req()).unwrap();
        assert_eq!(b.source, ResponseSource::Cache, "restored frontier must serve from cache");
        assert_eq!(svc_b.core().searches_run(), 0, "reprice must not admit the engine");

        // Reference: a cold search under book B. The repriced cached answer
        // must match it byte-for-byte on the canonical wire view.
        let svc_c = SearchService::new(small_core_with_book(book_b), ServiceConfig::default());
        let c = svc_c.handle(&frontier_req()).unwrap();
        assert_eq!(c.source, ResponseSource::Search);
        let catalog = &svc_c.core().catalog;
        assert_eq!(
            crate::json::to_string(&crate::report::report_json(&b.report, catalog)),
            crate::json::to_string(&crate::report::report_json(&c.report, catalog)),
            "reprice-from-cache must equal a cold re-search under the new book"
        );
        assert_eq!(b.report.top[0].money_usd.to_bits(), c.report.top[0].money_usd.to_bits());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn batch_dedupes_and_preserves_order() {
        let svc = SearchService::new(small_core(), ServiceConfig::default());
        let reqs = vec![req(8), req(16), req(8), req(32)];
        let out = svc.handle_batch(&reqs);
        assert_eq!(out.len(), 4);
        let resp: Vec<&ServiceResponse> = out.iter().map(|r| r.as_ref().unwrap()).collect();
        assert_eq!(resp[0].fingerprint, resp[2].fingerprint);
        assert_ne!(resp[0].fingerprint, resp[1].fingerprint);
        assert_eq!(resp[2].source, ResponseSource::Coalesced);
        assert_eq!(svc.core().searches_run(), 3, "3 distinct requests in the batch");
    }
}
