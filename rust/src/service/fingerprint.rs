//! Canonical request fingerprinting.
//!
//! A [`Fingerprint`] is a stable 64-bit FNV-1a hash over everything that
//! determines a search's *result*: the model architecture, the GPU-pool
//! mode, and the result-relevant [`EngineConfig`] knobs (space, rules, η
//! source, money model, objective). Semantically identical requests must
//! collide, so the encoding is canonicalized before hashing:
//!
//! * heterogeneous capacity lists canonicalize as per-type *maps*:
//!   duplicate entries merge by summation and entries sort by GPU name —
//!   neither the wire order nor the split of `caps` matters;
//! * candidate lists in [`SpaceConfig`] are sorted and deduplicated;
//! * rule sets hash as the sorted, deduplicated set of rule sources (rule
//!   order cannot change which strategies survive — any match drops);
//! * GPUs hash by catalog *name*, not index, so a reordered catalog does
//!   not shuffle keys;
//! * `workers` is excluded — thread count never changes the result.
//!
//! JSON field order is canonicalized upstream for free: the wire parser
//! ([`crate::service::server`]) materializes objects as sorted maps.

use crate::coordinator::{EngineConfig, ScoringEngine, SearchRequest};
use crate::gpu::GpuCatalog;
use crate::model::ModelSpec;
use crate::strategy::{merge_caps, GpuPoolMode, SpaceConfig};

/// A canonical request key. Displayed as 16 lowercase hex digits.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Fingerprint(pub u64);

impl std::fmt::Display for Fingerprint {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{:016x}", self.0)
    }
}

impl Fingerprint {
    /// Parse the 16-hex-digit wire form back into a fingerprint.
    pub fn parse(s: &str) -> Option<Fingerprint> {
        if s.len() != 16 {
            return None;
        }
        u64::from_str_radix(s, 16).ok().map(Fingerprint)
    }
}

/// Incremental FNV-1a (64-bit). Deterministic across platforms and runs —
/// unlike `DefaultHasher`, which is randomly seeded per process.
#[derive(Debug, Clone)]
pub struct Fnv64(u64);

const FNV_OFFSET: u64 = 0xcbf29ce484222325;
const FNV_PRIME: u64 = 0x100000001b3;

impl Default for Fnv64 {
    fn default() -> Self {
        Fnv64(FNV_OFFSET)
    }
}

impl Fnv64 {
    pub fn new() -> Fnv64 {
        Fnv64::default()
    }

    pub fn write_bytes(&mut self, bytes: &[u8]) -> &mut Self {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(FNV_PRIME);
        }
        self
    }

    /// Tagged field: the label keeps adjacent fields from aliasing.
    pub fn field_u64(&mut self, tag: &str, v: u64) -> &mut Self {
        self.write_bytes(tag.as_bytes()).write_bytes(&v.to_le_bytes())
    }

    pub fn field_usize(&mut self, tag: &str, v: usize) -> &mut Self {
        self.field_u64(tag, v as u64)
    }

    pub fn field_bool(&mut self, tag: &str, v: bool) -> &mut Self {
        self.field_u64(tag, v as u64)
    }

    /// f64 hashed by bit pattern (exact, including -0.0 vs 0.0 and inf).
    pub fn field_f64(&mut self, tag: &str, v: f64) -> &mut Self {
        self.field_u64(tag, v.to_bits())
    }

    pub fn field_str(&mut self, tag: &str, v: &str) -> &mut Self {
        self.write_bytes(tag.as_bytes())
            .field_usize("len", v.len())
            .write_bytes(v.as_bytes())
    }

    pub fn finish(&self) -> u64 {
        self.0
    }
}

/// Canonical sorted+deduped copy of a candidate list.
fn canon(xs: &[usize]) -> Vec<usize> {
    let mut v = xs.to_vec();
    v.sort_unstable();
    v.dedup();
    v
}

fn canon_bools(xs: &[bool]) -> Vec<bool> {
    let mut v = xs.to_vec();
    v.sort_unstable();
    v.dedup();
    v
}

fn hash_model(h: &mut Fnv64, m: &ModelSpec) {
    h.field_str("model.name", &m.name)
        .field_usize("model.layers", m.layers)
        .field_usize("model.hidden", m.hidden)
        .field_usize("model.heads", m.heads)
        .field_usize("model.kv_heads", m.kv_heads)
        .field_usize("model.ffn", m.ffn)
        .field_usize("model.vocab", m.vocab)
        .field_usize("model.seq_len", m.seq_len)
        .field_usize("model.global_batch", m.global_batch)
        .field_usize("model.num_experts", m.num_experts)
        .field_usize("model.moe_topk", m.moe_topk);
}

fn hash_mode(h: &mut Fnv64, mode: &GpuPoolMode, catalog: &GpuCatalog) {
    match mode {
        GpuPoolMode::Homogeneous { gpu, count } => {
            h.field_str("mode", "homogeneous")
                .field_str("gpu", &catalog.spec(*gpu).name)
                .field_usize("count", *count);
        }
        GpuPoolMode::Heterogeneous { total, caps } => {
            h.field_str("mode", "heterogeneous").field_usize("total", *total);
            // Caps are canonically a per-type map ([`merge_caps`]): merge
            // duplicate entries by summation (the JSON wire form is an
            // object and cannot even express duplicates), then sort by
            // name so entry order never matters.
            let mut named = merge_caps(
                caps.iter().map(|&(g, c)| (catalog.spec(g).name.as_str(), c)),
            );
            named.sort_unstable();
            h.field_usize("caps.len", named.len());
            for (name, cap) in named {
                h.field_str("cap.gpu", name).field_usize("cap.n", cap);
            }
        }
        GpuPoolMode::Cost { gpu, max_count, max_money } => {
            h.field_str("mode", "cost")
                .field_str("gpu", &catalog.spec(*gpu).name)
                .field_usize("max_count", *max_count)
                .field_f64("max_money", *max_money);
        }
        GpuPoolMode::HeteroCost { caps, max_money } => {
            h.field_str("mode", "hetero-cost").field_f64("max_money", *max_money);
            // Same per-type-map canonicalization as mode 2.
            let mut named = merge_caps(
                caps.iter().map(|&(g, c)| (catalog.spec(g).name.as_str(), c)),
            );
            named.sort_unstable();
            h.field_usize("caps.len", named.len());
            for (name, cap) in named {
                h.field_str("cap.gpu", name).field_usize("cap.n", cap);
            }
        }
        GpuPoolMode::Frontier { caps } => {
            h.field_str("mode", "frontier");
            let mut named = merge_caps(
                caps.iter().map(|&(g, c)| (catalog.spec(g).name.as_str(), c)),
            );
            named.sort_unstable();
            h.field_usize("caps.len", named.len());
            for (name, cap) in named {
                h.field_str("cap.gpu", name).field_usize("cap.n", cap);
            }
        }
    }
}

/// The price book is part of every result (it prices each scored
/// strategy), so the whole card enters the key: entries are already
/// canonically sorted by GPU name inside [`PriceBook`]. `pub(crate)`
/// because [`crate::persist::book_digest`] reuses this exact field walk —
/// one canonical list, so a new `PriceBook` field cannot silently enter
/// one hash and not the other.
pub(crate) fn hash_book(h: &mut Fnv64, book: &crate::pricing::PriceBook) {
    h.field_usize("book.len", book.entries().len());
    for e in book.entries() {
        h.field_str("book.gpu", &e.gpu)
            .field_f64("book.od", e.on_demand_per_hour)
            .field_f64("book.spot", e.spot_per_hour);
    }
    h.field_bool("book.use_spot", book.use_spot);
    match book.hour {
        Some(hr) => h.field_usize("book.hour", hr),
        None => h.field_str("book.hour", "none"),
    };
    h.field_usize("book.tod.len", book.tod_multipliers.len());
    for &m in &book.tod_multipliers {
        h.field_f64("book.tod", m);
    }
}

fn hash_space(h: &mut Fnv64, s: &SpaceConfig) {
    for (tag, xs) in [
        ("space.tp", &s.tp_candidates),
        ("space.mbs", &s.mbs_candidates),
        ("space.vpp", &s.vpp_candidates),
        ("space.ep", &s.ep_candidates),
    ] {
        let c = canon(xs);
        h.field_usize(tag, c.len());
        for v in c {
            h.field_usize(tag, v);
        }
    }
    h.field_usize("space.max_pp", s.max_pp);
    for (tag, xs) in [
        ("space.sp", &s.seq_parallel_options),
        ("space.do", &s.dist_opt_options),
        ("space.off", &s.offload_options),
    ] {
        let c = canon_bools(xs);
        h.field_usize(tag, c.len());
        for v in c {
            h.field_bool(tag, v);
        }
    }
    h.field_bool("space.rc_none", s.recompute_none)
        .field_bool("space.rc_sel", s.recompute_selective)
        .field_bool("space.rc_full", s.recompute_full)
        .field_bool("space.overlap", s.overlap)
        .field_bool("space.flash", s.use_flash_attn);
}

/// Membership-only view of the price book: the GPU-type *name set*, none
/// of the rates. This is the frontier cache key's money axis — frontier
/// candidate sets are rate-independent by construction (no budget, no
/// money pruning), so only a change that could alter frontier *membership*
/// (a type entering or leaving the book, flipping whose bills fall back to
/// the catalog rate) may change the key. On-demand/spot dollars,
/// `use_spot`, the billing hour and the time-of-day multipliers are all
/// deliberately absent: those changes are served by reprice, not
/// re-search.
pub(crate) fn hash_book_membership(h: &mut Fnv64, book: &crate::pricing::PriceBook) {
    h.field_usize("book.members.len", book.entries().len());
    for e in book.entries() {
        h.field_str("book.member", &e.gpu);
    }
}

/// Everything [`hash_config`] covers except the price book — shared by the
/// full fingerprint (which appends [`hash_book`]) and the frontier
/// fingerprint (which appends [`hash_book_membership`] instead).
fn hash_config_core(h: &mut Fnv64, cfg: &EngineConfig) {
    hash_space(h, &cfg.space);
    // Rule order is irrelevant (any match filters); sort + dedup sources.
    let mut sources: Vec<&str> = cfg.rules.rules.iter().map(|r| r.source.as_str()).collect();
    sources.sort_unstable();
    sources.dedup();
    h.field_usize("rules.len", sources.len());
    for s in sources {
        h.field_str("rule", s);
    }
    h.field_str(
        "engine",
        match cfg.engine {
            ScoringEngine::Native => "native",
            ScoringEngine::Hlo => "hlo",
        },
    )
    .field_bool("use_forests", cfg.use_forests)
    .field_f64("money.train_tokens", cfg.money.train_tokens)
    .field_bool("hetero_exhaustive", cfg.hetero_exhaustive)
    .field_bool("money_prune", cfg.money_prune)
    // `streaming` is a compatibility flag (it maps to the serial
    // workers=1/wave=1 plan, same executor, identical result bytes) but it
    // stays in the key so fingerprints are stable across the refactor that
    // retired the old reference pipeline.
    .field_bool("streaming", cfg.streaming)
    .field_usize("top_k", cfg.top_k);
    // `workers`, `sweep_wave`, `sweep_wave_max` and `batch_eta`
    // deliberately excluded: worker count never changes results, the
    // hetero-cost wave replay (adaptive or not) is byte-identical to the
    // serial sweep at any wave schedule, and the flat-forest batch kernel
    // is bit-identical to the scalar η walk (all differential-tested) —
    // none of them can change result bytes, so none may split the cache.
}

fn hash_config(h: &mut Fnv64, cfg: &EngineConfig) {
    hash_config_core(h, cfg);
    hash_book(h, &cfg.money.book);
}

/// Fingerprint of (request, config): the service cache key.
pub fn fingerprint(req: &SearchRequest, catalog: &GpuCatalog, cfg: &EngineConfig) -> Fingerprint {
    let mut h = Fnv64::new();
    h.field_str("astra.fingerprint", "v1");
    hash_model(&mut h, &req.model);
    hash_mode(&mut h, &req.mode, catalog);
    hash_config(&mut h, cfg);
    Fingerprint(h.finish())
}

/// The frontier cache key: identical to [`fingerprint`] except the price
/// book enters membership-only ([`hash_book_membership`]) — rates, spot
/// selection, billing hour and time-of-day multipliers are out, so a
/// rate-only book change keys to the *same* cached frontier and is served
/// by reprice instead of re-search. Its own version tag keeps the two
/// keyspaces from ever colliding inside the shared cache.
pub fn frontier_fingerprint(
    req: &SearchRequest,
    catalog: &GpuCatalog,
    cfg: &EngineConfig,
) -> Fingerprint {
    let mut h = Fnv64::new();
    h.field_str("astra.frontier_fingerprint", "v1");
    hash_model(&mut h, &req.model);
    hash_mode(&mut h, &req.mode, catalog);
    hash_config_core(&mut h, cfg);
    hash_book_membership(&mut h, &cfg.money.book);
    Fingerprint(h.finish())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelRegistry;

    fn model() -> ModelSpec {
        ModelRegistry::builtin().get("llama2-7b").unwrap().clone()
    }

    fn fp(req: &SearchRequest, cfg: &EngineConfig) -> Fingerprint {
        fingerprint(req, &GpuCatalog::builtin(), cfg)
    }

    #[test]
    fn identical_requests_collide() {
        let cfg = EngineConfig::default();
        let a = SearchRequest::homogeneous("a800", 64, model()).unwrap();
        let b = SearchRequest::homogeneous("a800", 64, model()).unwrap();
        assert_eq!(fp(&a, &cfg), fp(&b, &cfg));
    }

    #[test]
    fn capacity_order_is_canonical() {
        let cfg = EngineConfig::default();
        let a = SearchRequest::heterogeneous(&[("a800", 48), ("h100", 48)], 64, model()).unwrap();
        let b = SearchRequest::heterogeneous(&[("h100", 48), ("a800", 48)], 64, model()).unwrap();
        assert_eq!(fp(&a, &cfg), fp(&b, &cfg));
    }

    #[test]
    fn duplicate_cap_entries_merge_as_a_map() {
        // Caps are a per-type map: a hand-built mode with split duplicate
        // entries keys the same as the merged form.
        use crate::strategy::GpuPoolMode;
        let cfg = EngineConfig::default();
        let cat = GpuCatalog::builtin();
        let gpu = cat.find("a800").unwrap();
        let split = SearchRequest {
            mode: GpuPoolMode::Heterogeneous { total: 32, caps: vec![(gpu, 16), (gpu, 16)] },
            model: model(),
        };
        let merged = SearchRequest {
            mode: GpuPoolMode::Heterogeneous { total: 32, caps: vec![(gpu, 32)] },
            model: model(),
        };
        assert_eq!(fp(&split, &cfg), fp(&merged, &cfg));
        // The named constructor canonicalizes up front.
        let built =
            SearchRequest::heterogeneous(&[("a800", 16), ("a800", 16)], 32, model()).unwrap();
        match &built.mode {
            GpuPoolMode::Heterogeneous { caps, .. } => assert_eq!(caps, &vec![(gpu, 32)]),
            other => panic!("wrong mode {other:?}"),
        }
    }

    #[test]
    fn distinct_requests_diverge() {
        let cfg = EngineConfig::default();
        let base = SearchRequest::homogeneous("a800", 64, model()).unwrap();
        let other_count = SearchRequest::homogeneous("a800", 128, model()).unwrap();
        let other_gpu = SearchRequest::homogeneous("h100", 64, model()).unwrap();
        let other_model = SearchRequest::homogeneous(
            "a800",
            64,
            ModelRegistry::builtin().get("llama2-13b").unwrap().clone(),
        )
        .unwrap();
        let f = fp(&base, &cfg);
        assert_ne!(f, fp(&other_count, &cfg));
        assert_ne!(f, fp(&other_gpu, &cfg));
        assert_ne!(f, fp(&other_model, &cfg));
    }

    #[test]
    fn config_knobs_are_part_of_the_key() {
        let req = SearchRequest::homogeneous("a800", 64, model()).unwrap();
        let base = EngineConfig::default();
        let mut tokens = EngineConfig::default();
        tokens.money.train_tokens = 2e9;
        let mut topk = EngineConfig::default();
        topk.top_k = 3;
        let f = fp(&req, &base);
        assert_ne!(f, fp(&req, &tokens));
        assert_ne!(f, fp(&req, &topk));
    }

    #[test]
    fn price_book_is_part_of_the_key() {
        let req = SearchRequest::homogeneous("a800", 64, model()).unwrap();
        let base = EngineConfig::default();
        let f = fp(&req, &base);

        let mut spot = EngineConfig::default();
        spot.money.book.use_spot = true;
        assert_ne!(f, fp(&req, &spot), "spot billing must change the key");

        let mut repriced = EngineConfig::default();
        repriced.money.book.upsert(crate::pricing::PriceEntry {
            gpu: "a800".to_string(),
            on_demand_per_hour: 9.99,
            spot_per_hour: 1.0,
        });
        assert_ne!(f, fp(&req, &repriced), "a rate change must change the key");

        let mut tod = EngineConfig::default();
        tod.money.book.tod_multipliers[3] = 0.5;
        tod.money.book.hour = Some(3);
        assert_ne!(f, fp(&req, &tod), "time-of-day pricing must change the key");
    }

    #[test]
    fn hetero_cost_caps_canonicalize_like_mode_2() {
        let cfg = EngineConfig::default();
        let a = SearchRequest::hetero_cost(&[("a800", 48), ("h100", 16)], 5e4, model()).unwrap();
        let b = SearchRequest::hetero_cost(&[("h100", 16), ("a800", 48)], 5e4, model()).unwrap();
        let c = SearchRequest::hetero_cost(&[("h100", 16), ("a800", 24), ("a800", 24)], 5e4, model())
            .unwrap();
        assert_eq!(fp(&a, &cfg), fp(&b, &cfg));
        assert_eq!(fp(&a, &cfg), fp(&c, &cfg), "split duplicate caps must merge");
        // Distinct from the mode-2 shape with the same caps, and sensitive
        // to the budget.
        let mode2 =
            SearchRequest::heterogeneous(&[("a800", 48), ("h100", 16)], 64, model()).unwrap();
        assert_ne!(fp(&a, &cfg), fp(&mode2, &cfg));
        let other_budget =
            SearchRequest::hetero_cost(&[("a800", 48), ("h100", 16)], 6e4, model()).unwrap();
        assert_ne!(fp(&a, &cfg), fp(&other_budget, &cfg));
    }

    #[test]
    fn workers_do_not_change_the_key() {
        let req = SearchRequest::homogeneous("a800", 64, model()).unwrap();
        let mut a = EngineConfig::default();
        a.workers = 1;
        let mut b = EngineConfig::default();
        b.workers = 32;
        assert_eq!(fp(&req, &a), fp(&req, &b));
    }

    #[test]
    fn batch_eta_does_not_change_the_key() {
        // Like workers/waves, the batch kernel can't change result bytes,
        // so flipping it must hit the same cache entry.
        let req = SearchRequest::homogeneous("a800", 64, model()).unwrap();
        let mut a = EngineConfig::default();
        a.batch_eta = true;
        let mut b = EngineConfig::default();
        b.batch_eta = false;
        assert_eq!(fp(&req, &a), fp(&req, &b));
    }

    #[test]
    fn candidate_and_rule_order_canonicalized() {
        let req = SearchRequest::homogeneous("a800", 64, model()).unwrap();
        let mut a = EngineConfig::default();
        a.space.tp_candidates = vec![8, 1, 4, 2, 2];
        let b = EngineConfig::default(); // [1, 2, 4, 8]
        assert_eq!(fp(&req, &a), fp(&req, &b));

        let mut ra = crate::rules::RuleSet::new();
        ra.add("$tp > 8").unwrap();
        ra.add("$dp > 512").unwrap();
        let mut rb = crate::rules::RuleSet::new();
        rb.add("$dp > 512").unwrap();
        rb.add("$tp > 8").unwrap();
        let mut ca = EngineConfig::default();
        ca.rules = ra;
        let mut cb = EngineConfig::default();
        cb.rules = rb;
        assert_eq!(fp(&req, &ca), fp(&req, &cb));
    }

    #[test]
    fn frontier_key_drops_rates_but_keeps_membership() {
        let cat = GpuCatalog::builtin();
        let req = SearchRequest::frontier(&[("a800", 8), ("h100", 8)], model()).unwrap();
        let base = EngineConfig::default();
        let ffp = |cfg: &EngineConfig| frontier_fingerprint(&req, &cat, cfg);
        let f = ffp(&base);

        // Rate-only book changes: same frontier key (served by reprice) …
        let mut repriced = EngineConfig::default();
        repriced.money.book.upsert(crate::pricing::PriceEntry {
            gpu: "a800".to_string(),
            on_demand_per_hour: 9.99,
            spot_per_hour: 1.0,
        });
        assert_eq!(f, ffp(&repriced), "a rate move must not change the frontier key");
        let mut spot = EngineConfig::default();
        spot.money.book.use_spot = true;
        assert_eq!(f, ffp(&spot), "spot billing must not change the frontier key");
        let mut tod = EngineConfig::default();
        tod.money.book.tod_multipliers[3] = 0.5;
        tod.money.book.hour = Some(3);
        assert_eq!(f, ffp(&tod), "time-of-day pricing must not change the frontier key");
        // … while the full (response) fingerprint still sees them all.
        assert_ne!(fp(&req, &base), fp(&req, &repriced));
        assert_ne!(fp(&req, &base), fp(&req, &spot));

        // Membership changes re-key: a GPU type entering the book could
        // change whose bills fall back to the catalog rate.
        let mut grown = EngineConfig::default();
        grown.money.book.upsert(crate::pricing::PriceEntry {
            gpu: "tpu-v9".to_string(),
            on_demand_per_hour: 5.0,
            spot_per_hour: 2.0,
        });
        assert_ne!(f, ffp(&grown), "book membership must stay in the frontier key");
        // Non-book axes still key normally.
        let mut tokens = EngineConfig::default();
        tokens.money.train_tokens = 2e9;
        assert_ne!(f, ffp(&tokens));
        let other_caps = SearchRequest::frontier(&[("a800", 4), ("h100", 8)], model()).unwrap();
        assert_ne!(f, frontier_fingerprint(&other_caps, &cat, &base));
        // The two keyspaces never collide (distinct version tags).
        assert_ne!(f, fp(&req, &base));
    }

    #[test]
    fn frontier_caps_canonicalize_like_the_other_hetero_modes() {
        let cat = GpuCatalog::builtin();
        let cfg = EngineConfig::default();
        let a = SearchRequest::frontier(&[("a800", 48), ("h100", 16)], model()).unwrap();
        let b = SearchRequest::frontier(&[("h100", 16), ("a800", 48)], model()).unwrap();
        let c =
            SearchRequest::frontier(&[("h100", 16), ("a800", 24), ("a800", 24)], model()).unwrap();
        assert_eq!(frontier_fingerprint(&a, &cat, &cfg), frontier_fingerprint(&b, &cat, &cfg));
        assert_eq!(frontier_fingerprint(&a, &cat, &cfg), frontier_fingerprint(&c, &cat, &cfg));
        assert_eq!(fp(&a, &cfg), fp(&b, &cfg));
    }

    #[test]
    fn display_and_parse_roundtrip() {
        let f = Fingerprint(0x0123_4567_89ab_cdef);
        assert_eq!(f.to_string(), "0123456789abcdef");
        assert_eq!(Fingerprint::parse(&f.to_string()), Some(f));
        assert_eq!(Fingerprint::parse("xyz"), None);
    }
}
