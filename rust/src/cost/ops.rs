//! Operator census: the per-stage list of computation and communication
//! operators a strategy executes, with their analytic workloads θ (Eq. 25/26
//! numerators). This is the "analytical, not database-lookup" operator
//! model the paper highlights — it adapts to any architecture parsed from
//! [`crate::model::ModelSpec`].
//!
//! The same census (shape classes and counts) is re-implemented in the
//! Layer-2 JAX graph (`python/compile/model.py`); the two are parity-tested
//! through the HLO scorer.

use crate::model::ModelSpec;
use crate::strategy::ParallelStrategy;

/// One computation operator's workload descriptor (per GPU, per microbatch).
#[derive(Debug, Clone, Copy)]
pub struct OpShape {
    /// FLOPs of the op.
    pub flops: f64,
    /// Smallest GEMM dimension (drives tile efficiency).
    pub min_dim: f64,
    /// Bytes touched (drives the roofline clamp).
    pub bytes: f64,
}

impl OpShape {
    pub fn gemm(m: f64, n: f64, k: f64) -> OpShape {
        OpShape {
            flops: 2.0 * m * n * k,
            min_dim: m.min(n).min(k),
            bytes: 2.0 * (m * k + k * n + m * n),
        }
    }

    pub fn intensity(&self) -> f64 {
        self.flops / self.bytes.max(1.0)
    }
}

/// A computation op plus how many times it runs in the stage's forward pass.
#[derive(Debug, Clone, Copy)]
pub struct CountedOp {
    pub shape: OpShape,
    pub count: f64,
    /// Tag for debugging/reporting.
    pub kind: OpKind,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OpKind {
    QkvProj,
    AttnScore,
    AttnContext,
    AttnFused,
    OutProj,
    MlpUp,
    MlpDown,
    LmHead,
}

/// Forward computation census of one pipeline stage (per microbatch).
/// Counts already include the stage's layer count.
pub fn stage_fwd_ops(m: &ModelSpec, s: &ParallelStrategy, stage: usize) -> Vec<CountedOp> {
    let layers = s.cluster.layers_of_stage(stage) as f64;
    let b = s.micro_batch as f64;
    let seq = m.seq_len as f64;
    let h = m.hidden as f64;
    let t = s.tp as f64;
    let heads = m.heads as f64;
    let head_dim = h / heads;
    let kvf = m.kv_heads as f64 / heads;
    let ffn = m.ffn as f64;
    let gate = if m.gated_mlp() { 2.0 } else { 1.0 };
    let mb = b * seq; // token rows in the microbatch
    // MoE: each token visits top-k experts → k MLP GEMM passes per layer.
    let mlp_passes = m.active_mlp_factor();

    let mut ops = Vec::with_capacity(8);
    // Fused QKV projection: [mb, h] × [h, (1+2·kvf)·h / t]
    ops.push(CountedOp {
        shape: OpShape::gemm(mb, (1.0 + 2.0 * kvf) * h / t, h),
        count: layers,
        kind: OpKind::QkvProj,
    });
    if s.use_flash_attn {
        // Flash attention: scores+softmax+context fused; same FLOPs, but IO
        // is only the QKV/output tiles (no s×s materialization).
        let flops = 2.0 * 2.0 * b * seq * seq * h / t;
        let bytes = 2.0 * 4.0 * mb * h / t; // q,k,v,o tiles
        ops.push(CountedOp {
            shape: OpShape { flops, min_dim: head_dim.min(seq), bytes },
            count: layers,
            kind: OpKind::AttnFused,
        });
    } else {
        // Unfused: score GEMM then context GEMM, s×s materialized per head.
        let score_bytes = 2.0 * (b * heads / t) * (2.0 * seq * head_dim + seq * seq);
        ops.push(CountedOp {
            shape: OpShape {
                flops: 2.0 * b * seq * seq * h / t,
                min_dim: head_dim.min(seq),
                bytes: score_bytes,
            },
            count: layers,
            kind: OpKind::AttnScore,
        });
        ops.push(CountedOp {
            shape: OpShape {
                flops: 2.0 * b * seq * seq * h / t,
                min_dim: head_dim.min(seq),
                bytes: score_bytes,
            },
            count: layers,
            kind: OpKind::AttnContext,
        });
    }
    // Output projection: [mb, h/t] × [h/t, h]
    ops.push(CountedOp {
        shape: OpShape::gemm(mb, h, h / t),
        count: layers,
        kind: OpKind::OutProj,
    });
    // MLP up (+gate): [mb, h] × [h, gate·ffn/t] — ×top-k for MoE.
    ops.push(CountedOp {
        shape: OpShape::gemm(mb, gate * ffn / t, h),
        count: layers * mlp_passes,
        kind: OpKind::MlpUp,
    });
    // MLP down: [mb, ffn/t] × [ffn/t, h] — ×top-k for MoE.
    ops.push(CountedOp {
        shape: OpShape::gemm(mb, h, ffn / t),
        count: layers * mlp_passes,
        kind: OpKind::MlpDown,
    });
    // LM head on the last stage: [mb, h] × [h, vocab/t]
    if stage == s.pp() - 1 {
        ops.push(CountedOp {
            shape: OpShape::gemm(mb, m.vocab as f64 / t, h),
            count: 1.0,
            kind: OpKind::LmHead,
        });
    }
    ops
}

/// Communication workloads of one stage (per microbatch, per direction).
#[derive(Debug, Clone, Copy, Default)]
pub struct StageComm {
    /// Per-rank ring volume of all TP collectives in the stage's forward
    /// pass (bytes). Backward is symmetric.
    pub tp_ring_bytes: f64,
    /// Bytes of a single TP collective (for the latency/η model).
    pub tp_msg_bytes: f64,
    /// Number of TP collectives (fwd).
    pub tp_ops: f64,
    /// Pipeline p2p activation payload leaving this stage (bytes).
    pub p2p_bytes: f64,
    /// Per-rank ring volume of MoE all-to-all dispatch+combine (bytes, fwd).
    pub a2a_ring_bytes: f64,
    /// Message size of one all-to-all (for the η model).
    pub a2a_msg_bytes: f64,
}

/// TP + p2p communication census for one stage (forward direction).
pub fn stage_comm(m: &ModelSpec, s: &ParallelStrategy, stage: usize) -> StageComm {
    let layers = s.cluster.layers_of_stage(stage) as f64;
    let b = s.micro_batch as f64;
    let seq = m.seq_len as f64;
    let h = m.hidden as f64;
    let t = s.tp as f64;
    let act_bytes = 2.0 * b * seq * h; // bf16 activation tensor
    let mut c = StageComm::default();
    if s.tp > 1 {
        // Two collectives per layer forward (all-reduce, or reduce-scatter +
        // all-gather under sequence parallelism — same ring volume).
        let per_collective_ring = 2.0 * act_bytes * (t - 1.0) / t;
        let mut n_ops = 2.0 * layers;
        if stage == s.pp() - 1 {
            n_ops += 1.0; // LM-head input gather
        }
        c.tp_ops = n_ops;
        c.tp_msg_bytes = act_bytes;
        c.tp_ring_bytes = per_collective_ring * n_ops;
    }
    // MoE all-to-all: dispatch + combine per layer, top-k activations,
    // spread over the EP group (no traffic when ep == 1 — experts local).
    if m.is_moe() && s.ep > 1 {
        let e = s.ep as f64;
        let topk_bytes = act_bytes * m.moe_topk.max(1) as f64;
        c.a2a_msg_bytes = topk_bytes / e;
        c.a2a_ring_bytes = layers * 2.0 * topk_bytes * (e - 1.0) / e;
    }
    // Boundary activation to the next stage (none for the last stage).
    if stage + 1 < s.pp() {
        c.p2p_bytes = act_bytes;
    }
    c
}

/// Total dense FLOPs of one *model* forward pass over a full global batch
/// (all layers + head), used for MFU accounting.
pub fn model_fwd_flops(m: &ModelSpec, global_batch: usize) -> f64 {
    m.layer_fwd_flops(global_batch, m.seq_len) * m.layers as f64
        + m.head_fwd_flops(global_batch, m.seq_len)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelRegistry;
    use crate::strategy::{ClusterAssignment, ParallelStrategy, Recompute, RecomputeMethod};

    fn strat(m: &ModelSpec, tp: usize, pp: usize, dp: usize, flash: bool) -> ParallelStrategy {
        ParallelStrategy {
            cluster: ClusterAssignment::homogeneous(0, pp, m.layers / pp),
            tp,
            dp,
            micro_batch: 1,
            global_batch: m.global_batch,
            vpp: 1,
            sequence_parallel: tp > 1,
            use_distributed_optimizer: true,
            recompute: Recompute::None,
            recompute_method: RecomputeMethod::Uniform,
            recompute_num_layers: 0,
            offload_optimizer: false,
            overlap_grad_reduce: true,
            overlap_param_gather: true,
            overlap_p2p: true,
            tp_comm_overlap: true,
            use_flash_attn: flash,
            ep: 1,
        }
    }

    #[test]
    fn census_flops_match_model_analytics() {
        // Sum of census FLOPs across stages × tp must equal the model's
        // layer_fwd_flops analytics (same formulas, different decomposition).
        let reg = ModelRegistry::builtin();
        for name in ["llama2-7b", "llama2-70b", "glm-130b"] {
            let m = reg.get(name).unwrap();
            let pp = if m.layers % 4 == 0 { 4 } else { 2 };
            let s = strat(m, 2, pp, 4, true);
            let total: f64 = (0..pp)
                .flat_map(|st| stage_fwd_ops(m, &s, st))
                .map(|o| o.shape.flops * o.count)
                .sum();
            let expect = (m.layer_fwd_flops(1, m.seq_len) * m.layers as f64
                + m.head_fwd_flops(1, m.seq_len))
                / s.tp as f64;
            let rel = (total - expect).abs() / expect;
            assert!(rel < 1e-9, "{name}: census {total:.4e} vs analytic {expect:.4e}");
        }
    }

    #[test]
    fn head_only_on_last_stage() {
        let reg = ModelRegistry::builtin();
        let m = reg.get("llama2-7b").unwrap();
        let s = strat(m, 2, 4, 4, true);
        assert!(!stage_fwd_ops(m, &s, 0).iter().any(|o| o.kind == OpKind::LmHead));
        assert!(stage_fwd_ops(m, &s, 3).iter().any(|o| o.kind == OpKind::LmHead));
    }

    #[test]
    fn flash_fuses_attention() {
        let reg = ModelRegistry::builtin();
        let m = reg.get("llama2-7b").unwrap();
        let fused = stage_fwd_ops(m, &strat(m, 2, 1, 32, true), 0);
        let unfused = stage_fwd_ops(m, &strat(m, 2, 1, 32, false), 0);
        assert!(fused.iter().any(|o| o.kind == OpKind::AttnFused));
        assert!(unfused.iter().any(|o| o.kind == OpKind::AttnScore));
        // Same attention FLOPs either way.
        let f: f64 = fused
            .iter()
            .filter(|o| matches!(o.kind, OpKind::AttnFused))
            .map(|o| o.shape.flops * o.count)
            .sum();
        let u: f64 = unfused
            .iter()
            .filter(|o| matches!(o.kind, OpKind::AttnScore | OpKind::AttnContext))
            .map(|o| o.shape.flops * o.count)
            .sum();
        assert!((f - u).abs() / u < 1e-12);
        // Flash has far higher arithmetic intensity.
        let fi = fused.iter().find(|o| o.kind == OpKind::AttnFused).unwrap().shape.intensity();
        let ui = unfused.iter().find(|o| o.kind == OpKind::AttnScore).unwrap().shape.intensity();
        assert!(fi > 3.0 * ui);
    }

    #[test]
    fn tp_comm_only_when_tp() {
        let reg = ModelRegistry::builtin();
        let m = reg.get("llama2-7b").unwrap();
        let c1 = stage_comm(m, &strat(m, 1, 2, 32, true), 0);
        assert_eq!(c1.tp_ring_bytes, 0.0);
        assert!(c1.p2p_bytes > 0.0);
        let c2 = stage_comm(m, &strat(m, 4, 2, 8, true), 0);
        assert!(c2.tp_ring_bytes > 0.0);
        // Last stage has no outgoing p2p but one extra TP op (head gather).
        let c_last = stage_comm(m, &strat(m, 4, 2, 8, true), 1);
        assert_eq!(c_last.p2p_bytes, 0.0);
        assert!(c_last.tp_ops > c2.tp_ops);
    }
}
