//! Cost simulation (paper §3.5) — the analytic performance model.
//!
//! For every operator the time is `θ / (φ · η)` (Eq. 25/26): θ comes from
//! the operator census ([`ops`]), φ is the device peak (FLOPs or link
//! bandwidth), and η is the efficiency factor — predicted either by the
//! GBDT forests (the paper's XGBoost, [`EtaProvider::Forests`]) or taken
//! from the hardware-truth curves directly ([`EtaProvider::Analytic`]).
//!
//! Stage times compose into a step time with the paper's heterogeneous
//! pipeline formula (Eq. 22): `Σᵢ(tᵢ+hᵢ) + (K−1)·maxᵢ(tᵢ+hᵢ)`, applied to
//! forward and backward separately, plus data-parallel gradient
//! synchronization, optimizer step and offload traffic — each hidden
//! partially when the corresponding overlap flag is on.
//!
//! ## Memo architecture
//!
//! Within one search every strategy shares the model, so a stage's time is
//! fully determined by its [`StageKey`] (GPU types, layer count, tp/dp/mbs,
//! recompute and overlap flags) and the DP-sync/optimizer terms by a
//! [`SyncKey`] — tens of thousands of strategies collapse onto a few hundred
//! distinct profiles. Two memo layers exploit that:
//!
//! * [`CostMemo`] — the historical single-owner memo, still used by
//!   [`CostModel::evaluate_batch`] (standalone batch scoring in benches and
//!   tests) and by [`CostModel::evaluate`], which routes through the same
//!   [`CostModel::evaluate_memo`] path with a throwaway memo. The search
//!   pipeline itself always scores through the shared memo below.
//! * [`SharedCostMemo`] — a sharded, lock-striped concurrent memo owned by
//!   the coordinator's `ScoringCore` through a [`MemoRegistry`]. One memo
//!   is shared across worker chunks, across every round of the mode-2/3 and
//!   hetero-cost sweeps, and across service requests that hash to the same
//!   model scope — this is what makes repeat traffic sublinear in the
//!   candidates actually touched.
//!
//! **Where the batch kernel sits.** The level-synchronous flat-forest
//! kernel ([`crate::gbdt::FlatForest`]) lives strictly *behind* the memo:
//! [`CostModel::evaluate_pool_shared`] first probes the memo for every
//! `(strategy, stage)` of a pool, deduplicates the misses into a
//! first-seen-ordered pending list, and only that residue's η queries are
//! gathered and answered by one batched kernel call per η family — the
//! kernel only ever sees memo misses. Answers are memoized immediately, so
//! warm traffic never touches the kernel at all. Batch answers are
//! bit-identical to scalar [`EtaProvider::comp`]/[`EtaProvider::comm`]
//! calls (same features, casts and clamp; the flat kernel is bit-identical
//! to `Forest::predict` by construction), so memo values — and therefore
//! reports — do not depend on which path filled them. Only the hit/miss
//! *counters* can differ from a per-strategy interleaving; they are
//! observability, excluded from `report_json`.
//!
//! **Invalidation rules.** Everything strategy- or stage-shaped enters the
//! *key* (so it can never go stale); everything else is part of the memo's
//! *scope* and therefore decides which memo may be consulted at all:
//!
//! * key: GPU type per stage, layers/stage, tp, dp, mbs, ep, recompute
//!   variant, overlap flags, flash-attn (see [`StageKey`]/[`SyncKey`]);
//! * scope: the full `ModelSpec` (hashed by [`model_scope_key`]) — each
//!   distinct model gets its own [`SharedCostMemo`];
//! * fixed per `CostModel` lifetime: the GPU catalog, the η provider and
//!   [`CostConsts`]. These are immutable once a `ScoringCore` is built, so
//!   a registry owned by the core never needs to invalidate them; building
//!   a new core (new catalog / η source / consts) starts from empty memos.
//!
//! Hit/miss counters are surfaced per search in `SearchReport.memo_hits` /
//! `memo_misses` and benchmarked by `rust/benches/perf_search.rs`, which
//! writes `BENCH_search.json`: `cold` is a fresh-memo search, `warm` repeats
//! it against the populated memo, and `warm_restore` replays it on a fresh
//! engine restored from a spilled snapshot (the restart story below);
//! `memo_hit_rate` is hits/(hits+misses) and `strategies_per_sec` is
//! generated candidates over wall seconds. The `BENCH=1 ./ci.sh` lane fails
//! if the warm or restored hit-rate drops below its pinned floor.
//!
//! ## Warm-start snapshots ([`crate::persist`])
//!
//! Memos outlive the process: [`SharedCostMemo::export_rows`] drains the
//! stripe locks into sorted, flattened rows and
//! [`MemoRegistry::restore_scope`] imports them back, with the
//! line-delimited snapshot format owned by [`crate::persist`]. Because the
//! scope/key split above means a memo value is a pure function of its key
//! *given* the scope, a snapshot is safe to load exactly when every
//! scope-level input matches — which the persist layer enforces through a
//! scope header that is checked field-for-field before any row is imported
//! (mismatch ⇒ the scope is skipped and that model starts cold):
//!
//! | header field      | pins                                            |
//! |-------------------|-------------------------------------------------|
//! | `format`          | row-encoding version ([`crate::persist::FORMAT_VERSION`]) |
//! | `key`             | [`model_scope_key`] — the full `ModelSpec`      |
//! | `catalog`         | every `GpuSpec` field, order and topology (keys store catalog indices) |
//! | `eta`             | η source: `"analytic"` or a digest over every forest node |
//! | `consts`          | the [`CostConsts`] overlap/host-rate constants  |
//! | `book`            | the full price card incl. spot/time-of-day state |
//!
//! Values are serialized as IEEE-754 bit patterns and the footer carries a
//! row checksum, so a restored search is byte-identical to its cold
//! counterpart or the scope is rejected — never silently wrong.

pub mod features;
pub mod ops;

use crate::gbdt::EtaForests;
use crate::gpu::{GpuCatalog, GpuSpec, GpuType};
use crate::hw;
use crate::memory::MemoryModel;
use crate::model::ModelSpec;
use crate::strategy::{ParallelStrategy, Recompute};
use ops::{stage_comm, stage_fwd_ops};
use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Source of the η factors.
#[derive(Debug, Clone)]
pub enum EtaProvider {
    /// Hardware-truth curves (exact; the simulator's own physics).
    Analytic,
    /// Trained GBDT forests (the paper's deployed configuration).
    Forests(EtaForests),
}

/// One η_comp query — the arguments of [`EtaProvider::comp`] with the GPU
/// named by catalog index so queries can be gathered without holding a
/// `&GpuSpec` borrow.
#[derive(Debug, Clone, Copy)]
pub struct CompQuery {
    pub gpu: GpuType,
    pub flops: f64,
    pub min_dim: f64,
    pub intensity: f64,
}

/// One η_comm query — the arguments of [`EtaProvider::comm`].
#[derive(Debug, Clone, Copy)]
pub struct CommQuery {
    pub gpu: GpuType,
    pub bytes: f64,
    pub bw_gbs: f64,
    pub participants: f64,
}

/// Caller-owned scratch for the batched η path. Holds the gathered raw
/// queries, the packed f32 feature rows, the flat-kernel working buffers
/// and the η outputs — every allocation is amortized across
/// [`EtaProvider::comp_batch`] / [`EtaProvider::comm_batch`] calls (none
/// of them allocate per call once the buffers are warm).
#[derive(Debug, Default)]
pub struct EtaBatchScratch {
    /// Pending η_comp queries (filled by the gather pass).
    pub comp: Vec<CompQuery>,
    /// Pending η_comm queries (filled by the gather pass).
    pub comm: Vec<CommQuery>,
    /// η answers for `comp`, index-aligned.
    comp_eta: Vec<f64>,
    /// η answers for `comm`, index-aligned.
    comm_eta: Vec<f64>,
    /// Packed f32 feature rows (forest path only).
    xs: Vec<f32>,
    /// Flat-kernel row state.
    flat: crate::gbdt::FlatScratch,
    /// Raw f32 forest predictions before the clamp.
    pred: Vec<f32>,
}

impl EtaBatchScratch {
    /// Drop all pending queries and answers (buffers keep their capacity).
    pub fn clear(&mut self) {
        self.comp.clear();
        self.comm.clear();
        self.comp_eta.clear();
        self.comm_eta.clear();
    }

    /// η answers for the gathered comp queries, index-aligned with
    /// [`Self::comp`]. Valid after [`EtaProvider::comp_batch`].
    pub fn comp_eta(&self) -> &[f64] {
        &self.comp_eta
    }

    /// η answers for the gathered comm queries, index-aligned with
    /// [`Self::comm`]. Valid after [`EtaProvider::comm_batch`].
    pub fn comm_eta(&self) -> &[f64] {
        &self.comm_eta
    }
}

impl EtaProvider {
    pub fn comp(&self, spec: &GpuSpec, flops: f64, min_dim: f64, intensity: f64) -> f64 {
        match self {
            EtaProvider::Analytic => hw::eta_comp(spec, flops, min_dim, intensity),
            EtaProvider::Forests(f) => {
                let feats = hw::comp_features(spec, flops, min_dim, intensity);
                let mut x = [0.0f32; hw::COMP_FEATURES];
                for (o, &v) in x.iter_mut().zip(feats.iter()) {
                    *o = v as f32;
                }
                f.eta_comp(&x)
            }
        }
    }

    pub fn comm(&self, spec: &GpuSpec, bytes: f64, bw_gbs: f64, participants: f64) -> f64 {
        match self {
            EtaProvider::Analytic => hw::eta_comm(spec, bytes, bw_gbs, participants),
            EtaProvider::Forests(f) => {
                let feats = hw::comm_features(spec, bytes, bw_gbs, participants);
                let mut x = [0.0f32; hw::COMM_FEATURES];
                for (o, &v) in x.iter_mut().zip(feats.iter()) {
                    *o = v as f32;
                }
                f.eta_comm(&x)
            }
        }
    }

    /// Answer every query in `scratch.comp` into `scratch.comp_eta()`,
    /// index-aligned. For [`EtaProvider::Forests`] this packs all feature
    /// rows and runs *one* level-synchronous flat-kernel call; for
    /// [`EtaProvider::Analytic`] it loops the closed-form curve. Either
    /// way each answer is bit-identical to the corresponding
    /// [`EtaProvider::comp`] call (same feature math, same f64→f32 cast,
    /// same clamp; the flat kernel is bit-identical to `Forest::predict`).
    pub fn comp_batch(&self, catalog: &GpuCatalog, scratch: &mut EtaBatchScratch) {
        scratch.comp_eta.clear();
        match self {
            EtaProvider::Analytic => {
                for q in &scratch.comp {
                    scratch.comp_eta.push(hw::eta_comp(
                        catalog.spec(q.gpu),
                        q.flops,
                        q.min_dim,
                        q.intensity,
                    ));
                }
            }
            EtaProvider::Forests(f) => {
                scratch.xs.clear();
                for q in &scratch.comp {
                    hw::comp_features_into(
                        catalog.spec(q.gpu),
                        q.flops,
                        q.min_dim,
                        q.intensity,
                        &mut scratch.xs,
                    );
                }
                f.eta_comp_batch(
                    &scratch.xs,
                    hw::COMP_FEATURES,
                    &mut scratch.flat,
                    &mut scratch.pred,
                    &mut scratch.comp_eta,
                );
            }
        }
    }

    /// Answer every query in `scratch.comm` into `scratch.comm_eta()`;
    /// see [`Self::comp_batch`].
    pub fn comm_batch(&self, catalog: &GpuCatalog, scratch: &mut EtaBatchScratch) {
        scratch.comm_eta.clear();
        match self {
            EtaProvider::Analytic => {
                for q in &scratch.comm {
                    scratch.comm_eta.push(hw::eta_comm(
                        catalog.spec(q.gpu),
                        q.bytes,
                        q.bw_gbs,
                        q.participants,
                    ));
                }
            }
            EtaProvider::Forests(f) => {
                scratch.xs.clear();
                for q in &scratch.comm {
                    hw::comm_features_into(
                        catalog.spec(q.gpu),
                        q.bytes,
                        q.bw_gbs,
                        q.participants,
                        &mut scratch.xs,
                    );
                }
                f.eta_comm_batch(
                    &scratch.xs,
                    hw::COMM_FEATURES,
                    &mut scratch.flat,
                    &mut scratch.pred,
                    &mut scratch.comm_eta,
                );
            }
        }
    }
}

/// Tunable constants of the composition model (overlap hiding fractions,
/// host-side rates). Shared semantics with the discrete-event simulator.
#[derive(Debug, Clone)]
pub struct CostConsts {
    /// Fraction of p2p time hidden by `--overlap-p2p-communication`.
    pub p2p_hide: f64,
    /// Fraction of DP gradient-reduce hidden by `--overlap-grad-reduce`.
    pub grad_reduce_hide: f64,
    /// Fraction of param all-gather hidden by `--overlap-param-gather`.
    pub param_gather_hide: f64,
    /// Fraction of TP collective time hidden by `--tp-comm-overlap`.
    pub tp_hide: f64,
    /// Bytes read+written per parameter by the fused Adam kernel.
    pub adam_bytes_per_param: f64,
    /// Host DDR bandwidth for the offloaded optimizer (GB/s).
    pub host_ddr_gbs: f64,
    /// Fraction of offload traffic hidden when offload overlap is on.
    pub offload_hide: f64,
}

impl Default for CostConsts {
    fn default() -> Self {
        CostConsts {
            p2p_hide: 0.7,
            grad_reduce_hide: 0.8,
            param_gather_hide: 0.8,
            tp_hide: 0.3,
            adam_bytes_per_param: 20.0,
            host_ddr_gbs: 50.0,
            offload_hide: 0.6,
        }
    }
}

/// Per-stage times (seconds, per microbatch).
#[derive(Debug, Clone, Copy, Default)]
pub struct StageTime {
    /// Forward compute + exposed TP comm.
    pub fwd: f64,
    /// Backward compute (incl. recompute) + exposed TP comm.
    pub bwd: f64,
    /// Exposed p2p hand-off to the next stage.
    pub p2p: f64,
}

/// Full cost decomposition of a strategy (Eq. 27/28 result).
#[derive(Debug, Clone, Default)]
pub struct CostBreakdown {
    pub stage_times: Vec<StageTime>,
    pub pipeline_fwd: f64,
    pub pipeline_bwd: f64,
    /// Exposed data-parallel communication (grad reduce + param gather).
    pub dp_time: f64,
    pub optimizer_time: f64,
    pub offload_time: f64,
    /// Total step time (seconds).
    pub step_time: f64,
    /// Tokens per second over the whole cluster.
    pub tokens_per_s: f64,
    /// Model FLOPs utilization against the cluster's aggregate peak.
    pub mfu: f64,
}

/// The paper's Eq. 22 composition for one direction, with the interleaving
/// correction: `K·max + (Σ − max)/vpp` (identical to
/// `Σ + (K−1)·max` at `vpp = 1`).
pub fn pipeline_time(stage_total: &[f64], k: usize, vpp: usize) -> f64 {
    let sum: f64 = stage_total.iter().sum();
    let max = stage_total.iter().fold(0.0, |a: f64, &b| a.max(b));
    k as f64 * max + (sum - max) / vpp as f64
}

/// The analytic cost model.
#[derive(Debug, Clone)]
pub struct CostModel {
    pub catalog: GpuCatalog,
    pub eta: EtaProvider,
    pub consts: CostConsts,
}

/// Memo key for one pipeline stage's compute/comm profile. Within a single
/// search all strategies share the model, so the stage time is fully
/// determined by these fields — thousands of strategies collapse onto a few
/// hundred distinct profiles (EXPERIMENTS.md §Perf).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
struct StageKey {
    gpu: u16,
    next_gpu: u16, // u16::MAX when last stage
    layers: u16,
    is_last: bool,
    tp: u16,
    dp: u32, // p2p bandwidth depends on the tp·dp span
    mbs: u16,
    recompute: u8,
    rc_layers: u16,
    flash: bool,
    tp_ovl: bool,
    p2p_ovl: bool,
    ep: u16,
}

fn u16_of(x: u64) -> Option<u16> {
    u16::try_from(x).ok()
}

fn bool_of(x: u64) -> Option<bool> {
    match x {
        0 => Some(false),
        1 => Some(true),
        _ => None,
    }
}

impl StageKey {
    fn new(s: &ParallelStrategy, stage: usize) -> StageKey {
        StageKey {
            gpu: s.cluster.gpu_of_stage(stage) as u16,
            next_gpu: if stage + 1 < s.pp() {
                s.cluster.gpu_of_stage(stage + 1) as u16
            } else {
                u16::MAX
            },
            layers: s.cluster.layers_of_stage(stage) as u16,
            is_last: stage == s.pp() - 1,
            tp: s.tp as u16,
            dp: s.dp as u32,
            mbs: s.micro_batch as u16,
            recompute: s.recompute as u8,
            rc_layers: s.recompute_num_layers as u16,
            flash: s.use_flash_attn,
            tp_ovl: s.tp_comm_overlap,
            p2p_ovl: s.overlap_p2p,
            ep: s.ep as u16,
        }
    }

    /// Flattened snapshot form; the field order is part of the persist
    /// format version — changing it requires bumping
    /// `crate::persist::FORMAT_VERSION`.
    fn to_row(self) -> [u64; 13] {
        [
            self.gpu as u64,
            self.next_gpu as u64,
            self.layers as u64,
            self.is_last as u64,
            self.tp as u64,
            self.dp as u64,
            self.mbs as u64,
            self.recompute as u64,
            self.rc_layers as u64,
            self.flash as u64,
            self.tp_ovl as u64,
            self.p2p_ovl as u64,
            self.ep as u64,
        ]
    }

    /// Inverse of [`StageKey::to_row`]; `None` on any out-of-range field
    /// (restores reject the whole scope rather than guess).
    fn from_row(r: &[u64; 13]) -> Option<StageKey> {
        Some(StageKey {
            gpu: u16_of(r[0])?,
            next_gpu: u16_of(r[1])?,
            layers: u16_of(r[2])?,
            is_last: bool_of(r[3])?,
            tp: u16_of(r[4])?,
            dp: u32::try_from(r[5]).ok()?,
            mbs: u16_of(r[6])?,
            recompute: u8::try_from(r[7]).ok().filter(|&v| v <= 2)?,
            rc_layers: u16_of(r[8])?,
            flash: bool_of(r[9])?,
            tp_ovl: bool_of(r[10])?,
            p2p_ovl: bool_of(r[11])?,
            ep: u16_of(r[12])?,
        })
    }
}

/// Memo key for the DP-sync + optimizer terms (per strategy class).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
struct SyncKey {
    gpu: u16,
    layers: u16,
    is_first: bool,
    is_last: bool,
    tp: u16,
    dp: u32,
    dist_opt: bool,
    offload: bool,
    grad_ovl: bool,
    param_ovl: bool,
}

impl SyncKey {
    fn new(s: &ParallelStrategy, stage: usize) -> SyncKey {
        SyncKey {
            gpu: s.cluster.gpu_of_stage(stage) as u16,
            layers: s.cluster.layers_of_stage(stage) as u16,
            is_first: stage == 0,
            is_last: stage == s.pp() - 1,
            tp: s.tp as u16,
            dp: s.dp as u32,
            dist_opt: s.use_distributed_optimizer,
            offload: s.offload_optimizer,
            grad_ovl: s.overlap_grad_reduce,
            param_ovl: s.overlap_param_gather,
        }
    }

    /// Flattened snapshot form (see [`StageKey::to_row`]).
    fn to_row(self) -> [u64; 10] {
        [
            self.gpu as u64,
            self.layers as u64,
            self.is_first as u64,
            self.is_last as u64,
            self.tp as u64,
            self.dp as u64,
            self.dist_opt as u64,
            self.offload as u64,
            self.grad_ovl as u64,
            self.param_ovl as u64,
        ]
    }

    fn from_row(r: &[u64; 10]) -> Option<SyncKey> {
        Some(SyncKey {
            gpu: u16_of(r[0])?,
            layers: u16_of(r[1])?,
            is_first: bool_of(r[2])?,
            is_last: bool_of(r[3])?,
            tp: u16_of(r[4])?,
            dp: u32::try_from(r[5]).ok()?,
            dist_opt: bool_of(r[6])?,
            offload: bool_of(r[7])?,
            grad_ovl: bool_of(r[8])?,
            param_ovl: bool_of(r[9])?,
        })
    }
}

/// Flattened, order-stable dump of one memo's entries: key fields as raw
/// integers, values as IEEE-754 bit patterns (the persist layer's unit of
/// exchange — see [`crate::persist`] for the on-disk framing).
#[derive(Debug, Clone, Default)]
pub struct MemoRows {
    /// `(StageKey fields, (fwd, bwd, p2p) bit patterns)`.
    pub stages: Vec<([u64; 13], [u64; 3])>,
    /// `(SyncKey fields, (dp, opt, off) bit patterns)`.
    pub syncs: Vec<([u64; 10], [u64; 3])>,
}

impl MemoRows {
    pub fn len(&self) -> usize {
        self.stages.len() + self.syncs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.stages.is_empty() && self.syncs.is_empty()
    }

    /// Every row decodes to an in-range key. Restores check this before
    /// importing so a scope is taken whole or not at all.
    pub fn validate(&self) -> bool {
        self.stages.iter().all(|(k, _)| StageKey::from_row(k).is_some())
            && self.syncs.iter().all(|(k, _)| SyncKey::from_row(k).is_some())
    }
}

/// Per-batch memo for [`CostModel::evaluate_batch`].
#[derive(Default)]
pub struct CostMemo {
    stages: HashMap<StageKey, StageTime>,
    syncs: HashMap<SyncKey, (f64, f64, f64)>, // (dp, opt, off)
    pub hits: usize,
    pub misses: usize,
}

/// Deterministic FNV-1a [`Hasher`] for shard selection (the std
/// `DefaultHasher` is randomly seeded per process; shard choice never
/// affects results, but deterministic striping keeps perf reproducible).
struct FnvHasher(u64);

impl Hasher for FnvHasher {
    fn finish(&self) -> u64 {
        self.0
    }

    fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x100000001b3);
        }
    }
}

fn shard_of<K: Hash>(key: &K, shards: usize) -> usize {
    let mut h = FnvHasher(0xcbf29ce484222325);
    key.hash(&mut h);
    (h.finish() % shards as u64) as usize
}

/// Per-pass memo hit/miss accounting. Each worker accumulates its own
/// `MemoStats` locally (no atomics on the per-candidate path) and the
/// coordinator merges them; the [`SharedCostMemo`] additionally keeps
/// lifetime totals for cross-request observability.
#[derive(Debug, Clone, Copy, Default)]
pub struct MemoStats {
    pub hits: u64,
    pub misses: u64,
}

impl MemoStats {
    pub fn merge(&mut self, other: MemoStats) {
        self.hits += other.hits;
        self.misses += other.misses;
    }

    /// hits / (hits + misses); 0 when nothing was looked up.
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// Sharded, lock-striped concurrent memo for [`CostModel::evaluate_shared`].
///
/// Unlike the per-batch [`CostMemo`], one `SharedCostMemo` outlives a single
/// worker chunk: the coordinator reuses it across chunks, across all rounds
/// of a count sweep, and across service requests that share a model scope
/// (see the module docs for the key-vs-scope invalidation rules). Lookups
/// lock only the key's shard; misses compute *outside* the lock, so two
/// workers racing on the same key may both compute it — the values are pure
/// functions of the key within a scope, so the duplicate insert is
/// idempotent and results stay deterministic.
pub struct SharedCostMemo {
    stages: Vec<Mutex<HashMap<StageKey, StageTime>>>,
    syncs: Vec<Mutex<HashMap<SyncKey, (f64, f64, f64)>>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl Default for SharedCostMemo {
    fn default() -> Self {
        SharedCostMemo::new()
    }
}

impl SharedCostMemo {
    /// Default striping: enough shards that a full worker pool rarely
    /// collides (profiles cluster on a few hundred distinct keys).
    pub fn new() -> SharedCostMemo {
        SharedCostMemo::with_shards(64)
    }

    pub fn with_shards(shards: usize) -> SharedCostMemo {
        let shards = shards.max(1);
        SharedCostMemo {
            stages: (0..shards).map(|_| Mutex::new(HashMap::new())).collect(),
            syncs: (0..shards).map(|_| Mutex::new(HashMap::new())).collect(),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    fn get_stage(&self, key: &StageKey) -> Option<StageTime> {
        self.stages[shard_of(key, self.stages.len())].lock().unwrap().get(key).copied()
    }

    fn put_stage(&self, key: StageKey, val: StageTime) {
        self.stages[shard_of(&key, self.stages.len())].lock().unwrap().insert(key, val);
    }

    fn get_sync(&self, key: &SyncKey) -> Option<(f64, f64, f64)> {
        self.syncs[shard_of(key, self.syncs.len())].lock().unwrap().get(key).copied()
    }

    fn put_sync(&self, key: SyncKey, val: (f64, f64, f64)) {
        self.syncs[shard_of(&key, self.syncs.len())].lock().unwrap().insert(key, val);
    }

    /// Fold one pass's local counters into the lifetime totals. The
    /// per-memo atomics stay authoritative (tests isolate on them); the
    /// process-global registry is mirrored additionally so `{"cmd":"metrics"}`
    /// sees memo traffic from every scope at once.
    fn record(&self, stats: MemoStats) {
        if stats.hits > 0 {
            self.hits.fetch_add(stats.hits, Ordering::Relaxed);
            crate::telemetry::counter_macro!("astra_memo_hits_total").add(stats.hits);
        }
        if stats.misses > 0 {
            self.misses.fetch_add(stats.misses, Ordering::Relaxed);
            crate::telemetry::counter_macro!("astra_memo_misses_total").add(stats.misses);
        }
    }

    /// Lifetime hit count across every pass that used this memo.
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Lifetime miss count.
    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// Distinct stage profiles resident.
    pub fn stage_entries(&self) -> usize {
        self.stages.iter().map(|s| s.lock().unwrap().len()).sum()
    }

    /// Distinct sync profiles resident.
    pub fn sync_entries(&self) -> usize {
        self.syncs.iter().map(|s| s.lock().unwrap().len()).sum()
    }

    /// Drop every entry (counters are kept — they are lifetime totals).
    pub fn clear(&self) {
        for s in &self.stages {
            s.lock().unwrap().clear();
        }
        for s in &self.syncs {
            s.lock().unwrap().clear();
        }
    }

    /// Drain every resident entry into flattened rows for spilling. Each
    /// stripe lock is held only while its shard is cloned out; the sort
    /// (for a deterministic, diffable snapshot) runs outside all locks.
    /// Concurrent scoring may insert while this runs — the snapshot is a
    /// consistent-per-shard point-in-time view, which is all warm-start
    /// needs (a missed racing insert is just one future cold key).
    pub fn export_rows(&self) -> MemoRows {
        let mut rows = MemoRows::default();
        for shard in &self.stages {
            for (k, v) in shard.lock().unwrap().iter() {
                rows.stages.push((k.to_row(), [v.fwd.to_bits(), v.bwd.to_bits(), v.p2p.to_bits()]));
            }
        }
        for shard in &self.syncs {
            for (k, v) in shard.lock().unwrap().iter() {
                rows.syncs.push((k.to_row(), [v.0.to_bits(), v.1.to_bits(), v.2.to_bits()]));
            }
        }
        rows.stages.sort_unstable();
        rows.syncs.sort_unstable();
        rows
    }

    /// Import previously exported rows; returns how many were inserted.
    /// Values land bit-identical to what [`Self::export_rows`] drained, so
    /// a restored memo scores exactly like the one that was spilled.
    /// Malformed rows are skipped defensively — the persist layer validates
    /// ([`MemoRows::validate`]) and rejects whole scopes before calling in.
    pub fn import_rows(&self, rows: &MemoRows) -> usize {
        let mut n = 0;
        for (k, v) in &rows.stages {
            if let Some(key) = StageKey::from_row(k) {
                self.put_stage(
                    key,
                    StageTime {
                        fwd: f64::from_bits(v[0]),
                        bwd: f64::from_bits(v[1]),
                        p2p: f64::from_bits(v[2]),
                    },
                );
                n += 1;
            }
        }
        for (k, v) in &rows.syncs {
            if let Some(key) = SyncKey::from_row(k) {
                self.put_sync(
                    key,
                    (f64::from_bits(v[0]), f64::from_bits(v[1]), f64::from_bits(v[2])),
                );
                n += 1;
            }
        }
        n
    }
}

/// Scope key of a [`SharedCostMemo`]: the full model spec. Catalog, η and
/// cost constants are fixed per `CostModel` lifetime, so two searches may
/// share a memo exactly when their models hash equal under this key.
pub fn model_scope_key(m: &ModelSpec) -> u64 {
    let mut h = FnvHasher(0xcbf29ce484222325);
    h.write(m.name.as_bytes());
    for v in [
        m.layers,
        m.hidden,
        m.heads,
        m.kv_heads,
        m.ffn,
        m.vocab,
        m.seq_len,
        m.global_batch,
        m.num_experts,
        m.moe_topk,
    ] {
        h.write(&(v as u64).to_le_bytes());
    }
    h.finish()
}

/// Bounded registry of [`SharedCostMemo`]s keyed by [`model_scope_key`].
/// Owned by the coordinator's `ScoringCore`; service requests that share a
/// model scope get the same memo back and therefore score mostly warm.
/// Eviction is least-recently-used beyond `cap` (a logical clock, not wall
/// time, so behavior is deterministic for a fixed request sequence).
pub struct MemoRegistry {
    cap: usize,
    clock: AtomicU64,
    scopes: Mutex<Vec<(u64, u64, Arc<SharedCostMemo>)>>, // (key, last_use, memo)
    /// Hit/miss totals of scopes the LRU has evicted, folded in at
    /// eviction time so [`Self::counters`] is a true lifetime figure that
    /// never decreases between stats polls.
    evicted_hits: AtomicU64,
    evicted_misses: AtomicU64,
}

impl MemoRegistry {
    pub fn new(cap: usize) -> MemoRegistry {
        MemoRegistry {
            cap: cap.max(1),
            clock: AtomicU64::new(0),
            scopes: Mutex::new(Vec::new()),
            evicted_hits: AtomicU64::new(0),
            evicted_misses: AtomicU64::new(0),
        }
    }

    /// The memo for this model's scope, creating (and possibly evicting the
    /// least-recently-used scope) on first sight.
    pub fn for_model(&self, m: &ModelSpec) -> Arc<SharedCostMemo> {
        self.for_key(model_scope_key(m))
    }

    /// The memo for a raw scope key — the restore path, where the key comes
    /// from a snapshot header and no `ModelSpec` is in hand. Same
    /// get-or-create + LRU semantics as [`Self::for_model`].
    pub fn for_key(&self, key: u64) -> Arc<SharedCostMemo> {
        let now = self.clock.fetch_add(1, Ordering::Relaxed);
        let mut scopes = self.scopes.lock().unwrap();
        if let Some(entry) = scopes.iter_mut().find(|(k, _, _)| *k == key) {
            entry.1 = now;
            return entry.2.clone();
        }
        if scopes.len() >= self.cap {
            let mut oldest = 0usize;
            for (i, entry) in scopes.iter().enumerate() {
                if entry.1 < scopes[oldest].1 {
                    oldest = i;
                }
            }
            let (_, _, evicted) = scopes.swap_remove(oldest);
            self.evicted_hits.fetch_add(evicted.hits(), Ordering::Relaxed);
            self.evicted_misses.fetch_add(evicted.misses(), Ordering::Relaxed);
        }
        let memo = Arc::new(SharedCostMemo::new());
        scopes.push((key, now, memo.clone()));
        memo
    }

    /// Number of live scopes.
    pub fn scopes(&self) -> usize {
        self.scopes.lock().unwrap().len()
    }

    /// Every live scope `(key, memo)`, sorted by key so spills enumerate
    /// deterministically whatever the arrival order was.
    pub fn export_scopes(&self) -> Vec<(u64, Arc<SharedCostMemo>)> {
        self.export_scopes_with_recency().into_iter().map(|(k, _, m)| (k, m)).collect()
    }

    /// [`Self::export_scopes`] with each scope's LRU clock value
    /// (`last_use`): the byte-budgeted spill path drops least-recently-used
    /// scopes first, and the logical clock is the same deterministic
    /// recency order eviction uses. Sorted by key.
    pub fn export_scopes_with_recency(&self) -> Vec<(u64, u64, Arc<SharedCostMemo>)> {
        let scopes = self.scopes.lock().unwrap();
        let mut v: Vec<(u64, u64, Arc<SharedCostMemo>)> =
            scopes.iter().map(|(k, t, m)| (*k, *t, m.clone())).collect();
        drop(scopes);
        v.sort_unstable_by_key(|&(k, _, _)| k);
        v
    }

    /// Import spilled rows into a scope (created if absent, LRU-bumped if
    /// present — restoring into a live registry only ever *adds* warmth).
    /// Returns how many rows were inserted.
    pub fn restore_scope(&self, key: u64, rows: &MemoRows) -> usize {
        self.for_key(key).import_rows(rows)
    }

    /// Summed lifetime (hits, misses) over every scope ever registered —
    /// live scopes plus the folded-in totals of evicted ones, so the
    /// figure is monotone across stats polls.
    pub fn counters(&self) -> (u64, u64) {
        let scopes = self.scopes.lock().unwrap();
        scopes.iter().fold(
            (
                self.evicted_hits.load(Ordering::Relaxed),
                self.evicted_misses.load(Ordering::Relaxed),
            ),
            |(h, m), (_, _, memo)| (h + memo.hits(), m + memo.misses()),
        )
    }
}

impl CostModel {
    pub fn new(catalog: GpuCatalog, eta: EtaProvider) -> Self {
        CostModel { catalog, eta, consts: CostConsts::default() }
    }

    /// Per-microbatch forward/backward/p2p times of stage `i`.
    pub fn stage_time(&self, m: &ModelSpec, s: &ParallelStrategy, stage: usize) -> StageTime {
        self.stage_time_with(
            m,
            s,
            stage,
            &mut |g, flops, min_dim, intensity| {
                self.eta.comp(self.catalog.spec(g), flops, min_dim, intensity)
            },
            &mut |g, bytes, bw_gbs, parts| {
                self.eta.comm(self.catalog.spec(g), bytes, bw_gbs, parts)
            },
        )
    }

    /// [`Self::stage_time`] with the η source abstracted out. Both
    /// closures receive `(gpu, …)` with the exact argument tuples of
    /// [`EtaProvider::comp`] / [`EtaProvider::comm`], and are called in a
    /// deterministic order fixed by the operator census — which is what
    /// lets the batched path run this body twice (a *gather* pass whose
    /// closures record the queries, then a *compose* pass whose closures
    /// replay the batch-kernel answers in the same order) and land on
    /// bit-identical arithmetic.
    fn stage_time_with(
        &self,
        m: &ModelSpec,
        s: &ParallelStrategy,
        stage: usize,
        eta_comp: &mut dyn FnMut(GpuType, f64, f64, f64) -> f64,
        eta_comm: &mut dyn FnMut(GpuType, f64, f64, f64) -> f64,
    ) -> StageTime {
        let gpu = s.cluster.gpu_of_stage(stage);
        let spec = self.catalog.spec(gpu);
        let peak = spec.peak_flops();

        // --- computation ---
        let mut fwd_comp = 0.0;
        let mut attn_fwd = 0.0; // selective-recompute portion
        for op in stage_fwd_ops(m, s, stage) {
            let eta = eta_comp(gpu, op.shape.flops, op.shape.min_dim, op.shape.intensity());
            let t = op.count * op.shape.flops / (peak * eta);
            fwd_comp += t;
            if matches!(op.kind, ops::OpKind::AttnScore | ops::OpKind::AttnContext | ops::OpKind::AttnFused)
            {
                attn_fwd += t;
            }
        }
        // Backward GEMMs: dgrad + wgrad ≈ 2× forward work at the same shapes.
        let mut bwd_comp = 2.0 * fwd_comp;
        // Recomputation re-runs forward work before backward.
        match s.recompute {
            Recompute::Full => {
                let layers = s.cluster.layers_of_stage(stage) as f64;
                let frac = (s.recompute_num_layers as f64).min(layers) / layers.max(1.0);
                bwd_comp += frac * fwd_comp;
            }
            Recompute::Selective => {
                if !s.use_flash_attn {
                    bwd_comp += attn_fwd;
                }
            }
            Recompute::None => {}
        }

        // --- TP collectives ---
        let comm = stage_comm(m, s, stage);
        let mut tp_time = 0.0;
        if comm.tp_ops > 0.0 {
            let bw = self.catalog.group_bandwidth_gbs(gpu, s.tp) * 1e9;
            let eta = eta_comm(gpu, comm.tp_msg_bytes, bw / 1e9, s.tp as f64);
            tp_time = comm.tp_ring_bytes / (bw * eta);
            if s.tp_comm_overlap {
                tp_time *= 1.0 - self.consts.tp_hide;
            }
        }

        // --- MoE all-to-all (dispatch + combine over the EP group) ---
        let mut a2a_time = 0.0;
        if comm.a2a_ring_bytes > 0.0 {
            // EP ranks live inside the DP dimension: group spans tp·ep ranks.
            let bw = self.catalog.group_bandwidth_gbs(gpu, s.tp * s.ep);
            let eta = eta_comm(gpu, comm.a2a_msg_bytes, bw, s.ep as f64);
            a2a_time = comm.a2a_ring_bytes / (bw * 1e9 * eta);
        }

        // --- p2p ---
        let mut p2p = 0.0;
        if comm.p2p_bytes > 0.0 {
            let next_gpu = s.cluster.gpu_of_stage(stage + 1);
            let next_spec = self.catalog.spec(next_gpu);
            // Consecutive stages are tp·dp ranks apart: same node only for
            // tiny tp·dp; otherwise the inter-node fabric, limited by the
            // slower endpoint.
            let span = s.tp * s.dp;
            let bw_gbs = if span < self.catalog.gpus_per_node {
                spec.nvlink_gbs.min(next_spec.nvlink_gbs)
            } else {
                spec.internode_gbs.min(next_spec.internode_gbs)
            };
            let eta = eta_comm(gpu, comm.p2p_bytes, bw_gbs, 2.0);
            p2p = comm.p2p_bytes / (bw_gbs * 1e9 * eta);
            if s.overlap_p2p {
                p2p *= 1.0 - self.consts.p2p_hide;
            }
        }

        StageTime {
            fwd: fwd_comp + tp_time + a2a_time,
            bwd: bwd_comp + tp_time + a2a_time,
            p2p,
        }
    }

    /// Exposed data-parallel communication time (grad reduce + param
    /// gather), taking the max over stages (each dp group works its own
    /// stage shard concurrently).
    pub fn dp_time(&self, m: &ModelSpec, s: &ParallelStrategy, mem: &MemoryModel) -> f64 {
        (0..s.pp())
            .map(|stage| self.dp_stage_term(m, s, stage, mem))
            .fold(0.0, f64::max)
    }

    /// Optimizer step time (device Adam or offloaded host Adam + PCIe).
    pub fn optimizer_time(&self, m: &ModelSpec, s: &ParallelStrategy, mem: &MemoryModel) -> (f64, f64) {
        let mut opt_worst: f64 = 0.0;
        let mut off_worst: f64 = 0.0;
        for stage in 0..s.pp() {
            let (opt_t, off_t) = self.opt_stage_term(m, s, stage, mem);
            opt_worst = opt_worst.max(opt_t);
            off_worst = off_worst.max(off_t);
        }
        (opt_worst, off_worst)
    }

    /// Per-stage exposed DP communication (one term of [`Self::dp_time`]).
    fn dp_stage_term(&self, m: &ModelSpec, s: &ParallelStrategy, stage: usize, mem: &MemoryModel) -> f64 {
        if s.dp == 1 {
            return 0.0;
        }
        let d = s.dp as f64;
        let gpu = s.cluster.gpu_of_stage(stage);
        let spec = self.catalog.spec(gpu);
        let params = mem.stage_params(m, s, stage);
        let grad_bytes = params * 2.0;
        let bw_gbs = self.catalog.group_bandwidth_gbs(gpu, s.tp * s.dp);
        let eta = self.eta.comm(spec, grad_bytes, bw_gbs, d);
        let ring = 2.0 * grad_bytes * (d - 1.0) / d;
        let mut t = ring / (bw_gbs * 1e9 * eta);
        if s.overlap_grad_reduce {
            t *= 1.0 - self.consts.grad_reduce_hide;
        }
        if s.use_distributed_optimizer {
            let ag = params * 2.0 * (d - 1.0) / d;
            let mut tg = ag / (bw_gbs * 1e9 * eta);
            if s.overlap_param_gather {
                tg *= 1.0 - self.consts.param_gather_hide;
            }
            t += tg;
        }
        t
    }

    /// Per-stage optimizer/offload terms (one term of [`Self::optimizer_time`]).
    fn opt_stage_term(&self, m: &ModelSpec, s: &ParallelStrategy, stage: usize, mem: &MemoryModel) -> (f64, f64) {
        let gpu = s.cluster.gpu_of_stage(stage);
        let spec = self.catalog.spec(gpu);
        let params = mem.stage_params(m, s, stage);
        let shard = if s.use_distributed_optimizer { params / s.dp as f64 } else { params };
        if s.offload_optimizer {
            let pcie = spec.pcie_gbs * 1e9;
            let transfer = shard * (4.0 + 2.0) / pcie;
            let host = shard * self.consts.adam_bytes_per_param / (self.consts.host_ddr_gbs * 1e9);
            (0.0, (transfer + host) * (1.0 - self.consts.offload_hide))
        } else {
            (shard * self.consts.adam_bytes_per_param / (spec.hbm_gbs * 1e9), 0.0)
        }
    }

    /// Batch evaluation with per-batch memoization: strategies in one search
    /// share the model, so stage/sync profiles repeat massively (hundreds of
    /// distinct profiles across tens of thousands of strategies). This is
    /// the production scoring path used by the coordinator — ~20× faster
    /// than naive per-strategy evaluation with forest-η (see §Perf).
    pub fn evaluate_batch(&self, m: &ModelSpec, strategies: &[&ParallelStrategy]) -> Vec<CostBreakdown> {
        let mut memo = CostMemo::default();
        strategies.iter().map(|s| self.evaluate_memo(m, s, &mut memo)).collect()
    }

    /// Single evaluation against a caller-held memo.
    pub fn evaluate_memo(
        &self,
        m: &ModelSpec,
        s: &ParallelStrategy,
        memo: &mut CostMemo,
    ) -> CostBreakdown {
        let mem = MemoryModel::default();
        let pp = s.pp();
        let k = s.num_microbatches();

        let mut stage_times = Vec::with_capacity(pp);
        let mut dp_worst = 0.0f64;
        let mut opt_worst = 0.0f64;
        let mut off_worst = 0.0f64;
        for i in 0..pp {
            let skey = StageKey::new(s, i);
            let st = match memo.stages.get(&skey) {
                Some(st) => {
                    memo.hits += 1;
                    *st
                }
                None => {
                    memo.misses += 1;
                    let st = self.stage_time(m, s, i);
                    memo.stages.insert(skey, st);
                    st
                }
            };
            stage_times.push(st);

            let ykey = SyncKey::new(s, i);
            let (dp_t, opt_t, off_t) = match memo.syncs.get(&ykey) {
                Some(v) => {
                    memo.hits += 1;
                    *v
                }
                None => {
                    memo.misses += 1;
                    let dp_t = self.dp_stage_term(m, s, i, &mem);
                    let (opt_t, off_t) = self.opt_stage_term(m, s, i, &mem);
                    memo.syncs.insert(ykey, (dp_t, opt_t, off_t));
                    (dp_t, opt_t, off_t)
                }
            };
            dp_worst = dp_worst.max(dp_t);
            opt_worst = opt_worst.max(opt_t);
            off_worst = off_worst.max(off_t);
        }
        self.compose(m, s, k, stage_times, dp_worst, opt_worst, off_worst)
    }

    /// Single evaluation against a concurrent [`SharedCostMemo`], the
    /// coordinator's streaming scoring path. Hit/miss deltas land in the
    /// caller's local `stats` (merged into the search report) and in the
    /// memo's lifetime counters. Results are bit-identical to
    /// [`Self::evaluate`] / [`Self::evaluate_memo`]: the memo only caches
    /// values those paths would recompute.
    pub fn evaluate_shared(
        &self,
        m: &ModelSpec,
        s: &ParallelStrategy,
        memo: &SharedCostMemo,
        stats: &mut MemoStats,
    ) -> CostBreakdown {
        let mem = MemoryModel::default();
        let pp = s.pp();
        let k = s.num_microbatches();
        let mut local = MemoStats::default();

        let mut stage_times = Vec::with_capacity(pp);
        let mut dp_worst = 0.0f64;
        let mut opt_worst = 0.0f64;
        let mut off_worst = 0.0f64;
        for i in 0..pp {
            let skey = StageKey::new(s, i);
            let st = match memo.get_stage(&skey) {
                Some(st) => {
                    local.hits += 1;
                    st
                }
                None => {
                    local.misses += 1;
                    // Compute outside the shard lock; a racing duplicate
                    // insert writes the same value.
                    let st = self.stage_time(m, s, i);
                    memo.put_stage(skey, st);
                    st
                }
            };
            stage_times.push(st);

            let ykey = SyncKey::new(s, i);
            let (dp_t, opt_t, off_t) = match memo.get_sync(&ykey) {
                Some(v) => {
                    local.hits += 1;
                    v
                }
                None => {
                    local.misses += 1;
                    let dp_t = self.dp_stage_term(m, s, i, &mem);
                    let (opt_t, off_t) = self.opt_stage_term(m, s, i, &mem);
                    memo.put_sync(ykey, (dp_t, opt_t, off_t));
                    (dp_t, opt_t, off_t)
                }
            };
            dp_worst = dp_worst.max(dp_t);
            opt_worst = opt_worst.max(opt_t);
            off_worst = off_worst.max(off_t);
        }
        memo.record(local);
        stats.merge(local);
        self.compose(m, s, k, stage_times, dp_worst, opt_worst, off_worst)
    }

    /// Batched scoring of one pool's survivors against a shared memo —
    /// the executor's `batch_eta` path. Semantically identical to calling
    /// [`Self::evaluate_shared`] per strategy, but the stage profiles the
    /// memo does *not* already hold are scored through the level-synchronous
    /// flat-forest kernel in three passes instead of one η call at a time:
    ///
    /// 1. **lookup** — probe the memo per `(strategy, stage)`; deduplicate
    ///    the misses (a pool repeats a few hundred distinct profiles across
    ///    thousands of strategies) into a first-seen-ordered pending list.
    ///    Sync terms are computed inline (one comm-η call at most — not
    ///    worth batching).
    /// 2. **gather** — replay [`Self::stage_time_with`] over the pending
    ///    profiles with recording closures, accumulating every η query
    ///    into the caller's [`EtaBatchScratch`].
    /// 3. **solve + compose** — one [`EtaProvider::comp_batch`] and one
    ///    [`EtaProvider::comm_batch`] answer all queries (a single flat
    ///    kernel invocation each under [`EtaProvider::Forests`]); a second
    ///    `stage_time_with` replay consumes the answers in the same
    ///    deterministic order, yielding bit-identical [`StageTime`]s,
    ///    which are memoized and composed per strategy.
    ///
    /// Results are bit-identical to the scalar path; memo hit/miss
    /// *counters* may differ from a per-strategy interleaving (a profile
    /// seen `n` times in one pool counts 1 miss + `n−1` hits here), which
    /// is fine — counters are observability, excluded from `report_json`.
    pub fn evaluate_pool_shared(
        &self,
        m: &ModelSpec,
        strategies: &[ParallelStrategy],
        memo: &SharedCostMemo,
        stats: &mut MemoStats,
        scratch: &mut EtaBatchScratch,
    ) -> Vec<CostBreakdown> {
        let mem = MemoryModel::default();
        let mut local = MemoStats::default();

        // Pass 1: memo lookup + miss dedup. `Ok(st)` = resolved now,
        // `Err(j)` = pending profile `j` (filled by pass 3).
        let mut slots: Vec<Result<StageTime, usize>> = Vec::new();
        let mut strat_sync: Vec<(f64, f64, f64)> = Vec::with_capacity(strategies.len());
        let mut pending: Vec<(StageKey, usize, usize)> = Vec::new(); // (key, strat idx, stage)
        let mut pending_idx: HashMap<StageKey, usize> = HashMap::new();
        for (si, s) in strategies.iter().enumerate() {
            let pp = s.pp();
            let mut dp_worst = 0.0f64;
            let mut opt_worst = 0.0f64;
            let mut off_worst = 0.0f64;
            for i in 0..pp {
                let skey = StageKey::new(s, i);
                match memo.get_stage(&skey) {
                    Some(st) => {
                        local.hits += 1;
                        slots.push(Ok(st));
                    }
                    None => match pending_idx.get(&skey) {
                        Some(&j) => {
                            // Already queued this pool — the scalar path
                            // would have hit the memo here.
                            local.hits += 1;
                            slots.push(Err(j));
                        }
                        None => {
                            local.misses += 1;
                            let j = pending.len();
                            pending_idx.insert(skey, j);
                            pending.push((skey, si, i));
                            slots.push(Err(j));
                        }
                    },
                }

                let ykey = SyncKey::new(s, i);
                let (dp_t, opt_t, off_t) = match memo.get_sync(&ykey) {
                    Some(v) => {
                        local.hits += 1;
                        v
                    }
                    None => {
                        local.misses += 1;
                        let dp_t = self.dp_stage_term(m, s, i, &mem);
                        let (opt_t, off_t) = self.opt_stage_term(m, s, i, &mem);
                        memo.put_sync(ykey, (dp_t, opt_t, off_t));
                        (dp_t, opt_t, off_t)
                    }
                };
                dp_worst = dp_worst.max(dp_t);
                opt_worst = opt_worst.max(opt_t);
                off_worst = off_worst.max(off_t);
            }
            strat_sync.push((dp_worst, opt_worst, off_worst));
        }

        // Pass 2: gather every η query of the pending profiles, in the
        // deterministic per-profile order of `stage_time_with`.
        scratch.clear();
        for &(_, si, stage) in &pending {
            let s = &strategies[si];
            self.stage_time_with(
                m,
                s,
                stage,
                &mut |g, flops, min_dim, intensity| {
                    scratch.comp.push(CompQuery { gpu: g, flops, min_dim, intensity });
                    1.0 // placeholder; this pass's StageTime is discarded
                },
                &mut |g, bytes, bw_gbs, participants| {
                    scratch.comm.push(CommQuery { gpu: g, bytes, bw_gbs, participants });
                    1.0
                },
            );
        }

        // Pass 3: one batched kernel call per η family, then replay the
        // same order consuming the answers.
        self.eta.comp_batch(&self.catalog, scratch);
        self.eta.comm_batch(&self.catalog, scratch);
        let mut ci = 0usize;
        let mut mi = 0usize;
        let mut pending_vals: Vec<StageTime> = Vec::with_capacity(pending.len());
        for &(skey, si, stage) in &pending {
            let s = &strategies[si];
            let comp_eta = scratch.comp_eta();
            let comm_eta = scratch.comm_eta();
            let st = self.stage_time_with(
                m,
                s,
                stage,
                &mut |_, _, _, _| {
                    let v = comp_eta[ci];
                    ci += 1;
                    v
                },
                &mut |_, _, _, _| {
                    let v = comm_eta[mi];
                    mi += 1;
                    v
                },
            );
            // A racing worker may have inserted the same key meanwhile;
            // duplicate inserts write the same value (bit-identical by
            // construction), exactly like the scalar path's race note.
            memo.put_stage(skey, st);
            pending_vals.push(st);
        }
        debug_assert_eq!(ci, scratch.comp_eta().len());
        debug_assert_eq!(mi, scratch.comm_eta().len());

        // Compose per strategy from resolved + batch-filled slots.
        let mut out = Vec::with_capacity(strategies.len());
        let mut cursor = 0usize;
        for (si, s) in strategies.iter().enumerate() {
            let pp = s.pp();
            let k = s.num_microbatches();
            let stage_times: Vec<StageTime> = slots[cursor..cursor + pp]
                .iter()
                .map(|r| match r {
                    Ok(st) => *st,
                    Err(j) => pending_vals[*j],
                })
                .collect();
            cursor += pp;
            let (dp_worst, opt_worst, off_worst) = strat_sync[si];
            out.push(self.compose(m, s, k, stage_times, dp_worst, opt_worst, off_worst));
        }
        debug_assert_eq!(cursor, slots.len());

        memo.record(local);
        stats.merge(local);
        out
    }

    /// Shared composition tail of `evaluate`/`evaluate_memo`.
    #[allow(clippy::too_many_arguments)]
    fn compose(
        &self,
        m: &ModelSpec,
        s: &ParallelStrategy,
        k: usize,
        stage_times: Vec<StageTime>,
        dp_time: f64,
        optimizer_time: f64,
        offload_time: f64,
    ) -> CostBreakdown {
        let fwd_tot: Vec<f64> = stage_times.iter().map(|t| t.fwd + t.p2p).collect();
        let bwd_tot: Vec<f64> = stage_times.iter().map(|t| t.bwd + t.p2p).collect();
        let pipeline_fwd = pipeline_time(&fwd_tot, k, s.vpp);
        let pipeline_bwd = pipeline_time(&bwd_tot, k, s.vpp);
        let step_time = pipeline_fwd + pipeline_bwd + dp_time + optimizer_time + offload_time;
        let tokens = (s.global_batch * m.seq_len) as f64;
        let model_flops = 3.0 * ops::model_fwd_flops(m, s.global_batch);
        let agg_peak: f64 = s
            .cluster
            .gpus_by_type(s.tp, s.dp)
            .iter()
            .map(|(g, n)| self.catalog.spec(*g).peak_flops() * *n as f64)
            .sum();
        CostBreakdown {
            stage_times,
            pipeline_fwd,
            pipeline_bwd,
            dp_time,
            optimizer_time,
            offload_time,
            step_time,
            tokens_per_s: tokens / step_time,
            mfu: model_flops / (agg_peak * step_time),
        }
    }

    /// Evaluate the full step cost of a strategy (Eq. 27/28 + Eq. 22).
    /// Routed through [`Self::evaluate_memo`] with a throwaway memo so the
    /// single-strategy and batch paths share one compose implementation
    /// (they used to diverge in how stage/sync terms were gathered).
    pub fn evaluate(&self, m: &ModelSpec, s: &ParallelStrategy) -> CostBreakdown {
        self.evaluate_memo(m, s, &mut CostMemo::default())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelRegistry;
    use crate::strategy::{ClusterAssignment, RecomputeMethod, Segment};

    fn strat(m: &ModelSpec, tp: usize, pp: usize, dp: usize, mbs: usize) -> ParallelStrategy {
        ParallelStrategy {
            cluster: ClusterAssignment::homogeneous(1, pp, m.layers / pp),
            tp,
            dp,
            micro_batch: mbs,
            global_batch: m.global_batch,
            vpp: 1,
            sequence_parallel: tp > 1,
            use_distributed_optimizer: true,
            recompute: Recompute::None,
            recompute_method: RecomputeMethod::Uniform,
            recompute_num_layers: 0,
            offload_optimizer: false,
            overlap_grad_reduce: true,
            overlap_param_gather: true,
            overlap_p2p: true,
            tp_comm_overlap: true,
            use_flash_attn: true,
            ep: 1,
        }
    }

    fn cm() -> CostModel {
        CostModel::new(GpuCatalog::builtin(), EtaProvider::Analytic)
    }

    #[test]
    fn eq22_reduces_to_classic_formula() {
        // Homogeneous stages: Σ + (K-1)·max == K·t + (P-1)·t.
        let t = 0.01;
        let stages = vec![t; 8];
        let k = 32;
        let got = pipeline_time(&stages, k, 1);
        let expect = k as f64 * t + 7.0 * t;
        assert!((got - expect).abs() < 1e-12);
    }

    #[test]
    fn eq22_hetero_dominated_by_slowest() {
        let stages = vec![0.01, 0.05, 0.01, 0.01];
        let k = 100;
        let got = pipeline_time(&stages, k, 1);
        assert!(got > 100.0 * 0.05, "K·max dominates");
        assert!(got < 100.0 * 0.05 + 0.04, "fill/drain only adds Σ−max");
    }

    #[test]
    fn vpp_shrinks_bubble() {
        let stages = vec![0.01; 8];
        assert!(pipeline_time(&stages, 16, 4) < pipeline_time(&stages, 16, 1));
    }

    #[test]
    fn step_time_positive_and_mfu_sane() {
        let reg = ModelRegistry::builtin();
        let m = reg.get("llama2-7b").unwrap();
        let c = cm();
        let b = c.evaluate(m, &strat(m, 2, 4, 8, 2));
        assert!(b.step_time > 0.0);
        assert!(b.tokens_per_s > 0.0);
        assert!(b.mfu > 0.02 && b.mfu < 0.65, "mfu {:.3}", b.mfu);
    }

    #[test]
    fn h100_beats_a800() {
        let reg = ModelRegistry::builtin();
        let cat = GpuCatalog::builtin();
        let m = reg.get("llama2-7b").unwrap();
        let c = cm();
        let mut s = strat(m, 2, 4, 8, 2);
        s.cluster = ClusterAssignment::homogeneous(cat.find("a800").unwrap(), 4, m.layers / 4);
        let a = c.evaluate(m, &s);
        s.cluster = ClusterAssignment::homogeneous(cat.find("h100").unwrap(), 4, m.layers / 4);
        let h = c.evaluate(m, &s);
        assert!(h.tokens_per_s > 1.5 * a.tokens_per_s);
    }

    #[test]
    fn recompute_slows_backward() {
        let reg = ModelRegistry::builtin();
        let m = reg.get("llama2-7b").unwrap();
        let c = cm();
        let base = strat(m, 2, 4, 8, 2);
        let mut rc = base.clone();
        rc.recompute = Recompute::Full;
        rc.recompute_num_layers = m.layers / 4;
        let t0 = c.stage_time(m, &base, 1);
        let t1 = c.stage_time(m, &rc, 1);
        assert!(t1.bwd > t0.bwd * 1.2);
        assert!((t1.fwd - t0.fwd).abs() < 1e-9);
    }

    #[test]
    fn overlap_reduces_step_time() {
        let reg = ModelRegistry::builtin();
        let m = reg.get("llama2-13b").unwrap();
        let c = cm();
        let on = strat(m, 4, 2, 8, 2);
        let mut off = on.clone();
        off.overlap_grad_reduce = false;
        off.overlap_param_gather = false;
        off.overlap_p2p = false;
        off.tp_comm_overlap = false;
        let b_on = c.evaluate(m, &on);
        let b_off = c.evaluate(m, &off);
        assert!(b_on.step_time < b_off.step_time);
    }

    #[test]
    fn hetero_stage_times_reflect_gpu_speed() {
        let reg = ModelRegistry::builtin();
        let cat = GpuCatalog::builtin();
        let m = reg.get("llama2-7b").unwrap();
        let c = cm();
        let h100 = cat.find("h100").unwrap();
        let a800 = cat.find("a800").unwrap();
        let mut s = strat(m, 2, 4, 4, 1);
        s.cluster = ClusterAssignment {
            segments: vec![
                Segment { gpu: h100, stages: 2, layers_per_stage: 8 },
                Segment { gpu: a800, stages: 2, layers_per_stage: 8 },
            ],
        };
        let t_h = c.stage_time(m, &s, 0);
        let t_a = c.stage_time(m, &s, 2);
        assert!(t_a.fwd > 1.5 * t_h.fwd, "a800 stage slower: {} vs {}", t_a.fwd, t_h.fwd);
    }

    #[test]
    fn dp_time_zero_without_dp() {
        let reg = ModelRegistry::builtin();
        let m = reg.get("llama2-7b").unwrap();
        let c = cm();
        let s = strat(m, 8, 4, 1, 1);
        assert_eq!(c.dp_time(m, &s, &MemoryModel::default()), 0.0);
    }

    #[test]
    fn offload_charges_time() {
        let reg = ModelRegistry::builtin();
        let m = reg.get("llama2-13b").unwrap();
        let c = cm();
        let mut s = strat(m, 4, 2, 8, 1);
        s.offload_optimizer = true;
        let (opt, off) = c.optimizer_time(m, &s, &MemoryModel::default());
        assert_eq!(opt, 0.0);
        assert!(off > 0.0);
    }

    #[test]
    fn memoized_batch_matches_direct() {
        use crate::strategy::{SearchSpace, SpaceConfig};
        let reg = ModelRegistry::builtin();
        let cat = GpuCatalog::builtin();
        let m = reg.get("llama2-13b").unwrap();
        let c = cm();
        let space = SearchSpace::new(SpaceConfig::default());
        let strategies: Vec<_> = space
            .homogeneous(m, &cat, 1, 128)
            .into_iter()
            .step_by(23)
            .take(200)
            .collect();
        let refs: Vec<&ParallelStrategy> = strategies.iter().collect();
        let batch = c.evaluate_batch(m, &refs);
        for (s, b) in strategies.iter().zip(&batch) {
            let direct = c.evaluate(m, s);
            assert!(
                (direct.step_time - b.step_time).abs() / direct.step_time < 1e-12,
                "memo diverged on {}: {} vs {}",
                s.summary(),
                direct.step_time,
                b.step_time
            );
        }
    }

    #[test]
    fn memo_actually_hits() {
        use crate::strategy::{SearchSpace, SpaceConfig};
        let reg = ModelRegistry::builtin();
        let cat = GpuCatalog::builtin();
        let m = reg.get("llama2-7b").unwrap();
        let c = cm();
        let space = SearchSpace::new(SpaceConfig::default());
        let strategies: Vec<_> = space.homogeneous(m, &cat, 1, 64).into_iter().take(500).collect();
        let mut memo = CostMemo::default();
        for s in &strategies {
            c.evaluate_memo(m, s, &mut memo);
        }
        assert!(
            memo.hits > 4 * memo.misses,
            "memo ineffective: {} hits vs {} misses",
            memo.hits,
            memo.misses
        );
    }

    #[test]
    fn shared_memo_matches_direct_exactly() {
        use crate::strategy::{SearchSpace, SpaceConfig};
        let reg = ModelRegistry::builtin();
        let cat = GpuCatalog::builtin();
        let m = reg.get("llama2-13b").unwrap();
        let c = cm();
        let space = SearchSpace::new(SpaceConfig::default());
        let strategies: Vec<_> = space
            .homogeneous(m, &cat, 1, 128)
            .into_iter()
            .step_by(31)
            .take(150)
            .collect();
        let memo = SharedCostMemo::new();
        let mut stats = MemoStats::default();
        for s in &strategies {
            let shared = c.evaluate_shared(m, s, &memo, &mut stats);
            let direct = c.evaluate(m, s);
            // Bit-identical, not approximately equal: the memo only caches
            // values the direct path computes with the same code.
            assert_eq!(
                direct.step_time.to_bits(),
                shared.step_time.to_bits(),
                "shared memo diverged on {}",
                s.summary()
            );
            assert_eq!(direct.tokens_per_s.to_bits(), shared.tokens_per_s.to_bits());
            assert_eq!(direct.mfu.to_bits(), shared.mfu.to_bits());
        }
        assert_eq!(stats.hits, memo.hits());
        assert_eq!(stats.misses, memo.misses());
        assert!(stats.hits > stats.misses, "shared memo ineffective: {stats:?}");
        assert!(memo.stage_entries() > 0 && memo.sync_entries() > 0);
    }

    #[test]
    fn shared_memo_warm_reuse_is_all_hits() {
        use crate::strategy::{SearchSpace, SpaceConfig};
        let reg = ModelRegistry::builtin();
        let cat = GpuCatalog::builtin();
        let m = reg.get("llama2-7b").unwrap();
        let c = cm();
        let space = SearchSpace::new(SpaceConfig::default());
        let strategies: Vec<_> =
            space.homogeneous(m, &cat, 1, 64).into_iter().take(300).collect();
        let memo = SharedCostMemo::new();
        let mut cold = MemoStats::default();
        for s in &strategies {
            c.evaluate_shared(m, s, &memo, &mut cold);
        }
        let mut warm = MemoStats::default();
        for s in &strategies {
            c.evaluate_shared(m, s, &memo, &mut warm);
        }
        assert_eq!(warm.misses, 0, "second pass must be fully warm");
        assert!((warm.hit_rate() - 1.0).abs() < 1e-12);
        assert!(cold.hit_rate() < 1.0);
        // clear() drops entries but keeps the lifetime counters.
        let (h, mi) = (memo.hits(), memo.misses());
        memo.clear();
        assert_eq!(memo.stage_entries() + memo.sync_entries(), 0);
        assert_eq!((memo.hits(), memo.misses()), (h, mi));
        let mut cleared = MemoStats::default();
        c.evaluate_shared(m, &strategies[0], &memo, &mut cleared);
        assert!(cleared.misses > 0, "cleared memo must miss again");
    }

    #[test]
    fn shared_memo_concurrent_access_is_consistent() {
        use crate::strategy::{SearchSpace, SpaceConfig};
        let reg = ModelRegistry::builtin();
        let cat = GpuCatalog::builtin();
        let m = reg.get("llama2-7b").unwrap();
        let c = cm();
        let space = SearchSpace::new(SpaceConfig::default());
        let strategies: Vec<_> =
            space.homogeneous(m, &cat, 1, 64).into_iter().take(400).collect();
        let expect: Vec<u64> =
            strategies.iter().map(|s| c.evaluate(m, s).step_time.to_bits()).collect();
        let memo = SharedCostMemo::with_shards(8);
        std::thread::scope(|scope| {
            for chunk in strategies.chunks(100) {
                let memo = &memo;
                let c = &c;
                scope.spawn(move || {
                    let mut stats = MemoStats::default();
                    for s in chunk {
                        c.evaluate_shared(m, s, memo, &mut stats);
                    }
                });
            }
        });
        // Post-race, every lookup is a hit and every value is unchanged.
        let mut stats = MemoStats::default();
        for (s, bits) in strategies.iter().zip(&expect) {
            let b = c.evaluate_shared(m, s, &memo, &mut stats);
            assert_eq!(b.step_time.to_bits(), *bits);
        }
        assert_eq!(stats.misses, 0);
    }

    #[test]
    fn memo_registry_scopes_by_model_and_evicts_lru() {
        let reg = ModelRegistry::builtin();
        let m7 = reg.get("llama2-7b").unwrap();
        let m13 = reg.get("llama2-13b").unwrap();
        let registry = MemoRegistry::new(2);
        let a = registry.for_model(m7);
        let b = registry.for_model(m7);
        assert!(Arc::ptr_eq(&a, &b), "same scope must share one memo");
        let c = registry.for_model(m13);
        assert!(!Arc::ptr_eq(&a, &c), "distinct models get distinct memos");
        assert_eq!(registry.scopes(), 2);
        // A model that differs only in global batch is a different scope.
        let mut m7b = m7.clone();
        m7b.global_batch *= 2;
        assert_ne!(model_scope_key(m7), model_scope_key(&m7b));
        // Put traffic on the m13 scope so its counters are nonzero, touch
        // m7, and let m7b evict m13: the registry's lifetime counters must
        // keep the evicted scope's totals (monotone across stats polls).
        let cost = cm();
        let mut stats = MemoStats::default();
        let s13 = strat(m13, 2, 4, 8, 2);
        cost.evaluate_shared(m13, &s13, &c, &mut stats);
        assert!(stats.misses > 0);
        registry.for_model(m7);
        let before = registry.counters();
        let _ = registry.for_model(&m7b);
        assert_eq!(registry.scopes(), 2);
        assert_eq!(registry.counters(), before, "eviction must not lose lifetime counters");
        let a2 = registry.for_model(m7);
        assert!(Arc::ptr_eq(&a, &a2), "recently-used scope must survive eviction");
    }

    #[test]
    fn export_import_rows_roundtrip_bit_exactly() {
        use crate::strategy::{SearchSpace, SpaceConfig};
        let reg = ModelRegistry::builtin();
        let cat = GpuCatalog::builtin();
        let m = reg.get("llama2-7b").unwrap();
        let c = cm();
        let space = SearchSpace::new(SpaceConfig::default());
        let strategies: Vec<_> =
            space.homogeneous(m, &cat, 1, 64).into_iter().take(300).collect();
        let memo = SharedCostMemo::new();
        let mut stats = MemoStats::default();
        for s in &strategies {
            c.evaluate_shared(m, s, &memo, &mut stats);
        }
        let rows = memo.export_rows();
        assert!(!rows.is_empty());
        assert!(rows.validate());
        assert_eq!(rows.stages.len(), memo.stage_entries());
        assert_eq!(rows.syncs.len(), memo.sync_entries());
        // Export is deterministic (sorted) regardless of shard layout.
        let memo2 = SharedCostMemo::with_shards(3);
        assert_eq!(memo2.import_rows(&rows), rows.len());
        assert_eq!(memo2.export_rows().stages, rows.stages);
        assert_eq!(memo2.export_rows().syncs, rows.syncs);
        // A restored memo scores every strategy without a single miss and
        // bit-identically to the original.
        let mut warm = MemoStats::default();
        for s in &strategies {
            let a = c.evaluate_shared(m, s, &memo2, &mut warm);
            let b = c.evaluate(m, s);
            assert_eq!(a.step_time.to_bits(), b.step_time.to_bits());
        }
        assert_eq!(warm.misses, 0, "restored memo must be fully warm");
    }

    #[test]
    fn malformed_rows_fail_validation_and_are_skipped() {
        let mut rows = MemoRows::default();
        rows.stages.push(([1, 2, 8, 1, 2, 4, 1, 0, 0, 1, 1, 1, 1], [0, 0, 0]));
        assert!(rows.validate());
        // bool field out of range.
        rows.stages.push(([1, 2, 8, 7, 2, 4, 1, 0, 0, 1, 1, 1, 1], [0, 0, 0]));
        assert!(!rows.validate());
        let mut bad = MemoRows::default();
        bad.syncs.push(([1, 8, 1, 0, 2, 4, 1, 0, 1, 2], [0, 0, 0]));
        assert!(!bad.validate());
        let memo = SharedCostMemo::new();
        assert_eq!(memo.import_rows(&rows), 1, "only the valid row imports");
    }

    #[test]
    fn registry_restores_by_raw_key() {
        let reg = ModelRegistry::builtin();
        let m = reg.get("llama2-7b").unwrap();
        let c = cm();
        let registry = MemoRegistry::new(4);
        let memo = registry.for_model(m);
        let mut stats = MemoStats::default();
        c.evaluate_shared(m, &strat(m, 2, 4, 8, 2), &memo, &mut stats);
        let key = model_scope_key(m);
        let scopes = registry.export_scopes();
        assert_eq!(scopes.len(), 1);
        assert_eq!(scopes[0].0, key);
        let rows = scopes[0].1.export_rows();
        let fresh = MemoRegistry::new(4);
        assert_eq!(fresh.restore_scope(key, &rows), rows.len());
        // for_model after restore finds the same (now-warm) scope.
        let restored = fresh.for_model(m);
        assert_eq!(restored.stage_entries(), memo.stage_entries());
        assert_eq!(fresh.scopes(), 1);
    }

    #[test]
    fn moe_all_to_all_costs_time_but_ep_saves_memory_pressure() {
        let reg = ModelRegistry::builtin();
        let m = reg.get("mixtral-8x7b").unwrap();
        let c = cm();
        let mut s = strat(m, 2, 2, 16, 1);
        s.ep = 1;
        let t1 = c.stage_time(m, &s, 0);
        s.ep = 8;
        let t8 = c.stage_time(m, &s, 0);
        // All-to-all is charged only when ep > 1.
        assert!(t8.fwd > t1.fwd, "a2a missing: ep8 {} vs ep1 {}", t8.fwd, t1.fwd);
        // MoE fwd is costlier than an equally-shaped dense model (top-2).
        let dense = reg.get("llama3-8b").unwrap(); // same h/ffn shape family
        let sd = strat(dense, 2, 2, 16, 1);
        let td = c.stage_time(dense, &sd, 0);
        assert!(t1.fwd > td.fwd);
    }

    /// Small deterministic η forests exercising the real kernel path
    /// (multiple trees, both feature widths).
    fn synthetic_forests() -> crate::gbdt::EtaForests {
        let mut rng = crate::prng::Rng::new(0x5eed_f0e5_7001);
        let mut forest = |n_features: usize| {
            let trees: Vec<crate::gbdt::Tree> = (0..24)
                .map(|_| {
                    let depth = 1 + rng.below(5) as usize;
                    let internal = (1usize << depth) - 1;
                    crate::gbdt::Tree {
                        depth,
                        feat: (0..internal).map(|_| rng.below(n_features as u64) as u32).collect(),
                        thresh: (0..internal).map(|_| rng.range_f64(-2.0, 12.0) as f32).collect(),
                        leaf: (0..1usize << depth)
                            .map(|_| rng.range_f64(0.05, 1.2) as f32)
                            .collect(),
                    }
                })
                .collect();
            Forest { trees, base: 0.3, lr: 0.05, n_features }
        };
        let comp = forest(hw::COMP_FEATURES);
        let comm = forest(hw::COMM_FEATURES);
        crate::gbdt::EtaForests::new(comp, comm)
    }

    #[test]
    fn pool_batch_matches_per_strategy_scoring() {
        let reg = ModelRegistry::builtin();
        let m = reg.get("llama2-7b").unwrap();
        for c in [cm(), CostModel::new(GpuCatalog::builtin(), EtaProvider::Forests(synthetic_forests()))] {
            let pool: Vec<ParallelStrategy> = [(1, 2, 16, 1), (2, 4, 8, 2), (4, 4, 4, 2), (2, 4, 8, 1)]
                .iter()
                .map(|&(tp, pp, dp, mbs)| strat(m, tp, pp, dp, mbs))
                .collect();

            // Reference: per-strategy scalar walk against its own memo.
            let memo_a = SharedCostMemo::default();
            let mut stats_a = MemoStats::default();
            let want: Vec<CostBreakdown> =
                pool.iter().map(|s| c.evaluate_shared(m, s, &memo_a, &mut stats_a)).collect();

            // Batched path, fresh memo (all misses go through the kernel).
            let memo_b = SharedCostMemo::default();
            let mut stats_b = MemoStats::default();
            let mut scratch = EtaBatchScratch::default();
            let got = c.evaluate_pool_shared(m, &pool, &memo_b, &mut stats_b, &mut scratch);

            assert_eq!(got.len(), want.len());
            for (g, w) in got.iter().zip(&want) {
                assert_eq!(g.step_time.to_bits(), w.step_time.to_bits());
                assert_eq!(g.tokens_per_s.to_bits(), w.tokens_per_s.to_bits());
                assert_eq!(g.mfu.to_bits(), w.mfu.to_bits());
                assert_eq!(g.stage_times.len(), w.stage_times.len());
                for (gs, ws) in g.stage_times.iter().zip(&w.stage_times) {
                    assert_eq!(gs.fwd.to_bits(), ws.fwd.to_bits());
                    assert_eq!(gs.bwd.to_bits(), ws.bwd.to_bits());
                    assert_eq!(gs.p2p.to_bits(), ws.p2p.to_bits());
                }
            }
            // Identical total probes (hit/miss split may differ — see the
            // method docs — but every (strategy, stage) probes twice).
            assert_eq!(stats_a.hits + stats_a.misses, stats_b.hits + stats_b.misses);

            // Warm repeat: everything hits, nothing pending, same bytes.
            let mut stats_w = MemoStats::default();
            let warm = c.evaluate_pool_shared(m, &pool, &memo_b, &mut stats_w, &mut scratch);
            assert_eq!(stats_w.misses, 0);
            for (g, w) in warm.iter().zip(&want) {
                assert_eq!(g.step_time.to_bits(), w.step_time.to_bits());
            }
        }
    }

    #[test]
    fn batched_eta_queries_match_scalar_calls() {
        let cat = GpuCatalog::builtin();
        let gpu = cat.find("a800").unwrap();
        let spec = cat.spec(gpu);
        for eta in [EtaProvider::Analytic, EtaProvider::Forests(synthetic_forests())] {
            let mut scratch = EtaBatchScratch::default();
            for i in 0..17u32 {
                let f = 1e9 * (i as f64 + 1.0);
                scratch.comp.push(CompQuery {
                    gpu,
                    flops: f,
                    min_dim: 64.0 * (i as f64 + 1.0),
                    intensity: 10.0 + i as f64,
                });
                scratch.comm.push(CommQuery {
                    gpu,
                    bytes: 1e6 * (i as f64 + 1.0),
                    bw_gbs: 200.0,
                    participants: 2.0 + i as f64,
                });
            }
            eta.comp_batch(&cat, &mut scratch);
            eta.comm_batch(&cat, &mut scratch);
            for i in 0..17usize {
                let q = scratch.comp[i];
                let want = eta.comp(spec, q.flops, q.min_dim, q.intensity);
                assert_eq!(scratch.comp_eta()[i].to_bits(), want.to_bits(), "comp {i}");
                let q = scratch.comm[i];
                let want = eta.comm(spec, q.bytes, q.bw_gbs, q.participants);
                assert_eq!(scratch.comm_eta()[i].to_bits(), want.to_bits(), "comm {i}");
            }
        }
    }
}
