//! Cost simulation (paper §3.5) — the analytic performance model.
//!
//! For every operator the time is `θ / (φ · η)` (Eq. 25/26): θ comes from
//! the operator census ([`ops`]), φ is the device peak (FLOPs or link
//! bandwidth), and η is the efficiency factor — predicted either by the
//! GBDT forests (the paper's XGBoost, [`EtaProvider::Forests`]) or taken
//! from the hardware-truth curves directly ([`EtaProvider::Analytic`]).
//!
//! Stage times compose into a step time with the paper's heterogeneous
//! pipeline formula (Eq. 22): `Σᵢ(tᵢ+hᵢ) + (K−1)·maxᵢ(tᵢ+hᵢ)`, applied to
//! forward and backward separately, plus data-parallel gradient
//! synchronization, optimizer step and offload traffic — each hidden
//! partially when the corresponding overlap flag is on.

pub mod features;
pub mod ops;

use crate::gbdt::EtaForests;
use crate::gpu::{GpuCatalog, GpuSpec};
use crate::hw;
use crate::memory::MemoryModel;
use crate::model::ModelSpec;
use crate::strategy::{ParallelStrategy, Recompute};
use ops::{stage_comm, stage_fwd_ops};

/// Source of the η factors.
#[derive(Debug, Clone)]
pub enum EtaProvider {
    /// Hardware-truth curves (exact; the simulator's own physics).
    Analytic,
    /// Trained GBDT forests (the paper's deployed configuration).
    Forests(EtaForests),
}

impl EtaProvider {
    pub fn comp(&self, spec: &GpuSpec, flops: f64, min_dim: f64, intensity: f64) -> f64 {
        match self {
            EtaProvider::Analytic => hw::eta_comp(spec, flops, min_dim, intensity),
            EtaProvider::Forests(f) => {
                let feats = hw::comp_features(spec, flops, min_dim, intensity);
                let x: Vec<f32> = feats.iter().map(|&v| v as f32).collect();
                f.eta_comp(&x)
            }
        }
    }

    pub fn comm(&self, spec: &GpuSpec, bytes: f64, bw_gbs: f64, participants: f64) -> f64 {
        match self {
            EtaProvider::Analytic => hw::eta_comm(spec, bytes, bw_gbs, participants),
            EtaProvider::Forests(f) => {
                let feats = hw::comm_features(spec, bytes, bw_gbs, participants);
                let x: Vec<f32> = feats.iter().map(|&v| v as f32).collect();
                f.eta_comm(&x)
            }
        }
    }
}

/// Tunable constants of the composition model (overlap hiding fractions,
/// host-side rates). Shared semantics with the discrete-event simulator.
#[derive(Debug, Clone)]
pub struct CostConsts {
    /// Fraction of p2p time hidden by `--overlap-p2p-communication`.
    pub p2p_hide: f64,
    /// Fraction of DP gradient-reduce hidden by `--overlap-grad-reduce`.
    pub grad_reduce_hide: f64,
    /// Fraction of param all-gather hidden by `--overlap-param-gather`.
    pub param_gather_hide: f64,
    /// Fraction of TP collective time hidden by `--tp-comm-overlap`.
    pub tp_hide: f64,
    /// Bytes read+written per parameter by the fused Adam kernel.
    pub adam_bytes_per_param: f64,
    /// Host DDR bandwidth for the offloaded optimizer (GB/s).
    pub host_ddr_gbs: f64,
    /// Fraction of offload traffic hidden when offload overlap is on.
    pub offload_hide: f64,
}

impl Default for CostConsts {
    fn default() -> Self {
        CostConsts {
            p2p_hide: 0.7,
            grad_reduce_hide: 0.8,
            param_gather_hide: 0.8,
            tp_hide: 0.3,
            adam_bytes_per_param: 20.0,
            host_ddr_gbs: 50.0,
            offload_hide: 0.6,
        }
    }
}

/// Per-stage times (seconds, per microbatch).
#[derive(Debug, Clone, Copy, Default)]
pub struct StageTime {
    /// Forward compute + exposed TP comm.
    pub fwd: f64,
    /// Backward compute (incl. recompute) + exposed TP comm.
    pub bwd: f64,
    /// Exposed p2p hand-off to the next stage.
    pub p2p: f64,
}

/// Full cost decomposition of a strategy (Eq. 27/28 result).
#[derive(Debug, Clone, Default)]
pub struct CostBreakdown {
    pub stage_times: Vec<StageTime>,
    pub pipeline_fwd: f64,
    pub pipeline_bwd: f64,
    /// Exposed data-parallel communication (grad reduce + param gather).
    pub dp_time: f64,
    pub optimizer_time: f64,
    pub offload_time: f64,
    /// Total step time (seconds).
    pub step_time: f64,
    /// Tokens per second over the whole cluster.
    pub tokens_per_s: f64,
    /// Model FLOPs utilization against the cluster's aggregate peak.
    pub mfu: f64,
}

/// The paper's Eq. 22 composition for one direction, with the interleaving
/// correction: `K·max + (Σ − max)/vpp` (identical to
/// `Σ + (K−1)·max` at `vpp = 1`).
pub fn pipeline_time(stage_total: &[f64], k: usize, vpp: usize) -> f64 {
    let sum: f64 = stage_total.iter().sum();
    let max = stage_total.iter().fold(0.0, |a: f64, &b| a.max(b));
    k as f64 * max + (sum - max) / vpp as f64
}

/// The analytic cost model.
#[derive(Debug, Clone)]
pub struct CostModel {
    pub catalog: GpuCatalog,
    pub eta: EtaProvider,
    pub consts: CostConsts,
}

/// Memo key for one pipeline stage's compute/comm profile. Within a single
/// search all strategies share the model, so the stage time is fully
/// determined by these fields — thousands of strategies collapse onto a few
/// hundred distinct profiles (EXPERIMENTS.md §Perf).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
struct StageKey {
    gpu: u16,
    next_gpu: u16, // u16::MAX when last stage
    layers: u16,
    is_last: bool,
    tp: u16,
    dp: u32, // p2p bandwidth depends on the tp·dp span
    mbs: u16,
    recompute: u8,
    rc_layers: u16,
    flash: bool,
    tp_ovl: bool,
    p2p_ovl: bool,
    ep: u16,
}

impl StageKey {
    fn new(s: &ParallelStrategy, stage: usize) -> StageKey {
        StageKey {
            gpu: s.cluster.gpu_of_stage(stage) as u16,
            next_gpu: if stage + 1 < s.pp() {
                s.cluster.gpu_of_stage(stage + 1) as u16
            } else {
                u16::MAX
            },
            layers: s.cluster.layers_of_stage(stage) as u16,
            is_last: stage == s.pp() - 1,
            tp: s.tp as u16,
            dp: s.dp as u32,
            mbs: s.micro_batch as u16,
            recompute: s.recompute as u8,
            rc_layers: s.recompute_num_layers as u16,
            flash: s.use_flash_attn,
            tp_ovl: s.tp_comm_overlap,
            p2p_ovl: s.overlap_p2p,
            ep: s.ep as u16,
        }
    }
}

/// Memo key for the DP-sync + optimizer terms (per strategy class).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
struct SyncKey {
    gpu: u16,
    layers: u16,
    is_first: bool,
    is_last: bool,
    tp: u16,
    dp: u32,
    dist_opt: bool,
    offload: bool,
    grad_ovl: bool,
    param_ovl: bool,
}

/// Per-batch memo for [`CostModel::evaluate_batch`].
#[derive(Default)]
pub struct CostMemo {
    stages: std::collections::HashMap<StageKey, StageTime>,
    syncs: std::collections::HashMap<SyncKey, (f64, f64, f64)>, // (dp, opt, off)
    pub hits: usize,
    pub misses: usize,
}

impl CostModel {
    pub fn new(catalog: GpuCatalog, eta: EtaProvider) -> Self {
        CostModel { catalog, eta, consts: CostConsts::default() }
    }

    /// Per-microbatch forward/backward/p2p times of stage `i`.
    pub fn stage_time(&self, m: &ModelSpec, s: &ParallelStrategy, stage: usize) -> StageTime {
        let gpu = s.cluster.gpu_of_stage(stage);
        let spec = self.catalog.spec(gpu);
        let peak = spec.peak_flops();

        // --- computation ---
        let mut fwd_comp = 0.0;
        let mut attn_fwd = 0.0; // selective-recompute portion
        for op in stage_fwd_ops(m, s, stage) {
            let eta = self.eta.comp(spec, op.shape.flops, op.shape.min_dim, op.shape.intensity());
            let t = op.count * op.shape.flops / (peak * eta);
            fwd_comp += t;
            if matches!(op.kind, ops::OpKind::AttnScore | ops::OpKind::AttnContext | ops::OpKind::AttnFused)
            {
                attn_fwd += t;
            }
        }
        // Backward GEMMs: dgrad + wgrad ≈ 2× forward work at the same shapes.
        let mut bwd_comp = 2.0 * fwd_comp;
        // Recomputation re-runs forward work before backward.
        match s.recompute {
            Recompute::Full => {
                let layers = s.cluster.layers_of_stage(stage) as f64;
                let frac = (s.recompute_num_layers as f64).min(layers) / layers.max(1.0);
                bwd_comp += frac * fwd_comp;
            }
            Recompute::Selective => {
                if !s.use_flash_attn {
                    bwd_comp += attn_fwd;
                }
            }
            Recompute::None => {}
        }

        // --- TP collectives ---
        let comm = stage_comm(m, s, stage);
        let mut tp_time = 0.0;
        if comm.tp_ops > 0.0 {
            let bw = self.catalog.group_bandwidth_gbs(gpu, s.tp) * 1e9;
            let eta = self.eta.comm(spec, comm.tp_msg_bytes, bw / 1e9, s.tp as f64);
            tp_time = comm.tp_ring_bytes / (bw * eta);
            if s.tp_comm_overlap {
                tp_time *= 1.0 - self.consts.tp_hide;
            }
        }

        // --- MoE all-to-all (dispatch + combine over the EP group) ---
        let mut a2a_time = 0.0;
        if comm.a2a_ring_bytes > 0.0 {
            // EP ranks live inside the DP dimension: group spans tp·ep ranks.
            let bw = self.catalog.group_bandwidth_gbs(gpu, s.tp * s.ep);
            let eta = self.eta.comm(spec, comm.a2a_msg_bytes, bw, s.ep as f64);
            a2a_time = comm.a2a_ring_bytes / (bw * 1e9 * eta);
        }

        // --- p2p ---
        let mut p2p = 0.0;
        if comm.p2p_bytes > 0.0 {
            let next_gpu = s.cluster.gpu_of_stage(stage + 1);
            let next_spec = self.catalog.spec(next_gpu);
            // Consecutive stages are tp·dp ranks apart: same node only for
            // tiny tp·dp; otherwise the inter-node fabric, limited by the
            // slower endpoint.
            let span = s.tp * s.dp;
            let bw_gbs = if span < self.catalog.gpus_per_node {
                spec.nvlink_gbs.min(next_spec.nvlink_gbs)
            } else {
                spec.internode_gbs.min(next_spec.internode_gbs)
            };
            let eta = self.eta.comm(spec, comm.p2p_bytes, bw_gbs, 2.0);
            p2p = comm.p2p_bytes / (bw_gbs * 1e9 * eta);
            if s.overlap_p2p {
                p2p *= 1.0 - self.consts.p2p_hide;
            }
        }

        StageTime {
            fwd: fwd_comp + tp_time + a2a_time,
            bwd: bwd_comp + tp_time + a2a_time,
            p2p,
        }
    }

    /// Exposed data-parallel communication time (grad reduce + param
    /// gather), taking the max over stages (each dp group works its own
    /// stage shard concurrently).
    pub fn dp_time(&self, m: &ModelSpec, s: &ParallelStrategy, mem: &MemoryModel) -> f64 {
        (0..s.pp())
            .map(|stage| self.dp_stage_term(m, s, stage, mem))
            .fold(0.0, f64::max)
    }

    /// Optimizer step time (device Adam or offloaded host Adam + PCIe).
    pub fn optimizer_time(&self, m: &ModelSpec, s: &ParallelStrategy, mem: &MemoryModel) -> (f64, f64) {
        let mut opt_worst: f64 = 0.0;
        let mut off_worst: f64 = 0.0;
        for stage in 0..s.pp() {
            let (opt_t, off_t) = self.opt_stage_term(m, s, stage, mem);
            opt_worst = opt_worst.max(opt_t);
            off_worst = off_worst.max(off_t);
        }
        (opt_worst, off_worst)
    }

    /// Per-stage exposed DP communication (one term of [`Self::dp_time`]).
    fn dp_stage_term(&self, m: &ModelSpec, s: &ParallelStrategy, stage: usize, mem: &MemoryModel) -> f64 {
        if s.dp == 1 {
            return 0.0;
        }
        let d = s.dp as f64;
        let gpu = s.cluster.gpu_of_stage(stage);
        let spec = self.catalog.spec(gpu);
        let params = mem.stage_params(m, s, stage);
        let grad_bytes = params * 2.0;
        let bw_gbs = self.catalog.group_bandwidth_gbs(gpu, s.tp * s.dp);
        let eta = self.eta.comm(spec, grad_bytes, bw_gbs, d);
        let ring = 2.0 * grad_bytes * (d - 1.0) / d;
        let mut t = ring / (bw_gbs * 1e9 * eta);
        if s.overlap_grad_reduce {
            t *= 1.0 - self.consts.grad_reduce_hide;
        }
        if s.use_distributed_optimizer {
            let ag = params * 2.0 * (d - 1.0) / d;
            let mut tg = ag / (bw_gbs * 1e9 * eta);
            if s.overlap_param_gather {
                tg *= 1.0 - self.consts.param_gather_hide;
            }
            t += tg;
        }
        t
    }

    /// Per-stage optimizer/offload terms (one term of [`Self::optimizer_time`]).
    fn opt_stage_term(&self, m: &ModelSpec, s: &ParallelStrategy, stage: usize, mem: &MemoryModel) -> (f64, f64) {
        let gpu = s.cluster.gpu_of_stage(stage);
        let spec = self.catalog.spec(gpu);
        let params = mem.stage_params(m, s, stage);
        let shard = if s.use_distributed_optimizer { params / s.dp as f64 } else { params };
        if s.offload_optimizer {
            let pcie = spec.pcie_gbs * 1e9;
            let transfer = shard * (4.0 + 2.0) / pcie;
            let host = shard * self.consts.adam_bytes_per_param / (self.consts.host_ddr_gbs * 1e9);
            (0.0, (transfer + host) * (1.0 - self.consts.offload_hide))
        } else {
            (shard * self.consts.adam_bytes_per_param / (spec.hbm_gbs * 1e9), 0.0)
        }
    }

    /// Batch evaluation with per-batch memoization: strategies in one search
    /// share the model, so stage/sync profiles repeat massively (hundreds of
    /// distinct profiles across tens of thousands of strategies). This is
    /// the production scoring path used by the coordinator — ~20× faster
    /// than naive per-strategy evaluation with forest-η (see §Perf).
    pub fn evaluate_batch(&self, m: &ModelSpec, strategies: &[&ParallelStrategy]) -> Vec<CostBreakdown> {
        let mut memo = CostMemo::default();
        strategies.iter().map(|s| self.evaluate_memo(m, s, &mut memo)).collect()
    }

    /// Single evaluation against a caller-held memo.
    pub fn evaluate_memo(
        &self,
        m: &ModelSpec,
        s: &ParallelStrategy,
        memo: &mut CostMemo,
    ) -> CostBreakdown {
        let mem = MemoryModel::default();
        let pp = s.pp();
        let k = s.num_microbatches();

        let mut stage_times = Vec::with_capacity(pp);
        let mut dp_worst = 0.0f64;
        let mut opt_worst = 0.0f64;
        let mut off_worst = 0.0f64;
        for i in 0..pp {
            let skey = StageKey::new(s, i);
            let st = match memo.stages.get(&skey) {
                Some(st) => {
                    memo.hits += 1;
                    *st
                }
                None => {
                    memo.misses += 1;
                    let st = self.stage_time(m, s, i);
                    memo.stages.insert(skey, st);
                    st
                }
            };
            stage_times.push(st);

            let ykey = SyncKey {
                gpu: s.cluster.gpu_of_stage(i) as u16,
                layers: s.cluster.layers_of_stage(i) as u16,
                is_first: i == 0,
                is_last: i == pp - 1,
                tp: s.tp as u16,
                dp: s.dp as u32,
                dist_opt: s.use_distributed_optimizer,
                offload: s.offload_optimizer,
                grad_ovl: s.overlap_grad_reduce,
                param_ovl: s.overlap_param_gather,
            };
            let (dp_t, opt_t, off_t) = match memo.syncs.get(&ykey) {
                Some(v) => {
                    memo.hits += 1;
                    *v
                }
                None => {
                    memo.misses += 1;
                    let dp_t = self.dp_stage_term(m, s, i, &mem);
                    let (opt_t, off_t) = self.opt_stage_term(m, s, i, &mem);
                    memo.syncs.insert(ykey, (dp_t, opt_t, off_t));
                    (dp_t, opt_t, off_t)
                }
            };
            dp_worst = dp_worst.max(dp_t);
            opt_worst = opt_worst.max(opt_t);
            off_worst = off_worst.max(off_t);
        }
        self.compose(m, s, k, stage_times, dp_worst, opt_worst, off_worst)
    }

    /// Shared composition tail of `evaluate`/`evaluate_memo`.
    #[allow(clippy::too_many_arguments)]
    fn compose(
        &self,
        m: &ModelSpec,
        s: &ParallelStrategy,
        k: usize,
        stage_times: Vec<StageTime>,
        dp_time: f64,
        optimizer_time: f64,
        offload_time: f64,
    ) -> CostBreakdown {
        let fwd_tot: Vec<f64> = stage_times.iter().map(|t| t.fwd + t.p2p).collect();
        let bwd_tot: Vec<f64> = stage_times.iter().map(|t| t.bwd + t.p2p).collect();
        let pipeline_fwd = pipeline_time(&fwd_tot, k, s.vpp);
        let pipeline_bwd = pipeline_time(&bwd_tot, k, s.vpp);
        let step_time = pipeline_fwd + pipeline_bwd + dp_time + optimizer_time + offload_time;
        let tokens = (s.global_batch * m.seq_len) as f64;
        let model_flops = 3.0 * ops::model_fwd_flops(m, s.global_batch);
        let agg_peak: f64 = s
            .cluster
            .gpus_by_type(s.tp, s.dp)
            .iter()
            .map(|(g, n)| self.catalog.spec(*g).peak_flops() * *n as f64)
            .sum();
        CostBreakdown {
            stage_times,
            pipeline_fwd,
            pipeline_bwd,
            dp_time,
            optimizer_time,
            offload_time,
            step_time,
            tokens_per_s: tokens / step_time,
            mfu: model_flops / (agg_peak * step_time),
        }
    }

    /// Evaluate the full step cost of a strategy (Eq. 27/28 + Eq. 22).
    pub fn evaluate(&self, m: &ModelSpec, s: &ParallelStrategy) -> CostBreakdown {
        let mem = MemoryModel::default();
        let pp = s.pp();
        let k = s.num_microbatches();

        let stage_times: Vec<StageTime> =
            (0..pp).map(|i| self.stage_time(m, s, i)).collect();
        let dp_time = self.dp_time(m, s, &mem);
        let (optimizer_time, offload_time) = self.optimizer_time(m, s, &mem);
        let _ = pp;
        self.compose(m, s, k, stage_times, dp_time, optimizer_time, offload_time)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelRegistry;
    use crate::strategy::{ClusterAssignment, RecomputeMethod, Segment};

    fn strat(m: &ModelSpec, tp: usize, pp: usize, dp: usize, mbs: usize) -> ParallelStrategy {
        ParallelStrategy {
            cluster: ClusterAssignment::homogeneous(1, pp, m.layers / pp),
            tp,
            dp,
            micro_batch: mbs,
            global_batch: m.global_batch,
            vpp: 1,
            sequence_parallel: tp > 1,
            use_distributed_optimizer: true,
            recompute: Recompute::None,
            recompute_method: RecomputeMethod::Uniform,
            recompute_num_layers: 0,
            offload_optimizer: false,
            overlap_grad_reduce: true,
            overlap_param_gather: true,
            overlap_p2p: true,
            tp_comm_overlap: true,
            use_flash_attn: true,
            ep: 1,
        }
    }

    fn cm() -> CostModel {
        CostModel::new(GpuCatalog::builtin(), EtaProvider::Analytic)
    }

    #[test]
    fn eq22_reduces_to_classic_formula() {
        // Homogeneous stages: Σ + (K-1)·max == K·t + (P-1)·t.
        let t = 0.01;
        let stages = vec![t; 8];
        let k = 32;
        let got = pipeline_time(&stages, k, 1);
        let expect = k as f64 * t + 7.0 * t;
        assert!((got - expect).abs() < 1e-12);
    }

    #[test]
    fn eq22_hetero_dominated_by_slowest() {
        let stages = vec![0.01, 0.05, 0.01, 0.01];
        let k = 100;
        let got = pipeline_time(&stages, k, 1);
        assert!(got > 100.0 * 0.05, "K·max dominates");
        assert!(got < 100.0 * 0.05 + 0.04, "fill/drain only adds Σ−max");
    }

    #[test]
    fn vpp_shrinks_bubble() {
        let stages = vec![0.01; 8];
        assert!(pipeline_time(&stages, 16, 4) < pipeline_time(&stages, 16, 1));
    }

    #[test]
    fn step_time_positive_and_mfu_sane() {
        let reg = ModelRegistry::builtin();
        let m = reg.get("llama2-7b").unwrap();
        let c = cm();
        let b = c.evaluate(m, &strat(m, 2, 4, 8, 2));
        assert!(b.step_time > 0.0);
        assert!(b.tokens_per_s > 0.0);
        assert!(b.mfu > 0.02 && b.mfu < 0.65, "mfu {:.3}", b.mfu);
    }

    #[test]
    fn h100_beats_a800() {
        let reg = ModelRegistry::builtin();
        let cat = GpuCatalog::builtin();
        let m = reg.get("llama2-7b").unwrap();
        let c = cm();
        let mut s = strat(m, 2, 4, 8, 2);
        s.cluster = ClusterAssignment::homogeneous(cat.find("a800").unwrap(), 4, m.layers / 4);
        let a = c.evaluate(m, &s);
        s.cluster = ClusterAssignment::homogeneous(cat.find("h100").unwrap(), 4, m.layers / 4);
        let h = c.evaluate(m, &s);
        assert!(h.tokens_per_s > 1.5 * a.tokens_per_s);
    }

    #[test]
    fn recompute_slows_backward() {
        let reg = ModelRegistry::builtin();
        let m = reg.get("llama2-7b").unwrap();
        let c = cm();
        let base = strat(m, 2, 4, 8, 2);
        let mut rc = base.clone();
        rc.recompute = Recompute::Full;
        rc.recompute_num_layers = m.layers / 4;
        let t0 = c.stage_time(m, &base, 1);
        let t1 = c.stage_time(m, &rc, 1);
        assert!(t1.bwd > t0.bwd * 1.2);
        assert!((t1.fwd - t0.fwd).abs() < 1e-9);
    }

    #[test]
    fn overlap_reduces_step_time() {
        let reg = ModelRegistry::builtin();
        let m = reg.get("llama2-13b").unwrap();
        let c = cm();
        let on = strat(m, 4, 2, 8, 2);
        let mut off = on.clone();
        off.overlap_grad_reduce = false;
        off.overlap_param_gather = false;
        off.overlap_p2p = false;
        off.tp_comm_overlap = false;
        let b_on = c.evaluate(m, &on);
        let b_off = c.evaluate(m, &off);
        assert!(b_on.step_time < b_off.step_time);
    }

    #[test]
    fn hetero_stage_times_reflect_gpu_speed() {
        let reg = ModelRegistry::builtin();
        let cat = GpuCatalog::builtin();
        let m = reg.get("llama2-7b").unwrap();
        let c = cm();
        let h100 = cat.find("h100").unwrap();
        let a800 = cat.find("a800").unwrap();
        let mut s = strat(m, 2, 4, 4, 1);
        s.cluster = ClusterAssignment {
            segments: vec![
                Segment { gpu: h100, stages: 2, layers_per_stage: 8 },
                Segment { gpu: a800, stages: 2, layers_per_stage: 8 },
            ],
        };
        let t_h = c.stage_time(m, &s, 0);
        let t_a = c.stage_time(m, &s, 2);
        assert!(t_a.fwd > 1.5 * t_h.fwd, "a800 stage slower: {} vs {}", t_a.fwd, t_h.fwd);
    }

    #[test]
    fn dp_time_zero_without_dp() {
        let reg = ModelRegistry::builtin();
        let m = reg.get("llama2-7b").unwrap();
        let c = cm();
        let s = strat(m, 8, 4, 1, 1);
        assert_eq!(c.dp_time(m, &s, &MemoryModel::default()), 0.0);
    }

    #[test]
    fn offload_charges_time() {
        let reg = ModelRegistry::builtin();
        let m = reg.get("llama2-13b").unwrap();
        let c = cm();
        let mut s = strat(m, 4, 2, 8, 1);
        s.offload_optimizer = true;
        let (opt, off) = c.optimizer_time(m, &s, &MemoryModel::default());
        assert_eq!(opt, 0.0);
        assert!(off > 0.0);
    }

    #[test]
    fn memoized_batch_matches_direct() {
        use crate::strategy::{SearchSpace, SpaceConfig};
        let reg = ModelRegistry::builtin();
        let cat = GpuCatalog::builtin();
        let m = reg.get("llama2-13b").unwrap();
        let c = cm();
        let space = SearchSpace::new(SpaceConfig::default());
        let strategies: Vec<_> = space
            .homogeneous(m, &cat, 1, 128)
            .into_iter()
            .step_by(23)
            .take(200)
            .collect();
        let refs: Vec<&ParallelStrategy> = strategies.iter().collect();
        let batch = c.evaluate_batch(m, &refs);
        for (s, b) in strategies.iter().zip(&batch) {
            let direct = c.evaluate(m, s);
            assert!(
                (direct.step_time - b.step_time).abs() / direct.step_time < 1e-12,
                "memo diverged on {}: {} vs {}",
                s.summary(),
                direct.step_time,
                b.step_time
            );
        }
    }

    #[test]
    fn memo_actually_hits() {
        use crate::strategy::{SearchSpace, SpaceConfig};
        let reg = ModelRegistry::builtin();
        let cat = GpuCatalog::builtin();
        let m = reg.get("llama2-7b").unwrap();
        let c = cm();
        let space = SearchSpace::new(SpaceConfig::default());
        let strategies: Vec<_> = space.homogeneous(m, &cat, 1, 64).into_iter().take(500).collect();
        let mut memo = CostMemo::default();
        for s in &strategies {
            c.evaluate_memo(m, s, &mut memo);
        }
        assert!(
            memo.hits > 4 * memo.misses,
            "memo ineffective: {} hits vs {} misses",
            memo.hits,
            memo.misses
        );
    }

    #[test]
    fn moe_all_to_all_costs_time_but_ep_saves_memory_pressure() {
        let reg = ModelRegistry::builtin();
        let m = reg.get("mixtral-8x7b").unwrap();
        let c = cm();
        let mut s = strat(m, 2, 2, 16, 1);
        s.ep = 1;
        let t1 = c.stage_time(m, &s, 0);
        s.ep = 8;
        let t8 = c.stage_time(m, &s, 0);
        // All-to-all is charged only when ep > 1.
        assert!(t8.fwd > t1.fwd, "a2a missing: ep8 {} vs ep1 {}", t8.fwd, t1.fwd);
        // MoE fwd is costlier than an equally-shaped dense model (top-2).
        let dense = reg.get("llama3-8b").unwrap(); // same h/ffn shape family
        let sd = strat(dense, 2, 2, 16, 1);
        let td = c.stage_time(dense, &sd, 0);
        assert!(t1.fwd > td.fwd);
    }
}
