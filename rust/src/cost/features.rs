//! Feature packing for the AOT scorer (the Layer-2/Layer-1 contract).
//!
//! The HLO scorer (`python/compile/model.py`, lowered to
//! `artifacts/scorer.hlo.txt`) consumes three tensors per batch:
//!
//! * `stage_feats f32[B, PMAX, FS]` — per-(strategy, stage) rows,
//! * `stage_mask  f32[B, PMAX]`     — 1.0 for real stages,
//! * `strat_feats f32[B, FG]`       — per-strategy rows,
//!
//! and returns `f32[B, 4] = [step_time, pipeline_time, dp_time,
//! opt+offload_time]`. The layout constants below are the single source of
//! truth — `python/compile/model.py` mirrors the indices and
//! `artifacts/scorer_meta.json` pins them at AOT time (checked on load).

use crate::gpu::GpuCatalog;
use crate::memory::MemoryModel;
use crate::model::ModelSpec;
use crate::strategy::{ParallelStrategy, Recompute};

/// Per-stage feature width.
pub const FS: usize = 29;
/// Per-strategy feature width.
pub const FG: usize = 8;
/// Maximum pipeline depth the scorer supports.
pub const PMAX: usize = 64;
/// Scorer outputs per strategy.
pub const OUT: usize = 4;

// stage_feats indices
pub const SF_PEAK_TFLOPS: usize = 0;
pub const SF_HBM_GBS: usize = 1;
pub const SF_UTIL_MAX: usize = 2;
pub const SF_COMM_EFF_MAX: usize = 3;
pub const SF_TP_BW_GBS: usize = 4;
pub const SF_P2P_BW_GBS: usize = 5;
pub const SF_LAYERS: usize = 6;
pub const SF_IS_LAST: usize = 7;
pub const SF_TP: usize = 8;
pub const SF_MBS: usize = 9;
pub const SF_SEQ: usize = 10;
pub const SF_HIDDEN: usize = 11;
pub const SF_FFN: usize = 12;
pub const SF_KV_FRAC: usize = 13;
pub const SF_HEADS: usize = 14;
pub const SF_VOCAB: usize = 15;
pub const SF_GATED: usize = 16;
pub const SF_FLASH: usize = 17;
pub const SF_RC_GRAN: usize = 18;
pub const SF_RC_FRAC: usize = 19;
pub const SF_TP_OVERLAP: usize = 20;
pub const SF_P2P_OVERLAP: usize = 21;
pub const SF_PARAMS_M: usize = 22;
pub const SF_DP_BW_GBS: usize = 23;
pub const SF_PCIE_GBS: usize = 24;
pub const SF_N_EXPERTS: usize = 25;
pub const SF_MOE_TOPK: usize = 26;
pub const SF_EP: usize = 27;
pub const SF_EP_BW_GBS: usize = 28;

// strat_feats indices
pub const GF_K: usize = 0;
pub const GF_VPP: usize = 1;
pub const GF_DP: usize = 2;
pub const GF_OVERLAP_GRAD: usize = 3;
pub const GF_OVERLAP_PARAM: usize = 4;
pub const GF_DIST_OPT: usize = 5;
pub const GF_OFFLOAD: usize = 6;
pub const GF_SEQ_PARALLEL: usize = 7;

/// Pack one stage row. Mirrors `python/compile/model.py::pack conventions`.
/// `mem` is the (stateless-but-not-free) memory model used for the
/// `SF_PARAMS_M` feature — passed in so batch packers construct it once
/// per batch instead of once per stage row.
pub fn pack_stage(
    m: &ModelSpec,
    s: &ParallelStrategy,
    stage: usize,
    catalog: &GpuCatalog,
    mem: &MemoryModel,
    out: &mut [f32],
) {
    assert_eq!(out.len(), FS);
    let gpu = s.cluster.gpu_of_stage(stage);
    let spec = catalog.spec(gpu);
    let is_last = stage == s.pp() - 1;

    out[SF_PEAK_TFLOPS] = spec.peak_tflops_bf16 as f32;
    out[SF_HBM_GBS] = spec.hbm_gbs as f32;
    out[SF_UTIL_MAX] = spec.eff.util_max as f32;
    out[SF_COMM_EFF_MAX] = spec.eff.comm_eff_max as f32;
    out[SF_TP_BW_GBS] =
        if s.tp > 1 { catalog.group_bandwidth_gbs(gpu, s.tp) as f32 } else { 0.0 };
    out[SF_P2P_BW_GBS] = if is_last {
        0.0
    } else {
        let next = catalog.spec(s.cluster.gpu_of_stage(stage + 1));
        let span = s.tp * s.dp;
        let bw = if span < catalog.gpus_per_node {
            spec.nvlink_gbs.min(next.nvlink_gbs)
        } else {
            spec.internode_gbs.min(next.internode_gbs)
        };
        bw as f32
    };
    out[SF_LAYERS] = s.cluster.layers_of_stage(stage) as f32;
    out[SF_IS_LAST] = is_last as u8 as f32;
    out[SF_TP] = s.tp as f32;
    out[SF_MBS] = s.micro_batch as f32;
    out[SF_SEQ] = m.seq_len as f32;
    out[SF_HIDDEN] = m.hidden as f32;
    out[SF_FFN] = m.ffn as f32;
    out[SF_KV_FRAC] = (m.kv_heads as f64 / m.heads as f64) as f32;
    out[SF_HEADS] = m.heads as f32;
    out[SF_VOCAB] = m.vocab as f32;
    out[SF_GATED] = m.gated_mlp() as u8 as f32;
    out[SF_FLASH] = s.use_flash_attn as u8 as f32;
    out[SF_RC_GRAN] = match s.recompute {
        Recompute::None => 0.0,
        Recompute::Selective => 1.0,
        Recompute::Full => 2.0,
    };
    out[SF_RC_FRAC] = if s.recompute == Recompute::Full {
        let layers = s.cluster.layers_of_stage(stage) as f64;
        ((s.recompute_num_layers as f64).min(layers) / layers.max(1.0)) as f32
    } else {
        0.0
    };
    out[SF_TP_OVERLAP] = s.tp_comm_overlap as u8 as f32;
    out[SF_P2P_OVERLAP] = s.overlap_p2p as u8 as f32;
    out[SF_PARAMS_M] = (mem.stage_params(m, s, stage) / 1e6) as f32;
    out[SF_DP_BW_GBS] = catalog.group_bandwidth_gbs(gpu, s.tp * s.dp) as f32;
    out[SF_PCIE_GBS] = spec.pcie_gbs as f32;
    out[SF_N_EXPERTS] = m.num_experts as f32;
    out[SF_MOE_TOPK] = m.moe_topk as f32;
    out[SF_EP] = s.ep as f32;
    out[SF_EP_BW_GBS] = catalog.group_bandwidth_gbs(gpu, s.tp * s.ep) as f32;
}

/// Pack one strategy row.
pub fn pack_strategy(s: &ParallelStrategy, out: &mut [f32]) {
    assert_eq!(out.len(), FG);
    out[GF_K] = s.num_microbatches() as f32;
    out[GF_VPP] = s.vpp as f32;
    out[GF_DP] = s.dp as f32;
    out[GF_OVERLAP_GRAD] = s.overlap_grad_reduce as u8 as f32;
    out[GF_OVERLAP_PARAM] = s.overlap_param_gather as u8 as f32;
    out[GF_DIST_OPT] = s.use_distributed_optimizer as u8 as f32;
    out[GF_OFFLOAD] = s.offload_optimizer as u8 as f32;
    out[GF_SEQ_PARALLEL] = s.sequence_parallel as u8 as f32;
}

/// Pack a batch of strategies into the three scorer tensors, padding to
/// (`batch`, [`PMAX`]). Strategies deeper than `PMAX` are a caller error
/// (the generator caps `max_pp` at `PMAX`).
pub struct PackedBatch {
    pub stage_feats: Vec<f32>,
    pub stage_mask: Vec<f32>,
    pub strat_feats: Vec<f32>,
    pub batch: usize,
}

pub fn pack_batch(
    m: &ModelSpec,
    strategies: &[&ParallelStrategy],
    catalog: &GpuCatalog,
    batch: usize,
) -> PackedBatch {
    let mut scratch = PackScratch::default();
    pack_batch_into(m, strategies, catalog, batch, &mut scratch);
    PackedBatch {
        stage_feats: scratch.stage_feats,
        stage_mask: scratch.stage_mask,
        strat_feats: scratch.strat_feats,
        batch,
    }
}

/// Reusable tensor buffers for [`pack_batch_into`] — the HLO pack path
/// holds one of these per executor and re-zeroes in place instead of
/// allocating three fresh `Vec`s per pool.
#[derive(Debug, Default)]
pub struct PackScratch {
    pub stage_feats: Vec<f32>,
    pub stage_mask: Vec<f32>,
    pub strat_feats: Vec<f32>,
}

/// [`pack_batch`] into caller-owned buffers. The buffers are resized and
/// re-zeroed every call (the padding rows are contract surface), but keep
/// their capacity across calls.
pub fn pack_batch_into(
    m: &ModelSpec,
    strategies: &[&ParallelStrategy],
    catalog: &GpuCatalog,
    batch: usize,
    out: &mut PackScratch,
) {
    assert!(strategies.len() <= batch);
    let mem = MemoryModel::default();
    out.stage_feats.clear();
    out.stage_feats.resize(batch * PMAX * FS, 0.0);
    out.stage_mask.clear();
    out.stage_mask.resize(batch * PMAX, 0.0);
    out.strat_feats.clear();
    out.strat_feats.resize(batch * FG, 0.0);
    for (bi, s) in strategies.iter().enumerate() {
        let pp = s.pp();
        assert!(pp <= PMAX, "pp {pp} exceeds scorer PMAX {PMAX}");
        for stage in 0..pp {
            let off = (bi * PMAX + stage) * FS;
            pack_stage(m, s, stage, catalog, &mem, &mut out.stage_feats[off..off + FS]);
            out.stage_mask[bi * PMAX + stage] = 1.0;
        }
        pack_strategy(s, &mut out.strat_feats[bi * FG..(bi + 1) * FG]);
    }
    // Padded rows keep K=1 etc. harmless defaults.
    for bi in strategies.len()..batch {
        out.strat_feats[bi * FG + GF_K] = 1.0;
        out.strat_feats[bi * FG + GF_VPP] = 1.0;
        out.strat_feats[bi * FG + GF_DP] = 1.0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelRegistry;
    use crate::strategy::{ClusterAssignment, RecomputeMethod};

    fn strat(m: &ModelSpec, tp: usize, pp: usize, dp: usize) -> ParallelStrategy {
        ParallelStrategy {
            cluster: ClusterAssignment::homogeneous(1, pp, m.layers / pp),
            tp,
            dp,
            micro_batch: 2,
            global_batch: m.global_batch,
            vpp: 1,
            sequence_parallel: tp > 1,
            use_distributed_optimizer: true,
            recompute: Recompute::None,
            recompute_method: RecomputeMethod::Uniform,
            recompute_num_layers: 0,
            offload_optimizer: false,
            overlap_grad_reduce: true,
            overlap_param_gather: true,
            overlap_p2p: true,
            tp_comm_overlap: true,
            use_flash_attn: true,
            ep: 1,
        }
    }

    #[test]
    fn pack_shapes_and_mask() {
        let reg = ModelRegistry::builtin();
        let cat = GpuCatalog::builtin();
        let m = reg.get("llama2-7b").unwrap();
        let s1 = strat(m, 2, 4, 8);
        let s2 = strat(m, 4, 2, 8);
        let pb = pack_batch(m, &[&s1, &s2], &cat, 4);
        assert_eq!(pb.stage_feats.len(), 4 * PMAX * FS);
        assert_eq!(pb.stage_mask.len(), 4 * PMAX);
        assert_eq!(pb.strat_feats.len(), 4 * FG);
        // s1 has 4 live stages, s2 has 2, padding rows none.
        let live: f32 = pb.stage_mask.iter().sum();
        assert_eq!(live, 6.0);
        // Padded strategies keep K/vpp/dp = 1.
        assert_eq!(pb.strat_feats[3 * FG + GF_K], 1.0);
    }

    #[test]
    fn last_stage_flagged_once() {
        let reg = ModelRegistry::builtin();
        let cat = GpuCatalog::builtin();
        let m = reg.get("llama2-7b").unwrap();
        let s = strat(m, 2, 4, 8);
        let pb = pack_batch(m, &[&s], &cat, 1);
        let lasts: f32 = (0..PMAX).map(|p| pb.stage_feats[p * FS + SF_IS_LAST]).sum();
        assert_eq!(lasts, 1.0);
        assert_eq!(pb.stage_feats[3 * FS + SF_IS_LAST], 1.0);
        // Last stage has no p2p bandwidth.
        assert_eq!(pb.stage_feats[3 * FS + SF_P2P_BW_GBS], 0.0);
        assert!(pb.stage_feats[0 * FS + SF_P2P_BW_GBS] > 0.0);
    }

    #[test]
    fn scratch_reuse_matches_fresh_pack() {
        let reg = ModelRegistry::builtin();
        let cat = GpuCatalog::builtin();
        let m = reg.get("llama2-7b").unwrap();
        let s1 = strat(m, 2, 4, 8);
        let s2 = strat(m, 4, 2, 8);
        let mut scratch = PackScratch::default();
        // Dirty the scratch with a larger batch first; the smaller repack
        // must still match a fresh pack byte-for-byte (padding re-zeroed).
        pack_batch_into(m, &[&s1, &s2], &cat, 8, &mut scratch);
        pack_batch_into(m, &[&s2], &cat, 2, &mut scratch);
        let fresh = pack_batch(m, &[&s2], &cat, 2);
        assert_eq!(scratch.stage_feats, fresh.stage_feats);
        assert_eq!(scratch.stage_mask, fresh.stage_mask);
        assert_eq!(scratch.strat_feats, fresh.strat_feats);
    }

    #[test]
    fn feature_widths_locked() {
        // The python side hardcodes these; changing them must be deliberate.
        assert_eq!(FS, 29);
        assert_eq!(FG, 8);
        assert_eq!(PMAX, 64);
        assert_eq!(OUT, 4);
    }
}
