//! Minimal JSON substrate (parser + writer + access helpers).
//!
//! The offline image carries no `serde`/`serde_json`, so Astra ships its own
//! JSON layer. It is used for: the GPU catalog and hardware profile
//! (`data/*.json`), the GBDT forest interchange with the python compile path
//! (`artifacts/forest.json`), search-request config files, and machine-
//! readable bench output.
//!
//! Supported: full RFC 8259 syntax (objects, arrays, strings with escapes and
//! `\uXXXX` incl. surrogate pairs, numbers, booleans, null). Numbers are kept
//! as `f64` (adequate for all Astra payloads; integers up to 2^53 round-trip).

mod parse;
mod write;

pub use parse::parse;
pub use write::{to_string, to_string_pretty};

use std::collections::BTreeMap;

/// A parsed JSON value. Object keys are kept sorted (BTreeMap) so output is
/// deterministic — important for golden tests and artifact diffing.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    /// Build an empty object.
    pub fn obj() -> Value {
        Value::Obj(BTreeMap::new())
    }

    /// Fluent insertion for object construction.
    pub fn set(mut self, key: &str, v: impl Into<Value>) -> Value {
        if let Value::Obj(m) = &mut self {
            m.insert(key.to_string(), v.into());
        }
        self
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Value::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as u64),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|v| v as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Object field access; `None` for non-objects or missing keys.
    pub fn get(&self, key: &str) -> Option<&Value> {
        self.as_obj().and_then(|m| m.get(key))
    }

    /// Array index access.
    pub fn at(&self, idx: usize) -> Option<&Value> {
        self.as_arr().and_then(|a| a.get(idx))
    }

    /// `/a/b/0/c`-style pointer lookup (subset of RFC 6901: no escaping).
    pub fn pointer(&self, ptr: &str) -> Option<&Value> {
        let mut cur = self;
        for part in ptr.split('/').filter(|p| !p.is_empty()) {
            cur = match cur {
                Value::Obj(m) => m.get(part)?,
                Value::Arr(a) => a.get(part.parse::<usize>().ok()?)?,
                _ => return None,
            };
        }
        Some(cur)
    }

    /// Typed field access helpers with error messages, for config loading.
    pub fn req_f64(&self, key: &str) -> crate::Result<f64> {
        self.get(key)
            .and_then(Value::as_f64)
            .ok_or_else(|| crate::AstraError::Json(format!("missing/invalid number field '{key}'")))
    }

    pub fn req_str(&self, key: &str) -> crate::Result<&str> {
        self.get(key)
            .and_then(Value::as_str)
            .ok_or_else(|| crate::AstraError::Json(format!("missing/invalid string field '{key}'")))
    }

    pub fn req_arr(&self, key: &str) -> crate::Result<&[Value]> {
        self.get(key)
            .and_then(Value::as_arr)
            .ok_or_else(|| crate::AstraError::Json(format!("missing/invalid array field '{key}'")))
    }

    /// Required non-negative integer field (service wire protocol).
    pub fn req_usize(&self, key: &str) -> crate::Result<usize> {
        self.get(key)
            .and_then(Value::as_usize)
            .ok_or_else(|| {
                crate::AstraError::Json(format!(
                    "missing/invalid non-negative integer field '{key}'"
                ))
            })
    }

    /// Optional number field; `None` when missing or non-numeric.
    pub fn opt_f64(&self, key: &str) -> Option<f64> {
        self.get(key).and_then(Value::as_f64)
    }

    /// Optional non-negative integer field.
    pub fn opt_usize(&self, key: &str) -> Option<usize> {
        self.get(key).and_then(Value::as_usize)
    }

    /// Optional string field.
    pub fn opt_str(&self, key: &str) -> Option<&str> {
        self.get(key).and_then(Value::as_str)
    }

    /// Extract a flat `Vec<f64>` from an array field.
    pub fn req_f64_arr(&self, key: &str) -> crate::Result<Vec<f64>> {
        self.req_arr(key)?
            .iter()
            .map(|v| {
                v.as_f64()
                    .ok_or_else(|| crate::AstraError::Json(format!("non-number in array '{key}'")))
            })
            .collect()
    }
}

impl From<bool> for Value {
    fn from(b: bool) -> Value {
        Value::Bool(b)
    }
}
impl From<f64> for Value {
    fn from(n: f64) -> Value {
        Value::Num(n)
    }
}
impl From<usize> for Value {
    fn from(n: usize) -> Value {
        Value::Num(n as f64)
    }
}
impl From<u64> for Value {
    fn from(n: u64) -> Value {
        Value::Num(n as f64)
    }
}
impl From<i64> for Value {
    fn from(n: i64) -> Value {
        Value::Num(n as f64)
    }
}
impl From<u32> for Value {
    fn from(n: u32) -> Value {
        Value::Num(n as f64)
    }
}
impl From<&str> for Value {
    fn from(s: &str) -> Value {
        Value::Str(s.to_string())
    }
}
impl From<String> for Value {
    fn from(s: String) -> Value {
        Value::Str(s)
    }
}
impl<T: Into<Value>> From<Vec<T>> for Value {
    fn from(v: Vec<T>) -> Value {
        Value::Arr(v.into_iter().map(Into::into).collect())
    }
}

/// Read and parse a JSON file.
pub fn from_file(path: &std::path::Path) -> crate::Result<Value> {
    let text = std::fs::read_to_string(path)?;
    parse(&text)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for src in ["null", "true", "false", "0", "-1.5", "1e3", "\"hi\""] {
            let v = parse(src).unwrap();
            let back = parse(&to_string(&v)).unwrap();
            assert_eq!(v, back, "roundtrip of {src}");
        }
    }

    #[test]
    fn roundtrip_nested() {
        let src = r#"{"a":[1,2,{"b":null,"c":[true,false]}],"d":"x\ny"}"#;
        let v = parse(src).unwrap();
        let back = parse(&to_string(&v)).unwrap();
        assert_eq!(v, back);
        assert_eq!(v.pointer("/a/2/c/0"), Some(&Value::Bool(true)));
    }

    #[test]
    fn unicode_escapes() {
        let v = parse(r#""Aé😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "Aé😀");
        let back = parse(&to_string(&v)).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn rejects_garbage() {
        for src in ["", "{", "[1,]", "{\"a\":}", "tru", "1.2.3", "\"\\q\"", "[1 2]", "{\"a\" 1}"] {
            assert!(parse(src).is_err(), "should reject {src:?}");
        }
    }

    #[test]
    fn rejects_trailing() {
        assert!(parse("1 2").is_err());
        assert!(parse("{} []").is_err());
    }

    #[test]
    fn deep_pointer_and_helpers() {
        let v = parse(r#"{"gpus":[{"name":"a800","tflops":312.0}]}"#).unwrap();
        let g = v.pointer("/gpus/0").unwrap();
        assert_eq!(g.req_str("name").unwrap(), "a800");
        assert_eq!(g.req_f64("tflops").unwrap(), 312.0);
        assert!(g.req_str("missing").is_err());
    }

    #[test]
    fn optional_and_integer_helpers() {
        let v = parse(r#"{"gpus":64,"money":1.5,"name":"x","frac":0.5}"#).unwrap();
        assert_eq!(v.req_usize("gpus").unwrap(), 64);
        assert!(v.req_usize("frac").is_err(), "fractional number is not a usize");
        assert!(v.req_usize("missing").is_err());
        assert_eq!(v.opt_f64("money"), Some(1.5));
        assert_eq!(v.opt_f64("missing"), None);
        assert_eq!(v.opt_usize("gpus"), Some(64));
        assert_eq!(v.opt_str("name"), Some("x"));
        assert_eq!(v.opt_str("gpus"), None);
    }

    #[test]
    fn builder_api() {
        let v = Value::obj().set("x", 1.0).set("y", "z").set("b", true);
        assert_eq!(to_string(&v), r#"{"b":true,"x":1,"y":"z"}"#);
    }

    #[test]
    fn integer_fidelity() {
        // 2^53-safe integers must round-trip exactly.
        let n = 9007199254740991u64;
        let v = parse(&format!("{n}")).unwrap();
        assert_eq!(v.as_u64(), Some(n));
        assert_eq!(to_string(&v), format!("{n}"));
    }

    #[test]
    fn pretty_is_reparsable() {
        let src = r#"{"a":[1,{"b":[]}],"c":{}}"#;
        let v = parse(src).unwrap();
        assert_eq!(parse(&to_string_pretty(&v)).unwrap(), v);
    }
}
