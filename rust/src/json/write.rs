//! JSON serialization: compact and pretty writers.
//!
//! Numbers are emitted with the shortest representation that round-trips
//! through `f64` (integers without a fractional part print as integers, so
//! artifact files stay diff-friendly).

use super::Value;

/// Serialize compactly (no whitespace).
pub fn to_string(v: &Value) -> String {
    let mut out = String::new();
    write_value(v, &mut out);
    out
}

/// Serialize with 2-space indentation.
pub fn to_string_pretty(v: &Value) -> String {
    let mut out = String::new();
    write_pretty(v, &mut out, 0);
    out.push('\n');
    out
}

fn write_value(v: &Value, out: &mut String) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(true) => out.push_str("true"),
        Value::Bool(false) => out.push_str("false"),
        Value::Num(n) => write_num(*n, out),
        Value::Str(s) => write_str(s, out),
        Value::Arr(a) => {
            out.push('[');
            for (i, item) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_value(item, out);
            }
            out.push(']');
        }
        Value::Obj(m) => {
            out.push('{');
            for (i, (k, item)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_str(k, out);
                out.push(':');
                write_value(item, out);
            }
            out.push('}');
        }
    }
}

fn write_pretty(v: &Value, out: &mut String, indent: usize) {
    match v {
        Value::Arr(a) if !a.is_empty() => {
            out.push_str("[\n");
            for (i, item) in a.iter().enumerate() {
                if i > 0 {
                    out.push_str(",\n");
                }
                push_indent(out, indent + 1);
                write_pretty(item, out, indent + 1);
            }
            out.push('\n');
            push_indent(out, indent);
            out.push(']');
        }
        Value::Obj(m) if !m.is_empty() => {
            out.push_str("{\n");
            for (i, (k, item)) in m.iter().enumerate() {
                if i > 0 {
                    out.push_str(",\n");
                }
                push_indent(out, indent + 1);
                write_str(k, out);
                out.push_str(": ");
                write_pretty(item, out, indent + 1);
            }
            out.push('\n');
            push_indent(out, indent);
            out.push('}');
        }
        other => write_value(other, out),
    }
}

fn push_indent(out: &mut String, n: usize) {
    for _ in 0..n {
        out.push_str("  ");
    }
}

fn write_num(n: f64, out: &mut String) {
    if !n.is_finite() {
        // JSON has no Inf/NaN; clamp to null (callers should avoid these).
        out.push_str("null");
    } else if n.fract() == 0.0 && n.abs() < 9.2e18 {
        out.push_str(&format!("{}", n as i64));
    } else {
        // `{:?}` on f64 prints the shortest round-trip representation.
        out.push_str(&format!("{n:?}"));
    }
}

fn write_str(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            '\u{0008}' => out.push_str("\\b"),
            '\u{000C}' => out.push_str("\\f"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::json::parse;

    #[test]
    fn shortest_float_roundtrip() {
        for n in [0.1, 1e-10, 3.141592653589793, -2.5e300] {
            let s = to_string(&Value::Num(n));
            assert_eq!(parse(&s).unwrap().as_f64().unwrap(), n);
        }
    }

    #[test]
    fn control_chars_escaped() {
        let s = to_string(&Value::Str("\u{0001}\n".into()));
        assert_eq!(s, "\"\\u0001\\n\"");
        assert_eq!(parse(&s).unwrap().as_str().unwrap(), "\u{0001}\n");
    }

    #[test]
    fn nonfinite_becomes_null() {
        assert_eq!(to_string(&Value::Num(f64::NAN)), "null");
        assert_eq!(to_string(&Value::Num(f64::INFINITY)), "null");
    }
}
