//! Recursive-descent JSON parser over the input bytes.
//!
//! Errors carry a byte offset so malformed config/artifact files are easy to
//! locate. Depth is capped to keep adversarial inputs from overflowing the
//! stack (artifact files are machine-generated but configs are user-written).

use super::Value;
use crate::{AstraError, Result};
use std::collections::BTreeMap;

const MAX_DEPTH: usize = 128;

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

/// Parse a complete JSON document; trailing non-whitespace is an error.
pub fn parse(text: &str) -> Result<Value> {
    let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value(0)?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters after document"));
    }
    Ok(v)
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> AstraError {
        AstraError::Json(format!("{msg} at byte {}", self.pos))
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self, depth: usize) -> Result<Value> {
        if depth > MAX_DEPTH {
            return Err(self.err("nesting too deep"));
        }
        match self.peek() {
            Some(b'{') => self.object(depth),
            Some(b'[') => self.array(depth),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.literal(b"true", Value::Bool(true)),
            Some(b'f') => self.literal(b"false", Value::Bool(false)),
            Some(b'n') => self.literal(b"null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(_) => Err(self.err("unexpected character")),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn literal(&mut self, word: &[u8], v: Value) -> Result<Value> {
        if self.bytes[self.pos..].starts_with(word) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn object(&mut self, depth: usize) -> Result<Value> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value(depth + 1)?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self, depth: usize) -> Result<Value> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value(depth + 1)?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        let ch = if (0xD800..0xDC00).contains(&cp) {
                            // High surrogate: require a following \uXXXX low surrogate.
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("unpaired surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            char::from_u32(c).ok_or_else(|| self.err("invalid codepoint"))?
                        } else if (0xDC00..0xE000).contains(&cp) {
                            return Err(self.err("unpaired low surrogate"));
                        } else {
                            char::from_u32(cp).ok_or_else(|| self.err("invalid codepoint"))?
                        };
                        out.push(ch);
                    }
                    _ => return Err(self.err("invalid escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("raw control character in string")),
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences from the raw bytes.
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let len = utf8_len(c).ok_or_else(|| self.err("invalid utf-8"))?;
                        let start = self.pos - 1;
                        let end = start + len;
                        if end > self.bytes.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let s = std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        out.push_str(s);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u escape"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("invalid hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        // Integer part: either a single 0 or [1-9][0-9]*.
        match self.peek() {
            Some(b'0') => self.pos += 1,
            Some(c) if c.is_ascii_digit() => {
                while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                    self.pos += 1;
                }
            }
            _ => return Err(self.err("invalid number")),
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            if !matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                return Err(self.err("digit expected after '.'"));
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            if !matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                return Err(self.err("digit expected in exponent"));
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| self.err("number out of range"))
    }
}

fn utf8_len(first: u8) -> Option<usize> {
    match first {
        0xC0..=0xDF => Some(2),
        0xE0..=0xEF => Some(3),
        0xF0..=0xF7 => Some(4),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn numbers_edge_cases() {
        assert_eq!(parse("0").unwrap(), Value::Num(0.0));
        assert_eq!(parse("-0.5e-2").unwrap(), Value::Num(-0.005));
        assert!(parse("01").is_err()); // leading zero
        assert!(parse("-").is_err());
        assert!(parse("1.").is_err());
        assert!(parse("1e").is_err());
    }

    #[test]
    fn surrogate_pairs() {
        let v = parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "😀");
        assert!(parse(r#""\ud83d""#).is_err());
        assert!(parse(r#""\ude00""#).is_err());
    }

    #[test]
    fn depth_cap() {
        let deep = "[".repeat(500) + &"]".repeat(500);
        assert!(parse(&deep).is_err());
        let ok = "[".repeat(100) + &"]".repeat(100);
        assert!(parse(&ok).is_ok());
    }

    #[test]
    fn whitespace_everywhere() {
        let v = parse(" \t\n{ \"a\" : [ 1 , 2 ] , \"b\" : { } } \r\n").unwrap();
        assert_eq!(v.pointer("/a/1"), Some(&Value::Num(2.0)));
    }
}
