//! Deterministic PRNG substrate (no `rand` crate offline).
//!
//! `SplitMix64` seeds a `Xoshiro256**` generator — the standard pairing.
//! Used by: property tests, the expert/search tie-breaking jitter, the
//! discrete-event simulator's measurement-noise model, and workload
//! generators in the benches. Determinism across runs (given a seed) is a
//! hard requirement for reproducible EXPERIMENTS.md numbers.

/// SplitMix64: used to expand a single `u64` seed into stream state.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// Xoshiro256** — fast, high-quality, 256-bit state.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 (any seed, including 0, is fine).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Rng { s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        // 53 high bits → [0,1) with full double precision.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Uniform integer in `[0, n)` (n > 0). Lemire-style rejection-free
    /// widening multiply; bias is negligible for our n (<2^32).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "Rng::below(0)");
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform integer in `[lo, hi]` inclusive.
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }

    /// Pick a random element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }

    /// Standard normal via Box–Muller (used for measurement noise).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..1_000 {
            seen[r.below(10) as usize] = true;
        }
        assert!(seen.iter().all(|&b| b), "all residues hit");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
