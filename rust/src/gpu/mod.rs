//! GPU catalog, pricing and cluster topology.
//!
//! A [`GpuSpec`] carries the published peak rates plus the synthetic
//! efficiency-curve constants of the hardware-truth model (see
//! `data/hw_profile.json` and [`crate::hw`]). The same constants are read by
//! the python compile path when it samples the GBDT training set, and a
//! cross-language test pins the two implementations together.
//!
//! The paper's three GPU-pool modes (§3.2, Eq. 1–3) are represented by
//! [`crate::strategy::GpuPoolMode`]; this module supplies the specs and the
//! interconnect model: 8 GPUs per node over NVLink, nodes over PCIe/IB.

use crate::json::Value;
use crate::{AstraError, Result};

/// Index into the catalog; strategies store this instead of strings.
pub type GpuType = usize;

/// Efficiency-curve constants of the hardware-truth model for one GPU type.
#[derive(Debug, Clone, PartialEq)]
pub struct EffCurve {
    /// Peak achievable fraction of spec TFLOPs (MFU ceiling).
    pub util_max: f64,
    /// Per-kernel launch/setup overhead in seconds (drives the
    /// small-op efficiency collapse).
    pub launch_overhead_s: f64,
    /// GEMM dimensions below this get the skinny penalty.
    pub skinny_dim: f64,
    /// Multiplicative penalty for skinny GEMMs.
    pub skinny_penalty: f64,
    /// Arithmetic intensity (flop/byte) below which the op is memory-bound.
    pub mem_bound_intensity: f64,
    /// Per-collective base latency in seconds.
    pub comm_latency_s: f64,
    /// Peak achievable fraction of link bandwidth.
    pub comm_eff_max: f64,
}

/// One GPU type: published peaks + pricing + efficiency curve.
#[derive(Debug, Clone, PartialEq)]
pub struct GpuSpec {
    pub name: String,
    pub mem_gib: f64,
    pub peak_tflops_bf16: f64,
    pub hbm_gbs: f64,
    /// Intra-node (NVLink) per-GPU bandwidth, GB/s.
    pub nvlink_gbs: f64,
    /// Inter-node effective per-GPU bandwidth (IB/PCIe fabric), GB/s.
    pub internode_gbs: f64,
    /// Host↔device PCIe bandwidth (offload path), GB/s.
    pub pcie_gbs: f64,
    pub price_per_hour: f64,
    pub eff: EffCurve,
}

impl GpuSpec {
    /// Peak flop/s (not TFLOPs).
    pub fn peak_flops(&self) -> f64 {
        self.peak_tflops_bf16 * 1e12
    }

    /// Usable device memory in bytes (spec minus runtime/ctx reserve).
    pub fn usable_mem_bytes(&self) -> f64 {
        (self.mem_gib - 2.0).max(1.0) * 1024.0 * 1024.0 * 1024.0 * 0.94
    }

    pub fn price_per_second(&self) -> f64 {
        self.price_per_hour / 3600.0
    }
}

/// The catalog: all known GPU types plus cluster topology constants.
#[derive(Debug, Clone)]
pub struct GpuCatalog {
    specs: Vec<GpuSpec>,
    pub gpus_per_node: usize,
}

impl GpuCatalog {
    /// Compiled-in catalog mirroring `data/hw_profile.json` (tests and
    /// examples never depend on the working directory).
    pub fn builtin() -> Self {
        let mk = |name: &str,
                  mem: f64,
                  tflops: f64,
                  hbm: f64,
                  nvl: f64,
                  inter: f64,
                  pcie: f64,
                  price: f64,
                  eff: EffCurve| GpuSpec {
            name: name.to_string(),
            mem_gib: mem,
            peak_tflops_bf16: tflops,
            hbm_gbs: hbm,
            nvlink_gbs: nvl,
            internode_gbs: inter,
            pcie_gbs: pcie,
            price_per_hour: price,
            eff,
        };
        let ampere = EffCurve {
            util_max: 0.62,
            launch_overhead_s: 9.0e-6,
            skinny_dim: 128.0,
            skinny_penalty: 0.72,
            mem_bound_intensity: 80.0,
            comm_latency_s: 18.0e-6,
            comm_eff_max: 0.88,
        };
        let hopper = EffCurve {
            util_max: 0.58,
            launch_overhead_s: 7.0e-6,
            skinny_dim: 256.0,
            skinny_penalty: 0.66,
            mem_bound_intensity: 140.0,
            comm_latency_s: 15.0e-6,
            comm_eff_max: 0.90,
        };
        let volta = EffCurve {
            util_max: 0.55,
            launch_overhead_s: 12.0e-6,
            skinny_dim: 128.0,
            skinny_penalty: 0.70,
            mem_bound_intensity: 60.0,
            comm_latency_s: 25.0e-6,
            comm_eff_max: 0.85,
        };
        GpuCatalog {
            specs: vec![
                mk("a100", 80.0, 312.0, 2039.0, 600.0, 25.0, 32.0, 3.00, ampere.clone()),
                mk("a800", 80.0, 312.0, 2039.0, 400.0, 25.0, 32.0, 2.60, ampere),
                mk("h100", 80.0, 989.0, 3350.0, 900.0, 50.0, 64.0, 4.10, hopper.clone()),
                mk("h800", 80.0, 989.0, 3350.0, 400.0, 50.0, 64.0, 3.40, hopper),
                mk("v100", 32.0, 125.0, 900.0, 300.0, 12.0, 16.0, 1.50, volta),
            ],
            gpus_per_node: 8,
        }
    }

    /// Load from `data/hw_profile.json` (keeps rust and python in lockstep).
    pub fn from_json(v: &Value) -> Result<Self> {
        let mut specs = Vec::new();
        for g in v.req_arr("gpus")? {
            let eff = g
                .get("eff")
                .ok_or_else(|| AstraError::Json("gpu missing eff".into()))?;
            specs.push(GpuSpec {
                name: g.req_str("name")?.to_string(),
                mem_gib: g.req_f64("mem_gib")?,
                peak_tflops_bf16: g.req_f64("peak_tflops_bf16")?,
                hbm_gbs: g.req_f64("hbm_gbs")?,
                nvlink_gbs: g.req_f64("nvlink_gbs")?,
                internode_gbs: g.req_f64("internode_gbs")?,
                pcie_gbs: g.req_f64("pcie_gbs")?,
                price_per_hour: g.req_f64("price_per_hour")?,
                eff: EffCurve {
                    util_max: eff.req_f64("util_max")?,
                    launch_overhead_s: eff.req_f64("launch_overhead_s")?,
                    skinny_dim: eff.req_f64("skinny_dim")?,
                    skinny_penalty: eff.req_f64("skinny_penalty")?,
                    mem_bound_intensity: eff.req_f64("mem_bound_intensity")?,
                    comm_latency_s: eff.req_f64("comm_latency_s")?,
                    comm_eff_max: eff.req_f64("comm_eff_max")?,
                },
            });
        }
        Ok(GpuCatalog {
            specs,
            gpus_per_node: v.get("gpus_per_node").and_then(Value::as_usize).unwrap_or(8),
        })
    }

    pub fn from_file(path: &std::path::Path) -> Result<Self> {
        Self::from_json(&crate::json::from_file(path)?)
    }

    pub fn len(&self) -> usize {
        self.specs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.specs.is_empty()
    }

    pub fn spec(&self, t: GpuType) -> &GpuSpec {
        &self.specs[t]
    }

    pub fn all(&self) -> &[GpuSpec] {
        &self.specs
    }

    pub fn find(&self, name: &str) -> Result<GpuType> {
        self.specs
            .iter()
            .position(|s| s.name.eq_ignore_ascii_case(name))
            .ok_or_else(|| {
                AstraError::Config(format!(
                    "unknown GPU type '{name}' (known: {})",
                    self.specs.iter().map(|s| s.name.as_str()).collect::<Vec<_>>().join(", ")
                ))
            })
    }

    /// Parse a `'type:cap,type:cap'` capacity spec (the CLI/example
    /// `--hetero` format) into resolved per-type caps. Duplicate names
    /// merge by summation, matching the engine/fingerprint
    /// canonicalization ([`crate::strategy::merge_caps`]).
    pub fn parse_caps(&self, spec: &str) -> Result<Vec<(GpuType, usize)>> {
        let mut caps = Vec::new();
        for part in spec.split(',') {
            let (name, cap) = part
                .split_once(':')
                .ok_or_else(|| AstraError::Config(format!("bad hetero spec '{part}'")))?;
            caps.push((
                self.find(name.trim())?,
                cap.trim()
                    .parse::<usize>()
                    .map_err(|_| AstraError::Config(format!("bad cap '{cap}'")))?,
            ));
        }
        Ok(crate::strategy::merge_caps(caps))
    }

    /// Effective per-GPU bandwidth for a communication group that spans
    /// `group` ranks laid out contiguously: NVLink when the whole group fits
    /// in one node, inter-node fabric otherwise.
    pub fn group_bandwidth_gbs(&self, t: GpuType, group: usize) -> f64 {
        let s = self.spec(t);
        if group <= self.gpus_per_node {
            s.nvlink_gbs
        } else {
            s.internode_gbs
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_has_paper_gpus() {
        let c = GpuCatalog::builtin();
        for name in ["a800", "h100", "h800", "a100"] {
            assert!(c.find(name).is_ok(), "{name} present");
        }
        assert!(c.find("b200").is_err());
    }

    #[test]
    fn h100_outclasses_a800() {
        let c = GpuCatalog::builtin();
        let h = c.spec(c.find("h100").unwrap());
        let a = c.spec(c.find("a800").unwrap());
        assert!(h.peak_flops() > 2.0 * a.peak_flops());
        assert!(h.price_per_hour > a.price_per_hour);
    }

    #[test]
    fn bandwidth_topology_switch() {
        let c = GpuCatalog::builtin();
        let t = c.find("a800").unwrap();
        assert_eq!(c.group_bandwidth_gbs(t, 8), 400.0); // NVLink inside node
        assert_eq!(c.group_bandwidth_gbs(t, 16), 25.0); // crosses nodes
    }

    #[test]
    fn json_matches_builtin() {
        // data/hw_profile.json must agree with the compiled-in catalog.
        // The manifest may sit at the repo root or inside rust/; probe both
        // (plus $ASTRA_DATA) and skip loudly if the profile is absent.
        let manifest = std::path::Path::new(env!("CARGO_MANIFEST_DIR"));
        let mut candidates = vec![
            manifest.join("data/hw_profile.json"),
            manifest.join("../data/hw_profile.json"),
            manifest.join("rust/data/hw_profile.json"),
        ];
        if let Ok(d) = std::env::var("ASTRA_DATA") {
            candidates.insert(0, std::path::Path::new(&d).join("hw_profile.json"));
        }
        let Some(path) = candidates.into_iter().find(|p| p.exists()) else {
            eprintln!("SKIP: data/hw_profile.json not found near {manifest:?}");
            return;
        };
        let from_file = GpuCatalog::from_file(&path).unwrap();
        let builtin = GpuCatalog::builtin();
        assert_eq!(from_file.gpus_per_node, builtin.gpus_per_node);
        assert_eq!(from_file.len(), builtin.len());
        for (a, b) in from_file.all().iter().zip(builtin.all()) {
            assert_eq!(a, b, "spec mismatch for {}", a.name);
        }
    }

    #[test]
    fn caps_spec_parsing() {
        let c = GpuCatalog::builtin();
        let a800 = c.find("a800").unwrap();
        let h100 = c.find("h100").unwrap();
        assert_eq!(
            c.parse_caps("a800:48, h100:16").unwrap(),
            vec![(a800, 48), (h100, 16)]
        );
        // Duplicate names merge like the engine/fingerprint canonical form.
        assert_eq!(c.parse_caps("a800:8,a800:8").unwrap(), vec![(a800, 16)]);
        assert!(c.parse_caps("a800").is_err(), "missing colon");
        assert!(c.parse_caps("a800:lots").is_err(), "non-numeric cap");
        assert!(c.parse_caps("b200:8").is_err(), "unknown GPU");
    }

    #[test]
    fn usable_memory_below_spec() {
        let c = GpuCatalog::builtin();
        for s in c.all() {
            assert!(s.usable_mem_bytes() < s.mem_gib * 1073741824.0);
            assert!(s.usable_mem_bytes() > 0.5 * s.mem_gib * 1073741824.0);
        }
    }
}
