//! Memory-based filter (paper §3.3, Eq. 20–21).
//!
//! Estimates per-stage, per-GPU memory for a strategy and drops strategies
//! whose peak exceeds the device capacity. The activation terms follow the
//! published Megatron activation-memory analysis (Korthikanti et al., 2022):
//!
//! * baseline per layer per microbatch: `s·b·h·(10 + 24/t) + 5·a·s²·b/t`
//!   bytes (bf16 activations, fp32 softmax stats folded into the constants);
//! * flash attention or selective recompute drop the `5·a·s²·b/t` term;
//! * sequence parallelism shards the residual `10·s·b·h` by `t`;
//! * full recomputation stores only the `2·s·b·h` layer input for the
//!   recomputed layers.
//!
//! 1F1B keeps `min(K, P−i)` microbatches in flight on stage `i`; interleaving
//! adds a fractional extra chunk. Optimizer state is Adam (fp32 master +
//! m + v = 12 B/param), sharded by `dp` under the distributed optimizer and
//! moved to host entirely under optimizer offload.

use crate::gpu::GpuCatalog;
use crate::model::ModelSpec;
use crate::strategy::{ParallelStrategy, Recompute};

/// Byte-per-parameter constants (bf16 weights, fp32 grads, Adam fp32 states).
#[derive(Debug, Clone)]
pub struct MemoryModel {
    pub weight_bytes: f64,
    pub grad_bytes: f64,
    pub optimizer_bytes: f64,
    /// Fraction of capacity usable after fragmentation/workspace slack.
    pub headroom: f64,
}

impl Default for MemoryModel {
    fn default() -> Self {
        MemoryModel { weight_bytes: 2.0, grad_bytes: 4.0, optimizer_bytes: 12.0, headroom: 0.97 }
    }
}

/// Per-stage memory decomposition in bytes.
#[derive(Debug, Clone, Default)]
pub struct MemBreakdown {
    pub weights: f64,
    pub grads: f64,
    pub optimizer: f64,
    pub activations: f64,
    pub logits: f64,
    pub total: f64,
}

impl MemoryModel {
    /// Parameters held by one GPU of pipeline stage `i` (tensor-sharded).
    pub fn stage_params(&self, m: &ModelSpec, s: &ParallelStrategy, stage: usize) -> f64 {
        let tp = s.tp as f64;
        let layers = s.cluster.layers_of_stage(stage) as f64;
        let mut p = if m.is_moe() {
            // Expert weights are additionally sharded across the EP group;
            // attention/router/norms replicate like a dense layer.
            let h = m.hidden as f64;
            let kvf = m.kv_heads as f64 / m.heads as f64;
            let mats = if m.gated_mlp() { 3.0 } else { 2.0 };
            let attn = h * h * (2.0 + 2.0 * kvf);
            let router = h * m.num_experts as f64;
            let experts = m.num_experts as f64 * mats * h * m.ffn as f64 / s.ep as f64;
            layers * ((attn + router + 2.0 * h) / tp + experts / tp)
        } else {
            layers * m.layer_params() / tp
        };
        if stage == 0 {
            p += m.embedding_params() / tp; // input embedding, vocab-sharded
        }
        if stage == s.pp() - 1 {
            p += m.embedding_params() / tp; // untied LM head
            p += m.hidden as f64; // final norm
        }
        p
    }

    /// Activation bytes per *layer* per microbatch on one GPU.
    pub fn act_bytes_per_layer(&self, m: &ModelSpec, s: &ParallelStrategy) -> f64 {
        let b = s.micro_batch as f64;
        let seq = m.seq_len as f64;
        let h = m.hidden as f64;
        let a = m.heads as f64;
        let t = s.tp as f64;
        let sbh = seq * b * h;
        // MoE: top-k routing multiplies the MLP activation share (the
        // 24/t term is ~2/3 MLP); approximate with the active-expert factor.
        let mlp_factor = m.active_mlp_factor();
        let linear = if s.sequence_parallel {
            sbh * (10.0 / t + 24.0 * mlp_factor / t)
        } else {
            sbh * (10.0 + 24.0 * mlp_factor / t)
        };
        let score = if s.use_flash_attn || s.recompute == Recompute::Selective {
            0.0
        } else {
            5.0 * a * seq * seq * b / t
        };
        linear + score
    }

    /// Peak stored activation bytes on one GPU of stage `i`.
    pub fn stage_activation_bytes(&self, m: &ModelSpec, s: &ParallelStrategy, stage: usize) -> f64 {
        let pp = s.pp();
        let k = s.num_microbatches() as f64;
        let layers = s.cluster.layers_of_stage(stage) as f64;
        // 1F1B warmup depth for this stage, plus a fractional extra chunk
        // under interleaving (Megatron's interleaved schedule holds up to
        // (vpp-1)/vpp of one more chunk's activations).
        let in_flight = k.min((pp - stage) as f64) + (s.vpp as f64 - 1.0) / s.vpp as f64;
        let per_layer = self.act_bytes_per_layer(m, s);
        let input_only = 2.0 * m.seq_len as f64 * s.micro_batch as f64 * m.hidden as f64;
        let act_one_mb = match s.recompute {
            Recompute::Full => {
                let rl = (s.recompute_num_layers as f64).min(layers);
                rl * input_only + (layers - rl) * per_layer
            }
            _ => layers * per_layer,
        };
        act_one_mb * in_flight
    }

    /// Softmax logits buffer on the last stage (fp32, vocab-sharded by tp).
    pub fn logits_bytes(&self, m: &ModelSpec, s: &ParallelStrategy, stage: usize) -> f64 {
        if stage == s.pp() - 1 {
            4.0 * m.seq_len as f64 * s.micro_batch as f64 * m.vocab as f64 / s.tp as f64
        } else {
            0.0
        }
    }

    /// Full decomposition for one GPU of stage `i` (Eq. 20's `M_i(s_j)`).
    pub fn stage_breakdown(&self, m: &ModelSpec, s: &ParallelStrategy, stage: usize) -> MemBreakdown {
        let params = self.stage_params(m, s, stage);
        let weights = params * self.weight_bytes;
        let grads = params * self.grad_bytes;
        let optimizer = if s.offload_optimizer {
            0.0 // resident on host; PCIe traffic charged by the cost model
        } else if s.use_distributed_optimizer {
            params * self.optimizer_bytes / s.dp as f64
        } else {
            params * self.optimizer_bytes
        };
        let activations = self.stage_activation_bytes(m, s, stage);
        let logits = self.logits_bytes(m, s, stage);
        let total = weights + grads + optimizer + activations + logits;
        MemBreakdown { weights, grads, optimizer, activations, logits, total }
    }

    /// Peak across stages, in bytes.
    pub fn peak_bytes(&self, m: &ModelSpec, s: &ParallelStrategy) -> f64 {
        (0..s.pp())
            .map(|i| self.stage_breakdown(m, s, i).total)
            .fold(0.0, f64::max)
    }

    /// Eq. 21: strategy survives iff every stage fits its GPU's memory.
    pub fn fits(&self, m: &ModelSpec, s: &ParallelStrategy, catalog: &GpuCatalog) -> bool {
        (0..s.pp()).all(|i| {
            let cap = catalog.spec(s.cluster.gpu_of_stage(i)).usable_mem_bytes() * self.headroom;
            self.stage_breakdown(m, s, i).total <= cap
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::GpuCatalog;
    use crate::model::ModelRegistry;
    use crate::strategy::{ClusterAssignment, ParallelStrategy, RecomputeMethod};

    fn strat(m: &ModelSpec, tp: usize, pp: usize, dp: usize, mbs: usize) -> ParallelStrategy {
        ParallelStrategy {
            cluster: ClusterAssignment::homogeneous(1, pp, m.layers / pp),
            tp,
            dp,
            micro_batch: mbs,
            global_batch: m.global_batch,
            vpp: 1,
            sequence_parallel: tp > 1,
            use_distributed_optimizer: true,
            recompute: Recompute::None,
            recompute_method: RecomputeMethod::Uniform,
            recompute_num_layers: 0,
            offload_optimizer: false,
            overlap_grad_reduce: true,
            overlap_param_gather: true,
            overlap_p2p: true,
            tp_comm_overlap: true,
            use_flash_attn: true,
            ep: 1,
        }
    }

    fn setup() -> (ModelRegistry, GpuCatalog, MemoryModel) {
        (ModelRegistry::builtin(), GpuCatalog::builtin(), MemoryModel::default())
    }

    #[test]
    fn seventyb_needs_model_parallelism() {
        // Llama-2-70B cannot fit dp-only on 80 GiB GPUs: weights alone are
        // ~140 GB. The memory filter must reject tp=1,pp=1.
        let (reg, cat, mm) = setup();
        let m = reg.get("llama2-70b").unwrap();
        let s = strat(m, 1, 1, 64, 1);
        assert!(!mm.fits(m, &s, &cat));
        // With tp=8, pp=8 it comfortably fits.
        let s = strat(m, 8, 8, 1, 1);
        assert!(mm.fits(m, &s, &cat));
    }

    #[test]
    fn sevenb_fits_modest_parallelism() {
        let (reg, cat, mm) = setup();
        let m = reg.get("llama2-7b").unwrap();
        let s = strat(m, 2, 1, 32, 1);
        assert!(mm.fits(m, &s, &cat), "peak {:.1} GiB", mm.peak_bytes(m, &s) / 1073741824.0);
    }

    #[test]
    fn tp_reduces_memory() {
        let (reg, _, mm) = setup();
        let m = reg.get("llama2-13b").unwrap();
        let m1 = mm.peak_bytes(m, &strat(m, 1, 1, 64, 1));
        let m4 = mm.peak_bytes(m, &strat(m, 4, 1, 16, 1));
        assert!(m4 < m1 / 2.0, "tp=4 {m4:.3e} vs tp=1 {m1:.3e}");
    }

    #[test]
    fn full_recompute_cuts_activations() {
        let (reg, _, mm) = setup();
        let m = reg.get("llama2-7b").unwrap();
        let base = strat(m, 2, 4, 8, 4);
        let mut rc = base.clone();
        rc.recompute = Recompute::Full;
        rc.recompute_num_layers = m.layers / 4;
        let a0 = mm.stage_activation_bytes(m, &base, 0);
        let a1 = mm.stage_activation_bytes(m, &rc, 0);
        assert!(a1 < a0 * 0.2, "full recompute {a1:.3e} vs none {a0:.3e}");
    }

    #[test]
    fn flash_attn_drops_quadratic_term() {
        let (reg, _, mm) = setup();
        let m = reg.get("llama2-7b").unwrap();
        let mut s = strat(m, 2, 2, 16, 1);
        s.use_flash_attn = true;
        let with_flash = mm.act_bytes_per_layer(m, &s);
        s.use_flash_attn = false;
        let without = mm.act_bytes_per_layer(m, &s);
        assert!(without > with_flash * 1.5);
    }

    #[test]
    fn offload_frees_optimizer_memory() {
        let (reg, _, mm) = setup();
        let m = reg.get("llama2-13b").unwrap();
        let mut s = strat(m, 4, 2, 8, 1);
        s.use_distributed_optimizer = false;
        let on_dev = mm.stage_breakdown(m, &s, 0);
        s.offload_optimizer = true;
        let off = mm.stage_breakdown(m, &s, 0);
        assert_eq!(off.optimizer, 0.0);
        assert!(off.total < on_dev.total);
    }

    #[test]
    fn first_and_last_stage_heavier() {
        let (reg, _, mm) = setup();
        let m = reg.get("llama2-7b").unwrap();
        let s = strat(m, 2, 4, 8, 1);
        let w_mid = mm.stage_params(m, &s, 1);
        let w_first = mm.stage_params(m, &s, 0);
        let w_last = mm.stage_params(m, &s, 3);
        assert!(w_first > w_mid);
        assert!(w_last > w_mid);
    }

    #[test]
    fn stage0_holds_more_activations_than_last() {
        let (reg, _, mm) = setup();
        let m = reg.get("llama2-7b").unwrap();
        let s = strat(m, 2, 4, 8, 1); // K = 2048/8 = 256 >> pp
        let a0 = mm.stage_activation_bytes(m, &s, 0);
        let a3 = mm.stage_activation_bytes(m, &s, 3);
        assert!(a0 > a3, "1F1B warmup depth: stage0 {a0:.3e} vs last {a3:.3e}");
    }

    #[test]
    fn expert_parallel_shards_expert_weights() {
        let (reg, _, mm) = setup();
        let m = reg.get("mixtral-8x7b").unwrap();
        let mut s = strat(m, 2, 2, 16, 1);
        s.ep = 1;
        let p1 = mm.stage_params(m, &s, 0);
        s.ep = 8;
        let p8 = mm.stage_params(m, &s, 0);
        // 8 experts dominate the layer params → ep=8 cuts most of it.
        assert!(p8 < p1 * 0.35, "ep=8 {p8:.3e} vs ep=1 {p1:.3e}");
    }
}
