//! Expert baseline strategies (paper §5.1/§5.2).
//!
//! The paper recruits six engineers (6+ years of ML-systems experience) to
//! hand-craft a strategy per setting and compares Astra against the best of
//! the six. We replace the humans with six deterministic policies encoding
//! the standard heuristics such experts apply (DESIGN.md §3): each proposes
//! one strategy per setting; the panel's best (by whatever evaluator the
//! experiment uses — the discrete-event simulator in the benches) plays the
//! role of the "expert-optimal" plan.

use crate::gpu::{GpuCatalog, GpuType};
use crate::memory::MemoryModel;
use crate::model::ModelSpec;
use crate::strategy::{
    ClusterAssignment, ParallelStrategy, Recompute, RecomputeMethod, Segment,
};

/// The six policies.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExpertPolicy {
    /// Megatron playbook: TP up to the node, minimal PP to fit, rest DP.
    MegatronDefault,
    /// Avoid model parallelism; buy memory with recompute/offload.
    DpPurist,
    /// Maximize tensor parallelism, shallow pipeline.
    TpHeavy,
    /// Deep pipeline, small TP, interleaving.
    PpHeavy,
    /// Fit-first: aggressive recompute + offload, generous TP/PP.
    MemoryConservative,
    /// Minimize collective traffic: low TP, large micro-batches.
    CommMinimizer,
}

impl ExpertPolicy {
    pub fn name(&self) -> &'static str {
        match self {
            ExpertPolicy::MegatronDefault => "megatron-default",
            ExpertPolicy::DpPurist => "dp-purist",
            ExpertPolicy::TpHeavy => "tp-heavy",
            ExpertPolicy::PpHeavy => "pp-heavy",
            ExpertPolicy::MemoryConservative => "memory-conservative",
            ExpertPolicy::CommMinimizer => "comm-minimizer",
        }
    }
}

/// The panel of six.
#[derive(Debug, Clone)]
pub struct ExpertPanel {
    pub policies: Vec<ExpertPolicy>,
    mem: MemoryModel,
}

impl Default for ExpertPanel {
    fn default() -> Self {
        ExpertPanel {
            policies: vec![
                ExpertPolicy::MegatronDefault,
                ExpertPolicy::DpPurist,
                ExpertPolicy::TpHeavy,
                ExpertPolicy::PpHeavy,
                ExpertPolicy::MemoryConservative,
                ExpertPolicy::CommMinimizer,
            ],
            mem: MemoryModel::default(),
        }
    }
}

fn valid_tps(m: &ModelSpec, catalog: &GpuCatalog, count: usize) -> Vec<usize> {
    [1usize, 2, 4, 8]
        .into_iter()
        .filter(|&t| t <= catalog.gpus_per_node && m.heads % t == 0 && count % t == 0)
        .collect()
}

fn valid_pps(m: &ModelSpec, count: usize, tp: usize) -> Vec<usize> {
    (1..=m.layers.min(count / tp))
        .filter(|&p| m.layers % p == 0 && count % (tp * p) == 0)
        .collect()
}

impl ExpertPanel {
    /// All six proposals for a homogeneous setting (policies that cannot
    /// produce a fitting strategy abstain — like a stumped human would).
    pub fn proposals(
        &self,
        m: &ModelSpec,
        catalog: &GpuCatalog,
        gpu: GpuType,
        count: usize,
    ) -> Vec<(ExpertPolicy, ParallelStrategy)> {
        self.policies
            .iter()
            .filter_map(|&p| self.propose(p, m, catalog, gpu, count).map(|s| (p, s)))
            .collect()
    }

    /// One policy's homogeneous proposal.
    pub fn propose(
        &self,
        policy: ExpertPolicy,
        m: &ModelSpec,
        catalog: &GpuCatalog,
        gpu: GpuType,
        count: usize,
    ) -> Option<ParallelStrategy> {
        let tps = valid_tps(m, catalog, count);
        if tps.is_empty() {
            return None;
        }
        let max_tp = *tps.last().unwrap();
        // Per-policy preference: ordered (tp, pp) candidates + knobs.
        let (tp_order, mbs, want_vpp, recompute, offload): (
            Vec<usize>,
            usize,
            usize,
            Recompute,
            bool,
        ) = match policy {
            ExpertPolicy::MegatronDefault => (vec![max_tp], 1, 1, Recompute::None, false),
            ExpertPolicy::DpPurist => {
                (tps.clone(), 4, 1, Recompute::Full, true) // tp ascending
            }
            ExpertPolicy::TpHeavy => (vec![max_tp], 1, 1, Recompute::None, false),
            ExpertPolicy::PpHeavy => {
                let mut t = tps.clone();
                t.truncate(2); // tp ∈ {1,2}
                (t, 1, 2, Recompute::None, false)
            }
            ExpertPolicy::MemoryConservative => (vec![max_tp], 1, 1, Recompute::Full, true),
            ExpertPolicy::CommMinimizer => (tps.clone(), 8, 1, Recompute::Selective, false),
        };

        // Experts de-escalate their preferred micro-batch until things fit,
        // exactly like a human would when hitting OOM.
        let mut mbs_ladder = Vec::new();
        let mut mb = mbs;
        loop {
            mbs_ladder.push(mb);
            if mb == 1 {
                break;
            }
            mb /= 2;
        }
        for &tp in &tp_order {
            let mut pps = valid_pps(m, count, tp);
            match policy {
                // Deep pipelines first.
                ExpertPolicy::PpHeavy => pps.reverse(),
                // Memory-conservative aims mid-depth.
                ExpertPolicy::MemoryConservative => {
                    pps.retain(|&p| p >= 2);
                    if pps.is_empty() {
                        pps = valid_pps(m, count, tp);
                    }
                }
                _ => {}
            }
            for pp in pps.iter().copied().flat_map(|p| mbs_ladder.iter().map(move |&b| (p, b))) {
                let (pp, mbs) = pp;
                let dp = count / (tp * pp);
                if m.global_batch % (dp * mbs) != 0 {
                    continue;
                }
                let lps = m.layers / pp;
                let vpp = if want_vpp > 1 && pp > 1 && lps % want_vpp == 0 { want_vpp } else { 1 };
                let rc_layers = match recompute {
                    Recompute::Full => lps.min(pp.max(1)),
                    _ => 0,
                };
                let s = ParallelStrategy {
                    cluster: ClusterAssignment::homogeneous(gpu, pp, lps),
                    tp,
                    dp,
                    micro_batch: mbs,
                    global_batch: m.global_batch,
                    vpp,
                    sequence_parallel: tp > 1,
                    use_distributed_optimizer: true,
                    recompute,
                    recompute_method: RecomputeMethod::Uniform,
                    recompute_num_layers: rc_layers,
                    offload_optimizer: offload,
                    overlap_grad_reduce: true,
                    overlap_param_gather: true,
                    overlap_p2p: true,
                    tp_comm_overlap: true,
                    use_flash_attn: true,
            ep: 1,
                };
                if s.validate(m).is_ok() && self.mem.fits(m, &s, catalog) {
                    return Some(s);
                }
            }
        }
        None
    }

    /// Heterogeneous proposals: experts pick TP like the homogeneous case
    /// and split the pipeline between the two types; half the panel splits
    /// layers *equally* (the naive mistake the paper's Fig. 6 punishes),
    /// half proportionally to GPU speed.
    pub fn proposals_hetero(
        &self,
        m: &ModelSpec,
        catalog: &GpuCatalog,
        caps: &[(GpuType, usize)],
        total: usize,
    ) -> Vec<(ExpertPolicy, ParallelStrategy)> {
        self.policies
            .iter()
            .filter_map(|&p| {
                let proportional = matches!(
                    p,
                    ExpertPolicy::MegatronDefault
                        | ExpertPolicy::TpHeavy
                        | ExpertPolicy::CommMinimizer
                );
                self.propose_hetero(p, m, catalog, caps, total, proportional).map(|s| (p, s))
            })
            .collect()
    }

    fn propose_hetero(
        &self,
        policy: ExpertPolicy,
        m: &ModelSpec,
        catalog: &GpuCatalog,
        caps: &[(GpuType, usize)],
        total: usize,
        proportional: bool,
    ) -> Option<ParallelStrategy> {
        if caps.len() < 2 {
            return None;
        }
        // Fast type first (experts put the fast GPUs at the pipeline head).
        let mut order: Vec<(GpuType, usize)> = caps.to_vec();
        order.sort_by(|a, b| {
            catalog
                .spec(b.0)
                .peak_flops()
                .partial_cmp(&catalog.spec(a.0).peak_flops())
                .unwrap()
        });
        let (fast, fast_cap) = order[0];
        let (slow, slow_cap) = order[1];
        let speed_ratio =
            catalog.spec(fast).peak_flops() / catalog.spec(slow).peak_flops();

        let tps = valid_tps(m, catalog, total);
        let tp = match policy {
            ExpertPolicy::DpPurist | ExpertPolicy::CommMinimizer => tps.first().copied()?,
            _ => tps.last().copied()?,
        };
        let mbs = if policy == ExpertPolicy::CommMinimizer { 4 } else { 1 };

        // Try pipeline depths shallow→deep; pick the first that fits.
        for pp in 2..=m.layers.min(total / tp) {
            if total % (tp * pp) != 0 {
                continue;
            }
            let dp = total / (tp * pp);
            let group = tp * dp;
            let max_fast = fast_cap / group;
            let max_slow = slow_cap / group;
            if max_fast == 0 || max_slow == 0 {
                continue;
            }
            // Fill fast stages to capacity, remainder on the slow type.
            let m_fast = max_fast.min(pp - 1).max(1);
            let m_slow = pp - m_fast;
            if m_slow == 0 || m_slow > max_slow {
                continue;
            }
            // Layer split: equal or speed-proportional, integer-feasible.
            let n = m.layers;
            let target = if proportional {
                // n_fast/n_slow ≈ speed_ratio
                n as f64 * speed_ratio / (m_fast as f64 * speed_ratio + m_slow as f64)
            } else {
                n as f64 / pp as f64
            };
            let mut best: Option<(usize, usize)> = None;
            let mut best_err = f64::INFINITY;
            for n_fast in 1..=(n - m_slow) / m_fast {
                let rem = n - m_fast * n_fast;
                if rem % m_slow != 0 {
                    continue;
                }
                let n_slow = rem / m_slow;
                if n_slow == 0 {
                    continue;
                }
                let err = (n_fast as f64 - target).abs();
                if err < best_err {
                    best_err = err;
                    best = Some((n_fast, n_slow));
                }
            }
            let (n_fast, n_slow) = best?;
            let s = ParallelStrategy {
                cluster: ClusterAssignment {
                    segments: vec![
                        Segment { gpu: fast, stages: m_fast, layers_per_stage: n_fast },
                        Segment { gpu: slow, stages: m_slow, layers_per_stage: n_slow },
                    ],
                },
                tp,
                dp,
                micro_batch: mbs,
                global_batch: m.global_batch,
                vpp: 1,
                sequence_parallel: tp > 1,
                use_distributed_optimizer: true,
                recompute: if policy == ExpertPolicy::MemoryConservative {
                    Recompute::Full
                } else {
                    Recompute::None
                },
                recompute_method: RecomputeMethod::Uniform,
                recompute_num_layers: if policy == ExpertPolicy::MemoryConservative {
                    n_fast.min(pp)
                } else {
                    0
                },
                offload_optimizer: policy == ExpertPolicy::MemoryConservative,
                overlap_grad_reduce: true,
                overlap_param_gather: true,
                overlap_p2p: true,
                tp_comm_overlap: true,
                use_flash_attn: true,
            ep: 1,
            };
            if m.global_batch % (dp * mbs) == 0
                && s.validate(m).is_ok()
                && self.mem.fits(m, &s, catalog)
            {
                return Some(s);
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelRegistry;

    fn setup() -> (ModelRegistry, GpuCatalog, ExpertPanel) {
        (ModelRegistry::builtin(), GpuCatalog::builtin(), ExpertPanel::default())
    }

    #[test]
    fn panel_produces_proposals_for_paper_grid() {
        let (reg, cat, panel) = setup();
        let a800 = cat.find("a800").unwrap();
        for model in reg.paper_seven() {
            for count in [32usize, 128, 256, 1024] {
                let props = panel.proposals(model, &cat, a800, count);
                assert!(
                    props.len() >= 2,
                    "{} @ {count}: only {} expert proposals",
                    model.name,
                    props.len()
                );
                for (p, s) in &props {
                    s.validate(model).unwrap_or_else(|e| {
                        panic!("{} {} invalid: {e}", model.name, p.name())
                    });
                    assert_eq!(s.num_gpus(), count, "{} {}", model.name, p.name());
                }
            }
        }
    }

    #[test]
    fn dp_purist_avoids_model_parallelism_when_possible() {
        let (reg, cat, panel) = setup();
        let m = reg.get("llama2-7b").unwrap();
        let a800 = cat.find("a800").unwrap();
        let s = panel.propose(ExpertPolicy::DpPurist, m, &cat, a800, 64).unwrap();
        assert_eq!(s.tp, 1);
        assert_eq!(s.pp(), 1);
        assert_eq!(s.dp, 64);
    }

    #[test]
    fn pp_heavy_builds_deep_pipelines() {
        let (reg, cat, panel) = setup();
        let m = reg.get("llama2-70b").unwrap();
        let a800 = cat.find("a800").unwrap();
        let s = panel.propose(ExpertPolicy::PpHeavy, m, &cat, a800, 256).unwrap();
        assert!(s.pp() >= 8, "pp-heavy produced pp={}", s.pp());
    }

    #[test]
    fn hetero_proposals_use_both_types() {
        let (reg, cat, panel) = setup();
        let m = reg.get("llama2-13b").unwrap();
        let caps = vec![(cat.find("a800").unwrap(), 512), (cat.find("h100").unwrap(), 512)];
        let props = panel.proposals_hetero(m, &cat, &caps, 256);
        assert!(props.len() >= 2);
        for (p, s) in &props {
            assert!(s.cluster.is_heterogeneous(), "{} not hetero", p.name());
            assert_eq!(s.num_gpus(), 256);
            s.validate(m).unwrap();
        }
    }

    #[test]
    fn proportional_experts_give_fast_gpu_more_layers() {
        let (reg, cat, panel) = setup();
        let m = reg.get("llama2-13b").unwrap();
        let h100 = cat.find("h100").unwrap();
        let caps = vec![(cat.find("a800").unwrap(), 512), (h100, 512)];
        let s = panel
            .propose_hetero(ExpertPolicy::MegatronDefault, m, &cat, &caps, 256, true)
            .unwrap();
        let fast_seg = s.cluster.segments.iter().find(|seg| seg.gpu == h100).unwrap();
        let slow_seg = s.cluster.segments.iter().find(|seg| seg.gpu != h100).unwrap();
        assert!(fast_seg.layers_per_stage > slow_seg.layers_per_stage);
    }
}
