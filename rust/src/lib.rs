//! # Astra — automatic parallel-strategy search on heterogeneous GPUs
//!
//! Reproduction of *"Astra: Efficient and Money-saving Automatic Parallel
//! Strategies Search on Heterogeneous GPUs"* (cs.DC 2025).
//!
//! Astra searches the cross-product of Megatron-LM parallelization
//! parameters and GPU-pool configurations for the throughput-optimal (or
//! money-optimal) hybrid parallel strategy, using an analytic cost model
//! whose per-operator efficiency factors are predicted by a gradient-boosted
//! tree ensemble, and a closed-form heterogeneous pipeline time model
//! (Eq. 22/23 of the paper).
//!
//! ## Architecture (three layers)
//!
//! * **Layer 3 (this crate)** — the coordinator: GPU pools, strategy
//!   enumeration, rule/memory filters, heterogeneous partition solver,
//!   Pareto/money selection, the discrete-event ground-truth simulator and
//!   the benchmark harness.
//! * **Layer 2 (python/compile/model.py)** — the batched JAX scorer graph,
//!   AOT-lowered to HLO text in `artifacts/`.
//! * **Layer 1 (python/compile/kernels/)** — Pallas kernels: batched GBDT
//!   forest inference and batched pipeline-time evaluation.
//!
//! The [`runtime`] module loads the AOT artifacts through PJRT so that no
//! Python runs on the search path. The [`coordinator`] compiles every
//! request mode into a search-plan IR ([`coordinator::SearchPlan`]) and
//! runs it through one streaming executor; scoring uses either the
//! `native` pure-rust engine or the `hlo` engine — both implement
//! identical math (parity-tested) behind the same pipeline.
//!
//! ## Quickstart
//!
//! ```no_run
//! use astra::prelude::*;
//!
//! let catalog = GpuCatalog::builtin();
//! let model = ModelRegistry::builtin().get("llama2-7b").unwrap().clone();
//! let req = SearchRequest::homogeneous("a800", 64, model).unwrap();
//! let engine = AstraEngine::new(catalog, EngineConfig::default());
//! let report = engine.search(&req).unwrap();
//! println!("best: {}", report.best().unwrap().summary());
//! ```
//!
//! ## The service layer
//!
//! The [`service`] module turns the one-shot engine into a long-running,
//! multi-tenant search service: requests are canonicalized into stable
//! [`service::Fingerprint`]s (order-insensitive, config-aware), repeats are
//! served from a sharded LRU result cache in microseconds, concurrent
//! identical requests coalesce onto a single search (single-flight), and a
//! batched admission queue fans distinct requests out over the scoped
//! worker pool. The engine side is [`coordinator::ScoringCore`] — the
//! `Sync` scoring entry point one process shares across request threads.
//!
//! ```no_run
//! use astra::prelude::*;
//!
//! let core = ScoringCore::new(GpuCatalog::builtin(), EngineConfig::default());
//! let service = SearchService::new(core, ServiceConfig::default());
//! let model = ModelRegistry::builtin().get("llama2-7b").unwrap().clone();
//! let req = SearchRequest::homogeneous("a800", 64, model).unwrap();
//! let cold = service.handle(&req).unwrap();   // runs the engine
//! let warm = service.handle(&req).unwrap();   // served from the cache
//! assert_eq!(cold.fingerprint, warm.fingerprint);
//! ```
//!
//! On the command line, `astra serve` reads one JSON request per line from
//! stdin (or a TCP socket via `--listen host:port`) and emits one JSON
//! report per line; `astra batch <file>` scores a file of requests
//! concurrently through the same admission queue. The wire format is
//! documented in [`service::server`]:
//!
//! ```text
//! $ echo '{"model":"llama2-7b","gpu":"a800","gpus":64}' | astra serve
//! {"best":{…},"engine":{…},"fingerprint":"…","ok":true,"source":"search",…}
//! ```

pub mod bench_util;
pub mod cli;
pub mod coordinator;
pub mod cost;
pub mod expert;
pub mod gbdt;
pub mod gpu;
pub mod hetero;
pub mod hw;
pub mod json;
pub mod logging;
pub mod memory;
pub mod model;
pub mod pareto;
pub mod persist;
pub mod pool;
pub mod pricing;
pub mod prng;
pub mod report;
pub mod resilience;
pub mod rules;
pub mod runtime;
pub mod service;
pub mod simulator;
pub mod strategy;
pub mod telemetry;

/// Convenience re-exports for downstream users and the examples.
pub mod prelude {
    pub use crate::coordinator::{
        AstraEngine, EngineConfig, ScoredStrategy, ScoringCore, SearchReport, SearchRequest,
    };
    pub use crate::service::{
        CacheConfig, Fingerprint, SearchService, ServiceConfig, ServiceResponse,
    };
    pub use crate::cost::{CostBreakdown, CostModel, MemoStats, SharedCostMemo};
    pub use crate::expert::ExpertPanel;
    pub use crate::gpu::{GpuCatalog, GpuSpec, GpuType};
    pub use crate::hetero::HeteroSolver;
    pub use crate::memory::MemoryModel;
    pub use crate::model::{ModelRegistry, ModelSpec};
    pub use crate::pareto::{DominancePruner, MoneyModel, OptimalPool};
    pub use crate::persist::{RestoreStats, SpillStats};
    pub use crate::pricing::{PriceBook, PriceEntry};
    pub use crate::resilience::{CancelToken, RetryPolicy};
    pub use crate::rules::RuleSet;
    pub use crate::simulator::{PipelineSimulator, SimConfig};
    pub use crate::strategy::{GpuPoolMode, ParallelStrategy, SearchSpace, SpaceConfig};
}

/// Crate-wide error type. Hand-rolled (no `thiserror` in the offline image).
#[derive(Debug)]
pub enum AstraError {
    /// JSON syntax or type error, with byte offset context.
    Json(String),
    /// Rule DSL parse/eval error.
    Rule(String),
    /// Invalid search request / configuration.
    Config(String),
    /// Strategy space or solver inconsistency.
    Search(String),
    /// PJRT / artifact loading failure.
    Runtime(String),
    /// Filesystem error.
    Io(std::io::Error),
    /// Request deadline exceeded (cooperative cancellation; see
    /// [`resilience::CancelToken`]). Never carries a partial report.
    Deadline(String),
    /// Admission queue full — shed load. The only *retryable* kind: the
    /// wire layer marks it `"retryable":true` and `astra batch` backs off
    /// and retries it client-side.
    Overloaded(String),
    /// Injected or isolated internal fault (failpoints, degraded seams).
    Fault(String),
    /// A request handler panicked; the panic was caught and isolated by
    /// the service layer instead of killing the serve loop.
    Panicked(String),
}

impl std::fmt::Display for AstraError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AstraError::Json(m) => write!(f, "json error: {m}"),
            AstraError::Rule(m) => write!(f, "rule error: {m}"),
            AstraError::Config(m) => write!(f, "config error: {m}"),
            AstraError::Search(m) => write!(f, "search error: {m}"),
            AstraError::Runtime(m) => write!(f, "runtime error: {m}"),
            AstraError::Io(e) => write!(f, "io error: {e}"),
            AstraError::Deadline(m) => write!(f, "deadline error: {m}"),
            AstraError::Overloaded(m) => write!(f, "overloaded: {m}"),
            AstraError::Fault(m) => write!(f, "fault: {m}"),
            AstraError::Panicked(m) => write!(f, "panic: {m}"),
        }
    }
}

impl AstraError {
    /// Stable machine-readable kind tag, carried on wire error responses
    /// (`"kind"`) and across the single-flight slot so coalesced waiters
    /// receive the same typed error as the search leader.
    pub fn kind(&self) -> &'static str {
        match self {
            AstraError::Json(_) => "json",
            AstraError::Rule(_) => "rule",
            AstraError::Config(_) => "config",
            AstraError::Search(_) => "search",
            AstraError::Runtime(_) => "runtime",
            AstraError::Io(_) => "io",
            AstraError::Deadline(_) => "deadline",
            AstraError::Overloaded(_) => "overloaded",
            AstraError::Fault(_) => "fault",
            AstraError::Panicked(_) => "panic",
        }
    }

    /// Whether a client should retry the identical request after backoff.
    /// Only load shedding qualifies: every other kind is deterministic
    /// (same request, same failure) or needs operator attention.
    pub fn retryable(&self) -> bool {
        matches!(self, AstraError::Overloaded(_))
    }

    /// The inner message without the `Display` kind prefix (used when an
    /// error is rebuilt from `(kind, message)` across the single-flight
    /// slot — re-wrapping the full `Display` would stack prefixes).
    pub fn message(&self) -> String {
        match self {
            AstraError::Json(m)
            | AstraError::Rule(m)
            | AstraError::Config(m)
            | AstraError::Search(m)
            | AstraError::Runtime(m)
            | AstraError::Deadline(m)
            | AstraError::Overloaded(m)
            | AstraError::Fault(m)
            | AstraError::Panicked(m) => m.clone(),
            AstraError::Io(e) => e.to_string(),
        }
    }

    /// Rebuild a typed error from a [`kind`](AstraError::kind) tag and a
    /// message (errors are not `Clone`; the service layer fans one leader
    /// error out to every coalesced waiter). Unknown tags degrade to
    /// `Search`. `"io"` rebuilds as `Fault`: the original `io::Error`
    /// cannot be reconstructed and waiters only need kind + text.
    pub fn from_kind(kind: &str, msg: String) -> AstraError {
        match kind {
            "json" => AstraError::Json(msg),
            "rule" => AstraError::Rule(msg),
            "config" => AstraError::Config(msg),
            "runtime" => AstraError::Runtime(msg),
            "deadline" => AstraError::Deadline(msg),
            "overloaded" => AstraError::Overloaded(msg),
            "fault" | "io" => AstraError::Fault(msg),
            "panic" => AstraError::Panicked(msg),
            _ => AstraError::Search(msg),
        }
    }
}

impl std::error::Error for AstraError {}

impl From<std::io::Error> for AstraError {
    fn from(e: std::io::Error) -> Self {
        AstraError::Io(e)
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, AstraError>;
