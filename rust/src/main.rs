//! `astra` CLI — the Layer-3 entry point.
//!
//! Subcommands:
//!   search       run a strategy search (mode 1/2/3 per §3.2)
//!   hetero-cost  heterogeneous money search: sweep mixed pools under
//!                per-type caps and a budget, print the (tokens/s, USD)
//!                Pareto frontier and the within-budget pick
//!   frontier     the budget-free version of hetero-cost: sweep mixed
//!                pools under per-type caps and print the *full*
//!                (tokens/s, USD) Pareto curve — priced through
//!                `--price-book`/`--spot`, re-priceable from cache without
//!                re-searching when only rates change
//!   explain      run an audited search and render the decision audit:
//!                per-round, per-pool admitted-vs-pruned outcomes with the
//!                certifying evidence, candidate funnels, speculation waste
//!                and winner/runner-up margins (`--json` prints the
//!                canonical audit JSON — byte-identical at any worker or
//!                wave count)
//!   simulate     replay one strategy on the discrete-event simulator
//!   validate     cost model vs simulator accuracy over top-k strategies
//!   serve        long-running search service (stdin or TCP, JSON lines);
//!                `--warm-dir` restores warm state on boot and spills it
//!                every N admissions and on clean shutdown; `--deadline-ms`
//!                bounds every request without its own wire deadline and
//!                `--max-queue` sheds cold requests past the depth bound
//!   batch        score a file of JSON requests through the admission queue
//!                (retrying shed requests per `--retries`, seeded backoff)
//!   warm         save | load | inspect a warm-start snapshot
//!                (`astra warm save w.jsonl --model … --gpus …` runs the
//!                configured search to heat the memo, then spills it)
//!   stats        print the service statistics line (with --warm-dir:
//!                after restoring, so operators can see registry state
//!                across restarts; `--metrics-text` dumps the telemetry
//!                registry in Prometheus text format instead)
//!   health       print the live-ops health line the wire `{"cmd":"health"}`
//!                returns: readiness, queue depth, warm-restore state and
//!                rolling-window p50/p95/p99 latency + hit/shed/deadline/
//!                panic rates per mode
//!   trace-check  validate a flight-recorder trace file: every line must
//!                parse as JSON and carry a nondecreasing numeric `ts`
//!   info         print the GPU catalog and model registry
//!
//! `--trace <path>` (or `ASTRA_TRACE=<path>`) turns on the flight
//! recorder for any search-running command; span events stream to the
//! file as Chrome-trace JSONL without changing the picks.

use astra::cli::Cli;
use astra::coordinator::{AstraEngine, EngineConfig, ScoringCore, ScoringEngine, SearchRequest};
use astra::gpu::GpuCatalog;
use astra::model::ModelRegistry;
use astra::pareto::MoneyModel;
use astra::report::{fmt_secs, Table};
use astra::rules::RuleSet;
use astra::service::server::{run_batch_lines, run_serve_loop, serve_tcp, ServeOpts};
use astra::service::{CacheConfig, SearchService, ServiceConfig};
use astra::simulator::{PipelineSimulator, SimConfig};
use astra::strategy::GpuPoolMode;
use std::sync::Arc;
use std::time::Duration;

fn main() {
    let cli = Cli::new(
        "astra",
        "automatic parallel-strategy search on homogeneous and heterogeneous GPUs",
    )
    .positional("command", "search | hetero-cost | frontier | explain | simulate | validate | serve | batch | warm | stats | health | trace-check | info")
    .opt("model", "model name (see `astra info`)", Some("llama2-7b"))
    .opt("gpu", "GPU type for homogeneous/cost modes", Some("a800"))
    .opt("gpus", "cluster GPU count", Some("64"))
    .opt("mode", "homogeneous | heterogeneous | cost | hetero-cost | frontier", Some("homogeneous"))
    .opt("hetero", "hetero caps, e.g. 'a800:2048,h100:7168'", None)
    .opt("max-money", "money ceiling in USD (cost modes)", None)
    .opt("price-book", "rate card JSON (default: builtin data/price_book.json card)", None)
    .opt("train-tokens", "token budget used for pricing", Some("1e9"))
    .opt("engine", "native | hlo", Some("native"))
    .opt("rules", "path to a rule file (defaults to the paper's rules)", None)
    .opt("top", "how many strategies to print", Some("5"))
    .opt("listen", "serve over TCP on host:port instead of stdin", None)
    .opt("max-batch", "requests admitted per service batch", Some("32"))
    .opt("deadline-ms", "default per-request deadline in ms (0 = unlimited; wire deadline_ms wins)", Some("0"))
    .opt("max-queue", "max cold requests past admission before shedding (0 = unbounded)", Some("1024"))
    .opt("retries", "client-side retries of shed (retryable) requests (batch)", Some("3"))
    .opt("retry-base-ms", "base backoff delay in ms for --retries", Some("25"))
    .opt("retry-seed", "seed for the deterministic retry jitter", Some("42"))
    .opt("cache-entries", "service cache capacity (reports)", Some("1024"))
    .opt("cache-mb", "service cache byte budget (MiB)", Some("256"))
    .opt("cache-ttl-secs", "service cache TTL in seconds (0 = none)", Some("0"))
    .opt("warm-dir", "directory for the warm-start snapshot (serve/stats)", None)
    .opt("warm-spill-every", "spill after every N admissions (0 = shutdown only)", Some("32"))
    .opt("warm-max-bytes", "snapshot byte budget; LRU scopes dropped first (0 = unlimited)", Some("0"))
    .opt("warm-load", "restore a warm snapshot before searching (search)", None)
    .opt("warm-save", "spill the memo to a snapshot after searching (search)", None)
    .opt("trace", "stream flight-recorder span events to this JSONL file", None)
    .flag("metrics-text", "print the telemetry registry as Prometheus text (stats)")
    .flag("warm-no-cache", "persist memo scopes only, not the result cache (serve)")
    .flag("json", "print the canonical report JSON instead of tables (search)")
    .flag("audit", "attach the search decision audit (search/hetero-cost; see `astra explain`)")
    .flag("exhaustive", "exhaustive Eq.23 layer enumeration (hetero)")
    .flag("spot", "bill at spot rates instead of on-demand")
    .flag("no-prune", "disable branch-and-bound pool pruning (hetero-cost)")
    .flag("no-streaming", "serial oracle: execute the plan with workers=1 and wave=1")
    .flag("no-forest", "use analytic η instead of the trained GBDT")
    .flag("verbose", "debug logging");
    let args = cli.parse();

    if args.flag("verbose") {
        astra::logging::set_level(astra::logging::Level::Debug);
    }

    let command = args.positionals().first().cloned().unwrap_or_else(|| "search".into());
    if let Err(e) = run(&command, &args) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

/// Engine config shared by the one-shot and service paths.
fn build_config(args: &astra::cli::Args) -> astra::Result<EngineConfig> {
    let rules = match args.get("rules") {
        Some(path) => RuleSet::from_text(&std::fs::read_to_string(path)?)?,
        None => RuleSet::paper_defaults(),
    };
    let engine_kind = match args.get("engine").unwrap() {
        "hlo" => ScoringEngine::Hlo,
        _ => ScoringEngine::Native,
    };
    let mut book = match args.get("price-book") {
        Some(path) => astra::pricing::PriceBook::from_file(std::path::Path::new(path))?,
        None => astra::pricing::PriceBook::builtin(),
    };
    book.use_spot = args.flag("spot");
    Ok(EngineConfig {
        rules,
        engine: engine_kind,
        use_forests: !args.flag("no-forest"),
        hetero_exhaustive: args.flag("exhaustive"),
        money_prune: !args.flag("no-prune"),
        streaming: !args.flag("no-streaming"),
        money: MoneyModel { train_tokens: args.get_f64("train-tokens")?, book },
        top_k: args.get_usize("top")?.max(5),
        ..Default::default()
    })
}

fn build_service(args: &astra::cli::Args, catalog: GpuCatalog) -> astra::Result<SearchService> {
    let mut config = build_config(args)?;
    if config.engine == ScoringEngine::Hlo {
        // The PJRT handle is thread-confined; the multi-threaded service
        // always scores through the Sync native core.
        astra::log_warn!("service mode scores natively; ignoring --engine hlo");
        config.engine = ScoringEngine::Native;
    }
    let ttl = args.get_usize("cache-ttl-secs")?;
    let cache = CacheConfig {
        max_entries: args.get_usize("cache-entries")?.max(1),
        max_bytes: args.get_usize("cache-mb")?.max(1) << 20,
        ttl: (ttl > 0).then(|| Duration::from_secs(ttl as u64)),
        ..Default::default()
    };
    let warm = astra::service::WarmConfig {
        dir: args.get("warm-dir").map(std::path::PathBuf::from),
        spill_every: args.get_usize("warm-spill-every")? as u64,
        include_cache: !args.flag("warm-no-cache"),
        max_snapshot_bytes: args.get_usize("warm-max-bytes")? as u64,
    };
    let service_cfg = ServiceConfig {
        cache,
        max_batch: args.get_usize("max-batch")?.max(1),
        warm,
        default_deadline_ms: args.get_usize("deadline-ms")? as u64,
        max_queue_depth: args.get_usize("max-queue")?,
        ..Default::default()
    };
    Ok(SearchService::new(ScoringCore::new(catalog, config), service_cfg))
}

fn run(command: &str, args: &astra::cli::Args) -> astra::Result<()> {
    // ASTRA_TRACE first (so the recorder covers everything), --trace wins
    // when both are given.
    astra::telemetry::trace::init_from_env();
    if let Some(path) = args.get("trace") {
        astra::telemetry::trace::enable(std::path::Path::new(path))?;
    }

    let catalog = GpuCatalog::builtin();
    let registry = ModelRegistry::builtin();

    if command == "info" {
        let mut t = Table::new(&["gpu", "mem GiB", "bf16 TFLOPs", "NVLink GB/s", "inter GB/s", "$/h"]);
        for s in catalog.all() {
            t.row(&[
                s.name.clone(),
                format!("{:.0}", s.mem_gib),
                format!("{:.0}", s.peak_tflops_bf16),
                format!("{:.0}", s.nvlink_gbs),
                format!("{:.0}", s.internode_gbs),
                format!("{:.2}", s.price_per_hour),
            ]);
        }
        t.emit("GPU catalog", None);
        let mut m = Table::new(&["model", "layers", "hidden", "heads", "ffn", "vocab", "params"]);
        for spec in registry.all() {
            m.row(&[
                spec.name.clone(),
                spec.layers.to_string(),
                spec.hidden.to_string(),
                spec.heads.to_string(),
                spec.ffn.to_string(),
                spec.vocab.to_string(),
                format!("{:.1}B", spec.total_params() / 1e9),
            ]);
        }
        m.emit("Model registry", None);
        return Ok(());
    }

    if command == "serve" {
        let service = build_service(args, catalog)?;
        // No server-side retries: a remote client owns its retry budget;
        // retrying shed work inside the server would defeat the shedding.
        let opts = ServeOpts {
            max_batch: service.config().max_batch,
            top: args.get_usize("top")?,
            retries: 0,
            ..Default::default()
        };
        return match args.get("listen") {
            Some(addr) => serve_tcp(Arc::new(service), addr, &opts),
            None => {
                // BufReader<Stdin> (not StdinLock: the reader thread needs
                // a Send handle).
                let stdin = std::io::BufReader::new(std::io::stdin());
                let mut stdout = std::io::stdout().lock();
                // Spill before propagating any loop error — a failed write
                // to stdout must not also discard the accumulated warmth.
                let stats = run_serve_loop(&service, stdin, &mut stdout, &opts);
                spill_on_exit(&service);
                let stats = stats?;
                eprintln!(
                    "served {} lines ({} ok, {} errors); engine searches: {}",
                    stats.lines,
                    stats.ok,
                    stats.errors,
                    service.core().searches_run()
                );
                Ok(())
            }
        };
    }

    if command == "stats" {
        // Build the service (restoring any configured warm snapshot) and
        // print the same stats payload the wire `{"cmd":"stats"}` returns —
        // registry/persistence state stays observable across restarts.
        let service = build_service(args, catalog)?;
        if args.flag("metrics-text") {
            // Restore-on-boot above already folded persistence/cache state
            // into the registry; dump it Prometheus-style.
            print!("{}", astra::telemetry::registry_text());
            return Ok(());
        }
        println!(
            "{}",
            astra::json::to_string_pretty(&astra::service::server::stats_json(&service))
        );
        return Ok(());
    }

    if command == "health" {
        // One-shot view of the wire `{"cmd":"health"}` line: build the
        // service (restoring any configured warm snapshot so readiness
        // reflects warm state) and print the same JSON an operator's probe
        // would see. The window covers everything since boot — this
        // process served no traffic, so rates are the idle-window zeros.
        let service = build_service(args, catalog)?;
        println!(
            "{}",
            astra::json::to_string_pretty(&astra::service::server::health_json(&service))
        );
        return Ok(());
    }

    if command == "trace-check" {
        let path = args.positionals().get(1).ok_or_else(|| {
            astra::AstraError::Config("usage: astra trace-check <trace.jsonl>".into())
        })?;
        let text = std::fs::read_to_string(path)?;
        let mut last_ts = f64::NEG_INFINITY;
        let mut events = 0usize;
        for (i, line) in text.lines().enumerate() {
            if line.trim().is_empty() {
                continue;
            }
            let v = astra::json::parse(line).map_err(|e| {
                astra::AstraError::Config(format!("line {}: not valid JSON: {e}", i + 1))
            })?;
            let ts = v.get("ts").and_then(astra::json::Value::as_f64).ok_or_else(|| {
                astra::AstraError::Config(format!("line {}: missing numeric 'ts'", i + 1))
            })?;
            if ts < last_ts {
                return Err(astra::AstraError::Config(format!(
                    "line {}: ts {ts} < previous {last_ts} — trace not monotonic",
                    i + 1
                )));
            }
            last_ts = ts;
            events += 1;
        }
        println!("trace ok: {events} event(s), ts monotonic");
        return Ok(());
    }

    if command == "batch" {
        let path = args.positionals().get(1).ok_or_else(|| {
            astra::AstraError::Config("usage: astra batch <requests.jsonl>".into())
        })?;
        let text = std::fs::read_to_string(path)?;
        let service = build_service(args, catalog)?;
        // Batch is its own client: shed requests retry here with seeded
        // exponential backoff instead of surfacing as transient errors.
        let opts = ServeOpts {
            max_batch: service.config().max_batch,
            top: args.get_usize("top")?,
            retries: args.get_usize("retries")? as u32,
            retry_base_ms: args.get_usize("retry-base-ms")? as u64,
            retry_seed: args.get_usize("retry-seed")? as u64,
        };
        let t0 = std::time::Instant::now();
        let mut stdout = std::io::stdout().lock();
        let stats = run_batch_lines(&service, &text, &mut stdout, &opts);
        spill_on_exit(&service);
        let stats = stats?;
        let secs = t0.elapsed().as_secs_f64();
        let cache = service.cache_stats();
        eprintln!(
            "batch: {} requests in {:.2}s ({:.1} req/s) — {} searches, {} cache hits, {} errors",
            stats.lines,
            secs,
            stats.lines as f64 / secs.max(1e-9),
            service.core().searches_run(),
            cache.hits,
            stats.errors
        );
        return Ok(());
    }

    let model = registry.get(args.get("model").unwrap())?.clone();
    let count = args.get_usize("gpus")?;
    let hetero_cost_mode = |args: &astra::cli::Args| -> astra::Result<GpuPoolMode> {
        let spec = args.get("hetero").ok_or_else(|| {
            astra::AstraError::Config("--hetero 'type:cap,type:cap' required".into())
        })?;
        let caps = catalog.parse_caps(spec)?;
        let max_money = args.get_f64("max-money").unwrap_or(f64::INFINITY);
        Ok(GpuPoolMode::HeteroCost { caps, max_money })
    };
    let frontier_mode = |args: &astra::cli::Args| -> astra::Result<GpuPoolMode> {
        let spec = args.get("hetero").ok_or_else(|| {
            astra::AstraError::Config("--hetero 'type:cap,type:cap' required".into())
        })?;
        if args.get("max-money").is_some() {
            return Err(astra::AstraError::Config(
                "--max-money does not apply to frontier mode (the full Pareto curve \
                 is returned); use hetero-cost for a budgeted pick"
                    .into(),
            ));
        }
        let caps = catalog.parse_caps(spec)?;
        Ok(GpuPoolMode::Frontier { caps })
    };
    let mode = if command == "hetero-cost" {
        hetero_cost_mode(args)?
    } else if command == "frontier" {
        frontier_mode(args)?
    } else {
        match args.get("mode").unwrap() {
            "homogeneous" => {
                let gpu = catalog.find(args.get("gpu").unwrap())?;
                GpuPoolMode::Homogeneous { gpu, count }
            }
            "heterogeneous" => {
                let spec = args.get("hetero").ok_or_else(|| {
                    astra::AstraError::Config("--hetero 'type:cap,type:cap' required".into())
                })?;
                let caps = catalog.parse_caps(spec)?;
                GpuPoolMode::Heterogeneous { total: count, caps }
            }
            "cost" => {
                let gpu = catalog.find(args.get("gpu").unwrap())?;
                let max_money = args.get_f64("max-money").unwrap_or(f64::INFINITY);
                GpuPoolMode::Cost { gpu, max_count: count, max_money }
            }
            "hetero-cost" => hetero_cost_mode(args)?,
            "frontier" => frontier_mode(args)?,
            other => {
                return Err(astra::AstraError::Config(format!("unknown mode '{other}'")));
            }
        }
    };

    let config = build_config(args)?;
    let engine = AstraEngine::new(catalog.clone(), config);
    let req = SearchRequest { mode, model: model.clone() };

    match command {
        "search" => {
            if let Some(p) = args.get("warm-load") {
                let st = engine.core().load_warm(std::path::Path::new(p))?;
                eprintln!(
                    "warm: restored {} scope(s) ({} stage + {} sync rows), rejected {}",
                    st.scopes_restored, st.stage_rows, st.sync_rows, st.scopes_rejected
                );
            }
            let report = if args.flag("audit") {
                engine.search_audited(&req)?
            } else {
                engine.search(&req)?
            };
            if args.flag("json") {
                // Canonical result view (no wall-clock / memo fields):
                // byte-stable across runs, which the ci.sh persistence
                // roundtrip lane diffs cold-vs-restored.
                println!(
                    "{}",
                    astra::json::to_string_pretty(&astra::report::report_json(
                        &report, &catalog
                    ))
                );
                if args.flag("audit") {
                    if let Some(a) = astra::report::audit_json(&report) {
                        println!("{}", astra::json::to_string_pretty(&a));
                    }
                }
            } else {
                print_report(&model.name, &report, args.get_usize("top")?);
                if let Some(a) = &report.audit {
                    print_audit(a);
                }
            }
            if let Some(p) = args.get("warm-save") {
                let st = engine.core().save_warm(std::path::Path::new(p))?;
                eprintln!("warm: spilled {} scope(s), {} bytes to {p}", st.scopes, st.bytes);
            }
        }
        "explain" => {
            // The audit is assembled by the executor's serial replay, so
            // the canonical JSON below is byte-identical at any worker or
            // wave count (the human table additionally shows the
            // load-dependent memo/speculation observability).
            let report = engine.search_audited(&req)?;
            if args.flag("json") {
                let audit = astra::report::audit_json(&report).ok_or_else(|| {
                    astra::AstraError::Config("audited search returned no audit".into())
                })?;
                println!("{}", astra::json::to_string_pretty(&audit));
            } else {
                print_report(&model.name, &report, args.get_usize("top")?);
                match &report.audit {
                    Some(a) => print_audit(a),
                    None => {
                        return Err(astra::AstraError::Config(
                            "audited search returned no audit".into(),
                        ))
                    }
                }
            }
        }
        "warm" => {
            let usage = "usage: astra warm save|load|inspect <file> [search flags]";
            let action = args.positionals().get(1).cloned().unwrap_or_default();
            let file = args
                .positionals()
                .get(2)
                .ok_or_else(|| astra::AstraError::Config(usage.into()))?
                .clone();
            let path = std::path::Path::new(&file);
            match action.as_str() {
                "save" => {
                    // Heat the memo with the flag-configured search, then
                    // spill — a prewarming tool for the serve fleet.
                    let report = engine.search(&req)?;
                    let budget = args.get_usize("warm-max-bytes")? as u64;
                    let st = engine.core().save_warm_within(path, budget)?;
                    println!(
                        "warmed by 1 search ({} scored); spilled {} scope(s), {} bytes to {}",
                        report.scored,
                        st.scopes,
                        st.bytes,
                        path.display()
                    );
                }
                "load" => {
                    let st = engine.core().load_warm(path)?;
                    println!(
                        "restored {} scope(s) ({} stage + {} sync rows), rejected {}",
                        st.scopes_restored, st.stage_rows, st.sync_rows, st.scopes_rejected
                    );
                }
                "inspect" => {
                    let text = std::fs::read_to_string(path)?;
                    let meta = astra::persist::EngineMeta::of_core(engine.core());
                    let mut t = Table::new(&["kind", "scope", "rows", "status"]);
                    for info in astra::persist::inspect(&text, &meta) {
                        t.row(&[info.kind, info.detail, info.rows.to_string(), info.status]);
                    }
                    t.emit(&format!("warm snapshot {}", path.display()), None);
                }
                other => {
                    return Err(astra::AstraError::Config(format!(
                        "unknown warm action '{other}' — {usage}"
                    )));
                }
            }
        }
        "hetero-cost" => {
            let report = if args.flag("audit") {
                engine.search_audited(&req)?
            } else {
                engine.search(&req)?
            };
            print_report(&model.name, &report, args.get_usize("top")?);
            let max_money = match &req.mode {
                GpuPoolMode::HeteroCost { max_money, .. } => *max_money,
                _ => f64::INFINITY,
            };
            println!(
                "pruned pools: {} (branch-and-bound{})",
                report.pruned_pools,
                if max_money.is_finite() { "" } else { ", no money ceiling" }
            );
            let mut t = Table::new(&["tokens/s", "run cost USD", "gpus", "within budget"]);
            for e in report.pool.entries() {
                // Frontier entries index the pre-ranking scored list; the
                // per-entry GPU mix is recovered from the matching top
                // strategy when it survived ranking.
                let gpus = report
                    .top
                    .iter()
                    .find(|s| {
                        (s.money_usd - e.cost).abs() < 1e-9
                            && (s.cost.tokens_per_s - e.throughput).abs() < 1e-6
                    })
                    .map(|s| {
                        s.strategy
                            .cluster
                            .gpus_by_type(s.strategy.tp, s.strategy.dp)
                            .iter()
                            .map(|&(g, n)| format!("{}×{}", n, catalog.spec(g).name))
                            .collect::<Vec<_>>()
                            .join("+")
                    })
                    // Entries ranked out of `top` (beyond --top strategies)
                    // have no recoverable mix; mark rather than blank.
                    .unwrap_or_else(|| "(beyond top-k)".to_string());
                t.row(&[
                    format!("{:.0}", e.throughput),
                    format!("{:.0}", e.cost),
                    gpus,
                    if e.cost <= max_money { "yes".into() } else { String::new() },
                ]);
            }
            t.emit("Pareto frontier over mixed pools (tokens/s vs USD)", None);
            match report.best() {
                Some(best) if best.money_usd <= max_money => println!(
                    "\nselected: {:.0} tokens/s for ${:.0} — {}",
                    best.cost.tokens_per_s,
                    best.money_usd,
                    best.strategy.summary()
                ),
                _ => println!("\nno strategy fits the budget — raise it or relax the caps"),
            }
            if let Some(a) = &report.audit {
                print_audit(a);
            }
        }
        "frontier" => {
            let report = engine.search(&req)?;
            if args.flag("json") {
                println!(
                    "{}",
                    astra::json::to_string_pretty(&astra::report::report_json(
                        &report, &catalog
                    ))
                );
            } else {
                print_report(&model.name, &report, args.get_usize("top")?);
                let empty = Vec::new();
                let cands =
                    report.frontier.as_ref().map(|f| &f.candidates).unwrap_or(&empty);
                let mut t = Table::new(&["tokens/s", "run cost USD", "gpus", "strategy"]);
                for e in report.pool.entries() {
                    // Unlike the hetero-cost table's approximate float
                    // match, every frontier point joins exactly to its
                    // scored strategy through the shared index space.
                    let Some(c) = cands.iter().find(|c| c.idx == e.idx) else { continue };
                    let gpus = c
                        .scored
                        .strategy
                        .cluster
                        .gpus_by_type(c.scored.strategy.tp, c.scored.strategy.dp)
                        .iter()
                        .map(|&(g, n)| format!("{}×{}", n, catalog.spec(g).name))
                        .collect::<Vec<_>>()
                        .join("+");
                    t.row(&[
                        format!("{:.0}", e.throughput),
                        format!("{:.0}", e.cost),
                        gpus,
                        c.scored.strategy.summary(),
                    ]);
                }
                t.emit("full (tokens/s, USD) Pareto frontier over mixed pools", None);
                println!(
                    "\n{} frontier point(s); rate-only price-book changes re-price \
                     this curve from cache without re-searching",
                    report.pool.len()
                );
            }
        }
        "simulate" | "validate" => {
            let report = engine.search(&req)?;
            let sim = PipelineSimulator::new(catalog, SimConfig::default());
            let n = if command == "simulate" { 1 } else { args.get_usize("top")? };
            let mut t = Table::new(&["strategy", "predicted", "simulated", "accuracy"]);
            for s in report.top.iter().take(n) {
                let r = sim.measure(&model, &s.strategy);
                let acc = 1.0 - (s.cost.step_time - r.step_time).abs() / r.step_time;
                t.row(&[
                    s.strategy.summary(),
                    fmt_secs(s.cost.step_time),
                    fmt_secs(r.step_time),
                    format!("{:.1}%", acc * 100.0),
                ]);
            }
            t.emit("cost model vs discrete-event simulator", None);
        }
        other => {
            return Err(astra::AstraError::Config(format!(
                "unknown command '{other}' (search | hetero-cost | frontier | explain | simulate | validate | serve | batch | warm | stats | health | trace-check | info)"
            )));
        }
    }
    Ok(())
}

/// Final spill for the serve/batch front ends (clean shutdown half of the
/// warm policy); failures are reported, never fatal.
fn spill_on_exit(service: &SearchService) {
    match service.spill_warm() {
        Ok(Some(s)) => eprintln!(
            "warm spill: {} scope(s), {} cache entries, {} bytes",
            s.scopes, s.cache_entries, s.bytes
        ),
        Ok(None) => {}
        Err(e) => eprintln!("warm spill failed: {e}"),
    }
}

/// Human rendering of the search decision audit (`astra explain`,
/// `--audit`). Unlike the canonical `report::audit_json`, this view also
/// shows the load-dependent observability: per-pool memo hit rates and the
/// per-wave speculation-waste totals.
fn print_audit(a: &astra::coordinator::SearchAudit) {
    use astra::coordinator::AuditDecision;
    println!(
        "\naudit: {} pool(s) — {} admitted, {} pruned on budget, {} pruned by dominance",
        a.pool_count(),
        a.admitted(),
        a.pruned_budget(),
        a.pruned_dominated()
    );
    let mut t = Table::new(&[
        "round", "pool", "gpus", "tp", "dp", "ub tokens/s", "lb USD", "decision", "evidence",
    ]);
    for r in &a.rounds {
        for p in &r.pools {
            let gpus = p
                .gpus
                .iter()
                .map(|(g, n)| format!("{n}×{g}"))
                .collect::<Vec<_>>()
                .join("+");
            let evidence = match p.decision {
                AuditDecision::Admitted => p
                    .funnel
                    .map(|f| {
                        format!(
                            "funnel {}→{} scored ({} rules, {} mem; memo {}/{})",
                            f.expanded,
                            f.scored,
                            f.rules_rejected,
                            f.mem_rejected,
                            f.memo_hits,
                            f.memo_hits + f.memo_misses
                        )
                    })
                    .unwrap_or_default(),
                AuditDecision::PrunedBudget { lb_usd, budget } => {
                    format!("lb ${lb_usd:.0} > budget ${budget:.0}")
                }
                AuditDecision::PrunedDominated { by: (tput, usd) } => {
                    format!("dominated by {tput:.0} tokens/s @ ${usd:.0}")
                }
            };
            t.row(&[
                r.round.to_string(),
                p.pool.to_string(),
                gpus,
                p.tp.to_string(),
                p.dp.to_string(),
                if p.ub_tput.is_finite() { format!("{:.0}", p.ub_tput) } else { "inf".into() },
                format!("{:.0}", p.lb_usd),
                p.decision.tag().to_string(),
                evidence,
            ]);
        }
    }
    t.emit("search decision audit (serial-replay order)", None);
    if !a.waves.is_empty() {
        let speculated: usize = a.waves.iter().map(|w| w.speculated).sum();
        let wasted: usize = a.waves.iter().map(|w| w.wasted).sum();
        println!(
            "speculation: {} wave(s), {} pool(s) speculated, {} wasted (load-dependent)",
            a.waves.len(),
            speculated,
            wasted
        );
    }
    if let Some(m) = &a.margins {
        println!(
            "winner: {} — step {}, {:.0} tokens/s, ${:.0}",
            m.winner.summary,
            fmt_secs(m.winner.step_time_s),
            m.winner.tokens_per_s,
            m.winner.money_usd
        );
        match &m.runner_up {
            Some(r) => println!(
                "runner-up: {} — margins: step {:+.4}s, {:+.0} tokens/s, {:+.0} USD",
                r.summary, m.step_time_margin_s, m.tokens_per_s_margin, m.money_margin_usd
            ),
            None => println!("runner-up: none (a single strategy survived ranking)"),
        }
    }
}

fn print_report(model: &str, report: &astra::coordinator::SearchReport, top: usize) {
    println!(
        "\nmodel={model}  |S|={} generated, {} rule-filtered, {} memory-filtered, {} scored",
        report.generated, report.rule_filtered, report.mem_filtered, report.scored
    );
    println!(
        "search {}  simulation {}  e2e {}",
        fmt_secs(report.search_secs),
        fmt_secs(report.simulate_secs),
        fmt_secs(report.e2e_secs())
    );
    let mut t = Table::new(&["#", "strategy", "step", "tokens/s", "MFU", "run cost"]);
    for (i, s) in report.top.iter().take(top).enumerate() {
        t.row(&[
            (i + 1).to_string(),
            s.strategy.summary(),
            fmt_secs(s.cost.step_time),
            format!("{:.0}", s.cost.tokens_per_s),
            format!("{:.3}", s.cost.mfu),
            format!("${:.0}", s.money_usd),
        ]);
    }
    t.emit("best strategies", None);
}
