//! Declarative CLI-argument substrate (no `clap` offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional args,
//! subcommands, typed getters with defaults, `--help` generation, and
//! unknown-flag rejection. Used by the `astra` binary, the examples, and
//! every bench target (they accept `--fast`, `--csv <path>`, etc.).

use crate::{AstraError, Result};
use std::collections::BTreeMap;

/// One declared option.
#[derive(Debug, Clone)]
struct OptSpec {
    name: String,
    help: String,
    takes_value: bool,
    default: Option<String>,
}

/// Declarative parser builder.
#[derive(Debug, Clone, Default)]
pub struct Cli {
    program: String,
    about: String,
    opts: Vec<OptSpec>,
    positional: Vec<(String, String)>, // (name, help)
}

/// Parse result: resolved values.
#[derive(Debug, Clone, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: BTreeMap<String, bool>,
    positional: Vec<String>,
}

impl Cli {
    pub fn new(program: &str, about: &str) -> Self {
        Cli { program: program.into(), about: about.into(), ..Default::default() }
    }

    /// Declare a boolean flag (`--name`).
    pub fn flag(mut self, name: &str, help: &str) -> Self {
        self.opts.push(OptSpec {
            name: name.into(),
            help: help.into(),
            takes_value: false,
            default: None,
        });
        self
    }

    /// Declare a valued option (`--name <v>`), with optional default.
    pub fn opt(mut self, name: &str, help: &str, default: Option<&str>) -> Self {
        self.opts.push(OptSpec {
            name: name.into(),
            help: help.into(),
            takes_value: true,
            default: default.map(String::from),
        });
        self
    }

    /// Declare a positional argument (documentation only; all positionals
    /// are collected in order).
    pub fn positional(mut self, name: &str, help: &str) -> Self {
        self.positional.push((name.into(), help.into()));
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nUSAGE:\n  {}", self.program, self.about, self.program);
        for (p, _) in &self.positional {
            s.push_str(&format!(" <{p}>"));
        }
        s.push_str(" [OPTIONS]\n");
        if !self.positional.is_empty() {
            s.push_str("\nARGS:\n");
            for (p, h) in &self.positional {
                s.push_str(&format!("  <{p:<18}> {h}\n"));
            }
        }
        s.push_str("\nOPTIONS:\n");
        for o in &self.opts {
            let left = if o.takes_value {
                format!("--{} <v>", o.name)
            } else {
                format!("--{}", o.name)
            };
            let def = match &o.default {
                Some(d) => format!(" [default: {d}]"),
                None => String::new(),
            };
            s.push_str(&format!("  {left:<24} {}{def}\n", o.help));
        }
        s.push_str("  --help                   print this help\n");
        s
    }

    /// Parse from an explicit token list (tests) — `argv` excludes argv[0].
    pub fn parse_from(&self, argv: &[String]) -> Result<Args> {
        let mut args = Args::default();
        for o in &self.opts {
            if let Some(d) = &o.default {
                args.values.insert(o.name.clone(), d.clone());
            }
        }
        let mut it = argv.iter().peekable();
        while let Some(tok) = it.next() {
            if tok == "--help" || tok == "-h" {
                return Err(AstraError::Config(format!("HELP\n{}", self.usage())));
            }
            if let Some(rest) = tok.strip_prefix("--") {
                let (name, inline) = match rest.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (rest, None),
                };
                let spec = self
                    .opts
                    .iter()
                    .find(|o| o.name == name)
                    .ok_or_else(|| AstraError::Config(format!("unknown option --{name}")))?;
                if spec.takes_value {
                    let v = match inline {
                        Some(v) => v,
                        None => it
                            .next()
                            .cloned()
                            .ok_or_else(|| AstraError::Config(format!("--{name} needs a value")))?,
                    };
                    args.values.insert(name.to_string(), v);
                } else {
                    if inline.is_some() {
                        return Err(AstraError::Config(format!("--{name} takes no value")));
                    }
                    args.flags.insert(name.to_string(), true);
                }
            } else {
                args.positional.push(tok.clone());
            }
        }
        Ok(args)
    }

    /// Parse from the process environment. On `--help`, prints usage and
    /// exits 0; on error prints the message and exits 2.
    pub fn parse(&self) -> Args {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        match self.parse_from(&argv) {
            Ok(a) => a,
            Err(AstraError::Config(msg)) if msg.starts_with("HELP\n") => {
                println!("{}", &msg[5..]);
                std::process::exit(0);
            }
            Err(e) => {
                eprintln!("{e}\n\n{}", self.usage());
                std::process::exit(2);
            }
        }
    }
}

impl Args {
    pub fn flag(&self, name: &str) -> bool {
        self.flags.get(name).copied().unwrap_or(false)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(String::as_str)
    }

    pub fn get_usize(&self, name: &str) -> Result<usize> {
        match self.get(name) {
            None => Err(AstraError::Config(format!("missing --{name}"))),
            Some(v) => v
                .parse()
                .map_err(|_| AstraError::Config(format!("--{name}: '{v}' is not an integer"))),
        }
    }

    pub fn get_f64(&self, name: &str) -> Result<f64> {
        match self.get(name) {
            None => Err(AstraError::Config(format!("missing --{name}"))),
            Some(v) => v
                .parse()
                .map_err(|_| AstraError::Config(format!("--{name}: '{v}' is not a number"))),
        }
    }

    pub fn positionals(&self) -> &[String] {
        &self.positional
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(s: &str) -> Vec<String> {
        s.split_whitespace().map(String::from).collect()
    }

    fn demo() -> Cli {
        Cli::new("demo", "test tool")
            .flag("fast", "run fast")
            .opt("gpus", "gpu count", Some("64"))
            .opt("model", "model name", None)
            .positional("cmd", "subcommand")
    }

    #[test]
    fn defaults_and_overrides() {
        let a = demo().parse_from(&toks("search --model llama2-7b")).unwrap();
        assert_eq!(a.get_usize("gpus").unwrap(), 64);
        assert_eq!(a.get("model"), Some("llama2-7b"));
        assert_eq!(a.positionals(), &["search".to_string()]);
        assert!(!a.flag("fast"));
    }

    #[test]
    fn equals_form_and_flags() {
        let a = demo().parse_from(&toks("--gpus=128 --fast")).unwrap();
        assert_eq!(a.get_usize("gpus").unwrap(), 128);
        assert!(a.flag("fast"));
    }

    #[test]
    fn rejects_unknown_and_missing_value() {
        assert!(demo().parse_from(&toks("--nope")).is_err());
        assert!(demo().parse_from(&toks("--model")).is_err());
        assert!(demo().parse_from(&toks("--fast=1")).is_err());
    }

    #[test]
    fn help_is_error_variant() {
        let err = demo().parse_from(&toks("--help")).unwrap_err();
        match err {
            AstraError::Config(m) => assert!(m.contains("USAGE")),
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn typed_errors() {
        let a = demo().parse_from(&toks("--gpus abc")).unwrap();
        assert!(a.get_usize("gpus").is_err());
    }
}
