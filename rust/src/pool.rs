//! Scoped worker-pool substrate (no `rayon` offline).
//!
//! Built on `std::thread::scope`, so workers may borrow from the caller's
//! stack. Two primitives cover every parallel site in Astra:
//!
//! * [`par_map_chunks`] — split a slice into contiguous chunks, map each
//!   chunk on a worker, concatenate results in order (used by the scorer).
//! * [`par_for_indices`] — dynamic work-stealing over an index range via an
//!   atomic cursor (used by per-GPU-configuration search fan-out where item
//!   costs are wildly uneven).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Mutex, PoisonError};

/// Number of workers to use: `ASTRA_THREADS` env override, else available
/// parallelism, else 4.
pub fn default_workers() -> usize {
    if let Ok(s) = std::env::var("ASTRA_THREADS") {
        if let Ok(n) = s.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

/// Map `f` over contiguous chunks of `items` in parallel, preserving order.
/// `f` receives `(chunk_start_index, chunk)` and returns a Vec of per-item
/// outputs (must be `chunk.len()` long).
pub fn par_map_chunks<T: Sync, R: Send>(
    items: &[T],
    workers: usize,
    f: impl Fn(usize, &[T]) -> Vec<R> + Sync,
) -> Vec<R> {
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.max(1).min(n);
    if workers == 1 {
        return f(0, items);
    }
    let chunk = n.div_ceil(workers);
    let mut slots: Vec<Option<Vec<R>>> = (0..workers).map(|_| None).collect();
    std::thread::scope(|s| {
        let f = &f;
        let mut handles = Vec::new();
        for (w, slot) in slots.iter_mut().enumerate() {
            let start = w * chunk;
            if start >= n {
                break;
            }
            let end = ((w + 1) * chunk).min(n);
            let part = &items[start..end];
            handles.push(s.spawn(move || {
                let out = f(start, part);
                assert_eq!(out.len(), part.len(), "par_map_chunks: f must be 1:1");
                *slot = Some(out);
            }));
        }
        for h in handles {
            h.join().expect("worker panicked");
        }
    });
    let mut out = Vec::with_capacity(n);
    for s in slots.into_iter().flatten() {
        out.extend(s);
    }
    out
}

/// Dynamically schedule indices `0..n` over `workers` threads; each worker
/// calls `f(i)` and pushes the result; results are returned sorted by index.
pub fn par_for_indices<R: Send>(
    n: usize,
    workers: usize,
    f: impl Fn(usize) -> R + Sync,
) -> Vec<R> {
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.max(1).min(n);
    if workers == 1 {
        return (0..n).map(f).collect();
    }
    let cursor = AtomicUsize::new(0);
    let results: Mutex<Vec<(usize, R)>> = Mutex::new(Vec::with_capacity(n));
    std::thread::scope(|s| {
        let f = &f;
        let cursor = &cursor;
        let results = &results;
        let mut handles = Vec::new();
        for _ in 0..workers {
            handles.push(s.spawn(move || {
                let mut local: Vec<(usize, R)> = Vec::new();
                loop {
                    let i = cursor.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    local.push((i, f(i)));
                }
                // Poison-tolerant: if a sibling worker panicked inside `f`
                // (e.g. an injected fault), this worker's results are still
                // valid — the panic re-raises at the join below either way.
                results.lock().unwrap_or_else(PoisonError::into_inner).extend(local);
            }));
        }
        for h in handles {
            // A panicking `f` propagates to the caller thread here, where
            // the service layer's `catch_unwind` isolates it per-request.
            h.join().expect("worker panicked");
        }
    });
    let mut pairs = results.into_inner().unwrap_or_else(PoisonError::into_inner);
    pairs.sort_by_key(|(i, _)| *i);
    pairs.into_iter().map(|(_, r)| r).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_chunks_order_preserved() {
        let xs: Vec<u64> = (0..1000).collect();
        let out = par_map_chunks(&xs, 7, |_, chunk| chunk.iter().map(|x| x * 2).collect());
        assert_eq!(out, (0..1000).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn map_chunks_start_index_correct() {
        let xs = vec![(); 100];
        let out = par_map_chunks(&xs, 3, |start, chunk| {
            (0..chunk.len()).map(|i| start + i).collect()
        });
        assert_eq!(out, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn indices_dynamic_all_covered() {
        let out = par_for_indices(257, 5, |i| i * i);
        assert_eq!(out.len(), 257);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
    }

    #[test]
    fn empty_and_single() {
        let e: Vec<u32> = par_for_indices(0, 4, |_| 0u32);
        assert!(e.is_empty());
        let one = par_map_chunks(&[5u32], 8, |_, c| c.to_vec());
        assert_eq!(one, vec![5]);
    }

    #[test]
    fn workers_more_than_items() {
        let xs: Vec<u32> = (0..3).collect();
        let out = par_map_chunks(&xs, 64, |_, c| c.iter().map(|x| x + 1).collect());
        assert_eq!(out, vec![1, 2, 3]);
    }

    // The service admission queue leans on these primitives for fan-out;
    // pin the degenerate shapes it feeds them.

    #[test]
    fn map_chunks_empty_input() {
        let xs: Vec<u32> = Vec::new();
        let out = par_map_chunks(&xs, 8, |_, c| c.to_vec());
        assert!(out.is_empty());
    }

    #[test]
    fn indices_fewer_items_than_workers() {
        let out = par_for_indices(3, 64, |i| i + 1);
        assert_eq!(out, vec![1, 2, 3]);
        let one = par_for_indices(1, 16, |i| i);
        assert_eq!(one, vec![0]);
    }

    #[test]
    fn single_worker_is_sequential_and_complete() {
        let xs: Vec<u64> = (0..100).collect();
        let mapped = par_map_chunks(&xs, 1, |start, c| {
            assert_eq!(start, 0, "one worker sees the whole slice");
            c.iter().map(|x| x * 3).collect()
        });
        assert_eq!(mapped, (0..100).map(|x| x * 3).collect::<Vec<_>>());
        let idx = par_for_indices(100, 1, |i| i * 3);
        assert_eq!(idx, (0..100).map(|i| i * 3).collect::<Vec<_>>());
    }

    #[test]
    fn zero_workers_clamped_to_one() {
        let xs: Vec<u32> = (0..10).collect();
        let out = par_map_chunks(&xs, 0, |_, c| c.to_vec());
        assert_eq!(out, xs);
        let idx = par_for_indices(10, 0, |i| i);
        assert_eq!(idx, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn empty_input_with_zero_workers() {
        // The degenerate corner of both degenerate cases at once: the
        // streaming coordinator can legitimately produce an empty task
        // list (every pool pruned) under a clamped worker count.
        let xs: Vec<u32> = Vec::new();
        let out = par_map_chunks(&xs, 0, |_, c| c.to_vec());
        assert!(out.is_empty());
        let idx: Vec<usize> = par_for_indices(0, 0, |i| i);
        assert!(idx.is_empty());
    }

    #[test]
    fn results_identical_across_worker_counts() {
        // The determinism contract the streaming scorer leans on: output
        // order is input order for every worker count, including
        // non-divisible splits.
        let xs: Vec<u64> = (0..137).collect();
        let expect: Vec<u64> = xs.iter().map(|x| x * 7 + 1).collect();
        for workers in [0, 1, 2, 3, 5, 16, 200] {
            let mapped = par_map_chunks(&xs, workers, |_, c| {
                c.iter().map(|x| x * 7 + 1).collect()
            });
            assert_eq!(mapped, expect, "par_map_chunks drifted at workers={workers}");
            let idx = par_for_indices(137, workers, |i| xs[i] * 7 + 1);
            assert_eq!(idx, expect, "par_for_indices drifted at workers={workers}");
        }
    }
}
