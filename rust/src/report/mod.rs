//! ASCII-table and CSV rendering for the CLI, examples and benches.
//!
//! All benches print their paper artifact through [`Table`] so the output
//! rows line up with the paper's tables/figures, plus a machine-readable
//! CSV dump next to it (EXPERIMENTS.md is compiled from these).

/// A simple column-aligned table.
#[derive(Debug, Clone, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Table {
        Table { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    /// Convenience for mixed literal rows.
    pub fn row_strs(&mut self, cells: &[&str]) -> &mut Self {
        let owned: Vec<String> = cells.iter().map(|s| s.to_string()).collect();
        self.row(&owned)
    }

    pub fn render(&self) -> String {
        let cols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for c in 0..cols {
                widths[c] = widths[c].max(r[c].len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::from("|");
            for (c, cell) in cells.iter().enumerate() {
                line.push_str(&format!(" {:<w$} |", cell, w = widths[c]));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('|');
        for w in &widths {
            out.push_str(&format!("{:-<w$}|", "", w = w + 2));
        }
        out.push('\n');
        for r in &self.rows {
            out.push_str(&fmt_row(r, &widths));
        }
        out
    }

    pub fn csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = String::new();
        out.push_str(&self.header.iter().map(|h| esc(h)).collect::<Vec<_>>().join(","));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&r.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }

    /// Print the table and, if `csv_path` is set, also write the CSV.
    pub fn emit(&self, title: &str, csv_path: Option<&std::path::Path>) {
        println!("\n## {title}\n");
        print!("{}", self.render());
        if let Some(p) = csv_path {
            if let Err(e) = std::fs::write(p, self.csv()) {
                crate::log_warn!("could not write {p:?}: {e}");
            } else {
                println!("(csv: {})", p.display());
            }
        }
    }
}

/// Machine-readable form of one scored strategy — the payload of the
/// service wire protocol's `best` / `top` fields and of bench CSV siblings.
/// GPUs are identified by catalog *name* (like every other wire field), not
/// by internal index, so responses stay meaningful across catalog reorders.
pub fn scored_strategy_json(
    s: &crate::coordinator::ScoredStrategy,
    catalog: &crate::gpu::GpuCatalog,
) -> crate::json::Value {
    use crate::json::Value;
    let segments: Vec<Value> = s
        .strategy
        .cluster
        .segments
        .iter()
        .map(|seg| {
            Value::obj()
                .set("gpu", catalog.spec(seg.gpu).name.as_str())
                .set("stages", seg.stages)
                .set("layers_per_stage", seg.layers_per_stage)
        })
        .collect();
    Value::obj()
        .set("tp", s.strategy.tp)
        .set("pp", s.strategy.pp())
        .set("dp", s.strategy.dp)
        .set("mbs", s.strategy.micro_batch)
        .set("gbs", s.strategy.global_batch)
        .set("vpp", s.strategy.vpp)
        .set("ep", s.strategy.ep)
        .set("sequence_parallel", s.strategy.sequence_parallel)
        .set("distributed_optimizer", s.strategy.use_distributed_optimizer)
        .set("recompute", s.strategy.recompute.as_str())
        .set("recompute_method", s.strategy.recompute_method.as_str())
        .set("recompute_num_layers", s.strategy.recompute_num_layers)
        .set("offload_optimizer", s.strategy.offload_optimizer)
        .set("num_gpus", s.strategy.num_gpus())
        .set("segments", Value::Arr(segments))
        .set("step_time_s", s.cost.step_time)
        .set("tokens_per_s", s.cost.tokens_per_s)
        .set("mfu", s.cost.mfu)
        .set("money_usd", s.money_usd)
        .set("summary", s.strategy.summary())
}

/// Canonical *result* view of a whole [`crate::coordinator::SearchReport`]:
/// every deterministic field — counts, pruning statistics, the ranked `top`
/// list and the full Pareto pool — and none of the observability fields
/// (wall times, memo hit/miss counters), which legitimately vary run to
/// run. Two searches that select identically serialize byte-identically
/// here; the determinism and differential test suites compare exactly this
/// string across worker counts, sweep-wave sizes and the parallel executor
/// vs the serial workers=1/wave=1 oracle.
pub fn report_json(
    r: &crate::coordinator::SearchReport,
    catalog: &crate::gpu::GpuCatalog,
) -> crate::json::Value {
    use crate::json::Value;
    let top: Vec<Value> = r.top.iter().map(|s| scored_strategy_json(s, catalog)).collect();
    let pool: Vec<Value> = r
        .pool
        .entries()
        .iter()
        .map(|e| {
            Value::obj()
                .set("idx", e.idx)
                .set("throughput", e.throughput)
                .set("cost", e.cost)
        })
        .collect();
    let out = Value::obj()
        .set("generated", r.generated)
        .set("rule_filtered", r.rule_filtered)
        .set("mem_filtered", r.mem_filtered)
        .set("scored", r.scored)
        .set("pruned_pools", r.pruned_pools)
        .set("pruned_budget", r.pruned_budget)
        .set("pruned_dominated", r.pruned_dominated)
        .set("top", Value::Arr(top))
        .set("pool", Value::Arr(pool));
    match frontier_json(r, catalog) {
        Some(f) => out.set("frontier", f),
        None => out,
    }
}

/// Non-finite-safe number rendering: JSON has no `inf`, so the audit's
/// unbounded pool bounds serialize as the string `"inf"` (the same idiom
/// as [`crate::coordinator::plan_json`]).
fn num_or_inf(x: f64) -> crate::json::Value {
    if x.is_finite() {
        crate::json::Value::Num(x)
    } else {
        crate::json::Value::Str("inf".to_string())
    }
}

/// Canonical JSON view of a report's decision audit
/// ([`crate::coordinator::SearchAudit`]); `None` for unaudited reports.
///
/// Canonical means *deterministic*: like [`report_json`], this view holds
/// only fields that are byte-identical across worker counts and wave
/// schedules — every pool's decision with its certifying evidence, the
/// admitted pools' candidate funnels, and the winner/runner-up margins.
/// The audit's load-dependent observability (per-pool memo hit/miss, the
/// per-wave speculation-waste records, funnels of pruned-but-speculated
/// pools) is deliberately excluded; `astra explain` shows it instead.
pub fn audit_json(r: &crate::coordinator::SearchReport) -> Option<crate::json::Value> {
    use crate::coordinator::{AuditContender, AuditDecision};
    use crate::json::Value;
    let a = r.audit.as_ref()?;
    let rounds: Vec<Value> = a
        .rounds
        .iter()
        .map(|round| {
            let pools: Vec<Value> = round
                .pools
                .iter()
                .map(|p| {
                    let mut gpus = Value::obj();
                    for (name, n) in &p.gpus {
                        gpus = gpus.set(name.as_str(), *n);
                    }
                    let mut v = Value::obj()
                        .set("pool", p.pool)
                        .set("gpus", gpus)
                        .set("tp", p.tp)
                        .set("dp", p.dp)
                        .set("ub_tput", num_or_inf(p.ub_tput))
                        .set("lb_usd", num_or_inf(p.lb_usd))
                        .set("decision", p.decision.tag());
                    match p.decision {
                        AuditDecision::Admitted => {
                            // Always present for admitted pools (they were
                            // streamed by construction); deterministic.
                            if let Some(f) = &p.funnel {
                                v = v.set(
                                    "funnel",
                                    Value::obj()
                                        .set("expanded", f.expanded)
                                        .set("rules_rejected", f.rules_rejected)
                                        .set("mem_rejected", f.mem_rejected)
                                        .set("scored", f.scored),
                                );
                            }
                        }
                        AuditDecision::PrunedBudget { lb_usd, budget } => {
                            v = v.set(
                                "evidence",
                                Value::obj()
                                    .set("lb_usd", lb_usd)
                                    .set("budget", num_or_inf(budget)),
                            );
                        }
                        AuditDecision::PrunedDominated { by } => {
                            v = v.set(
                                "evidence",
                                Value::obj()
                                    .set("dominated_by_tokens_per_s", by.0)
                                    .set("dominated_by_money_usd", by.1),
                            );
                        }
                    }
                    v
                })
                .collect();
            Value::obj()
                .set("round", round.round)
                .set("total", round.total)
                .set("pools", Value::Arr(pools))
        })
        .collect();
    let contender = |c: &AuditContender| {
        Value::obj()
            .set("summary", c.summary.as_str())
            .set("step_time_s", c.step_time_s)
            .set("tokens_per_s", c.tokens_per_s)
            .set("money_usd", c.money_usd)
    };
    let mut out = Value::obj()
        .set("astra_audit", 1u64)
        .set("pools", a.pool_count())
        .set("admitted", a.admitted())
        .set("pruned_budget", a.pruned_budget())
        .set("pruned_dominated", a.pruned_dominated())
        .set("rounds", Value::Arr(rounds));
    if let Some(m) = &a.margins {
        let mut mv = Value::obj()
            .set("winner", contender(&m.winner))
            .set("step_time_margin_s", m.step_time_margin_s)
            .set("tokens_per_s_margin", m.tokens_per_s_margin)
            .set("money_margin_usd", m.money_margin_usd);
        if let Some(ru) = &m.runner_up {
            mv = mv.set("runner_up", contender(ru));
        }
        out = out.set("margins", mv);
    }
    Some(out)
}

/// Canonical wire view of a frontier-mode result: the full Pareto curve in
/// Eq. 33 order (throughput descending), each point joined back to its
/// complete scored strategy through the pool/skeleton shared index space.
/// `None` for reports of the other modes (their wire shape is unchanged).
pub fn frontier_json(
    r: &crate::coordinator::SearchReport,
    catalog: &crate::gpu::GpuCatalog,
) -> Option<crate::json::Value> {
    use crate::json::Value;
    let fr = r.frontier.as_ref()?;
    let points: Vec<Value> = r
        .pool
        .entries()
        .iter()
        .filter_map(|e| {
            fr.candidates
                .iter()
                .find(|c| c.idx == e.idx)
                .map(|c| scored_strategy_json(&c.scored, catalog))
        })
        .collect();
    Some(
        Value::obj()
            .set("astra_frontier", 1u64)
            .set("count", points.len())
            .set("points", Value::Arr(points)),
    )
}

/// Human formatting helpers shared by benches.
pub fn fmt_tput(tokens_per_s: f64) -> String {
    format!("{tokens_per_s:.0}")
}

pub fn fmt_secs(s: f64) -> String {
    if s < 1e-3 {
        format!("{:.1}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{s:.2}s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["model", "tput"]);
        t.row_strs(&["llama2-7b", "123"]);
        t.row_strs(&["x", "4567890"]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines.iter().all(|l| l.len() == lines[0].len()));
    }

    #[test]
    fn csv_escaping() {
        let mut t = Table::new(&["a", "b"]);
        t.row_strs(&["x,y", "q\"z"]);
        assert_eq!(t.csv(), "a,b\n\"x,y\",\"q\"\"z\"\n");
    }

    #[test]
    #[should_panic]
    fn arity_checked() {
        Table::new(&["a", "b"]).row_strs(&["only-one"]);
    }
}
