//! Rolling-window arithmetic over registry snapshots — the math behind
//! `{"cmd":"health"}` / `astra health`.
//!
//! The registry's counters and histograms are *cumulative* since process
//! start. A live health surface wants *recent* behavior: p50/p95/p99
//! latency and hit/shed/deadline/panic rates over the last window, not
//! since boot. This module computes both from **snapshot deltas**: the
//! service keeps the previous snapshot as a baseline, takes a fresh one
//! per health check, and the difference is exactly the window's traffic.
//!
//! Deliberately lock-free with respect to the search path: a
//! [`HistSnapshot`] reads only the histogram's relaxed atomics (the same
//! reads `{"cmd":"metrics"}` does) — no search-path lock is ever taken,
//! so a health probe can't stall or be stalled by admissions.
//!
//! Quantiles come from the log₂ bucket layout (see
//! [`super::Histogram`]): the estimate walks the delta's cumulative
//! counts to the target rank and linearly interpolates inside the
//! containing bucket. With doubling buckets the estimate is within 2× of
//! the true latency — exactly the precision a readiness probe needs, for
//! free, from data the registry already collects.

use super::{bucket_bound, Histogram, HIST_BUCKETS};

/// A point-in-time copy of one histogram's non-cumulative bucket counts
/// (overflow last) plus the total observation count.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HistSnapshot {
    buckets: Vec<u64>,
    count: u64,
}

impl HistSnapshot {
    /// Snapshot a live histogram (relaxed atomic reads only).
    pub fn of(h: &Histogram) -> HistSnapshot {
        HistSnapshot { buckets: h.bucket_counts(), count: h.count() }
    }

    /// The all-zero snapshot — the baseline before any health check, so
    /// the first window covers everything since process start.
    pub fn zero() -> HistSnapshot {
        HistSnapshot { buckets: vec![0; HIST_BUCKETS + 1], count: 0 }
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    /// `self - earlier`, per bucket, saturating at zero. Counters only
    /// grow, so a negative delta means mismatched snapshots — saturation
    /// keeps the window honest instead of panicking in a probe.
    pub fn delta(&self, earlier: &HistSnapshot) -> HistSnapshot {
        let n = self.buckets.len().max(earlier.buckets.len());
        let at = |v: &[u64], i: usize| v.get(i).copied().unwrap_or(0);
        HistSnapshot {
            buckets: (0..n)
                .map(|i| at(&self.buckets, i).saturating_sub(at(&earlier.buckets, i)))
                .collect(),
            count: self.count.saturating_sub(earlier.count),
        }
    }

    /// Quantile estimate (`q` in `[0,1]`) by linear interpolation inside
    /// the log₂ bucket containing the target rank; `None` when the window
    /// saw no observations. Overflow-bucket ranks clamp to the top finite
    /// bound (there is no upper edge to interpolate toward).
    pub fn quantile(&self, q: f64) -> Option<f64> {
        let total: u64 = self.buckets.iter().sum();
        if total == 0 {
            return None;
        }
        let rank = ((q.clamp(0.0, 1.0) * total as f64).ceil() as u64).clamp(1, total);
        let mut before = 0u64;
        for (i, &n) in self.buckets.iter().enumerate() {
            if n == 0 {
                continue;
            }
            if before + n >= rank {
                if i == HIST_BUCKETS {
                    return Some(bucket_bound(HIST_BUCKETS - 1));
                }
                let lower = if i == 0 { 0.0 } else { bucket_bound(i - 1) };
                let upper = bucket_bound(i);
                let frac = (rank - before) as f64 / n as f64;
                return Some(lower + frac * (upper - lower));
            }
            before += n;
        }
        // Unreachable (total > 0 guarantees the loop returns); harmless.
        None
    }
}

/// The p50/p95/p99 triple of one window, `None` when the window is empty.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Percentiles {
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
}

/// Convenience: all three health percentiles of a delta snapshot at once.
pub fn percentiles(d: &HistSnapshot) -> Option<Percentiles> {
    Some(Percentiles {
        p50: d.quantile(0.50)?,
        p95: d.quantile(0.95)?,
        p99: d.quantile(0.99)?,
    })
}

/// Windowed rate `num/den` with the zero-traffic convention `0/0 = 0`
/// (an idle window is healthy, not NaN).
pub fn ratio(num: u64, den: u64) -> f64 {
    if den == 0 {
        0.0
    } else {
        num as f64 / den as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn delta_subtracts_per_bucket_and_saturates() {
        let h = Histogram::default();
        h.observe(0.5);
        h.observe(0.5);
        let early = HistSnapshot::of(&h);
        h.observe(0.5);
        h.observe(4.0);
        let late = HistSnapshot::of(&h);
        let d = late.delta(&early);
        assert_eq!(d.count(), 2);
        assert_eq!(d.buckets.iter().sum::<u64>(), 2);
        // Mismatched order saturates to zero instead of underflowing.
        let rev = early.delta(&late);
        assert_eq!(rev.count(), 0);
        assert_eq!(rev.buckets.iter().sum::<u64>(), 0);
    }

    #[test]
    fn empty_window_has_no_quantiles() {
        assert_eq!(HistSnapshot::zero().quantile(0.5), None);
        assert!(percentiles(&HistSnapshot::zero()).is_none());
    }

    #[test]
    fn quantiles_are_ordered_and_bucket_accurate() {
        let h = Histogram::default();
        // 90 fast observations, 10 slow ones: p50 must sit near the fast
        // bucket, p99 near the slow one.
        for _ in 0..90 {
            h.observe(0.01);
        }
        for _ in 0..10 {
            h.observe(2.0);
        }
        let d = HistSnapshot::of(&h).delta(&HistSnapshot::zero());
        let p = percentiles(&d).unwrap();
        assert!(p.p50 <= p.p95 && p.p95 <= p.p99, "quantiles must be monotone: {p:?}");
        // Log₂ buckets bound the estimate within 2× of the truth.
        assert!(p.p50 > 0.005 && p.p50 <= 0.02, "p50 {p:?}");
        assert!(p.p99 > 1.0 && p.p99 <= 4.0, "p99 {p:?}");
    }

    #[test]
    fn single_bucket_interpolates_inside_its_bounds() {
        let h = Histogram::default();
        for _ in 0..4 {
            h.observe(0.75); // bucket (0.5, 1.0]
        }
        let d = HistSnapshot::of(&h).delta(&HistSnapshot::zero());
        for q in [0.25, 0.5, 0.99] {
            let v = d.quantile(q).unwrap();
            assert!(v > 0.5 && v <= 1.0, "q={q} → {v} must stay inside the bucket");
        }
    }

    #[test]
    fn overflow_ranks_clamp_to_the_top_finite_bound() {
        let h = Histogram::default();
        h.observe(f64::INFINITY);
        let d = HistSnapshot::of(&h).delta(&HistSnapshot::zero());
        assert_eq!(d.quantile(0.5), Some(bucket_bound(HIST_BUCKETS - 1)));
    }

    #[test]
    fn ratio_treats_idle_as_zero() {
        assert_eq!(ratio(0, 0), 0.0);
        assert_eq!(ratio(1, 4), 0.25);
    }
}
